# Developer entry points. `make check` is what CI runs (see
# .github/workflows/ci.yml): build, tests, formatting, lints.

CARGO ?= cargo
RUST_DIR := rust

.PHONY: check build test fmt fmt-check clippy bench

check: build test fmt-check clippy

build:
	cd $(RUST_DIR) && $(CARGO) build --release

test:
	cd $(RUST_DIR) && $(CARGO) test -q

fmt:
	cd $(RUST_DIR) && $(CARGO) fmt

fmt-check:
	cd $(RUST_DIR) && $(CARGO) fmt --check

clippy:
	cd $(RUST_DIR) && $(CARGO) clippy -- -D warnings

bench:
	cd $(RUST_DIR) && $(CARGO) bench --bench micro_ops
