# Developer entry points. `make check` is what CI runs (see
# .github/workflows/ci.yml): build, tests, formatting, lints.

CARGO ?= cargo
RUST_DIR := rust

.PHONY: check build test fmt fmt-check clippy audit bench bench-smoke gemm-parity

check: build test fmt-check clippy audit

build:
	cd $(RUST_DIR) && $(CARGO) build --release

test:
	cd $(RUST_DIR) && $(CARGO) test -q

fmt:
	cd $(RUST_DIR) && $(CARGO) fmt

fmt-check:
	cd $(RUST_DIR) && $(CARGO) fmt --check

# `--all-targets` covers tests, benches and examples, not just the lib;
# `--workspace` pulls in tools/pallas-audit so the linter is linted too.
clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

# The project's own static-analysis pass (tools/pallas-audit): SAFETY
# justifications on every unsafe, copy-free GEMM paths, pool-only
# threading, determinism hazards, mandatory OpInfo samples. Writes
# audit_report.json at the repo root; exits non-zero on any violation
# not covered by tools/pallas-audit/allow/.
audit:
	$(CARGO) run -q --release -p pallas-audit

# Full sweep; writes BENCH_ops.json (per-op records), BENCH_train.json
# (end-to-end samples/sec + loader-stall at workers 0/1/4) and
# BENCH_serve.json (serving p50/p99 + req/s over the max_batch × clients
# grid) at the repo root — the per-PR trajectory. See "Threading and
# memory model" in rust/src/dispatch/mod.rs and "Reading
# BENCH_train.json" in README.md.
bench:
	cd $(RUST_DIR) && BENCH_OUT=$(abspath BENCH_ops.json) $(CARGO) bench --bench micro_ops
	cd $(RUST_DIR) && BENCH_OUT=$(abspath BENCH_train.json) $(CARGO) bench --bench train_loop
	cd $(RUST_DIR) && BENCH_OUT=$(abspath BENCH_serve.json) $(CARGO) bench --bench serve_loop

# Packed-GEMM parity suite: all four trans combos vs the oracle, plus
# bit-identical-across-threads and zero-materialization pins.
gemm-parity:
	cd $(RUST_DIR) && $(CARGO) test -q --test gemm_parity

# One tiny iteration of every benchmark + JSON schema validation (CI).
# Runs the GEMM parity suite first: the smoke numbers are meaningless if
# the kernel they time is wrong.
bench-smoke: gemm-parity
	cd $(RUST_DIR) && BENCH_SMOKE=1 BENCH_OUT=$(abspath BENCH_ops.json) $(CARGO) bench --bench micro_ops
	cd $(RUST_DIR) && BENCH_SMOKE=1 BENCH_OUT=$(abspath BENCH_train.json) $(CARGO) bench --bench train_loop
	cd $(RUST_DIR) && BENCH_SMOKE=1 BENCH_OUT=$(abspath BENCH_serve.json) $(CARGO) bench --bench serve_loop
