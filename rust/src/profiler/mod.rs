//! Built-in profiler (§6.1, Figure 1 / Figure 2 instrumentation).
//!
//! Records (track, name, start, end) spans on two kinds of tracks: the
//! host control-flow thread ([`Track::Host`]) and each device stream
//! ([`Track::Stream`]). The Figure 1 bench renders the two rows of the
//! paper's timeline from these spans; `to_chrome_trace` exports the same
//! data for chrome://tracing.
//!
//! Disabled (the default) it costs one relaxed atomic load per op.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Which timeline row a span belongs to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Track {
    /// Host control-flow thread: op dispatch, launches, sync waits.
    Host,
    /// Device stream `n`: kernel execution.
    Stream(u32),
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub track: Track,
    pub name: String,
    /// Nanoseconds since profiler start.
    pub start_ns: u64,
    pub end_ns: u64,
}

impl TraceEvent {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns - self.start_ns
    }
}

struct ProfilerState {
    events: Mutex<Vec<TraceEvent>>,
    epoch: Mutex<Instant>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
/// Cap so a forgotten profiler can't eat all memory.
const MAX_EVENTS: usize = 2_000_000;

static STATE: once_cell::sync::Lazy<ProfilerState> = once_cell::sync::Lazy::new(|| ProfilerState {
    events: Mutex::new(Vec::new()),
    epoch: Mutex::new(Instant::now()),
});

/// An in-flight span returned by [`begin`]; finish it with [`end`].
pub struct Span {
    track: Track,
    name: Option<String>,
    start_ns: u64,
}

/// Start profiling (clears previously recorded events).
pub fn start() {
    let mut ev = STATE.events.lock().unwrap();
    ev.clear();
    *STATE.epoch.lock().unwrap() = Instant::now();
    DROPPED.store(0, Ordering::Relaxed);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop profiling and return the recorded events.
pub fn stop() -> Vec<TraceEvent> {
    ENABLED.store(false, Ordering::SeqCst);
    std::mem::take(&mut *STATE.events.lock().unwrap())
}

/// Whether the profiler is currently recording.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    STATE.epoch.lock().unwrap().elapsed().as_nanos() as u64
}

/// Begin a span on `track`. Cheap no-op when the profiler is off.
#[inline]
pub fn begin(track: Track, name: &str) -> Span {
    if !enabled() {
        return Span { track, name: None, start_ns: 0 };
    }
    Span { track, name: Some(name.to_string()), start_ns: now_ns() }
}

/// Finish a span started with [`begin`].
#[inline]
pub fn end(span: Span) {
    let Some(name) = span.name else { return };
    if !enabled() {
        return;
    }
    let end_ns = now_ns();
    let mut ev = STATE.events.lock().unwrap();
    if ev.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    ev.push(TraceEvent { track: span.track, name, start_ns: span.start_ns, end_ns });
}

/// Record a closed span directly (used by subsystems that time themselves).
pub fn record(track: Track, name: &str, start_ns: u64, end_ns: u64) {
    if !enabled() {
        return;
    }
    let mut ev = STATE.events.lock().unwrap();
    if ev.len() < MAX_EVENTS {
        ev.push(TraceEvent { track, name: name.to_string(), start_ns, end_ns });
    }
}

/// Events recorded so far without stopping.
pub fn snapshot() -> Vec<TraceEvent> {
    STATE.events.lock().unwrap().clone()
}

/// Aggregate statistics per track for a set of events.
#[derive(Debug, Default, Clone, Copy)]
pub struct TrackStats {
    pub spans: usize,
    pub busy_ns: u64,
    pub first_start_ns: u64,
    pub last_end_ns: u64,
}

impl TrackStats {
    /// Wall-clock extent of the track.
    pub fn extent_ns(&self) -> u64 {
        self.last_end_ns.saturating_sub(self.first_start_ns)
    }
    /// Fraction of the extent the track was busy — "almost perfect device
    /// utilization" reads as utilization ≈ 1.0 on the stream track.
    pub fn utilization(&self) -> f64 {
        if self.extent_ns() == 0 {
            0.0
        } else {
            self.busy_ns as f64 / self.extent_ns() as f64
        }
    }
}

/// Compute per-track statistics.
pub fn track_stats(events: &[TraceEvent], track: Track) -> TrackStats {
    let mut st = TrackStats { first_start_ns: u64::MAX, ..Default::default() };
    for e in events.iter().filter(|e| e.track == track) {
        st.spans += 1;
        st.busy_ns += e.dur_ns();
        st.first_start_ns = st.first_start_ns.min(e.start_ns);
        st.last_end_ns = st.last_end_ns.max(e.end_ns);
    }
    if st.spans == 0 {
        st.first_start_ns = 0;
    }
    st
}

/// Render the paper's Figure-1-style two-row ASCII timeline: host on top,
/// one row per stream below, `width` characters across the time extent.
pub fn ascii_timeline(events: &[TraceEvent], width: usize) -> String {
    if events.is_empty() {
        return "(no events)".into();
    }
    let t0 = events.iter().map(|e| e.start_ns).min().unwrap();
    let t1 = events.iter().map(|e| e.end_ns).max().unwrap().max(t0 + 1);
    let scale = |t: u64| -> usize {
        (((t - t0) as u128 * (width as u128 - 1)) / (t1 - t0) as u128) as usize
    };
    let mut tracks: Vec<(String, Track)> = vec![("host  ".into(), Track::Host)];
    let mut stream_ids: Vec<u32> = events
        .iter()
        .filter_map(|e| match e.track {
            Track::Stream(i) => Some(i),
            _ => None,
        })
        .collect();
    stream_ids.sort_unstable();
    stream_ids.dedup();
    for id in stream_ids {
        tracks.push((format!("strm {id}"), Track::Stream(id)));
    }
    let mut out = String::new();
    for (label, track) in tracks {
        let mut row = vec![b'.'; width];
        for e in events.iter().filter(|e| e.track == track) {
            let (a, b) = (scale(e.start_ns), scale(e.end_ns).max(scale(e.start_ns)));
            let ch = e.name.bytes().next().unwrap_or(b'#');
            for c in row.iter_mut().take(b + 1).skip(a) {
                *c = if *c == b'.' { ch } else { b'#' };
            }
        }
        out.push_str(&label);
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push_str("|\n");
    }
    out.push_str(&format!("extent: {:.3} ms\n", (t1 - t0) as f64 / 1e6));
    out
}

/// Export events as Chrome tracing JSON (load in chrome://tracing or
/// Perfetto to see the Figure 1 arrows-between-rows view).
pub fn to_chrome_trace(events: &[TraceEvent]) -> String {
    let mut out = String::from("[\n");
    for (i, e) in events.iter().enumerate() {
        let tid = match e.track {
            Track::Host => 0,
            Track::Stream(s) => 1 + s as u64,
        };
        let name = e.name.replace('"', "'");
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"pid\": 1, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}{}\n",
            name,
            tid,
            e.start_ns as f64 / 1e3,
            e.dur_ns() as f64 / 1e3,
            if i + 1 == events.len() { "" } else { "," }
        ));
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    // The profiler is global state; serialize tests touching it.
    static GUARD: StdMutex<()> = StdMutex::new(());

    #[test]
    fn disabled_profiler_records_nothing() {
        let _g = GUARD.lock().unwrap();
        ENABLED.store(false, Ordering::SeqCst);
        let s = begin(Track::Host, "x");
        end(s);
        assert!(snapshot().is_empty() || !enabled());
    }

    #[test]
    fn records_spans_with_monotonic_times() {
        let _g = GUARD.lock().unwrap();
        start();
        let s = begin(Track::Host, "alpha");
        std::thread::sleep(std::time::Duration::from_millis(2));
        end(s);
        let evs = stop();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "alpha");
        assert!(evs[0].dur_ns() >= 1_000_000);
    }

    #[test]
    fn track_stats_utilization() {
        let evs = vec![
            TraceEvent { track: Track::Stream(0), name: "k".into(), start_ns: 0, end_ns: 50 },
            TraceEvent { track: Track::Stream(0), name: "k".into(), start_ns: 50, end_ns: 100 },
            TraceEvent { track: Track::Host, name: "h".into(), start_ns: 0, end_ns: 10 },
        ];
        let st = track_stats(&evs, Track::Stream(0));
        assert_eq!(st.spans, 2);
        assert_eq!(st.busy_ns, 100);
        assert!((st.utilization() - 1.0).abs() < 1e-9);
        let host = track_stats(&evs, Track::Host);
        assert_eq!(host.busy_ns, 10);
    }

    #[test]
    fn ascii_timeline_has_expected_rows() {
        let evs = vec![
            TraceEvent { track: Track::Host, name: "launch".into(), start_ns: 0, end_ns: 10 },
            TraceEvent { track: Track::Stream(0), name: "conv".into(), start_ns: 5, end_ns: 100 },
        ];
        let tl = ascii_timeline(&evs, 40);
        assert!(tl.contains("host  |"));
        assert!(tl.contains("strm 0|"));
        assert!(tl.contains('c'), "stream row should show the conv span: {tl}");
    }

    #[test]
    fn chrome_trace_is_json_array() {
        let evs = vec![TraceEvent {
            track: Track::Host,
            name: "op".into(),
            start_ns: 1000,
            end_ns: 3000,
        }];
        let j = to_chrome_trace(&evs);
        assert!(j.starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert!(j.contains("\"dur\": 2.000"));
    }

    #[test]
    fn record_direct_span() {
        let _g = GUARD.lock().unwrap();
        start();
        record(Track::Stream(2), "manual", 10, 20);
        let evs = stop();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].track, Track::Stream(2));
    }
}
