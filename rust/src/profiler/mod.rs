//! Built-in profiler (§6.1, Figure 1 / Figure 2 instrumentation).
//!
//! Records (track, name, start, end) spans on two kinds of tracks: the
//! host control-flow thread ([`Track::Host`]) and each device stream
//! ([`Track::Stream`]). The Figure 1 bench renders the two rows of the
//! paper's timeline from these spans; `to_chrome_trace` exports the same
//! data for chrome://tracing.
//!
//! Spans are buffered **per thread**: each recording thread appends to
//! its own buffer (registered globally on first use), and [`stop`] /
//! [`snapshot`] merge every buffer — including those of threads that
//! have since exited — into one report ordered by start time. Two things
//! follow: recording never contends on a process-wide lock (the serving
//! hot path has many worker threads profiling concurrently), and a span
//! recorded on *any* thread — a serve worker, a loader prefetcher —
//! always appears in the merged report (pinned by the
//! `spans_from_worker_threads_appear_in_one_merged_report` test).
//! [`op_totals`] folds a report into per-op `{count, total_ns}` rows —
//! the aggregation `serve::ServeStats::op_totals` exposes live.
//!
//! Disabled (the default) it costs one relaxed atomic load per op.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which timeline row a span belongs to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Track {
    /// Host control-flow thread: op dispatch, launches, sync waits.
    Host,
    /// Device stream `n`: kernel execution.
    Stream(u32),
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub track: Track,
    pub name: String,
    /// Nanoseconds since profiler start.
    pub start_ns: u64,
    pub end_ns: u64,
}

impl TraceEvent {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns - self.start_ns
    }
}

/// One thread's span buffer. The owner pushes; merges read from other
/// threads — the Mutex is all but uncontended (owner-only until a merge).
struct ThreadBuf {
    events: Mutex<Vec<TraceEvent>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
/// Events recorded since [`start`], across all threads (approximate
/// under races, which only matters within a few events of the cap).
static EVENT_COUNT: AtomicUsize = AtomicUsize::new(0);
/// Cap so a forgotten profiler can't eat all memory.
const MAX_EVENTS: usize = 2_000_000;

/// Every live-or-exited thread buffer. An `Arc` keeps a buffer (and its
/// recorded spans) alive after its thread exits, until the next
/// [`start`] prunes it — a worker that records and dies before `stop`
/// still shows up in the merged report.
static REGISTRY: once_cell::sync::Lazy<Mutex<Vec<Arc<ThreadBuf>>>> =
    once_cell::sync::Lazy::new(|| Mutex::new(Vec::new()));

static EPOCH: once_cell::sync::Lazy<Mutex<Instant>> =
    once_cell::sync::Lazy::new(|| Mutex::new(Instant::now()));

thread_local! {
    static LOCAL: Arc<ThreadBuf> = {
        let buf = Arc::new(ThreadBuf { events: Mutex::new(Vec::new()) });
        REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).push(buf.clone());
        buf
    };
}

fn push(event: TraceEvent) {
    if EVENT_COUNT.load(Ordering::Relaxed) >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    EVENT_COUNT.fetch_add(1, Ordering::Relaxed);
    LOCAL.with(|buf| buf.events.lock().unwrap_or_else(|e| e.into_inner()).push(event));
}

/// Merge every thread's buffer into one report, ordered by start time
/// (`take` empties the buffers — the [`stop`] path).
fn merged(take: bool) -> Vec<TraceEvent> {
    let registry = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = Vec::new();
    for buf in registry.iter() {
        let mut events = buf.events.lock().unwrap_or_else(|e| e.into_inner());
        if take {
            out.append(&mut events);
        } else {
            out.extend(events.iter().cloned());
        }
    }
    out.sort_by(|a, b| (a.start_ns, a.end_ns).cmp(&(b.start_ns, b.end_ns)));
    out
}

/// An in-flight span returned by [`begin`]; finish it with [`end`].
pub struct Span {
    track: Track,
    name: Option<String>,
    start_ns: u64,
}

/// Start profiling (clears previously recorded events on every thread).
pub fn start() {
    ENABLED.store(false, Ordering::SeqCst);
    {
        let mut registry = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        for buf in registry.iter() {
            buf.events.lock().unwrap_or_else(|e| e.into_inner()).clear();
        }
        // Buffers owned only by the registry belong to exited threads;
        // now that they're cleared they carry nothing — prune them.
        registry.retain(|buf| Arc::strong_count(buf) > 1);
    }
    *EPOCH.lock().unwrap_or_else(|e| e.into_inner()) = Instant::now();
    DROPPED.store(0, Ordering::Relaxed);
    EVENT_COUNT.store(0, Ordering::Relaxed);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop profiling and return the merged, start-ordered events from
/// every recording thread.
pub fn stop() -> Vec<TraceEvent> {
    ENABLED.store(false, Ordering::SeqCst);
    let events = merged(true);
    EVENT_COUNT.store(0, Ordering::Relaxed);
    events
}

/// Whether the profiler is currently recording.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    EPOCH.lock().unwrap_or_else(|e| e.into_inner()).elapsed().as_nanos() as u64
}

/// Begin a span on `track`. Cheap no-op when the profiler is off.
#[inline]
pub fn begin(track: Track, name: &str) -> Span {
    if !enabled() {
        return Span { track, name: None, start_ns: 0 };
    }
    Span { track, name: Some(name.to_string()), start_ns: now_ns() }
}

/// Finish a span started with [`begin`].
#[inline]
pub fn end(span: Span) {
    let Some(name) = span.name else { return };
    if !enabled() {
        return;
    }
    let end_ns = now_ns();
    push(TraceEvent { track: span.track, name, start_ns: span.start_ns, end_ns });
}

/// Record a closed span directly (used by subsystems that time themselves).
pub fn record(track: Track, name: &str, start_ns: u64, end_ns: u64) {
    if !enabled() {
        return;
    }
    push(TraceEvent { track, name: name.to_string(), start_ns, end_ns });
}

/// Events recorded so far without stopping, merged across threads.
pub fn snapshot() -> Vec<TraceEvent> {
    merged(false)
}

/// Aggregate statistics per track for a set of events.
#[derive(Debug, Default, Clone, Copy)]
pub struct TrackStats {
    pub spans: usize,
    pub busy_ns: u64,
    pub first_start_ns: u64,
    pub last_end_ns: u64,
}

impl TrackStats {
    /// Wall-clock extent of the track.
    pub fn extent_ns(&self) -> u64 {
        self.last_end_ns.saturating_sub(self.first_start_ns)
    }
    /// Fraction of the extent the track was busy — "almost perfect device
    /// utilization" reads as utilization ≈ 1.0 on the stream track.
    pub fn utilization(&self) -> f64 {
        if self.extent_ns() == 0 {
            0.0
        } else {
            self.busy_ns as f64 / self.extent_ns() as f64
        }
    }
}

/// Compute per-track statistics.
pub fn track_stats(events: &[TraceEvent], track: Track) -> TrackStats {
    let mut st = TrackStats { first_start_ns: u64::MAX, ..Default::default() };
    for e in events.iter().filter(|e| e.track == track) {
        st.spans += 1;
        st.busy_ns += e.dur_ns();
        st.first_start_ns = st.first_start_ns.min(e.start_ns);
        st.last_end_ns = st.last_end_ns.max(e.end_ns);
    }
    if st.spans == 0 {
        st.first_start_ns = 0;
    }
    st
}

/// Per-op aggregate over a merged report: how often the op ran and its
/// cumulative time, regardless of which thread recorded the spans.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpTotal {
    /// Spans with this name.
    pub count: u64,
    /// Summed span durations (ns).
    pub total_ns: u64,
}

/// Fold events into per-op totals by span name — the cross-thread
/// aggregation the serving metrics surface live
/// (`serve::ServeStats::op_totals`).
pub fn op_totals(events: &[TraceEvent]) -> BTreeMap<String, OpTotal> {
    let mut out: BTreeMap<String, OpTotal> = BTreeMap::new();
    for e in events {
        let t = out.entry(e.name.clone()).or_default();
        t.count += 1;
        t.total_ns += e.dur_ns();
    }
    out
}

/// Render the paper's Figure-1-style two-row ASCII timeline: host on top,
/// one row per stream below, `width` characters across the time extent.
pub fn ascii_timeline(events: &[TraceEvent], width: usize) -> String {
    if events.is_empty() {
        return "(no events)".into();
    }
    let t0 = events.iter().map(|e| e.start_ns).min().unwrap();
    let t1 = events.iter().map(|e| e.end_ns).max().unwrap().max(t0 + 1);
    let scale = |t: u64| -> usize {
        (((t - t0) as u128 * (width as u128 - 1)) / (t1 - t0) as u128) as usize
    };
    let mut tracks: Vec<(String, Track)> = vec![("host  ".into(), Track::Host)];
    let mut stream_ids: Vec<u32> = events
        .iter()
        .filter_map(|e| match e.track {
            Track::Stream(i) => Some(i),
            _ => None,
        })
        .collect();
    stream_ids.sort_unstable();
    stream_ids.dedup();
    for id in stream_ids {
        tracks.push((format!("strm {id}"), Track::Stream(id)));
    }
    let mut out = String::new();
    for (label, track) in tracks {
        let mut row = vec![b'.'; width];
        for e in events.iter().filter(|e| e.track == track) {
            let (a, b) = (scale(e.start_ns), scale(e.end_ns).max(scale(e.start_ns)));
            let ch = e.name.bytes().next().unwrap_or(b'#');
            for c in row.iter_mut().take(b + 1).skip(a) {
                *c = if *c == b'.' { ch } else { b'#' };
            }
        }
        out.push_str(&label);
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push_str("|\n");
    }
    out.push_str(&format!("extent: {:.3} ms\n", (t1 - t0) as f64 / 1e6));
    out
}

/// Export events as Chrome tracing JSON (load in chrome://tracing or
/// Perfetto to see the Figure 1 arrows-between-rows view).
pub fn to_chrome_trace(events: &[TraceEvent]) -> String {
    let mut out = String::from("[\n");
    for (i, e) in events.iter().enumerate() {
        let tid = match e.track {
            Track::Host => 0,
            Track::Stream(s) => 1 + s as u64,
        };
        let name = e.name.replace('"', "'");
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"pid\": 1, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}{}\n",
            name,
            tid,
            e.start_ns as f64 / 1e3,
            e.dur_ns() as f64 / 1e3,
            if i + 1 == events.len() { "" } else { "," }
        ));
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    // The profiler is global state; serialize tests touching it.
    static GUARD: StdMutex<()> = StdMutex::new(());

    #[test]
    fn disabled_profiler_records_nothing() {
        let _g = GUARD.lock().unwrap();
        ENABLED.store(false, Ordering::SeqCst);
        let s = begin(Track::Host, "x");
        end(s);
        assert!(snapshot().is_empty() || !enabled());
    }

    #[test]
    fn records_spans_with_monotonic_times() {
        let _g = GUARD.lock().unwrap();
        start();
        let s = begin(Track::Host, "alpha");
        std::thread::sleep(std::time::Duration::from_millis(2));
        end(s);
        let evs = stop();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "alpha");
        assert!(evs[0].dur_ns() >= 1_000_000);
    }

    /// The cross-thread aggregation contract: spans recorded on worker
    /// threads (serve workers, loader prefetchers) must appear in one
    /// merged report — even when the threads exit before `stop()`.
    #[test]
    fn spans_from_worker_threads_appear_in_one_merged_report() {
        let _g = GUARD.lock().unwrap();
        start();
        let workers: Vec<_> = ["thread-a", "thread-b"]
            .into_iter()
            .map(|name| {
                std::thread::spawn(move || {
                    let s = begin(Track::Host, name);
                    end(s);
                    let s = begin(Track::Host, name);
                    end(s);
                })
            })
            .collect();
        for t in workers {
            t.join().unwrap();
        }
        let s = begin(Track::Host, "main-thread");
        end(s);
        let evs = stop();
        let totals = op_totals(&evs);
        assert_eq!(totals.get("thread-a").map(|t| t.count), Some(2), "{totals:?}");
        assert_eq!(totals.get("thread-b").map(|t| t.count), Some(2), "{totals:?}");
        assert_eq!(totals.get("main-thread").map(|t| t.count), Some(1));
        // Merged report is ordered by start time.
        assert!(evs.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));
    }

    #[test]
    fn start_clears_other_threads_buffers() {
        let _g = GUARD.lock().unwrap();
        start();
        std::thread::spawn(|| {
            let s = begin(Track::Host, "stale");
            end(s);
        })
        .join()
        .unwrap();
        assert_eq!(snapshot().len(), 1);
        start(); // must clear the (exited) worker's buffer too
        assert!(snapshot().is_empty());
        let _ = stop();
    }

    #[test]
    fn op_totals_sums_counts_and_durations() {
        let evs = vec![
            TraceEvent { track: Track::Host, name: "add".into(), start_ns: 0, end_ns: 10 },
            TraceEvent { track: Track::Stream(0), name: "add".into(), start_ns: 5, end_ns: 25 },
            TraceEvent { track: Track::Host, name: "mul".into(), start_ns: 1, end_ns: 2 },
        ];
        let totals = op_totals(&evs);
        assert_eq!(totals["add"], OpTotal { count: 2, total_ns: 30 });
        assert_eq!(totals["mul"], OpTotal { count: 1, total_ns: 1 });
    }

    #[test]
    fn track_stats_utilization() {
        let evs = vec![
            TraceEvent { track: Track::Stream(0), name: "k".into(), start_ns: 0, end_ns: 50 },
            TraceEvent { track: Track::Stream(0), name: "k".into(), start_ns: 50, end_ns: 100 },
            TraceEvent { track: Track::Host, name: "h".into(), start_ns: 0, end_ns: 10 },
        ];
        let st = track_stats(&evs, Track::Stream(0));
        assert_eq!(st.spans, 2);
        assert_eq!(st.busy_ns, 100);
        assert!((st.utilization() - 1.0).abs() < 1e-9);
        let host = track_stats(&evs, Track::Host);
        assert_eq!(host.busy_ns, 10);
    }

    #[test]
    fn ascii_timeline_has_expected_rows() {
        let evs = vec![
            TraceEvent { track: Track::Host, name: "launch".into(), start_ns: 0, end_ns: 10 },
            TraceEvent { track: Track::Stream(0), name: "conv".into(), start_ns: 5, end_ns: 100 },
        ];
        let tl = ascii_timeline(&evs, 40);
        assert!(tl.contains("host  |"));
        assert!(tl.contains("strm 0|"));
        assert!(tl.contains('c'), "stream row should show the conv span: {tl}");
    }

    #[test]
    fn chrome_trace_is_json_array() {
        let evs = vec![TraceEvent {
            track: Track::Host,
            name: "op".into(),
            start_ns: 1000,
            end_ns: 3000,
        }];
        let j = to_chrome_trace(&evs);
        assert!(j.starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert!(j.contains("\"dur\": 2.000"));
    }

    #[test]
    fn record_direct_span() {
        let _g = GUARD.lock().unwrap();
        start();
        record(Track::Stream(2), "manual", 10, 20);
        let evs = stop();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].track, Track::Stream(2));
    }
}
