//! Static-graph execution mode — the stand-in for the graph-based
//! frameworks of Table 1 (TensorFlow/CNTK/MXNet; DESIGN.md §2).
//!
//! A whole train step (forward + backward + SGD update) is AOT-compiled by
//! the Python path into one XLA executable with signature
//! `(batch…, params…) -> (loss, params’…)`. [`GraphTrainer`] keeps the
//! parameters resident as PJRT device buffers and feeds each step's output
//! state into the next step's input — no per-op host dispatch at all,
//! which is precisely the property that makes static-graph frameworks
//! fast and inflexible.

#[cfg(feature = "aot")]
use std::sync::Arc;

use crate::error::{Result, TorskError};
use crate::runtime::CompiledGraph;
#[cfg(feature = "aot")]
use crate::runtime::{literal_to_tensor, tensor_to_literal, Runtime};
use crate::tensor::Tensor;

/// Drives an AOT-compiled train-step graph, keeping the parameter state as
/// XLA literals that feed each step's outputs into the next step's inputs.
#[cfg(feature = "aot")]
pub struct GraphTrainer {
    graph: Arc<CompiledGraph>,
    /// Parameters (and optimizer state, if the graph carries any), in
    /// graph input order after the batch inputs.
    state: Vec<xla::Literal>,
    /// Number of leading batch inputs in the graph signature.
    n_batch_inputs: usize,
    pub steps_run: u64,
}

#[cfg(feature = "aot")]
impl GraphTrainer {
    /// Load `name` from the artifact manifest and upload `init_state`.
    /// The graph signature must be `(batch[0..n_batch], state…) ->
    /// (loss, state’…)`.
    pub fn new(name: &str, n_batch_inputs: usize, init_state: &[Tensor]) -> Result<GraphTrainer> {
        let rt = Runtime::global();
        let graph = rt.load(name)?;
        let expected_state = graph.meta.inputs.len() - n_batch_inputs;
        if init_state.len() != expected_state {
            return Err(TorskError::Msg(format!(
                "graph {name}: {} state tensors given, signature expects {expected_state}",
                init_state.len()
            )));
        }
        let state: Vec<xla::Literal> =
            init_state.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        Ok(GraphTrainer { graph, state, n_batch_inputs, steps_run: 0 })
    }

    /// Run one training step; returns the scalar loss. Parameter literals
    /// feed straight back into the next step (no torsk-tensor roundtrip).
    pub fn step(&mut self, batch: &[Tensor]) -> Result<f32> {
        crate::torsk_assert!(batch.len() == self.n_batch_inputs, "batch arity mismatch");
        let batch_lits: Vec<xla::Literal> =
            batch.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let mut inputs: Vec<&xla::Literal> = batch_lits.iter().collect();
        inputs.extend(self.state.iter());
        let mut outputs = self.graph.run_literals(&inputs)?;
        if outputs.len() != self.state.len() + 1 {
            return Err(TorskError::Xla(format!(
                "graph {} returned {} outputs, expected {}",
                self.graph.meta.name,
                outputs.len(),
                self.state.len() + 1
            )));
        }
        let loss_lit = outputs.remove(0);
        self.state = outputs;
        self.steps_run += 1;
        Ok(literal_to_tensor(&loss_lit)?.item())
    }

    /// Download the current parameter state to host tensors.
    pub fn state_tensors(&self) -> Result<Vec<Tensor>> {
        self.state.iter().map(literal_to_tensor).collect()
    }

    /// Underlying compiled graph metadata.
    pub fn graph(&self) -> &CompiledGraph {
        &self.graph
    }
}

/// Run a pure inference/eval graph once with host tensors.
#[cfg(feature = "aot")]
pub fn run_graph(name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let rt = Runtime::global();
    let graph = rt.load(name)?;
    graph.run(inputs)
}

/// Stub [`GraphTrainer`] for builds without the `aot` feature: it keeps
/// the API typecheckable but can never be constructed — [`GraphTrainer::new`]
/// returns the typed [`TorskError::AotDisabled`].
#[cfg(not(feature = "aot"))]
pub struct GraphTrainer {
    pub steps_run: u64,
    _aot_only: std::convert::Infallible,
}

#[cfg(not(feature = "aot"))]
impl GraphTrainer {
    /// Always fails: the PJRT/AOT path is compiled out.
    pub fn new(name: &str, _n_batch_inputs: usize, _init_state: &[Tensor]) -> Result<GraphTrainer> {
        Err(TorskError::aot_disabled(format!("GraphTrainer for graph `{name}`")))
    }

    /// Unreachable (no instance can exist); kept for API parity.
    pub fn step(&mut self, _batch: &[Tensor]) -> Result<f32> {
        match self._aot_only {}
    }

    /// Unreachable (no instance can exist); kept for API parity.
    pub fn state_tensors(&self) -> Result<Vec<Tensor>> {
        match self._aot_only {}
    }

    /// Unreachable (no instance can exist); kept for API parity.
    pub fn graph(&self) -> &CompiledGraph {
        match self._aot_only {}
    }
}

/// Run a pure inference/eval graph (aot builds only): typed error here.
#[cfg(not(feature = "aot"))]
pub fn run_graph(name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    Err(TorskError::aot_disabled(format!("run graph `{name}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_graph_errors_cleanly() {
        let r = GraphTrainer::new("no_such_graph", 1, &[]);
        assert!(r.is_err());
    }

    #[cfg(not(feature = "aot"))]
    #[test]
    fn stub_trainer_returns_typed_aot_disabled_error() {
        match GraphTrainer::new("mlp_step", 2, &[]) {
            Err(TorskError::AotDisabled { what }) => assert!(what.contains("mlp_step"), "{what}"),
            Ok(_) => panic!("stub GraphTrainer must not construct"),
            Err(other) => panic!("expected AotDisabled, got {other}"),
        }
        match run_graph("mlp_step", &[]) {
            Err(TorskError::AotDisabled { .. }) => {}
            Ok(_) => panic!("stub run_graph must not succeed"),
            Err(other) => panic!("expected AotDisabled, got {other}"),
        }
    }

    // End-to-end GraphTrainer tests live in rust/tests/graph_vs_eager.rs —
    // they need `make artifacts` to have produced the AOT graphs.
}
