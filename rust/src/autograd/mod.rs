//! Reverse-mode automatic differentiation (§4.3).
//!
//! torsk uses the *operator overloading* approach the paper describes: each
//! eager op that touches a gradient-requiring tensor records a [`Node`]
//! (a `grad_fn`) holding the op's backward closure and edges to the nodes
//! that produced its inputs. `backward` then runs the recorded graph in
//! reverse with the multithreaded [`engine`] (§5.1: a "multithreaded
//! evaluator which does not require holding the Python global interpreter
//! lock" — here, no lock at all beyond per-buffer accumulation).
//!
//! Mutation safety (§4.3): tensors saved for backward snapshot the storage
//! version ([`SavedTensor`]); if an in-place op bumped it before backward
//! runs, unpacking panics with the PyTorch error message rather than
//! silently using stale data. Copy-on-write is deliberately *not*
//! implemented — the paper argues surfacing a user error avoids hidden
//! performance cliffs.

pub mod engine;

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::tensor::Tensor;
use crate::torsk_assert;

/// Per-tensor autograd state.
#[derive(Default)]
pub struct AutogradMeta {
    /// Set on leaves by the user; interior tensors derive it from grad_fn.
    pub requires_grad: bool,
    /// Accumulated gradient (leaves, after backward).
    pub grad: Option<Tensor>,
    /// The function that produced this tensor, if recorded.
    pub grad_fn: Option<Arc<Node>>,
}

/// A backward function: maps the output gradient to per-input gradients.
pub trait Function: Send + Sync {
    /// Op name for diagnostics/profiling.
    fn name(&self) -> &str;
    /// Compute input gradients. `None` entries mean "input did not require
    /// grad". Must return exactly one entry per recorded edge.
    fn backward(&self, grad_output: &Tensor) -> Vec<Option<Tensor>>;
}

/// Backward function defined by a closure — the common case; ops capture
/// their [`SavedTensor`]s in the closure.
pub struct ClosureFunction {
    name: &'static str,
    f: Box<dyn Fn(&Tensor) -> Vec<Option<Tensor>> + Send + Sync>,
}

impl ClosureFunction {
    pub fn new(
        name: &'static str,
        f: impl Fn(&Tensor) -> Vec<Option<Tensor>> + Send + Sync + 'static,
    ) -> Box<dyn Function> {
        Box::new(ClosureFunction { name, f: Box::new(f) })
    }
}

impl Function for ClosureFunction {
    fn name(&self) -> &str {
        self.name
    }
    fn backward(&self, grad_output: &Tensor) -> Vec<Option<Tensor>> {
        (self.f)(grad_output)
    }
}

/// Where a node's input gradient flows next.
pub enum Edge {
    /// Into another recorded function.
    Node(Arc<Node>),
    /// Into a leaf tensor's `.grad` (PyTorch's `AccumulateGrad`).
    Leaf(Tensor),
    /// Nowhere (input doesn't require grad).
    None,
}

static NEXT_NODE_ID: AtomicU64 = AtomicU64::new(1);

/// A node in the dynamically-recorded backward graph.
pub struct Node {
    pub(crate) function: Box<dyn Function>,
    pub(crate) edges: Vec<Edge>,
    pub(crate) id: u64,
}

impl Node {
    pub fn new(function: Box<dyn Function>, edges: Vec<Edge>) -> Arc<Node> {
        Arc::new(Node { function, edges, id: NEXT_NODE_ID.fetch_add(1, Ordering::Relaxed) })
    }

    /// Op name of the recorded function.
    pub fn name(&self) -> &str {
        self.function.name()
    }

    /// Number of input edges.
    pub fn num_inputs(&self) -> usize {
        self.edges.len()
    }
}

// ---------------------------------------------------------------------
// Grad mode (torch.no_grad / torch.enable_grad)
// ---------------------------------------------------------------------

thread_local! {
    static GRAD_ENABLED: Cell<bool> = const { Cell::new(true) };
}

/// Is graph recording enabled on this thread?
pub fn grad_enabled() -> bool {
    GRAD_ENABLED.with(|c| c.get())
}

/// Run `f` with graph recording disabled (like `torch.no_grad()`).
pub fn no_grad<R>(f: impl FnOnce() -> R) -> R {
    with_grad_mode(false, f)
}

/// Run `f` with a specific grad-recording mode.
pub fn with_grad_mode<R>(enabled: bool, f: impl FnOnce() -> R) -> R {
    let prev = GRAD_ENABLED.with(|c| c.replace(enabled));
    struct Reset(bool);
    impl Drop for Reset {
        fn drop(&mut self) {
            GRAD_ENABLED.with(|c| c.set(self.0));
        }
    }
    let _reset = Reset(prev);
    f()
}

// ---------------------------------------------------------------------
// Saved tensors + versioning (§4.3)
// ---------------------------------------------------------------------

/// A tensor saved for the backward pass, with its storage version pinned.
pub struct SavedTensor {
    tensor: Tensor,
    saved_version: u64,
}

impl SavedTensor {
    /// Save `t` for backward, snapshotting its mutation version.
    pub fn save(t: &Tensor) -> SavedTensor {
        SavedTensor { tensor: t.detach(), saved_version: t.version() }
    }

    /// Retrieve the tensor, panicking if it was mutated in place since the
    /// save (the paper's deliberate fail-fast choice over copy-on-write).
    pub fn unpack(&self) -> Tensor {
        let now = self.tensor.version();
        torsk_assert!(
            now == self.saved_version,
            "one of the variables needed for gradient computation has been \
             modified by an inplace operation: expected version {}, found \
             version {}",
            self.saved_version,
            now
        );
        self.tensor.clone()
    }
}

// ---------------------------------------------------------------------
// Graph recording (called by the ops layer)
// ---------------------------------------------------------------------

/// Record `function` as the producer of `output`, with one edge per entry
/// of `inputs`. No-op if recording is off or no input requires grad.
pub fn record(inputs: &[&Tensor], output: &Tensor, function: impl FnOnce() -> Box<dyn Function>) {
    if !grad_enabled() {
        return;
    }
    if !inputs.iter().any(|t| t.requires_grad_flag()) {
        return;
    }
    let edges: Vec<Edge> = inputs
        .iter()
        .map(|t| match t.grad_fn() {
            Some(node) => Edge::Node(node),
            None if t.requires_grad_flag() => Edge::Leaf((*t).clone()),
            None => Edge::None,
        })
        .collect();
    output.set_grad_fn(Node::new(function(), edges));
}

/// Would an op over `inputs` record a graph node right now? Ops use this
/// to skip saving activations entirely during inference — one of the
/// "pragmatic performance" details of §3.
pub fn should_record(inputs: &[&Tensor]) -> bool {
    grad_enabled() && inputs.iter().any(|t| t.requires_grad_flag())
}

/// Accumulate `g` into a leaf tensor's `.grad` (AccumulateGrad).
pub(crate) fn accumulate_grad(leaf: &Tensor, g: Tensor) {
    torsk_assert!(
        leaf.shape() == g.shape(),
        "grad shape {:?} does not match leaf shape {:?}",
        g.shape(),
        leaf.shape()
    );
    let current = leaf.grad();
    let new = match current {
        // `g` is owned and dead after this add, so the dispatcher reuses
        // its buffer for the sum (`cur` is still referenced by the leaf's
        // metadata and is therefore never stolen).
        Some(cur) => no_grad(|| crate::dispatch::call_owned("add", vec![cur, g], &[])),
        None => g,
    };
    leaf.set_grad(Some(new));
}

/// Entry point used by `Tensor::backward`.
pub fn backward(root: &Tensor, grad: Option<Tensor>) {
    let seed = match grad {
        Some(g) => {
            torsk_assert!(
                g.shape() == root.shape(),
                "backward seed shape {:?} vs root {:?}",
                g.shape(),
                root.shape()
            );
            g
        }
        None => {
            torsk_assert!(
                root.numel() == 1,
                "grad can be implicitly created only for scalar outputs"
            );
            // Seed matches the root's dtype/device (f64 roots get f64 seeds).
            root.ones_like()
        }
    };
    match root.grad_fn() {
        Some(node) => engine::run_backward(node, seed),
        None => {
            torsk_assert!(
                root.requires_grad_flag(),
                "element 0 of tensors does not require grad and does not have a grad_fn"
            );
            accumulate_grad(root, seed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_mode_scoping() {
        assert!(grad_enabled());
        no_grad(|| {
            assert!(!grad_enabled());
            with_grad_mode(true, || assert!(grad_enabled()));
            assert!(!grad_enabled());
        });
        assert!(grad_enabled());
    }

    #[test]
    fn grad_mode_restored_on_panic() {
        let _ = std::panic::catch_unwind(|| {
            no_grad(|| panic!("boom"));
        });
        assert!(grad_enabled());
    }

    #[test]
    fn saved_tensor_unpacks_when_unmodified() {
        let t = Tensor::ones(&[2]);
        let s = SavedTensor::save(&t);
        let u = s.unpack();
        assert!(u.shares_storage(&t));
    }

    #[test]
    #[should_panic(expected = "modified by an inplace operation")]
    fn saved_tensor_detects_mutation() {
        let t = Tensor::ones(&[2]);
        let s = SavedTensor::save(&t);
        t.storage().bump_version(); // stand-in for an in-place op
        s.unpack();
    }

    #[test]
    fn record_skipped_without_requires_grad() {
        let a = Tensor::ones(&[2]);
        let b = Tensor::ones(&[2]);
        let out = Tensor::ones(&[2]);
        record(&[&a, &b], &out, || {
            ClosureFunction::new("test", |_| vec![None, None])
        });
        assert!(out.grad_fn().is_none());
    }

    #[test]
    fn record_creates_node_with_leaf_edges() {
        let a = Tensor::ones(&[2]).requires_grad(true);
        let b = Tensor::ones(&[2]);
        let out = Tensor::ones(&[2]);
        record(&[&a, &b], &out, || {
            ClosureFunction::new("test", |_| vec![None, None])
        });
        let node = out.grad_fn().expect("node recorded");
        assert_eq!(node.num_inputs(), 2);
        assert_eq!(node.name(), "test");
        assert!(matches!(node.edges[0], Edge::Leaf(_)));
        assert!(matches!(node.edges[1], Edge::None));
    }

    #[test]
    fn record_respects_no_grad() {
        let a = Tensor::ones(&[2]).requires_grad(true);
        let out = Tensor::ones(&[2]);
        no_grad(|| {
            record(&[&a], &out, || ClosureFunction::new("test", |_| vec![None]));
        });
        assert!(out.grad_fn().is_none());
    }

    #[test]
    #[should_panic(expected = "implicitly created only for scalar")]
    fn backward_on_nonscalar_without_seed_panics() {
        let t = Tensor::ones(&[2]).requires_grad(true);
        backward(&t, None);
    }
}
