//! The multithreaded backward engine (§5.1).
//!
//! The paper: derivative computation "is executed entirely in a
//! multithreaded evaluator which does not require holding the Python global
//! interpreter lock". torsk's engine is the same design as PyTorch's:
//!
//! 1. a forward DFS from the root counts, for every node, how many
//!    *consumers* will contribute to its output gradient (`dependencies`);
//! 2. the root is seeded and pushed on a ready queue;
//! 3. worker threads pop ready nodes, run their backward function, route
//!    each produced gradient along its edge — accumulating into either a
//!    downstream node's input buffer (decrementing its dependency count,
//!    enqueueing it at zero) or a leaf tensor's `.grad`;
//! 4. the pass completes when every reachable node has executed.
//!
//! Workers run with grad recording disabled (double backward is out of
//! scope, as forward-mode is for the paper).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use super::{accumulate_grad, no_grad, Edge, Node};
use crate::profiler;
use crate::tensor::Tensor;

/// Number of engine worker threads (including the calling thread).
fn engine_threads() -> usize {
    static N: once_cell::sync::Lazy<usize> = once_cell::sync::Lazy::new(|| {
        std::env::var("TORSK_BACKWARD_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
            })
            .max(1)
    });
    *N
}

struct TaskState {
    /// node id -> remaining consumers that have not yet contributed.
    dependencies: HashMap<u64, usize>,
    /// node id -> accumulated output gradient.
    buffers: HashMap<u64, Tensor>,
    ready: Vec<Arc<Node>>,
    /// Nodes whose backward has not finished yet.
    outstanding: usize,
    /// A worker panicked; abort the pass.
    poisoned: bool,
}

struct Shared {
    state: Mutex<TaskState>,
    cv: Condvar,
}

/// Execute the backward graph rooted at `root`, seeded with `seed`.
pub fn run_backward(root: Arc<Node>, seed: Tensor) {
    let span = profiler::begin(profiler::Track::Host, "backward");

    // Pass 1: dependency counting via iterative DFS over Node edges.
    let mut dependencies: HashMap<u64, usize> = HashMap::new();
    {
        let mut visited: HashMap<u64, ()> = HashMap::new();
        let mut stack: Vec<Arc<Node>> = vec![root.clone()];
        visited.insert(root.id, ());
        while let Some(node) = stack.pop() {
            for edge in &node.edges {
                if let Edge::Node(next) = edge {
                    *dependencies.entry(next.id).or_insert(0) += 1;
                    if visited.insert(next.id, ()).is_none() {
                        stack.push(next.clone());
                    }
                }
            }
        }
    }

    let total_nodes = dependencies.len() + 1; // +1 for the root
    let shared = Arc::new(Shared {
        state: Mutex::new(TaskState {
            dependencies,
            buffers: HashMap::new(),
            ready: vec![],
            outstanding: total_nodes,
            poisoned: false,
        }),
        cv: Condvar::new(),
    });
    {
        let mut st = shared.state.lock().unwrap();
        st.buffers.insert(root.id, seed);
        st.ready.push(root);
    }

    // Pass 2: multithreaded execution.
    let nthreads = engine_threads().min(total_nodes).max(1);
    if nthreads <= 1 {
        worker(&shared);
    } else {
        std::thread::scope(|scope| {
            for _ in 0..nthreads - 1 {
                let sh = shared.clone();
                scope.spawn(move || worker(&sh));
            }
            worker(&shared);
        });
    }

    let st = shared.state.lock().unwrap();
    if st.poisoned {
        drop(st);
        panic!("torsk: backward worker panicked (see stderr for the original error)");
    }
    profiler::end(span);
}

fn worker(shared: &Shared) {
    no_grad(|| loop {
        let node = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.poisoned || st.outstanding == 0 {
                    shared.cv.notify_all();
                    return;
                }
                if let Some(n) = st.ready.pop() {
                    break n;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };

        let grad_out = {
            let mut st = shared.state.lock().unwrap();
            st.buffers.remove(&node.id).expect("ready node must have a buffer")
        };

        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let span = profiler::begin(
                profiler::Track::Host,
                &format!("{}_backward", node.name()),
            );
            let grads = node.function.backward(&grad_out);
            profiler::end(span);
            assert_eq!(
                grads.len(),
                node.edges.len(),
                "backward of {} returned {} grads for {} edges",
                node.name(),
                grads.len(),
                node.edges.len()
            );
            grads
        }));

        let grads = match result {
            Ok(g) => g,
            Err(_) => {
                let mut st = shared.state.lock().unwrap();
                st.poisoned = true;
                shared.cv.notify_all();
                return;
            }
        };

        // Route gradients along edges.
        let mut newly_ready: Vec<Arc<Node>> = vec![];
        for (edge, grad) in node.edges.iter().zip(grads.into_iter()) {
            let Some(grad) = grad else { continue };
            match edge {
                Edge::None => {}
                Edge::Leaf(leaf) => accumulate_grad(leaf, grad),
                Edge::Node(next) => {
                    let mut st = shared.state.lock().unwrap();
                    let buf = st.buffers.remove(&next.id);
                    // Both operands are owned and dead after the add, so
                    // the dispatcher folds the accumulation into one of
                    // the existing gradient buffers (no allocation).
                    let acc = match buf {
                        Some(existing) => {
                            crate::dispatch::call_owned("add", vec![existing, grad], &[])
                        }
                        None => grad,
                    };
                    st.buffers.insert(next.id, acc);
                    let dep = st.dependencies.get_mut(&next.id).expect("dep counted");
                    *dep -= 1;
                    if *dep == 0 {
                        newly_ready.push(next.clone());
                    }
                }
            }
        }

        let mut st = shared.state.lock().unwrap();
        // Unreachable-gradient edges (grad=None into a Node) still satisfy
        // a dependency: decrement for None grads routed to nodes.
        for (edge, _) in node.edges.iter().zip(std::iter::repeat(())) {
            let _ = edge; // dependency bookkeeping for None grads handled below
        }
        st.outstanding -= 1;
        for n in newly_ready {
            st.ready.push(n);
        }
        shared.cv.notify_all();
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::{ClosureFunction, Edge, Node};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn single_node_routes_to_leaf() {
        let leaf = Tensor::zeros(&[2]).requires_grad(true);
        let node = Node::new(
            ClosureFunction::new("double", |g| {
                vec![Some(crate::ops::mul_scalar(g, 2.0))]
            }),
            vec![Edge::Leaf(leaf.clone())],
        );
        run_backward(node, Tensor::from_slice(&[1.0f32, 3.0]));
        let g = leaf.grad().unwrap();
        assert_eq!(g.to_vec::<f32>(), vec![2.0, 6.0]);
    }

    #[test]
    fn diamond_graph_accumulates_before_running() {
        // root -> (a, b) -> shared ; shared must run once with summed grad.
        static SHARED_RUNS: AtomicUsize = AtomicUsize::new(0);
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let shared = Node::new(
            ClosureFunction::new("shared", |g| {
                SHARED_RUNS.fetch_add(1, Ordering::SeqCst);
                vec![Some(g.clone())]
            }),
            vec![Edge::Leaf(leaf.clone())],
        );
        let a = Node::new(
            ClosureFunction::new("a", |g| vec![Some(crate::ops::mul_scalar(g, 2.0))]),
            vec![Edge::Node(shared.clone())],
        );
        let b = Node::new(
            ClosureFunction::new("b", |g| vec![Some(crate::ops::mul_scalar(g, 5.0))]),
            vec![Edge::Node(shared.clone())],
        );
        let root = Node::new(
            ClosureFunction::new("root", |g| vec![Some(g.clone()), Some(g.clone())]),
            vec![Edge::Node(a), Edge::Node(b)],
        );
        run_backward(root, Tensor::from_slice(&[1.0f32]));
        assert_eq!(SHARED_RUNS.load(Ordering::SeqCst), 1, "shared node must run exactly once");
        assert_eq!(leaf.grad().unwrap().to_vec::<f32>(), vec![7.0]);
    }

    #[test]
    fn deep_chain_completes() {
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let mut node = Node::new(
            ClosureFunction::new("base", |g| vec![Some(g.clone())]),
            vec![Edge::Leaf(leaf.clone())],
        );
        for _ in 0..200 {
            node = Node::new(
                ClosureFunction::new("link", |g| vec![Some(g.clone())]),
                vec![Edge::Node(node)],
            );
        }
        run_backward(node, Tensor::from_slice(&[1.5f32]));
        assert_eq!(leaf.grad().unwrap().to_vec::<f32>(), vec![1.5]);
    }

    #[test]
    #[should_panic(expected = "backward worker panicked")]
    fn worker_panic_propagates() {
        let node = Node::new(
            ClosureFunction::new("bad", |_| panic!("backward bug")),
            vec![Edge::None],
        );
        run_backward(node, Tensor::from_slice(&[1.0f32]));
    }
}
