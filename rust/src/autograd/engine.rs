//! The multithreaded backward engine (§5.1).
//!
//! The paper: derivative computation "is executed entirely in a
//! multithreaded evaluator which does not require holding the Python global
//! interpreter lock". torsk's engine is the same design as PyTorch's:
//!
//! 1. a forward DFS from the root counts, for every node, how many
//!    *consumers* will contribute to its output gradient (`dependencies`);
//! 2. the root is seeded and pushed on a ready queue;
//! 3. worker threads pop ready nodes, run their backward function, route
//!    each produced gradient along its edge — accumulating into either a
//!    downstream node's input buffer (decrementing its dependency count,
//!    enqueueing it at zero) or a leaf tensor's `.grad`;
//! 4. the pass completes when every reachable node has executed.
//!
//! Workers run with grad recording disabled (double backward is out of
//! scope, as forward-mode is for the paper).
//!
//! # Dependency-counting contract
//!
//! Correctness of step 3 rests on one invariant: **every** gradient a
//! node's backward produces must decrement its consumer's dependency
//! count — including `None` gradients (a backward that declines to
//! produce a gradient along an edge). A `None` routed to an interior
//! `Edge::Node` decrements the counter like any other contribution and
//! enqueues the node at zero; a node whose dependencies reach zero with
//! *no* accumulated buffer retires without executing, and its own
//! consumers are released transitively (a dead subgraph drains instead of
//! deadlocking the pass — regression-pinned with watchdog tests after the
//! PR 3 fix). Gradient *accumulation* into a node's input buffer is
//! order-independent by construction: buffers combine through the same
//! deterministic reduction drivers as the forward ops, so backward
//! results are bit-identical at any worker count.
//!
//! # Thread-count knobs
//!
//! The worker count resolves once, from (highest priority first):
//!
//! 1. [`set_backward_threads`] — runtime override, tests/benches only;
//! 2. `TORSK_BACKWARD_THREADS` — engine-specific env override (what lets
//!    the CI thread-matrix vary the two pools independently);
//! 3. `PALLAS_NUM_THREADS` — the shared knob, so one variable sizes both
//!    the kernel pool and this engine;
//! 4. `available_parallelism()`, capped at 8.
//!
//! The precedence is unit-tested below (`threads_from_env`); the kernel
//! pool's analogous chain lives in [`crate::kernels`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::{accumulate_grad, no_grad, Edge, Node};
use crate::profiler;
use crate::tensor::Tensor;

/// Runtime override of the worker count (0 = environment default); lets
/// tests/benches sweep backward parallelism inside one process, like
/// [`crate::kernels::set_num_threads`] does for the kernel pool.
static BACKWARD_THREADS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the number of backward worker threads at runtime.
/// `set_backward_threads(0)` restores the environment default.
pub fn set_backward_threads(n: usize) {
    BACKWARD_THREADS_OVERRIDE.store(n.min(1024), Ordering::Relaxed);
}

/// Resolve the worker count from the environment: `PALLAS_NUM_THREADS` is
/// the primary knob shared with the kernel pool, so one variable sizes
/// both pools consistently; `TORSK_BACKWARD_THREADS` (the legacy
/// backward-specific name) still wins when set, which is what lets the CI
/// thread-matrix vary the two pools independently.
fn threads_from_env(
    backward: Option<String>,
    pallas: Option<String>,
    fallback: usize,
) -> usize {
    backward
        .and_then(|v| v.parse().ok())
        .or_else(|| pallas.and_then(|v| v.parse().ok()))
        .unwrap_or(fallback)
        .max(1)
}

/// Number of engine worker threads (including the calling thread).
fn engine_threads() -> usize {
    match BACKWARD_THREADS_OVERRIDE.load(Ordering::Relaxed) {
        0 => {
            static N: once_cell::sync::Lazy<usize> = once_cell::sync::Lazy::new(|| {
                threads_from_env(
                    std::env::var("TORSK_BACKWARD_THREADS").ok(),
                    std::env::var("PALLAS_NUM_THREADS").ok(),
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
                )
            });
            *N
        }
        n => n,
    }
}

struct TaskState {
    /// node id -> remaining consumers that have not yet contributed.
    dependencies: HashMap<u64, usize>,
    /// node id -> accumulated output gradient.
    buffers: HashMap<u64, Tensor>,
    ready: Vec<Arc<Node>>,
    /// Nodes whose backward has not finished yet.
    outstanding: usize,
    /// A worker panicked; abort the pass.
    poisoned: bool,
}

struct Shared {
    state: Mutex<TaskState>,
    cv: Condvar,
}

/// Execute the backward graph rooted at `root`, seeded with `seed`.
pub fn run_backward(root: Arc<Node>, seed: Tensor) {
    let span = profiler::begin(profiler::Track::Host, "backward");

    // Pass 1: dependency counting via iterative DFS over Node edges.
    let mut dependencies: HashMap<u64, usize> = HashMap::new();
    {
        let mut visited: HashMap<u64, ()> = HashMap::new();
        let mut stack: Vec<Arc<Node>> = vec![root.clone()];
        visited.insert(root.id, ());
        while let Some(node) = stack.pop() {
            for edge in &node.edges {
                if let Edge::Node(next) = edge {
                    *dependencies.entry(next.id).or_insert(0) += 1;
                    if visited.insert(next.id, ()).is_none() {
                        stack.push(next.clone());
                    }
                }
            }
        }
    }

    let total_nodes = dependencies.len() + 1; // +1 for the root
    let shared = Arc::new(Shared {
        state: Mutex::new(TaskState {
            dependencies,
            buffers: HashMap::new(),
            ready: vec![],
            outstanding: total_nodes,
            poisoned: false,
        }),
        cv: Condvar::new(),
    });
    {
        let mut st = shared.state.lock().unwrap();
        st.buffers.insert(root.id, seed);
        st.ready.push(root);
    }

    // Pass 2: multithreaded execution.
    let nthreads = engine_threads().min(total_nodes).max(1);
    if nthreads <= 1 {
        worker(&shared);
    } else {
        std::thread::scope(|scope| {
            for _ in 0..nthreads - 1 {
                let sh = shared.clone();
                scope.spawn(move || worker(&sh));
            }
            worker(&shared);
        });
    }

    let st = shared.state.lock().unwrap();
    if st.poisoned {
        drop(st);
        panic!("torsk: backward worker panicked (see stderr for the original error)");
    }
    profiler::end(span);
}

fn worker(shared: &Shared) {
    no_grad(|| loop {
        let node = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.poisoned || st.outstanding == 0 {
                    shared.cv.notify_all();
                    return;
                }
                if let Some(n) = st.ready.pop() {
                    break n;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };

        let grad_out = {
            let mut st = shared.state.lock().unwrap();
            st.buffers.remove(&node.id).expect("ready node must have a buffer")
        };

        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let span = profiler::begin(
                profiler::Track::Host,
                &format!("{}_backward", node.name()),
            );
            let grads = node.function.backward(&grad_out);
            profiler::end(span);
            assert_eq!(
                grads.len(),
                node.edges.len(),
                "backward of {} returned {} grads for {} edges",
                node.name(),
                grads.len(),
                node.edges.len()
            );
            grads
        }));

        let grads = match result {
            Ok(g) => g,
            Err(_) => {
                let mut st = shared.state.lock().unwrap();
                st.poisoned = true;
                shared.cv.notify_all();
                return;
            }
        };

        // Route gradients along edges. A `None` gradient routed to an
        // `Edge::Node` still satisfies one of that node's dependencies:
        // without the decrement the count never reaches zero and every
        // worker parks on the condvar forever (the pre-fix deadlock).
        let mut newly_ready: Vec<Arc<Node>> = vec![];
        for (edge, grad) in node.edges.iter().zip(grads.into_iter()) {
            match edge {
                Edge::None => {}
                Edge::Leaf(leaf) => {
                    if let Some(grad) = grad {
                        accumulate_grad(leaf, grad);
                    }
                }
                Edge::Node(next) => {
                    let mut st = shared.state.lock().unwrap();
                    if let Some(grad) = grad {
                        let buf = st.buffers.remove(&next.id);
                        // Both operands are owned and dead after the add,
                        // so the dispatcher folds the accumulation into one
                        // of the existing gradient buffers (no allocation).
                        let acc = match buf {
                            Some(existing) => {
                                crate::dispatch::call_owned("add", vec![existing, grad], &[])
                            }
                            None => grad,
                        };
                        st.buffers.insert(next.id, acc);
                    }
                    let dep = st.dependencies.get_mut(&next.id).expect("dep counted");
                    *dep -= 1;
                    if *dep == 0 {
                        if st.buffers.contains_key(&next.id) {
                            newly_ready.push(next.clone());
                        } else {
                            // Every consumer contributed `None`: the node
                            // has no gradient to run on. Complete it (and
                            // any subgraph that becomes bufferless the same
                            // way) without executing its backward.
                            drop_bufferless(&mut st, next.clone(), &mut newly_ready);
                        }
                    }
                }
            }
        }

        let mut st = shared.state.lock().unwrap();
        st.outstanding -= 1;
        for n in newly_ready {
            st.ready.push(n);
        }
        shared.cv.notify_all();
    })
}

/// Retire `start` — whose dependencies all delivered `None` — without
/// running it, releasing its own edges' dependencies in turn. Nodes that
/// hit zero with a buffer become ready; nodes that hit zero with no
/// buffer retire recursively (iteratively, via a worklist).
fn drop_bufferless(st: &mut TaskState, start: Arc<Node>, ready_out: &mut Vec<Arc<Node>>) {
    let mut work = vec![start];
    while let Some(node) = work.pop() {
        st.outstanding -= 1;
        for edge in &node.edges {
            if let Edge::Node(next) = edge {
                let dep = st.dependencies.get_mut(&next.id).expect("dep counted");
                *dep -= 1;
                if *dep == 0 {
                    if st.buffers.contains_key(&next.id) {
                        ready_out.push(next.clone());
                    } else {
                        work.push(next.clone());
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::{ClosureFunction, Edge, Node};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn single_node_routes_to_leaf() {
        let leaf = Tensor::zeros(&[2]).requires_grad(true);
        let node = Node::new(
            ClosureFunction::new("double", |g| {
                vec![Some(crate::ops::mul_scalar(g, 2.0))]
            }),
            vec![Edge::Leaf(leaf.clone())],
        );
        run_backward(node, Tensor::from_slice(&[1.0f32, 3.0]));
        let g = leaf.grad().unwrap();
        assert_eq!(g.to_vec::<f32>(), vec![2.0, 6.0]);
    }

    #[test]
    fn diamond_graph_accumulates_before_running() {
        // root -> (a, b) -> shared ; shared must run once with summed grad.
        static SHARED_RUNS: AtomicUsize = AtomicUsize::new(0);
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let shared = Node::new(
            ClosureFunction::new("shared", |g| {
                SHARED_RUNS.fetch_add(1, Ordering::SeqCst);
                vec![Some(g.clone())]
            }),
            vec![Edge::Leaf(leaf.clone())],
        );
        let a = Node::new(
            ClosureFunction::new("a", |g| vec![Some(crate::ops::mul_scalar(g, 2.0))]),
            vec![Edge::Node(shared.clone())],
        );
        let b = Node::new(
            ClosureFunction::new("b", |g| vec![Some(crate::ops::mul_scalar(g, 5.0))]),
            vec![Edge::Node(shared.clone())],
        );
        let root = Node::new(
            ClosureFunction::new("root", |g| vec![Some(g.clone()), Some(g.clone())]),
            vec![Edge::Node(a), Edge::Node(b)],
        );
        run_backward(root, Tensor::from_slice(&[1.0f32]));
        assert_eq!(SHARED_RUNS.load(Ordering::SeqCst), 1, "shared node must run exactly once");
        assert_eq!(leaf.grad().unwrap().to_vec::<f32>(), vec![7.0]);
    }

    #[test]
    fn deep_chain_completes() {
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let mut node = Node::new(
            ClosureFunction::new("base", |g| vec![Some(g.clone())]),
            vec![Edge::Leaf(leaf.clone())],
        );
        for _ in 0..200 {
            node = Node::new(
                ClosureFunction::new("link", |g| vec![Some(g.clone())]),
                vec![Edge::Node(node)],
            );
        }
        run_backward(node, Tensor::from_slice(&[1.5f32]));
        assert_eq!(leaf.grad().unwrap().to_vec::<f32>(), vec![1.5]);
    }

    #[test]
    #[should_panic(expected = "backward worker panicked")]
    fn worker_panic_propagates() {
        let node = Node::new(
            ClosureFunction::new("bad", |_| panic!("backward bug")),
            vec![Edge::None],
        );
        run_backward(node, Tensor::from_slice(&[1.0f32]));
    }

    /// Run `f` under a watchdog: the engine used to hang forever when a
    /// `None` gradient was routed to an interior node (its dependency
    /// counter never decremented), so these regressions must *complete*,
    /// not merely be correct.
    fn with_watchdog(what: &str, f: impl FnOnce() + Send + 'static) {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            f();
            let _ = tx.send(());
        });
        use std::sync::mpsc::RecvTimeoutError;
        match rx.recv_timeout(std::time::Duration::from_secs(30)) {
            Ok(()) => {}
            Err(RecvTimeoutError::Timeout) => panic!("backward hung: {what}"),
            // The sender dropped without sending: f() panicked — report
            // that, not a phantom deadlock.
            Err(RecvTimeoutError::Disconnected) => {
                panic!("backward panicked (not hung): {what}")
            }
        }
    }

    #[test]
    fn none_grad_into_interior_node_completes() {
        // root --None--> interior --> leaf. Pre-fix: interior's dependency
        // count stays at 1, outstanding never drains, all workers park.
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let leaf2 = leaf.clone();
        with_watchdog("None grad to interior node leaked its dependency", move || {
            let interior = Node::new(
                ClosureFunction::new("interior", |g| vec![Some(g.clone())]),
                vec![Edge::Leaf(leaf2)],
            );
            let root = Node::new(
                ClosureFunction::new("root_none", |_| vec![None]),
                vec![Edge::Node(interior)],
            );
            run_backward(root, Tensor::from_slice(&[1.0f32]));
        });
        // The dropped subgraph never ran: the leaf keeps no gradient.
        assert!(leaf.grad().is_none());
    }

    #[test]
    fn mixed_none_and_some_grads_accumulate_the_some_path() {
        // root fans out to (a: None, b: Some) which both feed `shared`;
        // shared must run exactly once with only b's contribution.
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let leaf2 = leaf.clone();
        with_watchdog("mixed None/Some diamond did not complete", move || {
            let shared = Node::new(
                ClosureFunction::new("shared", |g| vec![Some(g.clone())]),
                vec![Edge::Leaf(leaf2)],
            );
            let a = Node::new(
                ClosureFunction::new("a_none", |_| vec![None]),
                vec![Edge::Node(shared.clone())],
            );
            let b = Node::new(
                ClosureFunction::new("b_five", |g| {
                    vec![Some(crate::ops::mul_scalar(g, 5.0))]
                }),
                vec![Edge::Node(shared.clone())],
            );
            let root = Node::new(
                ClosureFunction::new("root", |g| vec![Some(g.clone()), Some(g.clone())]),
                vec![Edge::Node(a), Edge::Node(b)],
            );
            run_backward(root, Tensor::from_slice(&[1.0f32]));
        });
        assert_eq!(leaf.grad().unwrap().to_vec::<f32>(), vec![5.0]);
    }

    #[test]
    fn dropped_chain_releases_transitive_dependencies() {
        // root --None--> n2 --> n1 --> leaf: the whole chain retires
        // without running (transitive bufferless drop), and the pass ends.
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let leaf2 = leaf.clone();
        with_watchdog("transitive bufferless drop hung", move || {
            let n1 = Node::new(
                ClosureFunction::new("n1", |g| vec![Some(g.clone())]),
                vec![Edge::Leaf(leaf2)],
            );
            let n2 = Node::new(
                ClosureFunction::new("n2", |g| vec![Some(g.clone())]),
                vec![Edge::Node(n1)],
            );
            let root = Node::new(
                ClosureFunction::new("root_none", |_| vec![None]),
                vec![Edge::Node(n2)],
            );
            run_backward(root, Tensor::from_slice(&[1.0f32]));
        });
        assert!(leaf.grad().is_none());
    }

    #[test]
    fn threads_from_env_prefers_backward_then_pallas() {
        // PALLAS_NUM_THREADS is the shared primary; the backward-specific
        // variable still overrides it (the CI matrix relies on this).
        assert_eq!(threads_from_env(None, None, 6), 6);
        assert_eq!(threads_from_env(None, Some("3".into()), 6), 3);
        assert_eq!(threads_from_env(Some("2".into()), Some("3".into()), 6), 2);
        assert_eq!(threads_from_env(Some("2".into()), None, 6), 2);
        // Garbage values fall through in order.
        assert_eq!(threads_from_env(Some("x".into()), Some("3".into()), 6), 3);
        assert_eq!(threads_from_env(Some("0".into()), None, 6), 1, "clamped to >= 1");
    }

    #[test]
    fn set_backward_threads_roundtrip() {
        let default = engine_threads();
        set_backward_threads(2);
        assert_eq!(engine_threads(), 2);
        // A small pass still completes under the override.
        let leaf = Tensor::zeros(&[1]).requires_grad(true);
        let node = Node::new(
            ClosureFunction::new("id", |g| vec![Some(g.clone())]),
            vec![Edge::Leaf(leaf.clone())],
        );
        run_backward(node, Tensor::from_slice(&[2.5f32]));
        assert_eq!(leaf.grad().unwrap().to_vec::<f32>(), vec![2.5]);
        set_backward_threads(0);
        assert_eq!(engine_threads(), default);
    }
}
