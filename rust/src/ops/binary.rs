//! Broadcasting binary elementwise ops — shims over the dispatcher's
//! multi-dtype registry entries (F32/F64/I64 with promotion).

use crate::dispatch;
use crate::tensor::Tensor;

pub use crate::dispatch::elementwise::reduce_grad_to_shape;

/// Elementwise addition with broadcasting.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("add", &[a, b], &[])
}

/// Elementwise subtraction with broadcasting.
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("sub", &[a, b], &[])
}

/// Elementwise multiplication with broadcasting.
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("mul", &[a, b], &[])
}

/// Elementwise division with broadcasting.
pub fn div(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("div", &[a, b], &[])
}

/// Elementwise maximum of two tensors.
pub fn maximum(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("maximum", &[a, b], &[])
}

/// Elementwise equality as a 0/1 mask in the promoted dtype (no grad).
pub fn eq_mask(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("eq", &[a, b], &[])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_same_shape() {
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0]);
        let b = Tensor::from_slice(&[0.5f32, 0.5, 0.5]);
        assert_eq!(add(&a, &b).to_vec::<f32>(), vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn add_broadcast_row() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = Tensor::from_slice(&[10.0f32, 20.0, 30.0]);
        assert_eq!(
            add(&a, &b).to_vec::<f32>(),
            vec![11.0, 22.0, 33.0, 14.0, 25.0, 36.0]
        );
    }

    #[test]
    fn add_broadcast_scalar_tensor() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0], &[2]);
        let s = Tensor::scalar(5.0);
        assert_eq!(add(&a, &s).to_vec::<f32>(), vec![6.0, 7.0]);
    }

    #[test]
    fn add_backward_no_broadcast() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32, 4.0]).requires_grad(true);
        let out = add(&a, &b);
        out.backward_with(Tensor::from_slice(&[1.0f32, 10.0]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0, 10.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![1.0, 10.0]);
    }

    #[test]
    fn add_backward_broadcast_reduces() {
        let a = Tensor::zeros(&[2, 3]).requires_grad(true);
        let b = Tensor::zeros(&[3]).requires_grad(true);
        let out = add(&a, &b);
        out.backward_with(Tensor::ones(&[2, 3]));
        assert_eq!(a.grad().unwrap().shape(), &[2, 3]);
        assert_eq!(b.grad().unwrap().shape(), &[3]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn mul_backward_product_rule() {
        let a = Tensor::from_slice(&[2.0f32, 3.0]).requires_grad(true);
        let b = Tensor::from_slice(&[5.0f32, 7.0]).requires_grad(true);
        mul(&a, &b).backward_with(Tensor::ones(&[2]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![5.0, 7.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![2.0, 3.0]);
    }

    #[test]
    fn div_backward() {
        let a = Tensor::from_slice(&[6.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]).requires_grad(true);
        div(&a, &b).backward();
        assert!((a.grad().unwrap().item() - 1.0 / 3.0).abs() < 1e-6);
        assert!((b.grad().unwrap().item() - (-6.0 / 9.0)).abs() < 1e-6);
    }

    #[test]
    fn maximum_routes_grad_to_larger() {
        let a = Tensor::from_slice(&[1.0f32, 5.0]).requires_grad(true);
        let b = Tensor::from_slice(&[2.0f32, 4.0]).requires_grad(true);
        maximum(&a, &b).backward_with(Tensor::ones(&[2]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.0, 1.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![1.0, 0.0]);
    }

    #[test]
    fn binary_on_transposed_view() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let at = a.t(); // [[1,3],[2,4]]
        let b = Tensor::from_vec(vec![10.0f32, 20.0, 30.0, 40.0], &[2, 2]);
        assert_eq!(add(&at, &b).to_vec::<f32>(), vec![11.0, 23.0, 32.0, 44.0]);
    }

    #[test]
    fn mutation_before_backward_is_detected() {
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]);
        let out = mul(&a, &b);
        // In-place mutation of a saved tensor invalidates the graph (§4.3).
        b.storage().bump_version();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| out.backward()));
        assert!(r.is_err(), "backward must fail after in-place mutation");
    }

    #[test]
    fn add_on_sim_device() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]).to_sim();
        let b = Tensor::from_slice(&[3.0f32, 4.0]).to_sim();
        let c = add(&a, &b);
        assert_eq!(c.device(), crate::device::Device::Sim);
        assert_eq!(c.to_vec::<f32>(), vec![4.0, 6.0]);
    }

    #[test]
    fn add_i64_tensors() {
        let a = Tensor::from_vec(vec![1i64, -2], &[2]);
        let b = Tensor::from_vec(vec![10i64, 20], &[2]);
        assert_eq!(add(&a, &b).to_vec::<i64>(), vec![11, 18]);
    }

    #[test]
    fn mixed_dtype_promotes_to_f64() {
        let a = Tensor::from_slice(&[1.5f32, 2.5]);
        let b = Tensor::from_vec(vec![1.0f64, 2.0], &[2]);
        let c = add(&a, &b);
        assert_eq!(c.dtype(), crate::tensor::DType::F64);
        assert_eq!(c.to_vec::<f64>(), vec![2.5, 4.5]);
    }

    #[test]
    fn mixed_dtype_backward_casts_grad_to_leaf_dtype() {
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_vec(vec![3.0f64], &[1]).requires_grad(true);
        let out = mul(&a, &b);
        assert_eq!(out.dtype(), crate::tensor::DType::F64);
        out.backward_with(Tensor::from_vec(vec![1.0f64], &[1]));
        let ga = a.grad().unwrap();
        assert_eq!(ga.dtype(), crate::tensor::DType::F32);
        assert_eq!(ga.to_vec::<f32>(), vec![3.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f64>(), vec![2.0]);
    }

    #[test]
    fn broadcast_with_zero_element_tensor() {
        // 0-element operands broadcast to 0-element outputs, no panic.
        let a = Tensor::from_vec(Vec::<f32>::new(), &[2, 0]);
        let b = Tensor::ones(&[2, 1]);
        let c = add(&a, &b);
        assert_eq!(c.shape(), &[2, 0]);
        assert_eq!(c.numel(), 0);
        let s = Tensor::scalar(1.0);
        assert_eq!(add(&a, &s).shape(), &[2, 0]);
    }

    #[test]
    fn eq_mask_i64() {
        let a = Tensor::from_vec(vec![1i64, 2, 3], &[3]);
        let b = Tensor::from_vec(vec![1i64, 0, 3], &[3]);
        assert_eq!(eq_mask(&a, &b).to_vec::<i64>(), vec![1, 0, 1]);
    }
}
