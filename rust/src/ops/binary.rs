//! Broadcasting binary elementwise ops with autograd.

use crate::autograd::{self, ClosureFunction};
use crate::device;
use crate::tensor::shape::{broadcast_shapes, broadcast_strides, numel, StridedIter};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::same_device;

/// Execute `f` elementwise over broadcast inputs (f32). Host computes
/// shapes/strides; the kernel closure runs wherever the tensors live.
pub(crate) fn binary_map(name: &'static str, a: &Tensor, b: &Tensor, f: fn(f32, f32) -> f32) -> Tensor {
    let dev = same_device(&[a, b]);
    torsk_assert!(a.dtype() == DType::F32 && b.dtype() == DType::F32, "{name}: f32 only");
    let out_shape = broadcast_shapes(a.shape(), b.shape());
    let out = Tensor::empty(&out_shape, DType::F32, dev);
    let n = numel(&out_shape);
    if n == 0 {
        return out;
    }

    let fast = a.shape() == out_shape.as_slice()
        && b.shape() == out_shape.as_slice()
        && a.is_contiguous()
        && b.is_contiguous();

    let (ap, bp, op) = (a.data_ptr(), b.data_ptr(), out.data_ptr());
    if fast {
        device::dispatch(dev, name, move || unsafe {
            let av = ap.as_slice::<f32>(0, n);
            let bv = bp.as_slice::<f32>(0, n);
            let ov = op.as_mut_slice::<f32>(0, n);
            crate::kernels::parallel_for(n, crate::kernels::PAR_GRAIN, |s, e| {
                // SAFETY: disjoint ranges.
                let ov = std::slice::from_raw_parts_mut(ov.as_ptr() as *mut f32, n);
                for i in s..e {
                    ov[i] = f(av[i], bv[i]);
                }
            });
        });
    } else {
        let sa = broadcast_strides(a.shape(), a.strides(), &out_shape);
        let sb = broadcast_strides(b.shape(), b.strides(), &out_shape);
        let osh = out_shape.clone();
        // §Perf: split off the longest trailing "linear run" — a suffix of
        // dims over which each operand advances either contiguously (step
        // 1) or not at all (step 0, i.e. broadcast). Inside the run the
        // kernel is a tight vectorizable loop; the generic odometer only
        // walks the leading dims. This is what makes batch-norm's
        // `x * gamma[1,C,1,1]` style ops fast.
        let (t, step_a, step_b) = linear_suffix(&osh, &sa, &sb);
        let inner: usize = osh[osh.len() - t..].iter().product();
        if t > 0 && inner > 1 {
            let outer_shape = osh[..osh.len() - t].to_vec();
            let outer_sa = sa[..sa.len() - t].to_vec();
            let outer_sb = sb[..sb.len() - t].to_vec();
            device::dispatch(dev, name, move || unsafe {
                let ov = op.as_mut_slice::<f32>(0, n);
                let ia = StridedIter::new(&outer_shape, &outer_sa);
                let ib = StridedIter::new(&outer_shape, &outer_sb);
                for (chunk, (offa, offb)) in ov.chunks_mut(inner).zip(ia.zip(ib)) {
                    let pa = ap.as_f32().add(offa);
                    let pb = bp.as_f32().add(offb);
                    match (step_a, step_b) {
                        (1, 0) => {
                            let s = *pb;
                            let av = std::slice::from_raw_parts(pa, inner);
                            for (o, &x) in chunk.iter_mut().zip(av) {
                                *o = f(x, s);
                            }
                        }
                        (0, 1) => {
                            let s = *pa;
                            let bv = std::slice::from_raw_parts(pb, inner);
                            for (o, &y) in chunk.iter_mut().zip(bv) {
                                *o = f(s, y);
                            }
                        }
                        (1, 1) => {
                            let av = std::slice::from_raw_parts(pa, inner);
                            let bv = std::slice::from_raw_parts(pb, inner);
                            for ((o, &x), &y) in chunk.iter_mut().zip(av).zip(bv) {
                                *o = f(x, y);
                            }
                        }
                        _ => {
                            let s = f(*pa, *pb);
                            chunk.fill(s);
                        }
                    }
                }
            });
        } else {
            device::dispatch(dev, name, move || unsafe {
                let ov = op.as_mut_slice::<f32>(0, n);
                let ia = StridedIter::new(&osh, &sa);
                let ib = StridedIter::new(&osh, &sb);
                for ((o, offa), offb) in ov.iter_mut().zip(ia).zip(ib) {
                    *o = f(*ap.as_f32().add(offa), *bp.as_f32().add(offb));
                }
            });
        }
    }
    out
}

/// Longest trailing dim-suffix over which both stride vectors advance
/// linearly (contiguously for the suffix's own shape, or with stride 0).
/// Returns (suffix_len_in_dims, step_a, step_b) with steps in {0, 1}.
pub(crate) fn linear_suffix(shape: &[usize], sa: &[usize], sb: &[usize]) -> (usize, usize, usize) {
    let rank = shape.len();
    let classify = |strides: &[usize], t: usize| -> Option<usize> {
        // Suffix of length t: all-zero (step 0) or block-contiguous (step 1).
        let suffix_shape = &shape[rank - t..];
        let suffix = &strides[rank - t..];
        if suffix.iter().zip(suffix_shape).all(|(&s, &d)| s == 0 || d == 1) {
            return Some(0);
        }
        let mut acc = 1usize;
        for d in (0..t).rev() {
            if suffix_shape[d] != 1 && suffix[d] != acc {
                return None;
            }
            acc *= suffix_shape[d].max(1);
        }
        Some(1)
    };
    let mut best = (0usize, 0usize, 0usize);
    for t in 1..=rank {
        match (classify(sa, t), classify(sb, t)) {
            (Some(x), Some(y)) => best = (t, x, y),
            _ => break,
        }
    }
    best
}

/// Sum `grad` down to `shape` (undo broadcasting) — the standard binary-op
/// backward reduction.
pub fn reduce_grad_to_shape(grad: &Tensor, shape: &[usize]) -> Tensor {
    if grad.shape() == shape {
        return grad.clone();
    }
    super::sum_to_shape(grad, shape)
}

/// Elementwise addition with broadcasting.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    let out = binary_map("add", a, b, |x, y| x + y);
    if autograd::should_record(&[a, b]) {
        let (sa, sb) = (a.shape().to_vec(), b.shape().to_vec());
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("add", move |g| {
                vec![
                    Some(reduce_grad_to_shape(g, &sa)),
                    Some(reduce_grad_to_shape(g, &sb)),
                ]
            })
        });
    }
    out
}

/// Elementwise subtraction with broadcasting.
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    let out = binary_map("sub", a, b, |x, y| x - y);
    if autograd::should_record(&[a, b]) {
        let (sa, sb) = (a.shape().to_vec(), b.shape().to_vec());
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("sub", move |g| {
                vec![
                    Some(reduce_grad_to_shape(g, &sa)),
                    Some(reduce_grad_to_shape(&super::neg(g), &sb)),
                ]
            })
        });
    }
    out
}

/// Elementwise multiplication with broadcasting.
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    let out = binary_map("mul", a, b, |x, y| x * y);
    if autograd::should_record(&[a, b]) {
        let (sa, sb) = (a.shape().to_vec(), b.shape().to_vec());
        let (va, vb) = (autograd::SavedTensor::save(a), autograd::SavedTensor::save(b));
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("mul", move |g| {
                let a = va.unpack();
                let b = vb.unpack();
                vec![
                    Some(reduce_grad_to_shape(&binary_map("mul", g, &b, |x, y| x * y), &sa)),
                    Some(reduce_grad_to_shape(&binary_map("mul", g, &a, |x, y| x * y), &sb)),
                ]
            })
        });
    }
    out
}

/// Elementwise division with broadcasting.
pub fn div(a: &Tensor, b: &Tensor) -> Tensor {
    let out = binary_map("div", a, b, |x, y| x / y);
    if autograd::should_record(&[a, b]) {
        let (sa, sb) = (a.shape().to_vec(), b.shape().to_vec());
        let (va, vb) = (autograd::SavedTensor::save(a), autograd::SavedTensor::save(b));
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("div", move |g| {
                let a = va.unpack();
                let b = vb.unpack();
                // d/da = g / b ; d/db = -g * a / b^2
                let ga = binary_map("div", g, &b, |x, y| x / y);
                let gb = binary_map("div_b", g, &binary_map("mul", &a, &binary_map("mul", &b, &b, |x, y| x * y), |x, y| x / y), |x, y| x * y);
                let gb = super::neg(&gb);
                vec![
                    Some(reduce_grad_to_shape(&ga, &sa)),
                    Some(reduce_grad_to_shape(&gb, &sb)),
                ]
            })
        });
    }
    out
}

/// Elementwise maximum of two tensors.
pub fn maximum(a: &Tensor, b: &Tensor) -> Tensor {
    let out = binary_map("maximum", a, b, |x, y| x.max(y));
    if autograd::should_record(&[a, b]) {
        let (sa, sb) = (a.shape().to_vec(), b.shape().to_vec());
        let (va, vb) = (autograd::SavedTensor::save(a), autograd::SavedTensor::save(b));
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("maximum", move |g| {
                let a = va.unpack();
                let b = vb.unpack();
                let mask_a = binary_map("ge_mask", &a, &b, |x, y| if x >= y { 1.0 } else { 0.0 });
                let mask_b = binary_map("lt_mask", &a, &b, |x, y| if x < y { 1.0 } else { 0.0 });
                vec![
                    Some(reduce_grad_to_shape(&binary_map("mul", g, &mask_a, |x, y| x * y), &sa)),
                    Some(reduce_grad_to_shape(&binary_map("mul", g, &mask_b, |x, y| x * y), &sb)),
                ]
            })
        });
    }
    out
}

/// Elementwise equality as 0/1 f32 (no grad).
pub fn eq_mask(a: &Tensor, b: &Tensor) -> Tensor {
    binary_map("eq", a, b, |x, y| if x == y { 1.0 } else { 0.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_same_shape() {
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0]);
        let b = Tensor::from_slice(&[0.5f32, 0.5, 0.5]);
        assert_eq!(add(&a, &b).to_vec::<f32>(), vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn add_broadcast_row() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = Tensor::from_slice(&[10.0f32, 20.0, 30.0]);
        assert_eq!(
            add(&a, &b).to_vec::<f32>(),
            vec![11.0, 22.0, 33.0, 14.0, 25.0, 36.0]
        );
    }

    #[test]
    fn add_broadcast_scalar_tensor() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0], &[2]);
        let s = Tensor::scalar(5.0);
        assert_eq!(add(&a, &s).to_vec::<f32>(), vec![6.0, 7.0]);
    }

    #[test]
    fn add_backward_no_broadcast() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32, 4.0]).requires_grad(true);
        let out = add(&a, &b);
        out.backward_with(Tensor::from_slice(&[1.0f32, 10.0]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0, 10.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![1.0, 10.0]);
    }

    #[test]
    fn add_backward_broadcast_reduces() {
        let a = Tensor::zeros(&[2, 3]).requires_grad(true);
        let b = Tensor::zeros(&[3]).requires_grad(true);
        let out = add(&a, &b);
        out.backward_with(Tensor::ones(&[2, 3]));
        assert_eq!(a.grad().unwrap().shape(), &[2, 3]);
        assert_eq!(b.grad().unwrap().shape(), &[3]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn mul_backward_product_rule() {
        let a = Tensor::from_slice(&[2.0f32, 3.0]).requires_grad(true);
        let b = Tensor::from_slice(&[5.0f32, 7.0]).requires_grad(true);
        mul(&a, &b).backward_with(Tensor::ones(&[2]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![5.0, 7.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![2.0, 3.0]);
    }

    #[test]
    fn div_backward() {
        let a = Tensor::from_slice(&[6.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]).requires_grad(true);
        div(&a, &b).backward();
        assert!((a.grad().unwrap().item() - 1.0 / 3.0).abs() < 1e-6);
        assert!((b.grad().unwrap().item() - (-6.0 / 9.0)).abs() < 1e-6);
    }

    #[test]
    fn maximum_routes_grad_to_larger() {
        let a = Tensor::from_slice(&[1.0f32, 5.0]).requires_grad(true);
        let b = Tensor::from_slice(&[2.0f32, 4.0]).requires_grad(true);
        maximum(&a, &b).backward_with(Tensor::ones(&[2]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.0, 1.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![1.0, 0.0]);
    }

    #[test]
    fn binary_on_transposed_view() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let at = a.t(); // [[1,3],[2,4]]
        let b = Tensor::from_vec(vec![10.0f32, 20.0, 30.0, 40.0], &[2, 2]);
        assert_eq!(add(&at, &b).to_vec::<f32>(), vec![11.0, 23.0, 32.0, 44.0]);
    }

    #[test]
    fn mutation_before_backward_is_detected() {
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]);
        let out = mul(&a, &b);
        // In-place mutation of a saved tensor invalidates the graph (§4.3).
        b.storage().bump_version();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| out.backward()));
        assert!(r.is_err(), "backward must fail after in-place mutation");
    }

    #[test]
    fn add_on_sim_device() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]).to_sim();
        let b = Tensor::from_slice(&[3.0f32, 4.0]).to_sim();
        let c = add(&a, &b);
        assert_eq!(c.device(), crate::device::Device::Sim);
        assert_eq!(c.to_vec::<f32>(), vec![4.0, 6.0]);
    }
}
