//! Gradient plumbing for view/layout ops (reshape, transpose, permute,
//! narrow, device moves) plus concatenation/stacking.
//!
//! View creation itself lives on `Tensor` (zero-copy, §5.5); these hooks
//! record the backward edges. `cat` routes through the dispatcher like
//! every data-producing op.

use crate::autograd::{self, ClosureFunction};
use crate::device::Device;
use crate::dispatch::{self, Param};
use crate::tensor::Tensor;
use crate::torsk_assert;

/// Backward hookup for shape-preserving-data ops (reshape, squeeze,
/// contiguous, to_device): gradient reshapes/moves back.
pub(crate) fn register_view_grad(src: &Tensor, out: &Tensor) {
    if !autograd::should_record(&[src]) {
        return;
    }
    let src_shape = src.shape().to_vec();
    let src_dev = src.device();
    autograd::record(&[src], out, || {
        ClosureFunction::new("view", move |g| {
            let g = g.to_device(src_dev);
            vec![Some(g.reshape(&src_shape))]
        })
    });
}

/// Backward hookup for transpose: transpose the gradient back.
pub(crate) fn register_transpose_grad(src: &Tensor, out: &Tensor, d0: usize, d1: usize) {
    if !autograd::should_record(&[src]) {
        return;
    }
    autograd::record(&[src], out, || {
        ClosureFunction::new("transpose", move |g| {
            vec![Some(g.transpose(d0, d1).contiguous())]
        })
    });
}

/// Backward hookup for permute: apply the inverse permutation.
pub(crate) fn register_permute_grad(src: &Tensor, out: &Tensor, perm: &[usize]) {
    if !autograd::should_record(&[src]) {
        return;
    }
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    autograd::record(&[src], out, || {
        ClosureFunction::new("permute", move |g| {
            vec![Some(g.permute(&inv).contiguous())]
        })
    });
}

/// Backward hookup for narrow: embed the gradient into zeros.
pub(crate) fn register_narrow_grad(src: &Tensor, out: &Tensor, dim: usize, start: usize) {
    if !autograd::should_record(&[src]) {
        return;
    }
    let src_shape = src.shape().to_vec();
    let dtype = src.dtype();
    autograd::record(&[src], out, || {
        ClosureFunction::new("narrow", move |g| {
            let full = Tensor::zeros_on(&src_shape, dtype, g.device());
            // Write g into the slice region (raw, in-place on fresh zeros).
            let dst = full.narrow(dim, start, g.size(dim));
            copy_into_view(&dst, g);
            vec![Some(full)]
        })
    });
}

/// Raw strided copy of `src` (contiguous) into a strided `view`. Internal:
/// used for narrow backward and `cat`.
pub(crate) fn copy_into_view(view: &Tensor, src: &Tensor) {
    crate::dispatch::views::copy_into_view(view, src);
}

/// Backward hookup for expand: sum the gradient back to the source shape.
pub(crate) fn register_expand_grad(src: &Tensor, out: &Tensor) {
    if !autograd::should_record(&[src]) {
        return;
    }
    let src_shape = src.shape().to_vec();
    autograd::record(&[src], out, || {
        ClosureFunction::new("expand", move |g| {
            vec![Some(super::sum_to_shape(g, &src_shape))]
        })
    });
}

/// Public wrapper over the internal strided copy (used by multiprocessing
/// helpers and tests to write into zero-copy views).
pub fn copy_into_view_public(view: &Tensor, src: &Tensor) {
    copy_into_view(view, src);
    view.bump_version();
}

/// Concatenate tensors along `dim`.
pub fn cat(tensors: &[&Tensor], dim: usize) -> Tensor {
    torsk_assert!(!tensors.is_empty(), "cat: empty input list");
    dispatch::call("cat", tensors, &[Param::Usize(dim)])
}

/// Stack tensors along a new leading `dim`.
pub fn stack(tensors: &[&Tensor], dim: usize) -> Tensor {
    let unsqueezed: Vec<Tensor> = tensors.iter().map(|t| t.unsqueeze(dim)).collect();
    let refs: Vec<&Tensor> = unsqueezed.iter().collect();
    cat(&refs, dim)
}

/// Move a batch of tensors to a device (convenience for data loaders).
pub fn to_device_all(tensors: &[Tensor], device: Device) -> Vec<Tensor> {
    tensors.iter().map(|t| t.to_device(device)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cat_dim0() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0], &[1, 2]);
        let b = Tensor::from_vec(vec![3.0f32, 4.0, 5.0, 6.0], &[2, 2]);
        let c = cat(&[&a, &b], 0);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn cat_dim1() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0], &[2, 1]);
        let b = Tensor::from_vec(vec![3.0f32, 4.0], &[2, 1]);
        let c = cat(&[&a, &b], 1);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.to_vec::<f32>(), vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn cat_backward_splits() {
        let a = Tensor::zeros(&[1, 2]).requires_grad(true);
        let b = Tensor::zeros(&[2, 2]).requires_grad(true);
        let c = cat(&[&a, &b], 0);
        c.backward_with(Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], &[3, 2]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0, 2.0]);
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn stack_creates_new_dim() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = Tensor::from_slice(&[3.0f32, 4.0]);
        let s = stack(&[&a, &b], 0);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn cat_f64_and_i64() {
        let a = Tensor::from_vec(vec![1.0f64, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0f64], &[1]);
        assert_eq!(cat(&[&a, &b], 0).to_vec::<f64>(), vec![1.0, 2.0, 3.0]);
        let i = Tensor::from_vec(vec![1i64, 2], &[2]);
        let j = Tensor::from_vec(vec![3i64], &[1]);
        assert_eq!(cat(&[&i, &j], 0).to_vec::<i64>(), vec![1, 2, 3]);
    }

    #[test]
    fn reshape_backward_flows() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]).requires_grad(true);
        let r = a.reshape(&[4]);
        r.backward_with(Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0]));
        assert_eq!(a.grad().unwrap().shape(), &[2, 2]);
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn transpose_backward_transposes_back() {
        let a = Tensor::zeros(&[2, 3]).requires_grad(true);
        let t = a.t();
        t.backward_with(Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[3, 2]));
        // g = [[1,2],[3,4],[5,6]] transposed back = [[1,3,5],[2,4,6]]
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn narrow_backward_pads_zeros() {
        let a = Tensor::zeros(&[4]).requires_grad(true);
        let nrw = a.narrow(0, 1, 2);
        nrw.backward_with(Tensor::from_slice(&[5.0f32, 7.0]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.0, 5.0, 7.0, 0.0]);
    }

    #[test]
    fn permute_backward_inverts() {
        let a = Tensor::zeros(&[2, 3, 4]).requires_grad(true);
        let p = a.permute(&[2, 0, 1]);
        p.sum().backward();
        assert_eq!(a.grad().unwrap().shape(), &[2, 3, 4]);
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0; 24]);
    }

    #[test]
    fn to_device_backward_returns_home() {
        let a = Tensor::ones(&[2]).requires_grad(true);
        let d = a.to_sim();
        let y = d.mul_scalar(2.0).sum();
        y.backward();
        let g = a.grad().unwrap();
        assert_eq!(g.device(), Device::Cpu);
        assert_eq!(g.to_vec::<f32>(), vec![2.0, 2.0]);
    }
}
