//! In-place mutation ops (`add_`, `mul_`, `zero_`, `copy_`, `fill_`).
//!
//! Every mutation bumps the storage version (§4.3). Mutating a leaf that
//! requires grad outside `no_grad` is an error, mirroring PyTorch's
//! "a leaf Variable that requires grad is being used in an in-place
//! operation". Optimizers mutate parameters inside `no_grad` (§4.1's
//! "optimizers are just programs" — they run the same ops).

use crate::autograd;
use crate::device;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

fn check_inplace_allowed(t: &Tensor, name: &str) {
    torsk_assert!(
        !(autograd::grad_enabled() && t.requires_grad_flag() && t.grad_fn().is_none()),
        "a leaf tensor that requires grad is being used in an in-place \
         operation ({name}); wrap the update in no_grad()"
    );
}

fn inplace_binary(name: &'static str, dst: &Tensor, src: &Tensor, f: fn(f32, f32) -> f32) {
    check_inplace_allowed(dst, name);
    torsk_assert!(dst.shape() == src.shape(), "{name}: shape {:?} vs {:?}", dst.shape(), src.shape());
    torsk_assert!(dst.is_contiguous(), "{name}: destination must be contiguous");
    let dev = super::same_device(&[dst, src]);
    let src = src.contiguous();
    let n = dst.numel();
    let (dp, sp) = (dst.data_ptr(), src.data_ptr());
    device::dispatch(dev, name, move || unsafe {
        let d = dp.as_mut_slice::<f32>(0, n);
        let s = sp.as_slice::<f32>(0, n);
        for i in 0..n {
            d[i] = f(d[i], s[i]);
        }
    });
    dst.bump_version();
}

fn inplace_scalar(name: &'static str, dst: &Tensor, s: f32, f: fn(f32, f32) -> f32) {
    check_inplace_allowed(dst, name);
    torsk_assert!(dst.is_contiguous(), "{name}: destination must be contiguous");
    let n = dst.numel();
    let dp = dst.data_ptr();
    device::dispatch(dst.device(), name, move || unsafe {
        let d = dp.as_mut_slice::<f32>(0, n);
        for x in d.iter_mut() {
            *x = f(*x, s);
        }
    });
    dst.bump_version();
}

impl Tensor {
    /// `self += other` in place.
    pub fn add_(&self, other: &Tensor) {
        inplace_binary("add_", self, other, |a, b| a + b);
    }

    /// `self -= other` in place.
    pub fn sub_(&self, other: &Tensor) {
        inplace_binary("sub_", self, other, |a, b| a - b);
    }

    /// `self *= other` in place.
    pub fn mul_(&self, other: &Tensor) {
        inplace_binary("mul_", self, other, |a, b| a * b);
    }

    /// `self += alpha * other` in place (the SGD update primitive).
    pub fn axpy_(&self, alpha: f32, other: &Tensor) {
        check_inplace_allowed(self, "axpy_");
        torsk_assert!(self.shape() == other.shape(), "axpy_: shape mismatch");
        torsk_assert!(self.is_contiguous(), "axpy_: destination must be contiguous");
        let dev = super::same_device(&[self, other]);
        let other = other.contiguous();
        let n = self.numel();
        let (dp, sp) = (self.data_ptr(), other.data_ptr());
        device::dispatch(dev, "axpy_", move || unsafe {
            let d = dp.as_mut_slice::<f32>(0, n);
            let s = sp.as_slice::<f32>(0, n);
            for i in 0..n {
                d[i] += alpha * s[i];
            }
        });
        self.bump_version();
    }

    /// `self *= s` in place.
    pub fn mul_scalar_(&self, s: f32) {
        inplace_scalar("mul_scalar_", self, s, |a, b| a * b);
    }

    /// `self += s` in place.
    pub fn add_scalar_(&self, s: f32) {
        inplace_scalar("add_scalar_", self, s, |a, b| a + b);
    }

    /// Fill with a constant.
    pub fn fill_(&self, v: f32) {
        inplace_scalar("fill_", self, v, |_, b| b);
    }

    /// Zero in place (`optimizer.zero_grad` style).
    pub fn zero_(&self) {
        self.fill_(0.0);
    }

    /// Copy data from `src` (same shape) in place.
    pub fn copy_(&self, src: &Tensor) {
        torsk_assert!(self.dtype() == src.dtype(), "copy_: dtype mismatch");
        match self.dtype() {
            DType::F32 => inplace_binary("copy_", self, src, |_, b| b),
            DType::I64 => {
                check_inplace_allowed(self, "copy_");
                torsk_assert!(self.shape() == src.shape(), "copy_: shape mismatch");
                let src = src.contiguous();
                let n = self.numel();
                let (dp, sp) = (self.data_ptr(), src.data_ptr());
                device::dispatch(self.device(), "copy_", move || unsafe {
                    let d = dp.as_mut_slice::<i64>(0, n);
                    let s = sp.as_slice::<i64>(0, n);
                    d.copy_from_slice(s);
                });
                self.bump_version();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::no_grad;

    #[test]
    fn add_inplace() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = Tensor::from_slice(&[10.0f32, 20.0]);
        a.add_(&b);
        assert_eq!(a.to_vec::<f32>(), vec![11.0, 22.0]);
    }

    #[test]
    fn inplace_bumps_version() {
        let a = Tensor::ones(&[2]);
        let v0 = a.version();
        a.mul_scalar_(2.0);
        assert_eq!(a.version(), v0 + 1);
        a.zero_();
        assert_eq!(a.version(), v0 + 2);
    }

    #[test]
    fn axpy_updates() {
        let p = Tensor::from_slice(&[1.0f32, 1.0]);
        let g = Tensor::from_slice(&[0.5f32, 1.0]);
        p.axpy_(-0.1, &g);
        let v = p.to_vec::<f32>();
        assert!((v[0] - 0.95).abs() < 1e-6);
        assert!((v[1] - 0.9).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "in-place")]
    fn inplace_on_grad_leaf_panics() {
        let p = Tensor::ones(&[2]).requires_grad(true);
        p.add_(&Tensor::ones(&[2]));
    }

    #[test]
    fn inplace_on_grad_leaf_ok_under_no_grad() {
        let p = Tensor::ones(&[2]).requires_grad(true);
        no_grad(|| p.add_(&Tensor::ones(&[2])));
        assert_eq!(p.to_vec::<f32>(), vec![2.0, 2.0]);
    }

    #[test]
    fn inplace_invalidates_saved_backward() {
        // The §4.3 end-to-end story: mutate an op input in place between
        // forward and backward -> backward must error, not silently use
        // stale data.
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]);
        let y = crate::ops::mul(&a, &b);
        b.fill_(100.0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| y.backward()));
        assert!(r.is_err());
    }

    #[test]
    fn copy_roundtrip() {
        let a = Tensor::zeros(&[3]);
        let b = Tensor::from_slice(&[1.0f32, 2.0, 3.0]);
        a.copy_(&b);
        assert_eq!(a.to_vec::<f32>(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn copy_i64() {
        let a = Tensor::from_vec(vec![0i64; 2], &[2]);
        let b = Tensor::from_vec(vec![5i64, -9], &[2]);
        a.copy_(&b);
        assert_eq!(a.to_vec::<i64>(), vec![5, -9]);
    }
}
