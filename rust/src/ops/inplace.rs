//! In-place mutation ops (`add_`, `mul_`, `zero_`, `copy_`, `fill_`) —
//! dispatcher shims exposed as `Tensor` methods.
//!
//! Every mutation bumps the storage version (§4.3). Mutating a leaf that
//! requires grad outside `no_grad` is an error, mirroring PyTorch's
//! "a leaf Variable that requires grad is being used in an in-place
//! operation". Optimizers mutate parameters inside `no_grad` (§4.1's
//! "optimizers are just programs" — they run the same ops).

use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

impl Tensor {
    /// `self += other` in place.
    pub fn add_(&self, other: &Tensor) {
        dispatch::call("add_", &[self, other], &[]);
    }

    /// `self -= other` in place.
    pub fn sub_(&self, other: &Tensor) {
        dispatch::call("sub_", &[self, other], &[]);
    }

    /// `self *= other` in place.
    pub fn mul_(&self, other: &Tensor) {
        dispatch::call("mul_", &[self, other], &[]);
    }

    /// `self += alpha * other` in place (the SGD update primitive).
    pub fn axpy_(&self, alpha: f32, other: &Tensor) {
        dispatch::call("axpy_", &[self, other], &[Param::F32(alpha)]);
    }

    /// `self *= s` in place.
    pub fn mul_scalar_(&self, s: f32) {
        dispatch::call("mul_scalar_", &[self], &[Param::F32(s)]);
    }

    /// `self += s` in place.
    pub fn add_scalar_(&self, s: f32) {
        dispatch::call("add_scalar_", &[self], &[Param::F32(s)]);
    }

    /// Fill with a constant.
    pub fn fill_(&self, v: f32) {
        dispatch::call("fill_", &[self], &[Param::F32(v)]);
    }

    /// Zero in place (`optimizer.zero_grad` style).
    pub fn zero_(&self) {
        self.fill_(0.0);
    }

    /// Copy data from `src` (same shape and dtype) in place.
    pub fn copy_(&self, src: &Tensor) {
        dispatch::call("copy_", &[self, src], &[]);
    }
}

#[cfg(test)]
mod tests {
    use crate::autograd::no_grad;
    use crate::tensor::Tensor;

    #[test]
    fn add_inplace() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = Tensor::from_slice(&[10.0f32, 20.0]);
        a.add_(&b);
        assert_eq!(a.to_vec::<f32>(), vec![11.0, 22.0]);
    }

    #[test]
    fn inplace_bumps_version() {
        let a = Tensor::ones(&[2]);
        let v0 = a.version();
        a.mul_scalar_(2.0);
        assert_eq!(a.version(), v0 + 1);
        a.zero_();
        assert_eq!(a.version(), v0 + 2);
    }

    #[test]
    fn axpy_updates() {
        let p = Tensor::from_slice(&[1.0f32, 1.0]);
        let g = Tensor::from_slice(&[0.5f32, 1.0]);
        p.axpy_(-0.1, &g);
        let v = p.to_vec::<f32>();
        assert!((v[0] - 0.95).abs() < 1e-6);
        assert!((v[1] - 0.9).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "in-place")]
    fn inplace_on_grad_leaf_panics() {
        let p = Tensor::ones(&[2]).requires_grad(true);
        p.add_(&Tensor::ones(&[2]));
    }

    #[test]
    fn inplace_on_grad_leaf_ok_under_no_grad() {
        let p = Tensor::ones(&[2]).requires_grad(true);
        no_grad(|| p.add_(&Tensor::ones(&[2])));
        assert_eq!(p.to_vec::<f32>(), vec![2.0, 2.0]);
    }

    #[test]
    fn inplace_invalidates_saved_backward() {
        // The §4.3 end-to-end story: mutate an op input in place between
        // forward and backward -> backward must error, not silently use
        // stale data.
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]);
        let y = crate::ops::mul(&a, &b);
        b.fill_(100.0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| y.backward()));
        assert!(r.is_err());
    }

    #[test]
    fn copy_roundtrip() {
        let a = Tensor::zeros(&[3]);
        let b = Tensor::from_slice(&[1.0f32, 2.0, 3.0]);
        a.copy_(&b);
        assert_eq!(a.to_vec::<f32>(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn copy_i64() {
        let a = Tensor::from_vec(vec![0i64; 2], &[2]);
        let b = Tensor::from_vec(vec![5i64, -9], &[2]);
        a.copy_(&b);
        assert_eq!(a.to_vec::<i64>(), vec![5, -9]);
    }

    #[test]
    fn inplace_f64() {
        let a = Tensor::from_vec(vec![1.0f64, 2.0], &[2]);
        a.add_(&Tensor::from_vec(vec![0.5f64, 0.5], &[2]));
        a.mul_scalar_(2.0);
        a.axpy_(1.0, &Tensor::from_vec(vec![1.0f64, 1.0], &[2]));
        assert_eq!(a.to_vec::<f64>(), vec![4.0, 6.0]);
    }
}
