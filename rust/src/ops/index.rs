//! Indexing ops: embedding lookup (gather rows) with scatter-add backward,
//! and one-hot encoding.

use crate::autograd::{self, ClosureFunction};
use crate::device;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// Embedding lookup: `weight [V, D]` gathered by i64 `indices [..]` ->
/// `[.., D]`. Backward scatter-adds into the weight gradient.
pub fn embedding(weight: &Tensor, indices: &Tensor) -> Tensor {
    torsk_assert!(weight.ndim() == 2, "embedding: weight must be [V, D]");
    torsk_assert!(indices.dtype() == DType::I64, "embedding: indices must be i64");
    let (v, d) = (weight.size(0), weight.size(1));
    let w = weight.contiguous();
    let idx = indices.contiguous();
    let n = idx.numel();
    let mut out_shape = indices.shape().to_vec();
    out_shape.push(d);
    let out = Tensor::empty(&out_shape, DType::F32, weight.device());
    {
        let (wp, ip, op) = (w.data_ptr(), idx.data_ptr(), out.data_ptr());
        device::dispatch(weight.device(), "embedding", move || unsafe {
            let wv = wp.as_slice::<f32>(0, v * d);
            let iv = ip.as_slice::<i64>(0, n);
            let ov = op.as_mut_slice::<f32>(0, n * d);
            for (r, &i) in iv.iter().enumerate() {
                assert!((0..v as i64).contains(&i), "embedding index {i} out of range 0..{v}");
                ov[r * d..(r + 1) * d].copy_from_slice(&wv[i as usize * d..(i as usize + 1) * d]);
            }
        });
    }
    if autograd::should_record(&[weight]) {
        let idx2 = idx.clone();
        let dev = weight.device();
        autograd::record(&[weight], &out, || {
            ClosureFunction::new("embedding", move |g| {
                let g = g.contiguous();
                let gv = g.to_vec::<f32>();
                let iv = idx2.to_vec::<i64>();
                let mut gw = vec![0.0f32; v * d];
                for (r, &i) in iv.iter().enumerate() {
                    let row = &gv[r * d..(r + 1) * d];
                    let acc = &mut gw[i as usize * d..(i as usize + 1) * d];
                    for (a, &x) in acc.iter_mut().zip(row.iter()) {
                        *a += x;
                    }
                }
                vec![Some(Tensor::from_vec(gw, &[v, d]).to_device(dev))]
            })
        });
    }
    out
}

/// One-hot encode i64 `indices [N]` into f32 `[N, classes]`.
pub fn one_hot(indices: &Tensor, classes: usize) -> Tensor {
    torsk_assert!(indices.dtype() == DType::I64, "one_hot: indices must be i64");
    let iv = indices.to_vec::<i64>();
    let n = iv.len();
    let mut data = vec![0.0f32; n * classes];
    for (r, &i) in iv.iter().enumerate() {
        torsk_assert!((0..classes as i64).contains(&i), "one_hot: index {i} out of range");
        data[r * classes + i as usize] = 1.0;
    }
    let mut shape = indices.shape().to_vec();
    shape.push(classes);
    Tensor::from_vec(data, &shape).to_device(indices.device())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_gathers_rows() {
        let w = Tensor::from_vec((0..6).map(|x| x as f32).collect(), &[3, 2]);
        let idx = Tensor::from_vec(vec![2i64, 0, 2], &[3]);
        let e = embedding(&w, &idx);
        assert_eq!(e.shape(), &[3, 2]);
        assert_eq!(e.to_vec::<f32>(), vec![4.0, 5.0, 0.0, 1.0, 4.0, 5.0]);
    }

    #[test]
    fn embedding_2d_indices() {
        let w = Tensor::from_vec((0..8).map(|x| x as f32).collect(), &[4, 2]);
        let idx = Tensor::from_vec(vec![0i64, 1, 2, 3], &[2, 2]);
        let e = embedding(&w, &idx);
        assert_eq!(e.shape(), &[2, 2, 2]);
    }

    #[test]
    fn embedding_backward_scatter_adds() {
        let w = Tensor::zeros(&[3, 2]).requires_grad(true);
        let idx = Tensor::from_vec(vec![1i64, 1, 0], &[3]);
        embedding(&w, &idx).sum().backward();
        let g = w.grad().unwrap().to_vec::<f32>();
        // Row 1 hit twice, row 0 once, row 2 never.
        assert_eq!(g, vec![1.0, 1.0, 2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn one_hot_basic() {
        let idx = Tensor::from_vec(vec![0i64, 2], &[2]);
        let oh = one_hot(&idx, 3);
        assert_eq!(oh.to_vec::<f32>(), vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn one_hot_out_of_range_panics() {
        one_hot(&Tensor::from_vec(vec![3i64], &[1]), 3);
    }
}
