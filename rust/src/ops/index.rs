//! Indexing ops: embedding lookup (gather rows) with scatter-add backward,
//! and one-hot encoding — dispatcher shims.

use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

/// Embedding lookup: `weight [V, D]` gathered by i64 `indices [..]` ->
/// `[.., D]`. Backward scatter-adds into the weight gradient.
pub fn embedding(weight: &Tensor, indices: &Tensor) -> Tensor {
    dispatch::call("embedding", &[weight, indices], &[])
}

/// One-hot encode i64 `indices [N]` into f32 `[N, classes]`.
pub fn one_hot(indices: &Tensor, classes: usize) -> Tensor {
    dispatch::call("one_hot", &[indices], &[Param::Usize(classes)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_gathers_rows() {
        let w = Tensor::from_vec((0..6).map(|x| x as f32).collect(), &[3, 2]);
        let idx = Tensor::from_vec(vec![2i64, 0, 2], &[3]);
        let e = embedding(&w, &idx);
        assert_eq!(e.shape(), &[3, 2]);
        assert_eq!(e.to_vec::<f32>(), vec![4.0, 5.0, 0.0, 1.0, 4.0, 5.0]);
    }

    #[test]
    fn embedding_2d_indices() {
        let w = Tensor::from_vec((0..8).map(|x| x as f32).collect(), &[4, 2]);
        let idx = Tensor::from_vec(vec![0i64, 1, 2, 3], &[2, 2]);
        let e = embedding(&w, &idx);
        assert_eq!(e.shape(), &[2, 2, 2]);
    }

    #[test]
    fn embedding_backward_scatter_adds() {
        let w = Tensor::zeros(&[3, 2]).requires_grad(true);
        let idx = Tensor::from_vec(vec![1i64, 1, 0], &[3]);
        embedding(&w, &idx).sum().backward();
        let g = w.grad().unwrap().to_vec::<f32>();
        // Row 1 hit twice, row 0 once, row 2 never.
        assert_eq!(g, vec![1.0, 1.0, 2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn one_hot_basic() {
        let idx = Tensor::from_vec(vec![0i64, 2], &[2]);
        let oh = one_hot(&idx, 3);
        assert_eq!(oh.to_vec::<f32>(), vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn one_hot_out_of_range_panics() {
        one_hot(&Tensor::from_vec(vec![3i64], &[1]), 3);
    }
}
