//! Normalization ops: batch-norm (2d, NCHW) and layer-norm, built
//! compositionally from differentiable primitives — the "models are just
//! programs" philosophy (§4.1) applied to the library's own internals.
//! Autograd handles their backward passes automatically.

use crate::autograd::{self, no_grad, ClosureFunction, SavedTensor};
use crate::device;
use crate::kernels::norm::{bn_backward, bn_normalize, bn_stats};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// Batch normalization over NCHW input (normalizes per channel across
/// N,H,W). In training mode computes batch statistics and updates the
/// running stats in place (under `no_grad`); in eval mode uses the running
/// stats. Returns the normalized, scaled, shifted output.
#[allow(clippy::too_many_arguments)]
pub fn batch_norm2d(
    input: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    running_mean: &Tensor,
    running_var: &Tensor,
    training: bool,
    momentum: f32,
    eps: f32,
) -> Tensor {
    torsk_assert!(input.ndim() == 4, "batch_norm2d: input must be NCHW");
    let c = input.size(1);
    torsk_assert!(gamma.shape() == [c] && beta.shape() == [c], "batch_norm2d: affine shape");
    let cshape = [1, c, 1, 1];

    if training {
        return batch_norm2d_fused(input, gamma, beta, running_mean, running_var, momentum, eps);
    }
    // Eval mode: running-stat normalization via (fast-path) broadcast ops.
    let (mean, var) = (
        running_mean.detach().reshape(&cshape),
        running_var.detach().reshape(&cshape),
    );
    let centered = super::sub(input, &mean);
    let inv_std = super::pow_scalar(&super::add_scalar(&var, eps), -0.5);
    let xhat = super::mul(&centered, &inv_std);
    let g = gamma.reshape(&cshape);
    let b = beta.reshape(&cshape);
    super::add(&super::mul(&xhat, &g), &b)
}

/// Fused training-mode batch norm (§Perf): single-kernel statistics +
/// normalize with a hand-written backward (the paper's "implementation
/// accepts added complexity in order to deliver performance", §3).
fn batch_norm2d_fused(
    input: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    running_mean: &Tensor,
    running_var: &Tensor,
    momentum: f32,
    eps: f32,
) -> Tensor {
    let (n, c, h, w) = (input.size(0), input.size(1), input.size(2), input.size(3));
    let hw = h * w;
    let x = input.contiguous();
    let gamma_c = gamma.contiguous();
    let beta_c = beta.contiguous();
    let dev = x.device();

    let out = Tensor::empty(x.shape(), DType::F32, dev);
    let mean_t = Tensor::empty(&[c], DType::F32, dev);
    let inv_std_t = Tensor::empty(&[c], DType::F32, dev);
    {
        let (xp, gp, bp, op) = (x.data_ptr(), gamma_c.data_ptr(), beta_c.data_ptr(), out.data_ptr());
        let (mp, ip) = (mean_t.data_ptr(), inv_std_t.data_ptr());
        let len = x.numel();
        device::dispatch(dev, "batch_norm", move || unsafe {
            let xv = xp.as_slice::<f32>(0, len);
            let mean = mp.as_mut_slice::<f32>(0, c);
            let inv_std = ip.as_mut_slice::<f32>(0, c);
            let mut var = vec![0.0f32; c];
            bn_stats(n, c, hw, xv, mean, &mut var);
            for (o, &v) in inv_std.iter_mut().zip(var.iter()) {
                *o = 1.0 / (v + eps).sqrt();
            }
            bn_normalize(
                n,
                c,
                hw,
                xv,
                mean,
                inv_std,
                gp.as_slice::<f32>(0, c),
                bp.as_slice::<f32>(0, c),
                op.as_mut_slice::<f32>(0, len),
            );
        });
    }
    // Update running stats from the just-computed batch stats.
    no_grad(|| {
        let mean_h = mean_t.detach();
        // var = 1/inv_std^2 - eps
        let var_h = super::add_scalar(
            &super::pow_scalar(&inv_std_t.detach(), -2.0),
            -eps,
        );
        running_mean.mul_scalar_(1.0 - momentum);
        running_mean.axpy_(momentum, &mean_h);
        running_var.mul_scalar_(1.0 - momentum);
        running_var.axpy_(momentum, &var_h);
    });

    if autograd::should_record(&[input, gamma, beta]) {
        let vx = SavedTensor::save(&x);
        let vgamma = SavedTensor::save(&gamma_c);
        let vmean = mean_t.clone();
        let vinv = inv_std_t.clone();
        autograd::record(&[input, gamma, beta], &out, || {
            ClosureFunction::new("batch_norm", move |g| {
                let x = vx.unpack().contiguous();
                let gamma = vgamma.unpack().contiguous();
                let g = g.contiguous();
                if g.device().is_async() {
                    device::synchronize();
                }
                let xv = x.to_vec::<f32>();
                let gv = g.to_vec::<f32>();
                let mean = vmean.to_vec::<f32>();
                let inv_std = vinv.to_vec::<f32>();
                let gam = gamma.to_vec::<f32>();
                let mut dx = vec![0.0f32; xv.len()];
                let mut dgamma = vec![0.0f32; c];
                let mut dbeta = vec![0.0f32; c];
                bn_backward(n, c, hw, &xv, &mean, &inv_std, &gam, &gv, &mut dx, &mut dgamma, &mut dbeta);
                let dev = x.device();
                vec![
                    Some(Tensor::from_vec(dx, x.shape()).to_device(dev)),
                    Some(Tensor::from_vec(dgamma, &[c]).to_device(dev)),
                    Some(Tensor::from_vec(dbeta, &[c]).to_device(dev)),
                ]
            })
        });
    }
    out
}

/// Layer normalization over the last dimension.
pub fn layer_norm(input: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let last = input.ndim() - 1;
    let d = input.size(last);
    torsk_assert!(gamma.shape() == [d] && beta.shape() == [d], "layer_norm: affine shape");
    let mean = super::mean_dims(input, &[last], true);
    let centered = super::sub(input, &mean);
    let var = super::mean_dims(&super::mul(&centered, &centered), &[last], true);
    let inv_std = super::pow_scalar(&super::add_scalar(&var, eps), -0.5);
    let xhat = super::mul(&centered, &inv_std);
    super::add(&super::mul(&xhat, gamma), beta)
}

/// Dropout: zeroes elements with probability `p` and scales survivors by
/// `1/(1-p)` (inverted dropout). Identity in eval mode.
pub fn dropout(input: &Tensor, p: f32, training: bool) -> Tensor {
    if !training || p == 0.0 {
        return input.clone();
    }
    torsk_assert!((0.0..1.0).contains(&p), "dropout: p must be in [0,1)");
    let scale = 1.0 / (1.0 - p);
    let mask_data: Vec<f32> = crate::rng::with_rng(|r| {
        (0..input.numel())
            .map(|_| if r.bernoulli(p) { 0.0 } else { scale })
            .collect()
    });
    let mask = Tensor::from_vec(mask_data, input.shape()).to_device(input.device());
    super::mul(input, &mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bn_setup(c: usize) -> (Tensor, Tensor, Tensor, Tensor) {
        (
            Tensor::ones(&[c]),
            Tensor::zeros(&[c]),
            Tensor::zeros(&[c]),
            Tensor::ones(&[c]),
        )
    }

    #[test]
    fn batch_norm_normalizes_channels() {
        crate::rng::manual_seed(1);
        let x = Tensor::randn(&[4, 3, 5, 5]);
        let (g, b, rm, rv) = bn_setup(3);
        let y = batch_norm2d(&x, &g, &b, &rm, &rv, true, 0.1, 1e-5);
        // Per-channel mean ~0, var ~1.
        let m = super::super::mean_dims(&y, &[0, 2, 3], false).to_vec::<f32>();
        let v = super::super::mean_dims(&super::super::mul(&y, &y), &[0, 2, 3], false).to_vec::<f32>();
        for c in 0..3 {
            assert!(m[c].abs() < 1e-4, "mean[{c}]={}", m[c]);
            assert!((v[c] - 1.0).abs() < 1e-2, "var[{c}]={}", v[c]);
        }
    }

    #[test]
    fn batch_norm_updates_running_stats() {
        let x = Tensor::full(&[2, 1, 2, 2], 10.0);
        let (g, b, rm, rv) = bn_setup(1);
        batch_norm2d(&x, &g, &b, &rm, &rv, true, 0.5, 1e-5);
        // running_mean = 0.5*0 + 0.5*10 = 5
        assert!((rm.to_vec::<f32>()[0] - 5.0).abs() < 1e-5);
        // batch var is 0 -> running_var = 0.5*1 + 0.5*0 = 0.5
        assert!((rv.to_vec::<f32>()[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn batch_norm_eval_uses_running_stats() {
        let x = Tensor::full(&[1, 1, 1, 1], 3.0);
        let (g, b, rm, rv) = bn_setup(1);
        rm.fill_(1.0);
        rv.fill_(4.0);
        let y = batch_norm2d(&x, &g, &b, &rm, &rv, false, 0.1, 0.0);
        // (3 - 1)/sqrt(4) = 1
        assert!((y.item() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn batch_norm_gradients_flow_to_affine() {
        crate::rng::manual_seed(2);
        let x = Tensor::randn(&[2, 2, 3, 3]).requires_grad(true);
        let g = Tensor::ones(&[2]).requires_grad(true);
        let b = Tensor::zeros(&[2]).requires_grad(true);
        let (rm, rv) = (Tensor::zeros(&[2]), Tensor::ones(&[2]));
        let y = batch_norm2d(&x, &g, &b, &rm, &rv, true, 0.1, 1e-5);
        y.sum().backward();
        assert!(x.grad().is_some());
        assert!(g.grad().is_some());
        // d(sum)/d(beta_c) = N*H*W = 18
        let gb = b.grad().unwrap().to_vec::<f32>();
        assert!((gb[0] - 18.0).abs() < 1e-3);
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        crate::rng::manual_seed(3);
        let x = Tensor::randn(&[5, 16]);
        let g = Tensor::ones(&[16]);
        let b = Tensor::zeros(&[16]);
        let y = layer_norm(&x, &g, &b, 1e-5);
        let v = y.to_vec::<f32>();
        for r in 0..5 {
            let row = &v[r * 16..(r + 1) * 16];
            let m: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / 16.0;
            assert!(m.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn dropout_eval_is_identity() {
        let x = Tensor::randn(&[10]);
        let y = dropout(&x, 0.5, false);
        assert!(x.shares_storage(&y));
    }

    #[test]
    fn dropout_train_zeroes_and_scales() {
        crate::rng::manual_seed(4);
        let x = Tensor::ones(&[10_000]);
        let y = dropout(&x, 0.25, true);
        let v = y.to_vec::<f32>();
        let zeros = v.iter().filter(|&&z| z == 0.0).count();
        let scale = 1.0 / 0.75;
        assert!(v.iter().all(|&z| z == 0.0 || (z - scale).abs() < 1e-6));
        assert!((2000..3000).contains(&zeros), "zeros={zeros}");
        // E[y] stays ~1 (inverted dropout).
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!((mean - 1.0).abs() < 0.05);
    }

    #[test]
    fn dropout_backward_masks_grad() {
        crate::rng::manual_seed(5);
        let x = Tensor::ones(&[100]).requires_grad(true);
        let y = dropout(&x, 0.5, true);
        y.sum().backward();
        let g = x.grad().unwrap().to_vec::<f32>();
        let yv = y.to_vec::<f32>();
        for i in 0..100 {
            if yv[i] == 0.0 {
                assert_eq!(g[i], 0.0);
            } else {
                assert!((g[i] - 2.0).abs() < 1e-6);
            }
        }
    }
}
