//! Normalization ops — dispatcher shims. Training-mode batch-norm routes
//! to the fused `batch_norm_train` registry entry; eval mode to the
//! composite `batch_norm` entry built from differentiable primitives.

use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

/// Batch normalization over NCHW input (normalizes per channel across
/// N,H,W). In training mode computes batch statistics and updates the
/// running stats in place (under `no_grad`); in eval mode uses the running
/// stats. Returns the normalized, scaled, shifted output.
#[allow(clippy::too_many_arguments)]
pub fn batch_norm2d(
    input: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    running_mean: &Tensor,
    running_var: &Tensor,
    training: bool,
    momentum: f32,
    eps: f32,
) -> Tensor {
    let inputs = [input, gamma, beta, running_mean, running_var];
    if training {
        dispatch::call("batch_norm_train", &inputs, &[Param::F32(momentum), Param::F32(eps)])
    } else {
        dispatch::call("batch_norm", &inputs, &[Param::F32(eps)])
    }
}

/// Layer normalization over the last dimension.
pub fn layer_norm(input: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    dispatch::call("layer_norm", &[input, gamma, beta], &[Param::F32(eps)])
}

/// Dropout: zeroes elements with probability `p` and scales survivors by
/// `1/(1-p)` (inverted dropout). Identity in eval mode.
pub fn dropout(input: &Tensor, p: f32, training: bool) -> Tensor {
    dispatch::call("dropout", &[input], &[Param::F32(p), Param::Bool(training)])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bn_setup(c: usize) -> (Tensor, Tensor, Tensor, Tensor) {
        (
            Tensor::ones(&[c]),
            Tensor::zeros(&[c]),
            Tensor::zeros(&[c]),
            Tensor::ones(&[c]),
        )
    }

    #[test]
    fn batch_norm_normalizes_channels() {
        crate::rng::manual_seed(1);
        let x = Tensor::randn(&[4, 3, 5, 5]);
        let (g, b, rm, rv) = bn_setup(3);
        let y = batch_norm2d(&x, &g, &b, &rm, &rv, true, 0.1, 1e-5);
        // Per-channel mean ~0, var ~1.
        let m = super::super::mean_dims(&y, &[0, 2, 3], false).to_vec::<f32>();
        let v = super::super::mean_dims(&super::super::mul(&y, &y), &[0, 2, 3], false).to_vec::<f32>();
        for c in 0..3 {
            assert!(m[c].abs() < 1e-4, "mean[{c}]={}", m[c]);
            assert!((v[c] - 1.0).abs() < 1e-2, "var[{c}]={}", v[c]);
        }
    }

    #[test]
    fn batch_norm_updates_running_stats() {
        let x = Tensor::full(&[2, 1, 2, 2], 10.0);
        let (g, b, rm, rv) = bn_setup(1);
        batch_norm2d(&x, &g, &b, &rm, &rv, true, 0.5, 1e-5);
        // running_mean = 0.5*0 + 0.5*10 = 5
        assert!((rm.to_vec::<f32>()[0] - 5.0).abs() < 1e-5);
        // batch var is 0 -> running_var = 0.5*1 + 0.5*0 = 0.5
        assert!((rv.to_vec::<f32>()[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn batch_norm_eval_uses_running_stats() {
        let x = Tensor::full(&[1, 1, 1, 1], 3.0);
        let (g, b, rm, rv) = bn_setup(1);
        rm.fill_(1.0);
        rv.fill_(4.0);
        let y = batch_norm2d(&x, &g, &b, &rm, &rv, false, 0.1, 0.0);
        // (3 - 1)/sqrt(4) = 1
        assert!((y.item() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn batch_norm_gradients_flow_to_affine() {
        crate::rng::manual_seed(2);
        let x = Tensor::randn(&[2, 2, 3, 3]).requires_grad(true);
        let g = Tensor::ones(&[2]).requires_grad(true);
        let b = Tensor::zeros(&[2]).requires_grad(true);
        let (rm, rv) = (Tensor::zeros(&[2]), Tensor::ones(&[2]));
        let y = batch_norm2d(&x, &g, &b, &rm, &rv, true, 0.1, 1e-5);
        y.sum().backward();
        assert!(x.grad().is_some());
        assert!(g.grad().is_some());
        // d(sum)/d(beta_c) = N*H*W = 18
        let gb = b.grad().unwrap().to_vec::<f32>();
        assert!((gb[0] - 18.0).abs() < 1e-3);
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        crate::rng::manual_seed(3);
        let x = Tensor::randn(&[5, 16]);
        let g = Tensor::ones(&[16]);
        let b = Tensor::zeros(&[16]);
        let y = layer_norm(&x, &g, &b, 1e-5);
        let v = y.to_vec::<f32>();
        for r in 0..5 {
            let row = &v[r * 16..(r + 1) * 16];
            let m: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / 16.0;
            assert!(m.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn dropout_eval_is_identity() {
        let x = Tensor::randn(&[10]);
        let y = dropout(&x, 0.5, false);
        assert!(x.shares_storage(&y));
    }

    #[test]
    fn dropout_train_zeroes_and_scales() {
        crate::rng::manual_seed(4);
        let x = Tensor::ones(&[10_000]);
        let y = dropout(&x, 0.25, true);
        let v = y.to_vec::<f32>();
        let zeros = v.iter().filter(|&&z| z == 0.0).count();
        let scale = 1.0 / 0.75;
        assert!(v.iter().all(|&z| z == 0.0 || (z - scale).abs() < 1e-6));
        assert!((2000..3000).contains(&zeros), "zeros={zeros}");
        // E[y] stays ~1 (inverted dropout).
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!((mean - 1.0).abs() < 0.05);
    }

    #[test]
    fn dropout_backward_masks_grad() {
        crate::rng::manual_seed(5);
        let x = Tensor::ones(&[100]).requires_grad(true);
        let y = dropout(&x, 0.5, true);
        y.sum().backward();
        let g = x.grad().unwrap().to_vec::<f32>();
        let yv = y.to_vec::<f32>();
        for i in 0..100 {
            if yv[i] == 0.0 {
                assert_eq!(g[i], 0.0);
            } else {
                assert!((g[i] - 2.0).abs() < 1e-6);
            }
        }
    }
}
