//! Unary elementwise ops — shims over the dispatcher's generic (F32/F64)
//! registry entries.

use crate::dispatch::{self, Param};
use crate::tensor::{DType, Tensor};

/// Elementwise `exp` with autograd.
pub fn exp(a: &Tensor) -> Tensor {
    dispatch::call("exp", &[a], &[])
}

/// Elementwise natural log with autograd.
pub fn log(a: &Tensor) -> Tensor {
    dispatch::call("log", &[a], &[])
}

/// Elementwise `sqrt` with autograd.
pub fn sqrt(a: &Tensor) -> Tensor {
    dispatch::call("sqrt", &[a], &[])
}

/// Elementwise `relu` with autograd.
pub fn relu(a: &Tensor) -> Tensor {
    dispatch::call("relu", &[a], &[])
}

/// GELU (tanh approximation), fused: forward and backward each run as a
/// single micro-op tape pass (`fused:gelu`) instead of the 9-op chain
/// `0.5*x*(1 + tanh(√(2/π)*(x + 0.044715*x³)))`.
pub fn gelu(a: &Tensor) -> Tensor {
    dispatch::call("fused:gelu", &[a], &[])
}

/// Elementwise logistic sigmoid with autograd.
pub fn sigmoid(a: &Tensor) -> Tensor {
    dispatch::call("sigmoid", &[a], &[])
}

/// Elementwise `tanh` with autograd.
pub fn tanh(a: &Tensor) -> Tensor {
    dispatch::call("tanh", &[a], &[])
}

/// Negation (any numeric dtype).
pub fn neg(a: &Tensor) -> Tensor {
    dispatch::call("neg", &[a], &[])
}

/// Add a scalar.
pub fn add_scalar(a: &Tensor, s: f32) -> Tensor {
    dispatch::call("add_scalar", &[a], &[Param::F32(s)])
}

/// Multiply by a scalar.
pub fn mul_scalar(a: &Tensor, s: f32) -> Tensor {
    dispatch::call("mul_scalar", &[a], &[Param::F32(s)])
}

/// Elementwise power with scalar exponent.
pub fn pow_scalar(a: &Tensor, p: f32) -> Tensor {
    dispatch::call("pow_scalar", &[a], &[Param::F32(p)])
}

/// Clamp to [lo, hi] (gradient flows where not clamped).
pub fn clamp(a: &Tensor, lo: f32, hi: f32) -> Tensor {
    dispatch::call("clamp", &[a], &[Param::F32(lo), Param::F32(hi)])
}

/// Convert to `dt` (gradients cast back to the input dtype).
pub fn cast(a: &Tensor, dt: DType) -> Tensor {
    dispatch::call("cast", &[a], &[Param::DType(dt)])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_of(f: impl Fn(&Tensor) -> Tensor, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let t = Tensor::from_slice(x).requires_grad(true);
        let y = f(&t);
        y.backward_with(Tensor::ones(&[x.len()]));
        (y.to_vec::<f32>(), t.grad().unwrap().to_vec::<f32>())
    }

    #[test]
    fn relu_forward_backward() {
        let (y, g) = grad_of(|t| relu(t), &[-1.0, 0.0, 2.0]);
        assert_eq!(y, vec![0.0, 0.0, 2.0]);
        assert_eq!(g, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn exp_grad_is_output() {
        let (y, g) = grad_of(|t| exp(t), &[0.0, 1.0]);
        assert_eq!(y, g);
        assert!((y[1] - std::f32::consts::E).abs() < 1e-5);
    }

    #[test]
    fn log_grad_is_reciprocal() {
        let (_, g) = grad_of(|t| log(t), &[2.0, 4.0]);
        assert!((g[0] - 0.5).abs() < 1e-6);
        assert!((g[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn sigmoid_values_and_grad() {
        let (y, g) = grad_of(|t| sigmoid(t), &[0.0]);
        assert!((y[0] - 0.5).abs() < 1e-6);
        assert!((g[0] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn tanh_grad() {
        let (y, g) = grad_of(|t| tanh(t), &[0.5]);
        assert!((g[0] - (1.0 - y[0] * y[0])).abs() < 1e-6);
    }

    #[test]
    fn sqrt_grad() {
        let (_, g) = grad_of(|t| sqrt(t), &[4.0]);
        assert!((g[0] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn pow_scalar_grad() {
        let (y, g) = grad_of(|t| pow_scalar(t, 3.0), &[2.0]);
        assert_eq!(y, vec![8.0]);
        assert!((g[0] - 12.0).abs() < 1e-4);
    }

    #[test]
    fn clamp_masks_grad() {
        let (y, g) = grad_of(|t| clamp(t, 0.0, 1.0), &[-0.5, 0.5, 1.5]);
        assert_eq!(y, vec![0.0, 0.5, 1.0]);
        assert_eq!(g, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn scalar_ops() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        assert_eq!(add_scalar(&a, 0.5).to_vec::<f32>(), vec![1.5, 2.5]);
        assert_eq!(mul_scalar(&a, -2.0).to_vec::<f32>(), vec![-2.0, -4.0]);
    }

    #[test]
    fn mul_scalar_grad_scales() {
        let (_, g) = grad_of(|t| mul_scalar(t, 3.0), &[1.0, 2.0]);
        assert_eq!(g, vec![3.0, 3.0]);
    }

    #[test]
    fn chained_unary_composition() {
        // f(x) = exp(relu(x)); f'(2) = exp(2)
        let t = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let y = exp(&relu(&t));
        y.backward_with(Tensor::ones(&[1]));
        let g = t.grad().unwrap().item();
        assert!((g - 2.0f32.exp()).abs() < 1e-4);
    }

    #[test]
    fn no_graph_recorded_under_no_grad() {
        let t = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let y = crate::autograd::no_grad(|| relu(&t));
        assert!(y.grad_fn().is_none());
    }

    #[test]
    fn unary_f64_end_to_end() {
        let t = Tensor::from_vec(vec![4.0f64], &[1]).requires_grad(true);
        let y = sqrt(&t);
        assert_eq!(y.dtype(), DType::F64);
        assert_eq!(y.to_vec::<f64>(), vec![2.0]);
        y.backward_with(Tensor::from_vec(vec![1.0f64], &[1]));
        let g = t.grad().unwrap().to_vec::<f64>();
        assert!((g[0] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn cast_roundtrip_and_grad() {
        let t = Tensor::from_slice(&[1.5f32, -2.0]).requires_grad(true);
        let d = cast(&t, DType::F64);
        assert_eq!(d.dtype(), DType::F64);
        assert_eq!(d.to_vec::<f64>(), vec![1.5, -2.0]);
        d.backward_with(Tensor::from_vec(vec![1.0f64, 2.0], &[2]));
        let g = t.grad().unwrap();
        assert_eq!(g.dtype(), DType::F32);
        assert_eq!(g.to_vec::<f32>(), vec![1.0, 2.0]);
        // i64 casts work too (no grad).
        let i = cast(&Tensor::from_slice(&[2.9f32]), DType::I64);
        assert_eq!(i.to_vec::<i64>(), vec![2]);
    }

    #[test]
    #[should_panic(expected = "unsupported dtype")]
    fn float_unary_rejects_i64() {
        exp(&Tensor::from_vec(vec![1i64], &[1]));
    }
}
