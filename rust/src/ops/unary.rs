//! Unary elementwise ops with autograd.

use crate::autograd::{self, ClosureFunction, SavedTensor};
use crate::device;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// Elementwise map (f32), preserving shape; works on strided views via a
/// contiguous materialization.
pub(crate) fn unary_map(name: &'static str, a: &Tensor, f: fn(f32) -> f32) -> Tensor {
    torsk_assert!(a.dtype() == DType::F32, "{name}: f32 only");
    let a = a.contiguous();
    let out = Tensor::empty(a.shape(), DType::F32, a.device());
    let n = a.numel();
    let (ap, op) = (a.data_ptr(), out.data_ptr());
    device::dispatch(a.device(), name, move || unsafe {
        let av = ap.as_slice::<f32>(0, n);
        crate::kernels::parallel_for(n, crate::kernels::PAR_GRAIN, |s, e| {
            let ov = std::slice::from_raw_parts_mut(op.as_f32_mut(), n);
            for i in s..e {
                ov[i] = f(av[i]);
            }
        });
    });
    out
}

macro_rules! unary_with_saved_output {
    ($name:literal, $fn_name:ident, $fwd:expr, $bwd_from_out:expr) => {
        #[doc = concat!("Elementwise `", $name, "` with autograd.")]
        pub fn $fn_name(a: &Tensor) -> Tensor {
            let out = unary_map($name, a, $fwd);
            if autograd::should_record(&[a]) {
                let saved_out = SavedTensor::save(&out);
                autograd::record(&[a], &out, || {
                    ClosureFunction::new($name, move |g| {
                        let y = saved_out.unpack();
                        let dydx = unary_map(concat!($name, "_bwd"), &y, $bwd_from_out);
                        vec![Some(super::binary_map("mul", g, &dydx, |x, w| x * w))]
                    })
                });
            }
            out
        }
    };
}

macro_rules! unary_with_saved_input {
    ($name:literal, $fn_name:ident, $fwd:expr, $bwd_from_in:expr) => {
        #[doc = concat!("Elementwise `", $name, "` with autograd.")]
        pub fn $fn_name(a: &Tensor) -> Tensor {
            let out = unary_map($name, a, $fwd);
            if autograd::should_record(&[a]) {
                let saved_in = SavedTensor::save(a);
                autograd::record(&[a], &out, || {
                    ClosureFunction::new($name, move |g| {
                        let x = saved_in.unpack();
                        let dydx = unary_map(concat!($name, "_bwd"), &x, $bwd_from_in);
                        vec![Some(super::binary_map("mul", g, &dydx, |x, w| x * w))]
                    })
                });
            }
            out
        }
    };
}

// d(exp)/dx = exp(x) = y ; d(sigmoid)/dx = y(1-y) ; d(tanh)/dx = 1-y^2;
// d(sqrt)/dx = 1/(2y) ; d(relu)/dx = [y > 0].
unary_with_saved_output!("exp", exp, |x| x.exp(), |y| y);
unary_with_saved_output!("sigmoid", sigmoid, |x| 1.0 / (1.0 + (-x).exp()), |y| y * (1.0 - y));
unary_with_saved_output!("tanh", tanh, |x| x.tanh(), |y| 1.0 - y * y);
unary_with_saved_output!("sqrt", sqrt, |x| x.sqrt(), |y| 0.5 / y);
unary_with_saved_output!("relu", relu, |x| x.max(0.0), |y| if y > 0.0 { 1.0 } else { 0.0 });

// d(log)/dx = 1/x needs the input.
unary_with_saved_input!("log", log, |x| x.ln(), |x| 1.0 / x);

/// Negation.
pub fn neg(a: &Tensor) -> Tensor {
    let out = unary_map("neg", a, |x| -x);
    if autograd::should_record(&[a]) {
        autograd::record(&[a], &out, || {
            ClosureFunction::new("neg", move |g| vec![Some(neg_nograd(g))])
        });
    }
    out
}

fn neg_nograd(g: &Tensor) -> Tensor {
    unary_map("neg", g, |x| -x)
}

/// Add a scalar.
pub fn add_scalar(a: &Tensor, s: f32) -> Tensor {
    // Closure over `s`: build via mul trick — use a dedicated dispatch.
    let out = scalar_map("add_scalar", a, s, |x, s| x + s);
    if autograd::should_record(&[a]) {
        autograd::record(&[a], &out, || {
            ClosureFunction::new("add_scalar", move |g| vec![Some(g.clone())])
        });
    }
    out
}

/// Multiply by a scalar.
pub fn mul_scalar(a: &Tensor, s: f32) -> Tensor {
    let out = scalar_map("mul_scalar", a, s, |x, s| x * s);
    if autograd::should_record(&[a]) {
        autograd::record(&[a], &out, || {
            ClosureFunction::new("mul_scalar", move |g| {
                vec![Some(scalar_map("mul_scalar", g, s, |x, s| x * s))]
            })
        });
    }
    out
}

/// Elementwise power with scalar exponent.
pub fn pow_scalar(a: &Tensor, p: f32) -> Tensor {
    let out = scalar_map("pow", a, p, |x, p| x.powf(p));
    if autograd::should_record(&[a]) {
        let saved = SavedTensor::save(a);
        autograd::record(&[a], &out, || {
            ClosureFunction::new("pow", move |g| {
                let x = saved.unpack();
                let dydx = scalar_map("pow_bwd", &x, p, |x, p| p * x.powf(p - 1.0));
                vec![Some(super::binary_map("mul", g, &dydx, |x, w| x * w))]
            })
        });
    }
    out
}

/// Clamp to [lo, hi] (gradient flows where not clamped).
pub fn clamp(a: &Tensor, lo: f32, hi: f32) -> Tensor {
    let out = scalar2_map("clamp", a, lo, hi, |x, lo, hi| x.clamp(lo, hi));
    if autograd::should_record(&[a]) {
        let saved = SavedTensor::save(a);
        autograd::record(&[a], &out, || {
            ClosureFunction::new("clamp", move |g| {
                let x = saved.unpack();
                let mask = scalar2_map("clamp_mask", &x, lo, hi, |x, lo, hi| {
                    if x >= lo && x <= hi {
                        1.0
                    } else {
                        0.0
                    }
                });
                vec![Some(super::binary_map("mul", g, &mask, |x, w| x * w))]
            })
        });
    }
    out
}

/// Elementwise map with one scalar parameter.
pub(crate) fn scalar_map(name: &'static str, a: &Tensor, s: f32, f: fn(f32, f32) -> f32) -> Tensor {
    torsk_assert!(a.dtype() == DType::F32, "{name}: f32 only");
    let a = a.contiguous();
    let out = Tensor::empty(a.shape(), DType::F32, a.device());
    let n = a.numel();
    let (ap, op) = (a.data_ptr(), out.data_ptr());
    device::dispatch(a.device(), name, move || unsafe {
        let av = ap.as_slice::<f32>(0, n);
        let ov = op.as_mut_slice::<f32>(0, n);
        for i in 0..n {
            ov[i] = f(av[i], s);
        }
    });
    out
}

fn scalar2_map(name: &'static str, a: &Tensor, s1: f32, s2: f32, f: fn(f32, f32, f32) -> f32) -> Tensor {
    let a = a.contiguous();
    let out = Tensor::empty(a.shape(), DType::F32, a.device());
    let n = a.numel();
    let (ap, op) = (a.data_ptr(), out.data_ptr());
    device::dispatch(a.device(), name, move || unsafe {
        let av = ap.as_slice::<f32>(0, n);
        let ov = op.as_mut_slice::<f32>(0, n);
        for i in 0..n {
            ov[i] = f(av[i], s1, s2);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_of(f: impl Fn(&Tensor) -> Tensor, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let t = Tensor::from_slice(x).requires_grad(true);
        let y = f(&t);
        y.backward_with(Tensor::ones(&[x.len()]));
        (y.to_vec::<f32>(), t.grad().unwrap().to_vec::<f32>())
    }

    #[test]
    fn relu_forward_backward() {
        let (y, g) = grad_of(|t| relu(t), &[-1.0, 0.0, 2.0]);
        assert_eq!(y, vec![0.0, 0.0, 2.0]);
        assert_eq!(g, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn exp_grad_is_output() {
        let (y, g) = grad_of(|t| exp(t), &[0.0, 1.0]);
        assert_eq!(y, g);
        assert!((y[1] - std::f32::consts::E).abs() < 1e-5);
    }

    #[test]
    fn log_grad_is_reciprocal() {
        let (_, g) = grad_of(|t| log(t), &[2.0, 4.0]);
        assert!((g[0] - 0.5).abs() < 1e-6);
        assert!((g[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn sigmoid_values_and_grad() {
        let (y, g) = grad_of(|t| sigmoid(t), &[0.0]);
        assert!((y[0] - 0.5).abs() < 1e-6);
        assert!((g[0] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn tanh_grad() {
        let (y, g) = grad_of(|t| tanh(t), &[0.5]);
        assert!((g[0] - (1.0 - y[0] * y[0])).abs() < 1e-6);
    }

    #[test]
    fn sqrt_grad() {
        let (_, g) = grad_of(|t| sqrt(t), &[4.0]);
        assert!((g[0] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn pow_scalar_grad() {
        let (y, g) = grad_of(|t| pow_scalar(t, 3.0), &[2.0]);
        assert_eq!(y, vec![8.0]);
        assert!((g[0] - 12.0).abs() < 1e-4);
    }

    #[test]
    fn clamp_masks_grad() {
        let (y, g) = grad_of(|t| clamp(t, 0.0, 1.0), &[-0.5, 0.5, 1.5]);
        assert_eq!(y, vec![0.0, 0.5, 1.0]);
        assert_eq!(g, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn scalar_ops() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        assert_eq!(add_scalar(&a, 0.5).to_vec::<f32>(), vec![1.5, 2.5]);
        assert_eq!(mul_scalar(&a, -2.0).to_vec::<f32>(), vec![-2.0, -4.0]);
    }

    #[test]
    fn mul_scalar_grad_scales() {
        let (_, g) = grad_of(|t| mul_scalar(t, 3.0), &[1.0, 2.0]);
        assert_eq!(g, vec![3.0, 3.0]);
    }

    #[test]
    fn chained_unary_composition() {
        // f(x) = exp(relu(x)); f'(2) = exp(2)
        let t = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let y = exp(&relu(&t));
        y.backward_with(Tensor::ones(&[1]));
        let g = t.grad().unwrap().item();
        assert!((g - 2.0f32.exp()).abs() < 1e-4);
    }

    #[test]
    fn no_graph_recorded_under_no_grad() {
        let t = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let y = crate::autograd::no_grad(|| relu(&t));
        assert!(y.grad_fn().is_none());
    }
}
