//! Eager operators over [`Tensor`] — thin shims over the central
//! [`crate::dispatch`] registry.
//!
//! Every public function here is one line: it names an op and forwards to
//! [`dispatch::call`], the single choke point that validates the schema,
//! resolves the backend key (`Cpu` runs inline, `Sim` queues on the
//! current stream, §5.2), promotes dtypes, emits a per-op profiler span
//! and records the autograd node. Op *semantics* (kernels + backward
//! rules) live in `dispatch/`'s registry entries; this module is the
//! stable user-facing API surface: free functions (`ops::add(&a, &b)`),
//! ergonomic `Tensor` methods (`a.add(&b)`), and `std::ops` operator
//! overloads (`&a * &b + &c`, `&a + 1.0`) mirroring `torch.add` /
//! `Tensor.add` / Python operators.

mod binary;
mod conv;
mod index;
mod inplace;
mod linalg;
mod loss;
mod norm;
mod pool;
mod reduce;
mod unary;
mod views;

pub use binary::*;
pub use conv::*;
pub use index::*;
#[allow(unused_imports)]
pub use inplace::*;
pub use linalg::*;
pub use loss::*;
pub use norm::*;
pub use pool::*;
pub use reduce::*;
pub use unary::*;
pub use views::*;

use crate::tensor::{DType, Tensor};

// ------------------------------------------------------------------
// Ergonomic Tensor methods (the `x.relu().matmul(&w)` chaining style
// of Listing 1).
// ------------------------------------------------------------------

impl Tensor {
    pub fn add(&self, other: &Tensor) -> Tensor {
        add(self, other)
    }
    pub fn sub(&self, other: &Tensor) -> Tensor {
        sub(self, other)
    }
    pub fn mul(&self, other: &Tensor) -> Tensor {
        mul(self, other)
    }
    pub fn div(&self, other: &Tensor) -> Tensor {
        div(self, other)
    }
    pub fn add_scalar(&self, s: f32) -> Tensor {
        add_scalar(self, s)
    }
    pub fn mul_scalar(&self, s: f32) -> Tensor {
        mul_scalar(self, s)
    }
    pub fn neg(&self) -> Tensor {
        neg(self)
    }
    pub fn exp(&self) -> Tensor {
        exp(self)
    }
    pub fn log(&self) -> Tensor {
        log(self)
    }
    pub fn sqrt(&self) -> Tensor {
        sqrt(self)
    }
    pub fn relu(&self) -> Tensor {
        relu(self)
    }
    pub fn gelu(&self) -> Tensor {
        gelu(self)
    }
    pub fn sigmoid(&self) -> Tensor {
        sigmoid(self)
    }
    pub fn tanh(&self) -> Tensor {
        tanh(self)
    }
    pub fn pow_scalar(&self, p: f32) -> Tensor {
        pow_scalar(self, p)
    }
    /// Convert to another dtype (`tensor.to(torch.float64)`); routes
    /// through the `cast` registry entry, so gradients cast back.
    pub fn to_dtype(&self, dt: DType) -> Tensor {
        cast(self, dt)
    }
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        matmul(self, other)
    }
    pub fn bmm(&self, other: &Tensor) -> Tensor {
        bmm(self, other)
    }
    pub fn sum(&self) -> Tensor {
        sum(self)
    }
    pub fn mean(&self) -> Tensor {
        mean(self)
    }
    pub fn sum_dims(&self, dims: &[usize], keepdim: bool) -> Tensor {
        sum_dims(self, dims, keepdim)
    }
    pub fn mean_dims(&self, dims: &[usize], keepdim: bool) -> Tensor {
        mean_dims(self, dims, keepdim)
    }
    pub fn max_all(&self) -> Tensor {
        max_all(self)
    }
    pub fn argmax_dim(&self, dim: usize) -> Tensor {
        argmax_dim(self, dim)
    }
    pub fn softmax(&self, dim_last: ()) -> Tensor {
        let _ = dim_last;
        softmax_last(self)
    }
    pub fn log_softmax_last(&self) -> Tensor {
        log_softmax_last(self)
    }
    pub fn cross_entropy(&self, targets: &Tensor) -> Tensor {
        cross_entropy(self, targets)
    }
    pub fn mse_loss(&self, target: &Tensor) -> Tensor {
        mse_loss(self, target)
    }
}

// ------------------------------------------------------------------
// Operator overloads: tensor ⊕ tensor and tensor ⊕ scalar, so user code
// reads `&a * &b + &c` / `&x + 1.0` — the paper's "code as a model"
// ergonomics. All route through the dispatcher like every other op.
// ------------------------------------------------------------------

impl std::ops::Add<&Tensor> for &Tensor {
    type Output = Tensor;
    fn add(self, rhs: &Tensor) -> Tensor {
        add(self, rhs)
    }
}

impl std::ops::Sub<&Tensor> for &Tensor {
    type Output = Tensor;
    fn sub(self, rhs: &Tensor) -> Tensor {
        sub(self, rhs)
    }
}

impl std::ops::Mul<&Tensor> for &Tensor {
    type Output = Tensor;
    fn mul(self, rhs: &Tensor) -> Tensor {
        mul(self, rhs)
    }
}

impl std::ops::Div<&Tensor> for &Tensor {
    type Output = Tensor;
    fn div(self, rhs: &Tensor) -> Tensor {
        div(self, rhs)
    }
}

impl std::ops::Neg for &Tensor {
    type Output = Tensor;
    fn neg(self) -> Tensor {
        neg(self)
    }
}

impl std::ops::Add<f32> for &Tensor {
    type Output = Tensor;
    fn add(self, rhs: f32) -> Tensor {
        add_scalar(self, rhs)
    }
}

impl std::ops::Sub<f32> for &Tensor {
    type Output = Tensor;
    fn sub(self, rhs: f32) -> Tensor {
        add_scalar(self, -rhs)
    }
}

impl std::ops::Mul<f32> for &Tensor {
    type Output = Tensor;
    fn mul(self, rhs: f32) -> Tensor {
        mul_scalar(self, rhs)
    }
}

impl std::ops::Div<f32> for &Tensor {
    type Output = Tensor;
    fn div(self, rhs: f32) -> Tensor {
        mul_scalar(self, 1.0 / rhs)
    }
}

impl std::ops::Add<&Tensor> for f32 {
    type Output = Tensor;
    fn add(self, rhs: &Tensor) -> Tensor {
        add_scalar(rhs, self)
    }
}

impl std::ops::Mul<&Tensor> for f32 {
    type Output = Tensor;
    fn mul(self, rhs: &Tensor) -> Tensor {
        mul_scalar(rhs, self)
    }
}

// ------------------------------------------------------------------
// Owned-operand overloads: `a + b` / `a + &b` where `a: Tensor` moves the
// operand into `dispatch::call_owned`, proving it dead so the output can
// steal its storage (allocation-free chains: `(x * 2.0 + &bias).relu()`-
// style expressions reuse one buffer end to end when not recording).
// Borrowed operands are cloned, which automatically disqualifies them
// from donation — semantics are identical to the `&a ⊕ &b` forms.
// ------------------------------------------------------------------

use crate::dispatch::{call_owned, Param};

macro_rules! owned_binary_overload {
    ($trait:ident, $method:ident, $op:literal) => {
        impl std::ops::$trait<Tensor> for Tensor {
            type Output = Tensor;
            fn $method(self, rhs: Tensor) -> Tensor {
                call_owned($op, vec![self, rhs], &[])
            }
        }
        impl std::ops::$trait<&Tensor> for Tensor {
            type Output = Tensor;
            fn $method(self, rhs: &Tensor) -> Tensor {
                call_owned($op, vec![self, rhs.clone()], &[])
            }
        }
    };
}

owned_binary_overload!(Add, add, "add");
owned_binary_overload!(Sub, sub, "sub");
owned_binary_overload!(Mul, mul, "mul");
owned_binary_overload!(Div, div, "div");

impl std::ops::Neg for Tensor {
    type Output = Tensor;
    fn neg(self) -> Tensor {
        call_owned("neg", vec![self], &[])
    }
}

impl std::ops::Add<f32> for Tensor {
    type Output = Tensor;
    fn add(self, rhs: f32) -> Tensor {
        call_owned("add_scalar", vec![self], &[Param::F32(rhs)])
    }
}

impl std::ops::Sub<f32> for Tensor {
    type Output = Tensor;
    fn sub(self, rhs: f32) -> Tensor {
        call_owned("add_scalar", vec![self], &[Param::F32(-rhs)])
    }
}

impl std::ops::Mul<f32> for Tensor {
    type Output = Tensor;
    fn mul(self, rhs: f32) -> Tensor {
        call_owned("mul_scalar", vec![self], &[Param::F32(rhs)])
    }
}

impl std::ops::Div<f32> for Tensor {
    type Output = Tensor;
    fn div(self, rhs: f32) -> Tensor {
        call_owned("mul_scalar", vec![self], &[Param::F32(1.0 / rhs)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operator_overloads() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = Tensor::from_slice(&[10.0f32, 20.0]);
        assert_eq!((&a + &b).to_vec::<f32>(), vec![11.0, 22.0]);
        assert_eq!((&b - &a).to_vec::<f32>(), vec![9.0, 18.0]);
        assert_eq!((&a * &b).to_vec::<f32>(), vec![10.0, 40.0]);
        assert_eq!((&b / &a).to_vec::<f32>(), vec![10.0, 10.0]);
        assert_eq!((-&a).to_vec::<f32>(), vec![-1.0, -2.0]);
    }

    #[test]
    fn scalar_operator_overloads() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        assert_eq!((&a + 1.0).to_vec::<f32>(), vec![2.0, 3.0]);
        assert_eq!((&a - 1.0).to_vec::<f32>(), vec![0.0, 1.0]);
        assert_eq!((&a * 3.0).to_vec::<f32>(), vec![3.0, 6.0]);
        assert_eq!((&a / 2.0).to_vec::<f32>(), vec![0.5, 1.0]);
        assert_eq!((2.0 + &a).to_vec::<f32>(), vec![3.0, 4.0]);
        assert_eq!((2.0 * &a).to_vec::<f32>(), vec![2.0, 4.0]);
    }

    #[test]
    fn operator_expression_reads_like_math() {
        // &a * &b + &c — the Listing 1 style, end to end with grad.
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]);
        let c = Tensor::from_slice(&[4.0f32]);
        let y = &(&a * &b) + &c;
        assert_eq!(y.to_vec::<f32>(), vec![10.0]);
        y.backward_with(Tensor::ones(&[1]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![3.0]);
    }

    #[test]
    fn owned_operator_chain_reuses_one_buffer() {
        let a = Tensor::from_vec(vec![1.0f32; 50_000], &[50_000]);
        let b = Tensor::from_vec(vec![2.0f32; 50_000], &[50_000]);
        let ptr = a.storage().ptr() as usize;
        // Every step moves the chain value in, so the whole expression
        // computes in a's original buffer.
        let y = (a * &b + 1.0) * 0.5;
        assert_eq!(y.storage().ptr() as usize, ptr);
        assert!(y.to_vec::<f32>().iter().all(|&v| v == 1.5));
    }

    #[test]
    fn owned_operators_with_grad_keep_graph_and_values() {
        let a = Tensor::from_slice(&[2.0f32]).requires_grad(true);
        let b = Tensor::from_slice(&[3.0f32]);
        let y = a.clone() * &b + 1.0;
        assert_eq!(y.to_vec::<f32>(), vec![7.0]);
        y.backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "same device")]
    fn mixed_device_panics() {
        let a = Tensor::ones(&[2]);
        let b = Tensor::ones(&[2]).to_sim();
        add(&a, &b);
    }
}
