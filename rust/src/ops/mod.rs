//! Eager operators over [`Tensor`] with autograd recording.
//!
//! Every op follows the paper's execution model (§5.2): the *host* thread
//! resolves shapes/broadcasting, allocates the output, records the
//! backward node, and dispatches the kernel — inline for CPU tensors,
//! queued on the current stream for simulated-device tensors. The op
//! returns as soon as the kernel is dispatched; data-dependent reads
//! synchronize.
//!
//! Ops are free functions (`ops::add(&a, &b)`) plus ergonomic `Tensor`
//! methods (`a.add(&b)`), mirroring `torch.add` / `Tensor.add`.

mod binary;
mod conv;
mod index;
mod inplace;
mod linalg;
mod loss;
mod norm;
mod pool;
mod reduce;
mod unary;
mod views;

pub use binary::*;
pub use conv::*;
pub use index::*;
#[allow(unused_imports)]
pub use inplace::*;
pub use linalg::*;
pub use loss::*;
pub use norm::*;
pub use pool::*;
pub use reduce::*;
pub use unary::*;
pub use views::*;

use crate::device::Device;
use crate::tensor::Tensor;
use crate::torsk_assert;

/// Check all tensors share a device; return it. Mirrors PyTorch's
/// "expected all tensors on the same device" error.
pub(crate) fn same_device(tensors: &[&Tensor]) -> Device {
    let d = tensors[0].device();
    for t in tensors.iter().skip(1) {
        torsk_assert!(
            t.device() == d,
            "expected all tensors to be on the same device, found {} and {}",
            d,
            t.device()
        );
    }
    d
}

// ------------------------------------------------------------------
// Ergonomic Tensor methods (the `x.relu().matmul(&w)` chaining style
// of Listing 1).
// ------------------------------------------------------------------

impl Tensor {
    pub fn add(&self, other: &Tensor) -> Tensor {
        add(self, other)
    }
    pub fn sub(&self, other: &Tensor) -> Tensor {
        sub(self, other)
    }
    pub fn mul(&self, other: &Tensor) -> Tensor {
        mul(self, other)
    }
    pub fn div(&self, other: &Tensor) -> Tensor {
        div(self, other)
    }
    pub fn add_scalar(&self, s: f32) -> Tensor {
        add_scalar(self, s)
    }
    pub fn mul_scalar(&self, s: f32) -> Tensor {
        mul_scalar(self, s)
    }
    pub fn neg(&self) -> Tensor {
        neg(self)
    }
    pub fn exp(&self) -> Tensor {
        exp(self)
    }
    pub fn log(&self) -> Tensor {
        log(self)
    }
    pub fn sqrt(&self) -> Tensor {
        sqrt(self)
    }
    pub fn relu(&self) -> Tensor {
        relu(self)
    }
    pub fn sigmoid(&self) -> Tensor {
        sigmoid(self)
    }
    pub fn tanh(&self) -> Tensor {
        tanh(self)
    }
    pub fn pow_scalar(&self, p: f32) -> Tensor {
        pow_scalar(self, p)
    }
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        matmul(self, other)
    }
    pub fn bmm(&self, other: &Tensor) -> Tensor {
        bmm(self, other)
    }
    pub fn sum(&self) -> Tensor {
        sum(self)
    }
    pub fn mean(&self) -> Tensor {
        mean(self)
    }
    pub fn sum_dims(&self, dims: &[usize], keepdim: bool) -> Tensor {
        sum_dims(self, dims, keepdim)
    }
    pub fn mean_dims(&self, dims: &[usize], keepdim: bool) -> Tensor {
        mean_dims(self, dims, keepdim)
    }
    pub fn max_all(&self) -> Tensor {
        max_all(self)
    }
    pub fn argmax_dim(&self, dim: usize) -> Tensor {
        argmax_dim(self, dim)
    }
    pub fn softmax(&self, dim_last: ()) -> Tensor {
        let _ = dim_last;
        softmax_last(self)
    }
    pub fn log_softmax_last(&self) -> Tensor {
        log_softmax_last(self)
    }
    pub fn cross_entropy(&self, targets: &Tensor) -> Tensor {
        cross_entropy(self, targets)
    }
    pub fn mse_loss(&self, target: &Tensor) -> Tensor {
        mse_loss(self, target)
    }
}

impl std::ops::Add<&Tensor> for &Tensor {
    type Output = Tensor;
    fn add(self, rhs: &Tensor) -> Tensor {
        add(self, rhs)
    }
}

impl std::ops::Sub<&Tensor> for &Tensor {
    type Output = Tensor;
    fn sub(self, rhs: &Tensor) -> Tensor {
        sub(self, rhs)
    }
}

impl std::ops::Mul<&Tensor> for &Tensor {
    type Output = Tensor;
    fn mul(self, rhs: &Tensor) -> Tensor {
        mul(self, rhs)
    }
}

impl std::ops::Neg for &Tensor {
    type Output = Tensor;
    fn neg(self) -> Tensor {
        neg(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operator_overloads() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = Tensor::from_slice(&[10.0f32, 20.0]);
        assert_eq!((&a + &b).to_vec::<f32>(), vec![11.0, 22.0]);
        assert_eq!((&b - &a).to_vec::<f32>(), vec![9.0, 18.0]);
        assert_eq!((&a * &b).to_vec::<f32>(), vec![10.0, 40.0]);
        assert_eq!((-&a).to_vec::<f32>(), vec![-1.0, -2.0]);
    }

    #[test]
    #[should_panic(expected = "same device")]
    fn mixed_device_panics() {
        let a = Tensor::ones(&[2]);
        let b = Tensor::ones(&[2]).to_sim();
        add(&a, &b);
    }
}
