//! The conv2d eager op with autograd (wraps the im2col kernels).

use crate::autograd::{self, ClosureFunction, SavedTensor};
use crate::device;
use crate::kernels::conv::{conv2d_backward_input, conv2d_backward_weight, conv2d_forward, Conv2dArgs};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// 2-D convolution: input [N,C,H,W], weight [Cout, Cin/groups, KH, KW],
/// optional bias [Cout].
pub fn conv2d(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    stride: usize,
    padding: usize,
    groups: usize,
) -> Tensor {
    torsk_assert!(input.ndim() == 4, "conv2d: input must be NCHW, got {:?}", input.shape());
    torsk_assert!(weight.ndim() == 4, "conv2d: weight must be 4-D, got {:?}", weight.shape());
    let args = Conv2dArgs {
        batch: input.size(0),
        c_in: input.size(1),
        h_in: input.size(2),
        w_in: input.size(3),
        c_out: weight.size(0),
        kh: weight.size(2),
        kw: weight.size(3),
        stride,
        padding,
        groups,
    };
    args.validate();
    torsk_assert!(
        weight.size(1) == args.cg_in(),
        "conv2d: weight in-channels {} != input {}/groups {}",
        weight.size(1),
        args.c_in,
        groups
    );

    let mut all_inputs: Vec<&Tensor> = vec![input, weight];
    if let Some(b) = bias {
        torsk_assert!(b.shape() == [args.c_out], "conv2d: bias shape {:?}", b.shape());
        all_inputs.push(b);
    }
    let dev = super::same_device(&all_inputs);

    let input_c = input.contiguous();
    let weight_c = weight.contiguous();
    let bias_c = bias.map(|b| b.contiguous());
    let out = Tensor::empty(&[args.batch, args.c_out, args.h_out(), args.w_out()], DType::F32, dev);

    {
        let (ip, wp, op) = (input_c.data_ptr(), weight_c.data_ptr(), out.data_ptr());
        let bp = bias_c.as_ref().map(|b| b.data_ptr());
        let (in_len, w_len, out_len) = (input_c.numel(), weight_c.numel(), out.numel());
        let c_out = args.c_out;
        device::dispatch(dev, "conv2d", move || unsafe {
            let iv = ip.as_slice::<f32>(0, in_len);
            let wv = wp.as_slice::<f32>(0, w_len);
            let bv = bp.map(|p| p.as_slice::<f32>(0, c_out));
            let ov = op.as_mut_slice::<f32>(0, out_len);
            conv2d_forward(&args, iv, wv, bv, ov);
        });
    }

    if autograd::should_record(&all_inputs) {
        let (vi, vw) = (SavedTensor::save(&input_c), SavedTensor::save(&weight_c));
        let has_bias = bias.is_some();
        autograd::record(&all_inputs, &out, || {
            ClosureFunction::new("conv2d", move |g| {
                let input = vi.unpack();
                let weight = vw.unpack();
                let g = g.contiguous();
                if g.device().is_async() {
                    device::synchronize();
                }
                let gv = g.to_vec::<f32>();
                let iv = input.to_vec::<f32>();
                let wv = weight.to_vec::<f32>();

                let mut gi = vec![0.0f32; iv.len()];
                conv2d_backward_input(&args, &gv, &wv, &mut gi);
                let mut gw = vec![0.0f32; wv.len()];
                let mut gb = if has_bias { Some(vec![0.0f32; args.c_out]) } else { None };
                conv2d_backward_weight(&args, &iv, &gv, &mut gw, gb.as_deref_mut());

                let dev = input.device();
                let mut grads = vec![
                    Some(Tensor::from_vec(gi, input.shape()).to_device(dev)),
                    Some(Tensor::from_vec(gw, weight.shape()).to_device(dev)),
                ];
                if let Some(gb) = gb {
                    grads.push(Some(Tensor::from_vec(gb, &[args.c_out]).to_device(dev)));
                }
                grads
            })
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::conv::conv2d_ref;

    #[test]
    fn conv2d_matches_reference() {
        crate::rng::manual_seed(11);
        let x = Tensor::randn(&[2, 3, 8, 8]);
        let w = Tensor::randn(&[4, 3, 3, 3]);
        let b = Tensor::randn(&[4]);
        let y = conv2d(&x, &w, Some(&b), 1, 1, 1);
        assert_eq!(y.shape(), &[2, 4, 8, 8]);
        let args = Conv2dArgs { batch: 2, c_in: 3, h_in: 8, w_in: 8, c_out: 4, kh: 3, kw: 3, stride: 1, padding: 1, groups: 1 };
        let expect = conv2d_ref(&args, &x.to_vec::<f32>(), &w.to_vec::<f32>(), Some(&b.to_vec::<f32>()));
        let got = y.to_vec::<f32>();
        for (i, (&a, &e)) in got.iter().zip(expect.iter()).enumerate() {
            assert!((a - e).abs() < 1e-4, "idx {i}: {a} vs {e}");
        }
    }

    #[test]
    fn conv2d_backward_shapes() {
        let x = Tensor::randn(&[1, 2, 6, 6]).requires_grad(true);
        let w = Tensor::randn(&[3, 2, 3, 3]).requires_grad(true);
        let b = Tensor::randn(&[3]).requires_grad(true);
        let y = conv2d(&x, &w, Some(&b), 2, 1, 1);
        y.sum().backward();
        assert_eq!(x.grad().unwrap().shape(), x.shape());
        assert_eq!(w.grad().unwrap().shape(), w.shape());
        assert_eq!(b.grad().unwrap().shape(), b.shape());
    }

    #[test]
    fn conv2d_grad_matches_finite_difference() {
        crate::rng::manual_seed(13);
        let x = Tensor::randn(&[1, 1, 5, 5]).requires_grad(true);
        let w = Tensor::randn(&[1, 1, 3, 3]).requires_grad(true);
        let y = conv2d(&x, &w, None, 1, 0, 1);
        y.sum().backward();
        let gw = w.grad().unwrap().to_vec::<f32>();

        let f = |wv: Vec<f32>| -> f32 {
            crate::autograd::no_grad(|| {
                conv2d(&x.detach(), &Tensor::from_vec(wv, &[1, 1, 3, 3]), None, 1, 0, 1).sum().item()
            })
        };
        let eps = 1e-2;
        let w0 = w.to_vec::<f32>();
        for idx in [0usize, 4, 8] {
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let fd = (f(wp) - f(wm)) / (2.0 * eps);
            assert!((gw[idx] - fd).abs() < 1e-2, "idx {idx}: {} vs {}", gw[idx], fd);
        }
    }

    #[test]
    fn depthwise_conv_output_channels() {
        let x = Tensor::randn(&[1, 4, 6, 6]);
        let w = Tensor::randn(&[4, 1, 3, 3]);
        let y = conv2d(&x, &w, None, 1, 1, 4);
        assert_eq!(y.shape(), &[1, 4, 6, 6]);
    }

    #[test]
    fn conv2d_on_sim_device() {
        let x = Tensor::randn(&[1, 2, 4, 4]).to_sim();
        let w = Tensor::randn(&[2, 2, 3, 3]).to_sim();
        let y = conv2d(&x, &w, None, 1, 1, 1);
        assert_eq!(y.device(), crate::device::Device::Sim);
        assert_eq!(y.shape(), &[1, 2, 4, 4]);
        let _ = y.to_vec::<f32>(); // forces sync, checks no deadlock
    }
}
