//! The conv2d eager op — dispatcher shim over the im2col kernel entry.

use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

/// 2-D convolution: input [N,C,H,W], weight [Cout, Cin/groups, KH, KW],
/// optional bias [Cout].
pub fn conv2d(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    stride: usize,
    padding: usize,
    groups: usize,
) -> Tensor {
    let params = [Param::Usize(stride), Param::Usize(padding), Param::Usize(groups)];
    match bias {
        Some(b) => dispatch::call("conv2d", &[input, weight, b], &params),
        None => dispatch::call("conv2d", &[input, weight], &params),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::conv::{conv2d_ref, Conv2dArgs};

    #[test]
    fn conv2d_matches_reference() {
        crate::rng::manual_seed(11);
        let x = Tensor::randn(&[2, 3, 8, 8]);
        let w = Tensor::randn(&[4, 3, 3, 3]);
        let b = Tensor::randn(&[4]);
        let y = conv2d(&x, &w, Some(&b), 1, 1, 1);
        assert_eq!(y.shape(), &[2, 4, 8, 8]);
        let args = Conv2dArgs { batch: 2, c_in: 3, h_in: 8, w_in: 8, c_out: 4, kh: 3, kw: 3, stride: 1, padding: 1, groups: 1 };
        let expect = conv2d_ref(&args, &x.to_vec::<f32>(), &w.to_vec::<f32>(), Some(&b.to_vec::<f32>()));
        let got = y.to_vec::<f32>();
        for (i, (&a, &e)) in got.iter().zip(expect.iter()).enumerate() {
            assert!((a - e).abs() < 1e-4, "idx {i}: {a} vs {e}");
        }
    }

    #[test]
    fn conv2d_backward_shapes() {
        let x = Tensor::randn(&[1, 2, 6, 6]).requires_grad(true);
        let w = Tensor::randn(&[3, 2, 3, 3]).requires_grad(true);
        let b = Tensor::randn(&[3]).requires_grad(true);
        let y = conv2d(&x, &w, Some(&b), 2, 1, 1);
        y.sum().backward();
        assert_eq!(x.grad().unwrap().shape(), x.shape());
        assert_eq!(w.grad().unwrap().shape(), w.shape());
        assert_eq!(b.grad().unwrap().shape(), b.shape());
    }

    #[test]
    fn conv2d_grad_matches_finite_difference() {
        crate::rng::manual_seed(13);
        let x = Tensor::randn(&[1, 1, 5, 5]).requires_grad(true);
        let w = Tensor::randn(&[1, 1, 3, 3]).requires_grad(true);
        let y = conv2d(&x, &w, None, 1, 0, 1);
        y.sum().backward();
        let gw = w.grad().unwrap().to_vec::<f32>();

        let f = |wv: Vec<f32>| -> f32 {
            crate::autograd::no_grad(|| {
                conv2d(&x.detach(), &Tensor::from_vec(wv, &[1, 1, 3, 3]), None, 1, 0, 1).sum().item()
            })
        };
        let eps = 1e-2;
        let w0 = w.to_vec::<f32>();
        for idx in [0usize, 4, 8] {
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let fd = (f(wp) - f(wm)) / (2.0 * eps);
            assert!((gw[idx] - fd).abs() < 1e-2, "idx {idx}: {} vs {}", gw[idx], fd);
        }
    }

    #[test]
    fn depthwise_conv_output_channels() {
        let x = Tensor::randn(&[1, 4, 6, 6]);
        let w = Tensor::randn(&[4, 1, 3, 3]);
        let y = conv2d(&x, &w, None, 1, 1, 4);
        assert_eq!(y.shape(), &[1, 4, 6, 6]);
    }

    #[test]
    fn conv2d_on_sim_device() {
        let x = Tensor::randn(&[1, 2, 4, 4]).to_sim();
        let w = Tensor::randn(&[2, 2, 3, 3]).to_sim();
        let y = conv2d(&x, &w, None, 1, 1, 1);
        assert_eq!(y.device(), crate::device::Device::Sim);
        assert_eq!(y.shape(), &[1, 2, 4, 4]);
        let _ = y.to_vec::<f32>(); // forces sync, checks no deadlock
    }

    #[test]
    #[should_panic(expected = "conv2d")]
    fn conv2d_bad_weight_shape_panics() {
        let x = Tensor::ones(&[1, 3, 4, 4]);
        let w = Tensor::ones(&[2, 2, 3, 3]); // in-channels mismatch
        conv2d(&x, &w, None, 1, 1, 1);
    }

    #[test]
    #[should_panic(expected = "unsupported dtype")]
    fn conv2d_rejects_f64() {
        let x = Tensor::from_vec(vec![0.0f64; 16], &[1, 1, 4, 4]);
        let w = Tensor::from_vec(vec![0.0f64; 9], &[1, 1, 3, 3]);
        conv2d(&x, &w, None, 1, 1, 1);
    }
}
