//! Loss ops: fused softmax/log-softmax, cross-entropy, MSE, BCE.

use crate::autograd::{self, ClosureFunction, SavedTensor};
use crate::device;
use crate::kernels::softmax::{
    cross_entropy_backward, cross_entropy_forward, log_softmax_backward_rows, log_softmax_rows,
    softmax_backward_rows, softmax_rows,
};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

fn rows_cols(t: &Tensor) -> (usize, usize) {
    torsk_assert!(t.ndim() >= 1, "softmax: needs at least 1 dim");
    let cols = *t.shape().last().unwrap();
    (t.numel() / cols.max(1), cols)
}

/// Softmax over the last dimension.
pub fn softmax_last(input: &Tensor) -> Tensor {
    let (rows, cols) = rows_cols(input);
    let x = input.contiguous();
    let out = Tensor::empty(x.shape(), DType::F32, x.device());
    let (xp, op) = (x.data_ptr(), out.data_ptr());
    let n = x.numel();
    device::dispatch(x.device(), "softmax", move || unsafe {
        softmax_rows(rows, cols, xp.as_slice::<f32>(0, n), op.as_mut_slice::<f32>(0, n));
    });
    if autograd::should_record(&[input]) {
        let saved_y = SavedTensor::save(&out);
        autograd::record(&[input], &out, || {
            ClosureFunction::new("softmax", move |g| {
                let y = saved_y.unpack().contiguous();
                let g = g.contiguous();
                let yv = y.to_vec::<f32>();
                let gv = g.to_vec::<f32>();
                let mut gi = vec![0.0f32; yv.len()];
                softmax_backward_rows(rows, cols, &yv, &gv, &mut gi);
                vec![Some(Tensor::from_vec(gi, y.shape()).to_device(g.device()))]
            })
        });
    }
    out
}

/// Log-softmax over the last dimension.
pub fn log_softmax_last(input: &Tensor) -> Tensor {
    let (rows, cols) = rows_cols(input);
    let x = input.contiguous();
    let out = Tensor::empty(x.shape(), DType::F32, x.device());
    let (xp, op) = (x.data_ptr(), out.data_ptr());
    let n = x.numel();
    device::dispatch(x.device(), "log_softmax", move || unsafe {
        log_softmax_rows(rows, cols, xp.as_slice::<f32>(0, n), op.as_mut_slice::<f32>(0, n));
    });
    if autograd::should_record(&[input]) {
        let saved_y = SavedTensor::save(&out);
        autograd::record(&[input], &out, || {
            ClosureFunction::new("log_softmax", move |g| {
                let y = saved_y.unpack().contiguous();
                let g = g.contiguous();
                let yv = y.to_vec::<f32>();
                let gv = g.to_vec::<f32>();
                let mut gi = vec![0.0f32; yv.len()];
                log_softmax_backward_rows(rows, cols, &yv, &gv, &mut gi);
                vec![Some(Tensor::from_vec(gi, y.shape()).to_device(g.device()))]
            })
        });
    }
    out
}

/// Fused cross-entropy loss: logits [N, C] (f32) + targets [N] (i64)
/// -> scalar mean loss. The hot-path classification loss (fuses
/// log-softmax + NLL like `torch.nn.functional.cross_entropy`).
pub fn cross_entropy(logits: &Tensor, targets: &Tensor) -> Tensor {
    torsk_assert!(logits.ndim() == 2, "cross_entropy: logits must be [N, C]");
    torsk_assert!(targets.dtype() == DType::I64, "cross_entropy: targets must be i64");
    torsk_assert!(
        targets.numel() == logits.size(0),
        "cross_entropy: {} targets for {} rows",
        targets.numel(),
        logits.size(0)
    );
    let (rows, cols) = (logits.size(0), logits.size(1));
    let x = logits.contiguous();
    // Forward runs synchronously on host data (the scalar loss is consumed
    // by control flow anyway); log-probs are saved for backward.
    let xv = x.to_vec::<f32>();
    let tv = targets.to_vec::<i64>();
    let mut log_probs = vec![0.0f32; rows * cols];
    let loss = cross_entropy_forward(rows, cols, &xv, &tv, &mut log_probs);
    let out = Tensor::scalar(loss).to_device(logits.device());
    if autograd::should_record(&[logits]) {
        let shape = logits.shape().to_vec();
        let dev = logits.device();
        autograd::record(&[logits], &out, || {
            ClosureFunction::new("cross_entropy", move |g| {
                let gs = g.item();
                let mut gi = vec![0.0f32; rows * cols];
                cross_entropy_backward(rows, cols, &log_probs, &tv, gs, &mut gi);
                vec![Some(Tensor::from_vec(gi, &shape).to_device(dev))]
            })
        });
    }
    out
}

/// Mean-squared-error loss (mean reduction).
pub fn mse_loss(pred: &Tensor, target: &Tensor) -> Tensor {
    torsk_assert!(pred.shape() == target.shape(), "mse_loss: shape mismatch");
    let diff = super::sub(pred, target);
    let sq = super::mul(&diff, &diff);
    super::mean(&sq)
}

/// Binary cross-entropy on probabilities in (0,1), mean reduction.
pub fn bce_loss(pred: &Tensor, target: &Tensor) -> Tensor {
    torsk_assert!(pred.shape() == target.shape(), "bce_loss: shape mismatch");
    let eps = 1e-7;
    let p = super::clamp(pred, eps, 1.0 - eps);
    // -[t*log(p) + (1-t)*log(1-p)]
    let log_p = super::log(&p);
    let one_minus_p = super::add_scalar(&super::neg(&p), 1.0);
    let log_1p = super::log(&one_minus_p);
    let one_minus_t = super::add_scalar(&super::neg(target), 1.0);
    let pos = super::mul(target, &log_p);
    let neg_term = super::mul(&one_minus_t, &log_1p);
    super::neg(&super::mean(&super::add(&pos, &neg_term)))
}

/// Classification accuracy (no grad): logits [N, C] vs i64 targets [N].
pub fn accuracy(logits: &Tensor, targets: &Tensor) -> f32 {
    let pred = super::argmax_dim(logits, 1);
    let pv = pred.to_vec::<i64>();
    let tv = targets.to_vec::<i64>();
    let correct = pv.iter().zip(tv.iter()).filter(|(a, b)| a == b).count();
    correct as f32 / pv.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_one() {
        let x = Tensor::randn(&[4, 7]);
        let y = softmax_last(&x);
        let v = y.to_vec::<f32>();
        for r in 0..4 {
            let s: f32 = v[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cross_entropy_perfect_prediction_low_loss() {
        // Strongly peaked logits at the target class.
        let logits = Tensor::from_vec(vec![10.0f32, -10.0, -10.0, -10.0, 10.0, -10.0], &[2, 3]);
        let targets = Tensor::from_vec(vec![0i64, 1], &[2]);
        let loss = cross_entropy(&logits, &targets);
        assert!(loss.item() < 1e-5, "loss={}", loss.item());
    }

    #[test]
    fn cross_entropy_uniform_is_log_c() {
        let logits = Tensor::zeros(&[3, 10]);
        let targets = Tensor::from_vec(vec![0i64, 5, 9], &[3]);
        let loss = cross_entropy(&logits, &targets);
        assert!((loss.item() - 10.0f32.ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_backward_decreases_loss() {
        crate::rng::manual_seed(5);
        let w = Tensor::randn(&[4, 3]).requires_grad(true); // logits as params
        let targets = Tensor::from_vec(vec![0i64, 1, 2, 0], &[4]);
        let loss0 = cross_entropy(&w, &targets);
        loss0.backward();
        let g = w.grad().unwrap();
        crate::autograd::no_grad(|| w.axpy_(-0.5, &g));
        let loss1 = cross_entropy(&w.detach(), &targets);
        assert!(loss1.item() < loss0.item(), "{} -> {}", loss0.item(), loss1.item());
    }

    #[test]
    fn log_softmax_backward_matches_softmax_identity() {
        // d/dx of sum(log_softmax(x)) for a single row with g=1:
        // 1 - C * softmax(x).
        let x = Tensor::from_vec(vec![0.3f32, -0.7, 1.1], &[1, 3]).requires_grad(true);
        log_softmax_last(&x).sum().backward();
        let g = x.grad().unwrap().to_vec::<f32>();
        let s = softmax_last(&x.detach()).to_vec::<f32>();
        for i in 0..3 {
            assert!((g[i] - (1.0 - 3.0 * s[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn mse_loss_value_and_grad() {
        let p = Tensor::from_slice(&[1.0f32, 2.0]).requires_grad(true);
        let t = Tensor::from_slice(&[0.0f32, 0.0]);
        let l = mse_loss(&p, &t);
        assert!((l.item() - 2.5).abs() < 1e-6); // (1+4)/2
        l.backward();
        let g = p.grad().unwrap().to_vec::<f32>();
        assert!((g[0] - 1.0).abs() < 1e-6); // 2*1/2
        assert!((g[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn bce_loss_is_low_for_correct_confident() {
        let p = Tensor::from_slice(&[0.999f32, 0.001]);
        let t = Tensor::from_slice(&[1.0f32, 0.0]);
        assert!(bce_loss(&p, &t).item() < 0.01);
    }

    #[test]
    fn bce_loss_grad_direction() {
        let p = Tensor::from_slice(&[0.3f32]).requires_grad(true);
        let t = Tensor::from_slice(&[1.0f32]);
        bce_loss(&p, &t).backward();
        // Target is 1 -> increasing p lowers loss -> gradient negative.
        assert!(p.grad().unwrap().item() < 0.0);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Tensor::from_vec(vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 0.0], &[3, 2]);
        let t = Tensor::from_vec(vec![0i64, 1, 1], &[3]);
        let acc = accuracy(&logits, &t);
        assert!((acc - 2.0 / 3.0).abs() < 1e-6);
    }
}
