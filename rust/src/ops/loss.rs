//! Loss ops: fused softmax/log-softmax, cross-entropy, MSE, BCE —
//! dispatcher shims.

use crate::dispatch;
use crate::tensor::Tensor;

/// Softmax over the last dimension.
pub fn softmax_last(input: &Tensor) -> Tensor {
    dispatch::call("softmax", &[input], &[])
}

/// Log-softmax over the last dimension.
pub fn log_softmax_last(input: &Tensor) -> Tensor {
    dispatch::call("log_softmax", &[input], &[])
}

/// Fused cross-entropy loss: logits [N, C] (f32) + targets [N] (i64)
/// -> scalar mean loss (fuses log-softmax + NLL like
/// `torch.nn.functional.cross_entropy`).
pub fn cross_entropy(logits: &Tensor, targets: &Tensor) -> Tensor {
    dispatch::call("cross_entropy", &[logits, targets], &[])
}

/// Mean-squared-error loss (mean reduction).
pub fn mse_loss(pred: &Tensor, target: &Tensor) -> Tensor {
    dispatch::call("mse_loss", &[pred, target], &[])
}

/// Binary cross-entropy on probabilities in (0,1), mean reduction.
pub fn bce_loss(pred: &Tensor, target: &Tensor) -> Tensor {
    dispatch::call("bce_loss", &[pred, target], &[])
}

/// Sigmoid + binary cross-entropy on raw logits, fused into one pass
/// (`fused:sigmoid_bce`) — the `BCEWithLogits` hot composite: where
/// `bce_loss(&sigmoid(&x), &t)` dispatched ~9 elementwise/reduction
/// kernels, this reads `x`/`t` once and reduces in the same traversal.
/// Bit-identical to the composed form (see `tests/fused_parity.rs`).
pub fn bce_with_logits(logits: &Tensor, target: &Tensor) -> Tensor {
    dispatch::call("fused:sigmoid_bce", &[logits, target], &[])
}

/// Classification accuracy (no grad): logits [N, C] vs i64 targets [N].
pub fn accuracy(logits: &Tensor, targets: &Tensor) -> f32 {
    let pred = super::argmax_dim(logits, 1);
    let pv = pred.to_vec::<i64>();
    let tv = targets.to_vec::<i64>();
    let correct = pv.iter().zip(tv.iter()).filter(|(a, b)| a == b).count();
    correct as f32 / pv.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_one() {
        let x = Tensor::randn(&[4, 7]);
        let y = softmax_last(&x);
        let v = y.to_vec::<f32>();
        for r in 0..4 {
            let s: f32 = v[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cross_entropy_perfect_prediction_low_loss() {
        // Strongly peaked logits at the target class.
        let logits = Tensor::from_vec(vec![10.0f32, -10.0, -10.0, -10.0, 10.0, -10.0], &[2, 3]);
        let targets = Tensor::from_vec(vec![0i64, 1], &[2]);
        let loss = cross_entropy(&logits, &targets);
        assert!(loss.item() < 1e-5, "loss={}", loss.item());
    }

    #[test]
    fn cross_entropy_uniform_is_log_c() {
        let logits = Tensor::zeros(&[3, 10]);
        let targets = Tensor::from_vec(vec![0i64, 5, 9], &[3]);
        let loss = cross_entropy(&logits, &targets);
        assert!((loss.item() - 10.0f32.ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_backward_decreases_loss() {
        crate::rng::manual_seed(5);
        let w = Tensor::randn(&[4, 3]).requires_grad(true); // logits as params
        let targets = Tensor::from_vec(vec![0i64, 1, 2, 0], &[4]);
        let loss0 = cross_entropy(&w, &targets);
        loss0.backward();
        let g = w.grad().unwrap();
        crate::autograd::no_grad(|| w.axpy_(-0.5, &g));
        let loss1 = cross_entropy(&w.detach(), &targets);
        assert!(loss1.item() < loss0.item(), "{} -> {}", loss0.item(), loss1.item());
    }

    #[test]
    fn log_softmax_backward_matches_softmax_identity() {
        // d/dx of sum(log_softmax(x)) for a single row with g=1:
        // 1 - C * softmax(x).
        let x = Tensor::from_vec(vec![0.3f32, -0.7, 1.1], &[1, 3]).requires_grad(true);
        log_softmax_last(&x).sum().backward();
        let g = x.grad().unwrap().to_vec::<f32>();
        let s = softmax_last(&x.detach()).to_vec::<f32>();
        for i in 0..3 {
            assert!((g[i] - (1.0 - 3.0 * s[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn mse_loss_value_and_grad() {
        let p = Tensor::from_slice(&[1.0f32, 2.0]).requires_grad(true);
        let t = Tensor::from_slice(&[0.0f32, 0.0]);
        let l = mse_loss(&p, &t);
        assert!((l.item() - 2.5).abs() < 1e-6); // (1+4)/2
        l.backward();
        let g = p.grad().unwrap().to_vec::<f32>();
        assert!((g[0] - 1.0).abs() < 1e-6); // 2*1/2
        assert!((g[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn mse_loss_f64() {
        let p = Tensor::from_vec(vec![1.0f64, 2.0], &[2]).requires_grad(true);
        let t = Tensor::from_vec(vec![0.0f64, 0.0], &[2]);
        let l = mse_loss(&p, &t);
        assert_eq!(l.dtype(), crate::tensor::DType::F64);
        assert!((l.to_vec::<f64>()[0] - 2.5).abs() < 1e-12);
        l.backward();
        let g = p.grad().unwrap().to_vec::<f64>();
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bce_loss_is_low_for_correct_confident() {
        let p = Tensor::from_slice(&[0.999f32, 0.001]);
        let t = Tensor::from_slice(&[1.0f32, 0.0]);
        assert!(bce_loss(&p, &t).item() < 0.01);
    }

    #[test]
    fn bce_loss_grad_direction() {
        let p = Tensor::from_slice(&[0.3f32]).requires_grad(true);
        let t = Tensor::from_slice(&[1.0f32]);
        bce_loss(&p, &t).backward();
        // Target is 1 -> increasing p lowers loss -> gradient negative.
        assert!(p.grad().unwrap().item() < 0.0);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Tensor::from_vec(vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 0.0], &[3, 2]);
        let t = Tensor::from_vec(vec![0i64, 1, 1], &[3]);
        let acc = accuracy(&logits, &t);
        assert!((acc - 2.0 / 3.0).abs() < 1e-6);
    }
}
