//! Matrix multiplication ops: `matmul`, batched `bmm`, and fused
//! `linear` (x @ Wᵀ + b, the nn.Linear hot path) — dispatcher shims.

use crate::dispatch;
use crate::tensor::Tensor;

/// 2-D matrix product with autograd (f32 or f64).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("matmul", &[a, b], &[])
}

/// Batched matrix product [B,m,k] @ [B,k,n] with autograd.
pub fn bmm(a: &Tensor, b: &Tensor) -> Tensor {
    dispatch::call("bmm", &[a, b], &[])
}

/// Fused linear layer: `x [N,in] @ Wᵀ [in,out] + b`, PyTorch weight layout
/// `W [out,in]`.
pub fn linear(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    match b {
        Some(bias) => dispatch::call("linear", &[x, w, bias], &[]),
        None => dispatch::call("linear", &[x, w], &[]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::linalg::matmul_raw;
    use crate::tensor::assert_close;

    #[test]
    fn matmul_values() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![5.0f32, 6.0, 7.0, 8.0], &[2, 2]);
        assert_eq!(matmul(&a, &b).to_vec::<f32>(), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[2, 3]);
        let b = Tensor::from_vec((1..=3).map(|x| x as f32).collect(), &[3, 1]);
        assert_eq!(matmul(&a, &b).to_vec::<f32>(), vec![14.0, 32.0]);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_shape_mismatch_panics() {
        matmul(&Tensor::ones(&[2, 3]), &Tensor::ones(&[4, 2]));
    }

    #[test]
    fn matmul_backward_matches_finite_difference() {
        crate::rng::manual_seed(1);
        let a = Tensor::randn(&[3, 4]).requires_grad(true);
        let b = Tensor::randn(&[4, 2]).requires_grad(true);
        let g = Tensor::randn(&[3, 2]);
        matmul(&a, &b).backward_with(g.clone());

        // Finite differences on a couple of entries.
        let f = |av: &Tensor, bv: &Tensor| -> f32 {
            crate::autograd::no_grad(|| super::super::mul(&matmul_raw(av, bv), &g).sum().item())
        };
        let eps = 1e-2;
        let ga = a.grad().unwrap().to_vec::<f32>();
        for idx in [0usize, 5, 11] {
            let mut ap = a.to_vec::<f32>();
            ap[idx] += eps;
            let mut am = a.to_vec::<f32>();
            am[idx] -= eps;
            let fd = (f(&Tensor::from_vec(ap, &[3, 4]), &b.detach())
                - f(&Tensor::from_vec(am, &[3, 4]), &b.detach()))
                / (2.0 * eps);
            assert!((ga[idx] - fd).abs() < 1e-2, "idx {idx}: {} vs {}", ga[idx], fd);
        }
    }

    #[test]
    fn bmm_values() {
        let a = Tensor::from_vec(vec![1.0f32, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 2.0], &[2, 2, 2]);
        let b = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], &[2, 2, 2]);
        let c = bmm(&a, &b);
        assert_eq!(c.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0, 10.0, 12.0, 14.0, 16.0]);
    }

    #[test]
    fn bmm_backward_shapes() {
        let a = Tensor::randn(&[2, 3, 4]).requires_grad(true);
        let b = Tensor::randn(&[2, 4, 5]).requires_grad(true);
        bmm(&a, &b).sum().backward();
        assert_eq!(a.grad().unwrap().shape(), &[2, 3, 4]);
        assert_eq!(b.grad().unwrap().shape(), &[2, 4, 5]);
    }

    #[test]
    fn linear_matches_manual() {
        let x = Tensor::from_vec(vec![1.0f32, 2.0], &[1, 2]);
        let w = Tensor::from_vec(vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0], &[3, 2]);
        let b = Tensor::from_slice(&[0.1f32, 0.2, 0.3]);
        let y = linear(&x, &w, Some(&b));
        assert_eq!(y.shape(), &[1, 3]);
        let v = y.to_vec::<f32>();
        assert!((v[0] - 1.1).abs() < 1e-6);
        assert!((v[1] - 2.2).abs() < 1e-6);
        assert!((v[2] - 3.3).abs() < 1e-6);
    }

    #[test]
    fn linear_backward_bias_is_row_sum() {
        let x = Tensor::ones(&[4, 3]);
        let w = Tensor::zeros(&[2, 3]).requires_grad(true);
        let b = Tensor::zeros(&[2]).requires_grad(true);
        linear(&x, &w, Some(&b)).sum().backward();
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![4.0, 4.0]);
        assert_eq!(w.grad().unwrap().to_vec::<f32>(), vec![4.0; 6]);
    }

    #[test]
    fn linear_agrees_with_matmul_composition() {
        crate::rng::manual_seed(3);
        let x = Tensor::randn(&[5, 7]);
        let w = Tensor::randn(&[4, 7]);
        let b = Tensor::randn(&[4]);
        let y1 = linear(&x, &w, Some(&b));
        let y2 = super::super::add(&matmul(&x, &w.t()), &b);
        assert_close(&y1, &y2, 1e-5, 1e-5);
    }

    #[test]
    fn matmul_f64_values_and_grad() {
        let a = Tensor::from_vec(vec![1.0f64, 2.0, 3.0, 4.0], &[2, 2]).requires_grad(true);
        let b = Tensor::from_vec(vec![5.0f64, 6.0, 7.0, 8.0], &[2, 2]);
        let y = matmul(&a, &b);
        assert_eq!(y.dtype(), crate::tensor::DType::F64);
        assert_eq!(y.to_vec::<f64>(), vec![19.0, 22.0, 43.0, 50.0]);
        y.sum().backward();
        // d(sum)/dA = ones @ Bᵀ
        assert_eq!(a.grad().unwrap().to_vec::<f64>(), vec![11.0, 15.0, 11.0, 15.0]);
    }

    #[test]
    #[should_panic(expected = "unsupported dtype")]
    fn matmul_rejects_i64() {
        let a = Tensor::from_vec(vec![1i64, 2, 3, 4], &[2, 2]);
        matmul(&a, &a);
    }
}
