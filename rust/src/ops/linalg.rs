//! Matrix multiplication ops: `matmul`, batched `bmm`, and fused
//! `linear` (x @ Wᵀ + b, the nn.Linear hot path).

use crate::autograd::{self, ClosureFunction, SavedTensor};
use crate::device;
use crate::kernels::matmul::{sgemm, sgemm_batched};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::same_device;

fn matmul_raw(a: &Tensor, b: &Tensor) -> Tensor {
    let dev = same_device(&[a, b]);
    torsk_assert!(a.ndim() == 2 && b.ndim() == 2, "matmul: need 2-D, got {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = (a.size(0), a.size(1));
    let (k2, n) = (b.size(0), b.size(1));
    torsk_assert!(k == k2, "matmul: inner dims {k} vs {k2}");
    let a = a.contiguous();
    let b = b.contiguous();
    let out = Tensor::empty(&[m, n], DType::F32, dev);
    let (ap, bp, op) = (a.data_ptr(), b.data_ptr(), out.data_ptr());
    device::dispatch(dev, "matmul", move || unsafe {
        sgemm(
            m,
            n,
            k,
            1.0,
            ap.as_slice::<f32>(0, m * k),
            bp.as_slice::<f32>(0, k * n),
            0.0,
            op.as_mut_slice::<f32>(0, m * n),
        );
    });
    out
}

/// 2-D matrix product with autograd.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let out = matmul_raw(a, b);
    if autograd::should_record(&[a, b]) {
        let (va, vb) = (SavedTensor::save(a), SavedTensor::save(b));
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("matmul", move |g| {
                let a = va.unpack();
                let b = vb.unpack();
                // dA = G @ Bᵀ ; dB = Aᵀ @ G
                let ga = matmul_raw(g, &b.t().contiguous());
                let gb = matmul_raw(&a.t().contiguous(), g);
                vec![Some(ga), Some(gb)]
            })
        });
    }
    out
}

fn bmm_raw(a: &Tensor, b: &Tensor) -> Tensor {
    let dev = same_device(&[a, b]);
    torsk_assert!(a.ndim() == 3 && b.ndim() == 3, "bmm: need 3-D");
    let (batch, m, k) = (a.size(0), a.size(1), a.size(2));
    let (b2, k2, n) = (b.size(0), b.size(1), b.size(2));
    torsk_assert!(batch == b2 && k == k2, "bmm: shape mismatch {:?} x {:?}", a.shape(), b.shape());
    let a = a.contiguous();
    let b = b.contiguous();
    let out = Tensor::empty(&[batch, m, n], DType::F32, dev);
    let (ap, bp, op) = (a.data_ptr(), b.data_ptr(), out.data_ptr());
    device::dispatch(dev, "bmm", move || unsafe {
        sgemm_batched(
            batch,
            m,
            n,
            k,
            ap.as_slice::<f32>(0, batch * m * k),
            bp.as_slice::<f32>(0, batch * k * n),
            op.as_mut_slice::<f32>(0, batch * m * n),
        );
    });
    out
}

/// Batched matrix product [B,m,k] @ [B,k,n] with autograd.
pub fn bmm(a: &Tensor, b: &Tensor) -> Tensor {
    let out = bmm_raw(a, b);
    if autograd::should_record(&[a, b]) {
        let (va, vb) = (SavedTensor::save(a), SavedTensor::save(b));
        autograd::record(&[a, b], &out, || {
            ClosureFunction::new("bmm", move |g| {
                let a = va.unpack();
                let b = vb.unpack();
                let bt = b.transpose(1, 2).contiguous();
                let at = a.transpose(1, 2).contiguous();
                vec![Some(bmm_raw(g, &bt)), Some(bmm_raw(&at, g))]
            })
        });
    }
    out
}

/// Fused linear layer: `x [N,in] @ Wᵀ [in,out] + b`, PyTorch weight layout
/// `W [out,in]`.
pub fn linear(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    torsk_assert!(x.ndim() == 2 && w.ndim() == 2, "linear: x 2-D, w 2-D");
    torsk_assert!(x.size(1) == w.size(1), "linear: in_features {} vs {}", x.size(1), w.size(1));
    let wt = w.t().contiguous();
    let y = matmul_raw(x, &wt);
    let out = match b {
        Some(bias) => super::binary_map("add_bias", &y, bias, |p, q| p + q),
        None => y,
    };
    let mut inputs: Vec<&Tensor> = vec![x, w];
    if let Some(bias) = b {
        inputs.push(bias);
    }
    if autograd::should_record(&inputs) {
        let (vx, vw) = (SavedTensor::save(x), SavedTensor::save(w));
        let has_bias = b.is_some();
        autograd::record(&inputs, &out, || {
            ClosureFunction::new("linear", move |g| {
                let x = vx.unpack();
                let w = vw.unpack();
                // gx = G @ W ; gw = Gᵀ @ x ; gb = sum rows of G
                let gx = matmul_raw(g, &w);
                let gw = matmul_raw(&g.t().contiguous(), &x);
                let mut grads = vec![Some(gx), Some(gw)];
                if has_bias {
                    grads.push(Some(super::sum_dims(g, &[0], false)));
                }
                grads
            })
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::assert_close;

    #[test]
    fn matmul_values() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![5.0f32, 6.0, 7.0, 8.0], &[2, 2]);
        assert_eq!(matmul(&a, &b).to_vec::<f32>(), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[2, 3]);
        let b = Tensor::from_vec((1..=3).map(|x| x as f32).collect(), &[3, 1]);
        assert_eq!(matmul(&a, &b).to_vec::<f32>(), vec![14.0, 32.0]);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_shape_mismatch_panics() {
        matmul(&Tensor::ones(&[2, 3]), &Tensor::ones(&[4, 2]));
    }

    #[test]
    fn matmul_backward_matches_finite_difference() {
        crate::rng::manual_seed(1);
        let a = Tensor::randn(&[3, 4]).requires_grad(true);
        let b = Tensor::randn(&[4, 2]).requires_grad(true);
        let g = Tensor::randn(&[3, 2]);
        matmul(&a, &b).backward_with(g.clone());

        // Finite differences on a couple of entries.
        let f = |av: &Tensor, bv: &Tensor| -> f32 {
            crate::autograd::no_grad(|| super::super::mul(&matmul_raw(av, bv), &g).sum().item())
        };
        let eps = 1e-2;
        let ga = a.grad().unwrap().to_vec::<f32>();
        for idx in [0usize, 5, 11] {
            let mut ap = a.to_vec::<f32>();
            ap[idx] += eps;
            let mut am = a.to_vec::<f32>();
            am[idx] -= eps;
            let fd = (f(&Tensor::from_vec(ap, &[3, 4]), &b.detach())
                - f(&Tensor::from_vec(am, &[3, 4]), &b.detach()))
                / (2.0 * eps);
            assert!((ga[idx] - fd).abs() < 1e-2, "idx {idx}: {} vs {}", ga[idx], fd);
        }
    }

    #[test]
    fn bmm_values() {
        let a = Tensor::from_vec(vec![1.0f32, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 2.0], &[2, 2, 2]);
        let b = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], &[2, 2, 2]);
        let c = bmm(&a, &b);
        assert_eq!(c.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0, 10.0, 12.0, 14.0, 16.0]);
    }

    #[test]
    fn bmm_backward_shapes() {
        let a = Tensor::randn(&[2, 3, 4]).requires_grad(true);
        let b = Tensor::randn(&[2, 4, 5]).requires_grad(true);
        bmm(&a, &b).sum().backward();
        assert_eq!(a.grad().unwrap().shape(), &[2, 3, 4]);
        assert_eq!(b.grad().unwrap().shape(), &[2, 4, 5]);
    }

    #[test]
    fn linear_matches_manual() {
        let x = Tensor::from_vec(vec![1.0f32, 2.0], &[1, 2]);
        let w = Tensor::from_vec(vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0], &[3, 2]);
        let b = Tensor::from_slice(&[0.1f32, 0.2, 0.3]);
        let y = linear(&x, &w, Some(&b));
        assert_eq!(y.shape(), &[1, 3]);
        let v = y.to_vec::<f32>();
        assert!((v[0] - 1.1).abs() < 1e-6);
        assert!((v[1] - 2.2).abs() < 1e-6);
        assert!((v[2] - 3.3).abs() < 1e-6);
    }

    #[test]
    fn linear_backward_bias_is_row_sum() {
        let x = Tensor::ones(&[4, 3]);
        let w = Tensor::zeros(&[2, 3]).requires_grad(true);
        let b = Tensor::zeros(&[2]).requires_grad(true);
        linear(&x, &w, Some(&b)).sum().backward();
        assert_eq!(b.grad().unwrap().to_vec::<f32>(), vec![4.0, 4.0]);
        assert_eq!(w.grad().unwrap().to_vec::<f32>(), vec![4.0; 6]);
    }

    #[test]
    fn linear_agrees_with_matmul_composition() {
        crate::rng::manual_seed(3);
        let x = Tensor::randn(&[5, 7]);
        let w = Tensor::randn(&[4, 7]);
        let b = Tensor::randn(&[4]);
        let y1 = linear(&x, &w, Some(&b));
        let y2 = super::super::add(&matmul(&x, &w.t()), &b);
        assert_close(&y1, &y2, 1e-5, 1e-5);
    }
}
