//! Reductions — shims over the dispatcher's registry entries, plus the raw
//! broadcast-gradient helpers (`sum_to_shape`, `broadcast_to`).

use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

/// Sum a tensor down to a broadcast-compatible `target` shape (each target
/// dim is either equal to the source dim or 1; the target may have fewer
/// dims, which behave as leading 1s). Raw helper: no autograd.
pub fn sum_to_shape(a: &Tensor, target: &[usize]) -> Tensor {
    crate::dispatch::reduce::sum_to_shape(a, target)
}

/// Broadcast a tensor up to `target` shape (materialized copy, used by
/// reduction backwards).
pub fn broadcast_to(a: &Tensor, target: &[usize]) -> Tensor {
    crate::dispatch::reduce::broadcast_to(a, target)
}

/// Full sum to a scalar.
pub fn sum(a: &Tensor) -> Tensor {
    dispatch::call("sum", &[a], &[])
}

/// Full mean to a scalar.
pub fn mean(a: &Tensor) -> Tensor {
    dispatch::call("mean", &[a], &[])
}

/// Sum over `dims`; `keepdim` keeps reduced axes as size-1. `dims = []`
/// is the identity (no axes reduced), not an error.
pub fn sum_dims(a: &Tensor, dims: &[usize], keepdim: bool) -> Tensor {
    dispatch::call("sum_dims", &[a], &[Param::UsizeList(dims.to_vec()), Param::Bool(keepdim)])
}

/// Mean over `dims`; `dims = []` is the identity.
pub fn mean_dims(a: &Tensor, dims: &[usize], keepdim: bool) -> Tensor {
    dispatch::call("mean_dims", &[a], &[Param::UsizeList(dims.to_vec()), Param::Bool(keepdim)])
}

/// Max over all elements (scalar, grad to the (first) argmax). Errors on
/// empty tensors.
pub fn max_all(a: &Tensor) -> Tensor {
    dispatch::call("max_all", &[a], &[])
}

/// Argmax along `dim` (returns i64 tensor; no grad). Synchronous.
pub fn argmax_dim(a: &Tensor, dim: usize) -> Tensor {
    dispatch::call("argmax_dim", &[a], &[Param::Usize(dim)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_all() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(sum(&a).item(), 10.0);
    }

    #[test]
    fn sum_backward_broadcasts_ones() {
        let a = Tensor::from_vec(vec![1.0f32; 6], &[2, 3]).requires_grad(true);
        sum(&a).backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0; 6]);
    }

    #[test]
    fn mean_backward_scales() {
        let a = Tensor::from_vec(vec![1.0f32; 4], &[4]).requires_grad(true);
        mean(&a).backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.25; 4]);
    }

    #[test]
    fn sum_dims_keepdim() {
        let a = Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[2, 3]);
        let s = sum_dims(&a, &[0], true);
        assert_eq!(s.shape(), &[1, 3]);
        assert_eq!(s.to_vec::<f32>(), vec![5.0, 7.0, 9.0]);
        let s2 = sum_dims(&a, &[1], false);
        assert_eq!(s2.shape(), &[2]);
        assert_eq!(s2.to_vec::<f32>(), vec![6.0, 15.0]);
    }

    #[test]
    fn sum_dims_multiple_axes() {
        let a = Tensor::ones(&[2, 3, 4]);
        let s = sum_dims(&a, &[0, 2], false);
        assert_eq!(s.shape(), &[3]);
        assert_eq!(s.to_vec::<f32>(), vec![8.0; 3]);
    }

    #[test]
    fn sum_dims_backward() {
        let a = Tensor::ones(&[2, 3]).requires_grad(true);
        let s = sum_dims(&a, &[0], false); // shape [3]
        s.backward_with(Tensor::from_slice(&[1.0f32, 2.0, 3.0]));
        assert_eq!(
            a.grad().unwrap().to_vec::<f32>(),
            vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn mean_dims_values() {
        let a = Tensor::from_vec(vec![2.0f32, 4.0, 6.0, 8.0], &[2, 2]);
        let m = mean_dims(&a, &[1], false);
        assert_eq!(m.to_vec::<f32>(), vec![3.0, 7.0]);
    }

    #[test]
    fn sum_to_shape_column_reduction() {
        let a = Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[2, 3]);
        let r = sum_to_shape(&a, &[2, 1]);
        assert_eq!(r.shape(), &[2, 1]);
        assert_eq!(r.to_vec::<f32>(), vec![6.0, 15.0]);
        let r2 = sum_to_shape(&a, &[3]);
        assert_eq!(r2.to_vec::<f32>(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn broadcast_to_materializes() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = broadcast_to(&a.reshape(&[2, 1]), &[2, 3]);
        assert_eq!(b.to_vec::<f32>(), vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn max_all_and_grad() {
        let a = Tensor::from_slice(&[1.0f32, 7.0, 3.0]).requires_grad(true);
        let m = max_all(&a);
        assert_eq!(m.item(), 7.0);
        m.backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn argmax_rows() {
        let a = Tensor::from_vec(vec![1.0f32, 9.0, 2.0, 8.0, 0.0, 3.0], &[2, 3]);
        let am = argmax_dim(&a, 1);
        assert_eq!(am.shape(), &[2]);
        assert_eq!(am.to_vec::<i64>(), vec![1, 0]);
    }

    #[test]
    fn argmax_dim0() {
        let a = Tensor::from_vec(vec![1.0f32, 9.0, 2.0, 8.0, 0.0, 3.0], &[2, 3]);
        let am = argmax_dim(&a, 0);
        assert_eq!(am.to_vec::<i64>(), vec![1, 0, 1]);
    }

    // --- regression tests: empty-dims / empty-tensor edge cases ---

    #[test]
    fn sum_dims_empty_dims_is_identity_copy() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let s = sum_dims(&a, &[], false);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
        // A fresh buffer, not an alias: mutating it must not touch `a`.
        assert!(!s.shares_storage(&a));
        s.add_scalar_(1.0);
        assert_eq!(a.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
        let s2 = sum_dims(&a, &[], true);
        assert_eq!(s2.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn sum_dims_empty_dims_backward_is_identity() {
        let a = Tensor::ones(&[2, 2]).requires_grad(true);
        let s = sum_dims(&a, &[], false);
        s.backward_with(Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]));
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn mean_dims_empty_dims_is_identity() {
        let a = Tensor::from_vec(vec![2.0f32, 4.0], &[2]);
        let m = mean_dims(&a, &[], false);
        assert_eq!(m.to_vec::<f32>(), vec![2.0, 4.0]);
    }

    #[test]
    fn reductions_over_zero_element_tensors() {
        let a = Tensor::from_vec(Vec::<f32>::new(), &[0, 3]);
        assert_eq!(sum(&a).item(), 0.0);
        let s = sum_dims(&a, &[0], false);
        assert_eq!(s.shape(), &[3]);
        assert_eq!(s.to_vec::<f32>(), vec![0.0; 3]);
        // mean over a 0-sized dim: zeros, not a divide-by-zero panic.
        let m = mean_dims(&a, &[0], false);
        assert_eq!(m.to_vec::<f32>(), vec![0.0; 3]);
    }

    #[test]
    #[should_panic(expected = "empty tensor")]
    fn max_all_on_empty_errors_cleanly() {
        max_all(&Tensor::from_vec(Vec::<f32>::new(), &[0]));
    }

    #[test]
    fn sum_f64_matches_f32() {
        let a = Tensor::from_vec(vec![1.0f64, 2.0, 3.0], &[3]);
        assert_eq!(sum(&a).to_vec::<f64>(), vec![6.0]);
        let s = sum_dims(&a, &[0], false);
        assert_eq!(s.to_vec::<f64>(), vec![6.0]);
    }
}
