//! Reductions: full and per-axis sums/means, max, argmax, and the
//! broadcast-gradient helpers (`sum_to_shape`, `broadcast_to`).

use crate::autograd::{self, ClosureFunction};
use crate::device;
use crate::tensor::shape::{contiguous_strides, numel};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// Sum a tensor down to a broadcast-compatible `target` shape (each target
/// dim is either equal to the source dim or 1; the target may have fewer
/// dims, which behave as leading 1s).
pub fn sum_to_shape(a: &Tensor, target: &[usize]) -> Tensor {
    let a = a.contiguous();
    let src_shape = a.shape().to_vec();
    // Pad target with leading 1s to the source rank.
    let mut padded = vec![1usize; src_shape.len()];
    let off = src_shape.len() - target.len();
    padded[off..].copy_from_slice(target);
    for (i, (&s, &t)) in src_shape.iter().zip(padded.iter()).enumerate() {
        torsk_assert!(t == s || t == 1, "sum_to_shape: dim {i}: {s} -> {t}");
    }

    let out = Tensor::zeros_on(target, DType::F32, a.device());
    let n = a.numel();
    if n == 0 {
        return out;
    }
    // Output strides aligned to the padded shape, 0 where target dim == 1.
    let tstrides_dense = contiguous_strides(&padded);
    let ostrides: Vec<usize> = padded
        .iter()
        .zip(tstrides_dense.iter())
        .map(|(&d, &st)| if d == 1 { 0 } else { st })
        .collect();

    let (ap, op) = (a.data_ptr(), out.data_ptr());
    let on = numel(target);
    // §Perf: like binary_map, handle a trailing linear run specially —
    // if the output does not advance over the suffix (reduced dims), the
    // inner loop is a vectorizable sum; if it advances contiguously, it
    // is a vectorizable elementwise accumulate.
    let rank = src_shape.len();
    let src_contig = contiguous_strides(&src_shape);
    let (t, _sa, step_o) = super::binary::linear_suffix(&src_shape, &src_contig, &ostrides);
    let inner: usize = src_shape[rank - t..].iter().product();
    if t > 0 && inner > 1 {
        let outer_shape = src_shape[..rank - t].to_vec();
        let outer_so = ostrides[..rank - t].to_vec();
        device::dispatch(a.device(), "sum_to", move || unsafe {
            let av = ap.as_slice::<f32>(0, n);
            let ov = op.as_mut_slice::<f32>(0, on);
            let io = crate::tensor::shape::StridedIter::new(&outer_shape, &outer_so);
            for (chunk, ooff) in av.chunks(inner).zip(io) {
                if step_o == 0 {
                    let mut acc = 0f32;
                    for &v in chunk {
                        acc += v;
                    }
                    ov[ooff] += acc;
                } else {
                    let dst = &mut ov[ooff..ooff + inner];
                    for (d, &v) in dst.iter_mut().zip(chunk) {
                        *d += v;
                    }
                }
            }
        });
        return out;
    }
    device::dispatch(a.device(), "sum_to", move || unsafe {
        let av = ap.as_slice::<f32>(0, n);
        let ov = op.as_mut_slice::<f32>(0, on);
        let mut idx = vec![0usize; src_shape.len()];
        let mut ooff = 0usize;
        for &v in av.iter() {
            ov[ooff] += v;
            for d in (0..src_shape.len()).rev() {
                idx[d] += 1;
                ooff += ostrides[d];
                if idx[d] < src_shape[d] {
                    break;
                }
                ooff -= idx[d] * ostrides[d];
                idx[d] = 0;
            }
        }
    });
    out
}

/// Broadcast a tensor up to `target` shape (materialized copy, used by
/// reduction backwards).
pub fn broadcast_to(a: &Tensor, target: &[usize]) -> Tensor {
    if a.shape() == target {
        return a.clone();
    }
    let expanded = a.expand(target);
    expanded.contiguous()
}

/// Full sum to a scalar.
pub fn sum(a: &Tensor) -> Tensor {
    let out = sum_to_shape(a, &[]);
    if autograd::should_record(&[a]) {
        let shape = a.shape().to_vec();
        autograd::record(&[a], &out, || {
            ClosureFunction::new("sum", move |g| {
                vec![Some(broadcast_to(g, &shape))]
            })
        });
    }
    out
}

/// Full mean to a scalar.
pub fn mean(a: &Tensor) -> Tensor {
    let n = a.numel().max(1) as f32;
    let s = sum(a);
    super::mul_scalar(&s, 1.0 / n)
}

/// Sum over `dims`; `keepdim` keeps reduced axes as size-1.
pub fn sum_dims(a: &Tensor, dims: &[usize], keepdim: bool) -> Tensor {
    let mut kept = a.shape().to_vec();
    for &d in dims {
        torsk_assert!(d < a.ndim(), "sum_dims: dim {d} out of range");
        kept[d] = 1;
    }
    let reduced = sum_to_shape(a, &kept); // keepdim layout
    let out = if keepdim {
        reduced.clone()
    } else {
        let final_shape: Vec<usize> = a
            .shape()
            .iter()
            .enumerate()
            .filter(|(i, _)| !dims.contains(i))
            .map(|(_, &d)| d)
            .collect();
        reduced.reshape(&final_shape)
    };
    if autograd::should_record(&[a]) && out.grad_fn().is_none() {
        let shape = a.shape().to_vec();
        let kept2 = kept.clone();
        autograd::record(&[a], &out, || {
            ClosureFunction::new("sum_dims", move |g| {
                let g = g.reshape(&kept2);
                vec![Some(broadcast_to(&g, &shape))]
            })
        });
    }
    out
}

/// Mean over `dims`.
pub fn mean_dims(a: &Tensor, dims: &[usize], keepdim: bool) -> Tensor {
    let count: usize = dims.iter().map(|&d| a.size(d)).product();
    let s = sum_dims(a, dims, keepdim);
    super::mul_scalar(&s, 1.0 / count.max(1) as f32)
}

/// Max over all elements (scalar, grad to the (first) argmax).
pub fn max_all(a: &Tensor) -> Tensor {
    let c = a.contiguous();
    let v = c.to_vec::<f32>();
    let (mut best_i, mut best) = (0usize, f32::NEG_INFINITY);
    for (i, &x) in v.iter().enumerate() {
        if x > best {
            best = x;
            best_i = i;
        }
    }
    let out = Tensor::scalar(best).to_device(a.device());
    if autograd::should_record(&[a]) {
        let shape = a.shape().to_vec();
        let dev = a.device();
        autograd::record(&[a], &out, || {
            ClosureFunction::new("max_all", move |g| {
                let gv = g.item();
                let mut data = vec![0.0f32; numel(&shape)];
                data[best_i] = gv;
                vec![Some(Tensor::from_vec(data, &shape).to_device(dev))]
            })
        });
    }
    out
}

/// Argmax along `dim` (returns i64 tensor; no grad). Synchronous.
pub fn argmax_dim(a: &Tensor, dim: usize) -> Tensor {
    torsk_assert!(dim < a.ndim(), "argmax: dim out of range");
    let c = a.contiguous();
    let v = c.to_vec::<f32>();
    let shape = a.shape();
    let inner: usize = shape[dim + 1..].iter().product();
    let outer: usize = shape[..dim].iter().product();
    let d = shape[dim];
    let mut out_shape: Vec<usize> = shape.to_vec();
    out_shape.remove(dim);
    let mut out = vec![0i64; outer * inner];
    for o in 0..outer {
        for i in 0..inner {
            let mut best = f32::NEG_INFINITY;
            let mut best_j = 0i64;
            for j in 0..d {
                let x = v[(o * d + j) * inner + i];
                if x > best {
                    best = x;
                    best_j = j as i64;
                }
            }
            out[o * inner + i] = best_j;
        }
    }
    Tensor::from_vec(out, &out_shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_all() {
        let a = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(sum(&a).item(), 10.0);
    }

    #[test]
    fn sum_backward_broadcasts_ones() {
        let a = Tensor::from_vec(vec![1.0f32; 6], &[2, 3]).requires_grad(true);
        sum(&a).backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![1.0; 6]);
    }

    #[test]
    fn mean_backward_scales() {
        let a = Tensor::from_vec(vec![1.0f32; 4], &[4]).requires_grad(true);
        mean(&a).backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.25; 4]);
    }

    #[test]
    fn sum_dims_keepdim() {
        let a = Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[2, 3]);
        let s = sum_dims(&a, &[0], true);
        assert_eq!(s.shape(), &[1, 3]);
        assert_eq!(s.to_vec::<f32>(), vec![5.0, 7.0, 9.0]);
        let s2 = sum_dims(&a, &[1], false);
        assert_eq!(s2.shape(), &[2]);
        assert_eq!(s2.to_vec::<f32>(), vec![6.0, 15.0]);
    }

    #[test]
    fn sum_dims_multiple_axes() {
        let a = Tensor::ones(&[2, 3, 4]);
        let s = sum_dims(&a, &[0, 2], false);
        assert_eq!(s.shape(), &[3]);
        assert_eq!(s.to_vec::<f32>(), vec![8.0; 3]);
    }

    #[test]
    fn sum_dims_backward() {
        let a = Tensor::ones(&[2, 3]).requires_grad(true);
        let s = sum_dims(&a, &[0], false); // shape [3]
        s.backward_with(Tensor::from_slice(&[1.0f32, 2.0, 3.0]));
        assert_eq!(
            a.grad().unwrap().to_vec::<f32>(),
            vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn mean_dims_values() {
        let a = Tensor::from_vec(vec![2.0f32, 4.0, 6.0, 8.0], &[2, 2]);
        let m = mean_dims(&a, &[1], false);
        assert_eq!(m.to_vec::<f32>(), vec![3.0, 7.0]);
    }

    #[test]
    fn sum_to_shape_column_reduction() {
        let a = Tensor::from_vec((1..=6).map(|x| x as f32).collect(), &[2, 3]);
        let r = sum_to_shape(&a, &[2, 1]);
        assert_eq!(r.shape(), &[2, 1]);
        assert_eq!(r.to_vec::<f32>(), vec![6.0, 15.0]);
        let r2 = sum_to_shape(&a, &[3]);
        assert_eq!(r2.to_vec::<f32>(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn broadcast_to_materializes() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = broadcast_to(&a.reshape(&[2, 1]), &[2, 3]);
        assert_eq!(b.to_vec::<f32>(), vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn max_all_and_grad() {
        let a = Tensor::from_slice(&[1.0f32, 7.0, 3.0]).requires_grad(true);
        let m = max_all(&a);
        assert_eq!(m.item(), 7.0);
        m.backward();
        assert_eq!(a.grad().unwrap().to_vec::<f32>(), vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn argmax_rows() {
        let a = Tensor::from_vec(vec![1.0f32, 9.0, 2.0, 8.0, 0.0, 3.0], &[2, 3]);
        let am = argmax_dim(&a, 1);
        assert_eq!(am.shape(), &[2]);
        assert_eq!(am.to_vec::<i64>(), vec![1, 0]);
    }

    #[test]
    fn argmax_dim0() {
        let a = Tensor::from_vec(vec![1.0f32, 9.0, 2.0, 8.0, 0.0, 3.0], &[2, 3]);
        let am = argmax_dim(&a, 0);
        assert_eq!(am.to_vec::<i64>(), vec![1, 0, 1]);
    }
}
