//! Pooling ops (max / avg / global-avg) with autograd.

use crate::autograd::{self, ClosureFunction};
use crate::device;
use crate::kernels::pool::{
    avgpool2d_backward, avgpool2d_forward, maxpool2d_backward, maxpool2d_forward, Pool2dArgs,
};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

fn pool_args(input: &Tensor, kernel: usize, stride: usize, padding: usize) -> Pool2dArgs {
    torsk_assert!(input.ndim() == 4, "pool2d: input must be NCHW");
    Pool2dArgs {
        batch: input.size(0),
        channels: input.size(1),
        h_in: input.size(2),
        w_in: input.size(3),
        kernel,
        stride,
        padding,
    }
}

/// Max pooling over 2-D spatial dims.
pub fn maxpool2d(input: &Tensor, kernel: usize, stride: usize, padding: usize) -> Tensor {
    let args = pool_args(input, kernel, stride, padding);
    let input_c = input.contiguous();
    let dev = input.device();
    let out = Tensor::empty(&[args.batch, args.channels, args.h_out(), args.w_out()], DType::F32, dev);
    let indices = Tensor::empty(out.shape(), DType::I64, dev);
    {
        let (ip, op, xp) = (input_c.data_ptr(), out.data_ptr(), indices.data_ptr());
        let (in_len, out_len) = (input_c.numel(), out.numel());
        device::dispatch(dev, "maxpool2d", move || unsafe {
            maxpool2d_forward(
                &args,
                ip.as_slice::<f32>(0, in_len),
                op.as_mut_slice::<f32>(0, out_len),
                xp.as_mut_slice::<i64>(0, out_len),
            );
        });
    }
    if autograd::should_record(&[input]) {
        let in_shape = input.shape().to_vec();
        autograd::record(&[input], &out, || {
            ClosureFunction::new("maxpool2d", move |g| {
                let g = g.contiguous();
                let gv = g.to_vec::<f32>();
                let iv = indices.to_vec::<i64>();
                let mut gi = vec![0.0f32; args.batch * args.channels * args.h_in * args.w_in];
                maxpool2d_backward(&args, &gv, &iv, &mut gi);
                vec![Some(Tensor::from_vec(gi, &in_shape).to_device(g.device()))]
            })
        });
    }
    out
}

/// Average pooling over 2-D spatial dims.
pub fn avgpool2d(input: &Tensor, kernel: usize, stride: usize, padding: usize) -> Tensor {
    let args = pool_args(input, kernel, stride, padding);
    let input_c = input.contiguous();
    let dev = input.device();
    let out = Tensor::empty(&[args.batch, args.channels, args.h_out(), args.w_out()], DType::F32, dev);
    {
        let (ip, op) = (input_c.data_ptr(), out.data_ptr());
        let (in_len, out_len) = (input_c.numel(), out.numel());
        device::dispatch(dev, "avgpool2d", move || unsafe {
            avgpool2d_forward(&args, ip.as_slice::<f32>(0, in_len), op.as_mut_slice::<f32>(0, out_len));
        });
    }
    if autograd::should_record(&[input]) {
        let in_shape = input.shape().to_vec();
        autograd::record(&[input], &out, || {
            ClosureFunction::new("avgpool2d", move |g| {
                let g = g.contiguous();
                let gv = g.to_vec::<f32>();
                let mut gi = vec![0.0f32; args.batch * args.channels * args.h_in * args.w_in];
                avgpool2d_backward(&args, &gv, &mut gi);
                vec![Some(Tensor::from_vec(gi, &in_shape).to_device(g.device()))]
            })
        });
    }
    out
}

/// Global average pooling NCHW -> NC (adaptive_avg_pool2d(1) + flatten).
pub fn global_avgpool2d(input: &Tensor) -> Tensor {
    torsk_assert!(input.ndim() == 4, "global_avgpool2d: input must be NCHW");
    let (n, c) = (input.size(0), input.size(1));
    let pooled = super::mean_dims(input, &[2, 3], false);
    pooled.reshape(&[n, c])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_forward_and_backward() {
        let x = Tensor::from_vec(
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0, 16.0],
            &[1, 1, 4, 4],
        )
        .requires_grad(true);
        let y = maxpool2d(&x, 2, 2, 0);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.to_vec::<f32>(), vec![6.0, 8.0, 14.0, 16.0]);
        y.sum().backward();
        let g = x.grad().unwrap().to_vec::<f32>();
        assert_eq!(g.iter().sum::<f32>(), 4.0);
        assert_eq!(g[5], 1.0);
        assert_eq!(g[15], 1.0);
    }

    #[test]
    fn avgpool_forward_and_backward() {
        let x = Tensor::ones(&[1, 1, 4, 4]).requires_grad(true);
        let y = avgpool2d(&x, 2, 2, 0);
        assert_eq!(y.to_vec::<f32>(), vec![1.0; 4]);
        y.sum().backward();
        assert_eq!(x.grad().unwrap().to_vec::<f32>(), vec![0.25; 16]);
    }

    #[test]
    fn global_avgpool_shape_and_grad() {
        let x = Tensor::randn(&[2, 3, 4, 4]).requires_grad(true);
        let y = global_avgpool2d(&x);
        assert_eq!(y.shape(), &[2, 3]);
        y.sum().backward();
        let g = x.grad().unwrap();
        assert_eq!(g.shape(), &[2, 3, 4, 4]);
        let gv = g.to_vec::<f32>();
        assert!(gv.iter().all(|&v| (v - 1.0 / 16.0).abs() < 1e-6));
    }

    #[test]
    fn maxpool_stride_one() {
        let x = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[1, 1, 2, 2]);
        let y = maxpool2d(&x, 2, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.item(), 4.0);
    }
}
