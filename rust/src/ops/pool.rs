//! Pooling ops (max / avg / global-avg) — dispatcher shims.

use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

/// Max pooling over 2-D spatial dims.
pub fn maxpool2d(input: &Tensor, kernel: usize, stride: usize, padding: usize) -> Tensor {
    dispatch::call(
        "maxpool2d",
        &[input],
        &[Param::Usize(kernel), Param::Usize(stride), Param::Usize(padding)],
    )
}

/// Average pooling over 2-D spatial dims.
pub fn avgpool2d(input: &Tensor, kernel: usize, stride: usize, padding: usize) -> Tensor {
    dispatch::call(
        "avgpool2d",
        &[input],
        &[Param::Usize(kernel), Param::Usize(stride), Param::Usize(padding)],
    )
}

/// Global average pooling NCHW -> NC (adaptive_avg_pool2d(1) + flatten).
pub fn global_avgpool2d(input: &Tensor) -> Tensor {
    dispatch::call("global_avgpool2d", &[input], &[])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_forward_and_backward() {
        let x = Tensor::from_vec(
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0, 16.0],
            &[1, 1, 4, 4],
        )
        .requires_grad(true);
        let y = maxpool2d(&x, 2, 2, 0);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.to_vec::<f32>(), vec![6.0, 8.0, 14.0, 16.0]);
        y.sum().backward();
        let g = x.grad().unwrap().to_vec::<f32>();
        assert_eq!(g.iter().sum::<f32>(), 4.0);
        assert_eq!(g[5], 1.0);
        assert_eq!(g[15], 1.0);
    }

    #[test]
    fn avgpool_forward_and_backward() {
        let x = Tensor::ones(&[1, 1, 4, 4]).requires_grad(true);
        let y = avgpool2d(&x, 2, 2, 0);
        assert_eq!(y.to_vec::<f32>(), vec![1.0; 4]);
        y.sum().backward();
        assert_eq!(x.grad().unwrap().to_vec::<f32>(), vec![0.25; 16]);
    }

    #[test]
    fn global_avgpool_shape_and_grad() {
        let x = Tensor::randn(&[2, 3, 4, 4]).requires_grad(true);
        let y = global_avgpool2d(&x);
        assert_eq!(y.shape(), &[2, 3]);
        y.sum().backward();
        let g = x.grad().unwrap();
        assert_eq!(g.shape(), &[2, 3, 4, 4]);
        let gv = g.to_vec::<f32>();
        assert!(gv.iter().all(|&v| (v - 1.0 / 16.0).abs() < 1e-6));
    }

    #[test]
    fn maxpool_stride_one() {
        let x = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[1, 1, 2, 2]);
        let y = maxpool2d(&x, 2, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.item(), 4.0);
    }
}
