//! Mini property-testing harness (proptest is unavailable in the offline
//! crate set — DESIGN.md §7 documents the substitution): seeded random
//! input generators + a `for_all` driver that reports the failing seed.

use crate::rng::Rng;

pub mod chaos;

/// Run `prop` against `cases` generated inputs; panics with the seed of
/// the first failing case so it can be replayed.
pub fn for_all<T, G, P>(name: &str, cases: usize, mut generate: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let seed = 0xBEEF ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut r = Rng::new(seed);
        let input = generate(&mut r);
        if !prop(&input) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {input:?}");
        }
    }
}

/// Random shape with bounded rank/extent.
pub fn gen_shape(r: &mut Rng, max_rank: usize, max_dim: usize) -> Vec<usize> {
    let rank = 1 + r.below(max_rank as u64) as usize;
    (0..rank).map(|_| 1 + r.below(max_dim as u64) as usize).collect()
}

/// Random f32 vector.
pub fn gen_vec(r: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| r.uniform_range(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_all("trivial", 25, |r| r.below(10), |_| { true });
        for_all("count", 5, |_| (), |_| { count += 1; true });
        assert_eq!(count, 5);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        for_all("fails", 10, |r| r.below(100), |&x| x < 1_000_000 && false || x > 1_000_000);
    }

    #[test]
    fn gen_shape_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..100 {
            let s = gen_shape(&mut r, 4, 8);
            assert!((1..=4).contains(&s.len()));
            assert!(s.iter().all(|&d| (1..=8).contains(&d)));
        }
    }
}
