//! Fault injection for the chaos suite (`tests/chaos.rs`).
//!
//! Production code exposes *named fault points* (e.g. the checkpoint
//! writer's `"checkpoint:write"`); a test arms a [`Fault`] at a point,
//! runs the scenario, and asserts the failure surfaced the contracted way
//! — a typed error, a loud panic, never silent truncation. When nothing
//! is armed (always, outside tests) the hooks cost one relaxed atomic
//! load and inject nothing.
//!
//! Registry faults are **thread-scoped**: they fire only on the thread
//! that armed them. Tests run concurrently in one process, and an armed
//! `"checkpoint:write"` must not fail some *other* test's save. Faults
//! that must cross threads (a loader worker dying in `Dataset::get`) use
//! the instance-scoped wrappers below instead, which inject only into
//! the pipeline that holds them.
//!
//! The module also ships deterministic misbehaving pipeline pieces —
//! [`ChaosDataset`] (panic or stall at a chosen index) and
//! [`PanickingCollate`] — plus a [`Gate`] for stalls, so "worker wedged
//! in `Dataset::get`" is a blocked condvar the test controls, not a
//! `sleep` and a prayer. No threads are spawned here: faults run on
//! whatever thread hits the fault point.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::data::{Collate, Dataset};
use crate::tensor::Tensor;

/// What an armed fault point does when execution reaches it.
#[derive(Clone, Debug)]
pub enum Fault {
    /// Panic with this message (a crashed worker, a dataset bug).
    Panic(String),
    /// For write-style points: let the first `n` bytes through, then fail
    /// the write (a torn checkpoint — kill -9 or disk-full mid-write).
    FailWriteAfter(usize),
}

struct Armed {
    fault: Fault,
    hits: usize,
    /// Only this thread observes the fault (see module docs).
    thread: std::thread::ThreadId,
}

/// Number of currently armed points — the fast path: [`fire`] and
/// [`write_fault`] skip the registry lock entirely when this is zero.
static ARMED_COUNT: AtomicUsize = AtomicUsize::new(0);

static REGISTRY: Mutex<BTreeMap<String, Armed>> = Mutex::new(BTreeMap::new());

/// Lock the registry, tolerating poison: a `Fault::Panic` unwinding out of
/// [`fire`] must not wedge every later test in the process.
fn registry() -> std::sync::MutexGuard<'static, BTreeMap<String, Armed>> {
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm `fault` at the named point for the **calling thread** (replacing
/// any previous arming of that point).
pub fn arm(point: &str, fault: Fault) {
    let mut reg = registry();
    let armed = Armed { fault, hits: 0, thread: std::thread::current().id() };
    if reg.insert(point.to_string(), armed).is_none() {
        ARMED_COUNT.fetch_add(1, Ordering::SeqCst);
    }
}

/// Disarm the named point (no-op if it was not armed).
pub fn disarm(point: &str) {
    let mut reg = registry();
    if reg.remove(point).is_some() {
        ARMED_COUNT.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Disarm everything (test teardown).
pub fn reset() {
    let mut reg = registry();
    let n = reg.len();
    reg.clear();
    ARMED_COUNT.fetch_sub(n, Ordering::SeqCst);
}

/// How many times the named point has fired since it was armed.
pub fn hits(point: &str) -> usize {
    registry().get(point).map_or(0, |a| a.hits)
}

/// Production-side hook for panic-style faults: if `point` is armed with
/// [`Fault::Panic`], record the hit and panic with its message. Free when
/// nothing is armed.
pub fn fire(point: &str) {
    if ARMED_COUNT.load(Ordering::Relaxed) == 0 {
        return;
    }
    let msg = {
        let mut reg = registry();
        match reg.get_mut(point) {
            Some(a) if a.thread == std::thread::current().id() => {
                a.hits += 1;
                match &a.fault {
                    Fault::Panic(msg) => Some(msg.clone()),
                    Fault::FailWriteAfter(_) => None,
                }
            }
            _ => None,
        }
    };
    // Panic only after the registry lock is released.
    if let Some(msg) = msg {
        panic!("chaos[{point}]: {msg}");
    }
}

/// Production-side hook for write-style points: if `point` is armed with
/// [`Fault::FailWriteAfter`], record the hit and return `Some(n)` — the
/// caller must write at most `n` bytes and then fail with an I/O error.
/// Free when nothing is armed.
pub fn write_fault(point: &str) -> Option<usize> {
    if ARMED_COUNT.load(Ordering::Relaxed) == 0 {
        return None;
    }
    let mut reg = registry();
    match reg.get_mut(point) {
        Some(a) if a.thread == std::thread::current().id() => {
            if let Fault::FailWriteAfter(n) = a.fault {
                a.hits += 1;
                Some(n)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// A reusable open/closed latch for stall faults: threads block in
/// [`Gate::wait`] until the test calls [`Gate::open`]. Cloning shares the
/// gate.
#[derive(Clone)]
pub struct Gate {
    inner: Arc<(Mutex<bool>, Condvar)>,
}

impl Gate {
    /// A new, closed gate.
    pub fn new() -> Gate {
        Gate { inner: Arc::new((Mutex::new(false), Condvar::new())) }
    }

    /// Open the gate, releasing every current and future [`Gate::wait`].
    pub fn open(&self) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap_or_else(|e| e.into_inner()) = true;
        cv.notify_all();
    }

    /// Block until the gate is opened (returns immediately if it already
    /// was).
    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut open = lock.lock().unwrap_or_else(|e| e.into_inner());
        while !*open {
            open = cv.wait(open).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Default for Gate {
    fn default() -> Gate {
        Gate::new()
    }
}

/// Request-scoped fault injection for the serving chaos suite
/// (`tests/serve_chaos.rs`). Instance-scoped like [`ChaosDataset`], not
/// registry-scoped: serve workers execute requests on their own threads,
/// where a thread-scoped arming could never fire. A test clones one of
/// these into `serve::ServeConfig::with_chaos`, arms faults by request
/// **sequence number**, and the worker fires them at the top of the
/// handler (inside its panic-isolation `catch_unwind`).
///
/// Faults stay armed after firing on purpose: the worker's poison
/// isolation re-runs a panicking batch one request at a time, and the
/// guilty request must panic *again* when alone to be failed typed.
#[derive(Clone, Default)]
pub struct RequestFaults {
    inner: Arc<RequestFaultsInner>,
}

#[derive(Default)]
struct RequestFaultsInner {
    panics: Mutex<std::collections::BTreeSet<u64>>,
    stalls: Mutex<BTreeMap<u64, Gate>>,
    stalled: Gate,
    hits: AtomicUsize,
}

impl RequestFaults {
    pub fn new() -> RequestFaults {
        RequestFaults::default()
    }

    /// Panic the handler whenever it executes request `seq`.
    pub fn panic_on(&self, seq: u64) {
        self.inner.panics.lock().unwrap_or_else(|e| e.into_inner()).insert(seq);
    }

    /// Block the handler on `gate` whenever it executes request `seq`
    /// (a wedged worker the test controls — no sleeps).
    pub fn stall_on(&self, seq: u64, gate: Gate) {
        self.inner.stalls.lock().unwrap_or_else(|e| e.into_inner()).insert(seq, gate);
    }

    /// A gate that opens the moment a stalled handler begins waiting —
    /// the test can block until the worker is *provably* wedged.
    pub fn stalled(&self) -> Gate {
        self.inner.stalled.clone()
    }

    /// Total times any armed fault fired.
    pub fn hits(&self) -> usize {
        self.inner.hits.load(Ordering::SeqCst)
    }

    /// Production-side hook (called by the serve worker per batch
    /// member): panic or stall if `seq` is armed. Free when nothing is.
    pub fn fire(&self, seq: u64) {
        let panics = {
            let set = self.inner.panics.lock().unwrap_or_else(|e| e.into_inner());
            set.contains(&seq)
        };
        if panics {
            self.inner.hits.fetch_add(1, Ordering::SeqCst);
            panic!("chaos[request {seq}]: injected handler panic");
        }
        let gate = {
            let stalls = self.inner.stalls.lock().unwrap_or_else(|e| e.into_inner());
            stalls.get(&seq).cloned()
        };
        if let Some(gate) = gate {
            self.inner.hits.fetch_add(1, Ordering::SeqCst);
            self.inner.stalled.open();
            gate.wait();
        }
    }
}

/// A [`Dataset`] wrapper that misbehaves at chosen indices: panic (a
/// crashed worker) or block on a [`Gate`] (a wedged worker). All other
/// indices pass through unchanged, so the surviving batches stay bitwise
/// identical to the clean run.
pub struct ChaosDataset {
    inner: Arc<dyn Dataset>,
    panic_at: Option<usize>,
    stall_at: Option<(usize, Gate)>,
    stalled: Gate,
}

impl ChaosDataset {
    pub fn new(inner: Arc<dyn Dataset>) -> ChaosDataset {
        ChaosDataset { inner, panic_at: None, stall_at: None, stalled: Gate::new() }
    }

    /// Panic when `get(index)` is called.
    pub fn panic_at(mut self, index: usize) -> ChaosDataset {
        self.panic_at = Some(index);
        self
    }

    /// Block on `gate` when `get(index)` is called, until the test opens
    /// it.
    pub fn stall_at(mut self, index: usize, gate: Gate) -> ChaosDataset {
        self.stall_at = Some((index, gate));
        self
    }

    /// A gate that opens the moment a stalled `get` begins waiting — lets a
    /// test block until the worker is provably wedged instead of sleeping.
    pub fn stalled(&self) -> Gate {
        self.stalled.clone()
    }
}

impl Dataset for ChaosDataset {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        if self.panic_at == Some(index) {
            panic!("chaos: dataset panic injected at index {index}");
        }
        if let Some((i, gate)) = &self.stall_at {
            if *i == index {
                self.stalled.open();
                gate.wait();
            }
        }
        self.inner.get(index)
    }
}

/// A [`Collate`] that panics on its `after`-th call (0-based), modeling a
/// collation bug that only a particular batch triggers.
pub struct PanickingCollate {
    inner: crate::data::DefaultCollate,
    after: usize,
    calls: AtomicUsize,
}

impl PanickingCollate {
    pub fn new(after: usize) -> PanickingCollate {
        PanickingCollate { inner: crate::data::DefaultCollate, after, calls: AtomicUsize::new(0) }
    }
}

impl Collate for PanickingCollate {
    fn collate(&self, samples: &[(Tensor, Tensor)]) -> (Tensor, Tensor) {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if n == self.after {
            panic!("chaos: collate panic injected on call {n}");
        }
        self.inner.collate(samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; these tests use distinct point names
    // so they can run concurrently with each other.

    #[test]
    fn unarmed_points_are_free_and_silent() {
        fire("chaos-test:never-armed");
        assert_eq!(write_fault("chaos-test:never-armed"), None);
        assert_eq!(hits("chaos-test:never-armed"), 0);
    }

    #[test]
    fn armed_write_fault_reports_budget_and_hits() {
        arm("chaos-test:w", Fault::FailWriteAfter(12));
        assert_eq!(write_fault("chaos-test:w"), Some(12));
        assert_eq!(write_fault("chaos-test:w"), Some(12));
        assert_eq!(hits("chaos-test:w"), 2);
        disarm("chaos-test:w");
        assert_eq!(write_fault("chaos-test:w"), None);
    }

    #[test]
    fn panic_fault_fires_with_point_name() {
        arm("chaos-test:p", Fault::Panic("boom".into()));
        let r = std::panic::catch_unwind(|| fire("chaos-test:p"));
        disarm("chaos-test:p");
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("chaos[chaos-test:p]: boom"), "{msg}");
    }

    #[test]
    fn gate_releases_waiters_on_open() {
        let gate = Gate::new();
        let g2 = gate.clone();
        gate.open();
        g2.wait(); // already open: returns immediately
    }

    #[test]
    fn chaos_dataset_passes_through_and_panics_on_target() {
        struct One;
        impl Dataset for One {
            fn len(&self) -> usize {
                4
            }
            fn get(&self, i: usize) -> (Tensor, Tensor) {
                (Tensor::full(&[1], i as f32), Tensor::from_vec(vec![i as i64], &[]))
            }
        }
        let ds = ChaosDataset::new(Arc::new(One)).panic_at(2);
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.get(1).0.to_vec::<f32>(), vec![1.0]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ds.get(2)));
        assert!(r.is_err());
    }

    #[test]
    fn request_faults_panic_and_stay_armed() {
        let faults = RequestFaults::new();
        faults.panic_on(3);
        faults.fire(2); // unarmed seq: silent
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| faults.fire(3)));
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("chaos[request 3]"), "{msg}");
        // Still armed: the isolation re-run must panic again.
        let r2 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| faults.fire(3)));
        assert!(r2.is_err());
        assert_eq!(faults.hits(), 2);
    }

    #[test]
    fn request_faults_stall_opens_stalled_gate() {
        let faults = RequestFaults::new();
        let release = Gate::new();
        faults.stall_on(7, release.clone());
        release.open(); // pre-open so this test's fire returns at once
        faults.fire(7);
        assert_eq!(faults.hits(), 1);
        faults.stalled().wait(); // opened by the fire
    }

    #[test]
    fn panicking_collate_counts_calls() {
        let c = PanickingCollate::new(1);
        let samples = vec![(Tensor::ones(&[2]), Tensor::from_vec(vec![0i64], &[]))];
        let _ = c.collate(&samples); // call 0: fine
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c.collate(&samples)));
        assert!(r.is_err(), "call 1 must panic");
    }
}
