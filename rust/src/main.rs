fn main() { torsk::cli::run(); }
