//! Global library context: per-device allocators and their wiring.
//!
//! Mirrors PyTorch's process-global singletons: the CUDA caching allocator
//! instance, the stream registry, the profiler. The simulated-device
//! allocator is swappable at runtime so the Figure 2 bench can compare the
//! caching allocator against the naive pass-through one on identical
//! workloads. Tensors capture an `Arc` to the allocator they came from, so
//! swapping never frees a live block into the wrong pool.

use std::sync::{Arc, RwLock};

use crate::alloc::caching::CachingAllocator;
use crate::alloc::driver::{HostMem, MemDriver, SimDeviceMem, SimDriverConfig};
use crate::alloc::naive::NaiveAllocator;
use crate::alloc::ArcAllocator;
use crate::device::{self, Device};

struct Ctx {
    host_alloc: ArcAllocator,
    sim_driver: Arc<SimDeviceMem>,
    sim_alloc: RwLock<ArcAllocator>,
}

static CTX: once_cell::sync::Lazy<Ctx> = once_cell::sync::Lazy::new(|| {
    let sim_driver = Arc::new(SimDeviceMem::new(SimDriverConfig::default(), device::streams()));
    let sim_alloc: ArcAllocator = Arc::new(CachingAllocator::new(sim_driver.clone() as Arc<dyn MemDriver>));
    Ctx {
        host_alloc: Arc::new(CachingAllocator::new(Arc::new(HostMem::default()))),
        sim_driver,
        sim_alloc: RwLock::new(sim_alloc),
    }
});

/// Allocator for host (CPU) tensors.
pub fn host_allocator() -> ArcAllocator {
    CTX.host_alloc.clone()
}

/// Allocator for simulated-device tensors (caching by default).
pub fn sim_allocator() -> ArcAllocator {
    CTX.sim_alloc.read().unwrap().clone()
}

/// The allocator serving `device`.
pub fn allocator_for(device: Device) -> ArcAllocator {
    match device {
        Device::Cpu => host_allocator(),
        Device::Sim => sim_allocator(),
    }
}

/// The simulated `cudaMalloc/cudaFree` driver (for stats in benches).
pub fn sim_driver() -> Arc<SimDeviceMem> {
    CTX.sim_driver.clone()
}

/// Replace the simulated-device allocator. Existing tensors keep (and
/// eventually free into) the allocator they were created from.
pub fn set_sim_allocator(a: ArcAllocator) {
    *CTX.sim_alloc.write().unwrap() = a;
}

/// Install a fresh *caching* allocator on the simulated device and return it.
pub fn use_caching_sim_allocator() -> Arc<CachingAllocator> {
    let a = Arc::new(CachingAllocator::new(CTX.sim_driver.clone() as Arc<dyn MemDriver>));
    set_sim_allocator(a.clone() as ArcAllocator);
    a
}

/// Install a fresh *naive* allocator on the simulated device and return it
/// (the no-caching baseline of Figure 2).
pub fn use_naive_sim_allocator() -> Arc<NaiveAllocator> {
    let a = Arc::new(NaiveAllocator::new(CTX.sim_driver.clone() as Arc<dyn MemDriver>));
    set_sim_allocator(a.clone() as ArcAllocator);
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{Allocator, StreamId};

    #[test]
    fn host_allocator_is_shared_singleton() {
        let a = host_allocator();
        let b = host_allocator();
        let blk = a.allocate(100, StreamId::HOST);
        b.deallocate(blk);
        assert!(a.stats().driver_allocs >= 1);
    }

    #[test]
    fn sim_allocator_swap_is_visible() {
        let naive = use_naive_sim_allocator();
        let blk = sim_allocator().allocate(256, StreamId::DEFAULT);
        sim_allocator().deallocate(blk);
        assert_eq!(naive.stats().driver_frees, 1);
        // Restore the default for other tests.
        use_caching_sim_allocator();
    }
}
