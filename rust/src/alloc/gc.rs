//! Deferred-reclamation ("garbage collected") allocator baseline (§5.5).
//!
//! The paper contrasts PyTorch's immediate reference-counted reclamation
//! with the garbage collection Torch7 inherited from Lua: "by deferring the
//! deallocation, it causes the program to use more memory overall", which
//! is unacceptable when device memory is scarce.
//!
//! [`GcAllocator`] models a tracing collector's *memory behaviour* from the
//! allocator's point of view: `deallocate` only queues the block on a
//! graveyard list; blocks are actually reclaimed when a "collection" runs —
//! either explicitly ([`GcAllocator::collect`]) or automatically once the
//! graveyard exceeds a heap-growth threshold, like generational collectors
//! triggering on allocation pressure. The `refcount_vs_gc` bench measures
//! the resulting peak-memory gap on a tensor-churn workload.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{AllocCounters, AllocStats, Allocator, Block, StreamId};

/// Allocator that defers frees until a collection cycle.
pub struct GcAllocator {
    inner: Arc<dyn Allocator>,
    graveyard: Mutex<Vec<Block>>,
    graveyard_bytes: AtomicU64,
    /// Run a collection automatically once this many bytes are dead.
    pub collect_threshold_bytes: u64,
    counters: AllocCounters,
    collections: AtomicU64,
}

impl GcAllocator {
    /// Wrap `inner` (the allocator doing real work) with deferred frees.
    pub fn new(inner: Arc<dyn Allocator>, collect_threshold_bytes: u64) -> Self {
        GcAllocator {
            inner,
            graveyard: Mutex::new(Vec::new()),
            graveyard_bytes: AtomicU64::new(0),
            collect_threshold_bytes,
            counters: AllocCounters::default(),
            collections: AtomicU64::new(0),
        }
    }

    /// Reclaim every dead block now (an explicit `gc.collect()` — the
    /// "sprinkle the program with explicit triggers" antipattern §5.5
    /// describes among Torch7 users).
    pub fn collect(&self) {
        let dead: Vec<Block> = std::mem::take(&mut *self.graveyard.lock().unwrap());
        for b in dead {
            self.graveyard_bytes.fetch_sub(b.size as u64, Ordering::Relaxed);
            self.inner.deallocate(b);
        }
        self.collections.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of collection cycles run so far.
    pub fn collections(&self) -> u64 {
        self.collections.load(Ordering::Relaxed)
    }

    /// Bytes sitting dead in the graveyard right now.
    pub fn dead_bytes(&self) -> u64 {
        self.graveyard_bytes.load(Ordering::Relaxed)
    }
}

impl Allocator for GcAllocator {
    fn allocate(&self, bytes: usize, stream: StreamId) -> Block {
        let b = self.inner.allocate(bytes, stream);
        // Peak accounting must include the graveyard: that memory is still
        // unavailable to the rest of the system (the §5.5 overhead).
        self.counters.on_alloc(b.size + self.graveyard_bytes.load(Ordering::Relaxed) as usize);
        self.counters.on_free(self.graveyard_bytes.load(Ordering::Relaxed) as usize);
        b
    }

    fn deallocate(&self, block: Block) {
        self.counters.on_free(block.size);
        let sz = block.size as u64;
        self.graveyard.lock().unwrap().push(block);
        let dead = self.graveyard_bytes.fetch_add(sz, Ordering::Relaxed) + sz;
        if dead >= self.collect_threshold_bytes {
            self.collect();
        }
    }

    fn stats(&self) -> AllocStats {
        // Report through the inner allocator's view plus graveyard size, so
        // `in_use + dead` is what a memory-pressure monitor would observe.
        let mut s = self.inner.stats();
        s.cached_bytes += self.graveyard_bytes.load(Ordering::Relaxed);
        s
    }

    fn empty_cache(&self) {
        self.collect();
        self.inner.empty_cache();
    }

    fn reset_stats(&self) {
        self.counters.reset();
        self.inner.reset_stats();
    }
}

impl Drop for GcAllocator {
    fn drop(&mut self) {
        self.collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::driver::HostMem;
    use crate::alloc::naive::NaiveAllocator;

    fn mk(threshold: u64) -> (Arc<NaiveAllocator>, GcAllocator) {
        let inner = Arc::new(NaiveAllocator::new(Arc::new(HostMem::default())));
        let gc = GcAllocator::new(inner.clone(), threshold);
        (inner, gc)
    }

    #[test]
    fn frees_are_deferred_until_collect() {
        let (inner, gc) = mk(u64::MAX);
        let b = gc.allocate(1024, StreamId::HOST);
        gc.deallocate(b);
        assert_eq!(inner.stats().driver_frees, 0, "free must be deferred");
        assert_eq!(gc.dead_bytes(), 1024);
        gc.collect();
        assert_eq!(inner.stats().driver_frees, 1);
        assert_eq!(gc.dead_bytes(), 0);
    }

    #[test]
    fn threshold_triggers_automatic_collection() {
        let (inner, gc) = mk(4096);
        for _ in 0..8 {
            let b = gc.allocate(1024, StreamId::HOST);
            gc.deallocate(b);
        }
        assert!(gc.collections() >= 1);
        assert!(inner.stats().driver_frees >= 4);
    }

    #[test]
    fn deferred_memory_raises_observed_footprint() {
        // With GC the dead bytes linger; refcounting (the plain inner
        // allocator) would show zero. This is the §5.5 claim in one assert.
        let (inner, gc) = mk(u64::MAX);
        let mut peak_gc = 0u64;
        for _ in 0..16 {
            let b = gc.allocate(64 * 1024, StreamId::HOST);
            gc.deallocate(b);
            let s = gc.stats();
            peak_gc = peak_gc.max(s.in_use_bytes + s.cached_bytes);
        }
        assert!(peak_gc >= 16 * 64 * 1024, "graveyard should accumulate: {peak_gc}");
        gc.collect();
        assert_eq!(inner.stats().in_use_bytes, 0);
    }
}
