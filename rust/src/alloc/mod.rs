//! Memory allocation subsystem (paper §5.3, Figure 2).
//!
//! The paper's key observation: eager frameworks allocate an output tensor
//! for almost every operator, and on an accelerator the raw driver calls
//! (`cudaMalloc` / `cudaFree`) are catastrophically expensive — `cudaFree`
//! blocks the host until all queued work on the device drains. PyTorch's
//! answer is a *caching* allocator that requests memory from the driver
//! once and reassigns it forever after, with three tuning decisions we
//! reproduce exactly:
//!
//! 1. sizes round up to multiples of 512 bytes to limit fragmentation,
//! 2. one pool per stream, so a block freed on the host can be reused
//!    immediately by later work on the *same* stream (stream FIFO ordering
//!    makes this safe even though the device may not have executed the
//!    freeing op's consumers yet),
//! 3. freed blocks are never returned to the driver until `empty_cache`.
//!
//! Layout of this module:
//! - [`driver`]  — the raw memory "drivers": [`driver::HostMem`] (plain
//!   aligned system allocation) and [`driver::SimDeviceMem`], a simulated
//!   `cudaMalloc`/`cudaFree` whose free blocks on stream drain (the GPU
//!   substitute; see DESIGN.md §2).
//! - [`caching`] — the caching allocator itself.
//! - [`naive`]   — a pass-through allocator (every request hits the
//!   driver), the baseline for Figure 2 / the Chainer-like mode.
//! - [`gc`]      — a deferred-reclamation arena used by the §5.5
//!   refcounting-vs-GC comparison bench.
//!
//! # Buffer donation (output-stealing) — who may skip this module
//!
//! One layer above, the dispatcher can bypass allocation entirely:
//! `dispatch::call_owned` lets an elementwise op's output *steal* an
//! input's storage. The contract an input must meet to be donated:
//!
//! 1. **Provably dead by ownership** — every live `Tensor` handle to it
//!    was moved into the call (`Arc` strong count == its occurrence count
//!    among the call's operands), its storage is not shared with any
//!    other tensor (storage refcount 1, non-view, offset 0);
//! 2. **no autograd recording** — stealing under a recording would
//!    corrupt saved intermediates;
//! 3. **layout-compatible** — same shape and dtype as the output, all
//!    operands contiguous, so the kernel runs the index-aligned Fast plan
//!    (kernels flagged `reuse_output` handle `out == input` aliasing with
//!    raw read-then-write loops).
//!
//! The donated block travels through a **thread-local slot**: the
//! dispatcher parks the dying input's storage there, and the next
//! `Storage::new` on that thread consumes it instead of calling
//! `allocate`. The counters here therefore *undercount* stolen outputs by
//! design — `dispatch::output_reuse_stats()` tracks those; everything
//! that isn't stolen (and every batch buffer the `data` pipeline
//! collates) is served by the caching allocator below, which is where the
//! steady-state `cache_hit_rate()` story in `BENCH_ops.json` /
//! `tests/alloc_reuse.rs` / `tests/data_loader.rs` comes from.

pub mod caching;
pub mod driver;
pub mod gc;
pub mod naive;

use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Identifies a device work queue (see [`crate::device`]). Stream 0 is the
/// default stream; host-side allocations use [`StreamId::HOST`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct StreamId(pub u32);

impl StreamId {
    /// Pseudo-stream for host (CPU) memory.
    pub const HOST: StreamId = StreamId(u32::MAX);
    /// The default device stream.
    pub const DEFAULT: StreamId = StreamId(0);
}

/// Allocation granularity: the paper rounds all requests up to multiples of
/// 512 bytes "to avoid fragmentation issues".
pub const ROUND_BYTES: usize = 512;

/// Round a byte count up to the allocator granularity.
#[inline]
pub fn round_up(bytes: usize) -> usize {
    if bytes == 0 {
        ROUND_BYTES
    } else {
        (bytes + ROUND_BYTES - 1) / ROUND_BYTES * ROUND_BYTES
    }
}

/// A block of device (or host) memory handed out by an [`Allocator`].
#[derive(Debug)]
pub struct Block {
    /// Base address. Valid until the owning allocator's `empty_cache` (for
    /// cached blocks) or `deallocate` (for pass-through allocators).
    pub ptr: NonNull<u8>,
    /// Rounded capacity of the block in bytes.
    pub size: usize,
    /// The caller's original request, `<= size`.
    pub requested: usize,
    /// Stream whose pool this block belongs to.
    pub stream: StreamId,
    /// True iff `ptr`/`size` are exactly what the driver returned — only
    /// such blocks may ever be handed back to the driver. Split fragments
    /// (interior pointers / shrunk sizes) must stay cached forever.
    pub root: bool,
}

// SAFETY: blocks are raw memory regions; synchronization of the *contents*
// is the responsibility of the stream discipline (see crate::device). The
// handle itself is freely sendable.
unsafe impl Send for Block {}
unsafe impl Sync for Block {}

/// The allocator interface used by tensor storage.
pub trait Allocator: Send + Sync {
    /// Allocate at least `bytes` bytes for use on `stream`.
    fn allocate(&self, bytes: usize, stream: StreamId) -> Block;
    /// Return a block. Depending on the implementation this may cache it,
    /// hand it back to the driver, or defer reclamation.
    fn deallocate(&self, block: Block);
    /// Statistics snapshot.
    fn stats(&self) -> AllocStats;
    /// Drop all cached blocks back to the driver (like
    /// `torch.cuda.empty_cache()`). Pass-through allocators are a no-op.
    fn empty_cache(&self) {}
    /// Reset the statistics counters (not the cache).
    fn reset_stats(&self);
}

/// Counters shared by all allocator implementations; the Figure 2 bench
/// reads these to report driver-call counts per training iteration.
#[derive(Default, Debug)]
pub struct AllocCounters {
    /// Requests served from the cache.
    pub cache_hits: AtomicU64,
    /// Requests that had to call the driver.
    pub driver_allocs: AtomicU64,
    /// Blocks returned to the driver (naive mode or `empty_cache`).
    pub driver_frees: AtomicU64,
    /// Total nanoseconds spent inside driver calls (the "stall" time that
    /// dominates iteration 1 in Figure 2).
    pub driver_ns: AtomicU64,
    /// Bytes currently held by user tensors.
    pub in_use_bytes: AtomicU64,
    /// Peak of `in_use_bytes`.
    pub peak_in_use_bytes: AtomicU64,
    /// Bytes parked in the cache (0 for pass-through allocators).
    pub cached_bytes: AtomicU64,
    /// Cumulative bytes handed out (cache hits *and* driver allocs) — the
    /// per-iteration "bytes allocated" column of BENCH_ops.json.
    pub allocated_bytes_total: AtomicU64,
}

impl AllocCounters {
    pub(crate) fn on_alloc(&self, bytes: usize) {
        let now = self.in_use_bytes.fetch_add(bytes as u64, Ordering::Relaxed) + bytes as u64;
        self.peak_in_use_bytes.fetch_max(now, Ordering::Relaxed);
        self.allocated_bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
    }
    pub(crate) fn on_free(&self, bytes: usize) {
        self.in_use_bytes.fetch_sub(bytes as u64, Ordering::Relaxed);
    }
    pub(crate) fn snapshot(&self) -> AllocStats {
        AllocStats {
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            driver_allocs: self.driver_allocs.load(Ordering::Relaxed),
            driver_frees: self.driver_frees.load(Ordering::Relaxed),
            driver_ns: self.driver_ns.load(Ordering::Relaxed),
            in_use_bytes: self.in_use_bytes.load(Ordering::Relaxed),
            peak_in_use_bytes: self.peak_in_use_bytes.load(Ordering::Relaxed),
            cached_bytes: self.cached_bytes.load(Ordering::Relaxed),
            allocated_bytes_total: self.allocated_bytes_total.load(Ordering::Relaxed),
        }
    }
    pub(crate) fn reset(&self) {
        self.cache_hits.store(0, Ordering::Relaxed);
        self.driver_allocs.store(0, Ordering::Relaxed);
        self.driver_frees.store(0, Ordering::Relaxed);
        self.driver_ns.store(0, Ordering::Relaxed);
        self.allocated_bytes_total.store(0, Ordering::Relaxed);
        self.peak_in_use_bytes
            .store(self.in_use_bytes.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// A point-in-time view of an allocator's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    pub cache_hits: u64,
    pub driver_allocs: u64,
    pub driver_frees: u64,
    pub driver_ns: u64,
    pub in_use_bytes: u64,
    pub peak_in_use_bytes: u64,
    pub cached_bytes: u64,
    pub allocated_bytes_total: u64,
}

impl AllocStats {
    /// Difference of two snapshots (for per-iteration deltas in Fig. 2).
    pub fn delta(&self, earlier: &AllocStats) -> AllocStats {
        AllocStats {
            cache_hits: self.cache_hits - earlier.cache_hits,
            driver_allocs: self.driver_allocs - earlier.driver_allocs,
            driver_frees: self.driver_frees - earlier.driver_frees,
            driver_ns: self.driver_ns - earlier.driver_ns,
            in_use_bytes: self.in_use_bytes,
            peak_in_use_bytes: self.peak_in_use_bytes,
            cached_bytes: self.cached_bytes,
            allocated_bytes_total: self.allocated_bytes_total - earlier.allocated_bytes_total,
        }
    }

    /// Fraction of allocation requests served from the cache (1.0 when no
    /// requests happened — steady state with full output-reuse).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.driver_allocs;
        if total == 0 {
            1.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Streams must be drainable for the simulated `cudaFree` blocking
/// semantics; `crate::device::Streams` implements this. A no-op impl is
/// provided for host-only tests.
pub trait DrainAll: Send + Sync {
    /// Block the calling thread until all queued device work completes.
    fn drain_all(&self);
}

/// No-op drainer for tests / host memory.
pub struct NoDrain;
impl DrainAll for NoDrain {
    fn drain_all(&self) {}
}

/// Convenience: the allocator type used everywhere (`Arc`-shared trait object).
pub type ArcAllocator = Arc<dyn Allocator>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_is_multiple_of_512() {
        for req in [0usize, 1, 4, 511, 512, 513, 1000, 4096, 123_457] {
            let r = round_up(req);
            assert_eq!(r % ROUND_BYTES, 0, "req={req}");
            assert!(r >= req.max(1));
            assert!(r < req + ROUND_BYTES + 1);
        }
    }

    #[test]
    fn round_up_zero_gives_one_granule() {
        assert_eq!(round_up(0), ROUND_BYTES);
    }

    #[test]
    fn counters_track_peak() {
        let c = AllocCounters::default();
        c.on_alloc(1000);
        c.on_alloc(2000);
        c.on_free(1000);
        c.on_alloc(500);
        let s = c.snapshot();
        assert_eq!(s.in_use_bytes, 2500);
        assert_eq!(s.peak_in_use_bytes, 3000);
    }

    #[test]
    fn stats_delta() {
        let a = AllocStats { cache_hits: 10, driver_allocs: 5, ..Default::default() };
        let b = AllocStats { cache_hits: 25, driver_allocs: 6, ..Default::default() };
        let d = b.delta(&a);
        assert_eq!(d.cache_hits, 15);
        assert_eq!(d.driver_allocs, 1);
    }
}
