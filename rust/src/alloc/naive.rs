//! Pass-through allocator: every allocation and free is a driver call.
//!
//! This is the behaviour of a framework *without* the paper's caching
//! allocator — what Figure 2's first iteration looks like all the time.
//! Used as the baseline in `fig2_allocator` and as part of the
//! Chainer-stand-in "NaiveEager" execution mode in Table 1.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::driver::MemDriver;
use super::{round_up, AllocCounters, AllocStats, Allocator, Block, StreamId};

/// Allocator that forwards every request straight to the driver.
pub struct NaiveAllocator {
    driver: Arc<dyn MemDriver>,
    counters: AllocCounters,
}

impl NaiveAllocator {
    pub fn new(driver: Arc<dyn MemDriver>) -> Self {
        NaiveAllocator { driver, counters: AllocCounters::default() }
    }

    pub fn driver(&self) -> &Arc<dyn MemDriver> {
        &self.driver
    }
}

impl Allocator for NaiveAllocator {
    fn allocate(&self, bytes: usize, stream: StreamId) -> Block {
        let size = round_up(bytes);
        let t0 = Instant::now();
        let ptr = self.driver.alloc(size);
        self.counters
            .driver_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.counters.driver_allocs.fetch_add(1, Ordering::Relaxed);
        self.counters.on_alloc(size);
        Block { ptr, size, requested: bytes, stream, root: true }
    }

    fn deallocate(&self, block: Block) {
        self.counters.on_free(block.size);
        let t0 = Instant::now();
        self.driver.free(block.ptr, block.size);
        self.counters
            .driver_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.counters.driver_frees.fetch_add(1, Ordering::Relaxed);
    }

    fn stats(&self) -> AllocStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::driver::HostMem;

    #[test]
    fn every_cycle_hits_driver() {
        let driver = Arc::new(HostMem::default());
        let a = NaiveAllocator::new(driver.clone());
        for _ in 0..5 {
            let b = a.allocate(1000, StreamId::DEFAULT);
            a.deallocate(b);
        }
        assert_eq!(driver.alloc_calls(), 5);
        assert_eq!(driver.free_calls(), 5);
        let s = a.stats();
        assert_eq!(s.driver_allocs, 5);
        assert_eq!(s.driver_frees, 5);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.in_use_bytes, 0);
    }

    #[test]
    fn rounds_like_the_caching_allocator() {
        let a = NaiveAllocator::new(Arc::new(HostMem::default()));
        let b = a.allocate(700, StreamId::DEFAULT);
        assert_eq!(b.size, 1024);
        a.deallocate(b);
    }
}
