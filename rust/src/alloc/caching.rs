//! The caching allocator (paper §5.3).
//!
//! "PyTorch implements a custom allocator which incrementally builds up a
//! cache of CUDA memory and reassigns it to later allocations without
//! further use of CUDA APIs."
//!
//! Implementation: a best-fit free list per stream, keyed by rounded block
//! size in a `BTreeMap`. Requests round up to 512 B ([`crate::alloc::round_up`]);
//! a cached block up to 2× the request (or within one granule) is reused
//! directly, a much larger one is split. Blocks freed on one stream are
//! cached in *that stream's* pool only — the one-pool-per-stream design the
//! paper argues is safe because streams serialize execution. Requesting a
//! block on a different stream than it was freed on therefore never reuses
//! the foreign pool; cross-stream movement only happens through
//! `empty_cache` + driver.

use std::collections::BTreeMap;
use std::ptr::NonNull;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::driver::MemDriver;
use super::{round_up, AllocCounters, AllocStats, Allocator, Block, StreamId, ROUND_BYTES};

/// Reuse a cached block without splitting if it is at most this factor
/// larger than the request (beyond one granule).
const SPLIT_THRESHOLD_FACTOR: usize = 2;

/// Smallest remainder worth keeping after a split.
const MIN_SPLIT_REMAINDER: usize = ROUND_BYTES;

#[derive(Debug)]
struct CachedRegion {
    ptr: NonNull<u8>,
    size: usize,
    /// Size the driver allocated; only regions with `driver_size == size`
    /// (i.e. never split) can be returned to the driver on `empty_cache`.
    driver_root: Option<usize>,
}

// SAFETY: raw region handles; contents synchronized by stream discipline.
unsafe impl Send for CachedRegion {}

#[derive(Default)]
struct StreamPool {
    /// size -> stack of free regions of exactly that size.
    free: BTreeMap<usize, Vec<CachedRegion>>,
    cached_bytes: usize,
}

impl StreamPool {
    /// Best-fit lookup: smallest cached region with size >= want.
    fn take(&mut self, want: usize) -> Option<CachedRegion> {
        let key = *self.free.range(want..).next()?.0;
        let list = self.free.get_mut(&key).expect("key exists");
        let region = list.pop().expect("non-empty list");
        if list.is_empty() {
            self.free.remove(&key);
        }
        self.cached_bytes -= region.size;
        Some(region)
    }

    fn put(&mut self, region: CachedRegion) {
        self.cached_bytes += region.size;
        self.free.entry(region.size).or_default().push(region);
    }
}

/// The caching allocator. One instance per device; shared via `Arc`.
pub struct CachingAllocator {
    driver: Arc<dyn MemDriver>,
    pools: Mutex<std::collections::HashMap<StreamId, StreamPool>>,
    counters: AllocCounters,
}

impl CachingAllocator {
    pub fn new(driver: Arc<dyn MemDriver>) -> Self {
        CachingAllocator {
            driver,
            pools: Mutex::new(Default::default()),
            counters: AllocCounters::default(),
        }
    }

    /// Access to the underlying driver (used by Fig. 2 to read call counts).
    pub fn driver(&self) -> &Arc<dyn MemDriver> {
        &self.driver
    }

    fn driver_alloc(&self, size: usize) -> NonNull<u8> {
        let t0 = Instant::now();
        let p = self.driver.alloc(size);
        self.counters
            .driver_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.counters.driver_allocs.fetch_add(1, Ordering::Relaxed);
        p
    }
}

impl Allocator for CachingAllocator {
    fn allocate(&self, bytes: usize, stream: StreamId) -> Block {
        let want = round_up(bytes);
        let mut pools = self.pools.lock().unwrap();
        let pool = pools.entry(stream).or_default();

        if let Some(mut region) = pool.take(want) {
            // Cache hit. Split if the region is much larger than needed so
            // a single huge block doesn't get pinned under small tensors.
            if region.size > want * SPLIT_THRESHOLD_FACTOR
                && region.size - want >= MIN_SPLIT_REMAINDER
            {
                // SAFETY: want < region.size, both within the region.
                let rest_ptr = unsafe { NonNull::new_unchecked(region.ptr.as_ptr().add(want)) };
                let rest = CachedRegion { ptr: rest_ptr, size: region.size - want, driver_root: None };
                pool.put(rest);
                region.size = want;
                region.driver_root = None;
            }
            self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
            self.counters
                .cached_bytes
                .store(pool.cached_bytes as u64, Ordering::Relaxed);
            self.counters.on_alloc(region.size);
            let root = matches!(region.driver_root, Some(sz) if sz == region.size);
            return Block { ptr: region.ptr, size: region.size, requested: bytes, stream, root };
        }
        drop(pools);

        // Cache miss: go to the driver.
        let ptr = self.driver_alloc(want);
        self.counters.on_alloc(want);
        Block { ptr, size: want, requested: bytes, stream, root: true }
    }

    fn deallocate(&self, block: Block) {
        self.counters.on_free(block.size);
        let mut pools = self.pools.lock().unwrap();
        let pool = pools.entry(block.stream).or_default();
        pool.put(CachedRegion {
            ptr: block.ptr,
            size: block.size,
            driver_root: if block.root { Some(block.size) } else { None },
        });
        self.counters
            .cached_bytes
            .store(pool.cached_bytes as u64, Ordering::Relaxed);
    }

    fn stats(&self) -> AllocStats {
        let mut s = self.counters.snapshot();
        let pools = self.pools.lock().unwrap();
        s.cached_bytes = pools.values().map(|p| p.cached_bytes as u64).sum();
        s
    }

    fn empty_cache(&self) {
        let mut pools = self.pools.lock().unwrap();
        for pool in pools.values_mut() {
            for (_, regions) in std::mem::take(&mut pool.free) {
                for r in regions {
                    // Split fragments cannot be individually returned to the
                    // driver (their base pointer is interior); they are
                    // intentionally leaked until process exit, matching the
                    // paper's "almost never returns memory" posture. Root
                    // regions go back to the driver.
                    if let Some(root) = r.driver_root {
                        debug_assert_eq!(root, r.size);
                        self.driver.free(r.ptr, r.size);
                        self.counters.driver_frees.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            pool.cached_bytes = 0;
        }
        self.counters.cached_bytes.store(0, Ordering::Relaxed);
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

impl Drop for CachingAllocator {
    fn drop(&mut self) {
        self.empty_cache();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::driver::HostMem;

    fn mk() -> CachingAllocator {
        CachingAllocator::new(Arc::new(HostMem::default()))
    }

    #[test]
    fn first_alloc_hits_driver_second_hits_cache() {
        let a = mk();
        let s = StreamId::DEFAULT;
        let b1 = a.allocate(1000, s);
        assert_eq!(a.stats().driver_allocs, 1);
        a.deallocate(b1);
        let b2 = a.allocate(900, s); // rounds to same 1024 granule class
        assert_eq!(a.stats().driver_allocs, 1, "should reuse cache");
        assert_eq!(a.stats().cache_hits, 1);
        a.deallocate(b2);
    }

    #[test]
    fn sizes_round_to_512() {
        let a = mk();
        let b = a.allocate(1, StreamId::DEFAULT);
        assert_eq!(b.size, 512);
        assert_eq!(b.requested, 1);
        a.deallocate(b);
    }

    #[test]
    fn one_pool_per_stream_no_cross_reuse() {
        let a = mk();
        let b1 = a.allocate(2048, StreamId(0));
        let p1 = b1.ptr;
        a.deallocate(b1);
        // Same size on another stream must NOT reuse stream 0's block.
        let b2 = a.allocate(2048, StreamId(1));
        assert_ne!(b2.ptr, p1, "cross-stream reuse violates §5.3");
        assert_eq!(a.stats().driver_allocs, 2);
        a.deallocate(b2);
        // But stream 0 reuses its own.
        let b3 = a.allocate(2048, StreamId(0));
        assert_eq!(b3.ptr, p1);
        a.deallocate(b3);
    }

    #[test]
    fn large_block_is_split() {
        let a = mk();
        let big = a.allocate(1 << 20, StreamId::DEFAULT);
        let base = big.ptr;
        a.deallocate(big);
        let small = a.allocate(4096, StreamId::DEFAULT);
        assert_eq!(small.ptr, base, "split should serve from region base");
        assert_eq!(small.size, 4096);
        // Remainder still cached: another medium alloc is a cache hit.
        let med = a.allocate(1 << 19, StreamId::DEFAULT);
        assert_eq!(a.stats().driver_allocs, 1, "remainder should satisfy this");
        a.deallocate(small);
        a.deallocate(med);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let a = mk();
        let s = StreamId::DEFAULT;
        let b1 = a.allocate(512, s);
        let b2 = a.allocate(4096, s);
        let (p_small, p_big) = (b1.ptr, b2.ptr);
        a.deallocate(b2);
        a.deallocate(b1);
        let c = a.allocate(512, s);
        assert_eq!(c.ptr, p_small, "best fit should pick the 512B block");
        let d = a.allocate(4096, s);
        assert_eq!(d.ptr, p_big);
        a.deallocate(c);
        a.deallocate(d);
    }

    #[test]
    fn empty_cache_returns_root_blocks() {
        let driver = Arc::new(HostMem::default());
        let a = CachingAllocator::new(driver.clone());
        let b = a.allocate(8192, StreamId::DEFAULT);
        a.deallocate(b);
        assert_eq!(driver.free_calls(), 0);
        a.empty_cache();
        assert_eq!(driver.free_calls(), 1);
        assert_eq!(a.stats().cached_bytes, 0);
    }

    #[test]
    fn in_use_accounting() {
        let a = mk();
        let b1 = a.allocate(1000, StreamId::DEFAULT);
        let b2 = a.allocate(2000, StreamId::DEFAULT);
        let s = a.stats();
        assert_eq!(s.in_use_bytes, (round_up(1000) + round_up(2000)) as u64);
        a.deallocate(b1);
        a.deallocate(b2);
        assert_eq!(a.stats().in_use_bytes, 0);
        assert!(a.stats().cached_bytes > 0);
    }

    #[test]
    fn steady_state_has_zero_driver_calls() {
        // The Figure 2 claim in miniature: a repeating alloc/free pattern
        // stops calling the driver after the first "iteration".
        let a = mk();
        let s = StreamId::DEFAULT;
        let pattern = [3000usize, 1500, 6000, 512, 3000];
        let mut iter_driver_calls = vec![];
        for _ in 0..4 {
            let before = a.stats().driver_allocs;
            let blocks: Vec<Block> = pattern.iter().map(|&n| a.allocate(n, s)).collect();
            for b in blocks {
                a.deallocate(b);
            }
            iter_driver_calls.push(a.stats().driver_allocs - before);
        }
        assert!(iter_driver_calls[0] > 0);
        assert_eq!(iter_driver_calls[2], 0, "{iter_driver_calls:?}");
        assert_eq!(iter_driver_calls[3], 0, "{iter_driver_calls:?}");
    }
}
