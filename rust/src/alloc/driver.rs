//! Raw memory drivers: the layer the caching allocator sits on top of.
//!
//! [`HostMem`] is a plain aligned system allocator. [`SimDeviceMem`] is the
//! GPU-driver substitute (DESIGN.md §2): its `free` blocks the calling
//! thread until every queued stream operation has drained, reproducing the
//! `cudaFree` behaviour that makes naive per-op allocation so expensive in
//! Figure 2, and its `alloc` charges a fixed driver-call latency.

use std::alloc::Layout;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::DrainAll;

/// Alignment for all tensor memory (cache-line / SIMD friendly).
pub const ALIGN: usize = 64;

/// A raw memory driver: allocates and frees whole regions.
pub trait MemDriver: Send + Sync {
    /// Allocate `bytes` bytes aligned to [`ALIGN`].
    fn alloc(&self, bytes: usize) -> NonNull<u8>;
    /// Free a region previously returned by `alloc`.
    fn free(&self, ptr: NonNull<u8>, bytes: usize);
    /// Number of driver allocations performed.
    fn alloc_calls(&self) -> u64;
    /// Number of driver frees performed.
    fn free_calls(&self) -> u64;
}

fn sys_alloc(bytes: usize) -> NonNull<u8> {
    let layout = Layout::from_size_align(bytes.max(1), ALIGN).expect("bad layout");
    // SAFETY: layout has non-zero size.
    let p = unsafe { std::alloc::alloc(layout) };
    NonNull::new(p).unwrap_or_else(|| std::alloc::handle_alloc_error(layout))
}

fn sys_free(ptr: NonNull<u8>, bytes: usize) {
    let layout = Layout::from_size_align(bytes.max(1), ALIGN).expect("bad layout");
    // SAFETY: ptr was allocated with this layout by `sys_alloc`.
    unsafe { std::alloc::dealloc(ptr.as_ptr(), layout) };
}

/// Host memory: thin wrapper over the system allocator. The paper notes
/// PyTorch "can rely on optimized libraries to handle this task on CPU".
#[derive(Default)]
pub struct HostMem {
    allocs: AtomicU64,
    frees: AtomicU64,
}

impl MemDriver for HostMem {
    fn alloc(&self, bytes: usize) -> NonNull<u8> {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        sys_alloc(bytes)
    }
    fn free(&self, ptr: NonNull<u8>, bytes: usize) {
        self.frees.fetch_add(1, Ordering::Relaxed);
        sys_free(ptr, bytes);
    }
    fn alloc_calls(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }
    fn free_calls(&self) -> u64 {
        self.frees.load(Ordering::Relaxed)
    }
}

/// Tuning knobs for the simulated device driver. Defaults are calibrated to
/// the same order of magnitude as real CUDA driver calls (tens to hundreds
/// of µs for `cudaMalloc` under allocation pressure; `cudaFree`
/// additionally synchronizes the device, which is its dominant cost).
#[derive(Clone, Copy, Debug)]
pub struct SimDriverConfig {
    /// Busy-wait latency charged per `alloc` call, nanoseconds.
    pub malloc_latency_ns: u64,
    /// Busy-wait latency charged per `free` call, nanoseconds (on top of
    /// the drain).
    pub free_latency_ns: u64,
    /// Whether `free` blocks until all queued stream work completes — the
    /// defining `cudaFree` behaviour of §5.3.
    pub free_synchronizes: bool,
}

impl Default for SimDriverConfig {
    fn default() -> Self {
        SimDriverConfig {
            malloc_latency_ns: 100_000,
            free_latency_ns: 50_000,
            free_synchronizes: true,
        }
    }
}

/// Simulated accelerator memory driver (the `cudaMalloc`/`cudaFree` stand-in).
pub struct SimDeviceMem {
    cfg: SimDriverConfig,
    drainer: Arc<dyn DrainAll>,
    allocs: AtomicU64,
    frees: AtomicU64,
    /// Total ns the host spent blocked inside this driver — the Figure 2
    /// "stall" metric.
    pub stall_ns: AtomicU64,
}

impl SimDeviceMem {
    pub fn new(cfg: SimDriverConfig, drainer: Arc<dyn DrainAll>) -> Self {
        SimDeviceMem { cfg, drainer, allocs: AtomicU64::new(0), frees: AtomicU64::new(0), stall_ns: AtomicU64::new(0) }
    }

    fn spin(ns: u64) {
        if ns == 0 {
            return;
        }
        let t0 = Instant::now();
        while (t0.elapsed().as_nanos() as u64) < ns {
            std::hint::spin_loop();
        }
    }
}

impl MemDriver for SimDeviceMem {
    fn alloc(&self, bytes: usize) -> NonNull<u8> {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        Self::spin(self.cfg.malloc_latency_ns);
        let p = sys_alloc(bytes);
        self.stall_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        p
    }

    fn free(&self, ptr: NonNull<u8>, bytes: usize) {
        self.frees.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        if self.cfg.free_synchronizes {
            // cudaFree "may block its caller until all previously queued
            // work on all GPUs completes" (§5.3).
            self.drainer.drain_all();
        }
        Self::spin(self.cfg.free_latency_ns);
        sys_free(ptr, bytes);
        self.stall_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    fn alloc_calls(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }
    fn free_calls(&self) -> u64 {
        self.frees.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::NoDrain;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn host_mem_roundtrip() {
        let m = HostMem::default();
        let p = m.alloc(4096);
        assert_eq!(p.as_ptr() as usize % ALIGN, 0);
        // Write and read back through the pointer.
        unsafe {
            std::ptr::write_bytes(p.as_ptr(), 0xAB, 4096);
            assert_eq!(*p.as_ptr().add(100), 0xAB);
        }
        m.free(p, 4096);
        assert_eq!(m.alloc_calls(), 1);
        assert_eq!(m.free_calls(), 1);
    }

    #[test]
    fn sim_device_charges_latency() {
        let cfg = SimDriverConfig { malloc_latency_ns: 50_000, free_latency_ns: 0, free_synchronizes: false };
        let m = SimDeviceMem::new(cfg, Arc::new(NoDrain));
        let t0 = Instant::now();
        let p = m.alloc(1024);
        let dt = t0.elapsed().as_nanos() as u64;
        m.free(p, 1024);
        assert!(dt >= 50_000, "alloc returned too quickly: {dt}ns");
        assert!(m.stall_ns.load(Ordering::Relaxed) >= 50_000);
    }

    struct FlagDrain(AtomicBool);
    impl DrainAll for FlagDrain {
        fn drain_all(&self) {
            self.0.store(true, Ordering::SeqCst);
        }
    }

    #[test]
    fn sim_free_synchronizes_streams() {
        let drain = Arc::new(FlagDrain(AtomicBool::new(false)));
        let cfg = SimDriverConfig { malloc_latency_ns: 0, free_latency_ns: 0, free_synchronizes: true };
        let m = SimDeviceMem::new(cfg, drain.clone());
        let p = m.alloc(64);
        assert!(!drain.0.load(Ordering::SeqCst));
        m.free(p, 64);
        assert!(drain.0.load(Ordering::SeqCst), "free must drain streams");
    }

    #[test]
    fn sim_free_no_sync_when_disabled() {
        let drain = Arc::new(FlagDrain(AtomicBool::new(false)));
        let cfg = SimDriverConfig { malloc_latency_ns: 0, free_latency_ns: 0, free_synchronizes: false };
        let m = SimDeviceMem::new(cfg, drain.clone());
        let p = m.alloc(64);
        m.free(p, 64);
        assert!(!drain.0.load(Ordering::SeqCst));
    }

    #[test]
    fn zero_byte_alloc_is_valid() {
        let m = HostMem::default();
        let p = m.alloc(0);
        m.free(p, 0);
    }
}
