//! Live serving telemetry: lock-free latency histograms and request
//! counters (ROADMAP Open item 2's "export the profiler's spans as live
//! metrics instead of post-hoc JSON").
//!
//! Every counter here is a relaxed atomic and every histogram bucket is
//! one `fetch_add` — recording a latency never takes a lock, so client
//! threads, the batcher, and the inference workers all write concurrently
//! without serializing the hot path (the post-hoc profiler, by contrast,
//! buffers full spans; see [`crate::profiler`] — [`ServeStats::op_totals`]
//! bridges the two by folding the profiler's per-op spans, recorded on
//! any worker thread, into one per-op table).
//!
//! Latencies land in [`Histogram`]s with power-of-two bucket edges:
//! `record(ns)` increments the bucket holding `ns`, and quantiles read
//! back the **upper edge** of the bucket where the cumulative count
//! crosses the rank — a deterministic ≤2× overestimate, which is the
//! right trade for a lock-free fixed-size structure (the bench headline
//! is p50/p99 *trajectory*, not nanosecond exactness).
//!
//! Two scopes, like the dispatcher's counters: every [`Server`]
//! (`crate::serve::Server`) owns an instance [`Metrics`] snapshotted by
//! `Server::stats()`, and the same events also feed a process-global
//! instance read by [`serve_stats`] (the `capture_stats()` analogue).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use once_cell::sync::Lazy;

/// Number of power-of-two latency buckets: bucket `i` holds durations in
/// `[2^(i-1), 2^i)` ns (bucket 0 holds 0–1 ns), so 48 buckets cover up to
/// ~78 hours — every latency a server could plausibly observe.
const N_BUCKETS: usize = 48;

/// A lock-free log2 latency histogram. `record` is one relaxed
/// `fetch_add` per counter; snapshots fold the buckets in order.
pub struct Histogram {
    count: AtomicU64,
    sum_ns: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn bucket_of(ns: u64) -> usize {
        // 0 → 0; otherwise 1 + floor(log2(ns)), capped at the last bucket.
        ((64 - ns.leading_zeros()) as usize).min(N_BUCKETS - 1)
    }

    /// Record one duration. Lock-free; safe from any thread.
    pub fn record(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// The upper bucket edge (in ns) at quantile `q` in `[0, 1]`: the
    /// smallest power-of-two edge below which at least `q` of the
    /// recorded durations fall. 0 when nothing was recorded.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        // ceil(q * total), clamped to [1, total]: the rank to reach.
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return if i == 0 { 1 } else { 1u64 << i };
            }
        }
        1u64 << (N_BUCKETS - 1)
    }

    /// Exact mean of recorded durations (sum and count are exact; only
    /// the quantiles are bucketed). 0 when nothing was recorded.
    pub fn mean_ns(&self) -> u64 {
        let n = self.count();
        if n == 0 {
            0
        } else {
            self.sum_ns.load(Ordering::Relaxed) / n
        }
    }

    /// Fold into the plain-data snapshot used by [`ServeStats`].
    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count(),
            mean_ns: self.mean_ns(),
            p50_ns: self.quantile_ns(0.50),
            p99_ns: self.quantile_ns(0.99),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Plain-data view of one [`Histogram`] at a point in time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencySnapshot {
    pub count: u64,
    /// Exact mean (ns).
    pub mean_ns: u64,
    /// Upper bucket edge at p50 (ns) — a ≤2× overestimate by design.
    pub p50_ns: u64,
    /// Upper bucket edge at p99 (ns).
    pub p99_ns: u64,
}

/// The full serving counter set. One instance per [`crate::serve::Server`]
/// plus one process-global instance behind [`serve_stats`]; all writes are
/// relaxed atomics.
pub struct Metrics {
    /// Requests accepted into the queue (`submit` returned a `Pending`).
    pub requests: AtomicU64,
    /// Requests answered with an output tensor.
    pub completed: AtomicU64,
    /// Requests answered with a typed error (handler panic, shutdown).
    pub failed: AtomicU64,
    /// Requests refused at `submit` (shape mismatch, closed server).
    pub rejected: AtomicU64,
    /// Deliveries whose `Pending` had already been dropped — the client
    /// walked away; the batcher delivered into the slot and moved on.
    pub abandoned: AtomicU64,
    /// Batches dispatched to the worker pool.
    pub batches: AtomicU64,
    /// Real (non-padding) requests summed over dispatched batches;
    /// `batched_requests / batches` is the mean batch size — the
    /// "coalescing actually happens" number.
    pub batched_requests: AtomicU64,
    /// Padding rows added to round batches up to their bucket shape.
    pub padded_rows: AtomicU64,
    /// Batches whose handler panicked (before isolation retry).
    pub handler_panics: AtomicU64,
    /// Guard-cache hits summed over the workers' capture sessions.
    pub guard_hits: AtomicU64,
    /// Guard-cache misses (traced eager runs) summed over sessions.
    pub guard_misses: AtomicU64,
    /// Graphs captured and compiled, summed over sessions.
    pub graphs_captured: AtomicU64,
    /// Submit → batch-closed, per request.
    pub queue: Histogram,
    /// Batch-dispatch → output ready, per batch.
    pub compute: Histogram,
    /// Submit → response delivered, per request.
    pub total: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            abandoned: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            handler_panics: AtomicU64::new(0),
            guard_hits: AtomicU64::new(0),
            guard_misses: AtomicU64::new(0),
            graphs_captured: AtomicU64::new(0),
            queue: Histogram::new(),
            compute: Histogram::new(),
            total: Histogram::new(),
        }
    }

    /// Snapshot every counter into plain data.
    pub fn snapshot(&self) -> ServeStats {
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            abandoned: self.abandoned.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            handler_panics: self.handler_panics.load(Ordering::Relaxed),
            guard_hits: self.guard_hits.load(Ordering::Relaxed),
            guard_misses: self.guard_misses.load(Ordering::Relaxed),
            graphs_captured: self.graphs_captured.load(Ordering::Relaxed),
            queue: self.queue.snapshot(),
            compute: self.compute.snapshot(),
            total: self.total.snapshot(),
        }
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

/// Point-in-time view of a server's (or the process's) serving counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub abandoned: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub padded_rows: u64,
    pub handler_panics: u64,
    pub guard_hits: u64,
    pub guard_misses: u64,
    pub graphs_captured: u64,
    pub queue: LatencySnapshot,
    pub compute: LatencySnapshot,
    pub total: LatencySnapshot,
}

impl ServeStats {
    /// Mean real requests per dispatched batch — > 1 means dynamic
    /// batching is actually coalescing concurrent traffic.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Counter deltas since an earlier snapshot (histograms are deltas of
    /// count/mean only in spirit: quantiles are re-read, counts subtract).
    pub fn delta(&self, earlier: &ServeStats) -> ServeStats {
        ServeStats {
            requests: self.requests - earlier.requests,
            completed: self.completed - earlier.completed,
            failed: self.failed - earlier.failed,
            rejected: self.rejected - earlier.rejected,
            abandoned: self.abandoned - earlier.abandoned,
            batches: self.batches - earlier.batches,
            batched_requests: self.batched_requests - earlier.batched_requests,
            padded_rows: self.padded_rows - earlier.padded_rows,
            handler_panics: self.handler_panics - earlier.handler_panics,
            guard_hits: self.guard_hits - earlier.guard_hits,
            guard_misses: self.guard_misses - earlier.guard_misses,
            graphs_captured: self.graphs_captured - earlier.graphs_captured,
            queue: self.queue,
            compute: self.compute,
            total: self.total,
        }
    }

    /// The profiler bridge: fold currently recorded profiler spans —
    /// including spans recorded on serve worker threads (the profiler
    /// merges its per-thread buffers; see
    /// [`crate::profiler::op_totals`]) — into one per-op `{count,
    /// total_ns}` table. Empty when the profiler is not recording.
    pub fn op_totals() -> BTreeMap<String, crate::profiler::OpTotal> {
        crate::profiler::op_totals(&crate::profiler::snapshot())
    }
}

/// The process-global metrics instance behind [`serve_stats`].
static GLOBAL: Lazy<Metrics> = Lazy::new(Metrics::new);

/// The global instance: every server records into its own [`Metrics`]
/// *and* this one.
pub(crate) fn global() -> &'static Metrics {
    &GLOBAL
}

/// Cumulative serving counters for the whole process since start — the
/// [`crate::dispatch::capture_stats`] analogue for the serving layer.
pub fn serve_stats() -> ServeStats {
    GLOBAL.snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_log2_and_reads_upper_edges() {
        let h = Histogram::new();
        for ns in [3u64, 3, 3, 3, 3, 3, 3, 3, 3, 1000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 10);
        // p50 over 9×3ns + 1×1000ns: rank 5 lands in the [2,4) bucket.
        assert_eq!(h.quantile_ns(0.50), 4);
        // p99: rank 10 is the 1000 ns outlier; its bucket's edge is 1024.
        assert_eq!(h.quantile_ns(0.99), 1024);
        assert_eq!(h.mean_ns(), (9 * 3 + 1000) / 10);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0);
        assert_eq!(h.snapshot(), LatencySnapshot::default());
    }

    #[test]
    fn zero_and_huge_durations_stay_in_range() {
        let h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile_ns(0.01), 1, "0 ns lands in the first bucket");
        assert_eq!(h.quantile_ns(1.0), 1u64 << (N_BUCKETS - 1), "clamped to the last bucket");
    }

    #[test]
    fn mean_batch_size_needs_batches() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().mean_batch_size(), 0.0);
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.snapshot().mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn stats_delta_subtracts_counters() {
        let m = Metrics::new();
        m.requests.store(5, Ordering::Relaxed);
        let s0 = m.snapshot();
        m.requests.store(9, Ordering::Relaxed);
        m.completed.store(7, Ordering::Relaxed);
        let d = m.snapshot().delta(&s0);
        assert_eq!(d.requests, 4);
        assert_eq!(d.completed, 7);
    }

    #[test]
    fn concurrent_recording_is_lossless() {
        let h = std::sync::Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000, "every concurrent record must land");
    }
}
