//! `serve` — inference serving with dynamic batching (ROADMAP Open
//! item 2: the north star says millions of users; this is the subsystem
//! that answers a request).
//!
//! The shape is the DataLoader turned inside out: the loader coalesces a
//! *known* index stream into batches ahead of a consumer, while a server
//! coalesces an *unknown* request stream into batches behind an SLO.
//! Many client threads [`ClientHandle::submit`] single-sample tensors
//! into one bounded `sync_channel` (backpressure, like the loader's
//! prefetch queue); a dedicated **batcher** thread drains it, closing
//! each batch at `max_batch` requests or `max_delay` after the batch's
//! first arrival, whichever comes first; a **worker pool** stacks each
//! batch (padding the row count up to a power-of-two bucket so the
//! [`crate::dispatch::GraphCapture`] guard cache replays a compiled
//! graph instead of recapturing per batch size), runs the model under
//! [`crate::autograd::no_grad`], and scatters per-request output rows
//! back through oneshot [`Pending`] slots.
//!
//! Contracts, pinned by `tests/serve_parity.rs` / `tests/serve_chaos.rs`:
//! * **Batching is invisible**: a request's output is bitwise identical
//!   whether it was served alone or coalesced with seven strangers, at
//!   every thread count and SIMD mode. This rests on the same invariant
//!   the GEMM suite pins — row blocking never changes a row's bits.
//! * **Failure is loud and scoped**: a panicking handler fails *that
//!   request* with a typed [`ServeError::HandlerPanic`] (co-batched
//!   requests are re-run alone — poison isolation); an abandoned client
//!   (dropped [`Pending`]) never wedges the batcher; [`Server::shutdown`]
//!   joins **bounded** and names any wedged in-flight request by seq.
//! * **Telemetry is live**: every stage records into lock-free
//!   [`metrics::Histogram`] counters readable mid-flight via
//!   [`Server::stats`] / [`serve_stats`] — not a post-hoc JSON dump.
//!
//! ```no_run
//! # // no_run: doc-test binaries skip the multi-thread setup; the same
//! # // flow is executed end-to-end in tests/serve_parity.rs.
//! use torsk::serve::{ServeConfig, Server};
//! use torsk::nn::Linear;
//!
//! let cfg = ServeConfig::new(&[16]).with_workers(2);
//! let server = Server::new(|| Box::new(Linear::new(16, 4)), cfg);
//! let handle = server.handle();
//! let pending = handle.submit(torsk::Tensor::randn(&[16])).unwrap();
//! let output = pending.wait().unwrap(); // shape [4]
//! # let _ = output;
//! let report = server.shutdown();
//! assert!(!report.timed_out);
//! ```

mod batcher;
pub mod metrics;
mod worker;

pub use metrics::{serve_stats, Histogram, LatencySnapshot, Metrics, ServeStats};

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::nn::Module;
use crate::serialize::Checkpoint;
use crate::tensor::Tensor;
use crate::testing::chaos::RequestFaults;

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Typed per-request failures. Serving errors are always scoped to one
/// request — the server itself keeps running (chaos contract).
#[derive(Debug, Clone, thiserror::Error, PartialEq, Eq)]
pub enum ServeError {
    /// The model panicked while computing this request. The panic was
    /// caught on the worker; the payload rides along so the client sees
    /// *why*, loudly, instead of a hung `wait`.
    #[error("request {seq} failed: handler panicked: {msg}")]
    HandlerPanic {
        /// The failed request's sequence number.
        seq: u64,
        /// The panic payload (stringified).
        msg: String,
    },

    /// The submitted tensor does not match the server's configured
    /// sample shape — rejected at `submit`, before queueing.
    #[error("request shape {found:?} does not match serve sample shape {expected:?}")]
    ShapeMismatch {
        /// The configured [`ServeConfig::sample_shape`].
        expected: Vec<usize>,
        /// The submitted tensor's shape.
        found: Vec<usize>,
    },

    /// The server is shutting down (or already gone); the request was
    /// not served.
    #[error("server is shut down; request not served")]
    Shutdown,
}

// ---------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Serving knobs. `PALLAS_SERVE_MAX_BATCH` / `PALLAS_SERVE_MAX_DELAY_MS`
/// seed the defaults (README env table); the builder methods override
/// per server.
#[derive(Clone)]
pub struct ServeConfig {
    /// Shape of one request tensor (no batch dimension — the server owns
    /// batching). Enforced at [`ClientHandle::submit`].
    pub sample_shape: Vec<usize>,
    /// Close a batch once it holds this many requests
    /// (`PALLAS_SERVE_MAX_BATCH`, default 8). Also the padding-bucket
    /// cap.
    pub max_batch: usize,
    /// Close a batch this long after its *first* request arrived, full
    /// or not (`PALLAS_SERVE_MAX_DELAY_MS`, default 2 ms) — the
    /// max-latency budget traded against batch size.
    pub max_delay: Duration,
    /// Inference worker threads, each with its own model replica and
    /// capture session (default 1).
    pub workers: usize,
    /// Bound of the request queue; `submit` blocks (backpressure) when
    /// full (default 64).
    pub queue_depth: usize,
    /// How long [`Server::shutdown`] waits for threads to exit before
    /// naming the wedged requests and detaching (default 30 s).
    pub join_timeout: Duration,
    /// Request-scoped fault injection for the chaos suite; `None`
    /// (always, outside tests) injects nothing.
    pub chaos: Option<RequestFaults>,
}

impl ServeConfig {
    /// Defaults for a given per-request sample shape.
    pub fn new(sample_shape: &[usize]) -> ServeConfig {
        ServeConfig {
            sample_shape: sample_shape.to_vec(),
            max_batch: env_u64("PALLAS_SERVE_MAX_BATCH", 8).max(1) as usize,
            max_delay: Duration::from_millis(env_u64("PALLAS_SERVE_MAX_DELAY_MS", 2)),
            workers: 1,
            queue_depth: 64,
            join_timeout: Duration::from_secs(30),
            chaos: None,
        }
    }

    pub fn with_max_batch(mut self, n: usize) -> ServeConfig {
        self.max_batch = n.max(1);
        self
    }

    pub fn with_max_delay(mut self, d: Duration) -> ServeConfig {
        self.max_delay = d;
        self
    }

    pub fn with_workers(mut self, n: usize) -> ServeConfig {
        self.workers = n.max(1);
        self
    }

    pub fn with_queue_depth(mut self, n: usize) -> ServeConfig {
        self.queue_depth = n.max(1);
        self
    }

    pub fn with_join_timeout(mut self, d: Duration) -> ServeConfig {
        self.join_timeout = d;
        self
    }

    /// Install request-scoped chaos faults (tests only).
    pub fn with_chaos(mut self, faults: RequestFaults) -> ServeConfig {
        self.chaos = Some(faults);
        self
    }
}

// ---------------------------------------------------------------------
// Oneshot slots
// ---------------------------------------------------------------------

/// The oneshot response slot a request and its [`Pending`] share.
/// First-writer-wins: during shutdown both the batcher (draining) and
/// the submitting client (racing `closed`) may try to fail the same
/// request — exactly one delivery counts, and a real result can never be
/// overwritten by a late shutdown error (or vice versa).
pub(crate) struct Slot {
    cell: Mutex<Option<Result<Tensor, ServeError>>>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Arc<Slot> {
        Arc::new(Slot { cell: Mutex::new(None), cv: Condvar::new() })
    }

    /// Deliver a response; `true` iff this call won (the slot was empty).
    pub(crate) fn deliver(&self, r: Result<Tensor, ServeError>) -> bool {
        let mut cell = self.cell.lock().unwrap_or_else(|e| e.into_inner());
        if cell.is_some() {
            return false;
        }
        *cell = Some(r);
        self.cv.notify_all();
        true
    }

    fn wait(&self) -> Result<Tensor, ServeError> {
        let mut cell = self.cell.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(r) = cell.take() {
                return r;
            }
            cell = self.cv.wait(cell).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// A submitted request's future response. Dropping it abandons the
/// request: the server still computes (the batch was already formed) but
/// delivery becomes a no-op — pinned by the chaos suite to never wedge
/// the batcher.
pub struct Pending {
    seq: u64,
    slot: Arc<Slot>,
}

impl Pending {
    /// This request's sequence number (the id chaos faults and shutdown
    /// reports refer to).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Block until the server answers: the output row (shape =
    /// the model's per-sample output shape) or a typed error.
    pub fn wait(self) -> Result<Tensor, ServeError> {
        self.slot.wait()
    }
}

// ---------------------------------------------------------------------
// Queue plumbing shared by batcher/worker
// ---------------------------------------------------------------------

/// One queued request.
pub(crate) struct Request {
    pub(crate) seq: u64,
    pub(crate) input: Tensor,
    pub(crate) slot: Arc<Slot>,
    pub(crate) submitted: Instant,
}

impl Request {
    /// Fail this request (first-writer-wins), keeping the books.
    pub(crate) fn fail(self, err: ServeError, m: &ServeShared) {
        if Arc::strong_count(&self.slot) == 1 {
            m.bump_abandoned();
        }
        if self.slot.deliver(Err(err)) {
            m.bump_failed();
        }
    }
}

/// What flows through the request channel.
pub(crate) enum Msg {
    Request(Request),
    /// Shutdown sentinel: flush the forming batch, fail the drain, exit.
    Shutdown,
}

/// A closed batch on its way to a worker.
pub(crate) struct Batch {
    pub(crate) members: Vec<Request>,
}

/// State every serve thread shares: config + the two metrics sinks
/// (per-server instance and the process-global one — every event lands
/// in both, mirroring how capture keeps session and global counters).
pub(crate) struct ServeShared {
    pub(crate) cfg: ServeConfig,
    pub(crate) metrics: Arc<Metrics>,
}

impl ServeShared {
    fn both(&self, f: impl Fn(&Metrics)) {
        f(&self.metrics);
        f(metrics::global());
    }
    pub(crate) fn bump_failed(&self) {
        self.both(|m| {
            m.failed.fetch_add(1, Ordering::Relaxed);
        });
    }
    pub(crate) fn bump_abandoned(&self) {
        self.both(|m| {
            m.abandoned.fetch_add(1, Ordering::Relaxed);
        });
    }
    pub(crate) fn add(&self, field: fn(&Metrics) -> &AtomicU64, n: u64) {
        self.both(|m| {
            field(m).fetch_add(n, Ordering::Relaxed);
        });
    }
    pub(crate) fn record_queue(&self, ns: u64) {
        self.both(|m| m.queue.record(ns));
    }
    pub(crate) fn record_compute(&self, ns: u64) {
        self.both(|m| m.compute.record(ns));
    }
    pub(crate) fn record_total(&self, ns: u64) {
        self.both(|m| m.total.record(ns));
    }
}

// ---------------------------------------------------------------------
// Bounded join (the DataLoader's ExitLatch pattern)
// ---------------------------------------------------------------------

/// Counts live serve threads so shutdown can wait for *thread exit* with
/// a timeout — `JoinHandle::join` alone cannot be bounded. Same pattern
/// as the DataLoader's drop-time join.
struct ExitLatch {
    live: Mutex<usize>,
    cv: Condvar,
}

impl ExitLatch {
    fn new(n: usize) -> Arc<ExitLatch> {
        Arc::new(ExitLatch { live: Mutex::new(n), cv: Condvar::new() })
    }

    fn depart(&self) {
        let mut live = self.live.lock().unwrap_or_else(|e| e.into_inner());
        *live -= 1;
        self.cv.notify_all();
    }

    /// Wait until every thread has exited; `false` on timeout.
    fn wait_all_exited(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut live = self.live.lock().unwrap_or_else(|e| e.into_inner());
        while *live > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) =
                self.cv.wait_timeout(live, deadline - now).unwrap_or_else(|e| e.into_inner());
            live = guard;
        }
        true
    }
}

/// Drop guard each serve thread holds for its whole life: unwinding out
/// of a panicking exec still signals the latch.
struct Departing(Arc<ExitLatch>);

impl Drop for Departing {
    fn drop(&mut self) {
        self.0.depart();
    }
}

// ---------------------------------------------------------------------
// Client handle
// ---------------------------------------------------------------------

/// A cloneable client endpoint. Each client thread clones one and calls
/// [`ClientHandle::submit`]; handles stay valid across (and report
/// [`ServeError::Shutdown`] after) server shutdown.
#[derive(Clone)]
pub struct ClientHandle {
    tx: SyncSender<Msg>,
    closed: Arc<AtomicBool>,
    next_seq: Arc<AtomicU64>,
    shared: Arc<ServeShared>,
}

impl ClientHandle {
    /// Enqueue one request tensor (shape must equal the configured
    /// sample shape). Blocks only when the request queue is full
    /// (backpressure). Returns a [`Pending`] to wait on.
    pub fn submit(&self, input: Tensor) -> Result<Pending, ServeError> {
        if input.shape() != &self.shared.cfg.sample_shape[..] {
            self.shared.add(|m| &m.rejected, 1);
            return Err(ServeError::ShapeMismatch {
                expected: self.shared.cfg.sample_shape.clone(),
                found: input.shape().to_vec(),
            });
        }
        if self.closed.load(Ordering::SeqCst) {
            self.shared.add(|m| &m.rejected, 1);
            return Err(ServeError::Shutdown);
        }
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let slot = Slot::new();
        let req =
            Request { seq, input, slot: slot.clone(), submitted: Instant::now() };
        if self.tx.send(Msg::Request(req)).is_err() {
            // Batcher gone entirely (server dropped): fail immediately.
            self.shared.add(|m| &m.rejected, 1);
            return Err(ServeError::Shutdown);
        }
        self.shared.add(|m| &m.requests, 1);
        // Shutdown race: `closed` is set *before* the sentinel is sent,
        // so if we still read false here our message was enqueued ahead
        // of the sentinel (channel FIFO) and the batcher will see it. If
        // we read true, the batcher's drain may already be past us —
        // self-fail the slot; first-writer-wins dedupes against a drain
        // that did see it.
        if self.closed.load(Ordering::SeqCst)
            && Arc::strong_count(&slot) > 1
            && slot.deliver(Err(ServeError::Shutdown))
        {
            self.shared.bump_failed();
        }
        Ok(Pending { seq, slot })
    }
}

// ---------------------------------------------------------------------
// Shutdown report
// ---------------------------------------------------------------------

/// One worker that failed to exit within the shutdown budget, with the
/// requests it held in flight — so "it hung" comes with names attached.
#[derive(Clone, Debug)]
pub struct WedgedWorker {
    /// Worker index (0-based).
    pub worker: usize,
    /// Sequence numbers of the requests the worker was executing.
    pub seqs: Vec<u64>,
}

/// The outcome of [`Server::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ShutdownReport {
    /// `true` when the join budget elapsed with threads still live; the
    /// stragglers were detached, not leaked into a hang.
    pub timed_out: bool,
    /// Workers still live at timeout, with their in-flight request seqs.
    pub wedged: Vec<WedgedWorker>,
}

impl std::fmt::Display for ShutdownReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.timed_out {
            return write!(f, "serve shutdown: clean");
        }
        write!(f, "serve shutdown: join timed out;")?;
        if self.wedged.is_empty() {
            write!(f, " no worker holds an in-flight request")?;
        }
        for w in &self.wedged {
            write!(f, " worker {} wedged on request(s) {:?};", w.worker, w.seqs)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------

/// A running inference server: one batcher thread + `cfg.workers`
/// inference threads, each owning a private model replica (the
/// [`Module`] trait is `Send` but not `Sync`) and a private
/// [`crate::dispatch::GraphCapture`] session.
pub struct Server {
    tx: Option<SyncSender<Msg>>,
    closed: Arc<AtomicBool>,
    next_seq: Arc<AtomicU64>,
    shared: Arc<ServeShared>,
    latch: Arc<ExitLatch>,
    /// Per-worker in-flight request seqs, for the shutdown report.
    inflight: Vec<Arc<Mutex<Vec<u64>>>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server. `make_model` is called once per worker thread to
    /// build that worker's private replica — for a checkpointed model
    /// use [`Server::from_checkpoint`], which wires the state-dict load
    /// into the factory.
    pub fn new<F>(make_model: F, mut cfg: ServeConfig) -> Server
    where
        F: Fn() -> Box<dyn Module> + Send + Sync + 'static,
    {
        // The builder methods clamp these, but the fields are pub: a
        // zero here would mean a server that can never answer.
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.workers = cfg.workers.max(1);
        cfg.queue_depth = cfg.queue_depth.max(1);
        let shared = Arc::new(ServeShared { metrics: Arc::new(Metrics::new()), cfg });
        let cfg = &shared.cfg;
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
        // Small bound: a deep batch queue would hide queue latency from
        // the batcher's own budget accounting.
        let (batch_tx, batch_rx) = sync_channel::<Batch>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let latch = ExitLatch::new(cfg.workers + 1);
        let make_model = Arc::new(make_model);

        let mut threads = Vec::with_capacity(cfg.workers + 1);
        let mut inflight = Vec::with_capacity(cfg.workers);

        {
            let shared = shared.clone();
            let guard = Departing(latch.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("torsk-serve-batcher".into())
                    .spawn(move || {
                        let _guard = guard;
                        batcher::run(rx, batch_tx, &shared);
                    })
                    .expect("spawn serve batcher"),
            );
        }

        for idx in 0..cfg.workers {
            let inf = Arc::new(Mutex::new(Vec::new()));
            inflight.push(inf.clone());
            let shared = shared.clone();
            let batch_rx = batch_rx.clone();
            let make_model = make_model.clone();
            let guard = Departing(latch.clone());
            threads.push(
                std::thread::Builder::new()
                    .name(format!("torsk-serve-worker-{idx}"))
                    .spawn(move || {
                        let _guard = guard;
                        let model = make_model();
                        worker::run(model, batch_rx, &shared, &inf);
                    })
                    .expect("spawn serve worker"),
            );
        }

        Server {
            tx: Some(tx),
            closed: Arc::new(AtomicBool::new(false)),
            next_seq: Arc::new(AtomicU64::new(0)),
            shared,
            latch,
            inflight,
            threads,
        }
    }

    /// Load a [`Checkpoint`] and serve it: `build_arch` constructs the
    /// (architecture-matching) module, then each worker's replica gets
    /// the checkpoint's state dict loaded — so the *file* defines the
    /// served weights, not the builder's init.
    pub fn from_checkpoint<F>(
        path: &Path,
        build_arch: F,
        cfg: ServeConfig,
    ) -> crate::Result<Server>
    where
        F: Fn() -> Box<dyn Module> + Send + Sync + 'static,
    {
        let ckpt = Checkpoint::load(path)?;
        let sd: Arc<BTreeMap<String, Tensor>> = Arc::new(ckpt.model);
        Ok(Server::new(
            move || {
                let model = build_arch();
                model.load_state_dict(&sd);
                model
            },
            cfg,
        ))
    }

    /// A new client endpoint (cheap; clone freely across threads).
    pub fn handle(&self) -> ClientHandle {
        ClientHandle {
            tx: self.tx.as_ref().expect("server already shut down").clone(),
            closed: self.closed.clone(),
            next_seq: self.next_seq.clone(),
            shared: self.shared.clone(),
        }
    }

    /// Live snapshot of this server's counters (the process-global view
    /// is [`serve_stats`]).
    pub fn stats(&self) -> ServeStats {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting requests, flush what's queued (queued requests are
    /// *failed* with [`ServeError::Shutdown`], not silently dropped),
    /// and join every thread — **bounded** by `cfg.join_timeout`. On
    /// timeout the report names each wedged worker's in-flight request
    /// seqs and the stragglers are detached, never awaited forever.
    pub fn shutdown(mut self) -> ShutdownReport {
        // Order matters: closed first, then the sentinel — submit's
        // post-send double-check relies on it (see ClientHandle::submit).
        self.closed.store(true, Ordering::SeqCst);
        if let Some(tx) = self.tx.take() {
            // try_send: a full queue in front of a wedged batcher must
            // not turn shutdown into the very hang it bounds. The drain
            // path fails queued requests either way; a missing sentinel
            // only means we take the timeout branch below.
            match tx.try_send(Msg::Shutdown) {
                Ok(()) | Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {}
            }
        }
        let clean = self.latch.wait_all_exited(self.shared.cfg.join_timeout);
        let mut report = ShutdownReport::default();
        if clean {
            for t in self.threads.drain(..) {
                let _ = t.join();
            }
        } else {
            report.timed_out = true;
            for (idx, inf) in self.inflight.iter().enumerate() {
                let seqs = inf.lock().unwrap_or_else(|e| e.into_inner()).clone();
                if !seqs.is_empty() {
                    report.wedged.push(WedgedWorker { worker: idx, seqs });
                }
            }
            // Detach: dropping the handles leaves the wedged threads to
            // the OS instead of leaving the caller in an unbounded join.
            self.threads.clear();
        }
        report
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // A dropped (not shut down) server still signals its threads;
        // it never blocks in drop — threads exit once clients' handles
        // go away and the channels disconnect.
        self.closed.store(true, Ordering::SeqCst);
        if let Some(tx) = self.tx.take() {
            let _ = tx.try_send(Msg::Shutdown);
        }
        self.threads.clear();
    }
}
