//! The inference workers: each owns a private model replica ([`Module`]
//! is `Send` but not `Sync` — replicas, not sharing) and a private
//! [`GraphCapture`] session, pulls closed batches from the shared
//! bounded queue, stacks them into a **padded bucket shape**, runs the
//! model under `no_grad`, and scatters output rows back to the waiting
//! clients.
//!
//! Bucket padding is what makes capture pay: batch row-counts are
//! whatever traffic produced (3, then 5, then 2, ...), and every new
//! shape would miss the capture guard and re-trace. Rounding the row
//! count up to the next power of two (capped at `max_batch`) collapses
//! all sizes onto `log2(max_batch)+1` shapes, so after a short warmup
//! every batch **replays** a compiled graph — `capture_stats()` shows
//! guard hits, not recaptures, under steady traffic (pinned by
//! `tests/serve_parity.rs`). Padding rows duplicate a real row and are
//! sliced off before scatter; they change no served bits because row
//! blocking never changes a row's bits (the GEMM parity invariant).
//!
//! A panicking model fails only the requests it was computing: the
//! unwind is caught, the batch is re-run one request at a time (poison
//! isolation), and the guilty request gets a typed
//! [`ServeError::HandlerPanic`] while its co-batched neighbours get
//! their real outputs. The worker thread itself never dies with work on
//! its queue.

use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::data::stack_into_batch;
use crate::dispatch::{GraphCapture, SessionStats};
use crate::nn::Module;
use crate::profiler;
use crate::tensor::Tensor;

use super::{Batch, ServeError, ServeShared};

/// The padding bucket for a batch of `n` real rows: next power of two,
/// capped at the configured maximum. `max_batch` itself is always a
/// bucket even when it is not a power of two.
fn bucket_for(n: usize, max_batch: usize) -> usize {
    n.next_power_of_two().min(max_batch).max(n)
}

pub(crate) fn run(
    model: Box<dyn Module>,
    batch_rx: Arc<Mutex<Receiver<Batch>>>,
    shared: &ServeShared,
    inflight: &Mutex<Vec<u64>>,
) {
    // The session lives (and is only touched) on this worker thread;
    // its guard table accumulates one graph per warm bucket shape.
    let sess = GraphCapture::new("serve:forward");
    let mut seen = SessionStats::default();
    loop {
        // Hold the receiver lock only for the handoff, never during
        // inference — a wedged exec must not block sibling workers.
        let batch = {
            let rx = batch_rx.lock().unwrap_or_else(|e| e.into_inner());
            match rx.recv() {
                Ok(b) => b,
                Err(_) => return, // batcher gone and queue drained
            }
        };
        *inflight.lock().unwrap_or_else(|e| e.into_inner()) =
            batch.members.iter().map(|m| m.seq).collect();
        exec(model.as_ref(), &sess, batch, shared);
        inflight.lock().unwrap_or_else(|e| e.into_inner()).clear();
        // Fold this session's guard activity into the serve counters as
        // deltas (sessions are per-worker; the metrics are per-server).
        let now = sess.session_stats();
        shared.add(|m| &m.guard_hits, now.guard_hits - seen.guard_hits);
        shared.add(|m| &m.guard_misses, now.guard_misses - seen.guard_misses);
        shared.add(|m| &m.graphs_captured, now.graphs_captured - seen.graphs_captured);
        seen = now;
    }
}

/// Execute one batch end-to-end: pad, stack, forward, scatter. Called
/// recursively (singleton batches) for poison isolation after a panic.
fn exec(model: &dyn Module, sess: &GraphCapture, batch: Batch, shared: &ServeShared) {
    let n = batch.members.len();
    debug_assert!(n > 0, "batcher never closes an empty batch");
    let bucket = bucket_for(n, shared.cfg.max_batch);
    shared.add(|m| &m.padded_rows, (bucket - n) as u64);
    let rows: Vec<&Tensor> = batch
        .members
        .iter()
        .map(|r| &r.input)
        .chain(std::iter::repeat(&batch.members[n - 1].input).take(bucket - n))
        .collect();
    let stacked = stack_into_batch(&rows);

    let chaos = shared.cfg.chaos.clone();
    let members = &batch.members;
    let t0 = Instant::now();
    let span = profiler::begin(profiler::Track::Host, "serve:batch");
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if let Some(faults) = &chaos {
            for m in members {
                faults.fire(m.seq);
            }
        }
        crate::autograd::no_grad(|| sess.run(&[&stacked], |ins| model.forward(ins[0])))
    }));
    profiler::end(span);

    match out {
        Ok(out) => {
            shared.record_compute(t0.elapsed().as_nanos() as u64);
            for (i, m) in batch.members.into_iter().enumerate() {
                // Padding rows sit past index n-1 and are never scattered.
                let row = out.select(0, i).contiguous();
                if Arc::strong_count(&m.slot) == 1 {
                    // Client dropped its Pending: deliver into the void
                    // (a no-op write) and count the abandonment.
                    shared.bump_abandoned();
                }
                if m.slot.deliver(Ok(row)) {
                    shared.add(|mm| &mm.completed, 1);
                    shared.record_total(m.submitted.elapsed().as_nanos() as u64);
                }
            }
        }
        Err(payload) => {
            shared.add(|m| &m.handler_panics, 1);
            let msg = panic_msg(payload);
            if n == 1 {
                let m = batch.members.into_iter().next().expect("n == 1");
                let seq = m.seq;
                m.fail(ServeError::HandlerPanic { seq, msg }, shared);
            } else {
                // Poison isolation: one bad request must not fail its
                // co-batched neighbours. Re-run each alone; the guilty
                // one panics again (n == 1 branch) and fails typed.
                for m in batch.members {
                    exec(model, sess, Batch { members: vec![m] }, shared);
                }
            }
        }
    }
}

/// Stringify a caught panic payload (the common `&str`/`String` cases).
fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_powers_of_two_capped_at_max() {
        assert_eq!(bucket_for(1, 8), 1);
        assert_eq!(bucket_for(2, 8), 2);
        assert_eq!(bucket_for(3, 8), 4);
        assert_eq!(bucket_for(5, 8), 8);
        assert_eq!(bucket_for(8, 8), 8);
        // Non-power-of-two cap: the cap itself is a bucket.
        assert_eq!(bucket_for(5, 6), 6);
        assert_eq!(bucket_for(6, 6), 6);
        assert_eq!(bucket_for(4, 6), 4);
    }
}
