//! The batcher thread: drains the request queue, closes dynamic batches
//! at `max_batch` requests or `max_delay` after the batch opener arrived
//! — the classic size-or-deadline policy. A lone request under light
//! load pays at most `max_delay` of extra latency; under heavy load
//! batches fill before the deadline and the deadline never fires.
//!
//! The batcher never touches tensors beyond moving them: stacking,
//! padding and inference all happen on the worker pool so a slow model
//! can't stop batches from *forming* (it only backpressures the bounded
//! batch queue).

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::time::Instant;

use super::{Batch, Msg, Request, ServeError, ServeShared};

/// What ended the fill loop of one batch.
enum Close {
    /// Size or deadline: keep serving.
    Normal,
    /// Shutdown sentinel seen mid-fill.
    Shutdown,
    /// Every sender is gone.
    Disconnected,
}

pub(crate) fn run(rx: Receiver<Msg>, batch_tx: SyncSender<Batch>, shared: &ServeShared) {
    loop {
        // Block (no deadline) for the request that opens the next batch.
        let first = match rx.recv() {
            Ok(Msg::Request(r)) => r,
            Ok(Msg::Shutdown) => {
                drain_and_fail(&rx, shared);
                return;
            }
            Err(_) => return,
        };
        // The budget runs from batch open, not from submit: under a
        // backlog (opener already waited in queue) closing instantly
        // would degrade to batches of one exactly when batching matters.
        let deadline = Instant::now() + shared.cfg.max_delay;
        let mut members = vec![first];
        let mut close = Close::Normal;
        while members.len() < shared.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Request(r)) => members.push(r),
                Ok(Msg::Shutdown) => {
                    close = Close::Shutdown;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    close = Close::Disconnected;
                    break;
                }
            }
        }

        dispatch(members, &batch_tx, shared);

        match close {
            Close::Normal => {}
            Close::Shutdown => {
                drain_and_fail(&rx, shared);
                return;
            }
            Close::Disconnected => return,
        }
    }
    // Returning drops `batch_tx`: the workers' recv disconnects and the
    // pool winds down after finishing what's queued.
}

/// Book a closed batch and hand it to the worker pool.
fn dispatch(members: Vec<Request>, batch_tx: &SyncSender<Batch>, shared: &ServeShared) {
    let closed_at = Instant::now();
    for m in &members {
        let queued = closed_at.saturating_duration_since(m.submitted);
        shared.record_queue(queued.as_nanos() as u64);
    }
    shared.add(|m| &m.batches, 1);
    shared.add(|m| &m.batched_requests, members.len() as u64);
    if let Err(e) = batch_tx.send(Batch { members }) {
        // Worker pool already gone (only possible once shutdown or drop
        // is underway): fail the batch loudly rather than dropping it.
        for m in e.0.members {
            m.fail(ServeError::Shutdown, shared);
        }
    }
}

/// Post-sentinel drain: everything still queued is failed with a typed
/// [`ServeError::Shutdown`] — a queued request must never just vanish.
/// Racing submits that enqueue *after* this drain observes Empty have
/// already seen `closed == true` and fail their own slot (see
/// `ClientHandle::submit`).
fn drain_and_fail(rx: &Receiver<Msg>, shared: &ServeShared) {
    loop {
        match rx.try_recv() {
            Ok(Msg::Request(r)) => r.fail(ServeError::Shutdown, shared),
            Ok(Msg::Shutdown) => {}
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return,
        }
    }
}
