//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so torsk ships a small, fast,
//! well-tested xoshiro256** generator plus the distributions the library
//! needs (uniform, normal via Box–Muller, permutations, Bernoulli).
//! A global seeded instance backs `Tensor::randn` etc. so whole training
//! runs are reproducible via [`manual_seed`], mirroring `torch.manual_seed`.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// xoshiro256** — public-domain generator by Blackman & Vigna.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full f32 mantissa coverage.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call; spare cached).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        // Avoid log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli(p) trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Fill a slice with standard-normal samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out.iter_mut() {
            *x = mean + std * self.normal();
        }
    }

    /// Fill a slice with uniform samples from [lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for x in out.iter_mut() {
            *x = self.uniform_range(lo, hi);
        }
    }

    /// Split off an independent generator (for worker threads).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// The full generator state (checkpointing). Restoring via
    /// [`Rng::from_state`] resumes the stream at exactly this position:
    /// `from_state(r.state())` produces the same outputs `r` would have.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a saved [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Deterministic per-index stream: the one audited recipe for
    /// `Dataset::get(i)`-style generation (mix `index` into `seed`
    /// through splitmix64 so adjacent indices get uncorrelated streams).
    /// Pure in `(seed, index)`, which is what keeps dataset bytes
    /// identical no matter which loader worker fetches them.
    pub fn for_index(seed: u64, index: u64) -> Rng {
        let mut s = seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(index.wrapping_add(1)));
        Rng::new(splitmix64(&mut s))
    }
}

static GLOBAL_SEED: AtomicU64 = AtomicU64::new(0x5EED_0F_70_25_4C);
static SEED_EPOCH: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_RNG: RefCell<(u64, Rng)> = RefCell::new((u64::MAX, Rng::new(0)));
}

/// Seed the global generator, like `torch.manual_seed`. Takes effect in all
/// threads (each thread derives its stream from the seed + a fresh epoch).
pub fn manual_seed(seed: u64) {
    GLOBAL_SEED.store(seed, Ordering::SeqCst);
    SEED_EPOCH.fetch_add(1, Ordering::SeqCst);
}

/// The current global seed (the last [`manual_seed`] value, or the boot
/// default). Checkpoints record it so a resumed run can re-seed identically.
pub fn global_seed() -> u64 {
    GLOBAL_SEED.load(Ordering::SeqCst)
}

/// Run a closure with the calling thread's global-derived generator.
pub fn with_rng<R>(f: impl FnOnce(&mut Rng) -> R) -> R {
    let epoch = SEED_EPOCH.load(Ordering::SeqCst);
    THREAD_RNG.with(|cell| {
        let mut guard = cell.borrow_mut();
        if guard.0 != epoch {
            let seed = GLOBAL_SEED.load(Ordering::SeqCst);
            // Mix in the thread id so threads get distinct streams.
            let tid = std::thread::current().id();
            let mut h = std::collections::hash_map::DefaultHasher::new();
            use std::hash::{Hash, Hasher};
            tid.hash(&mut h);
            *guard = (epoch, Rng::new(seed ^ h.finish()));
        }
        f(&mut guard.1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough_and_in_range() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mut sum = 0f64;
        let mut sumsq = 0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(17);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn manual_seed_resets_stream() {
        manual_seed(42);
        let a = with_rng(|r| r.next_u64());
        manual_seed(42);
        let b = with_rng(|r| r.next_u64());
        assert_eq!(a, b);
        manual_seed(43);
        let c = with_rng(|r| r.next_u64());
        assert_ne!(a, c);
        // global_seed() observes the last manual_seed (checkpoints save it).
        assert_eq!(global_seed(), 43);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(21);
        let mut b = a.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn for_index_is_pure_and_decorrelated() {
        let a1 = Rng::for_index(7, 3).next_u64();
        let a2 = Rng::for_index(7, 3).next_u64();
        assert_eq!(a1, a2, "pure in (seed, index)");
        // Adjacent indices and different seeds give distinct streams.
        let mut x = Rng::for_index(7, 3);
        let mut y = Rng::for_index(7, 4);
        let same_idx = (0..64).filter(|_| x.next_u64() == y.next_u64()).count();
        assert!(same_idx < 4);
        let mut z = Rng::for_index(8, 3);
        let mut w = Rng::for_index(7, 3);
        let same_seed = (0..64).filter(|_| z.next_u64() == w.next_u64()).count();
        assert!(same_seed < 4);
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64(); // advance to an arbitrary mid-stream position
        }
        let snapshot = a.state();
        let expected: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snapshot);
        let resumed: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(expected, resumed, "from_state must resume mid-stream exactly");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(23);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((28_000..32_000).contains(&hits), "hits={hits}");
    }
}
