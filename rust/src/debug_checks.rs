//! Runtime sanitizer for the unsafe parallel runtime (`debug-checks`).
//!
//! The static half of torsk's safety story is `tools/pallas-audit`: every
//! `unsafe` site documents an invariant. This module is the dynamic half —
//! when the crate is built with `--features debug-checks`, the dispatcher
//! and kernel drivers *re-verify* at runtime the invariants those SAFETY
//! comments claim:
//!
//! - [`verify_disjoint_cover`] — every `kernels::parallel_for` split must
//!   partition `0..n` into in-bounds, pairwise-disjoint ranges (the
//!   "chunks write disjoint ranges" claim behind every raw-pointer
//!   parallel write);
//! - [`verify_donation_dead`] — a buffer consumed from the donation slot
//!   must be genuinely dead: exactly the slot's clone plus the moved-in
//!   input handle may reference it (the `call_owned` output-stealing
//!   precondition);
//! - [`verify_output_aliasing`] — an op output aliasing an input's
//!   storage is legal only for declared in-place ops (the output *is* the
//!   input handle) or `reuse_output` kernels in the index-aligned Fast
//!   pattern (same shape/dtype, contiguous, offset 0);
//! - [`verify_tape`] — a fused micro-op tape must respect interpreter
//!   bounds (`MAX_STACK` depth, in-range `Load`s, single result), re-run
//!   at dispatch because tapes can be assembled outside `TapeBuilder`'s
//!   build-time tracking (e.g. the composed `SBCE_DX` tape);
//! - [`verify_access_extent`] — each fused-tape operand must cover every
//!   index its [`Access`](crate::dispatch::fuse) pattern can generate for
//!   an `n`-element pass.
//!
//! All checks panic with a `debug-checks:` message on violation. The
//! feature is compiled out of release builds; CI runs the test suite once
//! with it enabled (see `.github/workflows/ci.yml`).

use std::sync::Arc;

use crate::tensor::storage::Storage;
use crate::tensor::Tensor;

/// Assert that `ranges` partitions `0..n`: every range non-empty and
/// in-bounds, no two ranges overlapping, and all of `0..n` covered.
/// `kernels::parallel_for` routes every real split through this before
/// submitting work.
pub fn verify_disjoint_cover(n: usize, ranges: &[(usize, usize)]) {
    let mut sorted: Vec<(usize, usize)> = ranges.to_vec();
    sorted.sort_unstable();
    let mut prev_end = 0usize;
    let mut covered = 0usize;
    for &(s, e) in &sorted {
        assert!(s < e, "debug-checks: empty or inverted parallel_for range ({s}, {e})");
        assert!(e <= n, "debug-checks: parallel_for range ({s}, {e}) exceeds n = {n}");
        assert!(
            s >= prev_end,
            "debug-checks: overlapping parallel_for split — range ({s}, {e}) starts \
             before the previous range ends at {prev_end}"
        );
        covered += e - s;
        prev_end = e;
    }
    assert!(
        covered == n,
        "debug-checks: parallel_for split covers {covered} of {n} elements"
    );
}

/// Assert that a storage consumed from the donation slot is genuinely
/// dead. At consumption exactly two references exist: the slot's clone
/// (`s` here) and the moved-in input handle still held by `call_owned`'s
/// `inputs` vector. Anything more means a live tensor is about to have
/// its buffer overwritten.
pub fn verify_donation_dead(s: &Storage) {
    let rc = s.ref_count();
    assert!(
        rc == 2,
        "debug-checks: donated buffer is not dead at consumption — storage ref_count \
         is {rc}, expected 2 (the donation slot + the moved-in input handle)"
    );
}

/// Assert that an op output aliasing an input's storage follows a
/// declared pattern. Called by `dispatch::call_with` after the kernel
/// returns.
pub fn verify_output_aliasing(reuse_output: bool, name: &str, inputs: &[&Tensor], out: &Tensor) {
    if out.numel() == 0 {
        // Zero-sized storages may share a sentinel block pointer.
        return;
    }
    for t in inputs {
        if Arc::ptr_eq(&t.inner, &out.inner) {
            // In-place op returning its input handle: declared aliasing.
            continue;
        }
        if t.storage().ptr() == out.storage().ptr() {
            assert!(
                reuse_output,
                "debug-checks: op '{name}' returned an output aliasing an input's \
                 storage but is not registered reuse_output"
            );
            assert!(
                out.dtype() == t.dtype()
                    && out.shape() == t.shape()
                    && out.is_contiguous()
                    && t.is_contiguous()
                    && out.storage_offset() == 0
                    && t.storage_offset() == 0,
                "debug-checks: op '{name}' stole an input buffer outside the declared \
                 Fast-plan pattern (same shape/dtype, contiguous, offset 0)"
            );
        }
    }
}

/// Assert that a fused-tape operand with `numel` elements covers every
/// index its access pattern can generate over an `n`-element pass.
/// `max_index` is the largest source index the pattern produces
/// (`src_index(acc, n - 1)` for monotone patterns).
pub fn verify_access_extent(name: &str, operand: usize, numel: usize, max_index: usize) {
    assert!(
        max_index < numel,
        "debug-checks: {name}: fused-tape operand {operand} holds {numel} elements \
         but its access pattern reaches index {max_index}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_cover_accepts_partition() {
        verify_disjoint_cover(10, &[(0, 4), (4, 8), (8, 10)]);
        verify_disjoint_cover(1, &[(0, 1)]);
        verify_disjoint_cover(0, &[]);
    }

    #[test]
    #[should_panic(expected = "overlapping parallel_for split")]
    fn disjoint_cover_rejects_overlap() {
        verify_disjoint_cover(10, &[(0, 6), (4, 10)]);
    }

    #[test]
    #[should_panic(expected = "covers 8 of 10")]
    fn disjoint_cover_rejects_gap() {
        verify_disjoint_cover(10, &[(0, 4), (6, 10)]);
    }

    #[test]
    #[should_panic(expected = "exceeds n")]
    fn disjoint_cover_rejects_out_of_bounds() {
        verify_disjoint_cover(10, &[(0, 12)]);
    }

    #[test]
    fn access_extent_bounds() {
        verify_access_extent("fused:test", 0, 8, 7);
    }

    #[test]
    #[should_panic(expected = "reaches index 8")]
    fn access_extent_rejects_short_operand() {
        verify_access_extent("fused:test", 0, 8, 8);
    }
}
