//! Fully-connected layer — Listing 1's `LinearLayer`, as a library module.

use super::{init, Module};
use crate::ops;
use crate::tensor::Tensor;

/// `y = x @ Wᵀ + b` with `W [out, in]`.
///
/// The forward never copies the weight: the dispatcher's `linear` kernel
/// consumes `Wᵀ` as pre-packed GEMM panels cached per weight (keyed by
/// tensor id + storage version, so in-place optimizer steps invalidate
/// lazily), and folds the bias into the GEMM's beta pass. After the first
/// call a forward is one packed GEMM over `x` — zero weight copies, zero
/// extra allocations (`dispatch::packed_weight_stats()` observes this).
pub struct Linear {
    pub weight: Tensor,
    pub bias: Option<Tensor>,
}

impl Linear {
    /// New layer with Kaiming-uniform weights and PyTorch-default bias.
    pub fn new(in_features: usize, out_features: usize) -> Linear {
        Linear {
            weight: init::kaiming_uniform(&[out_features, in_features]).requires_grad(true),
            bias: Some(init::linear_bias(in_features, out_features).requires_grad(true)),
        }
    }

    /// Without bias.
    pub fn new_no_bias(in_features: usize, out_features: usize) -> Linear {
        Linear {
            weight: init::kaiming_uniform(&[out_features, in_features]).requires_grad(true),
            bias: None,
        }
    }

    pub fn in_features(&self) -> usize {
        self.weight.size(1)
    }

    pub fn out_features(&self) -> usize {
        self.weight.size(0)
    }
}

impl Module for Linear {
    fn forward(&self, input: &Tensor) -> Tensor {
        // Accept [N, in] or [..., in] by flattening leading dims.
        if input.ndim() == 2 {
            ops::linear(input, &self.weight, self.bias.as_ref())
        } else {
            let in_f = self.in_features();
            let lead: Vec<usize> = input.shape()[..input.ndim() - 1].to_vec();
            let x2 = input.reshape(&[usize::MAX, in_f]);
            let y = ops::linear(&x2, &self.weight, self.bias.as_ref());
            let mut out_shape = lead;
            out_shape.push(self.out_features());
            y.reshape(&out_shape)
        }
    }

    fn parameters(&self) -> Vec<Tensor> {
        let mut p = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            p.push(b.clone());
        }
        p
    }

    fn name(&self) -> &'static str {
        "Linear"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape() {
        crate::rng::manual_seed(0);
        let l = Linear::new(3, 5);
        let y = l.forward(&Tensor::randn(&[7, 3]));
        assert_eq!(y.shape(), &[7, 5]);
    }

    #[test]
    fn forward_3d_input() {
        crate::rng::manual_seed(0);
        let l = Linear::new(4, 2);
        let y = l.forward(&Tensor::randn(&[2, 3, 4]));
        assert_eq!(y.shape(), &[2, 3, 2]);
    }

    #[test]
    fn no_bias_has_one_param() {
        crate::rng::manual_seed(0);
        let l = Linear::new_no_bias(3, 3);
        assert_eq!(l.parameters().len(), 1);
    }

    #[test]
    fn gradients_reach_parameters() {
        crate::rng::manual_seed(0);
        let l = Linear::new(3, 2);
        l.forward(&Tensor::randn(&[4, 3])).sum().backward();
        assert_eq!(l.weight.grad().unwrap().shape(), &[2, 3]);
        assert_eq!(l.bias.as_ref().unwrap().grad().unwrap().to_vec::<f32>(), vec![4.0, 4.0]);
    }

    #[test]
    fn listing1_custom_layer_equivalent() {
        // The paper's Listing 1 LinearLayer: t = x @ w ; t + b — written
        // directly with ops, no Module required ("models are just programs").
        crate::rng::manual_seed(1);
        let w = Tensor::randn(&[3, 2]).requires_grad(true);
        let b = Tensor::randn(&[2]).requires_grad(true);
        let x = Tensor::randn(&[5, 3]);
        let y = ops::add(&ops::matmul(&x, &w), &b);
        assert_eq!(y.shape(), &[5, 2]);
        y.sum().backward();
        assert!(w.grad().is_some() && b.grad().is_some());
    }
}
