//! Neural-network modules — "layers … are typically expressed as Python
//! classes whose constructors create and initialize their parameters, and
//! whose forward methods process an input activation" (§4.1). In torsk a
//! layer is a Rust struct implementing [`Module`]; nothing forces users to
//! structure code this way (any function over tensors differentiates).

pub mod conv;
pub mod embedding;
pub mod init;
pub mod linear;
pub mod norm;
pub mod rnn;

pub use conv::{AvgPool2d, Conv2d, MaxPool2d};
pub use embedding::Embedding;
pub use linear::Linear;
pub use norm::{BatchNorm2d, Dropout, LayerNorm};
pub use rnn::{LSTMCell, LSTM};

use crate::ops;
use crate::tensor::Tensor;

/// A composable neural-network component: parameters + a forward function.
pub trait Module: Send {
    /// Apply the module.
    fn forward(&self, input: &Tensor) -> Tensor;

    /// All learnable parameters (leaves with `requires_grad`).
    fn parameters(&self) -> Vec<Tensor> {
        vec![]
    }

    /// Non-learnable state (running stats) that should follow the module
    /// across devices / into checkpoints.
    fn buffers(&self) -> Vec<Tensor> {
        vec![]
    }

    /// Toggle training/eval behaviour (dropout, batch-norm).
    fn set_training(&mut self, _training: bool) {}

    /// Short type name for printing.
    fn name(&self) -> &'static str {
        "Module"
    }
}

/// Helpers available on every module.
pub trait ModuleExt: Module {
    /// Total parameter count.
    fn num_parameters(&self) -> usize {
        self.parameters().iter().map(|p| p.numel()).sum()
    }

    /// Zero all parameter gradients.
    fn zero_grad(&self) {
        for p in self.parameters() {
            p.set_grad(None);
        }
    }
}

impl<M: Module + ?Sized> ModuleExt for M {}

/// A linear chain of modules (`nn.Sequential`).
pub struct Sequential {
    mods: Vec<Box<dyn Module>>,
}

impl Sequential {
    pub fn new() -> Sequential {
        Sequential { mods: Vec::new() }
    }

    /// Builder-style append.
    pub fn add(mut self, m: impl Module + 'static) -> Sequential {
        self.mods.push(Box::new(m));
        self
    }

    /// Append a boxed module.
    pub fn push(&mut self, m: Box<dyn Module>) {
        self.mods.push(m);
    }

    pub fn len(&self) -> usize {
        self.mods.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mods.is_empty()
    }
}

impl Default for Sequential {
    fn default() -> Self {
        Self::new()
    }
}

impl Module for Sequential {
    fn forward(&self, input: &Tensor) -> Tensor {
        let mut x = input.clone();
        for m in &self.mods {
            x = m.forward(&x);
        }
        x
    }

    fn parameters(&self) -> Vec<Tensor> {
        self.mods.iter().flat_map(|m| m.parameters()).collect()
    }

    fn buffers(&self) -> Vec<Tensor> {
        self.mods.iter().flat_map(|m| m.buffers()).collect()
    }

    fn set_training(&mut self, training: bool) {
        for m in &mut self.mods {
            m.set_training(training);
        }
    }

    fn name(&self) -> &'static str {
        "Sequential"
    }
}

/// ReLU as a module (for Sequential chains).
pub struct ReLU;
impl Module for ReLU {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::relu(input)
    }
    fn name(&self) -> &'static str {
        "ReLU"
    }
}

/// Sigmoid as a module.
pub struct Sigmoid;
impl Module for Sigmoid {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::sigmoid(input)
    }
    fn name(&self) -> &'static str {
        "Sigmoid"
    }
}

/// Tanh as a module.
pub struct Tanh;
impl Module for Tanh {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::tanh(input)
    }
    fn name(&self) -> &'static str {
        "Tanh"
    }
}

/// Flatten all dims after the batch dim.
pub struct Flatten;
impl Module for Flatten {
    fn forward(&self, input: &Tensor) -> Tensor {
        let n = input.size(0);
        input.reshape(&[n, usize::MAX])
    }
    fn name(&self) -> &'static str {
        "Flatten"
    }
}

/// Global average pooling NCHW -> NC as a module.
pub struct GlobalAvgPool;
impl Module for GlobalAvgPool {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::global_avgpool2d(input)
    }
    fn name(&self) -> &'static str {
        "GlobalAvgPool"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_chains_modules() {
        crate::rng::manual_seed(0);
        let model = Sequential::new()
            .add(Linear::new(4, 8))
            .add(ReLU)
            .add(Linear::new(8, 2));
        let x = Tensor::randn(&[3, 4]);
        let y = model.forward(&x);
        assert_eq!(y.shape(), &[3, 2]);
        assert_eq!(model.parameters().len(), 4); // 2x (weight, bias)
    }

    #[test]
    fn zero_grad_clears() {
        crate::rng::manual_seed(0);
        let model = Sequential::new().add(Linear::new(2, 2));
        let x = Tensor::randn(&[1, 2]);
        model.forward(&x).sum().backward();
        assert!(model.parameters()[0].grad().is_some());
        model.zero_grad();
        assert!(model.parameters()[0].grad().is_none());
    }

    #[test]
    fn flatten_module() {
        let x = Tensor::ones(&[2, 3, 4]);
        let y = Flatten.forward(&x);
        assert_eq!(y.shape(), &[2, 12]);
    }

    #[test]
    fn num_parameters_counts_elements() {
        crate::rng::manual_seed(0);
        let l = Linear::new(3, 5);
        assert_eq!(l.num_parameters(), 3 * 5 + 5);
    }

    #[test]
    fn set_training_propagates() {
        let mut model = Sequential::new().add(Dropout::new(0.5)).add(ReLU);
        model.set_training(false);
        let x = Tensor::ones(&[64]);
        // In eval mode dropout is identity.
        let y = model.forward(&x);
        assert_eq!(y.to_vec::<f32>(), vec![1.0; 64]);
    }
}
