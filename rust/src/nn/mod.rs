//! Neural-network modules — "layers … are typically expressed as Python
//! classes whose constructors create and initialize their parameters, and
//! whose forward methods process an input activation" (§4.1). In torsk a
//! layer is a Rust struct implementing [`Module`]; nothing forces users to
//! structure code this way (any function over tensors differentiates).

pub mod conv;
pub mod embedding;
pub mod init;
pub mod linear;
pub mod norm;
pub mod rnn;

pub use conv::{AvgPool2d, Conv2d, MaxPool2d};
pub use embedding::Embedding;
pub use linear::Linear;
pub use norm::{BatchNorm2d, Dropout, LayerNorm};
pub use rnn::{LSTMCell, LSTM};

use std::collections::BTreeMap;

use crate::ops;
use crate::tensor::Tensor;
use crate::{torsk_assert, torsk_bail};

/// A composable neural-network component: parameters + a forward function.
pub trait Module: Send {
    /// Apply the module.
    fn forward(&self, input: &Tensor) -> Tensor;

    /// All learnable parameters (leaves with `requires_grad`).
    fn parameters(&self) -> Vec<Tensor> {
        vec![]
    }

    /// Non-learnable state (running stats) that should follow the module
    /// across devices / into checkpoints.
    fn buffers(&self) -> Vec<Tensor> {
        vec![]
    }

    /// Named parameters. The default enumerates [`Module::parameters`]
    /// positionally (`param.0`, `param.1`, ...); structured modules may
    /// override with real names.
    fn named_parameters(&self) -> Vec<(String, Tensor)> {
        self.parameters()
            .into_iter()
            .enumerate()
            .map(|(i, p)| (format!("param.{i}"), p))
            .collect()
    }

    /// Named buffers (`buffer.0`, ...), same convention.
    fn named_buffers(&self) -> Vec<(String, Tensor)> {
        self.buffers()
            .into_iter()
            .enumerate()
            .map(|(i, b)| (format!("buffer.{i}"), b))
            .collect()
    }

    /// Snapshot of all state (parameters + buffers) as a name → Tensor
    /// map. Values are *copies* (checkpoint semantics): later training
    /// steps do not mutate a saved state dict.
    fn state_dict(&self) -> BTreeMap<String, Tensor> {
        let mut sd = BTreeMap::new();
        for (name, t) in self.named_parameters().into_iter().chain(self.named_buffers()) {
            let copy = Tensor::empty(t.shape(), t.dtype(), t.device());
            crate::autograd::no_grad(|| copy.copy_(&t.detach().contiguous()));
            torsk_assert!(
                sd.insert(name.clone(), copy).is_none(),
                "state_dict: duplicate entry name '{name}'"
            );
        }
        sd
    }

    /// Load a state dict produced by [`Module::state_dict`] into this
    /// module's parameters and buffers, in place. Strict: missing or
    /// unexpected keys and shape mismatches are errors.
    fn load_state_dict(&self, sd: &BTreeMap<String, Tensor>) {
        let targets: Vec<(String, Tensor)> =
            self.named_parameters().into_iter().chain(self.named_buffers()).collect();
        for key in sd.keys() {
            torsk_assert!(
                targets.iter().any(|(n, _)| n == key),
                "load_state_dict: unexpected key '{key}'"
            );
        }
        for (name, dst) in targets {
            let src = match sd.get(&name) {
                Some(t) => t,
                None => torsk_bail!("load_state_dict: missing key '{name}'"),
            };
            torsk_assert!(
                src.shape() == dst.shape(),
                "load_state_dict: shape mismatch for '{name}': {:?} vs {:?}",
                src.shape(),
                dst.shape()
            );
            crate::autograd::no_grad(|| dst.copy_(&src.to_device(dst.device())));
        }
    }

    /// Toggle training/eval behaviour (dropout, batch-norm).
    fn set_training(&mut self, _training: bool) {}

    /// Short type name for printing.
    fn name(&self) -> &'static str {
        "Module"
    }
}

/// Helpers available on every module.
pub trait ModuleExt: Module {
    /// Total parameter count.
    fn num_parameters(&self) -> usize {
        self.parameters().iter().map(|p| p.numel()).sum()
    }

    /// Zero all parameter gradients.
    fn zero_grad(&self) {
        for p in self.parameters() {
            p.set_grad(None);
        }
    }
}

impl<M: Module + ?Sized> ModuleExt for M {}

/// A linear chain of modules (`nn.Sequential`).
pub struct Sequential {
    mods: Vec<Box<dyn Module>>,
}

impl Sequential {
    pub fn new() -> Sequential {
        Sequential { mods: Vec::new() }
    }

    /// Builder-style append.
    pub fn add(mut self, m: impl Module + 'static) -> Sequential {
        self.mods.push(Box::new(m));
        self
    }

    /// Append a boxed module.
    pub fn push(&mut self, m: Box<dyn Module>) {
        self.mods.push(m);
    }

    pub fn len(&self) -> usize {
        self.mods.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mods.is_empty()
    }
}

impl Default for Sequential {
    fn default() -> Self {
        Self::new()
    }
}

impl Module for Sequential {
    fn forward(&self, input: &Tensor) -> Tensor {
        let mut x = input.clone();
        for m in &self.mods {
            x = m.forward(&x);
        }
        x
    }

    fn parameters(&self) -> Vec<Tensor> {
        self.mods.iter().flat_map(|m| m.parameters()).collect()
    }

    fn buffers(&self) -> Vec<Tensor> {
        self.mods.iter().flat_map(|m| m.buffers()).collect()
    }

    fn set_training(&mut self, training: bool) {
        for m in &mut self.mods {
            m.set_training(training);
        }
    }

    fn name(&self) -> &'static str {
        "Sequential"
    }
}

/// ReLU as a module (for Sequential chains).
pub struct ReLU;
impl Module for ReLU {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::relu(input)
    }
    fn name(&self) -> &'static str {
        "ReLU"
    }
}

/// GELU as a module (runs the single-pass `fused:gelu` tape kernel).
pub struct Gelu;
impl Module for Gelu {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::gelu(input)
    }
    fn name(&self) -> &'static str {
        "Gelu"
    }
}

/// Sigmoid as a module.
pub struct Sigmoid;
impl Module for Sigmoid {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::sigmoid(input)
    }
    fn name(&self) -> &'static str {
        "Sigmoid"
    }
}

/// Tanh as a module.
pub struct Tanh;
impl Module for Tanh {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::tanh(input)
    }
    fn name(&self) -> &'static str {
        "Tanh"
    }
}

/// Flatten all dims after the batch dim.
pub struct Flatten;
impl Module for Flatten {
    fn forward(&self, input: &Tensor) -> Tensor {
        let n = input.size(0);
        input.reshape(&[n, usize::MAX])
    }
    fn name(&self) -> &'static str {
        "Flatten"
    }
}

/// Global average pooling NCHW -> NC as a module.
pub struct GlobalAvgPool;
impl Module for GlobalAvgPool {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::global_avgpool2d(input)
    }
    fn name(&self) -> &'static str {
        "GlobalAvgPool"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_chains_modules() {
        crate::rng::manual_seed(0);
        let model = Sequential::new()
            .add(Linear::new(4, 8))
            .add(ReLU)
            .add(Linear::new(8, 2));
        let x = Tensor::randn(&[3, 4]);
        let y = model.forward(&x);
        assert_eq!(y.shape(), &[3, 2]);
        assert_eq!(model.parameters().len(), 4); // 2x (weight, bias)
    }

    #[test]
    fn zero_grad_clears() {
        crate::rng::manual_seed(0);
        let model = Sequential::new().add(Linear::new(2, 2));
        let x = Tensor::randn(&[1, 2]);
        model.forward(&x).sum().backward();
        assert!(model.parameters()[0].grad().is_some());
        model.zero_grad();
        assert!(model.parameters()[0].grad().is_none());
    }

    #[test]
    fn flatten_module() {
        let x = Tensor::ones(&[2, 3, 4]);
        let y = Flatten.forward(&x);
        assert_eq!(y.shape(), &[2, 12]);
    }

    #[test]
    fn num_parameters_counts_elements() {
        crate::rng::manual_seed(0);
        let l = Linear::new(3, 5);
        assert_eq!(l.num_parameters(), 3 * 5 + 5);
    }

    #[test]
    fn state_dict_round_trip_on_sequential() {
        crate::rng::manual_seed(7);
        let model = Sequential::new()
            .add(Linear::new(4, 8))
            .add(ReLU)
            .add(Linear::new(8, 2));
        let x = Tensor::randn(&[3, 4]);
        let y0 = model.forward(&x).to_vec::<f32>();

        // Snapshot, then perturb every parameter in place.
        let saved = model.state_dict();
        assert_eq!(saved.len(), model.parameters().len());
        crate::autograd::no_grad(|| {
            for p in model.parameters() {
                p.add_scalar_(1.5);
            }
        });
        let y1 = model.forward(&x).to_vec::<f32>();
        assert_ne!(y0, y1, "perturbation must change the output");

        // Restoring the snapshot restores the function.
        model.load_state_dict(&saved);
        let y2 = model.forward(&x).to_vec::<f32>();
        assert_eq!(y0, y2);
    }

    #[test]
    fn state_dict_is_a_copy_not_a_view() {
        crate::rng::manual_seed(8);
        let model = Sequential::new().add(Linear::new(2, 2));
        let saved = model.state_dict();
        let before = saved.get("param.0").unwrap().to_vec::<f32>();
        crate::autograd::no_grad(|| model.parameters()[0].add_scalar_(3.0));
        assert_eq!(saved.get("param.0").unwrap().to_vec::<f32>(), before);
    }

    #[test]
    #[should_panic(expected = "unexpected key")]
    fn load_state_dict_rejects_unknown_keys() {
        crate::rng::manual_seed(9);
        let model = Sequential::new().add(Linear::new(2, 2));
        let mut sd = model.state_dict();
        sd.insert("param.99".into(), Tensor::ones(&[1]));
        model.load_state_dict(&sd);
    }

    #[test]
    #[should_panic(expected = "missing key")]
    fn load_state_dict_rejects_missing_keys() {
        crate::rng::manual_seed(10);
        let model = Sequential::new().add(Linear::new(2, 2));
        let mut sd = model.state_dict();
        sd.remove("param.0");
        model.load_state_dict(&sd);
    }

    #[test]
    fn state_dict_includes_buffers() {
        let bn = BatchNorm2d::new(3);
        let sd = bn.state_dict();
        // gamma, beta params + running mean/var buffers.
        assert!(sd.contains_key("param.0"));
        assert!(sd.contains_key("buffer.0"));
        assert_eq!(sd.len(), bn.parameters().len() + bn.buffers().len());
    }

    #[test]
    fn set_training_propagates() {
        let mut model = Sequential::new().add(Dropout::new(0.5)).add(ReLU);
        model.set_training(false);
        let x = Tensor::ones(&[64]);
        // In eval mode dropout is identity.
        let y = model.forward(&x);
        assert_eq!(y.to_vec::<f32>(), vec![1.0; 64]);
    }
}
