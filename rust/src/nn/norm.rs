//! Normalization & regularization modules: BatchNorm2d, LayerNorm, Dropout.

use super::Module;
use crate::ops;
use crate::tensor::Tensor;

/// 2-D batch normalization with learnable affine + running statistics.
pub struct BatchNorm2d {
    pub gamma: Tensor,
    pub beta: Tensor,
    pub running_mean: Tensor,
    pub running_var: Tensor,
    pub momentum: f32,
    pub eps: f32,
    training: bool,
}

impl BatchNorm2d {
    pub fn new(channels: usize) -> BatchNorm2d {
        BatchNorm2d {
            gamma: Tensor::ones(&[channels]).requires_grad(true),
            beta: Tensor::zeros(&[channels]).requires_grad(true),
            running_mean: Tensor::zeros(&[channels]),
            running_var: Tensor::ones(&[channels]),
            momentum: 0.1,
            eps: 1e-5,
            training: true,
        }
    }
}

impl Module for BatchNorm2d {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::batch_norm2d(
            input,
            &self.gamma,
            &self.beta,
            &self.running_mean,
            &self.running_var,
            self.training,
            self.momentum,
            self.eps,
        )
    }

    fn parameters(&self) -> Vec<Tensor> {
        vec![self.gamma.clone(), self.beta.clone()]
    }

    fn buffers(&self) -> Vec<Tensor> {
        vec![self.running_mean.clone(), self.running_var.clone()]
    }

    fn set_training(&mut self, training: bool) {
        self.training = training;
    }

    fn name(&self) -> &'static str {
        "BatchNorm2d"
    }
}

/// Layer normalization over the last dimension.
pub struct LayerNorm {
    pub gamma: Tensor,
    pub beta: Tensor,
    pub eps: f32,
}

impl LayerNorm {
    pub fn new(dim: usize) -> LayerNorm {
        LayerNorm {
            gamma: Tensor::ones(&[dim]).requires_grad(true),
            beta: Tensor::zeros(&[dim]).requires_grad(true),
            eps: 1e-5,
        }
    }
}

impl Module for LayerNorm {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::layer_norm(input, &self.gamma, &self.beta, self.eps)
    }

    fn parameters(&self) -> Vec<Tensor> {
        vec![self.gamma.clone(), self.beta.clone()]
    }

    fn name(&self) -> &'static str {
        "LayerNorm"
    }
}

/// Inverted dropout.
pub struct Dropout {
    pub p: f32,
    training: bool,
}

impl Dropout {
    pub fn new(p: f32) -> Dropout {
        Dropout { p, training: true }
    }
}

impl Module for Dropout {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::dropout(input, self.p, self.training)
    }

    fn set_training(&mut self, training: bool) {
        self.training = training;
    }

    fn name(&self) -> &'static str {
        "Dropout"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batchnorm_module_roundtrip() {
        crate::rng::manual_seed(0);
        let mut bn = BatchNorm2d::new(3);
        let x = Tensor::randn(&[4, 3, 5, 5]);
        let y = bn.forward(&x);
        assert_eq!(y.shape(), x.shape());
        assert_eq!(bn.parameters().len(), 2);
        assert_eq!(bn.buffers().len(), 2);
        // Eval mode must not change running stats.
        bn.set_training(false);
        let rm_before = bn.running_mean.to_vec::<f32>();
        bn.forward(&x);
        assert_eq!(bn.running_mean.to_vec::<f32>(), rm_before);
    }

    #[test]
    fn layernorm_module() {
        crate::rng::manual_seed(0);
        let ln = LayerNorm::new(8);
        let y = ln.forward(&Tensor::randn(&[3, 8]));
        assert_eq!(y.shape(), &[3, 8]);
    }

    #[test]
    fn dropout_module_training_toggle() {
        crate::rng::manual_seed(0);
        let mut d = Dropout::new(0.9);
        let x = Tensor::ones(&[1000]);
        let y_train = d.forward(&x);
        let zeros = y_train.to_vec::<f32>().iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 800);
        d.set_training(false);
        let y_eval = d.forward(&x);
        assert_eq!(y_eval.to_vec::<f32>(), vec![1.0; 1000]);
    }
}
