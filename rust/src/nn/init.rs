//! Parameter initialization schemes (Kaiming/He, Xavier/Glorot, uniform).

use crate::rng;
use crate::tensor::Tensor;

/// Kaiming-uniform initialization for a weight of shape
/// `[fan_out, fan_in, ...]` (ReLU gain), PyTorch's Linear/Conv default.
pub fn kaiming_uniform(shape: &[usize]) -> Tensor {
    let fan_in: usize = shape[1..].iter().product::<usize>().max(1);
    let gain = (2.0f32).sqrt();
    let bound = gain * (3.0 / fan_in as f32).sqrt();
    uniform(shape, -bound, bound)
}

/// Xavier/Glorot-uniform initialization.
pub fn xavier_uniform(shape: &[usize]) -> Tensor {
    let fan_in: usize = shape[1..].iter().product::<usize>().max(1);
    let fan_out = shape[0];
    let bound = (6.0 / (fan_in + fan_out) as f32).sqrt();
    uniform(shape, -bound, bound)
}

/// Uniform initialization in [lo, hi).
pub fn uniform(shape: &[usize], lo: f32, hi: f32) -> Tensor {
    let n: usize = shape.iter().product();
    let mut data = vec![0.0f32; n];
    rng::with_rng(|r| r.fill_uniform(&mut data, lo, hi));
    Tensor::from_vec(data, shape)
}

/// Normal initialization.
pub fn normal(shape: &[usize], mean: f32, std: f32) -> Tensor {
    let n: usize = shape.iter().product();
    let mut data = vec![0.0f32; n];
    rng::with_rng(|r| r.fill_normal(&mut data, mean, std));
    Tensor::from_vec(data, shape)
}

/// Bias bound matching PyTorch's Linear default: U(-1/sqrt(fan_in), ...).
pub fn linear_bias(fan_in: usize, len: usize) -> Tensor {
    let bound = 1.0 / (fan_in as f32).sqrt();
    uniform(&[len], -bound, bound)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kaiming_bound_respected() {
        rng::manual_seed(1);
        let w = kaiming_uniform(&[64, 128]);
        let bound = (2.0f32).sqrt() * (3.0f32 / 128.0).sqrt();
        for v in w.to_vec::<f32>() {
            assert!(v.abs() <= bound + 1e-6);
        }
    }

    #[test]
    fn kaiming_variance_close_to_theory() {
        rng::manual_seed(2);
        let w = kaiming_uniform(&[256, 256]);
        let v = w.to_vec::<f32>();
        let var: f32 = v.iter().map(|x| x * x).sum::<f32>() / v.len() as f32;
        // Var of U(-b, b) = b^2/3 = 2/fan_in.
        let expect = 2.0 / 256.0;
        assert!((var - expect).abs() / expect < 0.1, "var {var} vs {expect}");
    }

    #[test]
    fn xavier_bound() {
        rng::manual_seed(3);
        let w = xavier_uniform(&[32, 64]);
        let bound = (6.0 / 96.0f32).sqrt();
        assert!(w.to_vec::<f32>().iter().all(|v| v.abs() <= bound + 1e-6));
    }

    #[test]
    fn normal_moments() {
        rng::manual_seed(4);
        let w = normal(&[10_000], 1.0, 0.5);
        let v = w.to_vec::<f32>();
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!((mean - 1.0).abs() < 0.02);
    }
}
