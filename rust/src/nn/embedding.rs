//! Embedding table module.

use super::{init, Module};
use crate::ops;
use crate::tensor::Tensor;

/// Lookup table `[vocab, dim]` indexed by i64 tensors.
pub struct Embedding {
    pub weight: Tensor,
}

impl Embedding {
    pub fn new(vocab: usize, dim: usize) -> Embedding {
        Embedding { weight: init::normal(&[vocab, dim], 0.0, 1.0).requires_grad(true) }
    }

    pub fn vocab(&self) -> usize {
        self.weight.size(0)
    }

    pub fn dim(&self) -> usize {
        self.weight.size(1)
    }
}

impl Module for Embedding {
    fn forward(&self, indices: &Tensor) -> Tensor {
        ops::embedding(&self.weight, indices)
    }

    fn parameters(&self) -> Vec<Tensor> {
        vec![self.weight.clone()]
    }

    fn name(&self) -> &'static str {
        "Embedding"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_module_lookup() {
        crate::rng::manual_seed(0);
        let e = Embedding::new(10, 4);
        let idx = Tensor::from_vec(vec![1i64, 3, 1], &[3]);
        let y = e.forward(&idx);
        assert_eq!(y.shape(), &[3, 4]);
        let v = y.to_vec::<f32>();
        assert_eq!(&v[0..4], &v[8..12], "same index same row");
    }

    #[test]
    fn embedding_grad_sparse_accumulation() {
        crate::rng::manual_seed(0);
        let e = Embedding::new(5, 2);
        let idx = Tensor::from_vec(vec![0i64, 0, 4], &[3]);
        e.forward(&idx).sum().backward();
        let g = e.weight.grad().unwrap().to_vec::<f32>();
        assert_eq!(&g[0..2], &[2.0, 2.0]);
        assert_eq!(&g[8..10], &[1.0, 1.0]);
        assert_eq!(&g[2..8], &[0.0; 6]);
    }
}
