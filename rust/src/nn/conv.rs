//! Convolution and pooling modules.

use super::{init, Module};
use crate::ops;
use crate::tensor::Tensor;

/// 2-D convolution layer (NCHW).
pub struct Conv2d {
    pub weight: Tensor,
    pub bias: Option<Tensor>,
    pub stride: usize,
    pub padding: usize,
    pub groups: usize,
}

impl Conv2d {
    pub fn new(in_ch: usize, out_ch: usize, kernel: usize, stride: usize, padding: usize) -> Conv2d {
        Conv2d::with_groups(in_ch, out_ch, kernel, stride, padding, 1, true)
    }

    /// Full constructor (groups=in_ch gives depthwise conv).
    pub fn with_groups(
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        groups: usize,
        bias: bool,
    ) -> Conv2d {
        let weight =
            init::kaiming_uniform(&[out_ch, in_ch / groups, kernel, kernel]).requires_grad(true);
        let bias = if bias {
            Some(init::linear_bias(in_ch / groups * kernel * kernel, out_ch).requires_grad(true))
        } else {
            None
        };
        Conv2d { weight, bias, stride, padding, groups }
    }
}

impl Module for Conv2d {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::conv2d(input, &self.weight, self.bias.as_ref(), self.stride, self.padding, self.groups)
    }

    fn parameters(&self) -> Vec<Tensor> {
        let mut p = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            p.push(b.clone());
        }
        p
    }

    fn name(&self) -> &'static str {
        "Conv2d"
    }
}

/// Max-pooling module.
pub struct MaxPool2d {
    pub kernel: usize,
    pub stride: usize,
    pub padding: usize,
}

impl MaxPool2d {
    pub fn new(kernel: usize, stride: usize) -> MaxPool2d {
        MaxPool2d { kernel, stride, padding: 0 }
    }
}

impl Module for MaxPool2d {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::maxpool2d(input, self.kernel, self.stride, self.padding)
    }
    fn name(&self) -> &'static str {
        "MaxPool2d"
    }
}

/// Average-pooling module.
pub struct AvgPool2d {
    pub kernel: usize,
    pub stride: usize,
}

impl AvgPool2d {
    pub fn new(kernel: usize, stride: usize) -> AvgPool2d {
        AvgPool2d { kernel, stride }
    }
}

impl Module for AvgPool2d {
    fn forward(&self, input: &Tensor) -> Tensor {
        ops::avgpool2d(input, self.kernel, self.stride, 0)
    }
    fn name(&self) -> &'static str {
        "AvgPool2d"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_module_shape() {
        crate::rng::manual_seed(0);
        let c = Conv2d::new(3, 8, 3, 1, 1);
        let y = c.forward(&Tensor::randn(&[2, 3, 16, 16]));
        assert_eq!(y.shape(), &[2, 8, 16, 16]);
        assert_eq!(c.parameters().len(), 2);
    }

    #[test]
    fn conv_stride_downsamples() {
        crate::rng::manual_seed(0);
        let c = Conv2d::new(1, 4, 3, 2, 1);
        let y = c.forward(&Tensor::randn(&[1, 1, 8, 8]));
        assert_eq!(y.shape(), &[1, 4, 4, 4]);
    }

    #[test]
    fn depthwise_conv_module() {
        crate::rng::manual_seed(0);
        let c = Conv2d::with_groups(8, 8, 3, 1, 1, 8, false);
        let y = c.forward(&Tensor::randn(&[1, 8, 6, 6]));
        assert_eq!(y.shape(), &[1, 8, 6, 6]);
        assert_eq!(c.parameters().len(), 1);
    }

    #[test]
    fn pool_modules() {
        let x = Tensor::randn(&[1, 2, 8, 8]);
        assert_eq!(MaxPool2d::new(2, 2).forward(&x).shape(), &[1, 2, 4, 4]);
        assert_eq!(AvgPool2d::new(2, 2).forward(&x).shape(), &[1, 2, 4, 4]);
    }

    #[test]
    fn conv_backward_through_module() {
        crate::rng::manual_seed(0);
        let c = Conv2d::new(2, 4, 3, 1, 1);
        c.forward(&Tensor::randn(&[1, 2, 5, 5])).sum().backward();
        assert!(c.weight.grad().is_some());
        assert!(c.bias.as_ref().unwrap().grad().is_some());
    }
}
