//! Recurrent layers: LSTM cell and multi-step LSTM — the control-flow-heavy
//! models the paper's define-by-run design exists for (§4.1: "numerical
//! programs often composed of many loops and recursive functions"). The
//! time loop is a plain Rust `for`; autograd unrolls through it naturally.

use super::{init, Module};
use crate::ops;
use crate::tensor::Tensor;

/// One LSTM step: gates = x @ Wihᵀ + h @ Whhᵀ + b; standard i,f,g,o split.
pub struct LSTMCell {
    pub w_ih: Tensor, // [4H, I]
    pub w_hh: Tensor, // [4H, H]
    pub b: Tensor,    // [4H]
    pub hidden: usize,
}

impl LSTMCell {
    pub fn new(input: usize, hidden: usize) -> LSTMCell {
        LSTMCell {
            w_ih: init::xavier_uniform(&[4 * hidden, input]).requires_grad(true),
            w_hh: init::xavier_uniform(&[4 * hidden, hidden]).requires_grad(true),
            b: Tensor::zeros(&[4 * hidden]).requires_grad(true),
            hidden,
        }
    }

    /// `(h, c) -> (h', c')` for a batch `x [N, I]`.
    pub fn step(&self, x: &Tensor, h: &Tensor, c: &Tensor) -> (Tensor, Tensor) {
        let gates = ops::add(
            &ops::linear(x, &self.w_ih, Some(&self.b)),
            &ops::linear(h, &self.w_hh, None),
        ); // [N, 4H]
        let hsz = self.hidden;
        let i = ops::sigmoid(&gates.narrow(1, 0, hsz));
        let f = ops::sigmoid(&gates.narrow(1, hsz, hsz));
        let g = ops::tanh(&gates.narrow(1, 2 * hsz, hsz));
        let o = ops::sigmoid(&gates.narrow(1, 3 * hsz, hsz));
        let c_new = ops::add(&ops::mul(&f, c), &ops::mul(&i, &g));
        let h_new = ops::mul(&o, &ops::tanh(&c_new));
        (h_new, c_new)
    }

    pub fn parameters(&self) -> Vec<Tensor> {
        vec![self.w_ih.clone(), self.w_hh.clone(), self.b.clone()]
    }
}

/// Multi-layer LSTM over a sequence `[T, N, I]`, returning all top-layer
/// hidden states `[T, N, H]` plus the final (h, c) per layer.
pub struct LSTM {
    pub cells: Vec<LSTMCell>,
    pub hidden: usize,
}

impl LSTM {
    pub fn new(input: usize, hidden: usize, layers: usize) -> LSTM {
        let mut cells = Vec::new();
        for l in 0..layers {
            cells.push(LSTMCell::new(if l == 0 { input } else { hidden }, hidden));
        }
        LSTM { cells, hidden }
    }

    /// Run the sequence; `init` optionally provides (h0, c0) per layer.
    pub fn run(
        &self,
        xs: &Tensor,
        init_state: Option<Vec<(Tensor, Tensor)>>,
    ) -> (Tensor, Vec<(Tensor, Tensor)>) {
        let (t_len, n) = (xs.size(0), xs.size(1));
        let mut state: Vec<(Tensor, Tensor)> = init_state.unwrap_or_else(|| {
            self.cells
                .iter()
                .map(|_| {
                    (
                        Tensor::zeros(&[n, self.hidden]).to_device(xs.device()),
                        Tensor::zeros(&[n, self.hidden]).to_device(xs.device()),
                    )
                })
                .collect()
        });
        let mut outputs: Vec<Tensor> = Vec::with_capacity(t_len);
        for t in 0..t_len {
            let mut x = xs.select(0, t); // [N, I]
            for (l, cell) in self.cells.iter().enumerate() {
                let (h, c) = cell.step(&x, &state[l].0, &state[l].1);
                state[l] = (h.clone(), c);
                x = h;
            }
            outputs.push(x);
        }
        let refs: Vec<&Tensor> = outputs.iter().collect();
        (ops::stack(&refs, 0), state)
    }
}

impl Module for LSTM {
    fn forward(&self, input: &Tensor) -> Tensor {
        self.run(input, None).0
    }

    fn parameters(&self) -> Vec<Tensor> {
        self.cells.iter().flat_map(|c| c.parameters()).collect()
    }

    fn name(&self) -> &'static str {
        "LSTM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_step_shapes() {
        crate::rng::manual_seed(0);
        let cell = LSTMCell::new(3, 5);
        let x = Tensor::randn(&[2, 3]);
        let h = Tensor::zeros(&[2, 5]);
        let c = Tensor::zeros(&[2, 5]);
        let (h1, c1) = cell.step(&x, &h, &c);
        assert_eq!(h1.shape(), &[2, 5]);
        assert_eq!(c1.shape(), &[2, 5]);
    }

    #[test]
    fn lstm_sequence_shapes() {
        crate::rng::manual_seed(0);
        let lstm = LSTM::new(4, 6, 2);
        let xs = Tensor::randn(&[5, 3, 4]); // T=5, N=3
        let (ys, state) = lstm.run(&xs, None);
        assert_eq!(ys.shape(), &[5, 3, 6]);
        assert_eq!(state.len(), 2);
        assert_eq!(state[0].0.shape(), &[3, 6]);
    }

    #[test]
    fn lstm_backward_through_time() {
        crate::rng::manual_seed(0);
        let lstm = LSTM::new(2, 3, 1);
        let xs = Tensor::randn(&[4, 2, 2]);
        let (ys, _) = lstm.run(&xs, None);
        ys.sum().backward();
        for p in lstm.parameters() {
            let g = p.grad().expect("param has grad");
            assert!(g.to_vec::<f32>().iter().any(|&v| v != 0.0), "non-trivial grad");
        }
    }

    #[test]
    fn hidden_state_bounded_by_tanh() {
        crate::rng::manual_seed(0);
        let lstm = LSTM::new(2, 4, 1);
        let xs = Tensor::randn(&[8, 2, 2]).mul_scalar(10.0);
        let (ys, _) = lstm.run(&xs, None);
        assert!(ys.to_vec::<f32>().iter().all(|&v| v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn forgetful_cell_ignores_history() {
        // With f-gate bias pushed very negative, c' ≈ i*g regardless of c.
        crate::rng::manual_seed(0);
        let cell = LSTMCell::new(1, 1);
        crate::autograd::no_grad(|| {
            // b layout: [i, f, g, o]; set f-bias to -100.
            let b = cell.b.to_vec::<f32>();
            let mut nb = b;
            nb[1] = -100.0;
            cell.b.copy_(&Tensor::from_vec(nb, &[4]));
        });
        let x = Tensor::zeros(&[1, 1]);
        let h = Tensor::zeros(&[1, 1]);
        let big_c = Tensor::full(&[1, 1], 100.0);
        let small_c = Tensor::zeros(&[1, 1]);
        let (_, c1) = cell.step(&x, &h, &big_c);
        let (_, c2) = cell.step(&x, &h, &small_c);
        assert!((c1.item() - c2.item()).abs() < 1e-4);
    }
}
