//! Pooling kernels: max-pool (with argmax indices for backward) and
//! average-pool over NCHW.

use super::parallel_for;

/// Shape/config for a 2-D pooling op.
#[derive(Clone, Copy, Debug)]
pub struct Pool2dArgs {
    pub batch: usize,
    pub channels: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub kernel: usize,
    pub stride: usize,
    pub padding: usize,
}

impl Pool2dArgs {
    pub fn h_out(&self) -> usize {
        (self.h_in + 2 * self.padding - self.kernel) / self.stride + 1
    }
    pub fn w_out(&self) -> usize {
        (self.w_in + 2 * self.padding - self.kernel) / self.stride + 1
    }
    pub fn out_len(&self) -> usize {
        self.batch * self.channels * self.h_out() * self.w_out()
    }
}

/// Max-pool forward; writes pooled values and the flat input index of each
/// max (per channel image) for the backward scatter.
pub fn maxpool2d_forward(args: &Pool2dArgs, input: &[f32], out: &mut [f32], indices: &mut [i64]) {
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let planes = args.batch * args.channels;
    let in_plane = args.h_in * args.w_in;
    let out_plane = h_out * w_out;
    let out_addr = out.as_mut_ptr() as usize;
    let idx_addr = indices.as_mut_ptr() as usize;
    let (out_len, idx_len) = (out.len(), indices.len());
    parallel_for(planes, 4, move |p0, p1| {
        // SAFETY: both addresses come from the caller's live `&mut out` /
        // `&mut indices` borrows (parallel_for blocks until all chunks
        // finish); chunks write disjoint plane ranges [p0*out_plane,
        // p1*out_plane).
        let out = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, out_len) };
        let indices = unsafe { std::slice::from_raw_parts_mut(idx_addr as *mut i64, idx_len) };
        for p in p0..p1 {
            let img = &input[p * in_plane..(p + 1) * in_plane];
            for oy in 0..h_out {
                for ox in 0..w_out {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0i64;
                    for ky in 0..args.kernel {
                        let iy = (oy * args.stride + ky) as isize - args.padding as isize;
                        if iy < 0 || iy >= args.h_in as isize {
                            continue;
                        }
                        for kx in 0..args.kernel {
                            let ix = (ox * args.stride + kx) as isize - args.padding as isize;
                            if ix < 0 || ix >= args.w_in as isize {
                                continue;
                            }
                            let idx = iy as usize * args.w_in + ix as usize;
                            let v = img[idx];
                            if v > best {
                                best = v;
                                best_idx = idx as i64;
                            }
                        }
                    }
                    out[p * out_plane + oy * w_out + ox] = best;
                    indices[p * out_plane + oy * w_out + ox] = best_idx;
                }
            }
        }
    });
}

/// Max-pool backward: scatter grad to the recorded argmax positions.
pub fn maxpool2d_backward(args: &Pool2dArgs, grad_out: &[f32], indices: &[i64], grad_in: &mut [f32]) {
    grad_in.fill(0.0);
    let planes = args.batch * args.channels;
    let in_plane = args.h_in * args.w_in;
    let out_plane = args.h_out() * args.w_out();
    let gi_addr = grad_in.as_mut_ptr() as usize;
    let gi_len = grad_in.len();
    parallel_for(planes, 4, move |p0, p1| {
        // SAFETY: `gi_addr/gi_len` come from the caller's live `&mut
        // grad_in` borrow (parallel_for blocks until all chunks finish);
        // the scatter stays inside plane `p`, and chunks own disjoint
        // plane ranges [p0, p1).
        let grad_in = unsafe { std::slice::from_raw_parts_mut(gi_addr as *mut f32, gi_len) };
        for p in p0..p1 {
            let gi = &mut grad_in[p * in_plane..(p + 1) * in_plane];
            let go = &grad_out[p * out_plane..(p + 1) * out_plane];
            let ids = &indices[p * out_plane..(p + 1) * out_plane];
            for (g, &i) in go.iter().zip(ids.iter()) {
                gi[i as usize] += g;
            }
        }
    });
}

/// Average-pool forward (count includes padding like PyTorch's default
/// `count_include_pad=True` for stride-covering windows; we use the
/// simpler fixed k*k divisor, which matches when padding = 0).
pub fn avgpool2d_forward(args: &Pool2dArgs, input: &[f32], out: &mut [f32]) {
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let planes = args.batch * args.channels;
    let in_plane = args.h_in * args.w_in;
    let out_plane = h_out * w_out;
    let denom = (args.kernel * args.kernel) as f32;
    let out_addr = out.as_mut_ptr() as usize;
    let out_len = out.len();
    parallel_for(planes, 4, move |p0, p1| {
        // SAFETY: `out_addr/out_len` come from the caller's live `&mut
        // out` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint plane ranges [p0*out_plane, p1*out_plane).
        let out = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, out_len) };
        for p in p0..p1 {
            let img = &input[p * in_plane..(p + 1) * in_plane];
            for oy in 0..h_out {
                for ox in 0..w_out {
                    let mut acc = 0f32;
                    for ky in 0..args.kernel {
                        let iy = (oy * args.stride + ky) as isize - args.padding as isize;
                        if iy < 0 || iy >= args.h_in as isize {
                            continue;
                        }
                        for kx in 0..args.kernel {
                            let ix = (ox * args.stride + kx) as isize - args.padding as isize;
                            if ix < 0 || ix >= args.w_in as isize {
                                continue;
                            }
                            acc += img[iy as usize * args.w_in + ix as usize];
                        }
                    }
                    out[p * out_plane + oy * w_out + ox] = acc / denom;
                }
            }
        }
    });
}

/// Average-pool backward: spread grad uniformly over each window.
pub fn avgpool2d_backward(args: &Pool2dArgs, grad_out: &[f32], grad_in: &mut [f32]) {
    grad_in.fill(0.0);
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let planes = args.batch * args.channels;
    let in_plane = args.h_in * args.w_in;
    let out_plane = h_out * w_out;
    let denom = (args.kernel * args.kernel) as f32;
    let gi_addr = grad_in.as_mut_ptr() as usize;
    let gi_len = grad_in.len();
    parallel_for(planes, 4, move |p0, p1| {
        // SAFETY: `gi_addr/gi_len` come from the caller's live `&mut
        // grad_in` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint plane ranges [p0*in_plane, p1*in_plane).
        let grad_in = unsafe { std::slice::from_raw_parts_mut(gi_addr as *mut f32, gi_len) };
        for p in p0..p1 {
            let gi = &mut grad_in[p * in_plane..(p + 1) * in_plane];
            let go = &grad_out[p * out_plane..(p + 1) * out_plane];
            for oy in 0..h_out {
                for ox in 0..w_out {
                    let g = go[oy * w_out + ox] / denom;
                    for ky in 0..args.kernel {
                        let iy = (oy * args.stride + ky) as isize - args.padding as isize;
                        if iy < 0 || iy >= args.h_in as isize {
                            continue;
                        }
                        for kx in 0..args.kernel {
                            let ix = (ox * args.stride + kx) as isize - args.padding as isize;
                            if ix < 0 || ix >= args.w_in as isize {
                                continue;
                            }
                            gi[iy as usize * args.w_in + ix as usize] += g;
                        }
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_2x2() -> Pool2dArgs {
        Pool2dArgs { batch: 1, channels: 1, h_in: 4, w_in: 4, kernel: 2, stride: 2, padding: 0 }
    }

    #[test]
    fn maxpool_picks_window_max() {
        let args = args_2x2();
        #[rustfmt::skip]
        let input = vec![
            1.0f32, 2.0, 5.0, 6.0,
            3.0, 4.0, 7.0, 8.0,
            -1.0, -2.0, 0.0, 0.5,
            -3.0, -4.0, 0.25, 0.75,
        ];
        let mut out = vec![0.0; 4];
        let mut idx = vec![0i64; 4];
        maxpool2d_forward(&args, &input, &mut out, &mut idx);
        assert_eq!(out, vec![4.0, 8.0, -1.0, 0.75]);
        assert_eq!(idx, vec![5, 7, 8, 15]);
    }

    #[test]
    fn maxpool_backward_routes_to_argmax() {
        let args = args_2x2();
        let idx = vec![5i64, 7, 8, 15];
        let go = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut gi = vec![0.0f32; 16];
        maxpool2d_backward(&args, &go, &idx, &mut gi);
        assert_eq!(gi[5], 1.0);
        assert_eq!(gi[7], 2.0);
        assert_eq!(gi[8], 3.0);
        assert_eq!(gi[15], 4.0);
        assert_eq!(gi.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn maxpool_overlapping_windows_accumulate_grad() {
        let args = Pool2dArgs { batch: 1, channels: 1, h_in: 3, w_in: 3, kernel: 2, stride: 1, padding: 0 };
        // Max at center (idx 4) for all 4 windows.
        let input = vec![0.0f32, 0.0, 0.0, 0.0, 9.0, 0.0, 0.0, 0.0, 0.0];
        let mut out = vec![0.0; 4];
        let mut idx = vec![0i64; 4];
        maxpool2d_forward(&args, &input, &mut out, &mut idx);
        assert_eq!(out, vec![9.0; 4]);
        let mut gi = vec![0.0f32; 9];
        maxpool2d_backward(&args, &[1.0; 4], &idx, &mut gi);
        assert_eq!(gi[4], 4.0);
    }

    #[test]
    fn avgpool_averages() {
        let args = args_2x2();
        let input: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut out = vec![0.0; 4];
        avgpool2d_forward(&args, &input, &mut out);
        assert_eq!(out, vec![2.5, 4.5, 10.5, 12.5]);
    }

    #[test]
    fn avgpool_backward_uniform() {
        let args = args_2x2();
        let mut gi = vec![0.0f32; 16];
        avgpool2d_backward(&args, &[4.0, 8.0, 12.0, 16.0], &mut gi);
        assert_eq!(gi[0], 1.0); // 4/4
        assert_eq!(gi[2], 2.0); // 8/4
        assert_eq!(gi[10], 4.0); // 16/4
        assert_eq!(gi.iter().sum::<f32>(), 40.0);
    }

    #[test]
    fn global_avgpool_as_full_kernel() {
        let args = Pool2dArgs { batch: 1, channels: 2, h_in: 4, w_in: 4, kernel: 4, stride: 4, padding: 0 };
        let mut input = vec![1.0f32; 32];
        for v in input[16..].iter_mut() {
            *v = 3.0;
        }
        let mut out = vec![0.0; 2];
        avgpool2d_forward(&args, &input, &mut out);
        assert_eq!(out, vec![1.0, 3.0]);
    }

    #[test]
    fn maxpool_with_padding_ignores_border() {
        let args = Pool2dArgs { batch: 1, channels: 1, h_in: 2, w_in: 2, kernel: 3, stride: 1, padding: 1 };
        let input = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut out = vec![0.0; args.out_len()];
        let mut idx = vec![0i64; args.out_len()];
        maxpool2d_forward(&args, &input, &mut out, &mut idx);
        // Every window sees element 4.0 except... all windows contain it here.
        assert_eq!(out, vec![4.0; 4]);
    }
}
