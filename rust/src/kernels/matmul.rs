//! SGEMM — the workhorse kernel (the cuBLAS stand-in).
//!
//! Row-major `C = alpha * A @ B + beta * C` with A `(m,k)`, B `(k,n)`,
//! C `(m,n)`, all contiguous. Blocked over K for cache locality with an
//! auto-vectorizable inner loop over N, parallelized across row blocks.
//! The ops layer materializes any transposed operands contiguously before
//! calling in (copy cost « gemm cost for the paper's model sizes).

use super::parallel_for;

/// K-panel size kept hot in cache.
const KC: usize = 256;

/// C(m,n) = alpha * A(m,k) @ B(k,n) + beta * C. Slices must be exactly
/// m*k, k*n, m*n long.
pub fn sgemm(m: usize, n: usize, k: usize, alpha: f32, a: &[f32], b: &[f32], beta: f32, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k, "A size");
    debug_assert_eq!(b.len(), k * n, "B size");
    debug_assert_eq!(c.len(), m * n, "C size");

    if m == 0 || n == 0 {
        return;
    }
    if k == 0 || alpha == 0.0 {
        if beta == 0.0 {
            c.fill(0.0);
        } else if beta != 1.0 {
            for x in c.iter_mut() {
                *x *= beta;
            }
        }
        return;
    }

    // SAFETY: parallel tasks write disjoint row-ranges of C.
    let c_addr = c.as_mut_ptr() as usize;
    // Grain: tiny problems run serially; everything else splits into
    // ceil(m / num_threads())-row tasks. Deriving the grain from `m` and
    // the thread count — instead of a fixed ROWS_PER_TASK floor — keeps
    // tall-skinny matmuls (m ≈ thread count) from leaving cores idle.
    let flops = 2 * m * n * k;
    let grain_rows = if flops <= 2 * super::SERIAL_GRAIN {
        m
    } else {
        m.div_ceil(super::num_threads()).max(1)
    };
    parallel_for(m, grain_rows, move |row_start, row_end| {
        let c = unsafe { std::slice::from_raw_parts_mut(c_addr as *mut f32, m * n) };
        for i in row_start..row_end {
            let crow = &mut c[i * n..(i + 1) * n];
            if beta == 0.0 {
                crow.fill(0.0);
            } else if beta != 1.0 {
                for x in crow.iter_mut() {
                    *x *= beta;
                }
            }
        }
        // K-blocked accumulation with an 8-row microkernel: each loaded
        // B row updates 8 C rows, cutting B-stream bandwidth 8x (§Perf:
        // 2.0x over the 1-row axpy kernel on the AVX-512 testbed).
        gemm_panel(row_start, row_end, n, k, alpha, a, b, c);
    });
}

/// Batched GEMM over leading batch dim: C[b] = A[b] @ B[b].
pub fn sgemm_batched(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), batch * m * k);
    debug_assert_eq!(b.len(), batch * k * n);
    debug_assert_eq!(c.len(), batch * m * n);
    let c_addr = c.as_mut_ptr() as usize;
    parallel_for(batch, 1, move |b0, b1| {
        let c_all = unsafe { std::slice::from_raw_parts_mut(c_addr as *mut f32, batch * m * n) };
        for i in b0..b1 {
            serial_gemm(
                m,
                n,
                k,
                &a[i * m * k..(i + 1) * m * k],
                &b[i * k * n..(i + 1) * k * n],
                &mut c_all[i * m * n..(i + 1) * m * n],
            );
        }
    });
}

/// Single-threaded gemm used inside already-parallel regions.
fn serial_gemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    c.fill(0.0);
    gemm_panel(0, m, n, k, 1.0, a, b, c);
}

/// The shared 8-row microkernel over C rows [row_start, row_end).
/// C must already hold the beta-scaled values; this accumulates.
pub(crate) fn gemm_panel(
    row_start: usize,
    row_end: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    const MR: usize = 8;
    let mut p0 = 0;
    while p0 < k {
        let pend = (p0 + KC).min(k);
        let mut i = row_start;
        while i + MR <= row_end {
            // SAFETY: the MR row slices are disjoint ranges of C.
            let cp = c.as_mut_ptr();
            let crows: [&mut [f32]; MR] = std::array::from_fn(|r| unsafe {
                std::slice::from_raw_parts_mut(cp.add((i + r) * n), n)
            });
            for p in p0..pend {
                let xs: [f32; MR] = std::array::from_fn(|r| alpha * a[(i + r) * k + p]);
                let brow = &b[p * n..(p + 1) * n];
                for (j, &bv) in brow.iter().enumerate() {
                    let mut r = 0;
                    while r < MR {
                        crows[r][j] += xs[r] * bv;
                        r += 1;
                    }
                }
            }
            i += MR;
        }
        // Remainder rows: scalar-A axpy.
        while i < row_end {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in p0..pend {
                let aip = alpha * arow[p];
                if aip == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aip * *bj;
                }
            }
            i += 1;
        }
        p0 = pend;
    }
}

/// Row-major `C = A @ B` in f64 — the precision-dtype GEMM behind the
/// dispatcher's F64 matmul entries. Parallel over rows with an axpy inner
/// loop; correctness-oriented (f64 is the gradcheck dtype, not the
/// throughput one).
pub fn dgemm(m: usize, n: usize, k: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k, "A size");
    debug_assert_eq!(b.len(), k * n, "B size");
    debug_assert_eq!(c.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    // SAFETY: parallel tasks write disjoint row-ranges of C.
    let c_addr = c.as_mut_ptr() as usize;
    parallel_for(m, 8, move |row_start, row_end| {
        let c = unsafe { std::slice::from_raw_parts_mut(c_addr as *mut f64, m * n) };
        for i in row_start..row_end {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            crow.fill(0.0);
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * n..(p + 1) * n];
                for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += av * bj;
                }
            }
        }
    });
}

/// Batched f64 GEMM over the leading batch dim: C[b] = A[b] @ B[b].
pub fn dgemm_batched(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
) {
    debug_assert_eq!(a.len(), batch * m * k);
    debug_assert_eq!(b.len(), batch * k * n);
    debug_assert_eq!(c.len(), batch * m * n);
    for i in 0..batch {
        dgemm(
            m,
            n,
            k,
            &a[i * m * k..(i + 1) * m * k],
            &b[i * k * n..(i + 1) * k * n],
            &mut c[i * m * n..(i + 1) * m * n],
        );
    }
}

/// Naive reference for tests: straightforward triple loop.
pub fn matmul_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.uniform_range(-1.0, 1.0)).collect()
    }

    fn check(m: usize, n: usize, k: usize, seed: u64) {
        let mut r = Rng::new(seed);
        let a = rand_vec(&mut r, m * k);
        let b = rand_vec(&mut r, k * n);
        let mut c = vec![0.0f32; m * n];
        sgemm(m, n, k, 1.0, &a, &b, 0.0, &mut c);
        let expect = matmul_ref(m, n, k, &a, &b);
        for (i, (&x, &y)) in c.iter().zip(expect.iter()).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                "({m},{n},{k}) idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn matches_reference_small() {
        check(1, 1, 1, 1);
        check(2, 3, 4, 2);
        check(5, 7, 11, 3);
        check(16, 16, 16, 4);
    }

    #[test]
    fn matches_reference_medium_parallel() {
        check(128, 96, 200, 5);
        check(257, 129, 300, 6); // odd sizes cross block boundaries
    }

    #[test]
    fn shape_sweep_tall_skinny_and_odd() {
        // Tall-skinny / tiny-m shapes the old fixed ROWS_PER_TASK grain
        // served with a single task; the grain now derives from m and
        // num_threads(), so every shape must still match the reference.
        let mut seed = 100;
        for &m in &[1usize, 2, 3, 4, 7, 8, 9, 15, 16, 31, 33, 100] {
            for &(n, k) in &[(64usize, 64usize), (33, 129), (256, 16)] {
                seed += 1;
                check(m, n, k, seed);
            }
        }
    }

    #[test]
    fn k_blocking_boundary() {
        check(8, 8, KC + 3, 7);
        check(8, 8, 2 * KC, 8);
    }

    #[test]
    fn alpha_beta_semantics() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![1.0f32, 0.0, 0.0, 1.0]; // identity
        let mut c = vec![10.0f32, 20.0, 30.0, 40.0];
        sgemm(2, 2, 2, 2.0, &a, &b, 0.5, &mut c);
        assert_eq!(c, vec![2.0 + 5.0, 4.0 + 10.0, 6.0 + 15.0, 8.0 + 20.0]);
    }

    #[test]
    fn zero_k_scales_c_by_beta() {
        let mut c = vec![2.0f32; 4];
        sgemm(2, 2, 0, 1.0, &[], &[], 0.0, &mut c);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    fn dgemm_matches_reference() {
        let mut r = Rng::new(10);
        let (m, n, k) = (7, 5, 9);
        let a32 = rand_vec(&mut r, m * k);
        let b32 = rand_vec(&mut r, k * n);
        let a: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
        let b: Vec<f64> = b32.iter().map(|&x| x as f64).collect();
        let mut c = vec![0.0f64; m * n];
        dgemm(m, n, k, &a, &b, &mut c);
        let expect = matmul_ref(m, n, k, &a32, &b32);
        for (i, (&x, &y)) in c.iter().zip(expect.iter()).enumerate() {
            assert!((x as f32 - y).abs() <= 1e-4 + 1e-4 * y.abs(), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn batched_matches_loop() {
        let mut r = Rng::new(9);
        let (batch, m, n, k) = (4, 6, 5, 7);
        let a = rand_vec(&mut r, batch * m * k);
        let b = rand_vec(&mut r, batch * k * n);
        let mut c = vec![0.0f32; batch * m * n];
        sgemm_batched(batch, m, n, k, &a, &b, &mut c);
        for i in 0..batch {
            let expect = matmul_ref(m, n, k, &a[i * m * k..(i + 1) * m * k], &b[i * k * n..(i + 1) * k * n]);
            for (j, (&x, &y)) in c[i * m * n..(i + 1) * m * n].iter().zip(expect.iter()).enumerate() {
                assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs(), "batch {i} idx {j}");
            }
        }
    }
}
