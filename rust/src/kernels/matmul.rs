//! The GEMM core — torsk's cuBLAS stand-in: a packed, transpose-aware,
//! BLIS-style blocked kernel.
//!
//! Row-major everywhere. `C = alpha * op(A) @ op(B) + beta * C` with `op`
//! selected by [`Trans`] flags, or — one level lower — by explicit
//! `(row, col)` element strides ([`sgemm_strided`]), so transposed (and
//! narrowed, and stride-0 broadcast) operands are consumed **in place**:
//! the packing routines read through the strides and nothing is ever
//! materialized.
//!
//! # Blocking and packing (the BLIS decomposition)
//!
//! ```text
//! task grid: (row blocks) x (column blocks)           parallel, disjoint C
//!   for pc in 0..k step KC                            serial, in k order
//!     pack A[i0.., pc..] into MR-row panels           (a_pack)
//!     pack B[pc.., j0..] into NR-column panels        (b_pack)
//!     for jr, ir: MR x NR register-tiled microkernel
//! ```
//!
//! Block sizes start at the `(MC, NC)` maxima and shrink per shape (see
//! `pick_blocks`) so skinny matrices still produce a grid wide enough to
//! fill a pool — block boundaries never affect the computed bits.
//!
//! * A panels: `a_pack[ip*kc*MR + p*MR + r] = A(i0 + ip*MR + r, pc + p)`,
//!   k-major with MR rows interleaved, zero-padded past the `m` edge.
//! * B panels: `b_pack[jp*kc*NR + p*NR + c] = B(pc + p, j0 + jp*NR + c)`,
//!   zero-padded past the `n` edge. K is never padded.
//! * Microkernel: an MR x NR accumulator tile lives in registers across
//!   the whole KC panel; `alpha` and `beta` apply at tile write-back
//!   (`beta` on the first k-panel only).
//!
//! f32 runs an 8x8 microkernel (the autovectorizer's sweet spot: 8 rows
//! of one 8-lane vector each), f64 a simpler 4x4 packed path. Blocking
//! parameters: `MC = 64`, `KC = 256`, `NC = 512` — the A block is 64 KiB
//! and the B block 512 KiB at f32.
//!
//! # Determinism
//!
//! Results are bit-for-bit identical at every thread count, by
//! construction: the tile grid and the k-panel walk derive only from
//! `(m, n, k)` and the constants above, each C tile has exactly one
//! writing task, and every tile accumulates its k panels serially in k
//! order through the microkernel's fixed-order loop. No partial-sum
//! boundary ever derives from the worker count.
//! `tests/gemm_parity.rs` and `tests/parallel_determinism.rs` pin this at
//! 1/2/8 threads.
//!
//! # Prepacked weights
//!
//! [`pack_b_strided_f32`] emits the full packed-B buffer in exactly the
//! layout the driver consumes; [`sgemm_prepacked`] then skips B packing
//! entirely. `dispatch::linalg` caches packed `nn::Linear` weights keyed
//! by (tensor id, storage version), so steady-state forwards do zero
//! weight copies or packs.

use super::parallel_for;

/// K-panel depth kept hot across a tile row.
pub const KC: usize = 256;
/// Rows of A per packed block (a multiple of every MR) — the *maximum*;
/// [`pick_blocks`] shrinks it for shapes whose natural grid is too coarse.
pub const MC: usize = 64;
/// Columns of B per packed block (a multiple of every NR) — the maximum.
pub const NC: usize = 512;
/// Minimum task-grid size [`pick_blocks`] aims for. A *constant* (never
/// the thread count): common model shapes (tall-skinny activations,
/// linear layers) produce only 1–4 blocks at the full MC x NC sizes,
/// which would leave most of any pool idle. Values are block-size
/// independent (see `pick_blocks`), so this is purely a scheduling knob.
const GRID_TARGET: usize = 32;

const MR_F32: usize = 8;
const NR_F32: usize = 8;
const MR_F64: usize = 4;
const NR_F64: usize = 4;

/// Operand layout flag (BLAS-style). Under `Trans::T` the slice holds the
/// matrix transposed: for an `(m, k)` A the buffer is a dense row-major
/// `(k, m)` matrix and `A(i, p) = buf[p*m + i]`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    /// Operand is stored row-major as its logical shape.
    N,
    /// Operand is stored row-major transposed.
    T,
}

/// Element type the packed core is generic over (f32 / f64).
pub trait GemmScalar:
    Copy
    + Send
    + Sync
    + PartialEq
    + std::ops::Add<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;

    /// Vectorized whole-panel tile accumulation
    /// ([`crate::kernels::simd`]): accumulate the `kc`-deep panels into
    /// the flattened MR×NR `acc` tile, bit-identically to the scalar
    /// loop. Returns `false` (leaving `acc` untouched) when no vector
    /// path is active — the microkernel then runs its scalar loop.
    fn simd_acc(kc: usize, a_panel: &[Self], b_panel: &[Self], acc: &mut [Self]) -> bool;
}

impl GemmScalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    fn simd_acc(kc: usize, a_panel: &[f32], b_panel: &[f32], acc: &mut [f32]) -> bool {
        super::simd::gemm_acc_f32(kc, a_panel, b_panel, acc)
    }
}

impl GemmScalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    fn simd_acc(kc: usize, a_panel: &[f64], b_panel: &[f64], acc: &mut [f64]) -> bool {
        super::simd::gemm_acc_f64(kc, a_panel, b_panel, acc)
    }
}

/// Raw strided matrix operand: `M(i, j) = *base.add(i*rs + j*cs)`. The
/// base is stored as a `usize` address so closures capturing it are
/// `Send + Sync` (the `SendPtr` convention).
#[derive(Clone, Copy)]
struct MatRef {
    addr: usize,
    rs: usize,
    cs: usize,
}

impl MatRef {
    fn new<T>(s: &[T], rs: usize, cs: usize) -> MatRef {
        MatRef { addr: s.as_ptr() as usize, rs, cs }
    }

    fn offset<T>(self, elems: usize) -> MatRef {
        MatRef { addr: self.addr + elems * std::mem::size_of::<T>(), ..self }
    }

    /// # Safety: caller guarantees `(i, j)` is in bounds of the backing
    /// allocation for the lifetime of the call.
    #[inline(always)]
    unsafe fn at<T: Copy>(&self, i: usize, j: usize) -> T {
        // SAFETY: in-bounds per this fn's contract.
        unsafe { *(self.addr as *const T).add(i * self.rs + j * self.cs) }
    }
}

/// Where the driver finds B: a strided matrix packed on the fly, or a
/// caller-provided buffer already in the canonical packed layout.
#[derive(Clone, Copy)]
enum BSrc {
    Strided(MatRef),
    Packed { addr: usize },
}

fn trans_strides_a(ta: Trans, m: usize, k: usize) -> (usize, usize) {
    match ta {
        Trans::N => (k, 1),
        Trans::T => (1, m),
    }
}

fn trans_strides_b(tb: Trans, k: usize, n: usize) -> (usize, usize) {
    match tb {
        Trans::N => (n, 1),
        Trans::T => (1, k),
    }
}

/// The degenerate-case table, explicit and unit-tested. When `k == 0` or
/// `alpha == 0` the product term vanishes and `C = beta * C` exactly:
///
/// | beta  | action                                          |
/// |-------|-------------------------------------------------|
/// | `0`   | `C <- 0` (also clears pre-existing NaN/garbage) |
/// | `1`   | no-op — C is already the answer                 |
/// | other | scale C in place                                |
///
/// Returns `true` when the caller must skip the product entirely.
fn degenerate_early_out<T: GemmScalar>(k: usize, alpha: T, beta: T, c: &mut [T]) -> bool {
    if k != 0 && alpha != T::ZERO {
        return false;
    }
    if beta == T::ZERO {
        c.fill(T::ZERO);
    } else if beta != T::ONE {
        for x in c.iter_mut() {
            *x = beta * *x;
        }
    }
    true
}

/// Pack the `mc x kc` block of A at `(i0, p0)` into MR-row panels:
/// `dst[ip*kc*MR + p*MR + r] = A(i0 + ip*MR + r, p0 + p)`, rows past `mc`
/// zero-padded so edge tiles run the same microkernel.
fn pack_a<T: GemmScalar, const MR: usize>(
    dst: &mut [T],
    a: MatRef,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
) {
    for ip in 0..mc.div_ceil(MR) {
        let rows = (mc - ip * MR).min(MR);
        let base = ip * kc * MR;
        for p in 0..kc {
            let off = base + p * MR;
            for (r, d) in dst[off..off + rows].iter_mut().enumerate() {
                // SAFETY: the driver clamps the block to mc <= m - i0 and
                // kc <= k - p0, so the row/col indices stay inside A.
                *d = unsafe { a.at(i0 + ip * MR + r, p0 + p) };
            }
            for d in dst[off + rows..off + MR].iter_mut() {
                *d = T::ZERO;
            }
        }
    }
}

/// Pack the `kc x nc` block of B at `(p0, j0)` into NR-column panels:
/// `dst[jp*kc*NR + p*NR + c] = B(p0 + p, j0 + jp*NR + c)`, columns past
/// `nc` zero-padded.
fn pack_b<T: GemmScalar, const NR: usize>(
    dst: &mut [T],
    b: MatRef,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
) {
    for jp in 0..nc.div_ceil(NR) {
        let cols = (nc - jp * NR).min(NR);
        let base = jp * kc * NR;
        for p in 0..kc {
            let off = base + p * NR;
            for (c, d) in dst[off..off + cols].iter_mut().enumerate() {
                // SAFETY: the driver clamps the block to kc <= k - p0 and
                // nc <= n - j0, so the row/col indices stay inside B.
                *d = unsafe { b.at(p0 + p, j0 + jp * NR + c) };
            }
            for d in dst[off + cols..off + NR].iter_mut() {
                *d = T::ZERO;
            }
        }
    }
}

/// Pack ALL of a strided `k x n` B into the canonical full layout: KC-tall
/// blocks in k order (block at k offset `p0` starts at element
/// `p0 * ceil(n/NR)*NR`), each holding every NR panel of that block in
/// column order. [`sgemm_prepacked`] consumes this directly.
fn pack_b_full<T: GemmScalar, const NR: usize>(b: MatRef, k: usize, n: usize) -> Vec<T> {
    let n_padded = n.div_ceil(NR) * NR;
    let mut out = vec![T::ZERO; k * n_padded];
    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        pack_b::<T, NR>(&mut out[p0 * n_padded..(p0 + kc) * n_padded], b, p0, kc, 0, n);
        p0 += kc;
    }
    out
}

/// Pack a strided f32 `k x n` B (`B(p, j) = b[p*rsb + j*csb]`) for
/// [`sgemm_prepacked`]. For `W [n, k]` row-major used as `B = Wᵀ`, pass
/// `rsb = 1, csb = k`.
pub fn pack_b_strided_f32(k: usize, n: usize, b: &[f32], rsb: usize, csb: usize) -> Vec<f32> {
    if k == 0 || n == 0 {
        return Vec::new();
    }
    check_span("pack_b B", b, k, rsb, n, csb);
    pack_b_full::<f32, NR_F32>(MatRef::new(b, rsb, csb), k, n)
}

/// Dense row-major helper over [`pack_b_strided_f32`] with a layout flag.
pub fn pack_b_f32(tb: Trans, k: usize, n: usize, b: &[f32]) -> Vec<f32> {
    let (rsb, csb) = trans_strides_b(tb, k, n);
    pack_b_strided_f32(k, n, b, rsb, csb)
}

/// The register-tiled MR x NR microkernel: accumulate the whole `kc`
/// panel into a register tile in fixed p order, then write back
/// `beta'*C + alpha*acc` (`beta'` only on the first k panel — `beta` is
/// `Some` then, `None` on later panels). `mr`/`nr` clip the write to the
/// valid region of edge tiles; the padded panel rows/columns only feed
/// the clipped-away accumulators, never the k sum.
#[inline(always)]
fn microkernel<T: GemmScalar, const MR: usize, const NR: usize>(
    kc: usize,
    alpha: T,
    a_panel: &[T],
    b_panel: &[T],
    c_addr: usize,
    ldc: usize,
    mr: usize,
    nr: usize,
    beta: Option<T>,
) {
    debug_assert!(a_panel.len() >= kc * MR && b_panel.len() >= kc * NR);
    let mut acc = [[T::ZERO; NR]; MR];
    // Vector fast path: same per-element k order (acc[i][j] accumulates
    // a[i]*b[j] for p ascending, mul and add rounded separately), so the
    // bits match the scalar loop exactly; declines to it when no vector
    // unit is active (see kernels/simd.rs).
    if !T::simd_acc(kc, &a_panel[..kc * MR], &b_panel[..kc * NR], acc.as_flattened_mut()) {
        for p in 0..kc {
            let av = &a_panel[p * MR..p * MR + MR];
            let bv = &b_panel[p * NR..p * NR + NR];
            for (acc_i, &ai) in acc.iter_mut().zip(av.iter()) {
                for (aij, &bj) in acc_i.iter_mut().zip(bv.iter()) {
                    *aij += ai * bj;
                }
            }
        }
    }
    let cp = c_addr as *mut T;
    // SAFETY: the caller hands each (task, tile) a disjoint C region.
    unsafe {
        match beta {
            None => {
                for (i, acc_i) in acc.iter().enumerate().take(mr) {
                    let row = std::slice::from_raw_parts_mut(cp.add(i * ldc), nr);
                    for (cj, &aij) in row.iter_mut().zip(acc_i.iter()) {
                        *cj += alpha * aij;
                    }
                }
            }
            Some(b0) if b0 == T::ZERO => {
                for (i, acc_i) in acc.iter().enumerate().take(mr) {
                    let row = std::slice::from_raw_parts_mut(cp.add(i * ldc), nr);
                    for (cj, &aij) in row.iter_mut().zip(acc_i.iter()) {
                        *cj = alpha * aij;
                    }
                }
            }
            Some(b0) => {
                for (i, acc_i) in acc.iter().enumerate().take(mr) {
                    let row = std::slice::from_raw_parts_mut(cp.add(i * ldc), nr);
                    for (cj, &aij) in row.iter_mut().zip(acc_i.iter()) {
                        *cj = b0 * *cj + alpha * aij;
                    }
                }
            }
        }
    }
}

/// Pick the `(row, column)` block sizes for the task grid: start at the
/// `(MC, NC)` maxima (clamped to the matrix) and halve the larger block —
/// keeping MR/NR multiples — until the grid reaches [`GRID_TARGET`] tasks
/// or both blocks hit the microkernel floor.
///
/// Derived from `(m, n)` and constants only. More fundamentally, block
/// sizes cannot change results at all: every C element is accumulated by
/// one microkernel call per KC panel, in a fixed per-panel p order, with
/// panels applied in k order — which tile or task it lands in never
/// enters the arithmetic. Only `KC` and the microkernel loop shape the
/// bits, and both are constants.
fn pick_blocks<const MR: usize, const NR: usize>(m: usize, n: usize) -> (usize, usize) {
    let mut mc = MC.min(m.div_ceil(MR) * MR);
    let mut nc = NC.min(n.div_ceil(NR) * NR);
    loop {
        if m.div_ceil(mc) * n.div_ceil(nc) >= GRID_TARGET {
            return (mc, nc);
        }
        let mc2 = ((mc / 2).max(MR)).div_ceil(MR) * MR;
        let nc2 = ((nc / 2).max(NR)).div_ceil(NR) * NR;
        let m_gain = m.div_ceil(mc2) > m.div_ceil(mc);
        let n_gain = n.div_ceil(nc2) > n.div_ceil(nc);
        if m_gain && (mc >= nc || !n_gain) {
            mc = mc2;
        } else if n_gain {
            nc = nc2;
        } else {
            return (mc, nc); // no split can add tasks
        }
    }
}

/// The blocked driver: a 2-D task grid over (row blocks x column blocks,
/// sized by [`pick_blocks`]); each task walks the shared KC panels of its
/// block serially in k order, packing its own A (and, unless prepacked,
/// B) panels. Tasks write disjoint C tiles, so the grid parallelizes
/// freely without changing a single bit of the result.
#[allow(clippy::too_many_arguments)]
fn gemm_driver<T: GemmScalar, const MR: usize, const NR: usize>(
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: MatRef,
    b: BSrc,
    beta: T,
    c_addr: usize,
    parallel: bool,
) {
    let (mcb, ncb) = pick_blocks::<MR, NR>(m, n);
    let row_blocks = m.div_ceil(mcb);
    let col_blocks = n.div_ceil(ncb);
    let tasks = row_blocks * col_blocks;
    let n_padded = n.div_ceil(NR) * NR;
    let kc_max = KC.min(k);
    let elem = std::mem::size_of::<T>();

    let run_block = move |t: usize| {
        let i0 = (t / col_blocks) * mcb;
        let mc = mcb.min(m - i0);
        let j0 = (t % col_blocks) * ncb;
        let nc = ncb.min(n - j0);
        let mut a_pack = vec![T::ZERO; mc.div_ceil(MR) * MR * kc_max];
        let mut b_pack = match b {
            BSrc::Strided(_) => vec![T::ZERO; nc.div_ceil(NR) * NR * kc_max],
            BSrc::Packed { .. } => Vec::new(),
        };
        let mut p0 = 0;
        while p0 < k {
            let kc = KC.min(k - p0);
            pack_a::<T, MR>(&mut a_pack, a, i0, mc, p0, kc);
            if let BSrc::Strided(bm) = b {
                pack_b::<T, NR>(&mut b_pack, bm, p0, kc, j0, nc);
            }
            let first = if p0 == 0 { Some(beta) } else { None };
            for jr in 0..nc.div_ceil(NR) {
                let jj = j0 + jr * NR;
                let nr = NR.min(j0 + nc - jj);
                let b_panel: &[T] = match b {
                    BSrc::Strided(_) => &b_pack[jr * kc * NR..(jr + 1) * kc * NR],
                    // Full-layout lookup: block at p0 * n_padded, global
                    // panel index j0/NR + jr, each panel kc*NR long.
                    // SAFETY: pack_b_full laid out k_padded * n_padded
                    // elements at `addr`; the caller keeps that buffer
                    // alive for the whole GEMM, and the panel offset is
                    // within it by the layout equation above.
                    BSrc::Packed { addr } => unsafe {
                        std::slice::from_raw_parts(
                            (addr as *const T).add(p0 * n_padded + (j0 / NR + jr) * kc * NR),
                            kc * NR,
                        )
                    },
                };
                for ir in 0..mc.div_ceil(MR) {
                    let ii = i0 + ir * MR;
                    let mr = MR.min(i0 + mc - ii);
                    microkernel::<T, MR, NR>(
                        kc,
                        alpha,
                        &a_pack[ir * kc * MR..(ir + 1) * kc * MR],
                        b_panel,
                        c_addr + (ii * n + jj) * elem,
                        n,
                        mr,
                        nr,
                        first,
                    );
                }
            }
            p0 += kc;
        }
    };

    if !parallel || tasks == 1 {
        for t in 0..tasks {
            run_block(t);
        }
    } else {
        parallel_for(tasks, 1, move |t0, t1| {
            for t in t0..t1 {
                run_block(t);
            }
        });
    }
}

/// Shared entry: degenerate table, then the blocked driver.
#[allow(clippy::too_many_arguments)]
fn gemm_entry<T: GemmScalar, const MR: usize, const NR: usize>(
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: MatRef,
    b: BSrc,
    beta: T,
    c: &mut [T],
    parallel: bool,
) {
    debug_assert_eq!(c.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    if degenerate_early_out(k, alpha, beta, c) {
        return;
    }
    gemm_driver::<T, MR, NR>(m, n, k, alpha, a, b, beta, c.as_mut_ptr() as usize, parallel);
}

/// Parallelize when the arithmetic dwarfs a pool wakeup (same threshold
/// family as the TensorIter drivers).
fn worth_parallelizing(m: usize, n: usize, k: usize) -> bool {
    m.saturating_mul(n).saturating_mul(k) > super::SERIAL_GRAIN
}

/// Bounds check for a strided operand: the largest reachable element
/// must sit inside the slice. Always on (not `debug_assert`): the packed
/// core reads operands through raw pointers, so this O(1) check is what
/// turns a caller's bad stride into a panic instead of an out-of-bounds
/// read — the same guarantee the old safe-indexing kernel gave.
#[track_caller]
fn check_span<T>(what: &str, s: &[T], d0: usize, st0: usize, d1: usize, st1: usize) {
    assert!(
        d0 == 0 || d1 == 0 || (d0 - 1) * st0 + (d1 - 1) * st1 < s.len(),
        "{what}: strided operand reaches element {} but the slice has {}",
        (d0 - 1) * st0 + (d1 - 1) * st1,
        s.len()
    );
}

/// Batched variant of [`check_span`] (adds the batch axis).
#[track_caller]
#[allow(clippy::too_many_arguments)]
fn check_span_batched<T>(
    what: &str,
    s: &[T],
    batch: usize,
    bs: usize,
    d0: usize,
    st0: usize,
    d1: usize,
    st1: usize,
) {
    assert!(
        batch == 0
            || d0 == 0
            || d1 == 0
            || (batch - 1) * bs + (d0 - 1) * st0 + (d1 - 1) * st1 < s.len(),
        "{what}: strided batched operand reaches element {} but the slice has {}",
        (batch - 1) * bs + (d0 - 1) * st0 + (d1 - 1) * st1,
        s.len()
    );
}

// ---------------------------------------------------------------------
// Public f32 entries
// ---------------------------------------------------------------------

/// `C(m,n) = alpha * op(A) @ op(B) + beta * C`, all buffers dense
/// row-major (`a` is `(m,k)` under `Trans::N`, `(k,m)` under `Trans::T`;
/// `b` likewise `(k,n)` / `(n,k)`).
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k, "A size");
    debug_assert_eq!(b.len(), k * n, "B size");
    let (rsa, csa) = trans_strides_a(ta, m, k);
    let (rsb, csb) = trans_strides_b(tb, k, n);
    sgemm_strided(m, n, k, alpha, a, rsa, csa, b, rsb, csb, beta, c);
}

/// The fully strided f32 entry: `A(i,p) = a[i*rsa + p*csa]`,
/// `B(p,j) = b[p*rsb + j*csb]`, C dense row-major. Any stride pattern —
/// transposed views, narrowed slices, stride-0 broadcasts — is packed
/// directly, never materialized.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_strided(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    rsa: usize,
    csa: usize,
    b: &[f32],
    rsb: usize,
    csb: usize,
    beta: f32,
    c: &mut [f32],
) {
    check_span("sgemm A", a, m, rsa, k, csa);
    check_span("sgemm B", b, k, rsb, n, csb);
    gemm_entry::<f32, MR_F32, NR_F32>(
        m,
        n,
        k,
        alpha,
        MatRef::new(a, rsa, csa),
        BSrc::Strided(MatRef::new(b, rsb, csb)),
        beta,
        c,
        worth_parallelizing(m, n, k),
    );
}

/// [`sgemm`] that never fans out to the pool — for call sites already
/// inside a `parallel_for` region (the conv im2col loops).
#[allow(clippy::too_many_arguments)]
pub fn sgemm_serial(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k, "A size");
    debug_assert_eq!(b.len(), k * n, "B size");
    let (rsa, csa) = trans_strides_a(ta, m, k);
    let (rsb, csb) = trans_strides_b(tb, k, n);
    check_span("sgemm_serial A", a, m, rsa, k, csa);
    check_span("sgemm_serial B", b, k, rsb, n, csb);
    gemm_entry::<f32, MR_F32, NR_F32>(
        m,
        n,
        k,
        alpha,
        MatRef::new(a, rsa, csa),
        BSrc::Strided(MatRef::new(b, rsb, csb)),
        beta,
        c,
        false,
    );
}

/// GEMM against a B prepacked by [`pack_b_strided_f32`] — the
/// `nn::Linear` steady-state path (zero copies, zero packing).
/// Bit-identical to the pack-on-the-fly entries: the packed values and
/// the tile walk are exactly the same.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_prepacked(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    rsa: usize,
    csa: usize,
    packed_b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(
        packed_b.len() >= k * n.div_ceil(NR_F32) * NR_F32,
        "prepacked B too short for (k={k}, n={n})"
    );
    check_span("sgemm_prepacked A", a, m, rsa, k, csa);
    gemm_entry::<f32, MR_F32, NR_F32>(
        m,
        n,
        k,
        alpha,
        MatRef::new(a, rsa, csa),
        BSrc::Packed { addr: packed_b.as_ptr() as usize },
        beta,
        c,
        worth_parallelizing(m, n, k),
    );
}

// ---------------------------------------------------------------------
// Public f64 entries
// ---------------------------------------------------------------------

/// f64 `C = alpha * op(A) @ op(B) + beta * C` — the precision-dtype GEMM
/// behind the dispatcher's F64 entries, on the same packed core with a
/// simpler 4x4 microkernel.
#[allow(clippy::too_many_arguments)]
pub fn dgemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    b: &[f64],
    beta: f64,
    c: &mut [f64],
) {
    debug_assert_eq!(a.len(), m * k, "A size");
    debug_assert_eq!(b.len(), k * n, "B size");
    let (rsa, csa) = trans_strides_a(ta, m, k);
    let (rsb, csb) = trans_strides_b(tb, k, n);
    dgemm_strided(m, n, k, alpha, a, rsa, csa, b, rsb, csb, beta, c);
}

/// Fully strided f64 entry; see [`sgemm_strided`].
#[allow(clippy::too_many_arguments)]
pub fn dgemm_strided(
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    rsa: usize,
    csa: usize,
    b: &[f64],
    rsb: usize,
    csb: usize,
    beta: f64,
    c: &mut [f64],
) {
    check_span("dgemm A", a, m, rsa, k, csa);
    check_span("dgemm B", b, k, rsb, n, csb);
    gemm_entry::<f64, MR_F64, NR_F64>(
        m,
        n,
        k,
        alpha,
        MatRef::new(a, rsa, csa),
        BSrc::Strided(MatRef::new(b, rsb, csb)),
        beta,
        c,
        worth_parallelizing(m, n, k),
    );
}

// ---------------------------------------------------------------------
// Batched entries (the bmm kernels)
// ---------------------------------------------------------------------

/// Shared batched driver: parallel over the batch dim when batches can
/// fill the pool (one serial packed GEMM per batch element), otherwise a
/// serial batch loop whose per-matrix GEMMs parallelize internally. Both
/// schedules produce bit-identical results — the tile decomposition never
/// depends on the schedule.
#[allow(clippy::too_many_arguments)]
fn gemm_batched_driver<T: GemmScalar, const MR: usize, const NR: usize>(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: MatRef,
    bsa: usize,
    b: MatRef,
    bsb: usize,
    c: &mut [T],
) {
    debug_assert_eq!(c.len(), batch * m * n, "C size");
    if batch == 0 || m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(T::ZERO);
        return;
    }
    let per_c = m * n;
    let c_addr = c.as_mut_ptr() as usize;
    let run_one = move |i: usize, parallel: bool| {
        // SAFETY: batch element i owns the disjoint C slice [i*per_c ..).
        let ci = unsafe {
            std::slice::from_raw_parts_mut((c_addr as *mut T).add(i * per_c), per_c)
        };
        gemm_entry::<T, MR, NR>(
            m,
            n,
            k,
            T::ONE,
            a.offset::<T>(i * bsa),
            BSrc::Strided(b.offset::<T>(i * bsb)),
            T::ZERO,
            ci,
            parallel,
        );
    };
    let total_work = batch.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if batch >= super::num_threads() && total_work > super::SERIAL_GRAIN {
        parallel_for(batch, 1, move |b0, b1| {
            for i in b0..b1 {
                run_one(i, false);
            }
        });
    } else {
        let inner = worth_parallelizing(m, n, k);
        for i in 0..batch {
            run_one(i, inner);
        }
    }
}

/// Batched f32 GEMM over dense `[batch, m, k] @ [batch, k, n]`.
pub fn sgemm_batched(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), batch * m * k);
    debug_assert_eq!(b.len(), batch * k * n);
    sgemm_batched_strided(batch, m, n, k, a, m * k, k, 1, b, k * n, n, 1, c)
}

/// Fully strided batched f32 GEMM: `A_i(r, p) = a[i*bsa + r*rsa + p*csa]`
/// (likewise B), C dense `[batch, m, n]` — transposed bmm operands are
/// consumed without materialization.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_batched_strided(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    bsa: usize,
    rsa: usize,
    csa: usize,
    b: &[f32],
    bsb: usize,
    rsb: usize,
    csb: usize,
    c: &mut [f32],
) {
    check_span_batched("sgemm_batched A", a, batch, bsa, m, rsa, k, csa);
    check_span_batched("sgemm_batched B", b, batch, bsb, k, rsb, n, csb);
    gemm_batched_driver::<f32, MR_F32, NR_F32>(
        batch,
        m,
        n,
        k,
        MatRef::new(a, rsa, csa),
        bsa,
        MatRef::new(b, rsb, csb),
        bsb,
        c,
    );
}

/// Batched f64 GEMM over dense `[batch, m, k] @ [batch, k, n]` — now
/// batch-parallel through the same driver as the f32 path (it used to be
/// a serial loop).
pub fn dgemm_batched(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
) {
    debug_assert_eq!(a.len(), batch * m * k);
    debug_assert_eq!(b.len(), batch * k * n);
    dgemm_batched_strided(batch, m, n, k, a, m * k, k, 1, b, k * n, n, 1, c)
}

/// Fully strided batched f64 GEMM; see [`sgemm_batched_strided`].
#[allow(clippy::too_many_arguments)]
pub fn dgemm_batched_strided(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    bsa: usize,
    rsa: usize,
    csa: usize,
    b: &[f64],
    bsb: usize,
    rsb: usize,
    csb: usize,
    c: &mut [f64],
) {
    check_span_batched("dgemm_batched A", a, batch, bsa, m, rsa, k, csa);
    check_span_batched("dgemm_batched B", b, batch, bsb, k, rsb, n, csb);
    gemm_batched_driver::<f64, MR_F64, NR_F64>(
        batch,
        m,
        n,
        k,
        MatRef::new(a, rsa, csa),
        bsa,
        MatRef::new(b, rsb, csb),
        bsb,
        c,
    );
}

// ---------------------------------------------------------------------
// References
// ---------------------------------------------------------------------

/// The previous streaming kernel (K-blocked 8-row microtile over
/// unpacked operands), kept verbatim as the `gemm:unpacked-ref` bench
/// baseline and as an independent implementation for parity tests.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_unpacked(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k, "A size");
    debug_assert_eq!(b.len(), k * n, "B size");
    debug_assert_eq!(c.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    if degenerate_early_out(k, alpha, beta, c) {
        return;
    }
    let c_addr = c.as_mut_ptr() as usize;
    let grain_rows = if m * n * k <= super::SERIAL_GRAIN {
        m
    } else {
        m.div_ceil(super::num_threads()).max(1)
    };
    // SAFETY: parallel tasks write disjoint row-ranges of C.
    parallel_for(m, grain_rows, move |row_start, row_end| {
        let c = unsafe { std::slice::from_raw_parts_mut(c_addr as *mut f32, m * n) };
        for i in row_start..row_end {
            let crow = &mut c[i * n..(i + 1) * n];
            if beta == 0.0 {
                crow.fill(0.0);
            } else if beta != 1.0 {
                for x in crow.iter_mut() {
                    *x *= beta;
                }
            }
        }
        unpacked_panel(row_start, row_end, n, k, alpha, a, b, c);
    });
}

/// The unpacked 8-row streaming microkernel over C rows
/// `[row_start, row_end)`; C must already hold the beta-scaled values.
fn unpacked_panel(
    row_start: usize,
    row_end: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    const MR: usize = 8;
    let mut p0 = 0;
    while p0 < k {
        let pend = (p0 + KC).min(k);
        let mut i = row_start;
        while i + MR <= row_end {
            // SAFETY: the MR row slices are disjoint ranges of C.
            let cp = c.as_mut_ptr();
            let crows: [&mut [f32]; MR] = std::array::from_fn(|r| unsafe {
                std::slice::from_raw_parts_mut(cp.add((i + r) * n), n)
            });
            for p in p0..pend {
                let xs: [f32; MR] = std::array::from_fn(|r| alpha * a[(i + r) * k + p]);
                let brow = &b[p * n..(p + 1) * n];
                for (j, &bv) in brow.iter().enumerate() {
                    let mut r = 0;
                    while r < MR {
                        crows[r][j] += xs[r] * bv;
                        r += 1;
                    }
                }
            }
            i += MR;
        }
        while i < row_end {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in p0..pend {
                let aip = alpha * arow[p];
                if aip == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aip * *bj;
                }
            }
            i += 1;
        }
        p0 = pend;
    }
}

/// Naive f64-accumulating oracle for tests: straightforward triple loop.
pub fn matmul_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    matmul_ref_t(Trans::N, Trans::N, m, n, k, a, b)
}

/// Trans-aware naive oracle (same layout conventions as [`sgemm`]).
pub fn matmul_ref_t(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
) -> Vec<f32> {
    let (rsa, csa) = trans_strides_a(ta, m, k);
    let (rsb, csb) = trans_strides_b(tb, k, n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += a[i * rsa + p * csa] as f64 * b[p * rsb + j * csb] as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.uniform_range(-1.0, 1.0)).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&x, &y)) in got.iter().zip(want.iter()).enumerate() {
            assert!((x - y).abs() <= tol + tol * y.abs(), "{what} idx {i}: {x} vs {y}");
        }
    }

    fn check_t(ta: Trans, tb: Trans, m: usize, n: usize, k: usize, seed: u64) {
        let mut r = Rng::new(seed);
        let a = rand_vec(&mut r, m * k);
        let b = rand_vec(&mut r, k * n);
        let mut c = vec![0.0f32; m * n];
        sgemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
        let expect = matmul_ref_t(ta, tb, m, n, k, &a, &b);
        let tol = if k > 512 { 1e-3 } else { 1e-4 };
        assert_close(&c, &expect, tol, &format!("({ta:?},{tb:?}) ({m},{n},{k})"));
    }

    fn check(m: usize, n: usize, k: usize, seed: u64) {
        check_t(Trans::N, Trans::N, m, n, k, seed);
    }

    #[test]
    fn matches_reference_small() {
        check(1, 1, 1, 1);
        check(2, 3, 4, 2);
        check(5, 7, 11, 3);
        check(16, 16, 16, 4);
    }

    #[test]
    fn matches_reference_medium_parallel() {
        check(128, 96, 200, 5);
        check(257, 129, 300, 6); // odd sizes cross block boundaries
    }

    #[test]
    fn all_trans_combos_match_reference() {
        let mut seed = 40;
        for &ta in &[Trans::N, Trans::T] {
            for &tb in &[Trans::N, Trans::T] {
                for &(m, n, k) in &[
                    (1usize, 1usize, 1usize),
                    (5, 7, 11),
                    (8, 8, KC + 3),   // KC boundary
                    (MC + 1, 9, 33),  // MC boundary
                    (3, NC + 5, 17),  // NC boundary
                    (2, 65, 300),     // tall-skinny
                    (100, 1, 7),
                ] {
                    seed += 1;
                    check_t(ta, tb, m, n, k, seed);
                }
            }
        }
    }

    #[test]
    fn strided_operands_match_dense() {
        // A = every other row of a bigger buffer; B = a transposed view
        // expressed purely through strides. The kernel must consume both
        // without any materialization.
        let (m, n, k) = (6usize, 5usize, 7usize);
        let mut r = Rng::new(77);
        let big_a = rand_vec(&mut r, 2 * m * k); // rows interleaved
        let bt = rand_vec(&mut r, n * k); // holds Bᵀ (n x k) row-major
        let mut c = vec![0.0f32; m * n];
        // A(i, p) = big_a[i*2k + p]; B(p, j) = bt[j*k + p].
        sgemm_strided(m, n, k, 1.0, &big_a, 2 * k, 1, &bt, 1, k, 0.0, &mut c);
        let a_dense: Vec<f32> =
            (0..m * k).map(|i| big_a[(i / k) * 2 * k + i % k]).collect();
        let expect = matmul_ref_t(Trans::N, Trans::T, m, n, k, &a_dense, &bt);
        assert_close(&c, &expect, 1e-4, "strided");
    }

    #[test]
    fn prepacked_matches_strided_bitwise() {
        let (m, n, k) = (33usize, 129usize, KC + 9);
        let mut r = Rng::new(21);
        let a = rand_vec(&mut r, m * k);
        let b = rand_vec(&mut r, k * n);
        let mut c1 = vec![0.0f32; m * n];
        sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, &b, 0.0, &mut c1);
        let packed = pack_b_f32(Trans::N, k, n, &b);
        let mut c2 = vec![0.0f32; m * n];
        sgemm_prepacked(m, n, k, 1.0, &a, k, 1, &packed, 0.0, &mut c2);
        assert_eq!(c1, c2, "prepacked must be bit-identical to on-the-fly packing");
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        let (m, n, k) = (MC + 5, NC + 7, KC + 11);
        let mut r = Rng::new(31);
        let a = rand_vec(&mut r, m * k);
        let b = rand_vec(&mut r, k * n);
        let run = || {
            let mut c = vec![0.0f32; m * n];
            sgemm(Trans::N, Trans::T, m, n, k, 1.0, &a, &b, 0.0, &mut c);
            c
        };
        crate::kernels::set_num_threads(1);
        let c1 = run();
        crate::kernels::set_num_threads(8);
        let c8 = run();
        crate::kernels::set_num_threads(0);
        assert_eq!(c1, c8, "packed gemm must not depend on the thread count");
    }

    #[test]
    fn packed_matches_unpacked_reference_kernel() {
        let (m, n, k) = (57, 83, 129);
        let mut r = Rng::new(51);
        let a = rand_vec(&mut r, m * k);
        let b = rand_vec(&mut r, k * n);
        let mut c_packed = vec![1.5f32; m * n];
        sgemm(Trans::N, Trans::N, m, n, k, 0.5, &a, &b, 2.0, &mut c_packed);
        let mut c_ref = vec![1.5f32; m * n];
        sgemm_unpacked(m, n, k, 0.5, &a, &b, 2.0, &mut c_ref);
        assert_close(&c_packed, &c_ref, 1e-4, "packed vs unpacked");
    }

    #[test]
    fn alpha_beta_semantics() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![1.0f32, 0.0, 0.0, 1.0]; // identity
        let mut c = vec![10.0f32, 20.0, 30.0, 40.0];
        sgemm(Trans::N, Trans::N, 2, 2, 2, 2.0, &a, &b, 0.5, &mut c);
        assert_eq!(c, vec![2.0 + 5.0, 4.0 + 10.0, 6.0 + 15.0, 8.0 + 20.0]);
    }

    /// The explicit degenerate table: every (alpha, beta, k) combo where
    /// the product term vanishes must reduce to exactly `C = beta * C`.
    #[test]
    fn degenerate_alpha_beta_k_table() {
        let a = vec![1.0f32; 6];
        let b = vec![1.0f32; 6];
        let c0 = vec![3.0f32, -1.0, 0.5, 2.0];
        for &(alpha, k) in &[(0.0f32, 2usize), (1.0, 0), (0.0, 0), (0.5, 0)] {
            for &beta in &[0.0f32, 1.0, 0.5] {
                let mut c = c0.clone();
                let (al, bl) = (2 * k, 2 * k);
                sgemm(Trans::N, Trans::N, 2, 2, k, alpha, &a[..al], &b[..bl], beta, &mut c);
                let expect: Vec<f32> = if beta == 0.0 {
                    vec![0.0; 4]
                } else if beta == 1.0 {
                    c0.clone()
                } else {
                    c0.iter().map(|&x| beta * x).collect()
                };
                assert_eq!(c, expect, "alpha={alpha} beta={beta} k={k}");
            }
        }
        // Non-degenerate sanity next to the table: k>0, alpha!=0, beta=1
        // accumulates on top of C.
        let mut c = c0.clone();
        sgemm(Trans::N, Trans::N, 2, 2, 2, 1.0, &a[..4], &b[..4], 1.0, &mut c);
        let expect: Vec<f32> = c0.iter().map(|&x| x + 2.0).collect();
        assert_eq!(c, expect);
    }

    #[test]
    fn degenerate_beta_zero_clears_nan() {
        let mut c = vec![f32::NAN; 4];
        sgemm(Trans::N, Trans::N, 2, 2, 0, 1.0, &[], &[], 0.0, &mut c);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    fn dgemm_matches_reference_all_trans() {
        let mut seed = 400;
        for &ta in &[Trans::N, Trans::T] {
            for &tb in &[Trans::N, Trans::T] {
                seed += 1;
                let (m, n, k) = (7, 5, 9);
                let mut r = Rng::new(seed);
                let a32 = rand_vec(&mut r, m * k);
                let b32 = rand_vec(&mut r, k * n);
                let a: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
                let b: Vec<f64> = b32.iter().map(|&x| x as f64).collect();
                let mut c = vec![0.0f64; m * n];
                dgemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                let expect = matmul_ref_t(ta, tb, m, n, k, &a32, &b32);
                for (i, (&x, &y)) in c.iter().zip(expect.iter()).enumerate() {
                    assert!(
                        (x as f32 - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                        "({ta:?},{tb:?}) idx {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_matches_loop() {
        let mut r = Rng::new(9);
        let (batch, m, n, k) = (4, 6, 5, 7);
        let a = rand_vec(&mut r, batch * m * k);
        let b = rand_vec(&mut r, batch * k * n);
        let mut c = vec![0.0f32; batch * m * n];
        sgemm_batched(batch, m, n, k, &a, &b, &mut c);
        for i in 0..batch {
            let expect =
                matmul_ref(m, n, k, &a[i * m * k..(i + 1) * m * k], &b[i * k * n..(i + 1) * k * n]);
            for (j, (&x, &y)) in c[i * m * n..(i + 1) * m * n].iter().zip(expect.iter()).enumerate()
            {
                assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs(), "batch {i} idx {j}");
            }
        }
    }

    #[test]
    fn dgemm_batched_parallel_matches_loop() {
        // Batch large enough to take the batch-parallel branch on any
        // pool size the test host has.
        let mut r = Rng::new(19);
        let (batch, m, n, k) = (32, 9, 8, 30);
        let a32 = rand_vec(&mut r, batch * m * k);
        let b32 = rand_vec(&mut r, batch * k * n);
        let a: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
        let b: Vec<f64> = b32.iter().map(|&x| x as f64).collect();
        let mut c = vec![0.0f64; batch * m * n];
        dgemm_batched(batch, m, n, k, &a, &b, &mut c);
        for i in 0..batch {
            let expect = matmul_ref(
                m,
                n,
                k,
                &a32[i * m * k..(i + 1) * m * k],
                &b32[i * k * n..(i + 1) * k * n],
            );
            for (j, (&x, &y)) in c[i * m * n..(i + 1) * m * n].iter().zip(expect.iter()).enumerate()
            {
                assert!((x as f32 - y).abs() <= 1e-4 + 1e-4 * y.abs(), "batch {i} idx {j}");
            }
        }
    }

    #[test]
    fn batched_strided_transposed_operands() {
        // bmm with B given as its transpose via strides only.
        let mut r = Rng::new(23);
        let (batch, m, n, k) = (3, 4, 6, 5);
        let a = rand_vec(&mut r, batch * m * k);
        let bt = rand_vec(&mut r, batch * n * k); // [batch, n, k] = Bᵀ per batch
        let mut c = vec![0.0f32; batch * m * n];
        sgemm_batched_strided(batch, m, n, k, &a, m * k, k, 1, &bt, n * k, 1, k, &mut c);
        for i in 0..batch {
            let expect = matmul_ref_t(
                Trans::N,
                Trans::T,
                m,
                n,
                k,
                &a[i * m * k..(i + 1) * m * k],
                &bt[i * n * k..(i + 1) * n * k],
            );
            for (j, (&x, &y)) in c[i * m * n..(i + 1) * m * n].iter().zip(expect.iter()).enumerate()
            {
                assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs(), "batch {i} idx {j}");
            }
        }
    }

    #[test]
    fn shape_sweep_tall_skinny_and_odd() {
        let mut seed = 100;
        for &m in &[1usize, 2, 3, 4, 7, 8, 9, 15, 16, 31, 33, 100] {
            for &(n, k) in &[(64usize, 64usize), (33, 129), (256, 16)] {
                seed += 1;
                check(m, n, k, seed);
            }
        }
    }

    #[test]
    fn k_blocking_boundary() {
        check(8, 8, KC + 3, 7);
        check(8, 8, 2 * KC, 8);
    }

    #[test]
    fn zero_k_scales_c_by_beta() {
        let mut c = vec![2.0f32; 4];
        sgemm(Trans::N, Trans::N, 2, 2, 0, 1.0, &[], &[], 0.0, &mut c);
        assert_eq!(c, vec![0.0; 4]);
        let mut c = vec![2.0f32; 4];
        sgemm(Trans::N, Trans::N, 2, 2, 0, 1.0, &[], &[], 1.0, &mut c);
        assert_eq!(c, vec![2.0; 4]);
    }
}
