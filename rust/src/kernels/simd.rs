//! Runtime-dispatched SIMD microkernels — the abstraction layer between
//! the scalar reference kernels and `core::arch` intrinsics.
//!
//! # Dispatch model
//!
//! The vector level is detected **once**, at first use, and cached for
//! the life of the process ([`level`]): `PALLAS_SIMD=0` forces the
//! scalar paths, otherwise x86-64 probes AVX2 with
//! `is_x86_feature_detected!` (the single allowlisted detection site —
//! see `tools/pallas-audit/allow/determinism.allow`) and aarch64 uses
//! NEON, which is baseline for the architecture. Kernels read the cached
//! level; there is no per-call CPUID. Tests and benches can force the
//! scalar paths at runtime with [`set_force_scalar`] (the same
//! process-global-override idiom as [`super::set_num_threads`] — safe
//! under concurrent toggling precisely because both paths produce
//! identical bits).
//!
//! # Bitwise parity contract
//!
//! Every vector kernel in this module (and in `dispatch/fuse/simd.rs`)
//! must produce results **bit-for-bit identical** to its scalar
//! reference. The trick is lane orientation: vectors run across
//! *independent* output elements (the NR columns of a GEMM tile, a block
//! of elementwise outputs), so each element's chain of IEEE operations —
//! order, operand pairing, rounding — is exactly the scalar chain. Under
//! that rule only per-lane-exact operations are allowed:
//!
//! * add/sub/mul/div/sqrt — IEEE-754 correctly rounded, one instruction
//!   per lane, bit-identical to the scalar op;
//! * **no FMA**: `a*b + c` fused rounds once where the scalar kernel
//!   rounds twice, so multiply-add stays two instructions;
//! * **no horizontal operations**: reductions fold lanes back in plain
//!   ascending index order (see the fuse sum driver).
//!
//! Anything whose vector semantics differ from the Rust scalar semantics
//! (libm `exp`/`ln`/`tanh`, `f32::max`'s NaN handling vs `maxps`) is
//! evaluated lane-by-lane with the *same scalar function* the reference
//! interpreter calls.

use std::sync::atomic::{AtomicBool, Ordering};

use once_cell::sync::Lazy;

/// The vector instruction set selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// No vector path: scalar reference kernels only.
    Scalar,
    /// x86-64 AVX2 (8×f32 / 4×f64 per vector).
    Avx2,
    /// aarch64 NEON (4×f32 / 2×f64 per vector) — baseline on aarch64.
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name (bench records, logs).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

/// One-shot detection: env knob first, then the architecture probe.
/// Cached in a `Lazy` so the process does exactly one CPUID.
static DETECTED: Lazy<SimdLevel> = Lazy::new(detect);

fn detect() -> SimdLevel {
    // PALLAS_SIMD=0 is the documented force-scalar knob (read once,
    // here; everything else reads the cached level).
    if std::env::var("PALLAS_SIMD").map(|v| v == "0").unwrap_or(false) {
        return SimdLevel::Scalar;
    }
    // Miri has no CPUID and no vector codegen to check against; the
    // scalar interpreter is the semantics being verified there anyway.
    #[cfg(miri)]
    {
        SimdLevel::Scalar
    }
    #[cfg(all(not(miri), target_arch = "x86_64"))]
    {
        if std::is_x86_feature_detected!("avx2") {
            SimdLevel::Avx2
        } else {
            SimdLevel::Scalar
        }
    }
    #[cfg(all(not(miri), target_arch = "aarch64"))]
    {
        SimdLevel::Neon
    }
    #[cfg(all(not(miri), not(target_arch = "x86_64"), not(target_arch = "aarch64")))]
    {
        SimdLevel::Scalar
    }
}

/// Runtime force-scalar override ([`set_force_scalar`]).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Test/bench-only hook: force the scalar kernels at runtime, without
/// re-detecting anything. Process-global, like
/// [`super::set_num_threads`]; concurrent toggling is harmless because
/// the vector and scalar paths are bitwise identical by contract (the
/// parity suites assert exactly that).
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// The level the hardware probe selected (ignores the runtime override;
/// reported in bench envelopes).
pub fn detected_level() -> SimdLevel {
    *DETECTED
}

/// The level kernels dispatch on right now: [`detected_level`] unless
/// [`set_force_scalar`] is active.
pub fn level() -> SimdLevel {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        SimdLevel::Scalar
    } else {
        *DETECTED
    }
}

// ---------------------------------------------------------------------
// GEMM microkernel accumulation
// ---------------------------------------------------------------------
//
// The packed GEMM's inner loop accumulates an MR×NR register tile over a
// KC panel: `acc[i][j] += a[p*MR + i] * b[p*NR + j]` for p ascending.
// The vector versions below keep that loop shape exactly — one vector
// holds `acc[i][j..j+L]` (a row chunk of the tile), every p step does a
// broadcast-multiply-add with separate mul and add instructions — so
// each `acc[i][j]` sees the same multiplications and additions, in the
// same order, with the same intermediate roundings as the scalar loop.
// Panels are zero-padded past the m/n edges by the packers, so the full
// MR×NR tile is always valid to compute.

/// f32 8×8 tile accumulation over a `kc`-deep panel pair. `acc` is the
/// row-major flattened `[ [f32; 8]; 8 ]` tile. Returns `false` (leaving
/// `acc` untouched) when no vector path is active or the buffers do not
/// match the expected panel layout — the caller then runs the scalar
/// loop.
pub(crate) fn gemm_acc_f32(kc: usize, a_panel: &[f32], b_panel: &[f32], acc: &mut [f32]) -> bool {
    if a_panel.len() < kc * 8 || b_panel.len() < kc * 8 {
        return false;
    }
    let Ok(tile) = <&mut [f32; 64]>::try_from(acc) else {
        return false;
    };
    match level() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            // SAFETY: AVX2 presence was established by the one-shot
            // probe behind `level()`; panel lengths checked above.
            unsafe { x86::gemm_acc_f32(kc, a_panel, b_panel, tile) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            // SAFETY: NEON is baseline on aarch64; panel lengths
            // checked above.
            unsafe { arm::gemm_acc_f32(kc, a_panel, b_panel, tile) };
            true
        }
        _ => false,
    }
}

/// f64 4×4 tile accumulation over a `kc`-deep panel pair; the f64 twin
/// of [`gemm_acc_f32`] (`acc` is the flattened `[ [f64; 4]; 4 ]` tile).
pub(crate) fn gemm_acc_f64(kc: usize, a_panel: &[f64], b_panel: &[f64], acc: &mut [f64]) -> bool {
    if a_panel.len() < kc * 4 || b_panel.len() < kc * 4 {
        return false;
    }
    let Ok(tile) = <&mut [f64; 16]>::try_from(acc) else {
        return false;
    };
    match level() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            // SAFETY: AVX2 presence was established by the one-shot
            // probe behind `level()`; panel lengths checked above.
            unsafe { x86::gemm_acc_f64(kc, a_panel, b_panel, tile) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            // SAFETY: NEON is baseline on aarch64; panel lengths
            // checked above.
            unsafe { arm::gemm_acc_f64(kc, a_panel, b_panel, tile) };
            true
        }
        _ => false,
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// AVX2 f32 8×8 accumulate: row i of the tile is one `__m256`
    /// (`acc[i][0..8]`); each p step broadcasts `a[p*8+i]` and does a
    /// separate mul + add (no FMA), the exact scalar chain per lane.
    ///
    /// # Safety
    /// AVX2 must be available, `a`/`b` must hold at least `kc * 8`
    /// elements each.
    #[target_feature(enable = "avx2")]
    #[allow(unused_unsafe)]
    pub(super) unsafe fn gemm_acc_f32(kc: usize, a: &[f32], b: &[f32], acc: &mut [f32; 64]) {
        // SAFETY: AVX2 per this fn's contract; every load/store stays
        // inside the length-checked `a`/`b`/`acc` buffers.
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut rows = [_mm256_setzero_ps(); 8];
            for (i, r) in rows.iter_mut().enumerate() {
                *r = _mm256_loadu_ps(acc.as_ptr().add(i * 8));
            }
            for p in 0..kc {
                let bv = _mm256_loadu_ps(bp.add(p * 8));
                for (i, r) in rows.iter_mut().enumerate() {
                    let ai = _mm256_set1_ps(*ap.add(p * 8 + i));
                    *r = _mm256_add_ps(*r, _mm256_mul_ps(ai, bv));
                }
            }
            for (i, r) in rows.iter().enumerate() {
                _mm256_storeu_ps(acc.as_mut_ptr().add(i * 8), *r);
            }
        }
    }

    /// AVX2 f64 4×4 accumulate: row i is one `__m256d`.
    ///
    /// # Safety
    /// AVX2 must be available, `a`/`b` must hold at least `kc * 4`
    /// elements each.
    #[target_feature(enable = "avx2")]
    #[allow(unused_unsafe)]
    pub(super) unsafe fn gemm_acc_f64(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; 16]) {
        // SAFETY: AVX2 per this fn's contract; every load/store stays
        // inside the length-checked `a`/`b`/`acc` buffers.
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut rows = [_mm256_setzero_pd(); 4];
            for (i, r) in rows.iter_mut().enumerate() {
                *r = _mm256_loadu_pd(acc.as_ptr().add(i * 4));
            }
            for p in 0..kc {
                let bv = _mm256_loadu_pd(bp.add(p * 4));
                for (i, r) in rows.iter_mut().enumerate() {
                    let ai = _mm256_set1_pd(*ap.add(p * 4 + i));
                    *r = _mm256_add_pd(*r, _mm256_mul_pd(ai, bv));
                }
            }
            for (i, r) in rows.iter().enumerate() {
                _mm256_storeu_pd(acc.as_mut_ptr().add(i * 4), *r);
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use core::arch::aarch64::*;

    /// NEON f32 8×8 accumulate: row i is a `float32x4_t` pair
    /// (`acc[i][0..4]` / `acc[i][4..8]`); separate `vmulq`+`vaddq`
    /// (never `vfmaq`) keeps the per-lane rounding identical to scalar.
    ///
    /// # Safety
    /// `a`/`b` must hold at least `kc * 8` elements each (NEON itself is
    /// baseline on aarch64).
    #[allow(unused_unsafe)]
    pub(super) unsafe fn gemm_acc_f32(kc: usize, a: &[f32], b: &[f32], acc: &mut [f32; 64]) {
        // SAFETY: every load/store stays inside the length-checked
        // `a`/`b`/`acc` buffers.
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut lo = [vdupq_n_f32(0.0); 8];
            let mut hi = [vdupq_n_f32(0.0); 8];
            for (i, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                *l = vld1q_f32(acc.as_ptr().add(i * 8));
                *h = vld1q_f32(acc.as_ptr().add(i * 8 + 4));
            }
            for p in 0..kc {
                let b0 = vld1q_f32(bp.add(p * 8));
                let b1 = vld1q_f32(bp.add(p * 8 + 4));
                for (i, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                    let ai = vdupq_n_f32(*ap.add(p * 8 + i));
                    *l = vaddq_f32(*l, vmulq_f32(ai, b0));
                    *h = vaddq_f32(*h, vmulq_f32(ai, b1));
                }
            }
            for (i, (l, h)) in lo.iter().zip(hi.iter()).enumerate() {
                vst1q_f32(acc.as_mut_ptr().add(i * 8), *l);
                vst1q_f32(acc.as_mut_ptr().add(i * 8 + 4), *h);
            }
        }
    }

    /// NEON f64 4×4 accumulate: row i is a `float64x2_t` pair.
    ///
    /// # Safety
    /// `a`/`b` must hold at least `kc * 4` elements each.
    #[allow(unused_unsafe)]
    pub(super) unsafe fn gemm_acc_f64(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; 16]) {
        // SAFETY: every load/store stays inside the length-checked
        // `a`/`b`/`acc` buffers.
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut lo = [vdupq_n_f64(0.0); 4];
            let mut hi = [vdupq_n_f64(0.0); 4];
            for (i, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                *l = vld1q_f64(acc.as_ptr().add(i * 4));
                *h = vld1q_f64(acc.as_ptr().add(i * 4 + 2));
            }
            for p in 0..kc {
                let b0 = vld1q_f64(bp.add(p * 4));
                let b1 = vld1q_f64(bp.add(p * 4 + 2));
                for (i, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                    let ai = vdupq_n_f64(*ap.add(p * 4 + i));
                    *l = vaddq_f64(*l, vmulq_f64(ai, b0));
                    *h = vaddq_f64(*h, vmulq_f64(ai, b1));
                }
            }
            for (i, (l, h)) in lo.iter().zip(hi.iter()).enumerate() {
                vst1q_f64(acc.as_mut_ptr().add(i * 4), *l);
                vst1q_f64(acc.as_mut_ptr().add(i * 4 + 2), *h);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Deterministic pseudo-random fill (same LCG family as the parity
    // suites).
    fn lcg_fill(seed: u64, out: &mut [f32]) {
        let mut state = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        for v in out.iter_mut() {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *v = ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
        }
    }

    fn scalar_acc_f32(kc: usize, a: &[f32], b: &[f32], acc: &mut [f32; 64]) {
        for p in 0..kc {
            for i in 0..8 {
                let ai = a[p * 8 + i];
                for j in 0..8 {
                    acc[i * 8 + j] += ai * b[p * 8 + j];
                }
            }
        }
    }

    fn scalar_acc_f64(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; 16]) {
        for p in 0..kc {
            for i in 0..4 {
                let ai = a[p * 4 + i];
                for j in 0..4 {
                    acc[i * 4 + j] += ai * b[p * 4 + j];
                }
            }
        }
    }

    #[test]
    fn detection_is_cached_and_consistent() {
        let a = detected_level();
        let b = detected_level();
        assert_eq!(a, b);
        assert!(matches!(a, SimdLevel::Scalar | SimdLevel::Avx2 | SimdLevel::Neon));
    }

    #[test]
    fn gemm_acc_f32_matches_scalar_bitwise() {
        // Odd kc exercises a non-trivial panel walk.
        let kc = 37;
        let mut a = vec![0.0f32; kc * 8];
        let mut b = vec![0.0f32; kc * 8];
        lcg_fill(11, &mut a);
        lcg_fill(23, &mut b);
        let mut init = [0.0f32; 64];
        lcg_fill(47, &mut init);

        let mut vec_tile = init;
        let used = gemm_acc_f32(kc, &a, &b, &mut vec_tile);
        let mut ref_tile = init;
        scalar_acc_f32(kc, &a, &b, &mut ref_tile);
        if used {
            for (v, r) in vec_tile.iter().zip(ref_tile.iter()) {
                assert_eq!(v.to_bits(), r.to_bits(), "vector lane diverged from scalar");
            }
        } else {
            // No vector path on this host/config: the tile must be
            // untouched so the caller's scalar loop runs from init.
            assert_eq!(vec_tile, init);
        }
    }

    #[test]
    fn gemm_acc_f64_matches_scalar_bitwise() {
        let kc = 53;
        let mut af = vec![0.0f32; kc * 4];
        let mut bf = vec![0.0f32; kc * 4];
        lcg_fill(5, &mut af);
        lcg_fill(7, &mut bf);
        let a: Vec<f64> = af.iter().map(|&x| x as f64).collect();
        let b: Vec<f64> = bf.iter().map(|&x| x as f64).collect();
        let mut initf = [0.0f32; 16];
        lcg_fill(9, &mut initf);
        let mut init = [0.0f64; 16];
        for (d, s) in init.iter_mut().zip(initf.iter()) {
            *d = *s as f64;
        }

        let mut vec_tile = init;
        let used = gemm_acc_f64(kc, &a, &b, &mut vec_tile);
        let mut ref_tile = init;
        scalar_acc_f64(kc, &a, &b, &mut ref_tile);
        if used {
            for (v, r) in vec_tile.iter().zip(ref_tile.iter()) {
                assert_eq!(v.to_bits(), r.to_bits(), "vector lane diverged from scalar");
            }
        } else {
            assert_eq!(vec_tile, init);
        }
    }

    #[test]
    fn force_scalar_roundtrip() {
        // The only in-crate test that toggles the override (the
        // cross-mode sweeps live in the integration suites, each in its
        // own process), so the restore below cannot race another test.
        let before = level();
        set_force_scalar(true);
        assert_eq!(level(), SimdLevel::Scalar);
        // Forced-scalar must make the vector entry points decline.
        let mut tile = [1.0f32; 64];
        assert!(!gemm_acc_f32(4, &[0.5; 32], &[0.25; 32], &mut tile));
        assert_eq!(tile, [1.0f32; 64]);
        set_force_scalar(false);
        assert_eq!(level(), before);
    }

    #[test]
    fn wrong_tile_size_declines() {
        let mut tile = vec![0.0f32; 63];
        assert!(!gemm_acc_f32(4, &[0.0; 32], &[0.0; 32], &mut tile));
        let mut short_panels = [0.0f32; 64];
        assert!(!gemm_acc_f32(9, &[0.0; 32], &[0.0; 32], &mut short_panels));
    }
}
