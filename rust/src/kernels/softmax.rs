//! Row-wise softmax / log-softmax and fused cross-entropy kernels.
//! Numerically stable (max-subtraction), parallel over rows. Every row is
//! processed serially by exactly one task, so results are bit-for-bit
//! identical at any thread count; the loss accumulation in
//! [`cross_entropy_forward`] uses fixed-width row chunks for the same
//! guarantee.

use super::{parallel_for, SERIAL_GRAIN};

/// Rows per task such that a task covers ~[`SERIAL_GRAIN`] elements —
/// serial for small inputs, saturating the pool for ≥1M-element softmax.
fn row_grain(cols: usize) -> usize {
    (SERIAL_GRAIN / cols.max(1)).max(1)
}

/// Softmax over the last dimension: `input`/`out` are [rows, cols].
pub fn softmax_rows(rows: usize, cols: usize, input: &[f32], out: &mut [f32]) {
    let out_addr = out.as_mut_ptr() as usize;
    let out_len = out.len();
    parallel_for(rows, row_grain(cols), move |r0, r1| {
        // SAFETY: `out_addr/out_len` come from the caller's live `&mut
        // out` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint row ranges [r0*cols, r1*cols).
        let out = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, out_len) };
        for r in r0..r1 {
            let x = &input[r * cols..(r + 1) * cols];
            let o = &mut out[r * cols..(r + 1) * cols];
            let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0f32;
            for (oi, &xi) in o.iter_mut().zip(x.iter()) {
                let e = (xi - m).exp();
                *oi = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for oi in o.iter_mut() {
                *oi *= inv;
            }
        }
    });
}

/// Backward of softmax: `gi = y * (go - sum(go * y))` per row, where y is
/// the forward output.
pub fn softmax_backward_rows(rows: usize, cols: usize, y: &[f32], grad_out: &[f32], grad_in: &mut [f32]) {
    let gi_addr = grad_in.as_mut_ptr() as usize;
    let gi_len = grad_in.len();
    parallel_for(rows, row_grain(cols), move |r0, r1| {
        // SAFETY: `gi_addr/gi_len` come from the caller's live `&mut
        // grad_in` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint row ranges [r0*cols, r1*cols).
        let grad_in = unsafe { std::slice::from_raw_parts_mut(gi_addr as *mut f32, gi_len) };
        for r in r0..r1 {
            let yr = &y[r * cols..(r + 1) * cols];
            let gr = &grad_out[r * cols..(r + 1) * cols];
            let gi = &mut grad_in[r * cols..(r + 1) * cols];
            let dot: f32 = yr.iter().zip(gr.iter()).map(|(&a, &b)| a * b).sum();
            for ((o, &yv), &gv) in gi.iter_mut().zip(yr.iter()).zip(gr.iter()) {
                *o = yv * (gv - dot);
            }
        }
    });
}

/// Log-softmax over the last dimension.
pub fn log_softmax_rows(rows: usize, cols: usize, input: &[f32], out: &mut [f32]) {
    let out_addr = out.as_mut_ptr() as usize;
    let out_len = out.len();
    parallel_for(rows, row_grain(cols), move |r0, r1| {
        // SAFETY: `out_addr/out_len` come from the caller's live `&mut
        // out` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint row ranges [r0*cols, r1*cols).
        let out = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, out_len) };
        for r in r0..r1 {
            let x = &input[r * cols..(r + 1) * cols];
            let o = &mut out[r * cols..(r + 1) * cols];
            let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0f32;
            for &xi in x.iter() {
                sum += (xi - m).exp();
            }
            let lse = m + sum.ln();
            for (oi, &xi) in o.iter_mut().zip(x.iter()) {
                *oi = xi - lse;
            }
        }
    });
}

/// Backward of log-softmax: `gi = go - exp(y) * sum(go)` per row (y is the
/// forward log-softmax output).
pub fn log_softmax_backward_rows(rows: usize, cols: usize, y: &[f32], grad_out: &[f32], grad_in: &mut [f32]) {
    let gi_addr = grad_in.as_mut_ptr() as usize;
    let gi_len = grad_in.len();
    parallel_for(rows, row_grain(cols), move |r0, r1| {
        // SAFETY: `gi_addr/gi_len` come from the caller's live `&mut
        // grad_in` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint row ranges [r0*cols, r1*cols).
        let grad_in = unsafe { std::slice::from_raw_parts_mut(gi_addr as *mut f32, gi_len) };
        for r in r0..r1 {
            let yr = &y[r * cols..(r + 1) * cols];
            let gr = &grad_out[r * cols..(r + 1) * cols];
            let gi = &mut grad_in[r * cols..(r + 1) * cols];
            let gsum: f32 = gr.iter().sum();
            for ((o, &yv), &gv) in gi.iter_mut().zip(yr.iter()).zip(gr.iter()) {
                *o = gv - yv.exp() * gsum;
            }
        }
    });
}

/// Fused cross-entropy forward: mean over rows of `-log_softmax(x)[target]`.
/// Returns the scalar loss; also writes per-row log-probs if `log_probs`
/// is provided (saved for backward).
pub fn cross_entropy_forward(
    rows: usize,
    cols: usize,
    logits: &[f32],
    targets: &[i64],
    log_probs: &mut [f32],
) -> f32 {
    log_softmax_rows(rows, cols, logits, log_probs);
    // Validate every target on the caller thread *before* fanning out: a
    // panic inside a pool-worker chunk would be swallowed by the pool's
    // unwind handling and turn into a silently wrong loss.
    for (r, &t) in targets.iter().enumerate().take(rows) {
        assert!((0..cols as i64).contains(&t), "target {t} (row {r}) out of range 0..{cols}");
    }
    // Deterministic parallel accumulation: fixed-width row chunks (never a
    // function of the thread count) summed per-chunk, then combined in
    // chunk order.
    const ROW_CHUNK: usize = 4096;
    let nchunks = rows.div_ceil(ROW_CHUNK).max(1);
    let mut partials = vec![0f64; nchunks];
    let pp = partials.as_mut_ptr() as usize;
    let lp: &[f32] = log_probs;
    parallel_for(nchunks, 1, move |c0, c1| {
        for c in c0..c1 {
            let r0 = c * ROW_CHUNK;
            let r1 = ((c + 1) * ROW_CHUNK).min(rows);
            let mut acc = 0f64;
            for r in r0..r1 {
                acc -= lp[r * cols + targets[r] as usize] as f64;
            }
            // SAFETY: each chunk index is written by exactly one task.
            unsafe { std::ptr::write((pp as *mut f64).add(c), acc) };
        }
    });
    let loss: f64 = partials.iter().sum();
    (loss / rows as f64) as f32
}

/// Fused cross-entropy backward: `gi = (softmax(x) - onehot(t)) * g / rows`.
pub fn cross_entropy_backward(
    rows: usize,
    cols: usize,
    log_probs: &[f32],
    targets: &[i64],
    grad_scalar: f32,
    grad_in: &mut [f32],
) {
    let scale = grad_scalar / rows as f32;
    let gi_addr = grad_in.as_mut_ptr() as usize;
    let gi_len = grad_in.len();
    parallel_for(rows, row_grain(cols), move |r0, r1| {
        // SAFETY: `gi_addr/gi_len` come from the caller's live `&mut
        // grad_in` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint row ranges [r0*cols, r1*cols).
        let grad_in = unsafe { std::slice::from_raw_parts_mut(gi_addr as *mut f32, gi_len) };
        for r in r0..r1 {
            let lp = &log_probs[r * cols..(r + 1) * cols];
            let gi = &mut grad_in[r * cols..(r + 1) * cols];
            for (o, &l) in gi.iter_mut().zip(lp.iter()) {
                *o = l.exp() * scale;
            }
            gi[targets[r] as usize] -= scale;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut r = Rng::new(1);
        let (rows, cols) = (17, 31);
        let x: Vec<f32> = (0..rows * cols).map(|_| r.uniform_range(-5.0, 5.0)).collect();
        let mut y = vec![0.0; rows * cols];
        softmax_rows(rows, cols, &x, &mut y);
        for rr in 0..rows {
            let s: f32 = y[rr * cols..(rr + 1) * cols].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {rr} sums to {s}");
            assert!(y[rr * cols..(rr + 1) * cols].iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_is_stable_for_large_logits() {
        let x = vec![1000.0f32, 1001.0, 999.0];
        let mut y = vec![0.0; 3];
        softmax_rows(1, 3, &x, &mut y);
        assert!(y.iter().all(|v| v.is_finite()));
        assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(y[1] > y[0] && y[0] > y[2]);
    }

    #[test]
    fn log_softmax_matches_softmax_log() {
        let x = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut ls = vec![0.0; 4];
        let mut s = vec![0.0; 4];
        log_softmax_rows(1, 4, &x, &mut ls);
        softmax_rows(1, 4, &x, &mut s);
        for i in 0..4 {
            assert!((ls[i] - s[i].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_backward_finite_difference() {
        let mut r = Rng::new(3);
        let cols = 5;
        let x: Vec<f32> = (0..cols).map(|_| r.uniform_range(-2.0, 2.0)).collect();
        let g: Vec<f32> = (0..cols).map(|_| r.uniform_range(-1.0, 1.0)).collect();
        let mut y = vec![0.0; cols];
        softmax_rows(1, cols, &x, &mut y);
        let mut gi = vec![0.0; cols];
        softmax_backward_rows(1, cols, &y, &g, &mut gi);

        let f = |x: &[f32]| -> f64 {
            let mut y = vec![0.0; cols];
            softmax_rows(1, cols, x, &mut y);
            y.iter().zip(g.iter()).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let eps = 1e-3;
        for i in 0..cols {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = ((f(&xp) - f(&xm)) / (2.0 * eps as f64)) as f32;
            assert!((gi[i] - fd).abs() < 1e-3, "idx {i}: {} vs {}", gi[i], fd);
        }
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let (rows, cols) = (4, 10);
        let logits = vec![0.0f32; rows * cols];
        let targets = vec![0i64, 3, 7, 9];
        let mut lp = vec![0.0; rows * cols];
        let loss = cross_entropy_forward(rows, cols, &logits, &targets, &mut lp);
        assert!((loss - (cols as f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_backward_sums_to_zero() {
        let mut r = Rng::new(5);
        let (rows, cols) = (3, 7);
        let logits: Vec<f32> = (0..rows * cols).map(|_| r.uniform_range(-2.0, 2.0)).collect();
        let targets = vec![1i64, 0, 6];
        let mut lp = vec![0.0; rows * cols];
        cross_entropy_forward(rows, cols, &logits, &targets, &mut lp);
        let mut gi = vec![0.0; rows * cols];
        cross_entropy_backward(rows, cols, &lp, &targets, 1.0, &mut gi);
        // Per row, softmax sums to 1 and the onehot subtracts 1 => sum 0.
        for rr in 0..rows {
            let s: f32 = gi[rr * cols..(rr + 1) * cols].iter().sum();
            assert!(s.abs() < 1e-5, "row {rr}: {s}");
        }
    }

    #[test]
    fn cross_entropy_backward_finite_difference() {
        let mut r = Rng::new(7);
        let (rows, cols) = (2, 4);
        let logits: Vec<f32> = (0..rows * cols).map(|_| r.uniform_range(-1.0, 1.0)).collect();
        let targets = vec![2i64, 0];
        let f = |x: &[f32]| -> f64 {
            let mut lp = vec![0.0; rows * cols];
            cross_entropy_forward(rows, cols, x, &targets, &mut lp) as f64
        };
        let mut lp = vec![0.0; rows * cols];
        cross_entropy_forward(rows, cols, &logits, &targets, &mut lp);
        let mut gi = vec![0.0; rows * cols];
        cross_entropy_backward(rows, cols, &lp, &targets, 1.0, &mut gi);
        let eps = 1e-3;
        for i in 0..rows * cols {
            let mut xp = logits.clone();
            xp[i] += eps;
            let mut xm = logits.clone();
            xm[i] -= eps;
            let fd = ((f(&xp) - f(&xm)) / (2.0 * eps as f64)) as f32;
            assert!((gi[i] - fd).abs() < 1e-3, "idx {i}: {} vs fd {}", gi[i], fd);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn cross_entropy_bad_target_panics() {
        let mut lp = vec![0.0; 4];
        cross_entropy_forward(1, 4, &[0.0; 4], &[4], &mut lp);
    }
}
