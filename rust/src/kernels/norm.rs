//! Fused batch-norm kernels (§Perf): the compositional BN built from
//! broadcast ops costs ~16 full-tensor passes forward+backward; these
//! kernels do it in 5 (stats, normalize; bwd: two reductions, one dx pass).
//!
//! Reductions parallelize over *channels* (each channel folded serially,
//! in image order, by exactly one task) and elementwise passes over
//! (image, channel) blocks — both layouts make results bit-for-bit
//! identical at every thread count, like the rest of the reduction stack.

use super::{parallel_for, SERIAL_GRAIN};

/// Channels per task so one task covers ~[`SERIAL_GRAIN`] elements.
fn channel_grain(n: usize, hw: usize) -> usize {
    (SERIAL_GRAIN / (n * hw).max(1)).max(1)
}

/// Per-channel mean/var over N,H,W of an NCHW tensor.
pub fn bn_stats(n: usize, c: usize, hw: usize, x: &[f32], mean: &mut [f32], var: &mut [f32]) {
    let m = (n * hw) as f32;
    let mean_addr = mean.as_mut_ptr() as usize;
    let var_addr = var.as_mut_ptr() as usize;
    parallel_for(c, channel_grain(n, hw), move |c0, c1| {
        // SAFETY: tasks own disjoint channel ranges of mean/var.
        let mean = unsafe { std::slice::from_raw_parts_mut(mean_addr as *mut f32, c) };
        let var = unsafe { std::slice::from_raw_parts_mut(var_addr as *mut f32, c) };
        for ch in c0..c1 {
            let mut acc = 0f32;
            for img in 0..n {
                let base = (img * c + ch) * hw;
                for &v in &x[base..base + hw] {
                    acc += v;
                }
            }
            let mu = acc / m;
            let mut vacc = 0f32;
            for img in 0..n {
                let base = (img * c + ch) * hw;
                for &v in &x[base..base + hw] {
                    let d = v - mu;
                    vacc += d * d;
                }
            }
            mean[ch] = mu;
            var[ch] = vacc / m;
        }
    });
}

/// y = (x - mean) * inv_std * gamma + beta, one pass.
#[allow(clippy::too_many_arguments)]
pub fn bn_normalize(
    n: usize,
    c: usize,
    hw: usize,
    x: &[f32],
    mean: &[f32],
    inv_std: &[f32],
    gamma: &[f32],
    beta: &[f32],
    y: &mut [f32],
) {
    let y_addr = y.as_mut_ptr() as usize;
    let y_len = y.len();
    let grain = (SERIAL_GRAIN / hw.max(1)).max(1);
    parallel_for(n * c, grain, move |b0, b1| {
        // SAFETY: tasks own disjoint (image, channel) blocks of y.
        let y = unsafe { std::slice::from_raw_parts_mut(y_addr as *mut f32, y_len) };
        for b in b0..b1 {
            let ch = b % c;
            let base = b * hw;
            let scale = inv_std[ch] * gamma[ch];
            let shift = beta[ch] - mean[ch] * scale;
            for (o, &v) in y[base..base + hw].iter_mut().zip(&x[base..base + hw]) {
                *o = v * scale + shift;
            }
        }
    });
}

/// Backward: given g = dL/dy, produce dx, dgamma, dbeta.
/// dx = gamma*inv_std*(g - mean(g) - xhat*mean(g*xhat)) per channel.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward(
    n: usize,
    c: usize,
    hw: usize,
    x: &[f32],
    mean: &[f32],
    inv_std: &[f32],
    gamma: &[f32],
    g: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let m = (n * hw) as f32;
    // Pass 1: per-channel sums of g and g*xhat — channel-parallel, each
    // channel folded serially in image order (deterministic).
    let dg_addr = dgamma.as_mut_ptr() as usize;
    let db_addr = dbeta.as_mut_ptr() as usize;
    parallel_for(c, channel_grain(n, hw), move |c0, c1| {
        // SAFETY: tasks own disjoint channel ranges of dgamma/dbeta.
        let dgamma = unsafe { std::slice::from_raw_parts_mut(dg_addr as *mut f32, c) };
        let dbeta = unsafe { std::slice::from_raw_parts_mut(db_addr as *mut f32, c) };
        for ch in c0..c1 {
            let (mu, istd) = (mean[ch], inv_std[ch]);
            let (mut sg, mut sgx) = (0f32, 0f32);
            for img in 0..n {
                let base = (img * c + ch) * hw;
                for (&gv, &xv) in g[base..base + hw].iter().zip(&x[base..base + hw]) {
                    sg += gv;
                    sgx += gv * (xv - mu) * istd;
                }
            }
            dbeta[ch] = sg;
            dgamma[ch] = sgx;
        }
    });
    // Pass 2: dx — pure map over (image, channel) blocks.
    let dx_addr = dx.as_mut_ptr() as usize;
    let dx_len = dx.len();
    let dbeta_ro: &[f32] = dbeta;
    let dgamma_ro: &[f32] = dgamma;
    let grain = (SERIAL_GRAIN / hw.max(1)).max(1);
    parallel_for(n * c, grain, move |b0, b1| {
        // SAFETY: tasks own disjoint (image, channel) blocks of dx.
        let dx = unsafe { std::slice::from_raw_parts_mut(dx_addr as *mut f32, dx_len) };
        for b in b0..b1 {
            let ch = b % c;
            let base = b * hw;
            let (mu, istd, gam) = (mean[ch], inv_std[ch], gamma[ch]);
            let k1 = dbeta_ro[ch] / m;
            let k2 = dgamma_ro[ch] / m;
            let scale = gam * istd;
            for ((o, &gv), &xv) in
                dx[base..base + hw].iter_mut().zip(&g[base..base + hw]).zip(&x[base..base + hw])
            {
                let xhat = (xv - mu) * istd;
                *o = scale * (gv - k1 - xhat * k2);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn setup(n: usize, c: usize, hw: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut r = Rng::new(seed);
        let x: Vec<f32> = (0..n * c * hw).map(|_| r.uniform_range(-2.0, 2.0)).collect();
        let gamma: Vec<f32> = (0..c).map(|_| r.uniform_range(0.5, 1.5)).collect();
        let beta: Vec<f32> = (0..c).map(|_| r.uniform_range(-0.5, 0.5)).collect();
        (x, gamma, beta)
    }

    #[test]
    fn stats_match_naive() {
        let (x, _, _) = setup(3, 2, 8, 1);
        let mut mean = vec![0.0; 2];
        let mut var = vec![0.0; 2];
        bn_stats(3, 2, 8, &x, &mut mean, &mut var);
        for ch in 0..2 {
            let vals: Vec<f32> = (0..3)
                .flat_map(|img| x[(img * 2 + ch) * 8..(img * 2 + ch + 1) * 8].to_vec())
                .collect();
            let mu: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let vr: f32 = vals.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / vals.len() as f32;
            assert!((mean[ch] - mu).abs() < 1e-5);
            assert!((var[ch] - vr).abs() < 1e-5);
        }
    }

    #[test]
    fn normalize_produces_unit_stats() {
        let (x, gamma, beta) = setup(4, 3, 16, 2);
        let (n, c, hw) = (4usize, 3usize, 16usize);
        let mut mean = vec![0.0; c];
        let mut var = vec![0.0; c];
        bn_stats(n, c, hw, &x, &mut mean, &mut var);
        let inv_std: Vec<f32> = var.iter().map(|v| 1.0 / (v + 1e-5).sqrt()).collect();
        let mut y = vec![0.0; x.len()];
        bn_normalize(n, c, hw, &x, &mean, &inv_std, &gamma, &beta, &mut y);
        // Undo affine and check unit stats per channel.
        for ch in 0..c {
            let vals: Vec<f32> = (0..n)
                .flat_map(|img| {
                    y[(img * c + ch) * hw..(img * c + ch + 1) * hw]
                        .iter()
                        .map(|v| (v - beta[ch]) / gamma[ch])
                        .collect::<Vec<_>>()
                })
                .collect();
            let mu: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let vr: f32 = vals.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / vals.len() as f32;
            assert!(mu.abs() < 1e-4, "mean {mu}");
            assert!((vr - 1.0).abs() < 1e-2, "var {vr}");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let (x, gamma, beta) = setup(2, 2, 6, 3);
        let (n, c, hw) = (2usize, 2usize, 6usize);
        let mut r = Rng::new(9);
        let gout: Vec<f32> = (0..x.len()).map(|_| r.uniform_range(-1.0, 1.0)).collect();
        let eps_bn = 1e-5f32;

        let forward = |x: &[f32], gamma: &[f32], beta: &[f32]| -> Vec<f32> {
            let mut mean = vec![0.0; c];
            let mut var = vec![0.0; c];
            bn_stats(n, c, hw, x, &mut mean, &mut var);
            let inv_std: Vec<f32> = var.iter().map(|v| 1.0 / (v + eps_bn).sqrt()).collect();
            let mut y = vec![0.0; x.len()];
            bn_normalize(n, c, hw, x, &mean, &inv_std, gamma, beta, &mut y);
            y
        };
        let loss = |x: &[f32], gamma: &[f32], beta: &[f32]| -> f64 {
            forward(x, gamma, beta).iter().zip(&gout).map(|(&y, &g)| (y * g) as f64).sum()
        };

        let mut mean = vec![0.0; c];
        let mut var = vec![0.0; c];
        bn_stats(n, c, hw, &x, &mut mean, &mut var);
        let inv_std: Vec<f32> = var.iter().map(|v| 1.0 / (v + eps_bn).sqrt()).collect();
        let mut dx = vec![0.0; x.len()];
        let mut dgamma = vec![0.0; c];
        let mut dbeta = vec![0.0; c];
        bn_backward(n, c, hw, &x, &mean, &inv_std, &gamma, &gout, &mut dx, &mut dgamma, &mut dbeta);

        let h = 1e-3f32;
        for idx in [0usize, 5, 11, x.len() - 1] {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let fd = ((loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * h as f64)) as f32;
            assert!((dx[idx] - fd).abs() < 2e-2, "dx[{idx}] {} vs {}", dx[idx], fd);
        }
        for ch in 0..c {
            let mut gp = gamma.clone();
            gp[ch] += h;
            let mut gm = gamma.clone();
            gm[ch] -= h;
            let fd = ((loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * h as f64)) as f32;
            assert!((dgamma[ch] - fd).abs() < 2e-2, "dgamma[{ch}] {} vs {}", dgamma[ch], fd);
            let mut bp = beta.clone();
            bp[ch] += h;
            let mut bm = beta.clone();
            bm[ch] -= h;
            let fd = ((loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * h as f64)) as f32;
            assert!((dbeta[ch] - fd).abs() < 2e-2, "dbeta[{ch}] {} vs {}", dbeta[ch], fd);
        }
    }
}
