//! Native CPU kernels — torsk's stand-in for the vendor libraries
//! (cuDNN/cuBLAS) that all frameworks in the paper's Table 1 share (§6.3:
//! "these tools offload most of the computation to the same version of the
//! cuDNN and cuBLAS libraries").
//!
//! Kernels are plain functions over raw `f32` slices. They run either
//! inline on the host (CPU tensors) or inside a stream worker (simulated
//! device). A small persistent thread pool parallelizes the heavy ones;
//! the "basic parallel primitives" of the paper's C++ core (§5.1).
//!
//! # Thread-count control
//!
//! The pool is sized once, at first use, from (in priority order):
//!
//! 1. `PALLAS_NUM_THREADS` — the supported override, mirroring
//!    `OMP_NUM_THREADS` for the vendor-library pools PyTorch wraps;
//! 2. `TORSK_KERNEL_THREADS` — legacy alias, kept for compatibility;
//! 3. `std::thread::available_parallelism()`.
//!
//! [`num_threads`] reports the *effective* count used to split
//! [`parallel_for`] ranges. Tests and benchmarks may lower or raise it at
//! runtime with [`set_num_threads`]; this changes only how work is
//! chunked — the spawned workers persist — so it is cheap to sweep thread
//! counts inside one process. All reduction kernels are written so results
//! are bit-for-bit identical at every thread count (fixed-size chunks /
//! one-owner-per-output; see `dispatch` module docs), which makes the
//! override safe even when tests run concurrently.
//!
//! # GEMM design
//!
//! [`matmul`] is a packed, transpose-aware BLIS-style GEMM:
//!
//! * **Blocking.** Up to `MC = 64` rows × `KC = 256` depth × `NC = 512`
//!   columns ([`matmul::MC`]/[`matmul::KC`]/[`matmul::NC`]): the packed A
//!   block is 64 KiB and the packed B block 512 KiB at f32, sized to live
//!   in L2 while a KC×NR B panel streams through L1. For shapes whose
//!   natural grid would be too coarse to fill a pool (tall-skinny
//!   activations, linear layers), the row/column blocks shrink — derived
//!   from the shape and constants only; block boundaries never change the
//!   computed bits.
//! * **Packing.** A blocks are repacked into `MR`-row panels
//!   (`a[ip·kc·MR + p·MR + r]`), B blocks into `NR`-column panels
//!   (`b[jp·kc·NR + p·NR + c]`), zero-padded past the m/n edges (k is
//!   never padded). The pack routines read operands through arbitrary
//!   `(row, col)` element strides, which is what makes the API
//!   transpose-aware: a [`matmul::Trans`] flag — or a raw strided view in
//!   [`matmul::sgemm_strided`] — turns `Aᵀ`/`Bᵀ` into a stride swap
//!   instead of a materialized copy. `nn::Linear` goes one step further
//!   and reuses a cached pre-packed `Wᵀ` ([`matmul::sgemm_prepacked`]).
//! * **Microkernel.** An MR×NR register-tiled accumulator (8×8 f32, 4×4
//!   f64) runs the whole KC panel before touching C; `alpha`/`beta` apply
//!   at tile write-back, `beta` only on the first k panel.
//! * **Parallelism & determinism.** Work splits as a 2-D task grid (MC
//!   row blocks × NC column blocks) over [`parallel_for`]; each C tile
//!   has exactly one writing task and accumulates its k panels serially
//!   in k order. The grid and panel walk derive only from `(m, n, k)` and
//!   the constants — never from the worker count — so results are
//!   bit-for-bit identical at every thread count, batched entries
//!   included (`sgemm_batched`/`dgemm_batched` parallelize over the batch
//!   dim with the same property).
//! * **Degenerate cases.** `k == 0` or `alpha == 0` reduce to the
//!   explicit `C = beta·C` table (0 → clear, 1 → no-op, else scale),
//!   unit-tested combo by combo.

pub mod conv;
pub mod matmul;
pub mod norm;
pub mod pool;
pub mod simd;
pub mod softmax;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

/// A persistent worker pool for data-parallel kernel loops.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: usize,
}

impl ThreadPool {
    fn new(workers: usize) -> ThreadPool {
        let shared = Arc::new(PoolShared { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() });
        for i in 0..workers {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("torsk-kernel-{i}"))
                .spawn(move || loop {
                    let job = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(j) = q.pop_front() {
                                break j;
                            }
                            q = sh.cv.wait(q).unwrap();
                        }
                    };
                    job();
                })
                .expect("spawn kernel worker");
        }
        ThreadPool { shared, workers }
    }

    fn submit(&self, job: Job) {
        self.shared.queue.lock().unwrap().push_back(job);
        self.shared.cv.notify_one();
    }
}

fn pool() -> &'static ThreadPool {
    static POOL: once_cell::sync::Lazy<ThreadPool> = once_cell::sync::Lazy::new(|| {
        // PALLAS_NUM_THREADS is the documented knob (read once, here);
        // TORSK_KERNEL_THREADS is the legacy alias.
        let n = std::env::var("PALLAS_NUM_THREADS")
            .or_else(|_| std::env::var("TORSK_KERNEL_THREADS"))
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
            .max(1);
        ThreadPool::new(n)
    });
    &POOL
}

/// Runtime override of the effective thread count (0 = pool default).
static EFFECTIVE_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of kernel threads [`parallel_for`] splits work across: the
/// `PALLAS_NUM_THREADS`-sized pool, unless overridden by
/// [`set_num_threads`].
pub fn num_threads() -> usize {
    match EFFECTIVE_THREADS.load(Ordering::Relaxed) {
        0 => pool().workers,
        n => n,
    }
}

/// Test/bench-only hook: override the effective thread count at runtime.
/// Values are clamped to `[1, 1024]`; `set_num_threads(0)` restores the
/// pool default. Affects only how ranges are chunked — workers beyond the
/// pool size are emulated by queueing extra chunks, so sweeping `1, 2, 8`
/// works on any machine. Process-global; results stay deterministic under
/// concurrent changes because every reduction is thread-count-invariant.
pub fn set_num_threads(n: usize) {
    EFFECTIVE_THREADS.store(n.min(1024), Ordering::Relaxed);
}

// `simd` is the sibling runtime knob to the thread-count override: the
// vector level is detected once ([`simd::level`]), `PALLAS_SIMD=0` or
// [`simd::set_force_scalar`] forces the scalar kernels, and every vector
// path is bit-identical to its scalar reference (see simd.rs module docs).

/// Element count below which the TensorIter / reduction drivers stay
/// serial: splitting ~32k-element loops across the pool costs more in
/// wakeups than it saves (measured on the elementwise chain bench).
pub const SERIAL_GRAIN: usize = 32 * 1024;

/// Split `0..n` into chunks and run `f(start, end)` on the pool, blocking
/// until every chunk completes. `f` must be safe to run concurrently on
/// disjoint ranges (the standard parallel-for contract).
pub fn parallel_for<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Send + Sync,
{
    if n == 0 {
        return;
    }
    let workers = num_threads();
    if n <= grain || workers <= 1 {
        f(0, n);
        return;
    }
    let chunks = workers.min(n.div_ceil(grain)).max(1);
    let chunk = n.div_ceil(chunks);

    // Sanitizer: every split must partition 0..n into disjoint ranges —
    // the invariant all raw-pointer parallel writes rely on.
    #[cfg(feature = "debug-checks")]
    {
        let ranges: Vec<(usize, usize)> = (0..chunks)
            .map(|c| (c * chunk, ((c + 1) * chunk).min(n)))
            .filter(|&(s, e)| s < e)
            .collect();
        crate::debug_checks::verify_disjoint_cover(n, &ranges);
    }

    // Run chunk 0 on the caller; the rest on the pool.
    let done = Arc::new((Mutex::new(0usize), Condvar::new()));
    let nspawned = chunks - 1;
    // SAFETY of lifetime: we block until all jobs signal completion, so `f`
    // outlives every job. Erase the lifetime with a raw pointer.
    let f_ptr = &f as *const F as usize;
    for c in 1..chunks {
        let start = c * chunk;
        let end = ((c + 1) * chunk).min(n);
        if start >= end {
            let (lock, cv) = &*done;
            *lock.lock().unwrap() += 1;
            cv.notify_one();
            continue;
        }
        let done2 = done.clone();
        pool().submit(Box::new(move || {
            // SAFETY: see above — caller blocks until completion.
            let f = unsafe { &*(f_ptr as *const F) };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(start, end)));
            let (lock, cv) = &*done2;
            *lock.lock().unwrap() += 1;
            cv.notify_one();
            if let Err(e) = result {
                std::panic::resume_unwind(e);
            }
        }));
    }
    f(0, chunk.min(n));
    // Wait for the spawned chunks, *helping* with queued work while we
    // block — this keeps nested parallel_for calls deadlock-free (a worker
    // waiting on inner chunks drains the queue instead of sleeping).
    let (lock, cv) = &*done;
    loop {
        {
            let count = lock.lock().unwrap();
            if *count >= nspawned {
                break;
            }
        }
        let stolen = pool().shared.queue.lock().unwrap().pop_front();
        match stolen {
            Some(job) => job(),
            None => {
                let count = lock.lock().unwrap();
                if *count >= nspawned {
                    break;
                }
                let (c, _timeout) = cv
                    .wait_timeout(count, std::time::Duration::from_micros(100))
                    .unwrap();
                if *c >= nspawned {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 100_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 1000, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_small_runs_inline() {
        let count = AtomicUsize::new(0);
        parallel_for(10, 1000, |a, b| {
            count.fetch_add(b - a, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_zero_is_noop() {
        parallel_for(0, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<f32> = (0..250_000).map(|i| (i % 7) as f32).collect();
        let total = Mutex::new(0f64);
        parallel_for(data.len(), 10_000, |a, b| {
            let part: f64 = data[a..b].iter().map(|&x| x as f64).sum();
            *total.lock().unwrap() += part;
        });
        let serial: f64 = data.iter().map(|&x| x as f64).sum();
        assert_eq!(*total.lock().unwrap(), serial);
    }

    #[test]
    fn set_num_threads_override_roundtrip() {
        let default = num_threads();
        assert!(default >= 1);
        set_num_threads(2);
        assert_eq!(num_threads(), 2);
        // Coverage stays exact while the override is active.
        let n = 50_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 1000, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        set_num_threads(0);
        assert_eq!(num_threads(), default);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_parallel_for_does_not_deadlock() {
        // Outer inline chunk calls parallel_for again; pool must not
        // deadlock because the caller always participates.
        parallel_for(4, 1, |a, b| {
            for _ in a..b {
                parallel_for(50_000, 10_000, |x, y| {
                    std::hint::black_box(y - x);
                });
            }
        });
    }
}
