//! 2-D convolution kernels (the cuDNN stand-in): im2col + GEMM forward,
//! col2im backward-data, im2col-GEMM backward-weight. Supports stride,
//! zero padding and groups (groups == in_channels gives the depthwise
//! convolutions MobileNet needs).
//!
//! Layouts: input NCHW, weight [C_out, C_in/groups, KH, KW], output NCHW.

use super::matmul::{sgemm, sgemm_serial, Trans};
use super::parallel_for;

/// Static shape/config descriptor for one conv op.
#[derive(Clone, Copy, Debug)]
pub struct Conv2dArgs {
    pub batch: usize,
    pub c_in: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub c_out: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub padding: usize,
    pub groups: usize,
}

impl Conv2dArgs {
    pub fn h_out(&self) -> usize {
        (self.h_in + 2 * self.padding - self.kh) / self.stride + 1
    }
    pub fn w_out(&self) -> usize {
        (self.w_in + 2 * self.padding - self.kw) / self.stride + 1
    }
    /// Channels per group on the input side.
    pub fn cg_in(&self) -> usize {
        self.c_in / self.groups
    }
    /// Channels per group on the output side.
    pub fn cg_out(&self) -> usize {
        self.c_out / self.groups
    }
    pub fn out_len(&self) -> usize {
        self.batch * self.c_out * self.h_out() * self.w_out()
    }
    pub fn validate(&self) {
        crate::torsk_assert!(self.c_in % self.groups == 0, "c_in % groups != 0");
        crate::torsk_assert!(self.c_out % self.groups == 0, "c_out % groups != 0");
        crate::torsk_assert!(self.stride >= 1, "stride must be >= 1");
        crate::torsk_assert!(
            self.h_in + 2 * self.padding >= self.kh && self.w_in + 2 * self.padding >= self.kw,
            "kernel larger than padded input"
        );
    }
}

/// Unfold one image's group-slice into columns.
/// `input` is the [cg_in, H, W] slice; output `col` is
/// [cg_in*kh*kw, h_out*w_out], row-major.
fn im2col(args: &Conv2dArgs, input: &[f32], col: &mut [f32]) {
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let (kh, kw, stride, pad) = (args.kh, args.kw, args.stride, args.padding);
    let (h_in, w_in) = (args.h_in, args.w_in);
    let cols = h_out * w_out;
    for c in 0..args.cg_in() {
        let img = &input[c * h_in * w_in..(c + 1) * h_in * w_in];
        for ky in 0..kh {
            for kx in 0..kw {
                let row = ((c * kh + ky) * kw + kx) * cols;
                for oy in 0..h_out {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    let dst = &mut col[row + oy * w_out..row + (oy + 1) * w_out];
                    if iy < 0 || iy >= h_in as isize {
                        dst.fill(0.0);
                        continue;
                    }
                    let src_row = &img[iy as usize * w_in..(iy as usize + 1) * w_in];
                    if stride == 1 {
                        // §Perf: copy the valid contiguous run, zero edges.
                        let ox_lo = pad.saturating_sub(kx);
                        let ox_hi = (w_in + pad - kx).min(w_out);
                        dst[..ox_lo].fill(0.0);
                        dst[ox_lo..ox_hi]
                            .copy_from_slice(&src_row[ox_lo + kx - pad..ox_hi + kx - pad]);
                        dst[ox_hi..].fill(0.0);
                    } else {
                        for (ox, d) in dst.iter_mut().enumerate() {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            *d = if ix < 0 || ix >= w_in as isize { 0.0 } else { src_row[ix as usize] };
                        }
                    }
                }
            }
        }
    }
}

/// Fold columns back into an image (transpose of im2col); accumulates.
fn col2im(args: &Conv2dArgs, col: &[f32], input_grad: &mut [f32]) {
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let (kh, kw, stride, pad) = (args.kh, args.kw, args.stride, args.padding);
    let (h_in, w_in) = (args.h_in, args.w_in);
    let cols = h_out * w_out;
    for c in 0..args.cg_in() {
        let img = &mut input_grad[c * h_in * w_in..(c + 1) * h_in * w_in];
        for ky in 0..kh {
            for kx in 0..kw {
                let row = ((c * kh + ky) * kw + kx) * cols;
                for oy in 0..h_out {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h_in as isize {
                        continue;
                    }
                    let src = &col[row + oy * w_out..row + (oy + 1) * w_out];
                    if stride == 1 {
                        // §Perf: branch-free inner loop over the valid ox
                        // range (ix = ox + kx - pad in [0, w_in)).
                        let ox_lo = pad.saturating_sub(kx);
                        let ox_hi = (w_in + pad - kx).min(w_out);
                        let base = iy as usize * w_in + kx;
                        for ox in ox_lo..ox_hi {
                            img[base + ox - pad] += src[ox];
                        }
                    } else {
                        for (ox, &v) in src.iter().enumerate() {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix >= 0 && ix < w_in as isize {
                                img[iy as usize * w_in + ix as usize] += v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Forward: `out[N, C_out, H_out, W_out] = conv(input, weight) + bias?`.
pub fn conv2d_forward(args: &Conv2dArgs, input: &[f32], weight: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
    args.validate();
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let cols = h_out * w_out;
    let (cg_in, cg_out) = (args.cg_in(), args.cg_out());
    let col_rows = cg_in * args.kh * args.kw;
    let in_img = args.c_in * args.h_in * args.w_in;
    let out_img = args.c_out * cols;

    let out_addr = out.as_mut_ptr() as usize;
    let out_len = out.len();
    parallel_for(args.batch, 1, move |n0, n1| {
        // SAFETY: `out_addr/out_len` come from the caller's live `&mut out`
        // borrow, which outlives this closure because parallel_for blocks
        // until every chunk completes; chunks write disjoint image ranges
        // [n0*out_img, n1*out_img).
        let out_all = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, out_len) };
        let mut col = vec![0.0f32; col_rows * cols];
        for n in n0..n1 {
            for g in 0..args.groups {
                let in_slice = &input[n * in_img + g * cg_in * args.h_in * args.w_in
                    ..n * in_img + (g + 1) * cg_in * args.h_in * args.w_in];
                im2col(args, in_slice, &mut col);
                // weight group: [cg_out, col_rows] @ col [col_rows, cols]
                let w_slice = &weight[g * cg_out * col_rows..(g + 1) * cg_out * col_rows];
                let o_slice = &mut out_all[n * out_img + g * cg_out * cols..n * out_img + (g + 1) * cg_out * cols];
                // Bias folds into the GEMM: pre-fill the output rows and
                // accumulate the product on top (beta = 1). Serial packed
                // gemm per (image, group); parallelism is over batch.
                let beta = match bias {
                    Some(b) => {
                        for oc in 0..cg_out {
                            o_slice[oc * cols..(oc + 1) * cols].fill(b[g * cg_out + oc]);
                        }
                        1.0
                    }
                    None => 0.0,
                };
                sgemm_serial(Trans::N, Trans::N, cg_out, cols, col_rows, 1.0, w_slice, &col, beta, o_slice);
            }
        }
    });
}

/// Backward w.r.t. input: scatter `weightᵀ @ grad_out` columns via col2im.
pub fn conv2d_backward_input(args: &Conv2dArgs, grad_out: &[f32], weight: &[f32], grad_in: &mut [f32]) {
    args.validate();
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let cols = h_out * w_out;
    let (cg_in, cg_out) = (args.cg_in(), args.cg_out());
    let col_rows = cg_in * args.kh * args.kw;
    let in_img = args.c_in * args.h_in * args.w_in;
    let out_img = args.c_out * cols;

    grad_in.fill(0.0);
    let gi_addr = grad_in.as_mut_ptr() as usize;
    let gi_len = grad_in.len();
    // No materialized weight transpose: the packed GEMM consumes
    // `weightᵀ` directly via the `Trans::T` flag.
    parallel_for(args.batch, 1, move |n0, n1| {
        // SAFETY: `gi_addr/gi_len` come from the caller's live `&mut
        // grad_in` borrow (parallel_for blocks until all chunks finish);
        // chunks write disjoint image ranges [n0*in_img, n1*in_img).
        let gi_all = unsafe { std::slice::from_raw_parts_mut(gi_addr as *mut f32, gi_len) };
        let mut col = vec![0.0f32; col_rows * cols];
        for n in n0..n1 {
            for g in 0..args.groups {
                let w_slice = &weight[g * cg_out * col_rows..(g + 1) * cg_out * col_rows];
                let go = &grad_out[n * out_img + g * cg_out * cols..n * out_img + (g + 1) * cg_out * cols];
                // col = wᵀ [col_rows, cg_out] @ go [cg_out, cols]
                sgemm_serial(Trans::T, Trans::N, col_rows, cols, cg_out, 1.0, w_slice, go, 0.0, &mut col);
                let gi = &mut gi_all[n * in_img + g * cg_in * args.h_in * args.w_in
                    ..n * in_img + (g + 1) * cg_in * args.h_in * args.w_in];
                col2im(args, &col, gi);
            }
        }
    });
}

/// Backward w.r.t. weight (+ bias): accumulate `grad_out @ colᵀ` per image.
pub fn conv2d_backward_weight(
    args: &Conv2dArgs,
    input: &[f32],
    grad_out: &[f32],
    grad_weight: &mut [f32],
    mut grad_bias: Option<&mut [f32]>,
) {
    args.validate();
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let cols = h_out * w_out;
    let (cg_in, cg_out) = (args.cg_in(), args.cg_out());
    let col_rows = cg_in * args.kh * args.kw;
    let in_img = args.c_in * args.h_in * args.w_in;
    let out_img = args.c_out * cols;

    grad_weight.fill(0.0);
    if let Some(gb) = grad_bias.as_deref_mut() {
        gb.fill(0.0);
    }
    // No materialized transposes: gw [cg_out, col_rows] += go [cg_out,
    // cols] @ colᵀ, with colᵀ consumed in place via `Trans::T`. The GEMM
    // itself parallelizes (we are at top level here); the batch loop is
    // serial and accumulates via beta = 1, so results stay bit-identical
    // at every thread count.
    let mut col = vec![0.0f32; col_rows * cols];
    for n in 0..args.batch {
        for g in 0..args.groups {
            let in_slice = &input[n * in_img + g * cg_in * args.h_in * args.w_in
                ..n * in_img + (g + 1) * cg_in * args.h_in * args.w_in];
            im2col(args, in_slice, &mut col);
            let go = &grad_out[n * out_img + g * cg_out * cols..n * out_img + (g + 1) * cg_out * cols];
            let gw = &mut grad_weight[g * cg_out * col_rows..(g + 1) * cg_out * col_rows];
            sgemm(Trans::N, Trans::T, cg_out, col_rows, cols, 1.0, go, &col, 1.0, gw);
            if let Some(gb) = grad_bias.as_deref_mut() {
                for oc in 0..cg_out {
                    let s: f32 = go[oc * cols..(oc + 1) * cols].iter().sum();
                    gb[g * cg_out + oc] += s;
                }
            }
        }
    }
}

/// Direct (quadruple-loop) reference convolution for tests.
pub fn conv2d_ref(args: &Conv2dArgs, input: &[f32], weight: &[f32], bias: Option<&[f32]>) -> Vec<f32> {
    let (h_out, w_out) = (args.h_out(), args.w_out());
    let (cg_in, cg_out) = (args.cg_in(), args.cg_out());
    let mut out = vec![0.0f32; args.out_len()];
    for n in 0..args.batch {
        for g in 0..args.groups {
            for oc in 0..cg_out {
                let ocg = g * cg_out + oc;
                for oy in 0..h_out {
                    for ox in 0..w_out {
                        let mut acc = bias.map(|b| b[ocg]).unwrap_or(0.0) as f64;
                        for ic in 0..cg_in {
                            let icg = g * cg_in + ic;
                            for ky in 0..args.kh {
                                for kx in 0..args.kw {
                                    let iy = (oy * args.stride + ky) as isize - args.padding as isize;
                                    let ix = (ox * args.stride + kx) as isize - args.padding as isize;
                                    if iy < 0 || ix < 0 || iy >= args.h_in as isize || ix >= args.w_in as isize {
                                        continue;
                                    }
                                    let iv = input[((n * args.c_in + icg) * args.h_in + iy as usize) * args.w_in + ix as usize];
                                    let wv = weight[((ocg * cg_in + ic) * args.kh + ky) * args.kw + kx];
                                    acc += (iv * wv) as f64;
                                }
                            }
                        }
                        out[((n * args.c_out + ocg) * h_out + oy) * w_out + ox] = acc as f32;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.uniform_range(-1.0, 1.0)).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() <= tol + tol * y.abs(), "{what} idx {i}: {x} vs {y}");
        }
    }

    fn check_forward(args: Conv2dArgs, seed: u64) {
        let mut r = Rng::new(seed);
        let input = rand_vec(&mut r, args.batch * args.c_in * args.h_in * args.w_in);
        let weight = rand_vec(&mut r, args.c_out * args.cg_in() * args.kh * args.kw);
        let bias = rand_vec(&mut r, args.c_out);
        let mut out = vec![0.0f32; args.out_len()];
        conv2d_forward(&args, &input, &weight, Some(&bias), &mut out);
        let expect = conv2d_ref(&args, &input, &weight, Some(&bias));
        assert_close(&out, &expect, 1e-4, "forward");
    }

    #[test]
    fn forward_basic_3x3() {
        check_forward(
            Conv2dArgs { batch: 2, c_in: 3, h_in: 8, w_in: 8, c_out: 4, kh: 3, kw: 3, stride: 1, padding: 1, groups: 1 },
            1,
        );
    }

    #[test]
    fn forward_stride_2_no_pad() {
        check_forward(
            Conv2dArgs { batch: 1, c_in: 2, h_in: 9, w_in: 7, c_out: 3, kh: 3, kw: 3, stride: 2, padding: 0, groups: 1 },
            2,
        );
    }

    #[test]
    fn forward_1x1_conv() {
        check_forward(
            Conv2dArgs { batch: 2, c_in: 8, h_in: 5, w_in: 5, c_out: 16, kh: 1, kw: 1, stride: 1, padding: 0, groups: 1 },
            3,
        );
    }

    #[test]
    fn forward_depthwise_groups() {
        check_forward(
            Conv2dArgs { batch: 2, c_in: 6, h_in: 8, w_in: 8, c_out: 6, kh: 3, kw: 3, stride: 1, padding: 1, groups: 6 },
            4,
        );
    }

    #[test]
    fn forward_grouped_conv() {
        check_forward(
            Conv2dArgs { batch: 1, c_in: 4, h_in: 6, w_in: 6, c_out: 8, kh: 3, kw: 3, stride: 1, padding: 1, groups: 2 },
            5,
        );
    }

    #[test]
    fn forward_large_kernel_big_pad() {
        check_forward(
            Conv2dArgs { batch: 1, c_in: 1, h_in: 10, w_in: 10, c_out: 2, kh: 5, kw: 5, stride: 1, padding: 2, groups: 1 },
            6,
        );
    }

    /// Finite-difference check of backward-input and backward-weight.
    #[test]
    fn backward_matches_finite_difference() {
        let args = Conv2dArgs { batch: 1, c_in: 2, h_in: 5, w_in: 5, c_out: 3, kh: 3, kw: 3, stride: 2, padding: 1, groups: 1 };
        let mut r = Rng::new(7);
        let input = rand_vec(&mut r, args.batch * args.c_in * args.h_in * args.w_in);
        let weight = rand_vec(&mut r, args.c_out * args.cg_in() * args.kh * args.kw);
        // Loss = sum(conv(x, w) * G) with fixed random G.
        let gvec = rand_vec(&mut r, args.out_len());
        let loss = |inp: &[f32], w: &[f32]| -> f64 {
            let out = conv2d_ref(&args, inp, w, None);
            out.iter().zip(gvec.iter()).map(|(&o, &g)| (o * g) as f64).sum()
        };

        let mut gi = vec![0.0f32; input.len()];
        conv2d_backward_input(&args, &gvec, &weight, &mut gi);
        let mut gw = vec![0.0f32; weight.len()];
        conv2d_backward_weight(&args, &input, &gvec, &mut gw, None);

        let eps = 1e-3f32;
        for idx in [0usize, 7, 13, input.len() - 1] {
            let mut ip = input.clone();
            ip[idx] += eps;
            let mut im = input.clone();
            im[idx] -= eps;
            let fd = ((loss(&ip, &weight) - loss(&im, &weight)) / (2.0 * eps as f64)) as f32;
            assert!((gi[idx] - fd).abs() < 2e-2, "input grad idx {idx}: {} vs fd {}", gi[idx], fd);
        }
        for idx in [0usize, 5, weight.len() - 1] {
            let mut wp = weight.clone();
            wp[idx] += eps;
            let mut wm = weight.clone();
            wm[idx] -= eps;
            let fd = ((loss(&input, &wp) - loss(&input, &wm)) / (2.0 * eps as f64)) as f32;
            assert!((gw[idx] - fd).abs() < 2e-2, "weight grad idx {idx}: {} vs fd {}", gw[idx], fd);
        }
    }

    #[test]
    fn backward_bias_sums_grad() {
        let args = Conv2dArgs { batch: 2, c_in: 1, h_in: 4, w_in: 4, c_out: 2, kh: 3, kw: 3, stride: 1, padding: 1, groups: 1 };
        let input = vec![0.5f32; 2 * 16];
        let grad_out = vec![1.0f32; args.out_len()];
        let mut gw = vec![0.0f32; 2 * 9];
        let mut gb = vec![0.0f32; 2];
        conv2d_backward_weight(&args, &input, &grad_out, &mut gw, Some(&mut gb));
        // Each output channel has batch*h_out*w_out = 2*16 grad ones.
        assert_eq!(gb, vec![32.0, 32.0]);
    }

    #[test]
    #[should_panic(expected = "groups")]
    fn invalid_groups_panics() {
        let args = Conv2dArgs { batch: 1, c_in: 3, h_in: 4, w_in: 4, c_out: 4, kh: 1, kw: 1, stride: 1, padding: 0, groups: 2 };
        let mut out = vec![0.0; args.out_len()];
        conv2d_forward(&args, &[0.0; 48], &[0.0; 8], None, &mut out);
    }
}
