//! Error types for torsk.
//!
//! Shape/dtype misuse panics with a descriptive message (mirroring the
//! eager, fail-fast semantics of the paper's Python API, §4.3: "the really
//! complicated cases result in a user error"). Runtime failures that a
//! caller can reasonably handle (I/O, PJRT, IPC) are `Result`-based.

use thiserror::Error;

/// Errors surfaced through `Result` on fallible torsk APIs.
#[derive(Error, Debug)]
pub enum TorskError {
    /// An artifact (AOT-compiled HLO module) could not be found or loaded.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The XLA/PJRT runtime reported an error.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Shared-memory / multiprocessing failure.
    #[error("multiprocessing error: {0}")]
    Multiproc(String),

    /// I/O error (artifact files, corpora, traces).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// A saved-for-backward tensor was mutated in place before the backward
    /// pass ran (§4.3's tensor versioning system).
    #[error(
        "one of the variables needed for gradient computation has been \
         modified by an inplace operation: expected version {expected}, \
         found version {found}"
    )]
    Version { expected: u64, found: u64 },

    /// Generic configuration / usage error.
    #[error("{0}")]
    Msg(String),
}

impl From<anyhow::Error> for TorskError {
    fn from(e: anyhow::Error) -> Self {
        TorskError::Xla(format!("{e:#}"))
    }
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, TorskError>;

/// Panic with a consistent prefix on API misuse (shape/dtype errors).
#[macro_export]
macro_rules! torsk_bail {
    ($($arg:tt)*) => {
        panic!("torsk: {}", format!($($arg)*))
    };
}

/// Assert a usage invariant, panicking with a torsk-prefixed message.
#[macro_export]
macro_rules! torsk_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            panic!("torsk: {}", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn version_error_message_mentions_inplace() {
        let e = TorskError::Version { expected: 3, found: 5 };
        let s = e.to_string();
        assert!(s.contains("inplace"));
        assert!(s.contains("expected version 3"));
    }

    #[test]
    fn msg_error_displays_inner() {
        let e = TorskError::Msg("bad config".into());
        assert_eq!(e.to_string(), "bad config");
    }

    #[test]
    #[should_panic(expected = "torsk: boom 7")]
    fn bail_macro_panics_with_prefix() {
        torsk_bail!("boom {}", 7);
    }

    #[test]
    fn assert_macro_passes_on_true() {
        torsk_assert!(1 + 1 == 2, "math broke");
    }

    #[test]
    #[should_panic(expected = "torsk: sizes differ")]
    fn assert_macro_panics_on_false() {
        torsk_assert!(false, "sizes differ");
    }
}
