//! Error types for torsk.
//!
//! Shape/dtype misuse panics with a descriptive message (mirroring the
//! eager, fail-fast semantics of the paper's Python API, §4.3: "the really
//! complicated cases result in a user error"). Runtime failures that a
//! caller can reasonably handle (I/O, PJRT, IPC) are `Result`-based.

use std::path::PathBuf;

use thiserror::Error;

/// Errors surfaced through `Result` on fallible torsk APIs.
#[derive(Error, Debug)]
pub enum TorskError {
    /// An artifact (AOT-compiled HLO module) could not be found or loaded.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The XLA/PJRT runtime reported an error.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// An XLA/PJRT entry point was called in a build without the `aot`
    /// feature — the `xla` dependency is compiled out, so artifacts can
    /// neither be compiled nor executed.
    #[error("{what}: torsk was built without the `aot` feature (rebuild with `--features aot`)")]
    AotDisabled {
        /// What was attempted ("load artifact `mlp_step`").
        what: String,
    },

    /// Shared-memory / multiprocessing failure.
    #[error("multiprocessing error: {0}")]
    Multiproc(String),

    /// One or more forked workers failed. Each entry names the rank, its
    /// pid, and *how* it died ([`crate::multiproc::RankExit`]) — a
    /// silently merged partial run (one dead rank, N-1 good ones) is the
    /// worst outcome, so callers get typed per-rank diagnostics rather
    /// than a prejoined string.
    #[error("{} of {total} worker(s) failed: {}", failed.len(), join_rank_failures(failed))]
    Workers {
        /// How many workers were forked.
        total: usize,
        /// The workers that did not exit cleanly, in rank order.
        failed: Vec<crate::multiproc::RankFailure>,
    },

    /// I/O failure with context: which operation, on which path. The
    /// underlying `std::io::Error` is source-chained so callers (and
    /// `{:#}`-style reports) see the OS-level cause.
    #[error("{op} {}: {source}", path.display())]
    Io {
        /// What was being attempted ("write checkpoint", "read checkpoint").
        op: &'static str,
        /// The file involved.
        path: PathBuf,
        /// The OS-level error.
        #[source]
        source: std::io::Error,
    },

    /// A file failed structural validation on load: bad magic, truncated
    /// payload, checksum mismatch. Carries enough context (path, byte
    /// offset, expected vs found) to diagnose torn writes and bit rot
    /// without a hex dump.
    #[error(
        "corrupt file {}: {what} at byte {offset} (expected {expected:#x}, found {found:#x})",
        path.display()
    )]
    Corrupt {
        /// The file that failed validation.
        path: PathBuf,
        /// Byte offset at which the problem was detected.
        offset: u64,
        /// What check failed ("bad magic", "checksum mismatch", ...).
        what: String,
        /// The expected value (checksum, magic, length...).
        expected: u64,
        /// The value actually found.
        found: u64,
    },

    /// A saved-for-backward tensor was mutated in place before the backward
    /// pass ran (§4.3's tensor versioning system).
    #[error(
        "one of the variables needed for gradient computation has been \
         modified by an inplace operation: expected version {expected}, \
         found version {found}"
    )]
    Version { expected: u64, found: u64 },

    /// Generic configuration / usage error.
    #[error("{0}")]
    Msg(String),
}

/// Join per-rank failures for the [`TorskError::Workers`] Display impl.
fn join_rank_failures(failed: &[crate::multiproc::RankFailure]) -> String {
    let parts: Vec<String> = failed.iter().map(|f| f.to_string()).collect();
    parts.join("; ")
}

impl From<anyhow::Error> for TorskError {
    fn from(e: anyhow::Error) -> Self {
        TorskError::Xla(format!("{e:#}"))
    }
}

impl TorskError {
    /// Wrap an `std::io::Error` with operation + path context. There is
    /// deliberately no bare `From<std::io::Error>`: every I/O failure must
    /// say what it was doing and to which file.
    pub fn io(op: &'static str, path: impl Into<PathBuf>, source: std::io::Error) -> TorskError {
        TorskError::Io { op, path: path.into(), source }
    }

    /// The typed "built without aot" error: `what` names the attempted
    /// operation. Returned by every stubbed PJRT/AOT entry point.
    pub fn aot_disabled(what: impl Into<String>) -> TorskError {
        TorskError::AotDisabled { what: what.into() }
    }
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, TorskError>;

/// Panic with a consistent prefix on API misuse (shape/dtype errors).
#[macro_export]
macro_rules! torsk_bail {
    ($($arg:tt)*) => {
        panic!("torsk: {}", format!($($arg)*))
    };
}

/// Assert a usage invariant, panicking with a torsk-prefixed message.
#[macro_export]
macro_rules! torsk_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            panic!("torsk: {}", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn version_error_message_mentions_inplace() {
        let e = TorskError::Version { expected: 3, found: 5 };
        let s = e.to_string();
        assert!(s.contains("inplace"));
        assert!(s.contains("expected version 3"));
    }

    #[test]
    fn aot_disabled_error_names_operation_and_fix() {
        let e = TorskError::aot_disabled("load artifact `mlp_step`");
        let s = e.to_string();
        assert!(s.contains("load artifact `mlp_step`"), "{s}");
        assert!(s.contains("--features aot"), "{s}");
    }

    #[test]
    fn workers_error_joins_per_rank_failures() {
        use crate::multiproc::{RankExit, RankFailure};
        let e = TorskError::Workers {
            total: 4,
            failed: vec![
                RankFailure { rank: 1, pid: 4242, exit: RankExit::Signaled(9) },
                RankFailure { rank: 3, pid: 4244, exit: RankExit::Exited(101) },
            ],
        };
        let s = e.to_string();
        assert_eq!(
            s,
            "2 of 4 worker(s) failed: rank 1 (pid 4242): killed by signal 9; \
             rank 3 (pid 4244): exited with status 101"
        );
    }

    #[test]
    fn msg_error_displays_inner() {
        let e = TorskError::Msg("bad config".into());
        assert_eq!(e.to_string(), "bad config");
    }

    #[test]
    fn io_error_names_op_path_and_chains_source() {
        use std::error::Error as _;
        let e = TorskError::io(
            "write checkpoint",
            "/tmp/model.ckpt",
            std::io::Error::new(std::io::ErrorKind::PermissionDenied, "denied"),
        );
        let s = e.to_string();
        assert!(s.contains("write checkpoint"), "{s}");
        assert!(s.contains("/tmp/model.ckpt"), "{s}");
        assert!(e.source().is_some(), "io::Error must be source-chained");
    }

    #[test]
    fn corrupt_error_reports_offset_and_checksums() {
        let e = TorskError::Corrupt {
            path: "/tmp/model.ckpt".into(),
            offset: 12,
            what: "checksum mismatch".into(),
            expected: 0xCBF4_3926,
            found: 0xDEAD_BEEF,
        };
        let s = e.to_string();
        assert!(s.contains("checksum mismatch"), "{s}");
        assert!(s.contains("byte 12"), "{s}");
        assert!(s.contains("0xcbf43926"), "{s}");
        assert!(s.contains("0xdeadbeef"), "{s}");
    }

    #[test]
    #[should_panic(expected = "torsk: boom 7")]
    fn bail_macro_panics_with_prefix() {
        torsk_bail!("boom {}", 7);
    }

    #[test]
    fn assert_macro_passes_on_true() {
        torsk_assert!(1 + 1 == 2, "math broke");
    }

    #[test]
    #[should_panic(expected = "torsk: sizes differ")]
    fn assert_macro_panics_on_false() {
        torsk_assert!(false, "sizes differ");
    }
}
