//! The conv2d kernel entry for the dispatcher (wraps the im2col kernels).
//!
//! The im2col products run on the packed BLIS-style GEMM core
//! (`kernels::matmul`): forward folds the bias into the GEMM's beta pass,
//! backward-input consumes `weightᵀ` via a `Trans` flag and
//! backward-weight consumes `colᵀ` the same way — no materialized
//! transposes anywhere in the conv path.

use crate::autograd::{ClosureFunction, Function, SavedTensor};
use crate::device;
use crate::kernels::conv::{conv2d_backward_input, conv2d_backward_weight, conv2d_forward, Conv2dArgs};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::{OpCtx, OpDef, OpSample, Param, Registry};

fn conv_args(ctx: &OpCtx) -> Conv2dArgs {
    let (input, weight) = (ctx.input(0), ctx.input(1));
    torsk_assert!(input.ndim() == 4, "conv2d: input must be NCHW, got {:?}", input.shape());
    torsk_assert!(weight.ndim() == 4, "conv2d: weight must be 4-D, got {:?}", weight.shape());
    let args = Conv2dArgs {
        batch: input.size(0),
        c_in: input.size(1),
        h_in: input.size(2),
        w_in: input.size(3),
        c_out: weight.size(0),
        kh: weight.size(2),
        kw: weight.size(3),
        stride: ctx.usize(0),
        padding: ctx.usize(1),
        groups: ctx.usize(2),
    };
    args.validate();
    torsk_assert!(
        weight.size(1) == args.cg_in(),
        "conv2d: weight in-channels {} != input {}/groups {}",
        weight.size(1),
        args.c_in,
        args.groups
    );
    if ctx.num_inputs() == 3 {
        torsk_assert!(
            ctx.input(2).shape() == [args.c_out],
            "conv2d: bias shape {:?}",
            ctx.input(2).shape()
        );
    }
    args
}

/// 2-D convolution: input [N,C,H,W], weight [Cout, Cin/groups, KH, KW],
/// optional bias [Cout] as the third input.
fn k_conv2d(ctx: &OpCtx) -> Tensor {
    let args = conv_args(ctx);
    let dev = ctx.device;
    let input_c = ctx.input(0).contiguous();
    let weight_c = ctx.input(1).contiguous();
    let bias_c = if ctx.num_inputs() == 3 { Some(ctx.input(2).contiguous()) } else { None };
    let out = Tensor::empty(&[args.batch, args.c_out, args.h_out(), args.w_out()], DType::F32, dev);

    let (ip, wp, op) = (input_c.data_ptr(), weight_c.data_ptr(), out.data_ptr());
    let bp = bias_c.as_ref().map(|b| b.data_ptr());
    let (in_len, w_len, out_len) = (input_c.numel(), weight_c.numel(), out.numel());
    let c_out = args.c_out;
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(dev, "conv2d", move || unsafe {
        let iv = ip.as_slice::<f32>(0, in_len);
        let wv = wp.as_slice::<f32>(0, w_len);
        let bv = bp.map(|p| p.as_slice::<f32>(0, c_out));
        let ov = op.as_mut_slice::<f32>(0, out_len);
        conv2d_forward(&args, iv, wv, bv, ov);
    });
    out
}

fn bw_conv2d(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let args = conv_args(ctx);
    let vi = SavedTensor::save(&ctx.input(0).contiguous());
    let vw = SavedTensor::save(&ctx.input(1).contiguous());
    let has_bias = ctx.num_inputs() == 3;
    ClosureFunction::new("conv2d", move |g| {
        let input = vi.unpack();
        let weight = vw.unpack();
        let g = g.contiguous();
        if g.device().is_async() {
            device::synchronize();
        }
        let gv = g.to_vec::<f32>();
        let iv = input.to_vec::<f32>();
        let wv = weight.to_vec::<f32>();

        let mut gi = vec![0.0f32; iv.len()];
        conv2d_backward_input(&args, &gv, &wv, &mut gi);
        let mut gw = vec![0.0f32; wv.len()];
        let mut gb = if has_bias { Some(vec![0.0f32; args.c_out]) } else { None };
        conv2d_backward_weight(&args, &iv, &gv, &mut gw, gb.as_deref_mut());

        let dev = input.device();
        let mut grads = vec![
            Some(Tensor::from_vec(gi, input.shape()).to_device(dev)),
            Some(Tensor::from_vec(gw, weight.shape()).to_device(dev)),
        ];
        if let Some(gb) = gb {
            grads.push(Some(Tensor::from_vec(gb, &[args.c_out]).to_device(dev)));
        }
        grads
    })
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

fn s_conv2d(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None; // f32-only im2col kernel
    }
    let x = super::sample_uniform(seed, &[1, 2, 4, 4], dt, -1.0, 1.0)?;
    let w = super::sample_uniform(seed ^ 0x1, &[2, 2, 3, 3], dt, -0.5, 0.5)?;
    let b = super::sample_uniform(seed ^ 0x2, &[2], dt, -0.5, 0.5)?;
    Some(OpSample {
        inputs: vec![x, w, b],
        params: vec![Param::Usize(1), Param::Usize(1), Param::Usize(1)],
        grad_inputs: vec![0, 1, 2],
    })
}

pub(crate) fn register(reg: &mut Registry) {
    reg.add(
        OpDef::new("conv2d", 2, 3, &[DType::F32])
            .kernel_all(k_conv2d)
            .backward(bw_conv2d)
            .sample_inputs(s_conv2d),
    );
}
