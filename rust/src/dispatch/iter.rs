//! `TensorIter`-style shared iteration planning for elementwise kernels.
//!
//! Mirrors ATen's `TensorIterator`: the *host* resolves broadcasting and
//! picks an execution strategy once, and every dtype-monomorphized kernel
//! then drives the same plan. Three strategies, fastest first:
//!
//! 1. **Fast**: all operands dense and same-shape — one parallel flat loop.
//! 2. **Suffix**: a trailing run of dims over which each operand advances
//!    either contiguously (step 1) or not at all (step 0, broadcast); the
//!    inner loop is tight and vectorizable, the odometer only walks the
//!    leading dims. This is what keeps `x * gamma[1,C,1,1]`-style ops fast.
//! 3. **Strided**: fully generic odometer walk (rare).

use crate::tensor::shape::{broadcast_shapes, broadcast_strides, numel, StridedIter};
use crate::tensor::storage::SendPtr;
use crate::tensor::{Element, Tensor};

/// Execution strategy for a planned elementwise traversal.
enum BinMode {
    Fast,
    Suffix {
        outer_shape: Vec<usize>,
        outer_sa: Vec<usize>,
        outer_sb: Vec<usize>,
        inner: usize,
        step_a: usize,
        step_b: usize,
    },
    Strided {
        sa: Vec<usize>,
        sb: Vec<usize>,
    },
}

/// A resolved two-operand broadcast traversal (the `TensorIter`).
pub(crate) struct TensorIter {
    pub out_shape: Vec<usize>,
    pub n: usize,
    mode: BinMode,
}

impl TensorIter {
    /// Plan the traversal for `a OP b` with NumPy broadcasting.
    pub(crate) fn binary(a: &Tensor, b: &Tensor) -> TensorIter {
        let out_shape = broadcast_shapes(a.shape(), b.shape());
        let n = numel(&out_shape);
        let fast = a.shape() == out_shape.as_slice()
            && b.shape() == out_shape.as_slice()
            && a.is_contiguous()
            && b.is_contiguous();
        if fast {
            return TensorIter { out_shape, n, mode: BinMode::Fast };
        }
        let sa = broadcast_strides(a.shape(), a.strides(), &out_shape);
        let sb = broadcast_strides(b.shape(), b.strides(), &out_shape);
        let (t, step_a, step_b) = linear_suffix(&out_shape, &sa, &sb);
        let rank = out_shape.len();
        let inner: usize = out_shape[rank - t..].iter().product();
        if t > 0 && inner > 1 {
            let mode = BinMode::Suffix {
                outer_shape: out_shape[..rank - t].to_vec(),
                outer_sa: sa[..rank - t].to_vec(),
                outer_sb: sb[..rank - t].to_vec(),
                inner,
                step_a,
                step_b,
            };
            TensorIter { out_shape, n, mode }
        } else {
            TensorIter { out_shape, n, mode: BinMode::Strided { sa, sb } }
        }
    }

    /// Drive the planned traversal with a scalar kernel `f`, reading `T`
    /// operands and writing `O` outputs. Runs on whatever thread executes
    /// the kernel (host or stream worker). Caller guarantees `ap`/`bp`
    /// point to `T` data valid for this plan's operand extents and `op`
    /// to an exclusive `O` buffer of `n` elements.
    pub(crate) fn run_binary<T: Element, O: Element>(
        &self,
        ap: SendPtr,
        bp: SendPtr,
        op: SendPtr,
        f: fn(T, T) -> O,
    ) {
        let n = self.n;
        if n == 0 {
            return;
        }
        match &self.mode {
            BinMode::Fast => unsafe {
                let av = ap.as_slice::<T>(0, n);
                let bv = bp.as_slice::<T>(0, n);
                crate::kernels::parallel_for(n, crate::kernels::PAR_GRAIN, |s, e| {
                    // SAFETY: disjoint ranges per chunk.
                    let ov = std::slice::from_raw_parts_mut(op.ptr() as *mut O, n);
                    for i in s..e {
                        ov[i] = f(av[i], bv[i]);
                    }
                });
            },
            BinMode::Suffix { outer_shape, outer_sa, outer_sb, inner, step_a, step_b } => unsafe {
                let inner = *inner;
                let (step_a, step_b) = (*step_a, *step_b);
                let ov = op.as_mut_slice::<O>(0, n);
                let ia = StridedIter::new(outer_shape, outer_sa);
                let ib = StridedIter::new(outer_shape, outer_sb);
                let (pa0, pb0) = (ap.ptr() as *const T, bp.ptr() as *const T);
                for (chunk, (offa, offb)) in ov.chunks_mut(inner).zip(ia.zip(ib)) {
                    let pa = pa0.add(offa);
                    let pb = pb0.add(offb);
                    match (step_a, step_b) {
                        (1, 0) => {
                            let s = *pb;
                            let av = std::slice::from_raw_parts(pa, inner);
                            for (o, &x) in chunk.iter_mut().zip(av) {
                                *o = f(x, s);
                            }
                        }
                        (0, 1) => {
                            let s = *pa;
                            let bv = std::slice::from_raw_parts(pb, inner);
                            for (o, &y) in chunk.iter_mut().zip(bv) {
                                *o = f(s, y);
                            }
                        }
                        (1, 1) => {
                            let av = std::slice::from_raw_parts(pa, inner);
                            let bv = std::slice::from_raw_parts(pb, inner);
                            for ((o, &x), &y) in chunk.iter_mut().zip(av).zip(bv) {
                                *o = f(x, y);
                            }
                        }
                        _ => {
                            let s = f(*pa, *pb);
                            chunk.fill(s);
                        }
                    }
                }
            },
            BinMode::Strided { sa, sb } => unsafe {
                let ov = op.as_mut_slice::<O>(0, n);
                let ia = StridedIter::new(&self.out_shape, sa);
                let ib = StridedIter::new(&self.out_shape, sb);
                let (pa0, pb0) = (ap.ptr() as *const T, bp.ptr() as *const T);
                for ((o, offa), offb) in ov.iter_mut().zip(ia).zip(ib) {
                    *o = f(*pa0.add(offa), *pb0.add(offb));
                }
            },
        }
    }
}

/// Flat parallel map for dense unary traversals (input made contiguous by
/// the caller). Caller guarantees `ap` points to `n` valid `T`s and `op`
/// to an exclusive `O` buffer of `n` elements.
pub(crate) fn run_unary<T: Element, O: Element>(n: usize, ap: SendPtr, op: SendPtr, f: fn(T) -> O) {
    if n == 0 {
        return;
    }
    unsafe {
        let av = ap.as_slice::<T>(0, n);
        crate::kernels::parallel_for(n, crate::kernels::PAR_GRAIN, |s, e| {
            // SAFETY: disjoint ranges per chunk.
            let ov = std::slice::from_raw_parts_mut(op.ptr() as *mut O, n);
            for i in s..e {
                ov[i] = f(av[i]);
            }
        });
    }
}

/// Longest trailing dim-suffix over which both stride vectors advance
/// linearly (contiguously for the suffix's own shape, or with stride 0).
/// Returns (suffix_len_in_dims, step_a, step_b) with steps in {0, 1}.
pub(crate) fn linear_suffix(shape: &[usize], sa: &[usize], sb: &[usize]) -> (usize, usize, usize) {
    let rank = shape.len();
    let classify = |strides: &[usize], t: usize| -> Option<usize> {
        // Suffix of length t: all-zero (step 0) or block-contiguous (step 1).
        let suffix_shape = &shape[rank - t..];
        let suffix = &strides[rank - t..];
        if suffix.iter().zip(suffix_shape).all(|(&s, &d)| s == 0 || d == 1) {
            return Some(0);
        }
        let mut acc = 1usize;
        for d in (0..t).rev() {
            if suffix_shape[d] != 1 && suffix[d] != acc {
                return None;
            }
            acc *= suffix_shape[d].max(1);
        }
        Some(1)
    };
    let mut best = (0usize, 0usize, 0usize);
    for t in 1..=rank {
        match (classify(sa, t), classify(sb, t)) {
            (Some(x), Some(y)) => best = (t, x, y),
            _ => break,
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_fast_for_dense_same_shape() {
        let a = Tensor::ones(&[2, 3]);
        let b = Tensor::ones(&[2, 3]);
        let it = TensorIter::binary(&a, &b);
        assert_eq!(it.out_shape, vec![2, 3]);
        assert!(matches!(it.mode, BinMode::Fast));
    }

    #[test]
    fn plan_suffix_for_row_broadcast() {
        let a = Tensor::ones(&[4, 8]);
        let b = Tensor::ones(&[8]);
        let it = TensorIter::binary(&a, &b);
        assert_eq!(it.out_shape, vec![4, 8]);
        assert!(matches!(it.mode, BinMode::Suffix { .. }));
    }

    #[test]
    fn plan_zero_element_output() {
        let a = Tensor::from_vec(Vec::<f32>::new(), &[2, 0]);
        let b = Tensor::ones(&[2, 1]);
        let it = TensorIter::binary(&a, &b);
        assert_eq!(it.out_shape, vec![2, 0]);
        assert_eq!(it.n, 0);
    }

    #[test]
    fn linear_suffix_detects_contig_and_broadcast() {
        let (t, sa, sb) = linear_suffix(&[2, 3], &[3, 1], &[0, 1]);
        assert_eq!((t, sa, sb), (2, 1, 1));
        let (t, sa, sb) = linear_suffix(&[2, 3], &[3, 1], &[1, 0]);
        assert_eq!(t, 1);
        assert_eq!((sa, sb), (1, 0));
    }
}
