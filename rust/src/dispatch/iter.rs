//! `TensorIter`-style shared iteration planning for elementwise kernels.
//!
//! Mirrors ATen's `TensorIterator`: the *host* resolves broadcasting and
//! picks an execution strategy once, and every dtype-monomorphized kernel
//! then drives the same plan. Three strategies, fastest first:
//!
//! 1. **Fast**: all operands dense and same-shape — one parallel flat loop.
//! 2. **Suffix**: a trailing run of dims over which each operand advances
//!    either contiguously (step 1) or not at all (step 0, broadcast); the
//!    inner loop is tight and vectorizable, the odometer only walks the
//!    leading dims. This is what keeps `x * gamma[1,C,1,1]`-style ops fast.
//! 3. **Strided**: fully generic odometer walk (rare).
//!
//! Every strategy is multi-threaded (§5.1 "basic parallel primitives"):
//! plans split into disjoint index ranges on [`crate::kernels::parallel_for`]
//! with a grain that keeps work serial below
//! [`crate::kernels::SERIAL_GRAIN`] elements. The shared reduction drivers
//! ([`run_reduce`], [`run_reduce_flat`]) live here too, so reductions,
//! softmax rows and losses parallelize with *deterministic* results: chunk
//! boundaries never depend on the thread count.

use crate::kernels::{parallel_for, SERIAL_GRAIN};
use crate::tensor::shape::{broadcast_shapes, broadcast_strides, numel, StridedIter};
use crate::tensor::storage::SendPtr;
use crate::tensor::{Element, Tensor};

/// Execution strategy for a planned elementwise traversal.
enum BinMode {
    Fast,
    Suffix {
        outer_shape: Vec<usize>,
        outer_sa: Vec<usize>,
        outer_sb: Vec<usize>,
        inner: usize,
        step_a: usize,
        step_b: usize,
    },
    Strided {
        sa: Vec<usize>,
        sb: Vec<usize>,
    },
}

/// A resolved two-operand broadcast traversal (the `TensorIter`).
pub(crate) struct TensorIter {
    pub out_shape: Vec<usize>,
    pub n: usize,
    mode: BinMode,
}

impl TensorIter {
    /// Plan the traversal for `a OP b` with NumPy broadcasting.
    pub(crate) fn binary(a: &Tensor, b: &Tensor) -> TensorIter {
        let out_shape = broadcast_shapes(a.shape(), b.shape());
        let n = numel(&out_shape);
        let fast = a.shape() == out_shape.as_slice()
            && b.shape() == out_shape.as_slice()
            && a.is_contiguous()
            && b.is_contiguous();
        if fast {
            return TensorIter { out_shape, n, mode: BinMode::Fast };
        }
        let sa = broadcast_strides(a.shape(), a.strides(), &out_shape);
        let sb = broadcast_strides(b.shape(), b.strides(), &out_shape);
        let (t, step_a, step_b) = linear_suffix(&out_shape, &sa, &sb);
        let rank = out_shape.len();
        let inner: usize = out_shape[rank - t..].iter().product();
        if t > 0 && inner > 1 {
            let mode = BinMode::Suffix {
                outer_shape: out_shape[..rank - t].to_vec(),
                outer_sa: sa[..rank - t].to_vec(),
                outer_sb: sb[..rank - t].to_vec(),
                inner,
                step_a,
                step_b,
            };
            TensorIter { out_shape, n, mode }
        } else {
            TensorIter { out_shape, n, mode: BinMode::Strided { sa, sb } }
        }
    }

    /// Drive the planned traversal with a scalar kernel `f`, reading `T`
    /// operands and writing `O` outputs. Runs on whatever thread executes
    /// the kernel (host or stream worker). Caller guarantees `ap`/`bp`
    /// point to `T` data valid for this plan's operand extents and `op`
    /// to an exclusive `O` buffer of `n` elements.
    pub(crate) fn run_binary<T: Element, O: Element>(
        &self,
        ap: SendPtr,
        bp: SendPtr,
        op: SendPtr,
        f: fn(T, T) -> O,
    ) {
        let n = self.n;
        if n == 0 {
            return;
        }
        match &self.mode {
            BinMode::Fast => {
                // Output-reuse (dispatch::call_owned) may hand the kernel a
                // stolen input buffer: the output then *aliases* one input.
                // That case must stay on raw pointers — a `&[T]`/`&mut [O]`
                // pair over the same memory is UB — and is index-aligned by
                // construction (Fast = same shape, contiguous, same dtype).
                let o = op.ptr() as usize;
                let aliased = o == ap.ptr() as usize || o == bp.ptr() as usize;
                if aliased {
                    // SAFETY: raw reads/writes only (no overlapping
                    // references); index-aligned in-place traversal, and
                    // chunks cover disjoint ranges [s, e).
                    parallel_for(n, SERIAL_GRAIN, |s, e| unsafe {
                        let (pa, pb) = (ap.ptr() as *const T, bp.ptr() as *const T);
                        let po = op.ptr() as *mut O;
                        for i in s..e {
                            let v = f(std::ptr::read(pa.add(i)), std::ptr::read(pb.add(i)));
                            std::ptr::write(po.add(i), v);
                        }
                    });
                } else {
                    // SAFETY: the dispatcher sized all three buffers to n
                    // elements and the aliased case was excluded above, so
                    // the shared input slices never overlap the output.
                    unsafe {
                        let av = ap.as_slice::<T>(0, n);
                        let bv = bp.as_slice::<T>(0, n);
                        parallel_for(n, SERIAL_GRAIN, |s, e| {
                            // SAFETY: disjoint ranges per chunk.
                            let ov = std::slice::from_raw_parts_mut(op.ptr() as *mut O, n);
                            for i in s..e {
                                ov[i] = f(av[i], bv[i]);
                            }
                        });
                    }
                }
            }
            BinMode::Suffix { outer_shape, outer_sa, outer_sb, inner, step_a, step_b } => {
                let inner = *inner;
                let (step_a, step_b) = (*step_a, *step_b);
                let outer: usize = outer_shape.iter().product();
                // Each outer step covers `inner` output elements; keep
                // ~SERIAL_GRAIN elements per task.
                let grain = (SERIAL_GRAIN / inner.max(1)).max(1);
                // SAFETY: Suffix plans never alias (broadcast shapes rule
                // out output stealing); chunks write disjoint outer slabs
                // [o0*inner, o1*inner), and StridedIter offsets stay
                // inside the validated input extents.
                parallel_for(outer, grain, |o0, o1| unsafe {
                    let ov = op.as_mut_slice::<O>(o0 * inner, (o1 - o0) * inner);
                    let ia = StridedIter::starting_at(outer_shape, outer_sa, o0, o1 - o0);
                    let ib = StridedIter::starting_at(outer_shape, outer_sb, o0, o1 - o0);
                    let (pa0, pb0) = (ap.ptr() as *const T, bp.ptr() as *const T);
                    for (chunk, (offa, offb)) in ov.chunks_mut(inner).zip(ia.zip(ib)) {
                        let pa = pa0.add(offa);
                        let pb = pb0.add(offb);
                        match (step_a, step_b) {
                            (1, 0) => {
                                let s = *pb;
                                let av = std::slice::from_raw_parts(pa, inner);
                                for (o, &x) in chunk.iter_mut().zip(av) {
                                    *o = f(x, s);
                                }
                            }
                            (0, 1) => {
                                let s = *pa;
                                let bv = std::slice::from_raw_parts(pb, inner);
                                for (o, &y) in chunk.iter_mut().zip(bv) {
                                    *o = f(s, y);
                                }
                            }
                            (1, 1) => {
                                let av = std::slice::from_raw_parts(pa, inner);
                                let bv = std::slice::from_raw_parts(pb, inner);
                                for ((o, &x), &y) in chunk.iter_mut().zip(av).zip(bv) {
                                    *o = f(x, y);
                                }
                            }
                            _ => {
                                let s = f(*pa, *pb);
                                chunk.fill(s);
                            }
                        }
                    }
                });
            }
            BinMode::Strided { sa, sb } => {
                // SAFETY: Strided plans never alias (non-contiguous
                // inputs rule out output stealing); chunks write disjoint
                // ranges [s, e), and StridedIter offsets stay inside the
                // validated input extents.
                parallel_for(n, SERIAL_GRAIN, |s, e| unsafe {
                    let ov = op.as_mut_slice::<O>(s, e - s);
                    let ia = StridedIter::starting_at(&self.out_shape, sa, s, e - s);
                    let ib = StridedIter::starting_at(&self.out_shape, sb, s, e - s);
                    let (pa0, pb0) = (ap.ptr() as *const T, bp.ptr() as *const T);
                    for ((o, offa), offb) in ov.iter_mut().zip(ia).zip(ib) {
                        *o = f(*pa0.add(offa), *pb0.add(offb));
                    }
                });
            }
        }
    }
}

/// Flat parallel map for dense unary traversals (input made contiguous by
/// the caller). Caller guarantees `ap` points to `n` valid `T`s and `op`
/// to an exclusive `O` buffer of `n` elements — or to the *same* buffer as
/// `ap` (output-reuse), which takes a raw-pointer in-place path. Generic
/// over the kernel closure so the scalar-parameter maps share this driver.
pub(crate) fn run_unary<T, O, F>(n: usize, ap: SendPtr, op: SendPtr, f: F)
where
    T: Element,
    O: Element,
    F: Fn(T) -> O + Send + Sync,
{
    if n == 0 {
        return;
    }
    if ap.ptr() as usize == op.ptr() as usize {
        // In-place (stolen output storage, same dtype): raw pointers only.
        // SAFETY: no references over the aliased buffer, each index read
        // before written, chunks cover disjoint ranges [s, e).
        parallel_for(n, SERIAL_GRAIN, |s, e| unsafe {
            let pa = ap.ptr() as *const T;
            let po = op.ptr() as *mut O;
            for i in s..e {
                let v = f(std::ptr::read(pa.add(i)));
                std::ptr::write(po.add(i), v);
            }
        });
        return;
    }
    // SAFETY: per this function's contract the input holds n valid Ts and
    // the output is an exclusive n-element buffer; the in-place case
    // returned above, so input and output never overlap.
    unsafe {
        let av = ap.as_slice::<T>(0, n);
        parallel_for(n, SERIAL_GRAIN, |s, e| {
            // SAFETY: disjoint ranges per chunk.
            let ov = std::slice::from_raw_parts_mut(op.ptr() as *mut O, n);
            for i in s..e {
                ov[i] = f(av[i]);
            }
        });
    }
}

// ---------------------------------------------------------------------
// Reduction drivers
// ---------------------------------------------------------------------

/// Fixed chunk width for flat reductions. A *constant* — never derived
/// from the thread count — so partial-sum boundaries, and therefore
/// floating-point rounding, are bit-for-bit identical at every
/// `PALLAS_NUM_THREADS` setting.
pub(crate) const REDUCE_CHUNK: usize = 64 * 1024;

/// Row-wise reduction driver: `out[o] = finish(fold(init, row o))` where
/// row `o` is the contiguous run `a[o*inner .. (o+1)*inner]`.
///
/// Parallel over `outer` rows with a grain keeping ~[`SERIAL_GRAIN`]
/// elements per task. Deterministic at any thread count: each output
/// element is folded serially, in index order, by exactly one task.
pub(crate) fn run_reduce<T, A, F, G>(
    outer: usize,
    inner: usize,
    ap: SendPtr,
    op: SendPtr,
    init: A,
    fold: F,
    finish: G,
) where
    T: Element,
    A: Copy + Send + Sync,
    F: Fn(A, T) -> A + Copy + Send + Sync,
    G: Fn(A) -> T + Copy + Send + Sync,
{
    if outer == 0 || inner == 0 {
        return;
    }
    let grain = (SERIAL_GRAIN / inner.max(1)).max(1);
    // SAFETY: input holds outer*inner elements, output holds outer;
    // reductions never steal their input, and chunks write disjoint
    // output ranges [o0, o1).
    parallel_for(outer, grain, |o0, o1| unsafe {
        let ov = op.as_mut_slice::<T>(o0, o1 - o0);
        for (k, o) in ov.iter_mut().enumerate() {
            let row = ap.as_slice::<T>((o0 + k) * inner, inner);
            let mut acc = init;
            for &v in row {
                acc = fold(acc, v);
            }
            *o = finish(acc);
        }
    });
}

/// Deterministic full reduction over `n` contiguous elements: per-chunk
/// partials ([`REDUCE_CHUNK`] wide, fixed order) computed in parallel,
/// then combined serially in chunk order — the same partial boundaries at
/// 1, 2 or 8 threads.
pub(crate) fn run_reduce_flat<T, A, F, C>(n: usize, ap: SendPtr, init: A, fold: F, combine: C) -> A
where
    T: Element,
    A: Copy + Send + Sync,
    F: Fn(A, T) -> A + Copy + Send + Sync,
    C: Fn(A, A) -> A,
{
    if n == 0 {
        return init;
    }
    let nchunks = n.div_ceil(REDUCE_CHUNK);
    if nchunks == 1 {
        // SAFETY: read-only view of the n elements the caller validated.
        let av = unsafe { ap.as_slice::<T>(0, n) };
        let mut acc = init;
        for &v in av {
            acc = fold(acc, v);
        }
        return acc;
    }
    let mut partials: Vec<A> = vec![init; nchunks];
    let pp = SendPtr::new(partials.as_mut_ptr() as *mut u8);
    // SAFETY: `partials` outlives the blocking parallel_for; each chunk c
    // reads its own input window and writes only partials[c].
    parallel_for(nchunks, 1, |c0, c1| unsafe {
        for c in c0..c1 {
            let s = c * REDUCE_CHUNK;
            let e = ((c + 1) * REDUCE_CHUNK).min(n);
            let av = ap.as_slice::<T>(s, e - s);
            let mut acc = init;
            for &v in av {
                acc = fold(acc, v);
            }
            // SAFETY: each chunk index written by exactly one task.
            std::ptr::write((pp.ptr() as *mut A).add(c), acc);
        }
    });
    let mut acc = partials[0];
    for p in &partials[1..] {
        acc = combine(acc, *p);
    }
    acc
}

/// Longest trailing dim-suffix over which both stride vectors advance
/// linearly (contiguously for the suffix's own shape, or with stride 0).
/// Returns (suffix_len_in_dims, step_a, step_b) with steps in {0, 1}.
pub(crate) fn linear_suffix(shape: &[usize], sa: &[usize], sb: &[usize]) -> (usize, usize, usize) {
    let rank = shape.len();
    let classify = |strides: &[usize], t: usize| -> Option<usize> {
        // Suffix of length t: all-zero (step 0) or block-contiguous (step 1).
        let suffix_shape = &shape[rank - t..];
        let suffix = &strides[rank - t..];
        if suffix.iter().zip(suffix_shape).all(|(&s, &d)| s == 0 || d == 1) {
            return Some(0);
        }
        let mut acc = 1usize;
        for d in (0..t).rev() {
            if suffix_shape[d] != 1 && suffix[d] != acc {
                return None;
            }
            acc *= suffix_shape[d].max(1);
        }
        Some(1)
    };
    let mut best = (0usize, 0usize, 0usize);
    for t in 1..=rank {
        match (classify(sa, t), classify(sb, t)) {
            (Some(x), Some(y)) => best = (t, x, y),
            _ => break,
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_fast_for_dense_same_shape() {
        let a = Tensor::ones(&[2, 3]);
        let b = Tensor::ones(&[2, 3]);
        let it = TensorIter::binary(&a, &b);
        assert_eq!(it.out_shape, vec![2, 3]);
        assert!(matches!(it.mode, BinMode::Fast));
    }

    #[test]
    fn plan_suffix_for_row_broadcast() {
        let a = Tensor::ones(&[4, 8]);
        let b = Tensor::ones(&[8]);
        let it = TensorIter::binary(&a, &b);
        assert_eq!(it.out_shape, vec![4, 8]);
        assert!(matches!(it.mode, BinMode::Suffix { .. }));
    }

    #[test]
    fn plan_zero_element_output() {
        let a = Tensor::from_vec(Vec::<f32>::new(), &[2, 0]);
        let b = Tensor::ones(&[2, 1]);
        let it = TensorIter::binary(&a, &b);
        assert_eq!(it.out_shape, vec![2, 0]);
        assert_eq!(it.n, 0);
    }

    #[test]
    fn run_reduce_rows_matches_serial_fold() {
        let (outer, inner) = (100usize, 1000usize);
        let data: Vec<f32> = (0..outer * inner).map(|i| ((i * 31) % 17) as f32 - 8.0).collect();
        let t = Tensor::from_vec(data.clone(), &[outer, inner]);
        let out = Tensor::zeros(&[outer]);
        run_reduce::<f32, f32, _, _>(
            outer,
            inner,
            t.data_ptr(),
            out.data_ptr(),
            0.0,
            |a, v| a + v,
            |a| a,
        );
        let got = out.to_vec::<f32>();
        for o in 0..outer {
            let expect = data[o * inner..(o + 1) * inner].iter().fold(0.0f32, |a, &v| a + v);
            assert_eq!(got[o], expect, "row {o}");
        }
    }

    #[test]
    fn run_reduce_flat_matches_fixed_chunk_order() {
        let n = 3 * REDUCE_CHUNK + 123;
        let data: Vec<f32> = (0..n).map(|i| ((i * 37) % 11) as f32 * 0.5 - 2.0).collect();
        let t = Tensor::from_vec(data.clone(), &[n]);
        let total =
            run_reduce_flat::<f32, f32, _, _>(n, t.data_ptr(), 0.0, |a, v| a + v, |a, b| a + b);
        let partials: Vec<f32> = data
            .chunks(REDUCE_CHUNK)
            .map(|c| c.iter().fold(0.0f32, |a, &v| a + v))
            .collect();
        let expect = partials[1..].iter().fold(partials[0], |a, &p| a + p);
        assert_eq!(total, expect, "must combine fixed-width partials in order");
    }

    #[test]
    fn parallel_paths_match_reference_at_scale() {
        // Fast: flat dense add above the serial grain.
        let n = 200_000;
        let a: Vec<f32> = (0..n).map(|i| (i % 13) as f32 - 6.0).collect();
        let b: Vec<f32> = (0..n).map(|i| (i % 7) as f32 + 1.0).collect();
        let fast = crate::ops::add(&Tensor::from_vec(a.clone(), &[n]), &Tensor::from_vec(b.clone(), &[n]))
            .to_vec::<f32>();
        for i in (0..n).step_by(997) {
            assert_eq!(fast[i], a[i] + b[i]);
        }

        // Suffix: [391, 512] + [512] row broadcast.
        let (r, c) = (391usize, 512usize);
        let m: Vec<f32> = (0..r * c).map(|i| i as f32 * 0.25).collect();
        let v: Vec<f32> = (0..c).map(|i| i as f32).collect();
        let tv = Tensor::from_vec(v.clone(), &[c]);
        let out = crate::ops::add(&Tensor::from_vec(m.clone(), &[r, c]), &tv).to_vec::<f32>();
        for i in (0..r * c).step_by(613) {
            assert_eq!(out[i], m[i] + v[i % c]);
        }

        // Strided: a transposed lhs forces the generic odometer at scale.
        let tt = Tensor::from_vec(m.clone(), &[c, r]).t(); // [r, c] view
        let got = crate::ops::add(&tt, &tv).to_vec::<f32>();
        for i in (0..r * c).step_by(613) {
            let (row, col) = (i / c, i % c);
            assert_eq!(got[i], m[col * r + row] + v[col]);
        }
    }

    #[test]
    fn linear_suffix_detects_contig_and_broadcast() {
        let (t, sa, sb) = linear_suffix(&[2, 3], &[3, 1], &[0, 1]);
        assert_eq!((t, sa, sb), (2, 1, 1));
        let (t, sa, sb) = linear_suffix(&[2, 3], &[3, 1], &[1, 0]);
        assert_eq!(t, 1);
        assert_eq!((sa, sb), (1, 0));
    }
}
