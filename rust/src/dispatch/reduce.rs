//! Reduction kernels for the dispatcher: full/per-axis sums, means, max,
//! argmax, plus the broadcast-gradient helpers (`sum_to_shape`,
//! `broadcast_to`). Generic over f32/f64 (sums also handle i64).

use crate::autograd::{ClosureFunction, Function};
use crate::device;
use crate::tensor::shape::{contiguous_strides, numel, StridedIter};
use crate::tensor::{DType, Element, Tensor};
use crate::{torsk_assert, torsk_bail};

use super::elementwise::FLOATS;
use super::iter::{self, linear_suffix};
use super::{OpCtx, OpDef, Registry};

// ---------------------------------------------------------------------
// Raw building blocks (no autograd)
// ---------------------------------------------------------------------

/// Sum a tensor down to a broadcast-compatible `target` shape (each target
/// dim is either equal to the source dim or 1; the target may have fewer
/// dims, which behave as leading 1s).
pub(crate) fn sum_to_shape(a: &Tensor, target: &[usize]) -> Tensor {
    match a.dtype() {
        DType::F32 => sum_to_shape_t::<f32>(a, target),
        DType::F64 => sum_to_shape_t::<f64>(a, target),
        DType::I64 => sum_to_shape_t::<i64>(a, target),
    }
}

fn sum_to_shape_t<T>(a: &Tensor, target: &[usize]) -> Tensor
where
    T: Element + std::ops::AddAssign + std::ops::Add<Output = T>,
{
    let a = a.contiguous();
    let src_shape = a.shape().to_vec();
    torsk_assert!(
        target.len() <= src_shape.len(),
        "sum_to_shape: target rank {} exceeds source rank {}",
        target.len(),
        src_shape.len()
    );
    // Pad target with leading 1s to the source rank.
    let mut padded = vec![1usize; src_shape.len()];
    let off = src_shape.len() - target.len();
    padded[off..].copy_from_slice(target);
    for (i, (&s, &t)) in src_shape.iter().zip(padded.iter()).enumerate() {
        torsk_assert!(t == s || t == 1, "sum_to_shape: dim {i}: {s} -> {t}");
    }

    let out = Tensor::zeros_on(target, T::DTYPE, a.device());
    let n = a.numel();
    if n == 0 {
        return out;
    }
    // Output strides aligned to the padded shape, 0 where target dim == 1.
    let tstrides_dense = contiguous_strides(&padded);
    let ostrides: Vec<usize> = padded
        .iter()
        .zip(tstrides_dense.iter())
        .map(|(&d, &st)| if d == 1 { 0 } else { st })
        .collect();

    let (ap, op) = (a.data_ptr(), out.data_ptr());
    let on = numel(target);

    // Full reduction to a single element: deterministic fixed-chunk
    // partials combined in order (see iter::run_reduce_flat) — parallel
    // at any size, bit-identical at any thread count.
    if on == 1 {
        device::dispatch(a.device(), "sum_to", move || {
            let total = iter::run_reduce_flat::<T, T, _, _>(
                n,
                ap,
                T::default(),
                |acc, v| acc + v,
                |x, y| x + y,
            );
            // SAFETY: `out` is a freshly allocated one-element tensor
            // whose storage stays alive per the stream FIFO discipline.
            unsafe {
                op.as_mut_slice::<T>(0, 1)[0] = total;
            }
        });
        return out;
    }

    // §Perf: like the elementwise TensorIter, handle a trailing linear run
    // specially — if the output does not advance over the suffix (reduced
    // dims), the inner loop is a vectorizable sum; if it advances
    // contiguously, it is a vectorizable elementwise accumulate. Both run
    // parallel with thread-count-invariant accumulation order.
    let rank = src_shape.len();
    let src_contig = contiguous_strides(&src_shape);
    let (t, _sa, step_o) = linear_suffix(&src_shape, &src_contig, &ostrides);
    let inner: usize = src_shape[rank - t..].iter().product();
    if t > 0 && inner > 1 {
        let r = rank - t;
        let outer: usize = src_shape[..r].iter().product();

        // Row reduction (softmax/layer-norm statistics, sum over trailing
        // dims): every outer dim is kept, so out[o] is owned by exactly
        // one task and folded serially in index order.
        if step_o == 0 && padded[..r] == src_shape[..r] {
            device::dispatch(a.device(), "sum_to", move || {
                iter::run_reduce::<T, T, _, _>(
                    outer,
                    inner,
                    ap,
                    op,
                    T::default(),
                    |acc, v| acc + v,
                    |acc| acc,
                );
            });
            return out;
        }

        // Column reduction (sum over leading dims): the output advances
        // contiguously over the suffix while outer steps fold into it.
        // Parallelize over *columns*: each task owns suffix range
        // [i0, i1) and walks every outer step serially in odometer order,
        // so each output element's accumulation order never depends on
        // the thread count.
        if step_o == 1 {
            let outer_shape = src_shape[..r].to_vec();
            let outer_so = ostrides[..r].to_vec();
            let grain_cols = (crate::kernels::SERIAL_GRAIN / outer.max(1)).max(1);
            device::dispatch(a.device(), "sum_to", move || {
                // SAFETY: tasks own disjoint suffix (column) ranges
                // [i0, i1) of the output; input reads are bounded by n and
                // the odometer offsets stay inside the output extent.
                crate::kernels::parallel_for(inner, grain_cols, |i0, i1| unsafe {
                    let av = ap.as_slice::<T>(0, n);
                    let io = StridedIter::new(&outer_shape, &outer_so);
                    for (step, ooff) in io.enumerate() {
                        let dst = op.as_mut_slice::<T>(ooff + i0, i1 - i0);
                        let src = &av[step * inner + i0..step * inner + i1];
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += v;
                        }
                    }
                });
            });
            return out;
        }

        // Mixed case (suffix reduced but some outer dim reduced too):
        // rare; serial suffix walk.
        let outer_shape = src_shape[..r].to_vec();
        let outer_so = ostrides[..r].to_vec();
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        device::dispatch(a.device(), "sum_to", move || unsafe {
            let av = ap.as_slice::<T>(0, n);
            let ov = op.as_mut_slice::<T>(0, on);
            let io = StridedIter::new(&outer_shape, &outer_so);
            for (chunk, ooff) in av.chunks(inner).zip(io) {
                let mut acc = T::default();
                for &v in chunk {
                    acc += v;
                }
                ov[ooff] += acc;
            }
        });
        return out;
    }
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(a.device(), "sum_to", move || unsafe {
        let av = ap.as_slice::<T>(0, n);
        let ov = op.as_mut_slice::<T>(0, on);
        let mut idx = vec![0usize; src_shape.len()];
        let mut ooff = 0usize;
        for &v in av.iter() {
            ov[ooff] += v;
            for d in (0..src_shape.len()).rev() {
                idx[d] += 1;
                ooff += ostrides[d];
                if idx[d] < src_shape[d] {
                    break;
                }
                ooff -= idx[d] * ostrides[d];
                idx[d] = 0;
            }
        }
    });
    out
}

/// Broadcast a tensor up to `target` shape (materialized copy, used by
/// reduction backwards).
pub(crate) fn broadcast_to(a: &Tensor, target: &[usize]) -> Tensor {
    if a.shape() == target {
        return a.clone();
    }
    a.expand(target).contiguous()
}

// ---------------------------------------------------------------------
// Registered ops
// ---------------------------------------------------------------------

fn k_sum(ctx: &OpCtx) -> Tensor {
    sum_to_shape(ctx.input(0), &[])
}

fn bw_sum(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let shape = ctx.input(0).shape().to_vec();
    ClosureFunction::new("sum", move |g| vec![Some(broadcast_to(g, &shape))])
}

/// Reduced ("keepdim") shape for a dim-list reduction.
fn kept_shape(a: &Tensor, dims: &[usize]) -> Vec<usize> {
    let mut kept = a.shape().to_vec();
    for &d in dims {
        torsk_assert!(d < a.ndim(), "sum_dims: dim {d} out of range for {:?}", a.shape());
        kept[d] = 1;
    }
    kept
}

fn k_sum_dims(ctx: &OpCtx) -> Tensor {
    let a = ctx.input(0);
    let dims = ctx.usize_list(0);
    let keepdim = ctx.bool(1);
    // dims = [] is a well-defined no-op reduction: kept == a.shape(), so
    // sum_to_shape degenerates to a fresh identity copy (never an alias).
    let kept = kept_shape(a, dims);
    let reduced = sum_to_shape(a, &kept); // keepdim layout
    if keepdim {
        reduced
    } else {
        let final_shape: Vec<usize> = a
            .shape()
            .iter()
            .enumerate()
            .filter(|(i, _)| !dims.contains(i))
            .map(|(_, &d)| d)
            .collect();
        reduced.reshape(&final_shape)
    }
}

fn bw_sum_dims(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let a = ctx.input(0);
    let dims = ctx.usize_list(0);
    let shape = a.shape().to_vec();
    let kept = if dims.is_empty() { shape.clone() } else { kept_shape(a, dims) };
    ClosureFunction::new("sum_dims", move |g| {
        let g = g.reshape(&kept);
        vec![Some(broadcast_to(&g, &shape))]
    })
}

/// Dispatch a full-precision scalar multiply (the `1/n` of a mean): the
/// factor travels as `Param::F64` so F64 tensors never see an f32 round.
/// Takes the tensor by value — the intermediate sum is dead after the
/// scale, so the dispatcher computes the mean in the sum's own buffer.
fn scale_full_precision(t: Tensor, s: f64) -> Tensor {
    super::call_owned("mul_scalar", vec![t], &[super::Param::F64(s)])
}

/// Composite: mean = sum * (1/n). The inner dispatched ops build the
/// gradient graph, so no backward entry is registered.
fn k_mean(ctx: &OpCtx) -> Tensor {
    let a = ctx.input(0);
    let n = a.numel().max(1) as f64;
    scale_full_precision(crate::ops::sum(a), 1.0 / n)
}

/// Composite: mean over dims. A 0-sized reduced dim yields zeros (the sum)
/// rather than a divide-by-zero.
fn k_mean_dims(ctx: &OpCtx) -> Tensor {
    let a = ctx.input(0);
    let dims = ctx.usize_list(0);
    let keepdim = ctx.bool(1);
    let count: usize = dims.iter().map(|&d| a.size(d)).product();
    let s = crate::ops::sum_dims(a, dims, keepdim);
    scale_full_precision(s, 1.0 / count.max(1) as f64)
}

fn max_all_t<T: Element>(ctx: &OpCtx, a: &Tensor) -> Tensor {
    let v = a.contiguous().to_vec::<T>();
    let (mut best_i, mut best) = (0usize, v[0]);
    for (i, &x) in v.iter().enumerate() {
        if x > best {
            best = x;
            best_i = i;
        }
    }
    // Stash the winning flat index for the backward builder.
    ctx.save(Tensor::from_vec(vec![best_i as i64], &[1]));
    Tensor::from_vec(vec![best], &[]).to_device(a.device())
}

fn k_max_all(ctx: &OpCtx) -> Tensor {
    let a = ctx.input(0);
    torsk_assert!(a.numel() > 0, "max_all: cannot reduce an empty tensor");
    match a.dtype() {
        DType::F32 => max_all_t::<f32>(ctx, a),
        DType::F64 => max_all_t::<f64>(ctx, a),
        other => torsk_bail!("max_all: unsupported dtype {other}"),
    }
}

fn bw_max_all(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let shape = ctx.input(0).shape().to_vec();
    let dt = ctx.input(0).dtype();
    let dev = ctx.input(0).device();
    let best = ctx.saved(0);
    ClosureFunction::new("max_all", move |g| {
        let i = best.to_vec::<i64>()[0] as usize;
        let grad = match dt {
            DType::F32 => {
                let mut data = vec![0.0f32; numel(&shape)];
                data[i] = g.to_vec::<f32>()[0];
                Tensor::from_vec(data, &shape)
            }
            DType::F64 => {
                let mut data = vec![0.0f64; numel(&shape)];
                data[i] = g.to_vec::<f64>()[0];
                Tensor::from_vec(data, &shape)
            }
            _ => torsk_bail!("max_all backward: unsupported dtype {dt}"),
        };
        vec![Some(grad.to_device(dev))]
    })
}

fn argmax_t<T: Element>(a: &Tensor, dim: usize) -> Tensor {
    let v = a.contiguous().to_vec::<T>();
    let shape = a.shape();
    let inner: usize = shape[dim + 1..].iter().product();
    let outer: usize = shape[..dim].iter().product();
    let d = shape[dim];
    let mut out_shape: Vec<usize> = shape.to_vec();
    out_shape.remove(dim);
    let mut out = vec![0i64; outer * inner];
    for o in 0..outer {
        for i in 0..inner {
            let mut best = v[o * d * inner + i];
            let mut best_j = 0i64;
            for j in 1..d {
                let x = v[(o * d + j) * inner + i];
                if x > best {
                    best = x;
                    best_j = j as i64;
                }
            }
            out[o * inner + i] = best_j;
        }
    }
    Tensor::from_vec(out, &out_shape).to_device(a.device())
}

fn k_argmax(ctx: &OpCtx) -> Tensor {
    let a = ctx.input(0);
    let dim = ctx.usize(0);
    torsk_assert!(dim < a.ndim(), "argmax: dim out of range");
    torsk_assert!(a.size(dim) > 0, "argmax: cannot reduce over an empty dim {dim}");
    match a.dtype() {
        DType::F32 => argmax_t::<f32>(a, dim),
        DType::F64 => argmax_t::<f64>(a, dim),
        DType::I64 => argmax_t::<i64>(a, dim),
    }
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

use super::{sample_distinct, sample_uniform, OpSample, Param};

fn s_full_reduce(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![a], params: vec![], grad_inputs: vec![0] })
}

fn dims_params(seed: u64) -> Vec<Param> {
    // Alternate reduced axis and keepdim across seeds.
    let dims = vec![(seed % 2) as usize];
    vec![Param::UsizeList(dims), Param::Bool(seed % 3 == 0)]
}

fn s_dims_reduce(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![a], params: dims_params(seed), grad_inputs: vec![0] })
}

fn s_max_all(seed: u64, dt: DType) -> Option<OpSample> {
    // Distinct values: a tied max would put the finite difference on the
    // winner-switch discontinuity.
    let a = sample_distinct(seed, &[3, 3], dt)?;
    Some(OpSample { inputs: vec![a], params: vec![], grad_inputs: vec![0] })
}

fn s_argmax(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_distinct(seed, &[3, 4], dt)?;
    Some(OpSample { inputs: vec![a], params: vec![Param::Usize(1)], grad_inputs: vec![] })
}

pub(crate) fn register(reg: &mut Registry) {
    use super::elementwise::NUMERIC;
    reg.add(
        OpDef::new("sum", 1, 1, NUMERIC)
            .kernel_all(k_sum)
            .backward(bw_sum)
            .sample_inputs(s_full_reduce),
    );
    reg.add(
        OpDef::new("sum_dims", 1, 1, NUMERIC)
            .kernel_all(k_sum_dims)
            .backward(bw_sum_dims)
            .sample_inputs(s_dims_reduce),
    );
    reg.add(OpDef::new("mean", 1, 1, FLOATS).kernel_all(k_mean).sample_inputs(s_full_reduce));
    reg.add(
        OpDef::new("mean_dims", 1, 1, FLOATS).kernel_all(k_mean_dims).sample_inputs(s_dims_reduce),
    );
    reg.add(
        OpDef::new("max_all", 1, 1, FLOATS)
            .kernel_all(k_max_all)
            .backward(bw_max_all)
            .sample_inputs(s_max_all),
    );
    reg.add(OpDef::new("argmax_dim", 1, 1, &[]).kernel_all(k_argmax).sample_inputs(s_argmax));
}
