//! SIMD block evaluation for the fused micro-op tapes.
//!
//! [`map_range`]/[`sum_range`] are the vector twins of the scalar loops
//! in `run_map_t`/`run_map_sum_t`: they interpret the *same* tape, but
//! over a block of `LANES` consecutive output elements at a time, with a
//! vector stack replacing the scalar stack. The drivers fall back to the
//! scalar interpreter (returning `false`/`None`) whenever
//! [`crate::kernels::simd::level`] reports no vector unit — including
//! under `PALLAS_SIMD=0` and `set_force_scalar`.
//!
//! # Why the bits cannot change
//!
//! Lanes are *independent output elements*. Every micro-op maps to a
//! per-lane-exact vector operation (add/sub/mul/div/sqrt are IEEE
//! correctly rounded per lane; Neg is the sign-bit flip; Ge/Le are
//! ordered-quiet compares masked to 1.0, which a NaN fails exactly like
//! the scalar branch), and the ops whose vector semantics differ from
//! Rust's scalar semantics — `exp`/`ln`/`tanh` (libm) and `max`/`min`
//! (NaN/±0 rules) — are evaluated lane-by-lane with the *same scalar
//! function* the scalar interpreter calls. So lane `l` of a block at `i`
//! performs exactly the instruction sequence `Tape::eval` performs for
//! element `i + l`: same operations, same operand pairs, same rounding.
//! The sum driver folds a block's lanes back into the accumulator in
//! ascending index order, which is precisely the scalar chunk's
//! `acc = acc + v[i]` chain — so [`super::REDUCE_CHUNK`] partials are
//! bitwise unchanged too.

use crate::tensor::storage::SendPtr;
use crate::tensor::FloatElement;

use super::{Access, Tape};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use super::{src_index, BinaryK, MicroOp, UnaryK, MAX_ARGS, MAX_STACK};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::kernels::simd::{self, SimdLevel};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::tensor::DType;

/// Run the `run_map_t` inner loop for `[s, e)` with vector blocks.
/// Returns `false` — having touched nothing — when no vector path is
/// active for this dtype/arch, and the caller's scalar loop runs.
///
/// # Safety: same contract as `run_map_t`'s `parallel_for` body — every
/// source sized for its `Access` pattern against the pass length, `out`
/// valid for `[s, e)`, disjoint across chunks; `out` may alias a `Flat`
/// source (output stealing) because reads and writes stay index-aligned.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(super) unsafe fn map_range<T: FloatElement>(
    tape: &Tape,
    srcs: &[(SendPtr, Access)],
    out: SendPtr,
    s: usize,
    e: usize,
) -> bool {
    match (T::DTYPE, simd::level()) {
        #[cfg(target_arch = "x86_64")]
        (DType::F32, SimdLevel::Avx2) => {
            // SAFETY: AVX2 per the cached probe; buffer contract forwarded.
            unsafe { drivers::map_f32(tape, srcs, out, s, e) };
            true
        }
        #[cfg(target_arch = "x86_64")]
        (DType::F64, SimdLevel::Avx2) => {
            // SAFETY: AVX2 per the cached probe; buffer contract forwarded.
            unsafe { drivers::map_f64(tape, srcs, out, s, e) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        (DType::F32, SimdLevel::Neon) => {
            // SAFETY: NEON is baseline on aarch64; contract forwarded.
            unsafe { drivers::map_f32(tape, srcs, out, s, e) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        (DType::F64, SimdLevel::Neon) => {
            // SAFETY: NEON is baseline on aarch64; contract forwarded.
            unsafe { drivers::map_f64(tape, srcs, out, s, e) };
            true
        }
        _ => false,
    }
}

/// Architectures with no vector path: always decline.
///
/// # Safety: never dereferences anything (trivially satisfies the
/// `run_map_t` chunk contract it inherits).
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(super) unsafe fn map_range<T: FloatElement>(
    _tape: &Tape,
    _srcs: &[(SendPtr, Access)],
    _out: SendPtr,
    _s: usize,
    _e: usize,
) -> bool {
    false
}

/// Sum one `REDUCE_CHUNK`-bounded range `[s, e)` of tape values, from
/// zero, in ascending index order — the exact scalar chunk chain.
/// `None` when no vector path is active (caller runs the scalar loop).
///
/// # Safety: same read-only contract as `run_map_sum_t`'s gathers —
/// every source sized for its `Access` pattern against the pass length.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(super) unsafe fn sum_range<T: FloatElement>(
    tape: &Tape,
    srcs: &[(SendPtr, Access)],
    s: usize,
    e: usize,
) -> Option<T> {
    match (T::DTYPE, simd::level()) {
        #[cfg(target_arch = "x86_64")]
        (DType::F32, SimdLevel::Avx2) => {
            // SAFETY: AVX2 per the cached probe; read contract forwarded.
            // f32 -> f64 -> T (= f32 in this arm at runtime) round-trips
            // exactly.
            Some(T::from_f64(unsafe { drivers::sum_f32(tape, srcs, s, e) } as f64))
        }
        #[cfg(target_arch = "x86_64")]
        (DType::F64, SimdLevel::Avx2) => {
            // SAFETY: AVX2 per the cached probe; read contract forwarded.
            Some(T::from_f64(unsafe { drivers::sum_f64(tape, srcs, s, e) }))
        }
        #[cfg(target_arch = "aarch64")]
        (DType::F32, SimdLevel::Neon) => {
            // SAFETY: NEON is baseline on aarch64; contract forwarded.
            Some(T::from_f64(unsafe { drivers::sum_f32(tape, srcs, s, e) } as f64))
        }
        #[cfg(target_arch = "aarch64")]
        (DType::F64, SimdLevel::Neon) => {
            // SAFETY: NEON is baseline on aarch64; contract forwarded.
            Some(T::from_f64(unsafe { drivers::sum_f64(tape, srcs, s, e) }))
        }
        _ => None,
    }
}

/// Architectures with no vector path: always decline.
///
/// # Safety: never dereferences anything.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(super) unsafe fn sum_range<T: FloatElement>(
    _tape: &Tape,
    _srcs: &[(SendPtr, Access)],
    _s: usize,
    _e: usize,
) -> Option<T> {
    None
}

// ---------------------------------------------------------------------
// Generic vector interpreter (monomorphized per arch × dtype below)
// ---------------------------------------------------------------------

/// Widest lane count any [`Lanes`] impl uses (AVX2 f32); sizes the
/// fixed spill buffers.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const MAX_LANES: usize = 8;

/// A vector of `N` consecutive elements. Implementations promise that
/// `add`/`sub`/`mul`/`div`/`sqrt`/`neg`/`ge_mask`/`le_mask` are
/// per-lane bitwise identical to the corresponding scalar `FloatElement`
/// operation (the module-level contract).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
trait Lanes: Copy {
    type Elem: FloatElement;
    const N: usize;

    fn splat(x: Self::Elem) -> Self;
    /// # Safety: `p` must be valid for reads of `N` elements.
    unsafe fn load(p: *const Self::Elem) -> Self;
    /// # Safety: `p` must be valid for writes of `N` elements.
    unsafe fn store(self, p: *mut Self::Elem);
    fn add(self, o: Self) -> Self;
    fn sub(self, o: Self) -> Self;
    fn mul(self, o: Self) -> Self;
    fn div(self, o: Self) -> Self;
    fn sqrt(self) -> Self;
    fn neg(self) -> Self;
    /// `1.0` where `self >= o`, else `0.0` (NaN compares false).
    fn ge_mask(self, o: Self) -> Self;
    /// `1.0` where `self <= o`, else `0.0` (NaN compares false).
    fn le_mask(self, o: Self) -> Self;

    /// Spill the lanes into the head of a fixed buffer.
    fn write(self, dst: &mut [Self::Elem; MAX_LANES]) {
        // SAFETY: `MAX_LANES >= N` for every impl, so the store stays
        // inside `dst`.
        unsafe { self.store(dst.as_mut_ptr()) }
    }

    /// Reload lanes from the head of a fixed buffer.
    fn read(src: &[Self::Elem; MAX_LANES]) -> Self {
        // SAFETY: `MAX_LANES >= N` for every impl.
        unsafe { Self::load(src.as_ptr()) }
    }
}

/// Apply a scalar function to every lane — the escape hatch for ops with
/// no bitwise-safe vector form (libm transcendentals, `fmax`/`fmin`).
/// Per lane it is literally the scalar interpreter's call.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn map_lanes<V: Lanes>(x: V, f: impl Fn(V::Elem) -> V::Elem) -> V {
    let mut buf = [V::Elem::ZERO; MAX_LANES];
    x.write(&mut buf);
    for v in buf[..V::N].iter_mut() {
        *v = f(*v);
    }
    V::read(&buf)
}

/// Two-operand lane-by-lane escape hatch (`fmax`/`fmin`).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn map2_lanes<V: Lanes>(x: V, y: V, f: impl Fn(V::Elem, V::Elem) -> V::Elem) -> V {
    let mut bx = [V::Elem::ZERO; MAX_LANES];
    let mut by = [V::Elem::ZERO; MAX_LANES];
    x.write(&mut bx);
    y.write(&mut by);
    for (a, &b) in bx[..V::N].iter_mut().zip(by[..V::N].iter()) {
        *a = f(*a, b);
    }
    V::read(&bx)
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn apply_un_v<V: Lanes>(k: UnaryK, x: V) -> V {
    match k {
        UnaryK::Neg => x.neg(),
        UnaryK::Exp => map_lanes(x, V::Elem::fexp),
        UnaryK::Ln => map_lanes(x, V::Elem::fln),
        UnaryK::Sqrt => x.sqrt(),
        UnaryK::Recip => V::splat(V::Elem::ONE).div(x),
        UnaryK::Tanh => map_lanes(x, V::Elem::ftanh),
    }
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn apply_bin_v<V: Lanes>(k: BinaryK, x: V, y: V) -> V {
    match k {
        BinaryK::Add => x.add(y),
        BinaryK::Sub => x.sub(y),
        BinaryK::Mul => x.mul(y),
        BinaryK::Div => x.div(y),
        // `fmax`/`fmin` keep Rust's NaN/±0 semantics (maxps/vmaxq
        // differ), so they run per lane through the scalar fn.
        BinaryK::Max => map2_lanes(x, y, V::Elem::fmax),
        BinaryK::Min => map2_lanes(x, y, V::Elem::fmin),
        BinaryK::Ge => x.ge_mask(y),
        BinaryK::Le => x.le_mask(y),
    }
}

/// Gather one operand for lanes `[i, i + N)`, honoring its [`Access`]
/// pattern. Fast paths: `Flat` is one contiguous load, `Scalar` a
/// splat, an in-row `Row` block a splat of that row's value, an in-row
/// `Col` block a contiguous load of the column slice; blocks that cross
/// a row boundary gather lane-by-lane through `src_index`.
///
/// # Safety: `p` must be sized for its `Access` pattern over the pass
/// (the `plan_srcs` contract), with `i + N` within the pass length.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn load_operand<V: Lanes>(src: &(SendPtr, Access), i: usize) -> V {
    let (p, access) = *src;
    let base = p.ptr() as *const V::Elem;
    // SAFETY: every index read below is `src_index(access, j)` for some
    // j in [i, i+N), which the plan bounds to the operand's extent; the
    // Flat/Col contiguous loads read exactly those indices.
    unsafe {
        match access {
            Access::Flat => V::load(base.add(i)),
            Access::Scalar => V::splat(*base),
            Access::Row(inner) => {
                if i % inner + V::N <= inner {
                    V::splat(*base.add(i / inner))
                } else {
                    gather::<V>(base, access, i)
                }
            }
            Access::Col(inner) => {
                let col = i % inner;
                if col + V::N <= inner {
                    V::load(base.add(col))
                } else {
                    gather::<V>(base, access, i)
                }
            }
        }
    }
}

/// Lane-by-lane gather through `src_index` — the slow generic path for
/// blocks that straddle a row boundary.
///
/// # Safety: as in [`load_operand`].
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn gather<V: Lanes>(base: *const V::Elem, access: Access, i: usize) -> V {
    let mut buf = [V::Elem::ZERO; MAX_LANES];
    for (l, slot) in buf[..V::N].iter_mut().enumerate() {
        // SAFETY: src_index stays within the operand extent per the
        // caller's contract.
        *slot = unsafe { *base.add(src_index(access, i + l)) };
    }
    V::read(&buf)
}

/// Evaluate the tape for lanes `[i, i + N)`: instruction-for-instruction
/// the scalar `Tape::eval`, with a vector stack.
///
/// # Safety: as in [`load_operand`]; `Load` indices are tape-verified
/// against `srcs.len()` at build time.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn eval_block<V: Lanes>(tape: &Tape, srcs: &[(SendPtr, Access)], i: usize) -> V {
    let mut stack = [V::splat(V::Elem::ZERO); MAX_STACK];
    let mut sp = 0usize;
    for op in &tape.ops {
        match *op {
            MicroOp::Load(k) => {
                // SAFETY: operand extents per this fn's contract.
                stack[sp] = unsafe { load_operand::<V>(&srcs[k as usize], i) };
                sp += 1;
            }
            MicroOp::Const(c) => {
                stack[sp] = V::splat(V::Elem::from_f64(c));
                sp += 1;
            }
            MicroOp::Dup => {
                stack[sp] = stack[sp - 1];
                sp += 1;
            }
            MicroOp::Swap => stack.swap(sp - 1, sp - 2),
            MicroOp::Un(k) => stack[sp - 1] = apply_un_v(k, stack[sp - 1]),
            MicroOp::Bin(k) => {
                sp -= 1;
                stack[sp - 1] = apply_bin_v(k, stack[sp - 1], stack[sp]);
            }
        }
    }
    debug_assert_eq!(sp, 1);
    stack[0]
}

/// Whole-block map driver: vector blocks over `[s, e)`, scalar
/// interpreter for the tail.
///
/// # Safety: the `run_map_t` chunk contract (see [`map_range`]).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn map_blocks<V: Lanes>(
    tape: &Tape,
    srcs: &[(SendPtr, Access)],
    out: SendPtr,
    s: usize,
    e: usize,
) {
    let po = out.ptr() as *mut V::Elem;
    let nargs = srcs.len();
    let mut i = s;
    // SAFETY: each block reads all its lanes' args before storing
    // out[i..i+N) — the same per-index read-then-write order as the
    // scalar loop, so index-aligned Flat aliasing (output stealing)
    // stays sound; the tail is literally the scalar loop.
    unsafe {
        while i + V::N <= e {
            let v = eval_block::<V>(tape, srcs, i);
            v.store(po.add(i));
            i += V::N;
        }
        let mut args = [V::Elem::ZERO; MAX_ARGS];
        for j in i..e {
            for (k, (p, acc)) in srcs.iter().enumerate() {
                args[k] = std::ptr::read((p.ptr() as *const V::Elem).add(src_index(*acc, j)));
            }
            std::ptr::write(po.add(j), tape.eval(&args[..nargs]));
        }
    }
}

/// Whole-range sum driver: fold each block's lanes into the accumulator
/// in ascending index order, scalar tail — the exact scalar chunk chain.
///
/// # Safety: the `run_map_sum_t` read contract (see [`sum_range`]).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn sum_blocks<V: Lanes>(
    tape: &Tape,
    srcs: &[(SendPtr, Access)],
    s: usize,
    e: usize,
) -> V::Elem {
    let nargs = srcs.len();
    let mut acc = V::Elem::ZERO;
    let mut buf = [V::Elem::ZERO; MAX_LANES];
    let mut i = s;
    // SAFETY: read-only gathers within the planned extents; lane values
    // fold in ascending index order, so every addition happens in the
    // scalar chunk's order.
    unsafe {
        while i + V::N <= e {
            eval_block::<V>(tape, srcs, i).write(&mut buf);
            for &x in &buf[..V::N] {
                acc = acc + x;
            }
            i += V::N;
        }
        let mut args = [V::Elem::ZERO; MAX_ARGS];
        for j in i..e {
            for (k, (p, a)) in srcs.iter().enumerate() {
                args[k] = std::ptr::read((p.ptr() as *const V::Elem).add(src_index(*a, j)));
            }
            acc = acc + tape.eval(&args[..nargs]);
        }
    }
    acc
}

// ---------------------------------------------------------------------
// Lane types + concrete drivers per architecture
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod lanes_impl {
    use core::arch::x86_64::*;

    use super::Lanes;

    /// 8 × f32 in a `__m256`. Intrinsic calls carry the feature-presence
    /// obligation; the drivers only run after `level()` reported AVX2.
    #[derive(Clone, Copy)]
    pub(super) struct F32x8(__m256);

    impl Lanes for F32x8 {
        type Elem = f32;
        const N: usize = 8;

        #[inline(always)]
        fn splat(x: f32) -> Self {
            // SAFETY: AVX2 presence established by the cached probe
            // before any vector driver runs; register-only op.
            F32x8(unsafe { _mm256_set1_ps(x) })
        }

        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            // SAFETY: AVX2 per the cached probe; `p` valid for 8 reads
            // per this fn's contract (unaligned load).
            F32x8(unsafe { _mm256_loadu_ps(p) })
        }

        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            // SAFETY: AVX2 per the cached probe; `p` valid for 8 writes
            // per this fn's contract (unaligned store).
            unsafe { _mm256_storeu_ps(p, self.0) }
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F32x8(unsafe { _mm256_add_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F32x8(unsafe { _mm256_sub_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F32x8(unsafe { _mm256_mul_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F32x8(unsafe { _mm256_div_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op
            // (vsqrtps is IEEE correctly rounded, like scalar sqrt).
            F32x8(unsafe { _mm256_sqrt_ps(self.0) })
        }

        #[inline(always)]
        fn neg(self) -> Self {
            // SAFETY: AVX2 per the cached probe. XOR with -0.0 flips
            // exactly the sign bit — the scalar `-x` on every payload,
            // NaNs included.
            F32x8(unsafe { _mm256_xor_ps(self.0, _mm256_set1_ps(-0.0)) })
        }

        #[inline(always)]
        fn ge_mask(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe. `_CMP_GE_OQ` (ordered,
            // quiet) is all-ones where x >= y and zero otherwise — NaN
            // compares false, matching the scalar branch — then masking
            // with 1.0 yields exactly {1.0, 0.0}.
            F32x8(unsafe {
                _mm256_and_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(self.0, o.0), _mm256_set1_ps(1.0))
            })
        }

        #[inline(always)]
        fn le_mask(self, o: Self) -> Self {
            // SAFETY: as in `ge_mask`, with `_CMP_LE_OQ`.
            F32x8(unsafe {
                _mm256_and_ps(_mm256_cmp_ps::<_CMP_LE_OQ>(self.0, o.0), _mm256_set1_ps(1.0))
            })
        }
    }

    /// 4 × f64 in a `__m256d`; the f64 twin of [`F32x8`].
    #[derive(Clone, Copy)]
    pub(super) struct F64x4(__m256d);

    impl Lanes for F64x4 {
        type Elem = f64;
        const N: usize = 4;

        #[inline(always)]
        fn splat(x: f64) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F64x4(unsafe { _mm256_set1_pd(x) })
        }

        #[inline(always)]
        unsafe fn load(p: *const f64) -> Self {
            // SAFETY: AVX2 per the cached probe; `p` valid for 4 reads
            // per this fn's contract (unaligned load).
            F64x4(unsafe { _mm256_loadu_pd(p) })
        }

        #[inline(always)]
        unsafe fn store(self, p: *mut f64) {
            // SAFETY: AVX2 per the cached probe; `p` valid for 4 writes
            // per this fn's contract (unaligned store).
            unsafe { _mm256_storeu_pd(p, self.0) }
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F64x4(unsafe { _mm256_add_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F64x4(unsafe { _mm256_sub_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F64x4(unsafe { _mm256_mul_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F64x4(unsafe { _mm256_div_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            // SAFETY: AVX2 per the cached probe; register-only op.
            F64x4(unsafe { _mm256_sqrt_pd(self.0) })
        }

        #[inline(always)]
        fn neg(self) -> Self {
            // SAFETY: AVX2 per the cached probe; sign-bit XOR, exactly
            // the scalar `-x`.
            F64x4(unsafe { _mm256_xor_pd(self.0, _mm256_set1_pd(-0.0)) })
        }

        #[inline(always)]
        fn ge_mask(self, o: Self) -> Self {
            // SAFETY: AVX2 per the cached probe; ordered-quiet compare
            // masked to 1.0, as in F32x8::ge_mask.
            F64x4(unsafe {
                _mm256_and_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(self.0, o.0), _mm256_set1_pd(1.0))
            })
        }

        #[inline(always)]
        fn le_mask(self, o: Self) -> Self {
            // SAFETY: as in `ge_mask`, with `_CMP_LE_OQ`.
            F64x4(unsafe {
                _mm256_and_pd(_mm256_cmp_pd::<_CMP_LE_OQ>(self.0, o.0), _mm256_set1_pd(1.0))
            })
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod lanes_impl {
    use core::arch::aarch64::*;

    use super::Lanes;

    /// 4 × f32 in a `float32x4_t` (NEON is baseline on aarch64).
    #[derive(Clone, Copy)]
    pub(super) struct F32x4(float32x4_t);

    impl Lanes for F32x4 {
        type Elem = f32;
        const N: usize = 4;

        #[inline(always)]
        fn splat(x: f32) -> Self {
            // SAFETY: NEON is baseline on aarch64; register-only op.
            F32x4(unsafe { vdupq_n_f32(x) })
        }

        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            // SAFETY: NEON baseline; `p` valid for 4 reads per contract.
            F32x4(unsafe { vld1q_f32(p) })
        }

        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            // SAFETY: NEON baseline; `p` valid for 4 writes per contract.
            unsafe { vst1q_f32(p, self.0) }
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F32x4(unsafe { vaddq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F32x4(unsafe { vsubq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F32x4(unsafe { vmulq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op (A64 vdivq is
            // IEEE correctly rounded, like scalar division).
            F32x4(unsafe { vdivq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            // SAFETY: NEON baseline; vsqrtq is correctly rounded.
            F32x4(unsafe { vsqrtq_f32(self.0) })
        }

        #[inline(always)]
        fn neg(self) -> Self {
            // SAFETY: NEON baseline; vnegq is the sign-bit flip, the
            // scalar `-x` on every payload.
            F32x4(unsafe { vnegq_f32(self.0) })
        }

        #[inline(always)]
        fn ge_mask(self, o: Self) -> Self {
            // SAFETY: NEON baseline. vcgeq is all-ones where x >= y and
            // zero otherwise (NaN compares false, like the scalar
            // branch); AND with the bit pattern of 1.0 yields {1.0, 0.0}.
            F32x4(unsafe {
                vreinterpretq_f32_u32(vandq_u32(
                    vcgeq_f32(self.0, o.0),
                    vreinterpretq_u32_f32(vdupq_n_f32(1.0)),
                ))
            })
        }

        #[inline(always)]
        fn le_mask(self, o: Self) -> Self {
            // SAFETY: as in `ge_mask`, with vcleq.
            F32x4(unsafe {
                vreinterpretq_f32_u32(vandq_u32(
                    vcleq_f32(self.0, o.0),
                    vreinterpretq_u32_f32(vdupq_n_f32(1.0)),
                ))
            })
        }
    }

    /// 2 × f64 in a `float64x2_t`; the f64 twin of [`F32x4`].
    #[derive(Clone, Copy)]
    pub(super) struct F64x2(float64x2_t);

    impl Lanes for F64x2 {
        type Elem = f64;
        const N: usize = 2;

        #[inline(always)]
        fn splat(x: f64) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F64x2(unsafe { vdupq_n_f64(x) })
        }

        #[inline(always)]
        unsafe fn load(p: *const f64) -> Self {
            // SAFETY: NEON baseline; `p` valid for 2 reads per contract.
            F64x2(unsafe { vld1q_f64(p) })
        }

        #[inline(always)]
        unsafe fn store(self, p: *mut f64) {
            // SAFETY: NEON baseline; `p` valid for 2 writes per contract.
            unsafe { vst1q_f64(p, self.0) }
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F64x2(unsafe { vaddq_f64(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F64x2(unsafe { vsubq_f64(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F64x2(unsafe { vmulq_f64(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            // SAFETY: NEON baseline; register-only op.
            F64x2(unsafe { vdivq_f64(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            // SAFETY: NEON baseline; vsqrtq is correctly rounded.
            F64x2(unsafe { vsqrtq_f64(self.0) })
        }

        #[inline(always)]
        fn neg(self) -> Self {
            // SAFETY: NEON baseline; sign-bit flip, the scalar `-x`.
            F64x2(unsafe { vnegq_f64(self.0) })
        }

        #[inline(always)]
        fn ge_mask(self, o: Self) -> Self {
            // SAFETY: NEON baseline; compare-then-mask as in F32x4.
            F64x2(unsafe {
                vreinterpretq_f64_u64(vandq_u64(
                    vcgeq_f64(self.0, o.0),
                    vreinterpretq_u64_f64(vdupq_n_f64(1.0)),
                ))
            })
        }

        #[inline(always)]
        fn le_mask(self, o: Self) -> Self {
            // SAFETY: as in `ge_mask`, with vcleq.
            F64x2(unsafe {
                vreinterpretq_f64_u64(vandq_u64(
                    vcleq_f64(self.0, o.0),
                    vreinterpretq_u64_f64(vdupq_n_f64(1.0)),
                ))
            })
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod drivers {
    use super::lanes_impl::{F32x8, F64x4};
    use super::{map_blocks, sum_blocks, Access, SendPtr, Tape};

    /// # Safety: AVX2 must be present; the `run_map_t` chunk contract.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn map_f32(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        out: SendPtr,
        s: usize,
        e: usize,
    ) {
        // SAFETY: contract forwarded verbatim.
        unsafe { map_blocks::<F32x8>(tape, srcs, out, s, e) }
    }

    /// # Safety: AVX2 must be present; the `run_map_t` chunk contract.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn map_f64(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        out: SendPtr,
        s: usize,
        e: usize,
    ) {
        // SAFETY: contract forwarded verbatim.
        unsafe { map_blocks::<F64x4>(tape, srcs, out, s, e) }
    }

    /// # Safety: AVX2 must be present; the `run_map_sum_t` read contract.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sum_f32(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        s: usize,
        e: usize,
    ) -> f32 {
        // SAFETY: contract forwarded verbatim.
        unsafe { sum_blocks::<F32x8>(tape, srcs, s, e) }
    }

    /// # Safety: AVX2 must be present; the `run_map_sum_t` read contract.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sum_f64(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        s: usize,
        e: usize,
    ) -> f64 {
        // SAFETY: contract forwarded verbatim.
        unsafe { sum_blocks::<F64x4>(tape, srcs, s, e) }
    }
}

#[cfg(target_arch = "aarch64")]
mod drivers {
    use super::lanes_impl::{F32x4, F64x2};
    use super::{map_blocks, sum_blocks, Access, SendPtr, Tape};

    /// # Safety: the `run_map_t` chunk contract (NEON is baseline).
    pub(super) unsafe fn map_f32(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        out: SendPtr,
        s: usize,
        e: usize,
    ) {
        // SAFETY: contract forwarded verbatim.
        unsafe { map_blocks::<F32x4>(tape, srcs, out, s, e) }
    }

    /// # Safety: the `run_map_t` chunk contract (NEON is baseline).
    pub(super) unsafe fn map_f64(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        out: SendPtr,
        s: usize,
        e: usize,
    ) {
        // SAFETY: contract forwarded verbatim.
        unsafe { map_blocks::<F64x2>(tape, srcs, out, s, e) }
    }

    /// # Safety: the `run_map_sum_t` read contract (NEON is baseline).
    pub(super) unsafe fn sum_f32(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        s: usize,
        e: usize,
    ) -> f32 {
        // SAFETY: contract forwarded verbatim.
        unsafe { sum_blocks::<F32x4>(tape, srcs, s, e) }
    }

    /// # Safety: the `run_map_sum_t` read contract (NEON is baseline).
    pub(super) unsafe fn sum_f64(
        tape: &Tape,
        srcs: &[(SendPtr, Access)],
        s: usize,
        e: usize,
    ) -> f64 {
        // SAFETY: contract forwarded verbatim.
        unsafe { sum_blocks::<F64x2>(tape, srcs, s, e) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::super::{src_index, BinaryK, Tape, UnaryK};

    // A tape exercising every micro-op class over 4 operands with mixed
    // access patterns: max(x*w + b, s) fed through dup/swap and a few
    // unaries, kept within MAX_STACK.
    fn test_tape() -> Tape {
        Tape::build(4)
            .load(0) // x        (Flat)
            .load(1) // w        (Col)
            .mul()
            .load(2) // b        (Row)
            .add()
            .dup()
            .un(UnaryK::Neg)
            .swap()
            .bin(BinaryK::Max)
            .c(0.75)
            .bin(BinaryK::Ge)
            .load(3) // s        (Scalar)
            .add()
            .un(UnaryK::Sqrt)
            .tanh()
            .c(1.0)
            .swap()
            .un(UnaryK::Recip)
            .bin(BinaryK::Sub)
            .done()
    }

    fn scalar_args(srcs: &[(SendPtr, Access)], i: usize) -> Vec<f32> {
        srcs.iter()
            .map(|(p, a)| {
                // SAFETY: test buffers sized for their access patterns.
                unsafe { *(p.ptr() as *const f32).add(src_index(*a, i)) }
            })
            .collect()
    }

    fn test_operands(n: usize, inner: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..n).map(|i| (i as f32) * 0.25 - 3.0).collect();
        let w: Vec<f32> = (0..inner).map(|i| 0.5 + (i as f32) * 0.125).collect();
        let b: Vec<f32> = (0..n.div_ceil(inner)).map(|i| (i as f32) - 1.5).collect();
        let s = vec![2.0f32];
        (x, w, b, s)
    }

    #[test]
    fn vector_map_matches_scalar_eval_bitwise() {
        let tape = test_tape();
        // inner = 7 forces Row/Col blocks that straddle row boundaries
        // (the gather slow path) as well as in-row fast paths.
        let (n, inner) = (93usize, 7usize);
        let (x, w, b, s) = test_operands(n, inner);
        let mut out = vec![0.0f32; n];
        let srcs = [
            (SendPtr::new(x.as_ptr() as *mut u8), Access::Flat),
            (SendPtr::new(w.as_ptr() as *mut u8), Access::Col(inner)),
            (SendPtr::new(b.as_ptr() as *mut u8), Access::Row(inner)),
            (SendPtr::new(s.as_ptr() as *mut u8), Access::Scalar),
        ];
        // SAFETY: every buffer above is sized for its access pattern
        // over n elements and outlives the call; out is disjoint.
        let used = unsafe {
            map_range::<f32>(&tape, &srcs, SendPtr::new(out.as_mut_ptr() as *mut u8), 0, n)
        };
        if !used {
            // Scalar-only config (PALLAS_SIMD=0, Miri, no AVX2): the
            // fallback path is the scalar interpreter itself.
            return;
        }
        for (i, &got) in out.iter().enumerate() {
            let want = tape.eval::<f32>(&scalar_args(&srcs, i));
            assert_eq!(got.to_bits(), want.to_bits(), "element {i} diverged");
        }
    }

    #[test]
    fn vector_sum_matches_scalar_chain_bitwise() {
        let tape = test_tape();
        let (n, inner) = (121usize, 11usize);
        let (x, w, b, s) = test_operands(n, inner);
        let srcs = [
            (SendPtr::new(x.as_ptr() as *mut u8), Access::Flat),
            (SendPtr::new(w.as_ptr() as *mut u8), Access::Col(inner)),
            (SendPtr::new(b.as_ptr() as *mut u8), Access::Row(inner)),
            (SendPtr::new(s.as_ptr() as *mut u8), Access::Scalar),
        ];
        // SAFETY: read-only, buffers sized as above.
        let got = unsafe { sum_range::<f32>(&tape, &srcs, 0, n) };
        let Some(got) = got else {
            return; // scalar-only config
        };
        let mut want = 0.0f32;
        for i in 0..n {
            want += tape.eval::<f32>(&scalar_args(&srcs, i));
        }
        assert_eq!(got.to_bits(), want.to_bits());
    }
}
