//! Concatenation and the raw strided-copy kernel behind it.

use crate::autograd::{ClosureFunction, Function};
use crate::tensor::shape::StridedIter;
use crate::tensor::{DType, Element, Tensor};
use crate::torsk_assert;

use super::{OpCtx, OpDef, Registry};

fn copy_into_view_t<T: Element>(view: &Tensor, src: &Tensor) {
    let src = src.contiguous();
    let n = src.numel();
    if n == 0 {
        return;
    }
    let (sp, vp) = (src.data_ptr(), view.data_ptr());
    let shape = view.shape().to_vec();
    let strides = view.strides().to_vec();
    // Keep host sources alive until the (possibly queued) copy runs.
    let keep = src.detach();
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    crate::device::dispatch(view.device(), "copy_into_view", move || unsafe {
        let sv = sp.as_slice::<T>(0, n);
        let base = vp.ptr() as *mut T;
        for (i, off) in StridedIter::new(&shape, &strides).enumerate() {
            *base.add(off) = sv[i];
        }
        drop(keep);
    });
}

/// Raw strided copy of `src` (made contiguous) into a strided `view`.
/// Internal: used for narrow backward and `cat`.
pub(crate) fn copy_into_view(view: &Tensor, src: &Tensor) {
    torsk_assert!(view.shape() == src.shape(), "copy_into_view: shape mismatch");
    torsk_assert!(view.dtype() == src.dtype(), "copy_into_view: dtype mismatch");
    match view.dtype() {
        DType::F32 => copy_into_view_t::<f32>(view, src),
        DType::F64 => copy_into_view_t::<f64>(view, src),
        DType::I64 => copy_into_view_t::<i64>(view, src),
    }
}

/// Concatenate tensors along `dim` (param 0).
fn k_cat(ctx: &OpCtx) -> Tensor {
    let tensors = ctx.inputs;
    let dim = ctx.usize(0);
    let first = tensors[0];
    let dev = ctx.device;
    let mut out_shape = first.shape().to_vec();
    torsk_assert!(dim < out_shape.len(), "cat: dim out of range");
    let mut total = 0usize;
    for t in tensors {
        torsk_assert!(t.ndim() == first.ndim(), "cat: rank mismatch");
        torsk_assert!(t.dtype() == first.dtype(), "cat: dtype mismatch");
        for d in 0..first.ndim() {
            if d != dim {
                torsk_assert!(t.size(d) == first.size(d), "cat: dim {d} mismatch");
            }
        }
        total += t.size(dim);
    }
    out_shape[dim] = total;
    let out = Tensor::empty(&out_shape, first.dtype(), dev);
    let mut offset = 0usize;
    for t in tensors {
        let view = out.detach().narrow(dim, offset, t.size(dim));
        copy_into_view(&view, t);
        offset += t.size(dim);
    }
    out
}

fn bw_cat(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let dim = ctx.usize(0);
    let sizes: Vec<usize> = ctx.inputs.iter().map(|t| t.size(dim)).collect();
    ClosureFunction::new("cat", move |g| {
        let mut grads = Vec::with_capacity(sizes.len());
        let mut off = 0usize;
        for &s in &sizes {
            grads.push(Some(g.narrow(dim, off, s).contiguous()));
            off += s;
        }
        grads
    })
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

use super::{OpSample, Param};

fn s_cat(seed: u64, dt: DType) -> Option<OpSample> {
    let a = super::sample_uniform(seed, &[2, 3], dt, -1.5, 1.5)?;
    let b = super::sample_uniform(seed ^ 0xC, &[2, 3], dt, -1.5, 1.5)?;
    Some(OpSample {
        inputs: vec![a, b],
        params: vec![Param::Usize((seed % 2) as usize)],
        grad_inputs: vec![0, 1],
    })
}

pub(crate) fn register(reg: &mut Registry) {
    reg.add(
        OpDef::new("cat", 1, usize::MAX, &[]).kernel_all(k_cat).backward(bw_cat).sample_inputs(s_cat),
    );
}
