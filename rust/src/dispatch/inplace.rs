//! In-place mutation kernel entries (`add_`, `mul_`, `zero_`, `copy_`,
//! `fill_`, `axpy_`).
//!
//! Every mutation bumps the storage version (§4.3). Mutating a leaf that
//! requires grad outside `no_grad` is an error, mirroring PyTorch's
//! "a leaf Variable that requires grad is being used in an in-place
//! operation". The destination is input 0; the (unchanged) handle is the
//! op result. No backward entries: in-place ops never record.

use crate::autograd;
use crate::device;
use crate::tensor::{DType, Element, Tensor};
use crate::torsk_assert;

use super::{same_device, OpCtx, OpDef, Registry};

fn check_inplace_allowed(t: &Tensor, name: &str) {
    torsk_assert!(
        !(autograd::grad_enabled() && t.requires_grad_flag() && t.grad_fn().is_none()),
        "a leaf tensor that requires grad is being used in an in-place \
         operation ({name}); wrap the update in no_grad()"
    );
}

fn inplace_binary_t<T: Element>(name: &'static str, dst: &Tensor, src: &Tensor, f: fn(T, T) -> T) {
    check_inplace_allowed(dst, name);
    torsk_assert!(
        dst.shape() == src.shape(),
        "{name}: shape {:?} vs {:?}",
        dst.shape(),
        src.shape()
    );
    torsk_assert!(dst.is_contiguous(), "{name}: destination must be contiguous");
    let dev = same_device(name, &[dst, src]);
    let src = src.contiguous();
    let n = dst.numel();
    let (dp, sp) = (dst.data_ptr(), src.data_ptr());
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(dev, name, move || unsafe {
        let d = dp.as_mut_slice::<T>(0, n);
        let s = sp.as_slice::<T>(0, n);
        for i in 0..n {
            d[i] = f(d[i], s[i]);
        }
    });
    dst.bump_version();
}

fn inplace_scalar_t<T: Element>(name: &'static str, dst: &Tensor, s: T, f: fn(T, T) -> T) {
    check_inplace_allowed(dst, name);
    torsk_assert!(dst.is_contiguous(), "{name}: destination must be contiguous");
    let n = dst.numel();
    let dp = dst.data_ptr();
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(dst.device(), name, move || unsafe {
        let d = dp.as_mut_slice::<T>(0, n);
        for x in d.iter_mut() {
            *x = f(*x, s);
        }
    });
    dst.bump_version();
}

/// Instantiate an in-place binary kernel over the destination dtype. The
/// source must match (no silent promotion into a fixed-size buffer).
macro_rules! inplace_binary {
    ($name:expr, $dst:expr, $src:expr, |$x:ident, $y:ident| $body:expr) => {{
        let (dst, src) = ($dst, $src);
        torsk_assert!(
            dst.dtype() == src.dtype(),
            "{}: dtype mismatch {} vs {}",
            $name,
            dst.dtype(),
            src.dtype()
        );
        match dst.dtype() {
            DType::F32 => inplace_binary_t::<f32>($name, dst, src, |$x, $y| $body),
            DType::F64 => inplace_binary_t::<f64>($name, dst, src, |$x, $y| $body),
            DType::I64 => inplace_binary_t::<i64>($name, dst, src, |$x, $y| $body),
        }
    }};
}

fn k_add_(ctx: &OpCtx) -> Tensor {
    inplace_binary!("add_", ctx.input(0), ctx.input(1), |a, b| a + b);
    ctx.input(0).clone()
}

fn k_sub_(ctx: &OpCtx) -> Tensor {
    inplace_binary!("sub_", ctx.input(0), ctx.input(1), |a, b| a - b);
    ctx.input(0).clone()
}

fn k_mul_(ctx: &OpCtx) -> Tensor {
    inplace_binary!("mul_", ctx.input(0), ctx.input(1), |a, b| a * b);
    ctx.input(0).clone()
}

fn k_copy_(ctx: &OpCtx) -> Tensor {
    inplace_binary!("copy_", ctx.input(0), ctx.input(1), |_a, b| b);
    ctx.input(0).clone()
}

/// `dst += alpha * src` — the SGD update primitive.
fn k_axpy_(ctx: &OpCtx) -> Tensor {
    let (dst, src) = (ctx.input(0), ctx.input(1));
    let alpha = ctx.f32(0);
    check_inplace_allowed(dst, "axpy_");
    torsk_assert!(dst.shape() == src.shape(), "axpy_: shape mismatch");
    torsk_assert!(dst.dtype() == src.dtype(), "axpy_: dtype mismatch");
    torsk_assert!(dst.is_contiguous(), "axpy_: destination must be contiguous");
    let dev = same_device("axpy_", &[dst, src]);
    let src_c = src.contiguous();
    let n = dst.numel();
    let (dp, sp) = (dst.data_ptr(), src_c.data_ptr());
    match dst.dtype() {
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        DType::F32 => device::dispatch(dev, "axpy_", move || unsafe {
            let d = dp.as_mut_slice::<f32>(0, n);
            let s = sp.as_slice::<f32>(0, n);
            for i in 0..n {
                d[i] += alpha * s[i];
            }
        }),
        DType::F64 => {
            let alpha = alpha as f64;
            // SAFETY: pointer/length pairs come from shape-checked live tensors
            // captured at enqueue time. On CPU this closure runs inline while the
            // caller's handles are alive; on a stream, the one-pool-per-stream
            // FIFO allocator guarantees freed storage is only reused by kernels
            // enqueued later on the same stream, so the bytes stay valid (and
            // writes exclusive) until this kernel completes.
            device::dispatch(dev, "axpy_", move || unsafe {
                let d = dp.as_mut_slice::<f64>(0, n);
                let s = sp.as_slice::<f64>(0, n);
                for i in 0..n {
                    d[i] += alpha * s[i];
                }
            })
        }
        other => crate::torsk_bail!("axpy_: unsupported dtype {other}"),
    }
    dst.bump_version();
    dst.clone()
}

fn k_mul_scalar_(ctx: &OpCtx) -> Tensor {
    let (dst, s) = (ctx.input(0), ctx.f32(0));
    match dst.dtype() {
        DType::F32 => inplace_scalar_t::<f32>("mul_scalar_", dst, s, |a, b| a * b),
        DType::F64 => inplace_scalar_t::<f64>("mul_scalar_", dst, s as f64, |a, b| a * b),
        other => crate::torsk_bail!("mul_scalar_: unsupported dtype {other}"),
    }
    dst.clone()
}

fn k_add_scalar_(ctx: &OpCtx) -> Tensor {
    let (dst, s) = (ctx.input(0), ctx.f32(0));
    match dst.dtype() {
        DType::F32 => inplace_scalar_t::<f32>("add_scalar_", dst, s, |a, b| a + b),
        DType::F64 => inplace_scalar_t::<f64>("add_scalar_", dst, s as f64, |a, b| a + b),
        other => crate::torsk_bail!("add_scalar_: unsupported dtype {other}"),
    }
    dst.clone()
}

fn k_fill_(ctx: &OpCtx) -> Tensor {
    let (dst, v) = (ctx.input(0), ctx.f32(0));
    match dst.dtype() {
        DType::F32 => inplace_scalar_t::<f32>("fill_", dst, v, |_a, b| b),
        DType::F64 => inplace_scalar_t::<f64>("fill_", dst, v as f64, |_a, b| b),
        DType::I64 => inplace_scalar_t::<i64>("fill_", dst, i64::from_f64(v as f64), |_a, b| b),
    }
    dst.clone()
}

// ---------------------------------------------------------------------
// OpInfo samples (in-place ops never record — grad_inputs stays empty)
// ---------------------------------------------------------------------

use super::{sample_uniform, OpSample, Param};

fn s_inplace_binary(seed: u64, dt: DType) -> Option<OpSample> {
    let dst = sample_uniform(seed, &[3, 4], dt, -1.5, 1.5)?;
    let src = sample_uniform(seed ^ 0xA, &[3, 4], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![dst, src], params: vec![], grad_inputs: vec![] })
}

fn s_axpy(seed: u64, dt: DType) -> Option<OpSample> {
    let dst = sample_uniform(seed, &[3, 4], dt, -1.5, 1.5)?;
    let src = sample_uniform(seed ^ 0xA, &[3, 4], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![dst, src], params: vec![Param::F32(0.5)], grad_inputs: vec![] })
}

fn s_inplace_scalar(seed: u64, dt: DType) -> Option<OpSample> {
    let dst = sample_uniform(seed, &[3, 4], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![dst], params: vec![Param::F32(0.25)], grad_inputs: vec![] })
}

pub(crate) fn register(reg: &mut Registry) {
    reg.add(OpDef::new("add_", 2, 2, &[]).kernel_all(k_add_).sample_inputs(s_inplace_binary));
    reg.add(OpDef::new("sub_", 2, 2, &[]).kernel_all(k_sub_).sample_inputs(s_inplace_binary));
    reg.add(OpDef::new("mul_", 2, 2, &[]).kernel_all(k_mul_).sample_inputs(s_inplace_binary));
    reg.add(OpDef::new("copy_", 2, 2, &[]).kernel_all(k_copy_).sample_inputs(s_inplace_binary));
    reg.add(
        OpDef::new("axpy_", 2, 2, super::elementwise::FLOATS)
            .kernel_all(k_axpy_)
            .sample_inputs(s_axpy),
    );
    reg.add(
        OpDef::new("mul_scalar_", 1, 1, super::elementwise::FLOATS)
            .kernel_all(k_mul_scalar_)
            .sample_inputs(s_inplace_scalar),
    );
    reg.add(
        OpDef::new("add_scalar_", 1, 1, super::elementwise::FLOATS)
            .kernel_all(k_add_scalar_)
            .sample_inputs(s_inplace_scalar),
    );
    reg.add(OpDef::new("fill_", 1, 1, &[]).kernel_all(k_fill_).sample_inputs(s_inplace_scalar));
}
