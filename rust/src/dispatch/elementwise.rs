//! Elementwise kernels (binary, unary, scalar, cast) for the dispatcher.
//!
//! One generic driver per traversal shape, monomorphized over
//! [`Element`]: F32, F64 and I64 run through the same registry entries.
//! Mixed-dtype operands are promoted with [`DType::promote`] before the
//! kernel instantiation is selected; gradients are cast back to each
//! input's dtype so leaves always accumulate gradients of their own type.

use crate::autograd::{ClosureFunction, Function, SavedTensor};
use crate::device;
use crate::tensor::{DType, Element, Tensor};
use crate::{torsk_assert, torsk_bail};

use super::iter::{self, TensorIter};
use super::{
    same_device, sample_away_from_zero, sample_uniform, OpCtx, OpDef, OpSample, Param, Registry,
};

pub(crate) const FLOATS: &[DType] = &[DType::F32, DType::F64];
pub(crate) const NUMERIC: &[DType] = &[DType::F32, DType::F64, DType::I64];

// ---------------------------------------------------------------------
// Generic drivers
// ---------------------------------------------------------------------

/// Broadcasting binary map: host plans the traversal, the kernel runs
/// inline (CPU) or queued on the current stream (Sim).
pub(crate) fn binary_map_t<T: Element, O: Element>(
    name: &'static str,
    a: &Tensor,
    b: &Tensor,
    f: fn(T, T) -> O,
) -> Tensor {
    let dev = same_device(name, &[a, b]);
    torsk_assert!(
        a.dtype() == T::DTYPE && b.dtype() == T::DTYPE,
        "{name}: kernel instantiated for {} got {} x {}",
        T::DTYPE,
        a.dtype(),
        b.dtype()
    );
    let plan = TensorIter::binary(a, b);
    let out = Tensor::empty(&plan.out_shape, O::DTYPE, dev);
    if plan.n == 0 {
        return out;
    }
    let (ap, bp, op) = (a.data_ptr(), b.data_ptr(), out.data_ptr());
    device::dispatch(dev, name, move || plan.run_binary::<T, O>(ap, bp, op, f));
    out
}

/// Elementwise unary map, preserving shape; works on strided views via a
/// contiguous materialization.
pub(crate) fn unary_map_t<T: Element, O: Element>(
    name: &'static str,
    a: &Tensor,
    f: fn(T) -> O,
) -> Tensor {
    torsk_assert!(a.dtype() == T::DTYPE, "{name}: kernel for {} got {}", T::DTYPE, a.dtype());
    let a = a.contiguous();
    let out = Tensor::empty(a.shape(), O::DTYPE, a.device());
    let n = a.numel();
    let (ap, op) = (a.data_ptr(), out.data_ptr());
    device::dispatch(a.device(), name, move || iter::run_unary::<T, O, _>(n, ap, op, f));
    out
}

/// Elementwise map with one scalar parameter (already converted to `T`).
pub(crate) fn scalar_map_t<T: Element>(
    name: &'static str,
    a: &Tensor,
    s: T,
    f: fn(T, T) -> T,
) -> Tensor {
    torsk_assert!(a.dtype() == T::DTYPE, "{name}: kernel for {} got {}", T::DTYPE, a.dtype());
    let a = a.contiguous();
    let out = Tensor::empty(a.shape(), T::DTYPE, a.device());
    let n = a.numel();
    let (ap, op) = (a.data_ptr(), out.data_ptr());
    device::dispatch(a.device(), name, move || iter::run_unary::<T, T, _>(n, ap, op, move |x| f(x, s)));
    out
}

/// Elementwise map with two scalar parameters.
pub(crate) fn scalar2_map_t<T: Element>(
    name: &'static str,
    a: &Tensor,
    s1: T,
    s2: T,
    f: fn(T, T, T) -> T,
) -> Tensor {
    let a = a.contiguous();
    let out = Tensor::empty(a.shape(), T::DTYPE, a.device());
    let n = a.numel();
    let (ap, op) = (a.data_ptr(), out.data_ptr());
    device::dispatch(a.device(), name, move || iter::run_unary::<T, T, _>(n, ap, op, move |x| f(x, s1, s2)));
    out
}

fn cast_kernel_t<S: Element, D: Element>(a: &Tensor) -> Tensor {
    unary_map_t::<S, D>("cast", a, |x| D::from_f64(x.to_f64()))
}

// ---------------------------------------------------------------------
// Promotion + raw (non-recording) helpers for backward math
// ---------------------------------------------------------------------

/// Raw dtype conversion (no autograd); identity clone when already `dt`.
pub(crate) fn cast_to(t: &Tensor, dt: DType) -> Tensor {
    match (t.dtype(), dt) {
        (a, b) if a == b => t.clone(),
        (DType::F32, DType::F64) => cast_kernel_t::<f32, f64>(t),
        (DType::F32, DType::I64) => cast_kernel_t::<f32, i64>(t),
        (DType::F64, DType::F32) => cast_kernel_t::<f64, f32>(t),
        (DType::F64, DType::I64) => cast_kernel_t::<f64, i64>(t),
        (DType::I64, DType::F32) => cast_kernel_t::<i64, f32>(t),
        (DType::I64, DType::F64) => cast_kernel_t::<i64, f64>(t),
        _ => unreachable!(),
    }
}

/// Promote both operands to their common dtype (cheap handle clones when
/// the dtypes already match).
pub(crate) fn promote_pair(a: &Tensor, b: &Tensor) -> (Tensor, Tensor) {
    if a.dtype() == b.dtype() {
        return (a.clone(), b.clone());
    }
    let dt = DType::promote(a.dtype(), b.dtype());
    (cast_to(a, dt), cast_to(b, dt))
}

/// Instantiate a broadcasting binary kernel over the promoted dtype of
/// two tensors. The closure body must be valid for f32, f64 and i64.
macro_rules! binary_arith {
    ($name:expr, $a:expr, $b:expr, |$x:ident, $y:ident| $body:expr) => {{
        let (pa, pb) = promote_pair($a, $b);
        match pa.dtype() {
            DType::F32 => binary_map_t::<f32, f32>($name, &pa, &pb, |$x, $y| $body),
            DType::F64 => binary_map_t::<f64, f64>($name, &pa, &pb, |$x, $y| $body),
            DType::I64 => binary_map_t::<i64, i64>($name, &pa, &pb, |$x, $y| $body),
        }
    }};
}

/// Instantiate a unary kernel over f32/f64 (floating inputs only).
macro_rules! float_unary {
    ($name:expr, $a:expr, |$x:ident| $body:expr) => {{
        let a = $a;
        match a.dtype() {
            DType::F32 => unary_map_t::<f32, f32>($name, a, |$x| $body),
            DType::F64 => unary_map_t::<f64, f64>($name, a, |$x| $body),
            other => torsk_bail!("{}: unsupported dtype {other}", $name),
        }
    }};
}

/// Instantiate a one-scalar kernel over f32/f64. The scalar travels as
/// f64 and is narrowed per-dtype, so F64 tensors keep full scalar
/// precision (e.g. `mean`'s 1/n factor).
macro_rules! float_scalar {
    ($name:expr, $a:expr, $s:expr, |$x:ident, $sv:ident| $body:expr) => {{
        let a = $a;
        let s: f64 = $s;
        match a.dtype() {
            DType::F32 => scalar_map_t::<f32>($name, a, s as f32, |$x, $sv| $body),
            DType::F64 => scalar_map_t::<f64>($name, a, s, |$x, $sv| $body),
            other => torsk_bail!("{}: unsupported dtype {other}", $name),
        }
    }};
}

pub(crate) fn raw_add(a: &Tensor, b: &Tensor) -> Tensor {
    binary_arith!("add", a, b, |x, y| x + y)
}

pub(crate) fn raw_sub(a: &Tensor, b: &Tensor) -> Tensor {
    binary_arith!("sub", a, b, |x, y| x - y)
}

pub(crate) fn raw_mul(a: &Tensor, b: &Tensor) -> Tensor {
    binary_arith!("mul", a, b, |x, y| x * y)
}

pub(crate) fn raw_div(a: &Tensor, b: &Tensor) -> Tensor {
    binary_arith!("div", a, b, |x, y| x / y)
}

pub(crate) fn raw_neg(a: &Tensor) -> Tensor {
    match a.dtype() {
        DType::F32 => unary_map_t::<f32, f32>("neg", a, |x| -x),
        DType::F64 => unary_map_t::<f64, f64>("neg", a, |x| -x),
        DType::I64 => unary_map_t::<i64, i64>("neg", a, |x| -x),
    }
}

pub(crate) fn raw_mul_scalar(a: &Tensor, s: f64) -> Tensor {
    float_scalar!("mul_scalar", a, s, |x, sv| x * sv)
}

/// 1/0 mask (in the operands' promoted dtype) where `a >= b`.
fn mask_ge(a: &Tensor, b: &Tensor) -> Tensor {
    let (pa, pb) = promote_pair(a, b);
    match pa.dtype() {
        DType::F32 => binary_map_t::<f32, f32>("ge_mask", &pa, &pb, |x, y| if x >= y { 1.0 } else { 0.0 }),
        DType::F64 => binary_map_t::<f64, f64>("ge_mask", &pa, &pb, |x, y| if x >= y { 1.0 } else { 0.0 }),
        DType::I64 => binary_map_t::<i64, i64>("ge_mask", &pa, &pb, |x, y| if x >= y { 1 } else { 0 }),
    }
}

/// 1/0 mask where `a < b`.
fn mask_lt(a: &Tensor, b: &Tensor) -> Tensor {
    let (pa, pb) = promote_pair(a, b);
    match pa.dtype() {
        DType::F32 => binary_map_t::<f32, f32>("lt_mask", &pa, &pb, |x, y| if x < y { 1.0 } else { 0.0 }),
        DType::F64 => binary_map_t::<f64, f64>("lt_mask", &pa, &pb, |x, y| if x < y { 1.0 } else { 0.0 }),
        DType::I64 => binary_map_t::<i64, i64>("lt_mask", &pa, &pb, |x, y| if x < y { 1 } else { 0 }),
    }
}

// ---------------------------------------------------------------------
// Gradient plumbing shared by every broadcasting op
// ---------------------------------------------------------------------

/// Sum `grad` down to `shape` (undo broadcasting) — the standard binary-op
/// backward reduction.
pub fn reduce_grad_to_shape(grad: &Tensor, shape: &[usize]) -> Tensor {
    if grad.shape() == shape {
        return grad.clone();
    }
    super::reduce::sum_to_shape(grad, shape)
}

/// Reduce a broadcast gradient to an input's shape *and* dtype.
pub(crate) fn grad_to(g: &Tensor, shape: &[usize], dtype: DType) -> Tensor {
    cast_to(&reduce_grad_to_shape(g, shape), dtype)
}

/// Shape+dtype signature of one input, captured for the backward closure.
fn sig(ctx: &OpCtx, i: usize) -> (Vec<usize>, DType) {
    (ctx.input(i).shape().to_vec(), ctx.input(i).dtype())
}

// ---------------------------------------------------------------------
// Binary ops
// ---------------------------------------------------------------------

fn k_add(ctx: &OpCtx) -> Tensor {
    binary_arith!("add", ctx.input(0), ctx.input(1), |x, y| x + y)
}

fn bw_add(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (sa, da) = sig(ctx, 0);
    let (sb, db) = sig(ctx, 1);
    ClosureFunction::new("add", move |g| {
        vec![Some(grad_to(g, &sa, da)), Some(grad_to(g, &sb, db))]
    })
}

fn k_sub(ctx: &OpCtx) -> Tensor {
    binary_arith!("sub", ctx.input(0), ctx.input(1), |x, y| x - y)
}

fn bw_sub(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (sa, da) = sig(ctx, 0);
    let (sb, db) = sig(ctx, 1);
    ClosureFunction::new("sub", move |g| {
        vec![
            Some(grad_to(g, &sa, da)),
            Some(grad_to(&raw_neg(g), &sb, db)),
        ]
    })
}

fn k_mul(ctx: &OpCtx) -> Tensor {
    binary_arith!("mul", ctx.input(0), ctx.input(1), |x, y| x * y)
}

fn bw_mul(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (sa, da) = sig(ctx, 0);
    let (sb, db) = sig(ctx, 1);
    let (pa, pb) = promote_pair(ctx.input(0), ctx.input(1));
    let (va, vb) = (SavedTensor::save(&pa), SavedTensor::save(&pb));
    ClosureFunction::new("mul", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        vec![
            Some(grad_to(&raw_mul(g, &b), &sa, da)),
            Some(grad_to(&raw_mul(g, &a), &sb, db)),
        ]
    })
}

fn k_div(ctx: &OpCtx) -> Tensor {
    binary_arith!("div", ctx.input(0), ctx.input(1), |x, y| x / y)
}

fn bw_div(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (sa, da) = sig(ctx, 0);
    let (sb, db) = sig(ctx, 1);
    let (pa, pb) = promote_pair(ctx.input(0), ctx.input(1));
    let (va, vb) = (SavedTensor::save(&pa), SavedTensor::save(&pb));
    ClosureFunction::new("div", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        // d/da = g / b ; d/db = -g * a / b^2
        let ga = raw_div(g, &b);
        let gb = raw_neg(&raw_mul(g, &raw_div(&a, &raw_mul(&b, &b))));
        vec![Some(grad_to(&ga, &sa, da)), Some(grad_to(&gb, &sb, db))]
    })
}

fn k_maximum(ctx: &OpCtx) -> Tensor {
    binary_arith!("maximum", ctx.input(0), ctx.input(1), |x, y| x.max(y))
}

fn bw_maximum(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (sa, da) = sig(ctx, 0);
    let (sb, db) = sig(ctx, 1);
    let (pa, pb) = promote_pair(ctx.input(0), ctx.input(1));
    let (va, vb) = (SavedTensor::save(&pa), SavedTensor::save(&pb));
    ClosureFunction::new("maximum", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        let ma = mask_ge(&a, &b);
        let mb = mask_lt(&a, &b);
        vec![
            Some(grad_to(&raw_mul(g, &ma), &sa, da)),
            Some(grad_to(&raw_mul(g, &mb), &sb, db)),
        ]
    })
}

fn k_eq(ctx: &OpCtx) -> Tensor {
    let (pa, pb) = promote_pair(ctx.input(0), ctx.input(1));
    match pa.dtype() {
        DType::F32 => binary_map_t::<f32, f32>("eq", &pa, &pb, |x, y| if x == y { 1.0 } else { 0.0 }),
        DType::F64 => binary_map_t::<f64, f64>("eq", &pa, &pb, |x, y| if x == y { 1.0 } else { 0.0 }),
        DType::I64 => binary_map_t::<i64, i64>("eq", &pa, &pb, |x, y| if x == y { 1 } else { 0 }),
    }
}

// ---------------------------------------------------------------------
// Unary ops
// ---------------------------------------------------------------------

fn k_neg(ctx: &OpCtx) -> Tensor {
    raw_neg(ctx.input(0))
}

fn bw_neg(_ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    ClosureFunction::new("neg", move |g| vec![Some(raw_neg(g))])
}

/// Unary ops whose derivative is a function of the *output* save the
/// output (smaller live set than the input when the input is a temp).
macro_rules! unary_from_output {
    ($kname:ident, $bwname:ident, $opname:literal, |$x:ident| $fwd:expr, |$y:ident| $dbody:expr) => {
        fn $kname(ctx: &OpCtx) -> Tensor {
            float_unary!($opname, ctx.input(0), |$x| $fwd)
        }
        fn $bwname(_ctx: &OpCtx, out: &Tensor) -> Box<dyn Function> {
            let saved = SavedTensor::save(out);
            ClosureFunction::new($opname, move |g| {
                let y = saved.unpack();
                let dydx = float_unary!(concat!($opname, "_bwd"), &y, |$y| $dbody);
                vec![Some(raw_mul(g, &dydx))]
            })
        }
    };
}

unary_from_output!(k_exp, bw_exp, "exp", |x| x.exp(), |y| y);
unary_from_output!(
    k_sigmoid,
    bw_sigmoid,
    "sigmoid",
    |x| 1.0 / (1.0 + (-x).exp()),
    |y| y * (1.0 - y)
);
unary_from_output!(k_tanh, bw_tanh, "tanh", |x| x.tanh(), |y| 1.0 - y * y);
unary_from_output!(k_sqrt, bw_sqrt, "sqrt", |x| x.sqrt(), |y| 0.5 / y);
unary_from_output!(
    k_relu,
    bw_relu,
    "relu",
    |x| x.max(0.0),
    |y| if y > 0.0 { 1.0 } else { 0.0 }
);

fn k_log(ctx: &OpCtx) -> Tensor {
    float_unary!("log", ctx.input(0), |x| x.ln())
}

fn bw_log(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let saved = SavedTensor::save(ctx.input(0));
    ClosureFunction::new("log", move |g| {
        let x = saved.unpack();
        let dydx = float_unary!("log_bwd", &x, |x| 1.0 / x);
        vec![Some(raw_mul(g, &dydx))]
    })
}

// ---------------------------------------------------------------------
// Scalar-parameter ops
// ---------------------------------------------------------------------

fn k_add_scalar(ctx: &OpCtx) -> Tensor {
    let s = ctx.scalar(0);
    float_scalar!("add_scalar", ctx.input(0), s, |x, sv| x + sv)
}

fn bw_add_scalar(_ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    ClosureFunction::new("add_scalar", move |g| vec![Some(g.clone())])
}

fn k_mul_scalar(ctx: &OpCtx) -> Tensor {
    raw_mul_scalar(ctx.input(0), ctx.scalar(0))
}

fn bw_mul_scalar(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let s = ctx.scalar(0);
    ClosureFunction::new("mul_scalar", move |g| vec![Some(raw_mul_scalar(g, s))])
}

fn k_pow_scalar(ctx: &OpCtx) -> Tensor {
    let p = ctx.scalar(0);
    float_scalar!("pow", ctx.input(0), p, |x, pv| x.powf(pv))
}

fn bw_pow_scalar(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let p = ctx.scalar(0);
    let saved = SavedTensor::save(ctx.input(0));
    ClosureFunction::new("pow", move |g| {
        let x = saved.unpack();
        let dydx = float_scalar!("pow_bwd", &x, p, |x, pv| pv * x.powf(pv - 1.0));
        vec![Some(raw_mul(g, &dydx))]
    })
}

fn k_clamp(ctx: &OpCtx) -> Tensor {
    let (lo, hi) = (ctx.scalar(0), ctx.scalar(1));
    match ctx.input(0).dtype() {
        DType::F32 => scalar2_map_t::<f32>("clamp", ctx.input(0), lo as f32, hi as f32, |x, a, b| {
            x.clamp(a, b)
        }),
        DType::F64 => scalar2_map_t::<f64>("clamp", ctx.input(0), lo, hi, |x, a, b| x.clamp(a, b)),
        other => torsk_bail!("clamp: unsupported dtype {other}"),
    }
}

fn bw_clamp(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (lo, hi) = (ctx.scalar(0), ctx.scalar(1));
    let saved = SavedTensor::save(ctx.input(0));
    ClosureFunction::new("clamp", move |g| {
        let x = saved.unpack();
        let mask = match x.dtype() {
            DType::F32 => scalar2_map_t::<f32>("clamp_mask", &x, lo as f32, hi as f32, |x, a, b| {
                if x >= a && x <= b {
                    1.0
                } else {
                    0.0
                }
            }),
            DType::F64 => scalar2_map_t::<f64>("clamp_mask", &x, lo, hi, |x, a, b| {
                if x >= a && x <= b {
                    1.0
                } else {
                    0.0
                }
            }),
            other => torsk_bail!("clamp: unsupported dtype {other}"),
        };
        vec![Some(raw_mul(g, &mask))]
    })
}

// ---------------------------------------------------------------------
// Cast
// ---------------------------------------------------------------------

fn k_cast(ctx: &OpCtx) -> Tensor {
    let t = ctx.input(0);
    let dt = ctx.dtype(0);
    if t.dtype() == dt {
        // Fresh impl so the dispatcher can attach a grad_fn without
        // touching the input's own autograd metadata.
        t.detach()
    } else {
        cast_to(t, dt)
    }
}

fn bw_cast(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let dt = ctx.input(0).dtype();
    ClosureFunction::new("cast", move |g| vec![Some(cast_to(g, dt))])
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

/// Second-operand shape: same-shape on even seeds, a row broadcast on odd
/// seeds, so gradcheck covers the broadcast-reduction backward too.
fn rhs_shape(seed: u64) -> &'static [usize] {
    if seed % 2 == 0 {
        &[2, 5]
    } else {
        &[5]
    }
}

fn s_binary(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[2, 5], dt, -1.5, 1.5)?;
    let b = sample_uniform(seed ^ 0xB0B, rhs_shape(seed), dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_div(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[2, 5], dt, -1.5, 1.5)?;
    // Denominator bounded away from zero.
    let b = sample_away_from_zero(seed ^ 0xB0B, rhs_shape(seed), dt, 0.5, 1.5)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_maximum(seed: u64, dt: DType) -> Option<OpSample> {
    // Operands never tie: b = a + (sign * [0.3, 1.3)).
    let a = sample_uniform(seed, &[2, 5], dt, -1.5, 1.5)?;
    let d = sample_away_from_zero(seed ^ 0xD1F, &[2, 5], dt, 0.3, 1.0)?;
    let b = raw_add(&a, &d);
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_eq(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[6], dt, -1.0, 1.0)?;
    let b = sample_uniform(seed ^ 0xB0B, &[6], dt, -1.0, 1.0)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![] })
}

fn s_unary_smooth(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, -2.0, 2.0)?;
    Some(OpSample { inputs: vec![a], params: vec![], grad_inputs: vec![0] })
}

fn s_unary_positive(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, 0.3, 2.5)?;
    Some(OpSample { inputs: vec![a], params: vec![], grad_inputs: vec![0] })
}

fn s_relu(seed: u64, dt: DType) -> Option<OpSample> {
    // Away from the kink at zero.
    let a = sample_away_from_zero(seed, &[3, 4], dt, 0.2, 1.5)?;
    Some(OpSample { inputs: vec![a], params: vec![], grad_inputs: vec![0] })
}

fn s_add_scalar(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, -2.0, 2.0)?;
    Some(OpSample { inputs: vec![a], params: vec![Param::F32(0.7)], grad_inputs: vec![0] })
}

fn s_mul_scalar(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, -2.0, 2.0)?;
    Some(OpSample { inputs: vec![a], params: vec![Param::F32(-1.3)], grad_inputs: vec![0] })
}

fn s_pow_scalar(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, 0.3, 2.0)?;
    Some(OpSample { inputs: vec![a], params: vec![Param::F32(1.7)], grad_inputs: vec![0] })
}

fn s_clamp(seed: u64, dt: DType) -> Option<OpSample> {
    // Inside the interval on even seeds, fully clamped on odd — never on
    // the kinks at the bounds.
    let a = if seed % 2 == 0 {
        sample_uniform(seed, &[3, 4], dt, -0.8, 0.8)?
    } else {
        sample_away_from_zero(seed, &[3, 4], dt, 1.2, 0.6)?
    };
    Some(OpSample {
        inputs: vec![a],
        params: vec![Param::F32(-1.0), Param::F32(1.0)],
        grad_inputs: vec![0],
    })
}

fn s_cast(seed: u64, dt: DType) -> Option<OpSample> {
    let a = sample_uniform(seed, &[3, 4], dt, -2.0, 2.0)?;
    // Always cast *up* to F64: the scalarized gradcheck loss then keeps
    // (at least) the input's precision, so the dtype-tier tolerances
    // apply. F32 covers the converting path (plus the grad cast back to
    // f32); F64 covers the same-dtype detach path.
    Some(OpSample {
        inputs: vec![a],
        params: vec![Param::DType(DType::F64)],
        grad_inputs: vec![0],
    })
}

// ---------------------------------------------------------------------
// Registration
// ---------------------------------------------------------------------

pub(crate) fn register(reg: &mut Registry) {
    // Every entry below except `cast` is index-aligned and dtype-preserving
    // when operands share a shape, so all are `reuse_output` (the
    // dispatcher may let the output steal a dead input's storage).
    reg.add(
        OpDef::new("add", 2, 2, NUMERIC)
            .kernel_all(k_add)
            .backward(bw_add)
            .reuse_output()
            .sample_inputs(s_binary),
    );
    reg.add(
        OpDef::new("sub", 2, 2, NUMERIC)
            .kernel_all(k_sub)
            .backward(bw_sub)
            .reuse_output()
            .sample_inputs(s_binary),
    );
    reg.add(
        OpDef::new("mul", 2, 2, NUMERIC)
            .kernel_all(k_mul)
            .backward(bw_mul)
            .reuse_output()
            .sample_inputs(s_binary),
    );
    reg.add(
        OpDef::new("div", 2, 2, NUMERIC)
            .kernel_all(k_div)
            .backward(bw_div)
            .reuse_output()
            .sample_inputs(s_div),
    );
    reg.add(
        OpDef::new("maximum", 2, 2, NUMERIC)
            .kernel_all(k_maximum)
            .backward(bw_maximum)
            .reuse_output()
            .sample_inputs(s_maximum),
    );
    reg.add(OpDef::new("eq", 2, 2, NUMERIC).kernel_all(k_eq).reuse_output().sample_inputs(s_eq));

    reg.add(
        OpDef::new("neg", 1, 1, NUMERIC)
            .kernel_all(k_neg)
            .backward(bw_neg)
            .reuse_output()
            .sample_inputs(s_unary_smooth),
    );
    reg.add(
        OpDef::new("exp", 1, 1, FLOATS)
            .kernel_all(k_exp)
            .backward(bw_exp)
            .reuse_output()
            .sample_inputs(s_unary_smooth),
    );
    reg.add(
        OpDef::new("log", 1, 1, FLOATS)
            .kernel_all(k_log)
            .backward(bw_log)
            .reuse_output()
            .sample_inputs(s_unary_positive),
    );
    reg.add(
        OpDef::new("sqrt", 1, 1, FLOATS)
            .kernel_all(k_sqrt)
            .backward(bw_sqrt)
            .reuse_output()
            .sample_inputs(s_unary_positive),
    );
    reg.add(
        OpDef::new("relu", 1, 1, FLOATS)
            .kernel_all(k_relu)
            .backward(bw_relu)
            .reuse_output()
            .sample_inputs(s_relu),
    );
    reg.add(
        OpDef::new("sigmoid", 1, 1, FLOATS)
            .kernel_all(k_sigmoid)
            .backward(bw_sigmoid)
            .reuse_output()
            .sample_inputs(s_unary_smooth),
    );
    reg.add(
        OpDef::new("tanh", 1, 1, FLOATS)
            .kernel_all(k_tanh)
            .backward(bw_tanh)
            .reuse_output()
            .sample_inputs(s_unary_smooth),
    );

    reg.add(
        OpDef::new("add_scalar", 1, 1, FLOATS)
            .kernel_all(k_add_scalar)
            .backward(bw_add_scalar)
            .reuse_output()
            .sample_inputs(s_add_scalar),
    );
    reg.add(
        OpDef::new("mul_scalar", 1, 1, FLOATS)
            .kernel_all(k_mul_scalar)
            .backward(bw_mul_scalar)
            .reuse_output()
            .sample_inputs(s_mul_scalar),
    );
    reg.add(
        OpDef::new("pow_scalar", 1, 1, FLOATS)
            .kernel_all(k_pow_scalar)
            .backward(bw_pow_scalar)
            .reuse_output()
            .sample_inputs(s_pow_scalar),
    );
    reg.add(
        OpDef::new("clamp", 1, 1, FLOATS)
            .kernel_all(k_clamp)
            .backward(bw_clamp)
            .reuse_output()
            .sample_inputs(s_clamp),
    );

    // `cast` may change the element size — never steal through it.
    reg.add(
        OpDef::new("cast", 1, 1, NUMERIC)
            .kernel_all(k_cast)
            .backward(bw_cast)
            .sample_inputs(s_cast),
    );
}
