//! Pooling kernel entries for the dispatcher (max / avg / global-avg).

use crate::autograd::{ClosureFunction, Function};
use crate::device;
use crate::kernels::pool::{
    avgpool2d_backward, avgpool2d_forward, maxpool2d_backward, maxpool2d_forward, Pool2dArgs,
};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::{OpCtx, OpDef, OpSample, Param, Registry};

fn pool_args(ctx: &OpCtx) -> Pool2dArgs {
    let input = ctx.input(0);
    torsk_assert!(input.ndim() == 4, "pool2d: input must be NCHW");
    Pool2dArgs {
        batch: input.size(0),
        channels: input.size(1),
        h_in: input.size(2),
        w_in: input.size(3),
        kernel: ctx.usize(0),
        stride: ctx.usize(1),
        padding: ctx.usize(2),
    }
}

/// Max pooling; the argmax index map is stashed for the backward builder.
fn k_maxpool2d(ctx: &OpCtx) -> Tensor {
    let args = pool_args(ctx);
    let input_c = ctx.input(0).contiguous();
    let dev = ctx.device;
    let out = Tensor::empty(&[args.batch, args.channels, args.h_out(), args.w_out()], DType::F32, dev);
    let indices = Tensor::empty(out.shape(), DType::I64, dev);
    {
        let (ip, op, xp) = (input_c.data_ptr(), out.data_ptr(), indices.data_ptr());
        let (in_len, out_len) = (input_c.numel(), out.numel());
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        device::dispatch(dev, "maxpool2d", move || unsafe {
            maxpool2d_forward(
                &args,
                ip.as_slice::<f32>(0, in_len),
                op.as_mut_slice::<f32>(0, out_len),
                xp.as_mut_slice::<i64>(0, out_len),
            );
        });
    }
    ctx.save(indices);
    out
}

fn bw_maxpool2d(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let args = pool_args(ctx);
    let in_shape = ctx.input(0).shape().to_vec();
    let indices = ctx.saved(0);
    ClosureFunction::new("maxpool2d", move |g| {
        let g = g.contiguous();
        let gv = g.to_vec::<f32>();
        let iv = indices.to_vec::<i64>();
        let mut gi = vec![0.0f32; args.batch * args.channels * args.h_in * args.w_in];
        maxpool2d_backward(&args, &gv, &iv, &mut gi);
        vec![Some(Tensor::from_vec(gi, &in_shape).to_device(g.device()))]
    })
}

/// Average pooling.
fn k_avgpool2d(ctx: &OpCtx) -> Tensor {
    let args = pool_args(ctx);
    let input_c = ctx.input(0).contiguous();
    let dev = ctx.device;
    let out = Tensor::empty(&[args.batch, args.channels, args.h_out(), args.w_out()], DType::F32, dev);
    let (ip, op) = (input_c.data_ptr(), out.data_ptr());
    let (in_len, out_len) = (input_c.numel(), out.numel());
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(dev, "avgpool2d", move || unsafe {
        avgpool2d_forward(&args, ip.as_slice::<f32>(0, in_len), op.as_mut_slice::<f32>(0, out_len));
    });
    out
}

fn bw_avgpool2d(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let args = pool_args(ctx);
    let in_shape = ctx.input(0).shape().to_vec();
    ClosureFunction::new("avgpool2d", move |g| {
        let g = g.contiguous();
        let gv = g.to_vec::<f32>();
        let mut gi = vec![0.0f32; args.batch * args.channels * args.h_in * args.w_in];
        avgpool2d_backward(&args, &gv, &mut gi);
        vec![Some(Tensor::from_vec(gi, &in_shape).to_device(g.device()))]
    })
}

/// Composite global average pooling NCHW -> NC.
fn k_global_avgpool(ctx: &OpCtx) -> Tensor {
    let input = ctx.input(0);
    torsk_assert!(input.ndim() == 4, "global_avgpool2d: input must be NCHW");
    let (n, c) = (input.size(0), input.size(1));
    let pooled = crate::ops::mean_dims(input, &[2, 3], false);
    pooled.reshape(&[n, c])
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

fn pool_params() -> Vec<Param> {
    vec![Param::Usize(2), Param::Usize(2), Param::Usize(0)]
}

fn s_maxpool(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None;
    }
    // Distinct values: a tied window max makes the subgradient ambiguous.
    let x = super::sample_distinct(seed, &[1, 2, 4, 4], dt)?;
    Some(OpSample { inputs: vec![x], params: pool_params(), grad_inputs: vec![0] })
}

fn s_avgpool(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None;
    }
    let x = super::sample_uniform(seed, &[1, 2, 4, 4], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![x], params: pool_params(), grad_inputs: vec![0] })
}

fn s_global_avgpool(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None; // composite, but NCHW sample kept canonical at f32
    }
    let x = super::sample_uniform(seed, &[2, 3, 3, 3], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![x], params: vec![], grad_inputs: vec![0] })
}

pub(crate) fn register(reg: &mut Registry) {
    const F32_ONLY: &[DType] = &[DType::F32];
    reg.add(
        OpDef::new("maxpool2d", 1, 1, F32_ONLY)
            .kernel_all(k_maxpool2d)
            .backward(bw_maxpool2d)
            .sample_inputs(s_maxpool),
    );
    reg.add(
        OpDef::new("avgpool2d", 1, 1, F32_ONLY)
            .kernel_all(k_avgpool2d)
            .backward(bw_avgpool2d)
            .sample_inputs(s_avgpool),
    );
    reg.add(
        OpDef::new("global_avgpool2d", 1, 1, super::elementwise::FLOATS)
            .kernel_all(k_global_avgpool)
            .sample_inputs(s_global_avgpool),
    );
}
