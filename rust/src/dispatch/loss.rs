//! Loss kernels for the dispatcher: fused softmax/log-softmax and
//! cross-entropy (f32 hot path), plus MSE/BCE wrappers (any float dtype)
//! that delegate to the single-pass `fused:*` tape kernels in
//! [`super::fuse`].

use crate::autograd::{ClosureFunction, Function, SavedTensor};
use crate::device;
use crate::kernels::softmax::{
    cross_entropy_backward, cross_entropy_forward, log_softmax_backward_rows, log_softmax_rows,
    softmax_backward_rows, softmax_rows,
};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::{OpCtx, OpDef, OpSample, Registry};

fn rows_cols(t: &Tensor) -> (usize, usize) {
    torsk_assert!(t.ndim() >= 1, "softmax: needs at least 1 dim");
    let cols = *t.shape().last().unwrap();
    (t.numel() / cols.max(1), cols)
}

fn k_softmax(ctx: &OpCtx) -> Tensor {
    let input = ctx.input(0);
    let (rows, cols) = rows_cols(input);
    let x = input.contiguous();
    let out = Tensor::empty(x.shape(), DType::F32, x.device());
    let (xp, op) = (x.data_ptr(), out.data_ptr());
    let n = x.numel();
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(x.device(), "softmax", move || unsafe {
        softmax_rows(rows, cols, xp.as_slice::<f32>(0, n), op.as_mut_slice::<f32>(0, n));
    });
    out
}

fn bw_softmax(ctx: &OpCtx, out: &Tensor) -> Box<dyn Function> {
    let (rows, cols) = rows_cols(ctx.input(0));
    let saved_y = SavedTensor::save(out);
    ClosureFunction::new("softmax", move |g| {
        let y = saved_y.unpack().contiguous();
        let g = g.contiguous();
        let yv = y.to_vec::<f32>();
        let gv = g.to_vec::<f32>();
        let mut gi = vec![0.0f32; yv.len()];
        softmax_backward_rows(rows, cols, &yv, &gv, &mut gi);
        vec![Some(Tensor::from_vec(gi, y.shape()).to_device(g.device()))]
    })
}

fn k_log_softmax(ctx: &OpCtx) -> Tensor {
    let input = ctx.input(0);
    let (rows, cols) = rows_cols(input);
    let x = input.contiguous();
    let out = Tensor::empty(x.shape(), DType::F32, x.device());
    let (xp, op) = (x.data_ptr(), out.data_ptr());
    let n = x.numel();
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(x.device(), "log_softmax", move || unsafe {
        log_softmax_rows(rows, cols, xp.as_slice::<f32>(0, n), op.as_mut_slice::<f32>(0, n));
    });
    out
}

fn bw_log_softmax(ctx: &OpCtx, out: &Tensor) -> Box<dyn Function> {
    let (rows, cols) = rows_cols(ctx.input(0));
    let saved_y = SavedTensor::save(out);
    ClosureFunction::new("log_softmax", move |g| {
        let y = saved_y.unpack().contiguous();
        let g = g.contiguous();
        let yv = y.to_vec::<f32>();
        let gv = g.to_vec::<f32>();
        let mut gi = vec![0.0f32; yv.len()];
        log_softmax_backward_rows(rows, cols, &yv, &gv, &mut gi);
        vec![Some(Tensor::from_vec(gi, y.shape()).to_device(g.device()))]
    })
}

/// Fused cross-entropy: logits [N, C] (f32) + i64 targets [N] -> scalar
/// mean loss. Runs synchronously on host data (the scalar is consumed by
/// control flow anyway); log-probs are stashed for the backward builder.
fn k_cross_entropy(ctx: &OpCtx) -> Tensor {
    let (logits, targets) = (ctx.input(0), ctx.input(1));
    torsk_assert!(logits.ndim() == 2, "cross_entropy: logits must be [N, C]");
    torsk_assert!(targets.dtype() == DType::I64, "cross_entropy: targets must be i64");
    torsk_assert!(
        targets.numel() == logits.size(0),
        "cross_entropy: {} targets for {} rows",
        targets.numel(),
        logits.size(0)
    );
    let (rows, cols) = (logits.size(0), logits.size(1));
    let xv = logits.contiguous().to_vec::<f32>();
    let tv = targets.to_vec::<i64>();
    let mut log_probs = vec![0.0f32; rows * cols];
    let loss = cross_entropy_forward(rows, cols, &xv, &tv, &mut log_probs);
    // Stash log-probs on host for the backward builder.
    ctx.save(Tensor::from_vec(log_probs, &[rows, cols]).to_cpu());
    Tensor::scalar(loss).to_device(logits.device())
}

fn bw_cross_entropy(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (rows, cols) = (ctx.input(0).size(0), ctx.input(0).size(1));
    let shape = ctx.input(0).shape().to_vec();
    let dev = ctx.input(0).device();
    let log_probs = ctx.saved(0);
    let tv = ctx.input(1).to_vec::<i64>();
    ClosureFunction::new("cross_entropy", move |g| {
        let gs = g.item();
        let lp = log_probs.to_vec::<f32>();
        let mut gi = vec![0.0f32; rows * cols];
        cross_entropy_backward(rows, cols, &lp, &tv, gs, &mut gi);
        // Targets get no gradient (second input).
        vec![Some(Tensor::from_vec(gi, &shape).to_device(dev)), None]
    })
}

/// Mean-squared-error loss (mean reduction), any float dtype. Delegates
/// to the single-pass `fused:mse` tape — the inner dispatched call records
/// the one fused autograd node, so this wrapper registers no backward.
/// (The unfused `mean(mul(sub(p, t)))` composition stays available through
/// the primitive ops; `tests/fused_parity.rs` pins both paths bit-equal.)
fn k_mse_loss(ctx: &OpCtx) -> Tensor {
    let (pred, target) = (ctx.input(0), ctx.input(1));
    torsk_assert!(pred.shape() == target.shape(), "mse_loss: shape mismatch");
    if super::capture::tracing_active() {
        // Under graph capture, trace the primitive chain instead so the
        // graph optimizer re-fuses it; `tests/capture_parity.rs` pins the
        // auto-fused tape bitwise against `fused:mse`.
        let d = crate::ops::sub(pred, target);
        return crate::ops::mean(&crate::ops::mul(&d, &d));
    }
    super::call("fused:mse", &[pred, target], &[])
}

/// Binary cross-entropy on probabilities in (0,1), mean reduction.
/// Delegates to the single-pass `fused:bce` tape (clamp → logs → blend →
/// chunked mean → neg in one traversal instead of eight).
fn k_bce_loss(ctx: &OpCtx) -> Tensor {
    let (pred, target) = (ctx.input(0), ctx.input(1));
    torsk_assert!(pred.shape() == target.shape(), "bce_loss: shape mismatch");
    if super::capture::tracing_active() {
        // Primitive composition under capture (same chain the fused tape
        // encodes); the optimizer folds it back into one map-reduce region.
        use crate::ops;
        use super::fuse::BCE_EPS;
        let pc = ops::clamp(pred, BCE_EPS, 1.0 - BCE_EPS);
        let pos = ops::mul(target, &ops::log(&pc));
        let neg = ops::mul(
            &ops::add_scalar(&ops::neg(target), 1.0),
            &ops::log(&ops::add_scalar(&ops::neg(&pc), 1.0)),
        );
        return ops::neg(&ops::mean(&ops::add(&pos, &neg)));
    }
    super::call("fused:bce", &[pred, target], &[])
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

fn rows_sample(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None; // f32-only row kernels
    }
    let x = super::sample_uniform(seed, &[3, 5], dt, -2.0, 2.0)?;
    Some(OpSample { inputs: vec![x], params: vec![], grad_inputs: vec![0] })
}

fn s_cross_entropy(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None;
    }
    let logits = super::sample_uniform(seed, &[4, 3], dt, -2.0, 2.0)?;
    let targets = super::sample_indices(seed ^ 0x7, &[4], 3);
    Some(OpSample { inputs: vec![logits, targets], params: vec![], grad_inputs: vec![0] })
}

pub(crate) fn register(reg: &mut Registry) {
    // The wrappers reuse the fused entries' generators, so wrapper and
    // fused op always gradcheck identical inputs.
    use super::fuse::{s_bce, s_mse};
    const F32_ONLY: &[DType] = &[DType::F32];
    reg.add(
        OpDef::new("softmax", 1, 1, F32_ONLY)
            .kernel_all(k_softmax)
            .backward(bw_softmax)
            .sample_inputs(rows_sample),
    );
    reg.add(
        OpDef::new("log_softmax", 1, 1, F32_ONLY)
            .kernel_all(k_log_softmax)
            .backward(bw_log_softmax)
            .sample_inputs(rows_sample),
    );
    reg.add(
        OpDef::new("cross_entropy", 2, 2, F32_ONLY)
            .kernel_all(k_cross_entropy)
            .backward(bw_cross_entropy)
            .sample_inputs(s_cross_entropy),
    );
    reg.add(
        OpDef::new("mse_loss", 2, 2, super::elementwise::FLOATS)
            .kernel_all(k_mse_loss)
            .sample_inputs(s_mse),
    );
    reg.add(
        OpDef::new("bce_loss", 2, 2, super::elementwise::FLOATS)
            .kernel_all(k_bce_loss)
            .sample_inputs(s_bce),
    );
}
