//! `dispatch::fuse` — fused elementwise pipelines (§5: keeping eager ops
//! memory-bandwidth-efficient).
//!
//! A fused op is a **micro-op tape**: a tiny stack program (load input /
//! push constant / unary / binary micro-ops) composed and constant-folded
//! once, at registration time, and then interpreted *per element* inside a
//! single TensorIter-style pass. A chain like `sigmoid → clamp → log →
//! mul → add → mean → neg` that used to run as 7 separately dispatched
//! passes — re-touching the same buffers every time — becomes ONE parallel
//! loop that reads each input element once and writes (or reduces into)
//! one output.
//!
//! Design rules:
//!
//! * **Bit-for-bit parity with the unfused composition.** Every tape
//!   mirrors the exact per-element expression the composed `ops::*` chain
//!   evaluates (same operations, same operand pairs; reordering only where
//!   IEEE addition/multiplication commute bitwise), and the reduction
//!   drivers reuse the fixed [`REDUCE_CHUNK`] boundaries of
//!   [`super::iter::run_reduce_flat`]. `tests/fused_parity.rs` pins
//!   fused == composed at `PALLAS_NUM_THREADS` = 1/2/8.
//! * **Parallel + deterministic.** Both drivers split on
//!   [`crate::kernels::parallel_for`] with the standard
//!   [`SERIAL_GRAIN`]; map-reduce uses fixed-width chunks combined in
//!   chunk order, so thread count never changes a result bit.
//! * **One autograd node.** Fused ops register a [`BackwardFn`] whose
//!   gradients are themselves tapes (plus the deterministic
//!   `sum_to_shape` reducers), so the graph records a single fused node
//!   instead of the 4–8 nodes of the composite chain.
//!
//! Registered fused kernels: `fused:gelu`, `fused:mse`, `fused:bce`,
//! `fused:sigmoid_bce`, `fused:ln_tail` (the layer-norm scale/shift
//! tail), and the in-place optimizer updates `fused:adam_step` /
//! `fused:sgd_step` (one pass over each param + state buffer). The
//! composite wrappers in `dispatch/loss.rs`, `dispatch/norm.rs` and
//! `optim/` route through these; see the "Fusion" section of the
//! [`crate::dispatch`] module docs for how to add one.

use once_cell::sync::Lazy;

use crate::autograd::{ClosureFunction, Function, SavedTensor};
use crate::device;
use crate::kernels::{parallel_for, SERIAL_GRAIN};
use crate::tensor::storage::SendPtr;
use crate::tensor::{DType, FloatElement, Tensor};
use crate::torsk_assert;

use super::elementwise::{cast_to, promote_pair, FLOATS};
use super::iter::REDUCE_CHUNK;
use super::reduce::sum_to_shape;
use super::{same_device, OpCtx, OpDef, OpSample, Param, Registry};

mod simd;

// ---------------------------------------------------------------------
// Micro-ops
// ---------------------------------------------------------------------

/// Unary micro-ops (pop x, push f(x)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UnaryK {
    /// `-x`
    Neg,
    /// `exp(x)`
    Exp,
    /// `ln(x)`
    Ln,
    /// `sqrt(x)`
    Sqrt,
    /// `1/x` (evaluated as `ONE / x`, matching the composed `1.0 / y`).
    Recip,
    /// `tanh(x)`
    Tanh,
}

/// Binary micro-ops (pop y, then x, push f(x, y)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BinaryK {
    Add,
    Sub,
    Mul,
    Div,
    /// `max(x, y)`
    Max,
    /// `min(x, y)`
    Min,
    /// `1` if `x >= y` else `0` (clamp-mask building block).
    Ge,
    /// `1` if `x <= y` else `0`.
    Le,
}

/// One instruction of a fused per-element program.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MicroOp {
    /// Push input operand `i`'s element.
    Load(u8),
    /// Push a constant (narrowed to the runtime dtype).
    Const(f64),
    /// Duplicate the top of stack.
    Dup,
    /// Swap the top two stack slots.
    Swap,
    Un(UnaryK),
    Bin(BinaryK),
}

/// Interpreter stack depth — asserted at build time, so `eval` can use a
/// fixed array with no bounds checks beyond the array itself.
pub(crate) const MAX_STACK: usize = 8;
/// Maximum tape operands (fused kernels are small by design).
pub(crate) const MAX_ARGS: usize = 6;

#[inline(always)]
fn apply_un<T: FloatElement>(k: UnaryK, x: T) -> T {
    match k {
        UnaryK::Neg => -x,
        UnaryK::Exp => x.fexp(),
        UnaryK::Ln => x.fln(),
        UnaryK::Sqrt => x.fsqrt(),
        UnaryK::Recip => T::ONE / x,
        UnaryK::Tanh => x.ftanh(),
    }
}

#[inline(always)]
fn apply_bin<T: FloatElement>(k: BinaryK, x: T, y: T) -> T {
    match k {
        BinaryK::Add => x + y,
        BinaryK::Sub => x - y,
        BinaryK::Mul => x * y,
        BinaryK::Div => x / y,
        BinaryK::Max => x.fmax(y),
        BinaryK::Min => x.fmin(y),
        BinaryK::Ge => {
            if x >= y {
                T::ONE
            } else {
                T::ZERO
            }
        }
        BinaryK::Le => {
            if x <= y {
                T::ONE
            } else {
                T::ZERO
            }
        }
    }
}

// ---------------------------------------------------------------------
// Tape + builder (with registration-time constant folding)
// ---------------------------------------------------------------------

/// A compiled fused per-element program.
#[derive(Clone, Debug)]
pub struct Tape {
    ops: Vec<MicroOp>,
    n_inputs: usize,
}

impl Tape {
    /// Start building a tape over `n_inputs` operands.
    pub fn build(n_inputs: usize) -> TapeBuilder {
        torsk_assert!(n_inputs <= MAX_ARGS, "fuse: at most {MAX_ARGS} tape inputs");
        TapeBuilder { ops: Vec::new(), n_inputs, depth: 0, max_depth: 0 }
    }

    /// Number of micro-ops (after constant folding).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the tape has no instructions.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Evaluate the tape for one element. `args` must hold `n_inputs`
    /// values.
    #[inline(always)]
    pub fn eval<T: FloatElement>(&self, args: &[T]) -> T {
        let mut stack = [T::ZERO; MAX_STACK];
        let mut sp = 0usize;
        for op in &self.ops {
            match *op {
                MicroOp::Load(i) => {
                    stack[sp] = args[i as usize];
                    sp += 1;
                }
                MicroOp::Const(c) => {
                    stack[sp] = T::from_f64(c);
                    sp += 1;
                }
                MicroOp::Dup => {
                    stack[sp] = stack[sp - 1];
                    sp += 1;
                }
                MicroOp::Swap => stack.swap(sp - 1, sp - 2),
                MicroOp::Un(k) => stack[sp - 1] = apply_un(k, stack[sp - 1]),
                MicroOp::Bin(k) => {
                    sp -= 1;
                    stack[sp - 1] = apply_bin(k, stack[sp - 1], stack[sp]);
                }
            }
        }
        debug_assert_eq!(sp, 1);
        stack[0]
    }

    /// Construct a tape from raw micro-ops, verifying interpreter bounds
    /// once here (the capture auto-fuser emits ops without going through
    /// [`TapeBuilder`]'s incremental tracking). Panics on an unbalanced or
    /// out-of-range program — the same checks [`Tape::verify`] runs.
    pub(crate) fn from_ops(ops: Vec<MicroOp>, n_inputs: usize) -> Tape {
        let t = Tape { ops, n_inputs };
        t.verify();
        t
    }

    /// Verify interpreter bounds on a finished tape: every `Load` in
    /// range, stack depth within [`MAX_STACK`] and never underflowing,
    /// exactly one result left. [`TapeBuilder`] enforces all of this
    /// during construction; tapes composed by splicing `ops` directly
    /// (see `SBCE_DX`) or emitted by the capture auto-fuser run this
    /// ONCE at assembly time — per-call dispatch only re-checks the
    /// cheap operand-extent bounds (see `verify_plan`), not the whole
    /// program, since tapes are immutable after construction.
    pub fn verify(&self) {
        let mut depth = 0usize;
        for op in &self.ops {
            match *op {
                MicroOp::Load(i) => {
                    torsk_assert!(
                        (i as usize) < self.n_inputs,
                        "fuse: tape Load({i}) out of range for {} inputs",
                        self.n_inputs
                    );
                    depth += 1;
                }
                MicroOp::Const(_) => depth += 1,
                MicroOp::Dup => {
                    torsk_assert!(depth >= 1, "fuse: Dup on empty stack");
                    depth += 1;
                }
                MicroOp::Swap => torsk_assert!(depth >= 2, "fuse: Swap on short stack"),
                MicroOp::Un(_) => torsk_assert!(depth >= 1, "fuse: unary on empty stack"),
                MicroOp::Bin(_) => {
                    torsk_assert!(depth >= 2, "fuse: binary on short stack");
                    depth -= 1;
                }
            }
            torsk_assert!(depth <= MAX_STACK, "fuse: tape exceeds MAX_STACK at {op:?}");
        }
        torsk_assert!(depth == 1, "fuse: tape leaves {depth} values on the stack");
    }
}

/// Builder accumulating micro-ops with stack-depth tracking and
/// constant folding (const-only subexpressions collapse at build time;
/// folding happens in f64 and narrows at eval exactly like a written
/// constant would).
pub struct TapeBuilder {
    ops: Vec<MicroOp>,
    n_inputs: usize,
    depth: usize,
    max_depth: usize,
}

impl TapeBuilder {
    fn push(&mut self, op: MicroOp) {
        match op {
            MicroOp::Load(_) | MicroOp::Const(_) | MicroOp::Dup => {
                self.depth += 1;
                self.max_depth = self.max_depth.max(self.depth);
                torsk_assert!(self.max_depth <= MAX_STACK, "fuse: tape exceeds MAX_STACK");
            }
            MicroOp::Swap => torsk_assert!(self.depth >= 2, "fuse: swap on short stack"),
            MicroOp::Un(k) => {
                torsk_assert!(self.depth >= 1, "fuse: unary on empty stack");
                // Constant-fold `Un(Const)`.
                if let Some(MicroOp::Const(c)) = self.ops.last().copied() {
                    *self.ops.last_mut().unwrap() = MicroOp::Const(apply_un::<f64>(k, c));
                    return;
                }
            }
            MicroOp::Bin(k) => {
                torsk_assert!(self.depth >= 2, "fuse: binary on short stack");
                self.depth -= 1;
                // Constant-fold `Bin(Const, Const)`.
                let n = self.ops.len();
                if n >= 2 {
                    let (a, b) = (self.ops[n - 2], self.ops[n - 1]);
                    if let (MicroOp::Const(x), MicroOp::Const(y)) = (a, b) {
                        self.ops.truncate(n - 2);
                        self.ops.push(MicroOp::Const(apply_bin::<f64>(k, x, y)));
                        return;
                    }
                }
            }
        }
        if let MicroOp::Load(i) = op {
            torsk_assert!((i as usize) < self.n_inputs, "fuse: load {i} out of range");
        }
        self.ops.push(op);
    }

    pub fn load(mut self, i: usize) -> Self {
        self.push(MicroOp::Load(i as u8));
        self
    }
    pub fn c(mut self, v: f64) -> Self {
        self.push(MicroOp::Const(v));
        self
    }
    pub fn dup(mut self) -> Self {
        self.push(MicroOp::Dup);
        self
    }
    pub fn swap(mut self) -> Self {
        self.push(MicroOp::Swap);
        self
    }
    pub fn un(mut self, k: UnaryK) -> Self {
        self.push(MicroOp::Un(k));
        self
    }
    pub fn bin(mut self, k: BinaryK) -> Self {
        self.push(MicroOp::Bin(k));
        self
    }
    pub fn neg(self) -> Self {
        self.un(UnaryK::Neg)
    }
    pub fn exp(self) -> Self {
        self.un(UnaryK::Exp)
    }
    pub fn ln(self) -> Self {
        self.un(UnaryK::Ln)
    }
    pub fn recip(self) -> Self {
        self.un(UnaryK::Recip)
    }
    pub fn tanh(self) -> Self {
        self.un(UnaryK::Tanh)
    }
    pub fn add(self) -> Self {
        self.bin(BinaryK::Add)
    }
    pub fn sub(self) -> Self {
        self.bin(BinaryK::Sub)
    }
    pub fn mul(self) -> Self {
        self.bin(BinaryK::Mul)
    }
    pub fn max_(self) -> Self {
        self.bin(BinaryK::Max)
    }
    pub fn min_(self) -> Self {
        self.bin(BinaryK::Min)
    }
    pub fn ge(self) -> Self {
        self.bin(BinaryK::Ge)
    }
    pub fn le(self) -> Self {
        self.bin(BinaryK::Le)
    }

    /// Finish; the tape must leave exactly one value on the stack.
    pub fn done(self) -> Tape {
        torsk_assert!(self.depth == 1, "fuse: tape leaves {} values on the stack", self.depth);
        Tape { ops: self.ops, n_inputs: self.n_inputs }
    }
}

// ---------------------------------------------------------------------
// Operand access + drivers
// ---------------------------------------------------------------------

/// How a tape operand is indexed for output element `i` of a pass whose
/// trailing dimension is `inner` wide.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Access {
    /// Same shape as the output: element `i`.
    Flat,
    /// One value per row (layer-norm statistics `[.., 1]`): `i / inner`.
    Row(usize),
    /// One value per column (affine `[d]`): `i % inner`.
    Col(usize),
    /// A 0-dim scalar (loss seeds): element `0`.
    Scalar,
}

#[inline(always)]
fn src_index(acc: Access, i: usize) -> usize {
    match acc {
        Access::Flat => i,
        Access::Row(inner) => i / inner,
        Access::Col(inner) => i % inner,
        Access::Scalar => 0,
    }
}

fn run_map_t<T: FloatElement>(tape: &Tape, srcs: &[(SendPtr, Access)], op: SendPtr, n: usize) {
    let nargs = srcs.len();
    // SAFETY: plan_srcs sized every source for its Access pattern against
    // n and the caller keeps the tensors alive across this call; chunks
    // write disjoint ranges [s, e) of the n-element output.
    parallel_for(n, SERIAL_GRAIN, |s, e| unsafe {
        // Vector fast path: the same instruction sequence per element,
        // over lane blocks (see `fuse/simd.rs` for the bitwise-parity
        // argument); declines when no vector unit is active.
        if simd::map_range::<T>(tape, srcs, op, s, e) {
            return;
        }
        let mut args = [T::ZERO; MAX_ARGS];
        let po = op.ptr() as *mut T;
        for i in s..e {
            for (k, (p, acc)) in srcs.iter().enumerate() {
                // Raw reads: with output-stealing the out buffer may alias
                // a Flat input; every arg is read before out[i] is written,
                // and index-aligned Flat access makes that sound.
                args[k] = std::ptr::read((p.ptr() as *const T).add(src_index(*acc, i)));
            }
            std::ptr::write(po.add(i), tape.eval(&args[..nargs]));
        }
    });
}

fn run_map_sum_t<T: FloatElement>(tape: &Tape, srcs: &[(SendPtr, Access)], n: usize) -> T {
    let nargs = srcs.len();
    if n == 0 {
        return T::ZERO;
    }
    // SAFETY: read-only gathers; plan_srcs sized every source for its
    // Access pattern against n, and src_index stays within that extent.
    let gather = |i: usize, args: &mut [T; MAX_ARGS]| unsafe {
        for (k, (p, acc)) in srcs.iter().enumerate() {
            args[k] = std::ptr::read((p.ptr() as *const T).add(src_index(*acc, i)));
        }
    };
    // Sum one chunk `[s, e)` from zero in ascending index order. The
    // vector path evaluates the identical addition chain over lane
    // blocks (see `fuse/simd.rs`) and declines when no vector unit is
    // active, so both branches produce the same bits.
    // SAFETY: read-only gathers within the planned extents, as in
    // `gather` above (the vector path inherits the same contract).
    let chunk_sum = |s: usize, e: usize| unsafe {
        if let Some(v) = simd::sum_range::<T>(tape, srcs, s, e) {
            return v;
        }
        let mut args = [T::ZERO; MAX_ARGS];
        let mut acc = T::ZERO;
        for i in s..e {
            gather(i, &mut args);
            acc = acc + tape.eval(&args[..nargs]);
        }
        acc
    };
    let nchunks = n.div_ceil(REDUCE_CHUNK);
    if nchunks == 1 {
        return chunk_sum(0, n);
    }
    let mut partials: Vec<T> = vec![T::ZERO; nchunks];
    let pp = SendPtr::new(partials.as_mut_ptr() as *mut u8);
    // SAFETY: `partials` outlives the blocking parallel_for; each chunk c
    // writes only partials[c], and source reads are bounds-safe as in
    // `gather` above.
    parallel_for(nchunks, 1, |c0, c1| unsafe {
        for c in c0..c1 {
            let s = c * REDUCE_CHUNK;
            let e = ((c + 1) * REDUCE_CHUNK).min(n);
            let acc = chunk_sum(s, e);
            // SAFETY: each chunk index written by exactly one task.
            std::ptr::write((pp.ptr() as *mut T).add(c), acc);
        }
    });
    let mut acc = partials[0];
    for p in &partials[1..] {
        acc = acc + *p;
    }
    acc
}

/// Materialize tape operands: contiguous handles (kept alive for queued
/// device closures) plus their pointers with the declared access pattern.
fn plan_srcs(inputs: &[(&Tensor, Access)]) -> (Vec<Tensor>, Vec<(SendPtr, Access)>) {
    let keep: Vec<Tensor> = inputs.iter().map(|(t, _)| t.contiguous()).collect();
    let srcs: Vec<(SendPtr, Access)> =
        keep.iter().zip(inputs.iter()).map(|(t, (_, a))| (t.data_ptr(), *a)).collect();
    (keep, srcs)
}

/// Sanitizer: verify that every operand covers the largest source index
/// its [`Access`] pattern can generate over an `n`-element pass (the
/// bound `src_index` relies on). Tape program bounds are NOT re-checked
/// here: tapes are immutable and verified once at build/capture time
/// ([`Tape::from_ops`] / the `SBCE_DX` splice), so per-call work stays
/// proportional to the operand count, not the program length.
#[cfg(feature = "debug-checks")]
fn verify_plan(name: &str, tape: &Tape, keep: &[Tensor], srcs: &[(SendPtr, Access)], n: usize) {
    let _ = tape;
    if n == 0 {
        return;
    }
    for (k, (t, (_, acc))) in keep.iter().zip(srcs.iter()).enumerate() {
        let max_index = match *acc {
            Access::Flat => n - 1,
            Access::Row(inner) => {
                torsk_assert!(inner > 0, "{name}: Row access with inner = 0");
                (n - 1) / inner
            }
            Access::Col(inner) => {
                torsk_assert!(inner > 0, "{name}: Col access with inner = 0");
                (n - 1).min(inner - 1)
            }
            Access::Scalar => 0,
        };
        crate::debug_checks::verify_access_extent(name, k, t.numel(), max_index);
    }
}

/// Run `tape` as one elementwise pass producing a tensor of `out_shape`.
/// All operands must share one float dtype and one device; broadcasts are
/// expressed via [`Access`], not materialized.
pub(crate) fn run_map(
    name: &'static str,
    tape: &Tape,
    inputs: &[(&Tensor, Access)],
    out_shape: &[usize],
) -> Tensor {
    torsk_assert!(tape.n_inputs == inputs.len(), "{name}: tape wants {} inputs", tape.n_inputs);
    let tensors: Vec<&Tensor> = inputs.iter().map(|(t, _)| *t).collect();
    let dev = same_device(name, &tensors);
    let dt = tensors[0].dtype();
    torsk_assert!(
        tensors.iter().all(|t| t.dtype() == dt) && dt.is_float(),
        "{name}: fused tapes need one float dtype"
    );
    let (keep, srcs) = plan_srcs(inputs);
    let out = Tensor::empty(out_shape, dt, dev);
    let n = out.numel();
    if n == 0 {
        return out;
    }
    #[cfg(feature = "debug-checks")]
    verify_plan(name, tape, &keep, &srcs, n);
    let op = out.data_ptr();
    let tape = tape.clone();
    device::dispatch(dev, name, move || {
        match dt {
            DType::F32 => run_map_t::<f32>(&tape, &srcs, op, n),
            DType::F64 => run_map_t::<f64>(&tape, &srcs, op, n),
            DType::I64 => unreachable!("fused tapes are float-only"),
        }
        drop(keep);
    });
    out
}

/// Run `tape` as one map-reduce pass: per-element values are summed with
/// the fixed [`REDUCE_CHUNK`] partial boundaries of the unfused reduction
/// driver (bit-identical at any thread count), then `finish` maps the
/// total (mean scaling, final negation) before the 0-dim result is
/// written.
pub(crate) fn run_map_sum(
    name: &'static str,
    tape: &Tape,
    inputs: &[(&Tensor, Access)],
    n: usize,
    finish: fn(f64, f64) -> f64,
    finish_arg: f64,
) -> Tensor {
    torsk_assert!(tape.n_inputs == inputs.len(), "{name}: tape wants {} inputs", tape.n_inputs);
    let tensors: Vec<&Tensor> = inputs.iter().map(|(t, _)| *t).collect();
    let dev = same_device(name, &tensors);
    let dt = tensors[0].dtype();
    torsk_assert!(
        tensors.iter().all(|t| t.dtype() == dt) && dt.is_float(),
        "{name}: fused tapes need one float dtype"
    );
    let (keep, srcs) = plan_srcs(inputs);
    #[cfg(feature = "debug-checks")]
    verify_plan(name, tape, &keep, &srcs, n);
    let out = Tensor::empty(&[], dt, dev);
    let op = out.data_ptr();
    let tape = tape.clone();
    device::dispatch(dev, name, move || {
        match dt {
            DType::F32 => {
                let total = run_map_sum_t::<f32>(&tape, &srcs, n);
                // `finish` runs at the tensor dtype: its f64 args/result
                // round-trip exactly for f32 values and scale factors are
                // narrowed first, mirroring the composed scalar kernels.
                let v = finish(total as f64, finish_arg) as f32;
                // SAFETY: `op` is the one-element output's storage; it
                // stays valid for this queued kernel per the stream FIFO
                // allocator discipline.
                unsafe { *(op.ptr() as *mut f32) = v };
            }
            DType::F64 => {
                let total = run_map_sum_t::<f64>(&tape, &srcs, n);
                let v = finish(total, finish_arg);
                // SAFETY: as in the F32 arm.
                unsafe { *(op.ptr() as *mut f64) = v };
            }
            DType::I64 => unreachable!("fused tapes are float-only"),
        }
        drop(keep);
    });
    out
}

// ---------------------------------------------------------------------
// finish() combinators for map-reduce kernels
// ---------------------------------------------------------------------

/// `total * rn` — matches the composed `mean = sum * (1/n)` scalar kernel
/// exactly: for F32 the f64 product of two exactly-widened f32s rounds to
/// the same f32 the composed `x * sv` kernel computes.
pub(crate) fn finish_mean(total: f64, rn: f64) -> f64 {
    scale_like_dtype(total, rn)
}

/// `-(total * rn)` — BCE's trailing `neg(mean(..))`.
pub(crate) fn finish_neg_mean(total: f64, rn: f64) -> f64 {
    -scale_like_dtype(total, rn)
}

/// One multiply in f64. For F32 callers, both operands are exact f32
/// widenings, so one f64 multiply + one narrow equals the f32 multiply
/// (a double-rounding-free product), matching the unfused kernel bitwise.
fn scale_like_dtype(total: f64, rn: f64) -> f64 {
    total * rn
}

/// The mean factor as the runtime dtype would see it: F32 kernels narrow
/// `1/n` to f32 before multiplying (see `float_scalar!` in elementwise).
pub(crate) fn mean_factor(n: usize, dt: DType) -> f64 {
    let rn = 1.0 / n.max(1) as f64;
    match dt {
        DType::F32 => rn as f32 as f64,
        _ => rn,
    }
}

// ---------------------------------------------------------------------
// Tape constants + shared subsequences
// ---------------------------------------------------------------------

/// GELU (tanh approximation) constants; f32 literals so the fused tape and
/// a composed `mul_scalar` chain see identical values at every dtype.
pub(crate) const GELU_A: f32 = 0.044_715;
pub(crate) const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
/// BCE probability clamp (the composite used `clamp(p, 1e-7, 1 - 1e-7)`).
pub(crate) const BCE_EPS: f32 = 1e-7;

fn bce_hi() -> f64 {
    (1.0f32 - BCE_EPS) as f64
}

/// Append `clamp(top, eps, hi)` — `max` then `min`, which equals Rust's
/// `f32::clamp` for `lo <= hi` and non-NaN inputs (the composed kernel).
fn clamp01(b: TapeBuilder) -> TapeBuilder {
    b.c(BCE_EPS as f64).max_().c(bce_hi()).min_()
}

/// Append `sigmoid(top)` exactly as the composed kernel computes it:
/// `1.0 / (1.0 + exp(-x))`.
fn sigmoid_seq(b: TapeBuilder) -> TapeBuilder {
    b.neg().exp().c(1.0).add().recip()
}

/// Push `p` for the plain-BCE tapes: a raw `Load(0)`.
fn load_p(b: TapeBuilder) -> TapeBuilder {
    b.load(0)
}

/// Push `p = sigmoid(x)` for the with-logits tapes.
fn load_sigmoid(b: TapeBuilder) -> TapeBuilder {
    sigmoid_seq(b.load(0))
}

// ---------------------------------------------------------------------
// fused:gelu
// ---------------------------------------------------------------------

/// `u = C*(x + A*x^3)` sub-sequence; pushes `tanh(u)`.
fn gelu_t_seq(b: TapeBuilder) -> TapeBuilder {
    // x*x -> x^3 -> A*x^3 -> + x -> *C -> tanh
    b.load(0)
        .load(0)
        .mul()
        .load(0)
        .mul()
        .c(GELU_A as f64)
        .mul()
        .load(0)
        .add()
        .c(GELU_C as f64)
        .mul()
        .tanh()
}

static GELU_FWD: Lazy<Tape> = Lazy::new(|| {
    // y = (0.5*x) * (tanh(u) + 1)
    gelu_t_seq(Tape::build(1)).c(1.0).add().load(0).c(0.5).mul().mul().done()
});

static GELU_BWD: Lazy<Tape> = Lazy::new(|| {
    // inputs [x, g]:
    // dy/dx = 0.5*(1+t) + ((((0.5*x)*(1-t^2))*C) * (1 + 3A*x^2))
    // t = tanh(u) is evaluated once and duplicated — bit-identical to
    // recomputing it, at half the transcendental cost.
    let b = gelu_t_seq(Tape::build(2)).dup(); // [t, t]
    let b = b.c(1.0).add().c(0.5).mul().swap(); // [term1, t]
    let b = b.dup().mul().neg().c(1.0).add(); // [term1, 1-t^2]
    let b = b.load(0).mul().c(0.5).mul().c(GELU_C as f64).mul(); // [term1, p]
    let b = b.load(0).dup().mul().c(3.0 * GELU_A as f64).mul().c(1.0).add(); // [term1, p, q]
    b.mul().add().load(1).mul().done() // g * dy/dx
});

fn k_gelu(ctx: &OpCtx) -> Tensor {
    let x = ctx.input(0);
    run_map("fused:gelu", &GELU_FWD, &[(x, Access::Flat)], x.shape())
}

fn bw_gelu(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let saved = SavedTensor::save(ctx.input(0));
    ClosureFunction::new("fused:gelu", move |g| {
        let x = saved.unpack();
        let srcs = [(&x, Access::Flat), (g, Access::Flat)];
        let gx = run_map("fused:gelu_bwd", &GELU_BWD, &srcs, x.shape());
        vec![Some(gx)]
    })
}

// ---------------------------------------------------------------------
// fused:mse
// ---------------------------------------------------------------------

static MSE_FWD: Lazy<Tape> =
    Lazy::new(|| Tape::build(2).load(0).load(1).sub().dup().mul().done());

static MSE_BWD_DP: Lazy<Tape> = Lazy::new(|| {
    // inputs [p, t, G] where G is the pre-scaled seed g*(1/n) (rn varies
    // per call, so it cannot be baked into the tape as a constant).
    // dp = 2 * (G * (p - t))   == (G*d) + (G*d) of the unfused graph.
    Tape::build(3).load(2).load(0).load(1).sub().mul().c(2.0).mul().done()
});

static MSE_BWD_DT: Lazy<Tape> =
    Lazy::new(|| Tape::build(3).load(2).load(0).load(1).sub().mul().c(2.0).mul().neg().done());

fn k_fused_mse(ctx: &OpCtx) -> Tensor {
    let (pred, target) = (ctx.input(0), ctx.input(1));
    torsk_assert!(pred.shape() == target.shape(), "fused:mse: shape mismatch");
    let (pa, pb) = promote_pair(pred, target);
    let n = pa.numel();
    run_map_sum(
        "fused:mse",
        &MSE_FWD,
        &[(&pa, Access::Flat), (&pb, Access::Flat)],
        n,
        finish_mean,
        mean_factor(n, pa.dtype()),
    )
}

fn bw_fused_mse(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (da, db) = (ctx.input(0).dtype(), ctx.input(1).dtype());
    let (pa, pb) = promote_pair(ctx.input(0), ctx.input(1));
    let shape = pa.shape().to_vec();
    let rn = mean_factor(pa.numel(), pa.dtype());
    let (va, vb) = (SavedTensor::save(&pa), SavedTensor::save(&pb));
    ClosureFunction::new("fused:mse", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        // G = g * (1/n), exactly the composed mean-backward scalar.
        let gs = super::call_owned("mul_scalar", vec![g.clone()], &[Param::F64(rn)]);
        let dp = run_map(
            "fused:mse_bwd",
            &MSE_BWD_DP,
            &[(&a, Access::Flat), (&b, Access::Flat), (&gs, Access::Scalar)],
            &shape,
        );
        let dt = run_map(
            "fused:mse_bwd",
            &MSE_BWD_DT,
            &[(&a, Access::Flat), (&b, Access::Flat), (&gs, Access::Scalar)],
            &shape,
        );
        vec![Some(cast_to(&dp, da)), Some(cast_to(&dt, db))]
    })
}

// ---------------------------------------------------------------------
// fused:bce / fused:sigmoid_bce
// ---------------------------------------------------------------------

/// Forward per-element BCE term, mirroring the composite chain
/// `pc = clamp(p); total = t*ln(pc) + (1-t)*ln(1-pc)` operation for
/// operation (`1-v` is evaluated as `(-v)+1`, as the composed
/// `add_scalar(neg(v), 1)` does). `load` selects raw `p` or `sigmoid(x)`.
fn bce_total_tape(load: fn(TapeBuilder) -> TapeBuilder, n_inputs: usize) -> Tape {
    let b = clamp01(load(Tape::build(n_inputs))); // [pc]
    let b = b.dup().neg().c(1.0).add().ln(); // [pc, ln(1-pc)]
    let b = b.load(1).neg().c(1.0).add().mul(); // [pc, neg_term]
    let b = b.swap().ln().load(1).mul(); // [neg_term, pos]
    b.add().done()
}

/// d/dp tape: `((G*t)*(1/pc) + -( (G*(1-t)) * (1/(1-pc)) )) * mask`.
/// Input 2 is the pre-scaled seed `G = (-g)*(1/n)` (computed per call by
/// the backward builder, since `n` is not known at tape-build time).
fn bce_dp_tape(load: fn(TapeBuilder) -> TapeBuilder, n_inputs: usize) -> Tape {
    let b = Tape::build(n_inputs).load(2); // [G]
    let b = b.dup().load(1).neg().c(1.0).add().mul(); // [G, G*(1-t)]
    let b = clamp01(load(b)); // [G, Gomt, pc]
    let b = b.neg().c(1.0).add().recip().mul().neg(); // [G, term2]
    let b = b.swap().load(1).mul(); // [term2, G*t]
    let b = clamp01(load(b)).recip().mul(); // [term2, term1]
    let b = b.add(); // [g_pc]
    let b = load(b).c(BCE_EPS as f64).ge(); // [g_pc, m1]
    let b = load(b).c(bce_hi()).le().mul(); // [g_pc, mask]
    b.mul().done()
}

/// d/dt tape: `(G*ln(pc)) + -(G*ln(1-pc))`; input 2 is `G`, as in
/// [`bce_dp_tape`].
fn bce_dt_tape(load: fn(TapeBuilder) -> TapeBuilder, n_inputs: usize) -> Tape {
    let b = Tape::build(n_inputs).load(2).dup(); // [G, G]
    let b = clamp01(load(b)).neg().c(1.0).add().ln(); // [G, G, ln(1-pc)]
    let b = b.mul().neg(); // [G, t2]
    let b = clamp01(load(b.swap())).ln().mul(); // [t2, t1]
    b.add().done()
}

static BCE_FWD: Lazy<Tape> = Lazy::new(|| bce_total_tape(load_p, 2));
static BCE_DP: Lazy<Tape> = Lazy::new(|| bce_dp_tape(load_p, 3));
static BCE_DT: Lazy<Tape> = Lazy::new(|| bce_dt_tape(load_p, 3));

static SBCE_FWD: Lazy<Tape> = Lazy::new(|| bce_total_tape(load_sigmoid, 2));
/// dx = dp-at-sigmoid * (s * (1 - s)), the composed sigmoid backward.
static SBCE_DX: Lazy<Tape> = Lazy::new(|| {
    let mut b = bce_dp_tape(load_sigmoid, 3);
    let tail = sigmoid_seq(Tape::build(3).load(0)).dup().neg().c(1.0).add().mul().done();
    b.ops.extend_from_slice(&tail.ops);
    b.ops.push(MicroOp::Bin(BinaryK::Mul));
    // The splice bypassed TapeBuilder's depth tracking: verify the
    // composed program once here, at assembly time.
    b.verify();
    b
});
static SBCE_DT: Lazy<Tape> = Lazy::new(|| bce_dt_tape(load_sigmoid, 3));

fn bce_like_forward(name: &'static str, tape: &Tape, ctx: &OpCtx) -> Tensor {
    let (a, b) = (ctx.input(0), ctx.input(1));
    torsk_assert!(a.shape() == b.shape(), "{name}: shape mismatch");
    let (pa, pb) = promote_pair(a, b);
    let n = pa.numel();
    run_map_sum(
        name,
        tape,
        &[(&pa, Access::Flat), (&pb, Access::Flat)],
        n,
        finish_neg_mean,
        mean_factor(n, pa.dtype()),
    )
}

fn bce_like_backward(
    name: &'static str,
    dp: &'static Lazy<Tape>,
    dt: &'static Lazy<Tape>,
    ctx: &OpCtx,
) -> Box<dyn Function> {
    let (da, db) = (ctx.input(0).dtype(), ctx.input(1).dtype());
    let (pa, pb) = promote_pair(ctx.input(0), ctx.input(1));
    let shape = pa.shape().to_vec();
    let rn = mean_factor(pa.numel(), pa.dtype());
    let (va, vb) = (SavedTensor::save(&pa), SavedTensor::save(&pb));
    ClosureFunction::new(name, move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        // G = (-g) * (1/n): the composed `neg` + mean backward scalars.
        let gneg = super::call_owned("neg", vec![g.clone()], &[]);
        let gs = super::call_owned("mul_scalar", vec![gneg], &[Param::F64(rn)]);
        let srcs = [(&a, Access::Flat), (&b, Access::Flat), (&gs, Access::Scalar)];
        let ga = run_map(name, dp, &srcs, &shape);
        let gb = run_map(name, dt, &srcs, &shape);
        vec![Some(cast_to(&ga, da)), Some(cast_to(&gb, db))]
    })
}

fn k_fused_bce(ctx: &OpCtx) -> Tensor {
    bce_like_forward("fused:bce", &BCE_FWD, ctx)
}

fn bw_fused_bce(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    bce_like_backward("fused:bce", &BCE_DP, &BCE_DT, ctx)
}

fn k_fused_sigmoid_bce(ctx: &OpCtx) -> Tensor {
    bce_like_forward("fused:sigmoid_bce", &SBCE_FWD, ctx)
}

fn bw_fused_sigmoid_bce(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    bce_like_backward("fused:sigmoid_bce", &SBCE_DX, &SBCE_DT, ctx)
}

// ---------------------------------------------------------------------
// fused:ln_tail — the layer-norm scale/shift tail
// ---------------------------------------------------------------------

/// `out = (centered * inv_std) * gamma + beta` in one pass.
static LN_TAIL_FWD: Lazy<Tape> =
    Lazy::new(|| Tape::build(4).load(0).load(1).mul().load(2).mul().load(3).add().done());
/// `dcentered = (g * gamma) * inv_std`.
static LN_TAIL_DC: Lazy<Tape> =
    Lazy::new(|| Tape::build(3).load(0).load(1).mul().load(2).mul().done());
/// Full-size `(g * gamma) * centered` (reduced to inv_std's shape after).
static LN_TAIL_DIS: Lazy<Tape> =
    Lazy::new(|| Tape::build(3).load(0).load(1).mul().load(2).mul().done());
/// Full-size `(centered * inv_std) * g` (reduced to gamma's shape after).
static LN_TAIL_DG: Lazy<Tape> =
    Lazy::new(|| Tape::build(3).load(1).load(2).mul().load(0).mul().done());

fn ln_tail_check(ctx: &OpCtx) -> (usize, Vec<usize>) {
    let (c, is, g, b) = (ctx.input(0), ctx.input(1), ctx.input(2), ctx.input(3));
    torsk_assert!(c.ndim() >= 1, "fused:ln_tail: needs at least 1 dim");
    let d = *c.shape().last().unwrap();
    let mut stat_shape = c.shape().to_vec();
    *stat_shape.last_mut().unwrap() = 1;
    torsk_assert!(
        is.shape() == stat_shape.as_slice(),
        "fused:ln_tail: inv_std shape {:?} vs {:?}",
        is.shape(),
        stat_shape
    );
    torsk_assert!(
        g.shape() == [d] && b.shape() == [d],
        "fused:ln_tail: affine shape must be [{d}]"
    );
    (d, stat_shape)
}

fn k_ln_tail(ctx: &OpCtx) -> Tensor {
    let (d, _) = ln_tail_check(ctx);
    let c = ctx.input(0);
    run_map(
        "fused:ln_tail",
        &LN_TAIL_FWD,
        &[
            (c, Access::Flat),
            (ctx.input(1), Access::Row(d)),
            (ctx.input(2), Access::Col(d)),
            (ctx.input(3), Access::Col(d)),
        ],
        c.shape(),
    )
}

fn bw_ln_tail(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (d, stat_shape) = ln_tail_check(ctx);
    let shape = ctx.input(0).shape().to_vec();
    let vc = SavedTensor::save(ctx.input(0));
    let vis = SavedTensor::save(ctx.input(1));
    let vg = SavedTensor::save(ctx.input(2));
    ClosureFunction::new("fused:ln_tail", move |g| {
        let c = vc.unpack();
        let is = vis.unpack();
        let gamma = vg.unpack();
        let srcs_dc = [(g, Access::Flat), (&gamma, Access::Col(d)), (&is, Access::Row(d))];
        let dc = run_map("fused:ln_tail_bwd", &LN_TAIL_DC, &srcs_dc, &shape);
        let srcs_dis = [(g, Access::Flat), (&gamma, Access::Col(d)), (&c, Access::Flat)];
        let dis_full = run_map("fused:ln_tail_bwd", &LN_TAIL_DIS, &srcs_dis, &shape);
        let dis = sum_to_shape(&dis_full, &stat_shape);
        let srcs_dg = [(g, Access::Flat), (&c, Access::Flat), (&is, Access::Row(d))];
        let dg_full = run_map("fused:ln_tail_bwd", &LN_TAIL_DG, &srcs_dg, &shape);
        let dg = sum_to_shape(&dg_full, &[d]);
        let db = sum_to_shape(g, &[d]);
        vec![Some(dc), Some(dis), Some(dg), Some(db)]
    })
}

// ---------------------------------------------------------------------
// Fused in-place optimizer updates
// ---------------------------------------------------------------------

fn check_step_operands(name: &str, ctx: &OpCtx) {
    let p = ctx.input(0);
    torsk_assert!(
        !(crate::autograd::grad_enabled() && p.requires_grad_flag() && p.grad_fn().is_none()),
        "a leaf tensor that requires grad is being used in an in-place \
         operation ({name}); wrap the update in no_grad()"
    );
    let dt = p.dtype();
    torsk_assert!(dt.is_float(), "{name}: float params only");
    for i in 0..ctx.num_inputs() {
        let t = ctx.input(i);
        torsk_assert!(t.shape() == p.shape(), "{name}: operand {i} shape mismatch");
        torsk_assert!(t.dtype() == dt, "{name}: operand {i} dtype mismatch");
    }
    torsk_assert!(p.is_contiguous(), "{name}: param must be contiguous");
}

#[allow(clippy::too_many_arguments)]
fn adam_step_t<T: FloatElement>(
    n: usize,
    pp: SendPtr,
    gp: SendPtr,
    mp: SendPtr,
    vp: SendPtr,
    lr: T,
    b1: T,
    b2: T,
    eps: T,
    wd: T,
    rbc1: T,
    rbc2: T,
) {
    let one_m_b1 = T::ONE - b1;
    let one_m_b2 = T::ONE - b2;
    // SAFETY: all four buffers are n-element, same-dtype parameter state
    // held alive by the caller; chunks touch disjoint index ranges [s, e)
    // and parallel_for blocks until every chunk completes.
    parallel_for(n, SERIAL_GRAIN, |s, e| unsafe {
        let p = pp.ptr() as *mut T;
        let g = gp.ptr() as *const T;
        let m = mp.ptr() as *mut T;
        let v = vp.ptr() as *mut T;
        for i in s..e {
            let mut gi = std::ptr::read(g.add(i));
            if wd != T::ZERO {
                gi = gi + std::ptr::read(p.add(i)) * wd;
            }
            // m = b1*m + (1-b1)*g ; v = b2*v + (1-b2)*g^2 — the exact
            // mul_scalar_/axpy_ composition, one pass instead of five.
            let mi = std::ptr::read(m.add(i)) * b1 + one_m_b1 * gi;
            let vi = std::ptr::read(v.add(i)) * b2 + one_m_b2 * (gi * gi);
            std::ptr::write(m.add(i), mi);
            std::ptr::write(v.add(i), vi);
            let mhat = mi * rbc1;
            let vhat = vi * rbc2;
            let update = mhat / (vhat.fsqrt() + eps);
            let pi = std::ptr::read(p.add(i)) + (-lr) * update;
            std::ptr::write(p.add(i), pi);
        }
    });
}

/// Fused Adam update: inputs [param, grad, m, v] (param/m/v mutated in
/// place); params [lr, beta1, beta2, eps, weight_decay, bc1, bc2] where
/// `bc*` are the bias corrections `1 - beta^t`.
fn k_adam_step(ctx: &OpCtx) -> Tensor {
    check_step_operands("fused:adam_step", ctx);
    let (p, m, v) = (ctx.input(0), ctx.input(2), ctx.input(3));
    torsk_assert!(
        m.is_contiguous() && v.is_contiguous(),
        "fused:adam_step: state buffers must be contiguous"
    );
    let g = ctx.input(1).contiguous();
    let (lr, b1, b2, eps) = (ctx.f32(0), ctx.f32(1), ctx.f32(2), ctx.f32(3));
    let (wd, bc1, bc2) = (ctx.f32(4), ctx.f32(5), ctx.f32(6));
    // 1/bc in f32 first: that is what the composed `mul_scalar(m, 1/bc1)`
    // multiplied by.
    let (rbc1, rbc2) = (1.0f32 / bc1, 1.0f32 / bc2);
    let n = p.numel();
    let (pp, gp, mp, vp) = (p.data_ptr(), g.data_ptr(), m.data_ptr(), v.data_ptr());
    let dt = p.dtype();
    let dev = ctx.device;
    device::dispatch(dev, "fused:adam_step", move || {
        match dt {
            DType::F32 => adam_step_t::<f32>(n, pp, gp, mp, vp, lr, b1, b2, eps, wd, rbc1, rbc2),
            DType::F64 => adam_step_t::<f64>(
                n,
                pp,
                gp,
                mp,
                vp,
                lr as f64,
                b1 as f64,
                b2 as f64,
                eps as f64,
                wd as f64,
                rbc1 as f64,
                rbc2 as f64,
            ),
            DType::I64 => unreachable!("schema admits floats only"),
        }
        drop(g);
    });
    for t in [p, m, v] {
        t.bump_version();
    }
    p.clone()
}

fn sgd_step_t<T: FloatElement>(
    n: usize,
    pp: SendPtr,
    gp: SendPtr,
    vp: Option<SendPtr>,
    lr: T,
    momentum: T,
    wd: T,
) {
    // SAFETY: param/grad (and optional momentum) buffers are n-element
    // state held alive by the caller; chunks touch disjoint index ranges
    // [s, e) and parallel_for blocks until every chunk completes.
    parallel_for(n, SERIAL_GRAIN, |s, e| unsafe {
        let p = pp.ptr() as *mut T;
        let g = gp.ptr() as *const T;
        for i in s..e {
            let mut gi = std::ptr::read(g.add(i));
            if wd != T::ZERO {
                gi = gi + std::ptr::read(p.add(i)) * wd;
            }
            if let Some(vp) = vp {
                let v = vp.ptr() as *mut T;
                let vi = std::ptr::read(v.add(i)) * momentum + gi;
                std::ptr::write(v.add(i), vi);
                gi = vi;
            }
            let pi = std::ptr::read(p.add(i)) + (-lr) * gi;
            std::ptr::write(p.add(i), pi);
        }
    });
}

/// Fused SGD update: inputs [param, grad] or [param, grad, velocity]
/// (param and velocity mutated in place); params [lr, momentum,
/// weight_decay]. A zero-initialized velocity reproduces the composed
/// first-step `v = g` exactly (`0*mu + g == g`).
fn k_sgd_step(ctx: &OpCtx) -> Tensor {
    check_step_operands("fused:sgd_step", ctx);
    let p = ctx.input(0);
    let g = ctx.input(1).contiguous();
    let vel = if ctx.num_inputs() == 3 {
        let v = ctx.input(2);
        torsk_assert!(v.is_contiguous(), "fused:sgd_step: velocity must be contiguous");
        Some(v.clone())
    } else {
        None
    };
    let (lr, momentum, wd) = (ctx.f32(0), ctx.f32(1), ctx.f32(2));
    let n = p.numel();
    let (pp, gp) = (p.data_ptr(), g.data_ptr());
    let vp = vel.as_ref().map(|v| v.data_ptr());
    let dt = p.dtype();
    device::dispatch(ctx.device, "fused:sgd_step", move || {
        match dt {
            DType::F32 => sgd_step_t::<f32>(n, pp, gp, vp, lr, momentum, wd),
            DType::F64 => {
                sgd_step_t::<f64>(n, pp, gp, vp, lr as f64, momentum as f64, wd as f64)
            }
            DType::I64 => unreachable!("schema admits floats only"),
        }
        drop(g);
    });
    p.bump_version();
    if let Some(v) = &vel {
        v.bump_version();
    }
    p.clone()
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

fn s_gelu(seed: u64, dt: DType) -> Option<OpSample> {
    let x = super::sample_uniform(seed, &[3, 5], dt, -2.0, 2.0)?;
    Some(OpSample { inputs: vec![x], params: vec![], grad_inputs: vec![0] })
}

/// Shared with the `mse_loss` wrapper registration in `dispatch/loss.rs`
/// so the fused entry and its wrapper always test identical inputs.
pub(crate) fn s_mse(seed: u64, dt: DType) -> Option<OpSample> {
    let p = super::sample_uniform(seed, &[2, 6], dt, -1.5, 1.5)?;
    let t = super::sample_uniform(seed ^ 0x5c5c, &[2, 6], dt, -1.5, 1.5)?;
    Some(OpSample { inputs: vec![p, t], params: vec![], grad_inputs: vec![0, 1] })
}

/// Probabilities well inside the clamp interval (no mask kinks); shared
/// with the `bce_loss` wrapper registration.
pub(crate) fn s_bce(seed: u64, dt: DType) -> Option<OpSample> {
    let p = super::sample_uniform(seed, &[2, 5], dt, 0.08, 0.92)?;
    let t = super::sample_uniform(seed ^ 0x7a7a, &[2, 5], dt, 0.1, 0.9)?;
    Some(OpSample { inputs: vec![p, t], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_sigmoid_bce(seed: u64, dt: DType) -> Option<OpSample> {
    let x = super::sample_uniform(seed, &[2, 5], dt, -2.5, 2.5)?;
    let t = super::sample_uniform(seed ^ 0x7a7a, &[2, 5], dt, 0.1, 0.9)?;
    Some(OpSample { inputs: vec![x, t], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_ln_tail(seed: u64, dt: DType) -> Option<OpSample> {
    let c = super::sample_uniform(seed, &[3, 4], dt, -2.0, 2.0)?;
    let is = super::sample_uniform(seed ^ 0x11, &[3, 1], dt, 0.5, 2.0)?;
    let g = super::sample_uniform(seed ^ 0x22, &[4], dt, 0.5, 1.5)?;
    let b = super::sample_uniform(seed ^ 0x33, &[4], dt, -0.5, 0.5)?;
    Some(OpSample { inputs: vec![c, is, g, b], params: vec![], grad_inputs: vec![0, 1, 2, 3] })
}

fn s_adam_step(seed: u64, dt: DType) -> Option<OpSample> {
    let p = super::sample_uniform(seed, &[8], dt, -1.0, 1.0)?;
    let g = super::sample_uniform(seed ^ 0x44, &[8], dt, -1.0, 1.0)?;
    let m = super::sample_uniform(seed ^ 0x55, &[8], dt, -0.1, 0.1)?;
    let v = super::sample_uniform(seed ^ 0x66, &[8], dt, 0.0, 0.1)?;
    Some(OpSample {
        inputs: vec![p, g, m, v],
        params: vec![
            Param::F32(1e-3),
            Param::F32(0.9),
            Param::F32(0.999),
            Param::F32(1e-8),
            Param::F32(0.0),
            Param::F32(0.1),
            Param::F32(0.001999),
        ],
        grad_inputs: vec![],
    })
}

fn s_sgd_step(seed: u64, dt: DType) -> Option<OpSample> {
    let p = super::sample_uniform(seed, &[8], dt, -1.0, 1.0)?;
    let g = super::sample_uniform(seed ^ 0x44, &[8], dt, -1.0, 1.0)?;
    let v = super::sample_uniform(seed ^ 0x55, &[8], dt, -0.1, 0.1)?;
    Some(OpSample {
        inputs: vec![p, g, v],
        params: vec![Param::F32(0.01), Param::F32(0.9), Param::F32(0.0)],
        grad_inputs: vec![],
    })
}

// ---------------------------------------------------------------------
// Registration
// ---------------------------------------------------------------------

pub(crate) fn register(reg: &mut Registry) {
    reg.add(
        OpDef::new("fused:gelu", 1, 1, FLOATS)
            .kernel_all(k_gelu)
            .backward(bw_gelu)
            .reuse_output()
            .sample_inputs(s_gelu),
    );
    reg.add(
        OpDef::new("fused:mse", 2, 2, FLOATS)
            .kernel_all(k_fused_mse)
            .backward(bw_fused_mse)
            .sample_inputs(s_mse),
    );
    reg.add(
        OpDef::new("fused:bce", 2, 2, FLOATS)
            .kernel_all(k_fused_bce)
            .backward(bw_fused_bce)
            .sample_inputs(s_bce),
    );
    reg.add(
        OpDef::new("fused:sigmoid_bce", 2, 2, FLOATS)
            .kernel_all(k_fused_sigmoid_bce)
            .backward(bw_fused_sigmoid_bce)
            .sample_inputs(s_sigmoid_bce),
    );
    reg.add(
        OpDef::new("fused:ln_tail", 4, 4, FLOATS)
            .kernel_all(k_ln_tail)
            .backward(bw_ln_tail)
            .sample_inputs(s_ln_tail),
    );
    reg.add(
        OpDef::new("fused:adam_step", 4, 4, FLOATS)
            .kernel_all(k_adam_step)
            .sample_inputs(s_adam_step),
    );
    reg.add(
        OpDef::new("fused:sgd_step", 2, 3, FLOATS)
            .kernel_all(k_sgd_step)
            .sample_inputs(s_sgd_step),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    #[test]
    fn tape_eval_matches_scalar_reference() {
        // (x*2 + 1) / (x - 3), via explicit micro-ops.
        let t = Tape::build(1)
            .load(0)
            .c(2.0)
            .mul()
            .c(1.0)
            .add()
            .load(0)
            .c(3.0)
            .sub()
            .bin(BinaryK::Div)
            .done();
        for x in [-1.5f32, 0.0, 2.25, 7.0] {
            let want = (x * 2.0 + 1.0) / (x - 3.0);
            assert_eq!(t.eval(&[x]), want);
        }
    }

    #[test]
    fn constant_folding_collapses_const_subtrees() {
        // exp(1) * 2 is folded into a single constant; one Load survives.
        let t = Tape::build(1).c(1.0).exp().c(2.0).mul().load(0).mul().done();
        assert_eq!(t.len(), 3, "tape {:?}", t);
        assert!(matches!(t.ops[0], MicroOp::Const(c) if (c - 2.0 * 1f64.exp()).abs() < 1e-12));
        assert_eq!(t.eval(&[3.0f64]), 3.0 * (2.0 * 1f64.exp()));
    }

    #[test]
    #[should_panic(expected = "leaves 2 values")]
    fn unbalanced_tape_panics() {
        let _ = Tape::build(1).load(0).load(0).done();
    }

    #[test]
    fn dup_swap_and_masks() {
        // |clamp eps..hi mask| at, below, above the interval.
        let m = Tape::build(1)
            .load(0)
            .c(0.25)
            .ge()
            .load(0)
            .c(0.75)
            .le()
            .mul()
            .done();
        assert_eq!(m.eval(&[0.5f32]), 1.0);
        assert_eq!(m.eval(&[0.1f32]), 0.0);
        assert_eq!(m.eval(&[0.9f32]), 0.0);
        let s = Tape::build(2).load(0).load(1).swap().sub().done(); // b - a
        assert_eq!(s.eval(&[3.0f32, 10.0]), 7.0);
        let d = Tape::build(1).load(0).dup().mul().done(); // x^2
        assert_eq!(d.eval(&[-4.0f32]), 16.0);
    }

    #[test]
    fn gelu_forward_matches_composed_unfused() {
        let x = Tensor::from_slice(&[-2.0f32, -0.3, 0.0, 0.7, 1.9]);
        let fused = ops::gelu(&x);
        // The composed chain the tape mirrors, operation for operation.
        let xx = ops::mul(&x, &x);
        let x3 = ops::mul(&xx, &x);
        let inner = ops::add(&ops::mul_scalar(&x3, GELU_A), &x);
        let t = ops::tanh(&ops::mul_scalar(&inner, GELU_C));
        let unfused = ops::mul(&ops::add_scalar(&t, 1.0), &ops::mul_scalar(&x, 0.5));
        // Bitwise: the tape mirrors this chain operation for operation
        // (tests/fused_parity.rs pins it across thread counts too).
        assert_eq!(fused.to_vec::<f32>(), unfused.to_vec::<f32>());
    }

    #[test]
    fn fused_mse_matches_composite_bitwise() {
        crate::rng::manual_seed(41);
        let p = Tensor::randn(&[317]);
        let t = Tensor::randn(&[317]);
        let fused = crate::dispatch::call("fused:mse", &[&p, &t], &[]);
        let diff = ops::sub(&p, &t);
        let composite = ops::mean(&ops::mul(&diff, &diff));
        assert_eq!(fused.to_vec::<f32>(), composite.to_vec::<f32>());
    }

    #[test]
    fn fused_bce_matches_composite_bitwise() {
        crate::rng::manual_seed(43);
        let p = ops::sigmoid(&Tensor::randn(&[253]));
        let t = Tensor::rand(&[253]);
        let fused = crate::dispatch::call("fused:bce", &[&p, &t], &[]);
        let eps = BCE_EPS;
        let pc = ops::clamp(&p, eps, 1.0 - eps);
        let log_p = ops::log(&pc);
        let log_1p = ops::log(&ops::add_scalar(&ops::neg(&pc), 1.0));
        let omt = ops::add_scalar(&ops::neg(&t), 1.0);
        let total = ops::add(&ops::mul(&t, &log_p), &ops::mul(&omt, &log_1p));
        let composite = ops::neg(&ops::mean(&total));
        assert_eq!(fused.to_vec::<f32>(), composite.to_vec::<f32>());
    }

    #[test]
    fn fused_sigmoid_bce_matches_sigmoid_then_bce() {
        crate::rng::manual_seed(47);
        let x = Tensor::randn(&[199]);
        let t = Tensor::rand(&[199]);
        let fused = ops::bce_with_logits(&x, &t);
        let composite = ops::bce_loss(&ops::sigmoid(&x), &t);
        assert_eq!(fused.to_vec::<f32>(), composite.to_vec::<f32>());
    }

    #[test]
    fn ln_tail_matches_broadcast_chain_bitwise() {
        crate::rng::manual_seed(53);
        let c = Tensor::randn(&[37, 64]);
        let is = ops::add_scalar(&Tensor::rand(&[37, 1]), 0.5);
        let g = Tensor::randn(&[64]);
        let b = Tensor::randn(&[64]);
        let fused = crate::dispatch::call("fused:ln_tail", &[&c, &is, &g, &b], &[]);
        let composite = ops::add(&ops::mul(&ops::mul(&c, &is), &g), &b);
        assert_eq!(fused.to_vec::<f32>(), composite.to_vec::<f32>());
    }

    #[test]
    fn fused_sgd_step_matches_composed_update() {
        let p = Tensor::from_slice(&[1.0f32, -2.0, 0.5]);
        let g = Tensor::from_slice(&[0.5f32, 0.25, -1.0]);
        let v = Tensor::zeros(&[3]);
        let pr = p.detach();
        crate::dispatch::call(
            "fused:sgd_step",
            &[&p, &g, &v],
            &[Param::F32(0.1), Param::F32(0.9), Param::F32(0.0)],
        );
        // First step with zero velocity: v = g, p -= lr*g.
        assert_eq!(v.to_vec::<f32>(), g.to_vec::<f32>());
        let expect = ops::add(&pr, &ops::mul_scalar(&g, -0.1));
        assert_eq!(p.to_vec::<f32>(), expect.to_vec::<f32>());
    }

    #[test]
    fn fused_adam_step_first_step_magnitude_is_lr() {
        let p = Tensor::from_slice(&[0.0f32]);
        let g = Tensor::from_slice(&[42.0f32]);
        let m = Tensor::zeros(&[1]);
        let v = Tensor::zeros(&[1]);
        crate::dispatch::call(
            "fused:adam_step",
            &[&p, &g, &m, &v],
            &[
                Param::F32(0.1),
                Param::F32(0.9),
                Param::F32(0.999),
                Param::F32(1e-8),
                Param::F32(0.0),
                Param::F32(1.0 - 0.9),
                Param::F32(1.0 - 0.999),
            ],
        );
        assert!((p.to_vec::<f32>()[0] + 0.1).abs() < 1e-4);
    }

    #[test]
    fn gelu_reuses_dead_input_storage() {
        let n = 100_000;
        let x = Tensor::from_vec(vec![0.5f32; n], &[n]);
        let ptr = x.storage().ptr() as usize;
        let y = crate::dispatch::call_owned("fused:gelu", vec![x], &[]);
        assert_eq!(y.storage().ptr() as usize, ptr, "fused:gelu must steal a dead input");
        let want = y.to_vec::<f32>()[0];
        assert!((want - 0.345714).abs() < 1e-4, "gelu(0.5)={want}");
    }

    #[test]
    fn fused_ops_emit_fused_spans() {
        crate::profiler::start();
        let x = Tensor::from_slice(&[0.1f32, -0.2]);
        let _ = ops::gelu(&x);
        let _ = ops::mse_loss(&x, &x);
        let events = crate::profiler::stop();
        let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
        for want in ["op:fused:gelu", "op:fused:mse"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
    }
}
