//! Matrix-multiplication ops for the dispatcher: `matmul`, batched `bmm`,
//! and the fused `linear` (x @ Wᵀ + b). F32 runs the blocked SGEMM; F64
//! runs the precision-oriented DGEMM.

use crate::autograd::{ClosureFunction, Function, SavedTensor};
use crate::device;
use crate::kernels::matmul::{dgemm, dgemm_batched, sgemm, sgemm_batched};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::elementwise::{raw_add, FLOATS};
use super::{same_device, OpCtx, OpDef, Registry};

/// Raw 2-D matmul (no autograd) — shared by forward and backward math.
pub(crate) fn matmul_raw(a: &Tensor, b: &Tensor) -> Tensor {
    let dev = same_device("matmul", &[a, b]);
    torsk_assert!(
        a.ndim() == 2 && b.ndim() == 2,
        "matmul: need 2-D, got {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    torsk_assert!(
        a.dtype() == b.dtype(),
        "matmul: dtype mismatch {} x {}",
        a.dtype(),
        b.dtype()
    );
    let (m, k) = (a.size(0), a.size(1));
    let (k2, n) = (b.size(0), b.size(1));
    torsk_assert!(k == k2, "matmul: inner dims {k} vs {k2}");
    let a = a.contiguous();
    let b = b.contiguous();
    let dtype = a.dtype();
    let out = Tensor::empty(&[m, n], dtype, dev);
    let (ap, bp, op) = (a.data_ptr(), b.data_ptr(), out.data_ptr());
    device::dispatch(dev, "matmul", move || unsafe {
        match dtype {
            DType::F32 => sgemm(
                m,
                n,
                k,
                1.0,
                ap.as_slice::<f32>(0, m * k),
                bp.as_slice::<f32>(0, k * n),
                0.0,
                op.as_mut_slice::<f32>(0, m * n),
            ),
            DType::F64 => dgemm(
                m,
                n,
                k,
                ap.as_slice::<f64>(0, m * k),
                bp.as_slice::<f64>(0, k * n),
                op.as_mut_slice::<f64>(0, m * n),
            ),
            _ => unreachable!("matmul schema admits floats only"),
        }
    });
    out
}

fn bmm_raw(a: &Tensor, b: &Tensor) -> Tensor {
    let dev = same_device("bmm", &[a, b]);
    torsk_assert!(a.ndim() == 3 && b.ndim() == 3, "bmm: need 3-D");
    torsk_assert!(a.dtype() == b.dtype(), "bmm: dtype mismatch {} x {}", a.dtype(), b.dtype());
    let (batch, m, k) = (a.size(0), a.size(1), a.size(2));
    let (b2, k2, n) = (b.size(0), b.size(1), b.size(2));
    torsk_assert!(
        batch == b2 && k == k2,
        "bmm: shape mismatch {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let a = a.contiguous();
    let b = b.contiguous();
    let dtype = a.dtype();
    let out = Tensor::empty(&[batch, m, n], dtype, dev);
    let (ap, bp, op) = (a.data_ptr(), b.data_ptr(), out.data_ptr());
    device::dispatch(dev, "bmm", move || unsafe {
        match dtype {
            DType::F32 => sgemm_batched(
                batch,
                m,
                n,
                k,
                ap.as_slice::<f32>(0, batch * m * k),
                bp.as_slice::<f32>(0, batch * k * n),
                op.as_mut_slice::<f32>(0, batch * m * n),
            ),
            DType::F64 => dgemm_batched(
                batch,
                m,
                n,
                k,
                ap.as_slice::<f64>(0, batch * m * k),
                bp.as_slice::<f64>(0, batch * k * n),
                op.as_mut_slice::<f64>(0, batch * m * n),
            ),
            _ => unreachable!("bmm schema admits floats only"),
        }
    });
    out
}

fn k_matmul(ctx: &OpCtx) -> Tensor {
    matmul_raw(ctx.input(0), ctx.input(1))
}

fn bw_matmul(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (va, vb) = (SavedTensor::save(ctx.input(0)), SavedTensor::save(ctx.input(1)));
    ClosureFunction::new("matmul", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        // dA = G @ Bᵀ ; dB = Aᵀ @ G
        let ga = matmul_raw(g, &b.t().contiguous());
        let gb = matmul_raw(&a.t().contiguous(), g);
        vec![Some(ga), Some(gb)]
    })
}

fn k_bmm(ctx: &OpCtx) -> Tensor {
    bmm_raw(ctx.input(0), ctx.input(1))
}

fn bw_bmm(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (va, vb) = (SavedTensor::save(ctx.input(0)), SavedTensor::save(ctx.input(1)));
    ClosureFunction::new("bmm", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        let bt = b.transpose(1, 2).contiguous();
        let at = a.transpose(1, 2).contiguous();
        vec![Some(bmm_raw(g, &bt)), Some(bmm_raw(&at, g))]
    })
}

/// Fused linear layer: `x [N,in] @ Wᵀ [in,out] + b`, PyTorch weight layout
/// `W [out,in]`. Bias is the optional third input.
fn k_linear(ctx: &OpCtx) -> Tensor {
    let (x, w) = (ctx.input(0), ctx.input(1));
    torsk_assert!(x.ndim() == 2 && w.ndim() == 2, "linear: x 2-D, w 2-D");
    torsk_assert!(
        x.size(1) == w.size(1),
        "linear: in_features {} vs {}",
        x.size(1),
        w.size(1)
    );
    let wt = w.t().contiguous();
    let y = matmul_raw(x, &wt);
    match ctx.num_inputs() {
        2 => y,
        _ => {
            let bias = ctx.input(2);
            torsk_assert!(
                bias.shape() == [w.size(0)],
                "linear: bias shape {:?} for {} out features",
                bias.shape(),
                w.size(0)
            );
            raw_add(&y, bias)
        }
    }
}

fn bw_linear(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (vx, vw) = (SavedTensor::save(ctx.input(0)), SavedTensor::save(ctx.input(1)));
    let has_bias = ctx.num_inputs() == 3;
    let bias_cols = if has_bias { ctx.input(1).size(0) } else { 0 };
    ClosureFunction::new("linear", move |g| {
        let x = vx.unpack();
        let w = vw.unpack();
        // gx = G @ W ; gw = Gᵀ @ x ; gb = sum rows of G
        let gx = matmul_raw(g, &w);
        let gw = matmul_raw(&g.t().contiguous(), &x);
        let mut grads = vec![Some(gx), Some(gw)];
        if has_bias {
            grads.push(Some(super::reduce::sum_to_shape(g, &[bias_cols])));
        }
        grads
    })
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

use super::OpSample;

fn s_matmul(seed: u64, dt: DType) -> Option<OpSample> {
    let a = super::sample_uniform(seed, &[3, 4], dt, -1.0, 1.0)?;
    let b = super::sample_uniform(seed ^ 0xB0B, &[4, 2], dt, -1.0, 1.0)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_bmm(seed: u64, dt: DType) -> Option<OpSample> {
    let a = super::sample_uniform(seed, &[2, 3, 4], dt, -1.0, 1.0)?;
    let b = super::sample_uniform(seed ^ 0xB0B, &[2, 4, 2], dt, -1.0, 1.0)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_linear(seed: u64, dt: DType) -> Option<OpSample> {
    let x = super::sample_uniform(seed, &[3, 4], dt, -1.0, 1.0)?;
    let w = super::sample_uniform(seed ^ 0xB0B, &[2, 4], dt, -1.0, 1.0)?;
    let b = super::sample_uniform(seed ^ 0xBEE, &[2], dt, -0.5, 0.5)?;
    Some(OpSample { inputs: vec![x, w, b], params: vec![], grad_inputs: vec![0, 1, 2] })
}

pub(crate) fn register(reg: &mut Registry) {
    reg.add(
        OpDef::new("matmul", 2, 2, FLOATS)
            .kernel_all(k_matmul)
            .backward(bw_matmul)
            .sample_inputs(s_matmul),
    );
    reg.add(
        OpDef::new("bmm", 2, 2, FLOATS).kernel_all(k_bmm).backward(bw_bmm).sample_inputs(s_bmm),
    );
    reg.add(
        OpDef::new("linear", 2, 3, FLOATS)
            .kernel_all(k_linear)
            .backward(bw_linear)
            .sample_inputs(s_linear),
    );
}
