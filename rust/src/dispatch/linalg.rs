//! Matrix-multiplication ops for the dispatcher: `matmul`, batched `bmm`,
//! and the fused `linear` (x @ Wᵀ + b). F32 runs the packed BLIS-style
//! SGEMM; F64 the precision-oriented packed DGEMM.
//!
//! **Transpose-aware, copy-free.** Every GEMM operand is handed to the
//! kernels as a raw strided view — `(ptr, row stride, col stride)` read
//! straight off the tensor — so transposed operands (user-level `x.t()`
//! views, and every `Gᵀ`/`Bᵀ`/`Aᵀ` the backward formulas need) are packed
//! in place by the kernel. No forward or backward path in this module
//! materializes a transpose; [`gemm_materialization_stats`] counts the
//! (currently unreachable) fallback and `tests/gemm_parity.rs` asserts it
//! stays zero.
//!
//! **Packed-weight cache.** `linear` keeps each weight's packed-Bᵀ panels
//! in a process-global cache keyed by (tensor id, storage version): the
//! first forward packs once, every later forward reuses the panels with
//! zero copies, and any in-place update (an optimizer step bumps the
//! storage version) repacks lazily on the next forward.
//! [`packed_weight_stats`] exposes (hits, misses).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::autograd::{ClosureFunction, Function, SavedTensor};
use crate::device::{self, Device};
use crate::kernels::matmul::{
    dgemm_batched_strided, dgemm_strided, pack_b_strided_f32, sgemm_batched_strided,
    sgemm_prepacked, sgemm_strided,
};
use crate::tensor::storage::SendPtr;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::{same_device, OpCtx, OpDef, Registry};
use crate::dispatch::elementwise::FLOATS;

// ---------------------------------------------------------------------
// Strided GEMM operands (the no-copy contract)
// ---------------------------------------------------------------------

static GEMM_MATERIALIZATIONS: AtomicU64 = AtomicU64::new(0);

/// Number of times a linalg op had to *materialize* (copy) an operand
/// before a GEMM since process start. The packed kernels consume every
/// 2-D/3-D stride pattern directly, so no registered path increments
/// this today — it exists so any future fallback copy is counted, and so
/// tests can assert the transpose-free invariant
/// (`tests/gemm_parity.rs` pins it at 0 across transposed forward and
/// backward workloads).
pub fn gemm_materialization_stats() -> u64 {
    GEMM_MATERIALIZATIONS.load(Ordering::Relaxed)
}

/// Smallest slice length covering a strided view (0 for empty shapes).
fn span(shape: &[usize], strides: &[usize]) -> usize {
    let mut s = 1usize;
    for (&d, &st) in shape.iter().zip(strides.iter()) {
        if d == 0 {
            return 0;
        }
        s += (d - 1) * st;
    }
    s
}

/// Resolve a 2-D tensor into a raw GEMM operand: base pointer, row
/// stride, col stride, and the slice span — whatever its layout
/// (contiguous, transposed view, narrowed, stride-0 broadcast).
fn gemm_operand2(t: &Tensor) -> (SendPtr, usize, usize, usize) {
    debug_assert_eq!(t.ndim(), 2, "gemm operand must be 2-D");
    let st = t.strides();
    (t.data_ptr(), st[0], st[1], span(t.shape(), st))
}

/// Resolve a 3-D tensor into a batched GEMM operand: base pointer, batch
/// stride, row stride, col stride, span.
fn gemm_operand3(t: &Tensor) -> (SendPtr, usize, usize, usize, usize) {
    debug_assert_eq!(t.ndim(), 3, "bmm operand must be 3-D");
    let st = t.strides();
    (t.data_ptr(), st[0], st[1], st[2], span(t.shape(), st))
}

// ---------------------------------------------------------------------
// Raw (no-autograd) math — shared by forward kernels and backward closures
// ---------------------------------------------------------------------

/// Raw 2-D matmul (no autograd) — shared by forward and backward math.
/// Transposed inputs are consumed as strided views: `matmul_raw(&g.t(),
/// &x)` packs `g` transposed in place, with zero copies.
pub(crate) fn matmul_raw(a: &Tensor, b: &Tensor) -> Tensor {
    let dev = same_device("matmul", &[a, b]);
    torsk_assert!(
        a.ndim() == 2 && b.ndim() == 2,
        "matmul: need 2-D, got {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    torsk_assert!(
        a.dtype() == b.dtype(),
        "matmul: dtype mismatch {} x {}",
        a.dtype(),
        b.dtype()
    );
    let (m, k) = (a.size(0), a.size(1));
    let (k2, n) = (b.size(0), b.size(1));
    torsk_assert!(k == k2, "matmul: inner dims {k} vs {k2}");
    let dtype = a.dtype();
    let out = Tensor::empty(&[m, n], dtype, dev);
    let (ap, ars, acs, aspan) = gemm_operand2(a);
    let (bp, brs, bcs, bspan) = gemm_operand2(b);
    let op = out.data_ptr();
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(dev, "matmul", move || unsafe {
        match dtype {
            DType::F32 => sgemm_strided(
                m,
                n,
                k,
                1.0,
                ap.as_slice::<f32>(0, aspan),
                ars,
                acs,
                bp.as_slice::<f32>(0, bspan),
                brs,
                bcs,
                0.0,
                op.as_mut_slice::<f32>(0, m * n),
            ),
            DType::F64 => dgemm_strided(
                m,
                n,
                k,
                1.0,
                ap.as_slice::<f64>(0, aspan),
                ars,
                acs,
                bp.as_slice::<f64>(0, bspan),
                brs,
                bcs,
                0.0,
                op.as_mut_slice::<f64>(0, m * n),
            ),
            _ => unreachable!("matmul schema admits floats only"),
        }
    });
    out
}

fn bmm_raw(a: &Tensor, b: &Tensor) -> Tensor {
    let dev = same_device("bmm", &[a, b]);
    torsk_assert!(a.ndim() == 3 && b.ndim() == 3, "bmm: need 3-D");
    torsk_assert!(a.dtype() == b.dtype(), "bmm: dtype mismatch {} x {}", a.dtype(), b.dtype());
    let (batch, m, k) = (a.size(0), a.size(1), a.size(2));
    let (b2, k2, n) = (b.size(0), b.size(1), b.size(2));
    torsk_assert!(
        batch == b2 && k == k2,
        "bmm: shape mismatch {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let dtype = a.dtype();
    let out = Tensor::empty(&[batch, m, n], dtype, dev);
    let (ap, abs_, ars, acs, aspan) = gemm_operand3(a);
    let (bp, bbs, brs, bcs, bspan) = gemm_operand3(b);
    let op = out.data_ptr();
    // SAFETY: pointer/length pairs come from shape-checked live tensors
    // captured at enqueue time. On CPU this closure runs inline while the
    // caller's handles are alive; on a stream, the one-pool-per-stream
    // FIFO allocator guarantees freed storage is only reused by kernels
    // enqueued later on the same stream, so the bytes stay valid (and
    // writes exclusive) until this kernel completes.
    device::dispatch(dev, "bmm", move || unsafe {
        match dtype {
            DType::F32 => sgemm_batched_strided(
                batch,
                m,
                n,
                k,
                ap.as_slice::<f32>(0, aspan),
                abs_,
                ars,
                acs,
                bp.as_slice::<f32>(0, bspan),
                bbs,
                brs,
                bcs,
                op.as_mut_slice::<f32>(0, batch * m * n),
            ),
            DType::F64 => dgemm_batched_strided(
                batch,
                m,
                n,
                k,
                ap.as_slice::<f64>(0, aspan),
                abs_,
                ars,
                acs,
                bp.as_slice::<f64>(0, bspan),
                bbs,
                brs,
                bcs,
                op.as_mut_slice::<f64>(0, batch * m * n),
            ),
            _ => unreachable!("bmm schema admits floats only"),
        }
    });
    out
}

// ---------------------------------------------------------------------
// Packed-weight cache for `linear`
// ---------------------------------------------------------------------

struct CachedPack {
    version: u64,
    in_features: usize,
    out_features: usize,
    data: Arc<Vec<f32>>,
    /// Tick of the last hit/insert — the eviction key. Entries for
    /// dropped weight tensors can never be hit again, so they age out.
    last_used: u64,
}

/// Caps on the packed-weight cache: entry count AND total bytes (a few
/// large dead packs can dwarf hundreds of small ones). Past either, the
/// least-recently-used entries are evicted down to half the budget —
/// live models keep their hot panels while entries for dropped tensors
/// age out (a model's live Linear weights are bounded, so eviction never
/// fires in steady state; the caps bound pathological churn like a
/// construct-and-drop hyperparameter sweep).
const PACKED_CACHE_CAP: usize = 256;
const PACKED_CACHE_MAX_BYTES: usize = 256 << 20;

static PACKED_WEIGHTS: once_cell::sync::Lazy<Mutex<HashMap<u64, CachedPack>>> =
    once_cell::sync::Lazy::new(|| Mutex::new(HashMap::new()));
static PACK_HITS: AtomicU64 = AtomicU64::new(0);
static PACK_MISSES: AtomicU64 = AtomicU64::new(0);
static PACK_TICK: AtomicU64 = AtomicU64::new(0);

/// `(hits, misses)` of the linear packed-weight cache since process
/// start. An inference / repeated-forward loop shows exactly one miss
/// per weight, ever — the zero-copy steady state. A training loop shows
/// one miss per weight per optimizer step *by design*: the step mutates
/// the weight in place (bumping the storage version), so the next
/// forward must repack — that repack replaces the `w.t().contiguous()`
/// copy the old kernel paid, it is not a cache defect. Multiple forwards
/// between steps (grad accumulation, eval passes) all hit.
pub fn packed_weight_stats() -> (u64, u64) {
    (PACK_HITS.load(Ordering::Relaxed), PACK_MISSES.load(Ordering::Relaxed))
}

/// Packed `Wᵀ` panels for `W [out, in]`, cached by (tensor id, storage
/// version): in-place weight updates bump the version, invalidating the
/// entry lazily; repacking happens on the next forward.
///
/// The key is the *tensor* id, so the cache helps callers that hold a
/// stable weight handle (`nn::Linear` does). Passing a freshly created
/// view of the weight each call gets a miss every time — equivalent to
/// the old per-call transpose copy, never worse; the byte-bounded LRU
/// keeps such churn from accumulating.
fn packed_weight(w: &Tensor) -> Arc<Vec<f32>> {
    let (out_f, in_f) = (w.size(0), w.size(1));
    let key = w.id();
    let ver = w.version();
    let tick = PACK_TICK.fetch_add(1, Ordering::Relaxed) + 1;
    {
        let mut cache = PACKED_WEIGHTS.lock().unwrap();
        if let Some(e) = cache.get_mut(&key) {
            if e.version == ver && e.in_features == in_f && e.out_features == out_f {
                e.last_used = tick;
                PACK_HITS.fetch_add(1, Ordering::Relaxed);
                return e.data.clone();
            }
        }
    }
    PACK_MISSES.fetch_add(1, Ordering::Relaxed);
    // B = Wᵀ is (in, out): B(p, j) = W(j, p), so B's row stride is W's
    // column stride and vice versa — packed straight from W's layout.
    let st = w.strides();
    let wspan = span(w.shape(), st);
    // SAFETY: read-only view over the weight's full strided span; `w` is
    // a live handle for the duration of the pack.
    let data = unsafe { w.data_ptr().as_slice::<f32>(0, wspan) };
    let packed = Arc::new(pack_b_strided_f32(in_f, out_f, data, st[1], st[0]));
    let mut cache = PACKED_WEIGHTS.lock().unwrap();
    let total_bytes: usize = cache.values().map(|e| e.data.len() * 4).sum();
    if cache.len() >= PACKED_CACHE_CAP || total_bytes + packed.len() * 4 > PACKED_CACHE_MAX_BYTES {
        // Evict least-recently-used entries down to half of each budget:
        // dead tensors' entries go first, live weights mostly survive
        // and avoid a thundering repack.
        let mut by_age: Vec<(u64, u64, usize)> =
            cache.iter().map(|(id, e)| (e.last_used, *id, e.data.len() * 4)).collect();
        by_age.sort_unstable();
        let mut len = cache.len();
        let mut bytes = total_bytes;
        for (_, id, nbytes) in by_age {
            if len <= PACKED_CACHE_CAP / 2 && bytes <= PACKED_CACHE_MAX_BYTES / 2 {
                break;
            }
            cache.remove(&id);
            len -= 1;
            bytes -= nbytes;
        }
    }
    cache.insert(
        key,
        CachedPack {
            version: ver,
            in_features: in_f,
            out_features: out_f,
            data: packed.clone(),
            last_used: tick,
        },
    );
    packed
}

// ---------------------------------------------------------------------
// Kernels + backwards
// ---------------------------------------------------------------------

fn k_matmul(ctx: &OpCtx) -> Tensor {
    matmul_raw(ctx.input(0), ctx.input(1))
}

fn bw_matmul(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (va, vb) = (SavedTensor::save(ctx.input(0)), SavedTensor::save(ctx.input(1)));
    ClosureFunction::new("matmul", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        // dA = G @ Bᵀ ; dB = Aᵀ @ G — `.t()` views, packed in place.
        let ga = matmul_raw(g, &b.t());
        let gb = matmul_raw(&a.t(), g);
        vec![Some(ga), Some(gb)]
    })
}

fn k_bmm(ctx: &OpCtx) -> Tensor {
    bmm_raw(ctx.input(0), ctx.input(1))
}

fn bw_bmm(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (va, vb) = (SavedTensor::save(ctx.input(0)), SavedTensor::save(ctx.input(1)));
    ClosureFunction::new("bmm", move |g| {
        let a = va.unpack();
        let b = vb.unpack();
        // Zero-copy transpose views; the batched kernel reads the strides.
        vec![
            Some(bmm_raw(g, &b.transpose(1, 2))),
            Some(bmm_raw(&a.transpose(1, 2), g)),
        ]
    })
}

/// Fused linear layer: `x [N,in] @ Wᵀ [in,out] + b`, PyTorch weight layout
/// `W [out,in]`. Bias is the optional third input, folded into the GEMM's
/// `beta` pass (the output rows are pre-filled with the bias, then the
/// product accumulates on top) — no separate add, no extra allocation.
fn k_linear(ctx: &OpCtx) -> Tensor {
    let (x, w) = (ctx.input(0), ctx.input(1));
    torsk_assert!(x.ndim() == 2 && w.ndim() == 2, "linear: x 2-D, w 2-D");
    torsk_assert!(
        x.size(1) == w.size(1),
        "linear: in_features {} vs {}",
        x.size(1),
        w.size(1)
    );
    torsk_assert!(
        x.dtype() == w.dtype(),
        "linear: dtype mismatch {} x {}",
        x.dtype(),
        w.dtype()
    );
    let dev = same_device("linear", &[x, w]);
    let (m, k_in) = (x.size(0), x.size(1));
    let n_out = w.size(0);
    let has_bias = ctx.num_inputs() == 3;
    let bias_info = if has_bias {
        let bias = ctx.input(2);
        torsk_assert!(
            bias.shape() == [n_out],
            "linear: bias shape {:?} for {n_out} out features",
            bias.shape()
        );
        torsk_assert!(
            bias.dtype() == x.dtype(),
            "linear: bias dtype {} vs {}",
            bias.dtype(),
            x.dtype()
        );
        Some((bias.data_ptr(), bias.strides()[0]))
    } else {
        None
    };
    let dtype = x.dtype();
    let out = Tensor::empty(&[m, n_out], dtype, dev);
    let op = out.data_ptr();
    let (xp, xs0, xs1, xspan) = gemm_operand2(x);

    match dtype {
        // The hot path: prepacked Wᵀ panels from the process-global cache
        // (CPU only — the cache packs eagerly on the host thread, which
        // must not race queued stream kernels).
        DType::F32 if dev == Device::Cpu && k_in > 0 && n_out > 0 => {
            let packed = packed_weight(w);
            // SAFETY: pointer/length pairs come from shape-checked live tensors
            // captured at enqueue time. On CPU this closure runs inline while the
            // caller's handles are alive; on a stream, the one-pool-per-stream
            // FIFO allocator guarantees freed storage is only reused by kernels
            // enqueued later on the same stream, so the bytes stay valid (and
            // writes exclusive) until this kernel completes.
            device::dispatch(dev, "linear", move || unsafe {
                let ov = op.as_mut_slice::<f32>(0, m * n_out);
                let beta = fill_bias_f32(ov, m, n_out, bias_info);
                sgemm_prepacked(
                    m,
                    n_out,
                    k_in,
                    1.0,
                    xp.as_slice::<f32>(0, xspan),
                    xs0,
                    xs1,
                    &packed,
                    beta,
                    ov,
                );
            });
        }
        DType::F32 => {
            let (wp, ws0, ws1, wspan) = gemm_operand2(w);
            // SAFETY: pointer/length pairs come from shape-checked live tensors
            // captured at enqueue time. On CPU this closure runs inline while the
            // caller's handles are alive; on a stream, the one-pool-per-stream
            // FIFO allocator guarantees freed storage is only reused by kernels
            // enqueued later on the same stream, so the bytes stay valid (and
            // writes exclusive) until this kernel completes.
            device::dispatch(dev, "linear", move || unsafe {
                let ov = op.as_mut_slice::<f32>(0, m * n_out);
                let beta = fill_bias_f32(ov, m, n_out, bias_info);
                // B = Wᵀ: swap W's strides.
                sgemm_strided(
                    m,
                    n_out,
                    k_in,
                    1.0,
                    xp.as_slice::<f32>(0, xspan),
                    xs0,
                    xs1,
                    wp.as_slice::<f32>(0, wspan),
                    ws1,
                    ws0,
                    beta,
                    ov,
                );
            });
        }
        DType::F64 => {
            let (wp, ws0, ws1, wspan) = gemm_operand2(w);
            // SAFETY: pointer/length pairs come from shape-checked live tensors
            // captured at enqueue time. On CPU this closure runs inline while the
            // caller's handles are alive; on a stream, the one-pool-per-stream
            // FIFO allocator guarantees freed storage is only reused by kernels
            // enqueued later on the same stream, so the bytes stay valid (and
            // writes exclusive) until this kernel completes.
            device::dispatch(dev, "linear", move || unsafe {
                let ov = op.as_mut_slice::<f64>(0, m * n_out);
                let beta = fill_bias_f64(ov, m, n_out, bias_info);
                dgemm_strided(
                    m,
                    n_out,
                    k_in,
                    1.0,
                    xp.as_slice::<f64>(0, xspan),
                    xs0,
                    xs1,
                    wp.as_slice::<f64>(0, wspan),
                    ws1,
                    ws0,
                    beta,
                    ov,
                );
            });
        }
        _ => unreachable!("linear schema admits floats only"),
    }
    out
}

/// Pre-fill the output rows with the (possibly strided) bias and return
/// the GEMM `beta` that preserves it (1.0), or 0.0 without a bias.
///
/// # Safety: `bias` must point at `n` elements with the given stride.
unsafe fn fill_bias_f32(
    out: &mut [f32],
    m: usize,
    n: usize,
    bias: Option<(SendPtr, usize)>,
) -> f32 {
    match bias {
        None => 0.0,
        Some((bp, bs)) => {
            for i in 0..m {
                for (j, v) in out[i * n..(i + 1) * n].iter_mut().enumerate() {
                    // SAFETY: j*bs < n*stride per this fn's contract.
                    *v = unsafe { *bp.as_f32().add(j * bs) };
                }
            }
            1.0
        }
    }
}

/// # Safety: as [`fill_bias_f32`].
unsafe fn fill_bias_f64(
    out: &mut [f64],
    m: usize,
    n: usize,
    bias: Option<(SendPtr, usize)>,
) -> f64 {
    match bias {
        None => 0.0,
        Some((bp, bs)) => {
            for i in 0..m {
                for (j, v) in out[i * n..(i + 1) * n].iter_mut().enumerate() {
                    // SAFETY: j*bs < n*stride per this fn's contract.
                    *v = unsafe { *(bp.ptr() as *const f64).add(j * bs) };
                }
            }
            1.0
        }
    }
}

fn bw_linear(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (vx, vw) = (SavedTensor::save(ctx.input(0)), SavedTensor::save(ctx.input(1)));
    let has_bias = ctx.num_inputs() == 3;
    let bias_cols = if has_bias { ctx.input(1).size(0) } else { 0 };
    ClosureFunction::new("linear", move |g| {
        let x = vx.unpack();
        let w = vw.unpack();
        // gx = G @ W ; gw = Gᵀ @ x ; gb = sum rows of G. `g.t()` is a
        // zero-copy view — the kernel packs the transpose in place.
        let gx = matmul_raw(g, &w);
        let gw = matmul_raw(&g.t(), &x);
        let mut grads = vec![Some(gx), Some(gw)];
        if has_bias {
            grads.push(Some(super::reduce::sum_to_shape(g, &[bias_cols])));
        }
        grads
    })
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

use super::OpSample;

fn s_matmul(seed: u64, dt: DType) -> Option<OpSample> {
    let a = super::sample_uniform(seed, &[3, 4], dt, -1.0, 1.0)?;
    let b = super::sample_uniform(seed ^ 0xB0B, &[4, 2], dt, -1.0, 1.0)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_bmm(seed: u64, dt: DType) -> Option<OpSample> {
    let a = super::sample_uniform(seed, &[2, 3, 4], dt, -1.0, 1.0)?;
    let b = super::sample_uniform(seed ^ 0xB0B, &[2, 4, 2], dt, -1.0, 1.0)?;
    Some(OpSample { inputs: vec![a, b], params: vec![], grad_inputs: vec![0, 1] })
}

fn s_linear(seed: u64, dt: DType) -> Option<OpSample> {
    let x = super::sample_uniform(seed, &[3, 4], dt, -1.0, 1.0)?;
    let w = super::sample_uniform(seed ^ 0xB0B, &[2, 4], dt, -1.0, 1.0)?;
    let b = super::sample_uniform(seed ^ 0xBEE, &[2], dt, -0.5, 0.5)?;
    Some(OpSample { inputs: vec![x, w, b], params: vec![], grad_inputs: vec![0, 1, 2] })
}

pub(crate) fn register(reg: &mut Registry) {
    reg.add(
        OpDef::new("matmul", 2, 2, FLOATS)
            .kernel_all(k_matmul)
            .backward(bw_matmul)
            .sample_inputs(s_matmul),
    );
    reg.add(
        OpDef::new("bmm", 2, 2, FLOATS).kernel_all(k_bmm).backward(bw_bmm).sample_inputs(s_bmm),
    );
    reg.add(
        OpDef::new("linear", 2, 3, FLOATS)
            .kernel_all(k_linear)
            .backward(bw_linear)
            .sample_inputs(s_linear),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;
    use crate::tensor::assert_close;

    // NOTE: transposed-view vs materialized parity lives in
    // tests/gemm_parity.rs — this file must stay free of contiguous-copy
    // calls (a source-level pin there enforces it, tests included).

    #[test]
    fn linear_bias_beta_fold_matches_composition() {
        crate::rng::manual_seed(7);
        let x = Tensor::randn(&[6, 9]);
        let w = Tensor::randn(&[4, 9]);
        let b = Tensor::randn(&[4]);
        let y = ops::linear(&x, &w, Some(&b));
        let y2 = ops::add(&ops::matmul(&x, &w.t()), &b);
        assert_close(&y, &y2, 1e-5, 1e-5);
    }

    #[test]
    fn linear_zero_in_features() {
        // k == 0 degenerates to broadcast bias (or zeros without one).
        let x = Tensor::zeros(&[3, 0]);
        let w = Tensor::zeros(&[2, 0]);
        let b = Tensor::from_slice(&[1.5f32, -2.0]);
        let y = ops::linear(&x, &w, Some(&b));
        assert_eq!(y.to_vec::<f32>(), vec![1.5, -2.0, 1.5, -2.0, 1.5, -2.0]);
        let y0 = ops::linear(&x, &w, None);
        assert_eq!(y0.to_vec::<f32>(), vec![0.0; 6]);
    }
}
