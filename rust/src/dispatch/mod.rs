//! The central operator dispatcher — torsk's ATen-style registry (§5.1).
//!
//! Every eager operator is declared **once**, as an [`OpDef`]: a schema
//! (name, arity, dtype constraints) plus per-[`DispatchKey`] kernel
//! entries. The public `ops::*` functions are thin shims over
//! [`call`], which is the single choke point that
//!
//! 1. validates the schema (arity, dtype support, same-device),
//! 2. resolves the backend key from the inputs' device (`Cpu` or `Sim`),
//! 3. emits a host-track profiler span for *every* op with zero per-op
//!    code (the §6.1 instrumentation comes for free), and
//! 4. composes the `Autograd` wrapping key: when recording is on and the
//!    op registered a backward builder, the output's `grad_fn` is recorded
//!    uniformly — individual ops no longer hand-roll
//!    `autograd::record(...)` boilerplate.
//!
//! Broadcasting and dtype promotion are resolved by the shared
//! [`iter::TensorIter`] helper, so F32, F64 and I64 run through the same
//! registry entries instead of per-op `f32 only` asserts.
//!
//! # Threading and memory model
//!
//! The eager hot path is multi-threaded and allocation-light (§5.1/§5.3
//! "careful and pragmatic implementation of the key components of its
//! runtime"). The rules, in one place:
//!
//! **Grain sizes.** Every TensorIter plan (Fast/Suffix/Strided) and every
//! reduction splits its index space over `kernels::parallel_for`, staying
//! serial below [`crate::kernels::SERIAL_GRAIN`] (~32k) elements — below
//! that, pool wakeups cost more than they save. Suffix/row drivers convert
//! the grain to rows (`SERIAL_GRAIN / inner`); the packed GEMM splits a
//! 2-D (row block × column block) task grid so tall-skinny *and* wide
//! matmuls fill every core. The thread count comes from
//! `PALLAS_NUM_THREADS` (read once) and can be swept at runtime with
//! [`crate::kernels::set_num_threads`].
//!
//! **Determinism.** Parallel reductions are bit-for-bit identical at every
//! thread count, by construction rather than by schedule: row/column
//! reductions give each output element exactly one owning task that folds
//! serially in index order, and flat reductions (`sum`, losses) use
//! fixed-width chunks ([`iter::REDUCE_CHUNK`], a constant) whose partials
//! combine serially in chunk order. The packed GEMM core follows the same
//! rule: its tile grid and k-panel walk derive only from `(m, n, k)` and
//! fixed blocking constants (see "GEMM design" in the `kernels` module
//! docs). Nothing derives a partial-sum boundary from the thread count.
//! `tests/parallel_determinism.rs` pins this at `PALLAS_NUM_THREADS` =
//! 1, 2 and 8.
//!
//! **Output-stealing.** [`call_owned`] lets an op's output steal a dead
//! input's storage instead of allocating (PyTorch's `resize_`/`out=`
//! trick, automated at the dispatch layer). An input is donated only when
//! (1) the op is registered `reuse_output` (elementwise, index-aligned,
//! dtype-preserving), (2) no autograd recording will happen, (3) every
//! live handle to the tensor was moved into the call and nothing else
//! shares its storage (non-view, offset 0), and (4) all operands are
//! contiguous with one shape and dtype, so the kernel runs the
//! index-aligned Fast plan. Owned operator overloads (`a + &b`), the
//! backward engine's gradient accumulation and the composite loss/norm
//! kernels all route through it; everything else allocates through the
//! per-device [`crate::alloc::caching::CachingAllocator`].
//!
//! **Reading `BENCH_ops.json`** (emitted by `make bench`, schema
//! `torsk.bench_ops.v1`): one record per (op, size, threads) with
//! `ns_per_iter` (wall time), `bytes_allocated` (allocator bytes handed
//! out per iteration — cache hits included, stolen outputs excluded),
//! `cache_hit_rate` (host caching-allocator hits over the window) and
//! `reused_outputs` (storages stolen per iteration). Compare `threads=1`
//! vs `threads=4` rows at the same size for scaling, and the
//! `mlp_train_loop` record for the steady-state allocator story.
//!
//! # Fusion
//!
//! Composite hot paths (the BCE/MSE loss chains, the layer-norm
//! scale/shift tail, GELU, optimizer updates) used to run as 4–8
//! separately dispatched TensorIter passes, re-touching the same buffers
//! every time. The [`fuse`] module collapses each chain into ONE pass:
//!
//! * **Tape format.** A fused kernel is a [`fuse::Tape`] — a constant-
//!   folded stack program of micro-ops ([`fuse::MicroOp`]: load input /
//!   push constant / dup / swap / unary / binary) interpreted per element
//!   inside a single `parallel_for` loop. Tapes are built once, at
//!   registration time, with [`fuse::Tape::build`]'s builder; stack depth
//!   and operand arity are checked as the tape is composed. Map-reduce
//!   tapes (losses) fold their per-element values with the same fixed
//!   [`iter::REDUCE_CHUNK`]-wide partials as the unfused reduction
//!   driver, so they stay bit-identical at every thread count.
//! * **Registering a fused composite.** Declare an `OpDef` named
//!   `fused:<name>` whose kernel runs the tape via the `fuse` drivers,
//!   attach a `BackwardFn` whose gradients are tapes too (one fused
//!   autograd node instead of a chain), and register it like any other
//!   op. The profiler then emits one `op:fused:<name>` span per call.
//! * **Fused vs unfused.** The composite wrappers (`mse_loss`,
//!   `bce_loss`, `layer_norm`, the optimizers) delegate to the fused
//!   entry whenever the operand shapes/dtypes fit its tape (same-shape
//!   float operands; `[.., 1]` row stats and `[d]` affine vectors are
//!   expressed as tape access patterns, not materialized broadcasts).
//!   Anything else — and user code composing `ops::*` directly — takes
//!   the generic unfused TensorIter path. Both paths are pinned
//!   bit-for-bit equal in `tests/fused_parity.rs`.
//!
//! # Registering a new op
//!
//! A new operator (or a new backend for an existing one) is a registry
//! entry, not a code audit. Every op must declare a
//! [`OpDef::sample_inputs`] generator — the OpInfo machinery
//! (`tests/opinfo.rs`) uses it to smoke-call and numerically gradcheck
//! every registered op at F32 and F64; registration panics without one:
//!
//! ```no_run
//! use torsk::dispatch::{self, DispatchKey, OpCtx, OpDef, OpSample, Param};
//! use torsk::tensor::{DType, Tensor};
//!
//! // 1. A kernel: host resolves shapes, computes (or queues) the result.
//! fn shifted_relu(ctx: &OpCtx) -> Tensor {
//!     let x = ctx.input(0);
//!     let shift = ctx.f32(0);
//!     // Compose existing dispatched ops, or write a raw kernel.
//!     torsk::ops::relu(&torsk::ops::add_scalar(x, shift))
//! }
//!
//! // 2. An OpInfo sample: one generated invocation per (seed, dtype).
//! fn shifted_relu_samples(seed: u64, dt: DType) -> Option<OpSample> {
//!     let x = dispatch::sample_uniform(seed, &[2, 3], dt, 0.2, 2.0)?;
//!     Some(OpSample { inputs: vec![x], params: vec![Param::F32(1.0)], grad_inputs: vec![0] })
//! }
//!
//! // 3. One declaration: schema + per-key kernels (+ optional backward).
//! dispatch::register_op(
//!     OpDef::new("shifted_relu", 1, 1, &[DType::F32, DType::F64])
//!         .kernel(DispatchKey::Cpu, shifted_relu)
//!         .kernel(DispatchKey::Sim, shifted_relu)
//!         .sample_inputs(shifted_relu_samples),
//! );
//!
//! // 4. Call it — profiling, device routing and schema checks are free.
//! let y = dispatch::call("shifted_relu", &[&Tensor::ones(&[4])], &[Param::F32(1.0)]);
//! assert_eq!(y.shape(), &[4]);
//! ```

pub mod capture;
pub(crate) mod conv;
pub(crate) mod elementwise;
pub mod fuse;
pub(crate) mod index;
pub(crate) mod inplace;
pub(crate) mod iter;
pub(crate) mod linalg;
pub(crate) mod loss;
pub(crate) mod norm;
pub(crate) mod pool;
pub(crate) mod reduce;
pub(crate) mod views;

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::autograd::{self, Function};
use crate::device::Device;
use crate::profiler;
use crate::tensor::{storage, DType, Tensor};
use crate::{torsk_assert, torsk_bail};

pub use capture::{capture_stats, CaptureStats, GraphCapture, SessionStats};
pub use linalg::{gemm_materialization_stats, packed_weight_stats};

// ---------------------------------------------------------------------
// Keys
// ---------------------------------------------------------------------

/// Dispatch keys, highest priority first. `Autograd` is a *wrapping* key:
/// it does not select a kernel but wraps the backend call with graph
/// recording. `Sim` and `Cpu` are backend keys selecting kernel table
/// entries.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DispatchKey {
    /// Graph-recording wrapper (active when grad mode is on and an input
    /// requires grad).
    Autograd,
    /// Simulated-accelerator backend: kernels queue on the current stream.
    Sim,
    /// Host backend: kernels run inline on the calling thread.
    Cpu,
}

/// Number of backend (kernel-table) keys.
const NUM_BACKEND_KEYS: usize = 2;

impl DispatchKey {
    /// The backend key serving tensors on `device`.
    pub fn for_device(d: Device) -> DispatchKey {
        match d {
            Device::Cpu => DispatchKey::Cpu,
            Device::Sim => DispatchKey::Sim,
        }
    }

    fn backend_index(self) -> usize {
        match self {
            DispatchKey::Cpu => 0,
            DispatchKey::Sim => 1,
            DispatchKey::Autograd => {
                crate::torsk_bail!("Autograd is a wrapping key, not a backend kernel slot")
            }
        }
    }
}

/// The key stack [`call`] walks for a given op invocation (diagnostics /
/// tests): `[Autograd, backend]` when recording would happen, else
/// `[backend]`.
pub fn key_stack(inputs: &[&Tensor]) -> Vec<DispatchKey> {
    let mut keys = Vec::with_capacity(2);
    if autograd::should_record(inputs) {
        keys.push(DispatchKey::Autograd);
    }
    if let Some(first) = inputs.first() {
        keys.push(DispatchKey::for_device(first.device()));
    }
    keys
}

// ---------------------------------------------------------------------
// Non-tensor op arguments
// ---------------------------------------------------------------------

/// A non-tensor operator argument (the boxed-scalar side of an op call).
#[derive(Clone, Debug)]
pub enum Param {
    F32(f32),
    F64(f64),
    I64(i64),
    Usize(usize),
    Bool(bool),
    UsizeList(Vec<usize>),
    DType(DType),
}

// ---------------------------------------------------------------------
// Op call context
// ---------------------------------------------------------------------

/// Everything a kernel (and a backward builder) sees about one op call:
/// tensor inputs, scalar params, resolved device, plus a stash for
/// forward-computed intermediates the backward pass needs
/// (`save`/`saved` — PyTorch's `ctx.save_for_backward`).
pub struct OpCtx<'a> {
    pub inputs: &'a [&'a Tensor],
    pub params: &'a [Param],
    pub device: Device,
    saved: RefCell<Vec<Tensor>>,
}

impl<'a> OpCtx<'a> {
    fn new(inputs: &'a [&'a Tensor], params: &'a [Param], device: Device) -> OpCtx<'a> {
        OpCtx { inputs, params, device, saved: RefCell::new(Vec::new()) }
    }

    /// Tensor input `i`.
    #[inline]
    pub fn input(&self, i: usize) -> &Tensor {
        self.inputs[i]
    }

    /// Number of tensor inputs (for ops with optional inputs, e.g. bias).
    #[inline]
    pub fn num_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Scalar param `i` as f32.
    pub fn f32(&self, i: usize) -> f32 {
        match self.param(i) {
            Param::F32(v) => *v,
            p => torsk_bail!("param {i}: expected f32, got {p:?}"),
        }
    }

    /// Scalar param `i` widened to f64 (accepts `F32` — exact — or `F64`).
    /// Kernels that instantiate per-dtype read through this so F64 tensors
    /// never lose scalar precision to an f32 round-trip.
    pub fn scalar(&self, i: usize) -> f64 {
        match self.param(i) {
            Param::F32(v) => *v as f64,
            Param::F64(v) => *v,
            p => torsk_bail!("param {i}: expected a float scalar, got {p:?}"),
        }
    }

    /// Scalar param `i` as usize.
    pub fn usize(&self, i: usize) -> usize {
        match self.param(i) {
            Param::Usize(v) => *v,
            p => torsk_bail!("param {i}: expected usize, got {p:?}"),
        }
    }

    /// Scalar param `i` as bool.
    pub fn bool(&self, i: usize) -> bool {
        match self.param(i) {
            Param::Bool(v) => *v,
            p => torsk_bail!("param {i}: expected bool, got {p:?}"),
        }
    }

    /// Param `i` as a usize list (dims, kernel sizes).
    pub fn usize_list(&self, i: usize) -> &[usize] {
        match self.param(i) {
            Param::UsizeList(v) => v,
            p => torsk_bail!("param {i}: expected usize list, got {p:?}"),
        }
    }

    /// Param `i` as a dtype.
    pub fn dtype(&self, i: usize) -> DType {
        match self.param(i) {
            Param::DType(v) => *v,
            p => torsk_bail!("param {i}: expected dtype, got {p:?}"),
        }
    }

    fn param(&self, i: usize) -> &Param {
        match self.params.get(i) {
            Some(p) => p,
            None => torsk_bail!("op called with {} params, kernel wants index {i}", self.params.len()),
        }
    }

    /// Stash a forward-computed intermediate for the backward builder
    /// (max-pool indices, batch-norm statistics, ...).
    pub fn save(&self, t: Tensor) {
        self.saved.borrow_mut().push(t);
    }

    /// Retrieve stash entry `i` (in `save` order).
    pub fn saved(&self, i: usize) -> Tensor {
        match self.saved.borrow().get(i) {
            Some(t) => t.clone(),
            None => torsk_bail!("backward wants saved tensor {i}, only {} stashed", self.saved.borrow().len()),
        }
    }
}

// ---------------------------------------------------------------------
// Schema + definition
// ---------------------------------------------------------------------

/// A kernel entry: resolves shapes on the host, allocates the output and
/// computes inline (CPU) or queues the computation (Sim).
pub type KernelFn = fn(&OpCtx) -> Tensor;

/// A backward builder: called at record time with the op context and the
/// forward output; returns the backward [`Function`] whose `backward`
/// yields one gradient per tensor input (in input order).
pub type BackwardFn = fn(&OpCtx, &Tensor) -> Box<dyn Function>;

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

/// One generated invocation of an op, produced by its
/// [`OpDef::sample_inputs`] generator: the TorchBench-style OpInfo record
/// that lets `tests/opinfo.rs` smoke-call and numerically gradcheck every
/// registered op without per-op test code.
pub struct OpSample {
    /// Tensor inputs, in schema order.
    pub inputs: Vec<Tensor>,
    /// Scalar params, in kernel order.
    pub params: Vec<Param>,
    /// Indices of `inputs` whose gradients are numerically checked.
    /// Empty = the op is not differentiable (or not via this sample).
    pub grad_inputs: Vec<usize>,
}

/// Sample generator: `(seed, dtype)` → one invocation, or `None` when the
/// op does not support that dtype (f32-only kernels return `None` at F64).
/// Distinct seeds must yield distinct data so gradcheck covers more than
/// one point.
pub type SampleFn = fn(u64, DType) -> Option<OpSample>;

/// Everything `tests/opinfo.rs` needs about one registered op.
pub struct OpInfo {
    pub name: &'static str,
    pub min_inputs: usize,
    pub max_inputs: usize,
    /// The op registered a [`BackwardFn`] (composite ops without one can
    /// still be differentiable through their inner recorded calls — the
    /// sample's `grad_inputs` is the source of truth for gradcheck).
    pub has_backward: bool,
    pub sample: SampleFn,
}

/// OpInfo metadata for a registered op (None if the name is unknown).
pub fn op_info(name: &str) -> Option<OpInfo> {
    let def = { REGISTRY.read().unwrap().ops.get(name).copied() }?;
    Some(OpInfo {
        name: def.schema.name,
        min_inputs: def.schema.min_inputs,
        max_inputs: def.schema.max_inputs,
        has_backward: def.backward.is_some(),
        sample: def.samples.expect("registration enforces samples"),
    })
}

fn sample_rng(seed: u64) -> crate::rng::Rng {
    crate::rng::Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15))
}

/// Sample helper: uniform values in `[lo, hi)` at `dt` (`None` for I64 —
/// float samples only; integer inputs use [`sample_indices`]).
pub fn sample_uniform(seed: u64, shape: &[usize], dt: DType, lo: f32, hi: f32) -> Option<Tensor> {
    let mut r = sample_rng(seed);
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| r.uniform_range(lo, hi)).collect();
    match dt {
        DType::F32 => Some(Tensor::from_vec(data, shape)),
        DType::F64 => {
            Some(Tensor::from_vec(data.into_iter().map(|v| v as f64).collect::<Vec<f64>>(), shape))
        }
        DType::I64 => None,
    }
}

/// Sample helper: uniform magnitudes in `[margin, margin+span)` with
/// random signs — keeps gradcheck away from kinks at zero (relu, abs).
pub fn sample_away_from_zero(
    seed: u64,
    shape: &[usize],
    dt: DType,
    margin: f32,
    span: f32,
) -> Option<Tensor> {
    let mut r = sample_rng(seed);
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n)
        .map(|_| {
            let v = r.uniform_range(margin, margin + span);
            if r.bernoulli(0.5) {
                -v
            } else {
                v
            }
        })
        .collect();
    match dt {
        DType::F32 => Some(Tensor::from_vec(data, shape)),
        DType::F64 => {
            Some(Tensor::from_vec(data.into_iter().map(|v| v as f64).collect::<Vec<f64>>(), shape))
        }
        DType::I64 => None,
    }
}

/// Sample helper: strictly distinct values (max/argmax samples must not
/// tie, or the finite difference straddles the tie-break).
pub fn sample_distinct(seed: u64, shape: &[usize], dt: DType) -> Option<Tensor> {
    let mut r = sample_rng(seed);
    let n: usize = shape.iter().product();
    let mut order: Vec<usize> = (0..n).collect();
    r.shuffle(&mut order);
    let mut data = vec![0.0f32; n];
    for (rank, &i) in order.iter().enumerate() {
        data[i] = rank as f32 * 0.5 + r.uniform_range(0.0, 0.2) - n as f32 * 0.125;
    }
    match dt {
        DType::F32 => Some(Tensor::from_vec(data, shape)),
        DType::F64 => {
            Some(Tensor::from_vec(data.into_iter().map(|v| v as f64).collect::<Vec<f64>>(), shape))
        }
        DType::I64 => None,
    }
}

/// Sample helper: i64 indices in `[0, hi)`.
pub fn sample_indices(seed: u64, shape: &[usize], hi: usize) -> Tensor {
    let mut r = sample_rng(seed);
    let n: usize = shape.iter().product();
    let data: Vec<i64> = (0..n).map(|_| r.below(hi as u64) as i64).collect();
    Tensor::from_vec(data, shape)
}

/// Declared call signature of an op.
#[derive(Clone, Copy, Debug)]
pub struct OpSchema {
    pub name: &'static str,
    pub min_inputs: usize,
    pub max_inputs: usize,
    /// Allowed dtypes of the primary (first) input. Empty slice = any.
    pub dtypes: &'static [DType],
}

impl OpSchema {
    fn check(&self, inputs: &[&Tensor]) {
        torsk_assert!(
            inputs.len() >= self.min_inputs && inputs.len() <= self.max_inputs,
            "{}: expected {}..={} tensor inputs, got {}",
            self.name,
            self.min_inputs,
            self.max_inputs,
            inputs.len()
        );
        if !self.dtypes.is_empty() {
            let dt = inputs[0].dtype();
            if !self.dtypes.contains(&dt) {
                let supported: Vec<&str> = self.dtypes.iter().map(|d| d.name()).collect();
                torsk_bail!(
                    "{}: unsupported dtype {} (supported: {})",
                    self.name,
                    dt,
                    supported.join(", ")
                );
            }
        }
    }
}

/// One operator: schema + per-backend kernels + optional backward builder.
///
/// Ops whose kernel *composes* other dispatched ops (layer-norm, losses)
/// register no backward: their gradient graph is built by the inner calls.
/// Fused ops register a [`BackwardFn`] and get recording for free.
#[derive(Clone, Copy)]
pub struct OpDef {
    pub schema: OpSchema,
    kernels: [Option<KernelFn>; NUM_BACKEND_KEYS],
    backward: Option<BackwardFn>,
    /// Kernel reads input element `i` only to produce output element `i`
    /// when all operands share the output's shape (the TensorIter Fast
    /// plan) — the precondition for [`call_owned`]'s output-stealing.
    reuse_output: bool,
    /// OpInfo sample generator — mandatory; registration panics without
    /// one, so no op can dodge the auto-generated gradcheck suite.
    samples: Option<SampleFn>,
}

impl OpDef {
    /// Start declaring an op: name, input arity range, allowed dtypes of
    /// the first input (empty = any).
    pub fn new(
        name: &'static str,
        min_inputs: usize,
        max_inputs: usize,
        dtypes: &'static [DType],
    ) -> OpDef {
        OpDef {
            schema: OpSchema { name, min_inputs, max_inputs, dtypes },
            kernels: [None; NUM_BACKEND_KEYS],
            backward: None,
            reuse_output: false,
            samples: None,
        }
    }

    /// Attach the mandatory OpInfo sample generator (see [`OpSample`]).
    pub fn sample_inputs(mut self, f: SampleFn) -> OpDef {
        self.samples = Some(f);
        self
    }

    /// Declare the op safe for output-stealing (see the `reuse_output`
    /// field): elementwise, index-aligned, dtype-preserving kernels only.
    pub fn reuse_output(mut self) -> OpDef {
        self.reuse_output = true;
        self
    }

    /// Attach a kernel for one backend key.
    pub fn kernel(mut self, key: DispatchKey, f: KernelFn) -> OpDef {
        self.kernels[key.backend_index()] = Some(f);
        self
    }

    /// Attach the same kernel for every backend key (the common case: the
    /// kernel body is queued or run inline by `device::dispatch`).
    pub fn kernel_all(mut self, f: KernelFn) -> OpDef {
        for slot in self.kernels.iter_mut() {
            *slot = Some(f);
        }
        self
    }

    /// Attach the backward builder (enables the Autograd wrapping key).
    pub fn backward(mut self, f: BackwardFn) -> OpDef {
        self.backward = Some(f);
        self
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// The op registry. Built once with the built-in ops; extendable at
/// runtime via [`register_op`].
pub struct Registry {
    ops: HashMap<&'static str, OpDef>,
}

impl Registry {
    /// Insert an op definition; duplicate names and sample-less ops are
    /// bugs (every op must be reachable by the OpInfo gradcheck suite).
    pub fn add(&mut self, def: OpDef) {
        let name = def.schema.name;
        torsk_assert!(
            def.samples.is_some(),
            "op '{name}' registered without sample_inputs — every op must provide OpInfo samples"
        );
        torsk_assert!(
            self.ops.insert(name, def).is_none(),
            "op '{name}' registered twice"
        );
    }
}

static REGISTRY: once_cell::sync::Lazy<RwLock<Registry>> = once_cell::sync::Lazy::new(|| {
    let mut r = Registry { ops: HashMap::new() };
    elementwise::register(&mut r);
    linalg::register(&mut r);
    reduce::register(&mut r);
    loss::register(&mut r);
    conv::register(&mut r);
    pool::register(&mut r);
    norm::register(&mut r);
    index::register(&mut r);
    inplace::register(&mut r);
    views::register(&mut r);
    fuse::register(&mut r);
    RwLock::new(r)
});

/// Register an additional operator at runtime (new ops, new backends).
/// Like the built-ins, runtime ops must carry [`OpDef::sample_inputs`].
pub fn register_op(def: OpDef) {
    let name = def.schema.name;
    torsk_assert!(
        def.samples.is_some(),
        "op '{name}' registered without sample_inputs — every op must provide OpInfo samples"
    );
    // Check-then-insert without panicking under the lock (a poisoned
    // registry would take every subsequent op call down with it).
    let duplicate = {
        let mut reg = REGISTRY.write().unwrap();
        if reg.ops.contains_key(name) {
            true
        } else {
            reg.ops.insert(name, def);
            false
        }
    };
    torsk_assert!(!duplicate, "op '{name}' registered twice");
}

/// Is an op with this name registered?
pub fn has_op(name: &str) -> bool {
    REGISTRY.read().unwrap().ops.contains_key(name)
}

/// Sorted names of all registered ops.
pub fn op_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = REGISTRY.read().unwrap().ops.keys().copied().collect();
    names.sort_unstable();
    names
}

/// Check all tensors share a device; return it. Mirrors PyTorch's
/// "expected all tensors on the same device" error.
pub(crate) fn same_device(name: &str, tensors: &[&Tensor]) -> Device {
    let d = tensors[0].device();
    for t in tensors.iter().skip(1) {
        torsk_assert!(
            t.device() == d,
            "{name}: expected all tensors to be on the same device, found {} and {}",
            d,
            t.device()
        );
    }
    d
}

// ---------------------------------------------------------------------
// The choke point
// ---------------------------------------------------------------------

/// Invoke operator `name` on `inputs` with scalar `params`.
///
/// This is the single path every eager op takes: schema validation, key
/// resolution, per-op profiling and uniform autograd recording live here,
/// once, instead of in ~40 op bodies.
pub fn call(name: &str, inputs: &[&Tensor], params: &[Param]) -> Tensor {
    call_with(resolve(name), name, inputs, params)
}

/// One registry round-trip: look `name` up or panic with the catalog.
fn resolve(name: &str) -> OpDef {
    let def = { REGISTRY.read().unwrap().ops.get(name).copied() };
    match def {
        Some(d) => d,
        None => {
            let known = op_names().join(", ");
            torsk_bail!("no operator named '{name}' is registered (known ops: {known})");
        }
    }
}

/// [`call`] after registry resolution — shared with [`call_owned`], which
/// needs the `OpDef` up front (for the `reuse_output` flag) and must not
/// pay a second lock/lookup on the per-op hot path.
fn call_with(def: OpDef, name: &str, inputs: &[&Tensor], params: &[Param]) -> Tensor {
    torsk_assert!(!inputs.is_empty(), "{name}: ops take at least one tensor input");
    def.schema.check(inputs);
    let device = same_device(name, inputs);
    let key = DispatchKey::for_device(device);
    let kernel = match def.kernels[key.backend_index()] {
        Some(k) => k,
        None => torsk_bail!("op '{name}' has no kernel registered for dispatch key {key:?}"),
    };

    // Free per-op profiling: one host span per dispatched op. The span name
    // is only materialized when the profiler is recording.
    let span = if profiler::enabled() {
        Some(profiler::begin(profiler::Track::Host, &format!("op:{name}")))
    } else {
        None
    };

    // Graph capture (tracing DispatchKey): remember how many trace nodes
    // exist before the kernel runs, so composite kernels that dispatch
    // nested ops record only their primitive leaves (the nested calls bump
    // the count, and `trace_op` then declines the composite frame).
    let mark = capture::trace_mark();

    let ctx = OpCtx::new(inputs, params, device);
    let out = kernel(&ctx);

    // Sanitizer: output-aliases-input only in the declared patterns
    // (in-place handle return, or reuse_output in the Fast-plan shape).
    #[cfg(feature = "debug-checks")]
    crate::debug_checks::verify_output_aliasing(def.reuse_output, name, inputs, &out);

    // The Autograd wrapping key: uniform graph recording.
    if let Some(bw) = def.backward {
        if autograd::should_record(inputs) {
            autograd::record(inputs, &out, || bw(&ctx, &out));
        }
    }

    capture::trace_op(name, inputs, &out, params, mark);

    if let Some(s) = span {
        profiler::end(s);
    }
    out
}

// ---------------------------------------------------------------------
// Output-stealing (allocation-free op outputs)
// ---------------------------------------------------------------------

static REUSE_ATTEMPTS: AtomicU64 = AtomicU64::new(0);
static REUSE_HITS: AtomicU64 = AtomicU64::new(0);

/// `(donations armed, outputs that actually stole an input's storage)`
/// since process start — the "allocation-free outputs" counters reported
/// in `BENCH_ops.json`.
pub fn output_reuse_stats() -> (u64, u64) {
    (REUSE_ATTEMPTS.load(Ordering::Relaxed), REUSE_HITS.load(Ordering::Relaxed))
}

/// Disarms any unconsumed donation when the op returns (or panics) and
/// counts a hit when the kernel consumed it.
struct DonationGuard {
    armed: bool,
}

impl Drop for DonationGuard {
    fn drop(&mut self) {
        if self.armed && storage::disarm_donation().is_none() {
            REUSE_HITS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Like [`call`], but takes *ownership* of its tensor inputs, which lets
/// the dispatcher prove an input dead and let the output steal its
/// storage — PyTorch's `resize_`/`out=` trick automated at the dispatch
/// layer, so every `reuse_output` op gets it for free.
///
/// An input's buffer is donated only when every condition holds:
///
/// 1. the op is registered [`OpDef::reuse_output`] (elementwise,
///    index-aligned, dtype-preserving kernels);
/// 2. no autograd recording will happen (`should_record` is false) — a
///    recorded op may save inputs for backward;
/// 3. the input is provably dead: moved in by value with no other handle
///    (`Arc::strong_count == 1`) and no other tensor sharing the storage
///    (`ref_count == 1`, offset 0) — a caller who still needs a tensor
///    necessarily holds a clone, which disqualifies it automatically;
/// 4. all operands are contiguous with the same shape and dtype, so the
///    kernel runs the Fast plan and writes out[i] only after reading
///    in[i].
///
/// When no input qualifies this degrades to a plain [`call`]; the
/// borrowed-input shims (`ops::add(&a, &b)`) always clone handles and
/// therefore never donate.
pub fn call_owned(name: &str, inputs: Vec<Tensor>, params: &[Param]) -> Tensor {
    let def = resolve(name);
    let guard = DonationGuard { armed: maybe_donate(&def, &inputs) };
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let out = call_with(def, name, &refs, params);
    drop(refs);
    drop(guard);
    out
}

fn maybe_donate(def: &OpDef, inputs: &[Tensor]) -> bool {
    if !def.reuse_output || inputs.is_empty() {
        return false;
    }
    // should_record, without building a temporary &Tensor slice on the
    // per-op hot path.
    if autograd::grad_enabled() && inputs.iter().any(|t| t.requires_grad_flag()) {
        return false;
    }
    let dt = inputs[0].dtype();
    let shape = inputs[0].shape();
    if inputs.iter().any(|t| t.dtype() != dt || t.shape() != shape || !t.is_contiguous()) {
        return false;
    }
    for t in inputs {
        // Dead after the op: every live handle to this tensor is inside
        // `inputs` (covers `x * x` self-products, where the same impl
        // appears twice) and nothing else shares the storage.
        let occurrences = inputs.iter().filter(|u| Arc::ptr_eq(&u.inner, &t.inner)).count();
        let sole_owner =
            Arc::strong_count(&t.inner) == occurrences && t.storage().ref_count() == 1;
        if sole_owner && t.storage_offset() == 0 {
            storage::arm_donation(t.storage().clone());
            REUSE_ATTEMPTS.fetch_add(1, Ordering::Relaxed);
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_core_ops() {
        for op in ["add", "mul", "matmul", "sum", "relu", "conv2d", "cross_entropy"] {
            assert!(has_op(op), "missing builtin op {op}");
        }
        assert!(!has_op("definitely_not_an_op"));
    }

    #[test]
    fn op_names_sorted_nonempty() {
        let names = op_names();
        assert!(names.len() >= 30, "expected a full registry, got {}", names.len());
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
    }

    #[test]
    #[should_panic(expected = "no operator named")]
    fn unknown_op_panics_with_catalog() {
        let a = Tensor::ones(&[1]);
        call("definitely_not_an_op", &[&a], &[]);
    }

    /// Minimal sample generator for runtime-registered test ops.
    fn test_samples(seed: u64, dt: DType) -> Option<OpSample> {
        let x = sample_uniform(seed, &[3], dt, -1.0, 1.0)?;
        Some(OpSample { inputs: vec![x], params: vec![], grad_inputs: vec![] })
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_registration_panics() {
        register_op(OpDef::new("add", 2, 2, &[]).sample_inputs(test_samples));
    }

    #[test]
    #[should_panic(expected = "without sample_inputs")]
    fn sampleless_registration_panics() {
        register_op(OpDef::new("test_no_samples", 1, 1, &[]));
    }

    #[test]
    fn op_info_exposes_samples_for_every_op() {
        for name in op_names() {
            let info = op_info(name).expect("registered op has info");
            assert_eq!(info.name, name);
            // Every op yields at least one sample at F32 or (i64-input
            // ops) declares itself via a canonical F32-keyed sample.
            let any = (info.sample)(0, DType::F32).is_some()
                || (info.sample)(0, DType::F64).is_some();
            assert!(any, "op '{name}' produced no sample at any float dtype");
        }
        assert!(op_info("not_an_op").is_none());
    }

    #[test]
    fn key_stack_reflects_autograd_and_device() {
        let a = Tensor::ones(&[2]);
        assert_eq!(key_stack(&[&a]), vec![DispatchKey::Cpu]);
        let g = Tensor::ones(&[2]).requires_grad(true);
        assert_eq!(key_stack(&[&g]), vec![DispatchKey::Autograd, DispatchKey::Cpu]);
        let s = Tensor::ones(&[2]).to_sim();
        assert_eq!(key_stack(&[&s]), vec![DispatchKey::Sim]);
    }

    #[test]
    fn register_and_call_custom_op() {
        fn double(ctx: &OpCtx) -> Tensor {
            crate::ops::mul_scalar(ctx.input(0), 2.0)
        }
        register_op(
            OpDef::new("test_double", 1, 1, &[DType::F32])
                .kernel(DispatchKey::Cpu, double)
                .kernel(DispatchKey::Sim, double)
                .sample_inputs(test_samples),
        );
        let y = call("test_double", &[&Tensor::from_slice(&[1.5f32])], &[]);
        assert_eq!(y.to_vec::<f32>(), vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "no kernel registered for dispatch key Sim")]
    fn missing_backend_kernel_panics() {
        fn id(ctx: &OpCtx) -> Tensor {
            ctx.input(0).clone()
        }
        register_op(
            OpDef::new("test_cpu_only", 1, 1, &[])
                .kernel(DispatchKey::Cpu, id)
                .sample_inputs(test_samples),
        );
        let a = Tensor::ones(&[1]).to_sim();
        call("test_cpu_only", &[&a], &[]);
    }

    #[test]
    #[should_panic(expected = "unsupported dtype")]
    fn dtype_mismatch_panics() {
        let idx = Tensor::from_vec(vec![1i64], &[1]);
        call("relu", &[&idx], &[]);
    }

    #[test]
    fn call_owned_steals_dead_input_storage() {
        // Large enough to run the parallel in-place Fast path.
        let n = 100_000;
        let a = Tensor::from_vec(vec![1.0f32; n], &[n]);
        let b = Tensor::from_vec(vec![2.0f32; n], &[n]);
        let ptr = a.storage().ptr() as usize;
        let (_, hits_before) = output_reuse_stats();
        let out = call_owned("add", vec![a, b], &[]);
        assert_eq!(out.storage().ptr() as usize, ptr, "output must steal a's buffer");
        let v = out.to_vec::<f32>();
        assert!(v.iter().all(|&x| x == 3.0));
        assert!(output_reuse_stats().1 > hits_before);
    }

    #[test]
    fn call_owned_never_steals_live_or_recorded_inputs() {
        let a = Tensor::from_vec(vec![1.0f32; 4096], &[4096]);
        let keep = a.clone();
        let b = Tensor::from_vec(vec![2.0f32; 4096], &[4096]);
        let out = call_owned("add", vec![a, b.clone()], &[]);
        // `keep` still references `a` and `b` was cloned: neither may be
        // clobbered, the caller's data stays intact.
        assert!(!out.shares_storage(&keep) && !out.shares_storage(&b));
        assert!(keep.to_vec::<f32>().iter().all(|&x| x == 1.0));
        assert!(out.to_vec::<f32>().iter().all(|&x| x == 3.0));

        // Autograd recording disables stealing even for a moved-in sole
        // owner (backward may need the input / saved output).
        let g = Tensor::from_vec(vec![-1.0f32; 4096], &[4096]).requires_grad(true);
        let out = call_owned("relu", vec![g], &[]);
        assert!(out.grad_fn().is_some());
        assert!(out.to_vec::<f32>().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn call_owned_skips_broadcast_and_mixed_dtype() {
        let a = Tensor::from_vec(vec![1.0f32; 64 * 64], &[64, 64]);
        let aptr = a.storage().ptr() as usize;
        let row = Tensor::from_vec(vec![1.0f32; 64], &[64]);
        let out = call_owned("add", vec![a, row], &[]);
        assert_ne!(out.storage().ptr() as usize, aptr, "broadcast op must not steal");

        let x = Tensor::from_vec(vec![1.0f32; 256], &[256]);
        let xptr = x.storage().ptr() as usize;
        let y = Tensor::from_vec(vec![1.0f64; 256], &[256]);
        let out = call_owned("add", vec![x, y], &[]);
        assert_eq!(out.dtype(), DType::F64);
        assert_ne!(out.storage().ptr() as usize, xptr, "promoting op must not steal");
    }
}
