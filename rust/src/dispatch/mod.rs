//! The central operator dispatcher — torsk's ATen-style registry (§5.1).
//!
//! Every eager operator is declared **once**, as an [`OpDef`]: a schema
//! (name, arity, dtype constraints) plus per-[`DispatchKey`] kernel
//! entries. The public `ops::*` functions are thin shims over
//! [`call`], which is the single choke point that
//!
//! 1. validates the schema (arity, dtype support, same-device),
//! 2. resolves the backend key from the inputs' device (`Cpu` or `Sim`),
//! 3. emits a host-track profiler span for *every* op with zero per-op
//!    code (the §6.1 instrumentation comes for free), and
//! 4. composes the `Autograd` wrapping key: when recording is on and the
//!    op registered a backward builder, the output's `grad_fn` is recorded
//!    uniformly — individual ops no longer hand-roll
//!    `autograd::record(...)` boilerplate.
//!
//! Broadcasting and dtype promotion are resolved by the shared
//! [`iter::TensorIter`] helper, so F32, F64 and I64 run through the same
//! registry entries instead of per-op `f32 only` asserts.
//!
//! # Registering a new op
//!
//! A new operator (or a new backend for an existing one) is a registry
//! entry, not a code audit:
//!
//! ```no_run
//! use torsk::dispatch::{self, DispatchKey, OpCtx, OpDef, Param};
//! use torsk::tensor::{DType, Tensor};
//!
//! // 1. A kernel: host resolves shapes, computes (or queues) the result.
//! fn shifted_relu(ctx: &OpCtx) -> Tensor {
//!     let x = ctx.input(0);
//!     let shift = ctx.f32(0);
//!     // Compose existing dispatched ops, or write a raw kernel.
//!     torsk::ops::relu(&torsk::ops::add_scalar(x, shift))
//! }
//!
//! // 2. One declaration: schema + per-key kernels (+ optional backward).
//! dispatch::register_op(
//!     OpDef::new("shifted_relu", 1, 1, &[DType::F32, DType::F64])
//!         .kernel(DispatchKey::Cpu, shifted_relu)
//!         .kernel(DispatchKey::Sim, shifted_relu),
//! );
//!
//! // 3. Call it — profiling, device routing and schema checks are free.
//! let y = dispatch::call("shifted_relu", &[&Tensor::ones(&[4])], &[Param::F32(1.0)]);
//! assert_eq!(y.shape(), &[4]);
//! ```

pub(crate) mod conv;
pub(crate) mod elementwise;
pub(crate) mod index;
pub(crate) mod inplace;
pub(crate) mod iter;
pub(crate) mod linalg;
pub(crate) mod loss;
pub(crate) mod norm;
pub(crate) mod pool;
pub(crate) mod reduce;
pub(crate) mod views;

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::RwLock;

use crate::autograd::{self, Function};
use crate::device::Device;
use crate::profiler;
use crate::tensor::{DType, Tensor};
use crate::{torsk_assert, torsk_bail};

// ---------------------------------------------------------------------
// Keys
// ---------------------------------------------------------------------

/// Dispatch keys, highest priority first. `Autograd` is a *wrapping* key:
/// it does not select a kernel but wraps the backend call with graph
/// recording. `Sim` and `Cpu` are backend keys selecting kernel table
/// entries.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DispatchKey {
    /// Graph-recording wrapper (active when grad mode is on and an input
    /// requires grad).
    Autograd,
    /// Simulated-accelerator backend: kernels queue on the current stream.
    Sim,
    /// Host backend: kernels run inline on the calling thread.
    Cpu,
}

/// Number of backend (kernel-table) keys.
const NUM_BACKEND_KEYS: usize = 2;

impl DispatchKey {
    /// The backend key serving tensors on `device`.
    pub fn for_device(d: Device) -> DispatchKey {
        match d {
            Device::Cpu => DispatchKey::Cpu,
            Device::Sim => DispatchKey::Sim,
        }
    }

    fn backend_index(self) -> usize {
        match self {
            DispatchKey::Cpu => 0,
            DispatchKey::Sim => 1,
            DispatchKey::Autograd => {
                crate::torsk_bail!("Autograd is a wrapping key, not a backend kernel slot")
            }
        }
    }
}

/// The key stack [`call`] walks for a given op invocation (diagnostics /
/// tests): `[Autograd, backend]` when recording would happen, else
/// `[backend]`.
pub fn key_stack(inputs: &[&Tensor]) -> Vec<DispatchKey> {
    let mut keys = Vec::with_capacity(2);
    if autograd::should_record(inputs) {
        keys.push(DispatchKey::Autograd);
    }
    if let Some(first) = inputs.first() {
        keys.push(DispatchKey::for_device(first.device()));
    }
    keys
}

// ---------------------------------------------------------------------
// Non-tensor op arguments
// ---------------------------------------------------------------------

/// A non-tensor operator argument (the boxed-scalar side of an op call).
#[derive(Clone, Debug)]
pub enum Param {
    F32(f32),
    F64(f64),
    I64(i64),
    Usize(usize),
    Bool(bool),
    UsizeList(Vec<usize>),
    DType(DType),
}

// ---------------------------------------------------------------------
// Op call context
// ---------------------------------------------------------------------

/// Everything a kernel (and a backward builder) sees about one op call:
/// tensor inputs, scalar params, resolved device, plus a stash for
/// forward-computed intermediates the backward pass needs
/// (`save`/`saved` — PyTorch's `ctx.save_for_backward`).
pub struct OpCtx<'a> {
    pub inputs: &'a [&'a Tensor],
    pub params: &'a [Param],
    pub device: Device,
    saved: RefCell<Vec<Tensor>>,
}

impl<'a> OpCtx<'a> {
    fn new(inputs: &'a [&'a Tensor], params: &'a [Param], device: Device) -> OpCtx<'a> {
        OpCtx { inputs, params, device, saved: RefCell::new(Vec::new()) }
    }

    /// Tensor input `i`.
    #[inline]
    pub fn input(&self, i: usize) -> &Tensor {
        self.inputs[i]
    }

    /// Number of tensor inputs (for ops with optional inputs, e.g. bias).
    #[inline]
    pub fn num_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Scalar param `i` as f32.
    pub fn f32(&self, i: usize) -> f32 {
        match self.param(i) {
            Param::F32(v) => *v,
            p => torsk_bail!("param {i}: expected f32, got {p:?}"),
        }
    }

    /// Scalar param `i` widened to f64 (accepts `F32` — exact — or `F64`).
    /// Kernels that instantiate per-dtype read through this so F64 tensors
    /// never lose scalar precision to an f32 round-trip.
    pub fn scalar(&self, i: usize) -> f64 {
        match self.param(i) {
            Param::F32(v) => *v as f64,
            Param::F64(v) => *v,
            p => torsk_bail!("param {i}: expected a float scalar, got {p:?}"),
        }
    }

    /// Scalar param `i` as usize.
    pub fn usize(&self, i: usize) -> usize {
        match self.param(i) {
            Param::Usize(v) => *v,
            p => torsk_bail!("param {i}: expected usize, got {p:?}"),
        }
    }

    /// Scalar param `i` as bool.
    pub fn bool(&self, i: usize) -> bool {
        match self.param(i) {
            Param::Bool(v) => *v,
            p => torsk_bail!("param {i}: expected bool, got {p:?}"),
        }
    }

    /// Param `i` as a usize list (dims, kernel sizes).
    pub fn usize_list(&self, i: usize) -> &[usize] {
        match self.param(i) {
            Param::UsizeList(v) => v,
            p => torsk_bail!("param {i}: expected usize list, got {p:?}"),
        }
    }

    /// Param `i` as a dtype.
    pub fn dtype(&self, i: usize) -> DType {
        match self.param(i) {
            Param::DType(v) => *v,
            p => torsk_bail!("param {i}: expected dtype, got {p:?}"),
        }
    }

    fn param(&self, i: usize) -> &Param {
        match self.params.get(i) {
            Some(p) => p,
            None => torsk_bail!("op called with {} params, kernel wants index {i}", self.params.len()),
        }
    }

    /// Stash a forward-computed intermediate for the backward builder
    /// (max-pool indices, batch-norm statistics, ...).
    pub fn save(&self, t: Tensor) {
        self.saved.borrow_mut().push(t);
    }

    /// Retrieve stash entry `i` (in `save` order).
    pub fn saved(&self, i: usize) -> Tensor {
        match self.saved.borrow().get(i) {
            Some(t) => t.clone(),
            None => torsk_bail!("backward wants saved tensor {i}, only {} stashed", self.saved.borrow().len()),
        }
    }
}

// ---------------------------------------------------------------------
// Schema + definition
// ---------------------------------------------------------------------

/// A kernel entry: resolves shapes on the host, allocates the output and
/// computes inline (CPU) or queues the computation (Sim).
pub type KernelFn = fn(&OpCtx) -> Tensor;

/// A backward builder: called at record time with the op context and the
/// forward output; returns the backward [`Function`] whose `backward`
/// yields one gradient per tensor input (in input order).
pub type BackwardFn = fn(&OpCtx, &Tensor) -> Box<dyn Function>;

/// Declared call signature of an op.
#[derive(Clone, Copy, Debug)]
pub struct OpSchema {
    pub name: &'static str,
    pub min_inputs: usize,
    pub max_inputs: usize,
    /// Allowed dtypes of the primary (first) input. Empty slice = any.
    pub dtypes: &'static [DType],
}

impl OpSchema {
    fn check(&self, inputs: &[&Tensor]) {
        torsk_assert!(
            inputs.len() >= self.min_inputs && inputs.len() <= self.max_inputs,
            "{}: expected {}..={} tensor inputs, got {}",
            self.name,
            self.min_inputs,
            self.max_inputs,
            inputs.len()
        );
        if !self.dtypes.is_empty() {
            let dt = inputs[0].dtype();
            if !self.dtypes.contains(&dt) {
                let supported: Vec<&str> = self.dtypes.iter().map(|d| d.name()).collect();
                torsk_bail!(
                    "{}: unsupported dtype {} (supported: {})",
                    self.name,
                    dt,
                    supported.join(", ")
                );
            }
        }
    }
}

/// One operator: schema + per-backend kernels + optional backward builder.
///
/// Ops whose kernel *composes* other dispatched ops (layer-norm, losses)
/// register no backward: their gradient graph is built by the inner calls.
/// Fused ops register a [`BackwardFn`] and get recording for free.
#[derive(Clone, Copy)]
pub struct OpDef {
    pub schema: OpSchema,
    kernels: [Option<KernelFn>; NUM_BACKEND_KEYS],
    backward: Option<BackwardFn>,
}

impl OpDef {
    /// Start declaring an op: name, input arity range, allowed dtypes of
    /// the first input (empty = any).
    pub fn new(
        name: &'static str,
        min_inputs: usize,
        max_inputs: usize,
        dtypes: &'static [DType],
    ) -> OpDef {
        OpDef {
            schema: OpSchema { name, min_inputs, max_inputs, dtypes },
            kernels: [None; NUM_BACKEND_KEYS],
            backward: None,
        }
    }

    /// Attach a kernel for one backend key.
    pub fn kernel(mut self, key: DispatchKey, f: KernelFn) -> OpDef {
        self.kernels[key.backend_index()] = Some(f);
        self
    }

    /// Attach the same kernel for every backend key (the common case: the
    /// kernel body is queued or run inline by `device::dispatch`).
    pub fn kernel_all(mut self, f: KernelFn) -> OpDef {
        for slot in self.kernels.iter_mut() {
            *slot = Some(f);
        }
        self
    }

    /// Attach the backward builder (enables the Autograd wrapping key).
    pub fn backward(mut self, f: BackwardFn) -> OpDef {
        self.backward = Some(f);
        self
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// The op registry. Built once with the built-in ops; extendable at
/// runtime via [`register_op`].
pub struct Registry {
    ops: HashMap<&'static str, OpDef>,
}

impl Registry {
    /// Insert an op definition; duplicate names are a bug.
    pub fn add(&mut self, def: OpDef) {
        let name = def.schema.name;
        torsk_assert!(
            self.ops.insert(name, def).is_none(),
            "op '{name}' registered twice"
        );
    }
}

static REGISTRY: once_cell::sync::Lazy<RwLock<Registry>> = once_cell::sync::Lazy::new(|| {
    let mut r = Registry { ops: HashMap::new() };
    elementwise::register(&mut r);
    linalg::register(&mut r);
    reduce::register(&mut r);
    loss::register(&mut r);
    conv::register(&mut r);
    pool::register(&mut r);
    norm::register(&mut r);
    index::register(&mut r);
    inplace::register(&mut r);
    views::register(&mut r);
    RwLock::new(r)
});

/// Register an additional operator at runtime (new ops, new backends).
pub fn register_op(def: OpDef) {
    let name = def.schema.name;
    // Check-then-insert without panicking under the lock (a poisoned
    // registry would take every subsequent op call down with it).
    let duplicate = {
        let mut reg = REGISTRY.write().unwrap();
        if reg.ops.contains_key(name) {
            true
        } else {
            reg.ops.insert(name, def);
            false
        }
    };
    torsk_assert!(!duplicate, "op '{name}' registered twice");
}

/// Is an op with this name registered?
pub fn has_op(name: &str) -> bool {
    REGISTRY.read().unwrap().ops.contains_key(name)
}

/// Sorted names of all registered ops.
pub fn op_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = REGISTRY.read().unwrap().ops.keys().copied().collect();
    names.sort_unstable();
    names
}

/// Check all tensors share a device; return it. Mirrors PyTorch's
/// "expected all tensors on the same device" error.
pub(crate) fn same_device(name: &str, tensors: &[&Tensor]) -> Device {
    let d = tensors[0].device();
    for t in tensors.iter().skip(1) {
        torsk_assert!(
            t.device() == d,
            "{name}: expected all tensors to be on the same device, found {} and {}",
            d,
            t.device()
        );
    }
    d
}

// ---------------------------------------------------------------------
// The choke point
// ---------------------------------------------------------------------

/// Invoke operator `name` on `inputs` with scalar `params`.
///
/// This is the single path every eager op takes: schema validation, key
/// resolution, per-op profiling and uniform autograd recording live here,
/// once, instead of in ~40 op bodies.
pub fn call(name: &str, inputs: &[&Tensor], params: &[Param]) -> Tensor {
    let def = { REGISTRY.read().unwrap().ops.get(name).copied() };
    let def = match def {
        Some(d) => d,
        None => {
            let known = op_names().join(", ");
            torsk_bail!("no operator named '{name}' is registered (known ops: {known})");
        }
    };
    torsk_assert!(!inputs.is_empty(), "{name}: ops take at least one tensor input");
    def.schema.check(inputs);
    let device = same_device(name, inputs);
    let key = DispatchKey::for_device(device);
    let kernel = match def.kernels[key.backend_index()] {
        Some(k) => k,
        None => torsk_bail!("op '{name}' has no kernel registered for dispatch key {key:?}"),
    };

    // Free per-op profiling: one host span per dispatched op. The span name
    // is only materialized when the profiler is recording.
    let span = if profiler::enabled() {
        Some(profiler::begin(profiler::Track::Host, &format!("op:{name}")))
    } else {
        None
    };

    let ctx = OpCtx::new(inputs, params, device);
    let out = kernel(&ctx);

    // The Autograd wrapping key: uniform graph recording.
    if let Some(bw) = def.backward {
        if autograd::should_record(inputs) {
            autograd::record(inputs, &out, || bw(&ctx, &out));
        }
    }

    if let Some(s) = span {
        profiler::end(s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_core_ops() {
        for op in ["add", "mul", "matmul", "sum", "relu", "conv2d", "cross_entropy"] {
            assert!(has_op(op), "missing builtin op {op}");
        }
        assert!(!has_op("definitely_not_an_op"));
    }

    #[test]
    fn op_names_sorted_nonempty() {
        let names = op_names();
        assert!(names.len() >= 30, "expected a full registry, got {}", names.len());
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
    }

    #[test]
    #[should_panic(expected = "no operator named")]
    fn unknown_op_panics_with_catalog() {
        let a = Tensor::ones(&[1]);
        call("definitely_not_an_op", &[&a], &[]);
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_registration_panics() {
        register_op(OpDef::new("add", 2, 2, &[]));
    }

    #[test]
    fn key_stack_reflects_autograd_and_device() {
        let a = Tensor::ones(&[2]);
        assert_eq!(key_stack(&[&a]), vec![DispatchKey::Cpu]);
        let g = Tensor::ones(&[2]).requires_grad(true);
        assert_eq!(key_stack(&[&g]), vec![DispatchKey::Autograd, DispatchKey::Cpu]);
        let s = Tensor::ones(&[2]).to_sim();
        assert_eq!(key_stack(&[&s]), vec![DispatchKey::Sim]);
    }

    #[test]
    fn register_and_call_custom_op() {
        fn double(ctx: &OpCtx) -> Tensor {
            crate::ops::mul_scalar(ctx.input(0), 2.0)
        }
        register_op(
            OpDef::new("test_double", 1, 1, &[DType::F32])
                .kernel(DispatchKey::Cpu, double)
                .kernel(DispatchKey::Sim, double),
        );
        let y = call("test_double", &[&Tensor::from_slice(&[1.5f32])], &[]);
        assert_eq!(y.to_vec::<f32>(), vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "no kernel registered for dispatch key Sim")]
    fn missing_backend_kernel_panics() {
        fn id(ctx: &OpCtx) -> Tensor {
            ctx.input(0).clone()
        }
        register_op(OpDef::new("test_cpu_only", 1, 1, &[]).kernel(DispatchKey::Cpu, id));
        let a = Tensor::ones(&[1]).to_sim();
        call("test_cpu_only", &[&a], &[]);
    }

    #[test]
    #[should_panic(expected = "unsupported dtype")]
    fn dtype_mismatch_panics() {
        let idx = Tensor::from_vec(vec![1i64], &[1]);
        call("relu", &[&idx], &[]);
    }

}
