//! Indexing kernel entries: embedding lookup (gather rows) with
//! scatter-add backward, and one-hot encoding.

use crate::autograd::{ClosureFunction, Function};
use crate::device;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::{OpCtx, OpDef, Registry};

/// Embedding lookup: `weight [V, D]` gathered by i64 `indices [..]` ->
/// `[.., D]`. Inputs: [weight, indices].
fn k_embedding(ctx: &OpCtx) -> Tensor {
    let (weight, indices) = (ctx.input(0), ctx.input(1));
    torsk_assert!(weight.ndim() == 2, "embedding: weight must be [V, D]");
    torsk_assert!(indices.dtype() == DType::I64, "embedding: indices must be i64");
    let (v, d) = (weight.size(0), weight.size(1));
    let w = weight.contiguous();
    let idx = indices.contiguous();
    let n = idx.numel();
    let mut out_shape = indices.shape().to_vec();
    out_shape.push(d);
    let out = Tensor::empty(&out_shape, DType::F32, weight.device());
    {
        let (wp, ip, op) = (w.data_ptr(), idx.data_ptr(), out.data_ptr());
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        device::dispatch(weight.device(), "embedding", move || unsafe {
            let wv = wp.as_slice::<f32>(0, v * d);
            let iv = ip.as_slice::<i64>(0, n);
            let ov = op.as_mut_slice::<f32>(0, n * d);
            for (r, &i) in iv.iter().enumerate() {
                assert!((0..v as i64).contains(&i), "embedding index {i} out of range 0..{v}");
                ov[r * d..(r + 1) * d].copy_from_slice(&wv[i as usize * d..(i as usize + 1) * d]);
            }
        });
    }
    ctx.save(idx);
    out
}

fn bw_embedding(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let (v, d) = (ctx.input(0).size(0), ctx.input(0).size(1));
    let dev = ctx.input(0).device();
    let idx = ctx.saved(0);
    ClosureFunction::new("embedding", move |g| {
        let g = g.contiguous();
        let gv = g.to_vec::<f32>();
        let iv = idx.to_vec::<i64>();
        let mut gw = vec![0.0f32; v * d];
        for (r, &i) in iv.iter().enumerate() {
            let row = &gv[r * d..(r + 1) * d];
            let acc = &mut gw[i as usize * d..(i as usize + 1) * d];
            for (a, &x) in acc.iter_mut().zip(row.iter()) {
                *a += x;
            }
        }
        vec![Some(Tensor::from_vec(gw, &[v, d]).to_device(dev)), None]
    })
}

/// One-hot encode i64 `indices [N]` into f32 `[N, classes]`. No grad.
fn k_one_hot(ctx: &OpCtx) -> Tensor {
    let indices = ctx.input(0);
    let classes = ctx.usize(0);
    torsk_assert!(indices.dtype() == DType::I64, "one_hot: indices must be i64");
    let iv = indices.to_vec::<i64>();
    let n = iv.len();
    let mut data = vec![0.0f32; n * classes];
    for (r, &i) in iv.iter().enumerate() {
        torsk_assert!((0..classes as i64).contains(&i), "one_hot: index {i} out of range");
        data[r * classes + i as usize] = 1.0;
    }
    let mut shape = indices.shape().to_vec();
    shape.push(classes);
    Tensor::from_vec(data, &shape).to_device(indices.device())
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

use super::{OpSample, Param};

fn s_embedding(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None; // f32 weight table
    }
    let w = super::sample_uniform(seed, &[5, 3], dt, -1.0, 1.0)?;
    let idx = super::sample_indices(seed ^ 0x9, &[4], 5);
    Some(OpSample { inputs: vec![w, idx], params: vec![], grad_inputs: vec![0] })
}

fn s_one_hot(seed: u64, dt: DType) -> Option<OpSample> {
    if dt != DType::F32 {
        return None; // canonical sample keyed at F32 (indices are i64)
    }
    let idx = super::sample_indices(seed, &[6], 4);
    Some(OpSample { inputs: vec![idx], params: vec![Param::Usize(4)], grad_inputs: vec![] })
}

pub(crate) fn register(reg: &mut Registry) {
    reg.add(
        OpDef::new("embedding", 2, 2, &[DType::F32])
            .kernel_all(k_embedding)
            .backward(bw_embedding)
            .sample_inputs(s_embedding),
    );
    reg.add(
        OpDef::new("one_hot", 1, 1, &[DType::I64]).kernel_all(k_one_hot).sample_inputs(s_one_hot),
    );
}
