//! Normalization kernel entries: fused training-mode batch-norm, composite
//! eval-mode batch-norm / layer-norm / dropout.

use crate::autograd::{no_grad, ClosureFunction, Function, SavedTensor};
use crate::device;
use crate::kernels::norm::{bn_backward, bn_normalize, bn_stats};
use crate::ops;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::{OpCtx, OpDef, Registry};

fn bn_check(ctx: &OpCtx) -> usize {
    let input = ctx.input(0);
    torsk_assert!(input.ndim() == 4, "batch_norm2d: input must be NCHW");
    let c = input.size(1);
    torsk_assert!(
        ctx.input(1).shape() == [c] && ctx.input(2).shape() == [c],
        "batch_norm2d: affine shape"
    );
    c
}

/// Eval-mode batch norm: running-stat normalization via (fast-path)
/// broadcast ops — composite, autograd comes from the inner ops.
/// Inputs: [input, gamma, beta, running_mean, running_var]; params: [eps].
fn k_batch_norm_eval(ctx: &OpCtx) -> Tensor {
    let c = bn_check(ctx);
    let eps = ctx.f32(0);
    let input = ctx.input(0);
    let cshape = [1, c, 1, 1];
    let (mean, var) = (
        ctx.input(3).detach().reshape(&cshape),
        ctx.input(4).detach().reshape(&cshape),
    );
    let centered = ops::sub(input, &mean);
    // The add_scalar temp is dead after the pow: in eval mode (no
    // recording) the 1/sqrt(var+eps) chain computes in one buffer.
    let inv_std =
        super::call_owned("pow_scalar", vec![ops::add_scalar(&var, eps)], &[super::Param::F32(-0.5)]);
    let xhat = ops::mul(&centered, &inv_std);
    let g = ctx.input(1).reshape(&cshape);
    let b = ctx.input(2).reshape(&cshape);
    ops::add(&ops::mul(&xhat, &g), &b)
}

/// Fused training-mode batch norm (§Perf): single-kernel statistics +
/// normalize with a hand-written backward. Updates the running stats in
/// place (under `no_grad`). Inputs/params as `batch_norm`, plus momentum.
fn k_batch_norm_train(ctx: &OpCtx) -> Tensor {
    let c = bn_check(ctx);
    let (momentum, eps) = (ctx.f32(0), ctx.f32(1));
    let input = ctx.input(0);
    let (n, h, w) = (input.size(0), input.size(2), input.size(3));
    let hw = h * w;
    let x = input.contiguous();
    let gamma_c = ctx.input(1).contiguous();
    let beta_c = ctx.input(2).contiguous();
    let dev = x.device();

    let out = Tensor::empty(x.shape(), DType::F32, dev);
    let mean_t = Tensor::empty(&[c], DType::F32, dev);
    let inv_std_t = Tensor::empty(&[c], DType::F32, dev);
    {
        let (xp, gp, bp, op) = (x.data_ptr(), gamma_c.data_ptr(), beta_c.data_ptr(), out.data_ptr());
        let (mp, ip) = (mean_t.data_ptr(), inv_std_t.data_ptr());
        let len = x.numel();
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        device::dispatch(dev, "batch_norm", move || unsafe {
            let xv = xp.as_slice::<f32>(0, len);
            let mean = mp.as_mut_slice::<f32>(0, c);
            let inv_std = ip.as_mut_slice::<f32>(0, c);
            let mut var = vec![0.0f32; c];
            bn_stats(n, c, hw, xv, mean, &mut var);
            for (o, &v) in inv_std.iter_mut().zip(var.iter()) {
                *o = 1.0 / (v + eps).sqrt();
            }
            bn_normalize(
                n,
                c,
                hw,
                xv,
                mean,
                inv_std,
                gp.as_slice::<f32>(0, c),
                bp.as_slice::<f32>(0, c),
                op.as_mut_slice::<f32>(0, len),
            );
        });
    }
    // Update running stats from the just-computed batch stats.
    let (running_mean, running_var) = (ctx.input(3), ctx.input(4));
    no_grad(|| {
        let mean_h = mean_t.detach();
        // var = 1/inv_std^2 - eps
        let var_h = ops::add_scalar(&ops::pow_scalar(&inv_std_t.detach(), -2.0), -eps);
        running_mean.mul_scalar_(1.0 - momentum);
        running_mean.axpy_(momentum, &mean_h);
        running_var.mul_scalar_(1.0 - momentum);
        running_var.axpy_(momentum, &var_h);
    });
    // Stash what the hand-written backward needs.
    ctx.save(x);
    ctx.save(gamma_c);
    ctx.save(mean_t);
    ctx.save(inv_std_t);
    out
}

fn bw_batch_norm_train(ctx: &OpCtx, _out: &Tensor) -> Box<dyn Function> {
    let input = ctx.input(0);
    let (n, c, h, w) = (input.size(0), input.size(1), input.size(2), input.size(3));
    let hw = h * w;
    let vx = SavedTensor::save(&ctx.saved(0));
    let vgamma = SavedTensor::save(&ctx.saved(1));
    let vmean = ctx.saved(2);
    let vinv = ctx.saved(3);
    ClosureFunction::new("batch_norm", move |g| {
        let x = vx.unpack().contiguous();
        let gamma = vgamma.unpack().contiguous();
        let g = g.contiguous();
        if g.device().is_async() {
            device::synchronize();
        }
        let xv = x.to_vec::<f32>();
        let gv = g.to_vec::<f32>();
        let mean = vmean.to_vec::<f32>();
        let inv_std = vinv.to_vec::<f32>();
        let gam = gamma.to_vec::<f32>();
        let mut dx = vec![0.0f32; xv.len()];
        let mut dgamma = vec![0.0f32; c];
        let mut dbeta = vec![0.0f32; c];
        bn_backward(n, c, hw, &xv, &mean, &inv_std, &gam, &gv, &mut dx, &mut dgamma, &mut dbeta);
        let dev = x.device();
        vec![
            Some(Tensor::from_vec(dx, x.shape()).to_device(dev)),
            Some(Tensor::from_vec(dgamma, &[c]).to_device(dev)),
            Some(Tensor::from_vec(dbeta, &[c]).to_device(dev)),
            None, // running_mean: buffer, no grad
            None, // running_var: buffer, no grad
        ]
    })
}

/// Layer normalization over the last dimension.
/// Inputs: [input, gamma, beta]; params: [eps].
///
/// Row statistics run through the deterministic parallel reduction driver
/// (`iter::run_reduce` behind `mean_dims`); the scale/shift tail —
/// `(centered * inv_std) * gamma + beta`, previously three broadcast
/// passes — is one `fused:ln_tail` tape pass recording a single autograd
/// node.
fn k_layer_norm(ctx: &OpCtx) -> Tensor {
    let (input, gamma, beta) = (ctx.input(0), ctx.input(1), ctx.input(2));
    let eps = ctx.f32(0);
    let last = input.ndim() - 1;
    let d = input.size(last);
    torsk_assert!(gamma.shape() == [d] && beta.shape() == [d], "layer_norm: affine shape");
    let mean = ops::mean_dims(input, &[last], true);
    let centered = ops::sub(input, &mean);
    let var = ops::mean_dims(&ops::mul(&centered, &centered), &[last], true);
    let inv_std =
        super::call_owned("pow_scalar", vec![ops::add_scalar(&var, eps)], &[super::Param::F32(-0.5)]);
    if super::capture::tracing_active() {
        // Under graph capture, trace the scale/shift tail as primitives so
        // the optimizer re-fuses them; `tests/capture_parity.rs` pins the
        // auto-fused tape bitwise against `fused:ln_tail`.
        return ops::add(&ops::mul(&ops::mul(&centered, &inv_std), gamma), beta);
    }
    super::call("fused:ln_tail", &[&centered, &inv_std, gamma, beta], &[])
}

/// Composite inverted dropout. Params: [p, training].
fn k_dropout(ctx: &OpCtx) -> Tensor {
    let input = ctx.input(0);
    let (p, training) = (ctx.f32(0), ctx.bool(1));
    if !training || p == 0.0 {
        return input.clone();
    }
    torsk_assert!((0.0..1.0).contains(&p), "dropout: p must be in [0,1)");
    let scale = 1.0 / (1.0 - p);
    let mask_data: Vec<f32> = crate::rng::with_rng(|r| {
        (0..input.numel())
            .map(|_| if r.bernoulli(p) { 0.0 } else { scale })
            .collect()
    });
    let mask = Tensor::from_vec(mask_data, input.shape()).to_device(input.device());
    ops::mul(input, &super::elementwise::cast_to(&mask, input.dtype()))
}

// ---------------------------------------------------------------------
// OpInfo samples
// ---------------------------------------------------------------------

use super::{sample_uniform, OpSample, Param};

fn bn_sample(seed: u64, dt: DType, train: bool) -> Option<OpSample> {
    if dt != DType::F32 {
        return None; // f32-only NCHW kernels
    }
    let x = sample_uniform(seed, &[2, 3, 2, 2], dt, -2.0, 2.0)?;
    let gamma = sample_uniform(seed ^ 0x1, &[3], dt, 0.5, 1.5)?;
    let beta = sample_uniform(seed ^ 0x2, &[3], dt, -0.5, 0.5)?;
    let rm = sample_uniform(seed ^ 0x3, &[3], dt, -0.5, 0.5)?;
    let rv = sample_uniform(seed ^ 0x4, &[3], dt, 0.5, 1.5)?;
    let params = if train {
        vec![Param::F32(0.1), Param::F32(1e-5)]
    } else {
        vec![Param::F32(1e-5)]
    };
    Some(OpSample { inputs: vec![x, gamma, beta, rm, rv], params, grad_inputs: vec![0, 1, 2] })
}

fn s_batch_norm_eval(seed: u64, dt: DType) -> Option<OpSample> {
    bn_sample(seed, dt, false)
}

fn s_batch_norm_train(seed: u64, dt: DType) -> Option<OpSample> {
    bn_sample(seed, dt, true)
}

fn s_layer_norm(seed: u64, dt: DType) -> Option<OpSample> {
    let x = sample_uniform(seed, &[3, 6], dt, -2.0, 2.0)?;
    let gamma = sample_uniform(seed ^ 0x1, &[6], dt, 0.5, 1.5)?;
    let beta = sample_uniform(seed ^ 0x2, &[6], dt, -0.5, 0.5)?;
    Some(OpSample {
        inputs: vec![x, gamma, beta],
        params: vec![Param::F32(1e-5)],
        grad_inputs: vec![0, 1, 2],
    })
}

fn s_dropout(seed: u64, dt: DType) -> Option<OpSample> {
    // training=false: the identity path is the deterministic one a
    // numeric gradcheck can verify.
    let x = sample_uniform(seed, &[3, 4], dt, -2.0, 2.0)?;
    Some(OpSample {
        inputs: vec![x],
        params: vec![Param::F32(0.5), Param::Bool(false)],
        grad_inputs: vec![0],
    })
}

pub(crate) fn register(reg: &mut Registry) {
    const F32_ONLY: &[DType] = &[DType::F32];
    reg.add(
        OpDef::new("batch_norm", 5, 5, F32_ONLY)
            .kernel_all(k_batch_norm_eval)
            .sample_inputs(s_batch_norm_eval),
    );
    reg.add(
        OpDef::new("batch_norm_train", 5, 5, F32_ONLY)
            .kernel_all(k_batch_norm_train)
            .backward(bw_batch_norm_train)
            .sample_inputs(s_batch_norm_train),
    );
    reg.add(
        OpDef::new("layer_norm", 3, 3, super::elementwise::FLOATS)
            .kernel_all(k_layer_norm)
            .sample_inputs(s_layer_norm),
    );
    reg.add(
        OpDef::new("dropout", 1, 1, super::elementwise::FLOATS)
            .kernel_all(k_dropout)
            .sample_inputs(s_dropout),
    );
}
