//! `dispatch::capture` — the tracing DispatchKey: graph capture from
//! unmodified eager code, compile-style optimization, cached replay
//! (§PyTorch-2 / TorchDynamo direction; eager semantics, compiled speed).
//!
//! A [`GraphCapture`] session wraps a block of eager code. The first
//! time a given input signature is seen, the block runs **eagerly** —
//! correct by construction — while the dispatcher's choke point records
//! every *leaf* op invocation (composite kernels record their primitive
//! streams, not themselves) into a [`graph::Graph`]. The graph is then
//! optimized — dead-code elimination, automatic fusion of elementwise
//! chains into `fuse` micro-op tapes (with emitted backward tapes, ONE
//! autograd node per region), and buffer planning over the donation
//! protocol — and cached under a **guard key** derived from the session
//! inputs' shapes/dtypes/strides (never tensor *data*; pallas-audit's
//! `no-data-hash` lint enforces this). Later calls with the same
//! signature **replay** the optimized plan through the normal kernels;
//! a shape change misses the guard table and recaptures. The table is
//! LRU-bounded like the packed-weight cache.
//!
//! Replay is **bitwise identical** to eager at every thread count and
//! SIMD mode (`tests/capture_parity.rs` pins forward + backward), so
//! capture is a pure performance knob, never a semantics knob.
//!
//! Scope and caveats (the standard tracing contract):
//! * Keep data-dependent control flow out of the captured block — the
//!   trace bakes in the branch taken at capture time. Shapes are
//!   guarded; Rust-side branches on tensor *values* are not.
//! * Tensors read by the block but not passed as session inputs
//!   (weights, constants) are captured as **externals** by handle:
//!   replay re-reads their current storage, so in-place optimizer
//!   updates between steps behave exactly as in eager mode.
//! * Run `backward()` *outside* the captured block.
//! * A block whose result does not depend on every session input (e.g.
//!   an input consumed only through a pre-computed view) is refused and
//!   permanently runs eager — the safety net against stale closures.
//!
//! `PALLAS_CAPTURE=0` is the kill switch: sessions stop capturing and
//! every `run` degrades to plain eager execution.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

use once_cell::sync::Lazy;

use crate::tensor::Tensor;

use super::Param;

mod graph;
mod replay;

use graph::{Graph, Node, PlannedGraph, ValueInfo};

/// Guard-table bound per session (LRU eviction beyond this).
const MAX_GRAPHS: usize = 8;

// ---------------------------------------------------------------------
// Process-wide stats (satellite: dispatch::capture_stats())
// ---------------------------------------------------------------------

static GRAPHS_CAPTURED: AtomicU64 = AtomicU64::new(0);
static GUARD_HITS: AtomicU64 = AtomicU64::new(0);
static GUARD_MISSES: AtomicU64 = AtomicU64::new(0);
static OPS_FUSED: AtomicU64 = AtomicU64::new(0);
static BUFFERS_PLANNED: AtomicU64 = AtomicU64::new(0);

/// Counters for the capture subsystem since process start, alongside
/// [`crate::dispatch::output_reuse_stats`] and
/// [`crate::dispatch::packed_weight_stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CaptureStats {
    /// Graphs captured and compiled (guard misses that produced a plan).
    pub graphs_captured: u64,
    /// Session calls served by a cached plan (or cached eager verdict).
    pub guard_hits: u64,
    /// Session calls that had to (re)trace.
    pub guard_misses: u64,
    /// Eager ops subsumed into fused regions, summed over captures.
    pub ops_fused: u64,
    /// Interior buffers the planner marked for donation, summed over
    /// captures.
    pub buffers_planned: u64,
}

/// Per-session counters (the instance-scoped slice of [`CaptureStats`]):
/// what *this* [`GraphCapture`] did, unpolluted by concurrent sessions.
/// The serve workers diff these snapshots to attribute guard activity to
/// one server's metrics; process-wide totals stay in [`capture_stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Session calls served by a cached plan (or cached eager verdict).
    pub guard_hits: u64,
    /// Session calls that had to (re)trace.
    pub guard_misses: u64,
    /// Graphs this session captured and compiled.
    pub graphs_captured: u64,
}

/// Snapshot the capture counters.
pub fn capture_stats() -> CaptureStats {
    CaptureStats {
        graphs_captured: GRAPHS_CAPTURED.load(Ordering::Relaxed),
        guard_hits: GUARD_HITS.load(Ordering::Relaxed),
        guard_misses: GUARD_MISSES.load(Ordering::Relaxed),
        ops_fused: OPS_FUSED.load(Ordering::Relaxed),
        buffers_planned: BUFFERS_PLANNED.load(Ordering::Relaxed),
    }
}

/// `PALLAS_CAPTURE` kill switch, read once: unset or any value but "0"
/// leaves capture available to sessions that opt in.
static ENABLED: Lazy<bool> =
    Lazy::new(|| std::env::var("PALLAS_CAPTURE").map(|v| v != "0").unwrap_or(true));

// ---------------------------------------------------------------------
// Thread-local trace state (the tracing DispatchKey)
// ---------------------------------------------------------------------

struct TraceState {
    nodes: Vec<Node>,
    values: Vec<ValueInfo>,
    /// tensor id -> value id (rebound on in-place mutation: the op's
    /// output handle renames the value, SSA-style).
    by_tensor: BTreeMap<u64, usize>,
    n_session_inputs: usize,
}

impl TraceState {
    /// The value id feeding `t` into a node: a known value, or a fresh
    /// external captured by handle.
    fn value_of(&mut self, t: &Tensor) -> usize {
        if let Some(&v) = self.by_tensor.get(&t.id()) {
            return v;
        }
        let v = self.values.len();
        self.values.push(ValueInfo {
            shape: t.shape().to_vec(),
            dtype: t.dtype(),
            external: Some(t.clone()),
        });
        self.by_tensor.insert(t.id(), v);
        v
    }
}

thread_local! {
    static TRACE: RefCell<Option<TraceState>> = RefCell::new(None);
}

/// Is a capture trace active on this thread? Composite wrappers
/// (`loss.rs`, `norm.rs`) consult this to route through primitive
/// compositions the auto-fuser can recapture.
pub fn tracing_active() -> bool {
    TRACE.with(|c| c.borrow().is_some())
}

/// Trace-node count before a kernel runs; [`trace_op`] records the op
/// only when the count is unchanged after (i.e. the kernel dispatched
/// no nested ops — it is a primitive leaf, not a composite).
#[inline]
pub(crate) fn trace_mark() -> usize {
    TRACE.with(|c| c.borrow().as_ref().map_or(0, |s| s.nodes.len()))
}

/// The dispatcher's capture hook: record one leaf op invocation into
/// the active trace (no-op when no session is tracing on this thread).
pub(crate) fn trace_op(
    name: &str,
    inputs: &[&Tensor],
    out: &Tensor,
    params: &[Param],
    mark: usize,
) {
    TRACE.with(|cell| {
        let mut borrow = cell.borrow_mut();
        let st = match borrow.as_mut() {
            Some(s) => s,
            None => return,
        };
        if st.nodes.len() != mark {
            // Nested ops were recorded while this kernel ran: this is a
            // composite frame; its primitive leaves already traced.
            return;
        }
        let ivs: Vec<usize> = inputs.iter().map(|t| st.value_of(t)).collect();
        let out_id = st.values.len();
        st.values.push(ValueInfo {
            shape: out.shape().to_vec(),
            dtype: out.dtype(),
            external: None,
        });
        st.by_tensor.insert(out.id(), out_id);
        st.nodes.push(Node {
            name: name.to_string(),
            inputs: ivs,
            output: out_id,
            params: params.to_vec(),
        });
    });
}

/// Clears the thread's trace on scope exit (including panics mid-trace,
/// so a failed capture never poisons later dispatches).
struct TraceGuard;

impl Drop for TraceGuard {
    fn drop(&mut self) {
        TRACE.with(|c| *c.borrow_mut() = None);
    }
}

// ---------------------------------------------------------------------
// Guard keys
// ---------------------------------------------------------------------

/// The recapture guard: shapes, dtypes, strides and the grad-mode bit —
/// metadata only. Tensor *data* must never feed a cache key (enforced
/// by pallas-audit's `no-data-hash` lint over this module).
fn guard_key(inputs: &[&Tensor]) -> String {
    let mut key = String::new();
    for t in inputs {
        let _ = write!(key, "{:?}|{:?}|{:?};", t.shape(), t.dtype(), t.strides());
    }
    if crate::autograd::grad_enabled() {
        key.push('G');
    }
    key
}

// ---------------------------------------------------------------------
// The session
// ---------------------------------------------------------------------

enum Compiled {
    Plan(Box<PlannedGraph>),
    /// The traced block failed a capture precondition; this signature
    /// permanently runs eager (correctness first).
    Eager,
}

struct Entry {
    compiled: Compiled,
    last_use: u64,
}

/// A capture session: a guard table mapping input signatures to
/// optimized, replayable graphs. One session per traced block (e.g. one
/// per model forward); sessions are single-threaded like the modules
/// they wrap.
pub struct GraphCapture {
    name: &'static str,
    graphs: RefCell<BTreeMap<String, Entry>>,
    tick: Cell<u64>,
    stats: Cell<SessionStats>,
}

impl GraphCapture {
    /// New, empty session. `name` labels profiler spans and errors.
    pub fn new(name: &'static str) -> GraphCapture {
        GraphCapture {
            name,
            graphs: RefCell::new(BTreeMap::new()),
            tick: Cell::new(0),
            stats: Cell::new(SessionStats::default()),
        }
    }

    /// This session's own guard counters (the process-global view is
    /// [`capture_stats`]).
    pub fn session_stats(&self) -> SessionStats {
        self.stats.get()
    }

    fn bump(&self, f: impl FnOnce(&mut SessionStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }

    /// Number of compiled graphs currently cached.
    pub fn cached_graphs(&self) -> usize {
        self.graphs
            .borrow()
            .values()
            .filter(|e| matches!(e.compiled, Compiled::Plan(_)))
            .count()
    }

    /// Run `f` under this session. First call per input signature traces
    /// eagerly (and returns that eager result); later calls replay the
    /// optimized graph. `f` receives exactly the `inputs` slice and must
    /// derive its result from those tensors (plus captured externals).
    pub fn run<F>(&self, inputs: &[&Tensor], f: F) -> Tensor
    where
        F: FnOnce(&[&Tensor]) -> Tensor,
    {
        if !*ENABLED || tracing_active() || inputs.is_empty() {
            return f(inputs);
        }
        let key = guard_key(inputs);
        let tick = self.tick.get() + 1;
        self.tick.set(tick);

        // Guard hit: replay the plan (or honor a cached eager verdict).
        {
            let mut graphs = self.graphs.borrow_mut();
            if let Some(entry) = graphs.get_mut(&key) {
                entry.last_use = tick;
                GUARD_HITS.fetch_add(1, Ordering::Relaxed);
                self.bump(|s| s.guard_hits += 1);
                match &entry.compiled {
                    Compiled::Plan(plan) => return replay::replay(plan, inputs),
                    Compiled::Eager => {}
                }
                drop(graphs);
                return f(inputs);
            }
        }

        // Guard miss: trace one eager run.
        GUARD_MISSES.fetch_add(1, Ordering::Relaxed);
        self.bump(|s| s.guard_misses += 1);
        let _guard = TraceGuard;
        TRACE.with(|c| {
            let mut values = Vec::with_capacity(inputs.len());
            let mut by_tensor = BTreeMap::new();
            for (i, t) in inputs.iter().enumerate() {
                values.push(ValueInfo {
                    shape: t.shape().to_vec(),
                    dtype: t.dtype(),
                    external: None,
                });
                by_tensor.insert(t.id(), i);
            }
            *c.borrow_mut() = Some(TraceState {
                nodes: Vec::new(),
                values,
                by_tensor,
                n_session_inputs: inputs.len(),
            });
        });
        let result = f(inputs);
        let state = TRACE.with(|c| c.borrow_mut().take()).expect("trace state vanished");
        drop(_guard);

        let compiled = match self.compile(state, &result) {
            Some(plan) => {
                GRAPHS_CAPTURED.fetch_add(1, Ordering::Relaxed);
                self.bump(|s| s.graphs_captured += 1);
                OPS_FUSED.fetch_add(plan.ops_fused, Ordering::Relaxed);
                BUFFERS_PLANNED.fetch_add(plan.buffers_planned, Ordering::Relaxed);
                Compiled::Plan(Box::new(plan))
            }
            None => Compiled::Eager,
        };
        let mut graphs = self.graphs.borrow_mut();
        if graphs.len() >= MAX_GRAPHS {
            // LRU eviction, like the packed-weight cache.
            if let Some(oldest) =
                graphs.iter().min_by_key(|(_, e)| e.last_use).map(|(k, _)| k.clone())
            {
                graphs.remove(&oldest);
            }
        }
        graphs.insert(key, Entry { compiled, last_use: tick });
        result
    }

    /// Lower a finished trace to an optimized plan, or `None` when a
    /// capture precondition fails (this signature then stays eager).
    fn compile(&self, state: TraceState, result: &Tensor) -> Option<PlannedGraph> {
        let _ = self.name;
        if state.nodes.is_empty() {
            return None;
        }
        // The block's result must be a traced op output.
        let output = *state.by_tensor.get(&result.id())?;
        if output < state.n_session_inputs {
            return None;
        }
        // Safety net: every session input must actually feed the trace —
        // an unreferenced input means the closure computed from something
        // else (e.g. a stale pre-reshaped view), which guards cannot see.
        let mut used = vec![false; state.n_session_inputs];
        for node in &state.nodes {
            for &iv in &node.inputs {
                if iv < state.n_session_inputs {
                    used[iv] = true;
                }
            }
        }
        if used.iter().any(|u| !u) {
            return None;
        }
        let g = Graph {
            nodes: state.nodes,
            values: state.values,
            n_session_inputs: state.n_session_inputs,
            output,
        };
        Some(g.optimize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    fn mse_block(inputs: &[&Tensor]) -> Tensor {
        let d = ops::sub(inputs[0], inputs[1]);
        ops::mean(&ops::mul(&d, &d))
    }

    #[test]
    fn capture_replay_matches_eager_bitwise() {
        crate::rng::manual_seed(71);
        let sess = GraphCapture::new("test:mse");
        let p = Tensor::randn(&[257]);
        let t = Tensor::randn(&[257]);
        let eager = mse_block(&[&p, &t]);
        let first = sess.run(&[&p, &t], mse_block); // traced eager run
        let second = sess.run(&[&p, &t], mse_block); // replayed plan
        assert_eq!(first.to_vec::<f32>(), eager.to_vec::<f32>());
        assert_eq!(second.to_vec::<f32>(), eager.to_vec::<f32>());
    }

    #[test]
    fn guard_recaptures_on_shape_change_and_stats_move() {
        let before = capture_stats();
        let sess = GraphCapture::new("test:guard");
        let f = |ins: &[&Tensor]| ops::relu(&ops::add(ins[0], ins[0]));
        let a = Tensor::ones(&[16]);
        let r1 = sess.run(&[&a], f);
        let r2 = sess.run(&[&a], f);
        assert_eq!(r1.to_vec::<f32>(), r2.to_vec::<f32>());
        let b = Tensor::ones(&[32]);
        let _ = sess.run(&[&b], f);
        let after = capture_stats();
        // Stats are process-global and tests run concurrently: assert
        // this test's own contribution as a lower bound.
        assert!(after.guard_misses >= before.guard_misses + 2, "shape change must re-trace");
        assert!(after.guard_hits >= before.guard_hits + 1);
        assert!(after.graphs_captured >= before.graphs_captured + 2);
        assert!(after.ops_fused >= before.ops_fused + 4, "add+relu fuse in both captures");
        assert_eq!(sess.cached_graphs(), 2);
    }

    #[test]
    fn dce_drops_dead_ops_and_planner_donates_interiors() {
        crate::rng::manual_seed(73);
        let before = capture_stats();
        let sess = GraphCapture::new("test:dce");
        let f = |ins: &[&Tensor]| {
            let _dead = ops::exp(ins[0]); // never consumed: DCE'd
            ops::relu(&ops::matmul(ins[0], ins[0]))
        };
        let x = Tensor::randn(&[8, 8]);
        let eager = ops::relu(&ops::matmul(&x, &x));
        let _first = sess.run(&[&x], f);
        let second = sess.run(&[&x], f);
        assert_eq!(second.to_vec::<f32>(), eager.to_vec::<f32>());
        let after = capture_stats();
        // The matmul intermediate dies at the relu: planned for donation.
        assert!(after.buffers_planned >= before.buffers_planned + 1);
    }

    #[test]
    fn session_stats_are_instance_scoped() {
        let a = GraphCapture::new("test:sess-a");
        let b = GraphCapture::new("test:sess-b");
        let f = |ins: &[&Tensor]| ops::relu(&ops::add(ins[0], ins[0]));
        let x = Tensor::ones(&[8]);
        let _ = a.run(&[&x], f); // miss + capture
        let _ = a.run(&[&x], f); // hit
        let _ = a.run(&[&x], f); // hit
        assert_eq!(
            a.session_stats(),
            SessionStats { guard_hits: 2, guard_misses: 1, graphs_captured: 1 },
        );
        // Session b saw nothing — unlike the process-global counters,
        // which tests running concurrently also move.
        assert_eq!(b.session_stats(), SessionStats::default());
    }

    #[test]
    fn unreferenced_session_input_refuses_capture() {
        let sess = GraphCapture::new("test:refuse");
        let x = Tensor::ones(&[4]);
        let y = Tensor::ones(&[4]);
        // The closure ignores its inputs entirely: capture must refuse
        // (and keep refusing) rather than replay a stale constant.
        let g = ops::add(&x, &x);
        let r1 = sess.run(&[&y], |_| ops::relu(&g));
        let r2 = sess.run(&[&y], |_| ops::relu(&g));
        assert_eq!(sess.cached_graphs(), 0, "stale-closure captures must be refused");
        assert_eq!(r1.to_vec::<f32>(), r2.to_vec::<f32>());
    }
}
