//! Captured-graph IR and the three optimizer passes: dead-code
//! elimination, automatic elementwise fusion (graph regions compiled to
//! [`fuse::Tape`] programs with fused backward tapes), and buffer
//! planning (donation of interior storages that die inside the graph).
//!
//! The bitwise contract: every tape emitted here mirrors, operation for
//! operation, the exact per-element expression the traced eager chain
//! evaluated — same micro-op arithmetic as the composed kernels, operand
//! pairing preserved, reordering only where IEEE addition/multiplication
//! commute bitwise (`x + y == y + x`, `x + x == 2 * x`,
//! `x - y == x + (-y)`). Regions that cannot meet the contract (stack
//! overflow, too many operands, a value feeding more than two consuming
//! slots, a broadcast operand feeding more than one) simply stay eager:
//! declining a fusion is always correct.

use std::collections::BTreeMap;

use crate::dispatch::fuse::{Access, BinaryK, MicroOp, Tape, UnaryK, MAX_ARGS, MAX_STACK};
use crate::dispatch::Param;
use crate::tensor::{DType, Tensor};

/// Longest tape the auto-fuser will emit; longer programs decline.
const MAX_TAPE_LEN: usize = 512;

/// One traced op invocation (a leaf: composite kernels record their
/// primitive streams, not themselves).
#[derive(Clone, Debug)]
pub(crate) struct Node {
    pub name: String,
    pub inputs: Vec<usize>,
    pub output: usize,
    pub params: Vec<Param>,
}

/// One SSA value: a session input (`0..n_session_inputs`), an external
/// captured by handle (weights, constants), or a node output.
#[derive(Clone)]
pub(crate) struct ValueInfo {
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// `Some` for externals: the traced handle, re-read at every replay
    /// (in-place updates between replays are seen, like eager).
    pub external: Option<Tensor>,
}

/// The raw trace, before optimization.
pub(crate) struct Graph {
    pub nodes: Vec<Node>,
    pub values: Vec<ValueInfo>,
    pub n_session_inputs: usize,
    pub output: usize,
}

// ---------------------------------------------------------------------
// Fusible-op classification
// ---------------------------------------------------------------------

/// The elementwise ops the auto-fuser understands, each mapped to the
/// exact micro-op sequence its eager kernel evaluates per element.
#[derive(Clone, Copy, Debug, PartialEq)]
enum FuseKind {
    Bin(BinaryK),
    Un(UnaryK),
    Relu,
    Sigmoid,
    AddScalar,
    MulScalar,
    Clamp,
}

fn fusible_kind(name: &str) -> Option<FuseKind> {
    Some(match name {
        "add" => FuseKind::Bin(BinaryK::Add),
        "sub" => FuseKind::Bin(BinaryK::Sub),
        "mul" => FuseKind::Bin(BinaryK::Mul),
        "div" => FuseKind::Bin(BinaryK::Div),
        "neg" => FuseKind::Un(UnaryK::Neg),
        "exp" => FuseKind::Un(UnaryK::Exp),
        "log" => FuseKind::Un(UnaryK::Ln),
        "sqrt" => FuseKind::Un(UnaryK::Sqrt),
        "tanh" => FuseKind::Un(UnaryK::Tanh),
        "relu" => FuseKind::Relu,
        "sigmoid" => FuseKind::Sigmoid,
        "add_scalar" => FuseKind::AddScalar,
        "mul_scalar" => FuseKind::MulScalar,
        "clamp" => FuseKind::Clamp,
        _ => return None,
    })
}

/// Ops that must survive DCE even when nothing consumes their output:
/// every in-place op (the `_` suffix convention) plus kernels with
/// side effects or RNG draws.
fn is_impure(name: &str) -> bool {
    name.ends_with('_')
        || matches!(name, "fused:sgd_step" | "fused:adam_step" | "dropout" | "batch_norm_train")
}

fn param_f64(p: &Param) -> Option<f64> {
    match *p {
        Param::F32(v) => Some(v as f64),
        Param::F64(v) => Some(v),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Optimized plan
// ---------------------------------------------------------------------

/// A fused region: consecutive elementwise nodes collapsed into one
/// forward tape (optionally with a `sum` / `sum → mul_scalar` reduce
/// tail) plus one backward tape per external operand.
pub(crate) struct FusedRegion {
    pub fwd: Tape,
    /// One gradient tape per external, args `[externals.., G]` (the
    /// whole region declines if any gradient tape fails to emit).
    pub bwds: Vec<Tape>,
    /// Value ids of the external operands, in tape-arg order.
    pub exts: Vec<usize>,
    pub access: Vec<Access>,
    pub ext_shapes: Vec<Vec<usize>>,
    pub out: usize,
    /// Shape of the elementwise map (the reduce tail, when present,
    /// collapses it to a 0-dim scalar).
    pub map_shape: Vec<usize>,
    pub reduce: Option<ReduceTail>,
    /// Eager ops this region subsumed (the `ops_fused` stat).
    pub n_ops: usize,
}

/// A `sum` (and optional trailing `mul_scalar`) folded into the region
/// via the deterministic chunked map-reduce driver.
pub(crate) struct ReduceTail {
    /// The raw `mul_scalar` parameter (`None` for a bare `sum`); the
    /// replay narrows it per dtype exactly like the eager scalar kernel.
    pub scale: Option<f64>,
}

pub(crate) enum Step {
    Op {
        name: String,
        inputs: Vec<usize>,
        /// Per input: replay may donate the slot's storage (interior
        /// value at its last use, appearing once in this op).
        donate: Vec<bool>,
        params: Vec<Param>,
        out: usize,
    },
    Fused(FusedRegion),
}

pub(crate) struct PlannedGraph {
    pub steps: Vec<Step>,
    /// `(value id, handle)` for every external, bound at replay.
    pub externals: Vec<(usize, Tensor)>,
    pub n_session_inputs: usize,
    pub n_values: usize,
    pub output: usize,
    /// Per step: interior values whose last use is this step (slots are
    /// cleared after the step so dead storages return to the allocator).
    pub drop_after: Vec<Vec<usize>>,
    /// Static pass results, folded into the process-wide counters once
    /// per capture.
    pub ops_fused: u64,
    pub buffers_planned: u64,
}

// ---------------------------------------------------------------------
// Tape emitter (fallible; declining a region keeps it eager)
// ---------------------------------------------------------------------

struct Emitter {
    ops: Vec<MicroOp>,
    depth: usize,
    max_depth: usize,
    ok: bool,
}

impl Emitter {
    fn new() -> Emitter {
        Emitter { ops: Vec::new(), depth: 0, max_depth: 0, ok: true }
    }

    fn push(&mut self, op: MicroOp) {
        if !self.ok {
            return;
        }
        match op {
            MicroOp::Load(_) | MicroOp::Const(_) | MicroOp::Dup => {
                self.depth += 1;
                self.max_depth = self.max_depth.max(self.depth);
            }
            MicroOp::Swap => {}
            MicroOp::Un(_) => {}
            MicroOp::Bin(_) => {
                if self.depth < 2 {
                    self.ok = false;
                    return;
                }
                self.depth -= 1;
            }
        }
        if self.max_depth > MAX_STACK || self.ops.len() >= MAX_TAPE_LEN {
            self.ok = false;
            return;
        }
        self.ops.push(op);
    }

    fn finish(self, n_inputs: usize) -> Option<Tape> {
        if self.ok && self.depth == 1 {
            Some(Tape::from_ops(self.ops, n_inputs))
        } else {
            None
        }
    }
}

/// Everything the recursive emitters need about one candidate region.
struct RegionCtx<'a> {
    graph: &'a Graph,
    /// Node indices (into `graph.nodes`) forming the region, in order.
    nodes: &'a [usize],
    /// value id -> tape arg slot, for externals.
    ext_slot: BTreeMap<usize, usize>,
    /// value id -> position in `nodes`, for interior values.
    producer: BTreeMap<usize, usize>,
    /// value id -> consuming (node position, input slot) pairs within
    /// the region.
    consumers: BTreeMap<usize, Vec<(usize, usize)>>,
}

impl<'a> RegionCtx<'a> {
    fn node(&self, pos: usize) -> &Node {
        &self.graph.nodes[self.nodes[pos]]
    }
}

/// Emit the forward expression for `v` — exactly the arithmetic the
/// eager chain evaluated, with shared subexpressions recomputed (the
/// recomputation is deterministic, so the bits cannot differ).
fn emit_value(ctx: &RegionCtx, e: &mut Emitter, v: usize) {
    if let Some(&slot) = ctx.ext_slot.get(&v) {
        e.push(MicroOp::Load(slot as u8));
        return;
    }
    let pos = ctx.producer[&v];
    let node = ctx.node(pos);
    let kind = fusible_kind(&node.name).expect("region nodes are fusible");
    match kind {
        FuseKind::Bin(k) => {
            emit_value(ctx, e, node.inputs[0]);
            emit_value(ctx, e, node.inputs[1]);
            e.push(MicroOp::Bin(k));
        }
        FuseKind::Un(k) => {
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Un(k));
        }
        FuseKind::Relu => {
            // Eager: `x.max(0.0)`.
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Const(0.0));
            e.push(MicroOp::Bin(BinaryK::Max));
        }
        FuseKind::Sigmoid => {
            // Eager: `1 / (1 + exp(-x))`, the `sigmoid_seq` sequence.
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Un(UnaryK::Neg));
            e.push(MicroOp::Un(UnaryK::Exp));
            e.push(MicroOp::Const(1.0));
            e.push(MicroOp::Bin(BinaryK::Add));
            e.push(MicroOp::Un(UnaryK::Recip));
        }
        FuseKind::AddScalar | FuseKind::MulScalar => {
            // `Const` narrows to the runtime dtype at eval, exactly like
            // the eager `float_scalar!` kernels narrow their parameter.
            let s = param_f64(&node.params[0]).expect("scalar param");
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Const(s));
            e.push(MicroOp::Bin(if kind == FuseKind::AddScalar {
                BinaryK::Add
            } else {
                BinaryK::Mul
            }));
        }
        FuseKind::Clamp => {
            // Eager `x.clamp(lo, hi)` == `max(lo) then min(hi)` for
            // `lo <= hi` and non-NaN inputs (checked at region scan).
            let lo = param_f64(&node.params[0]).expect("clamp lo");
            let hi = param_f64(&node.params[1]).expect("clamp hi");
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Const(lo));
            e.push(MicroOp::Bin(BinaryK::Max));
            e.push(MicroOp::Const(hi));
            e.push(MicroOp::Bin(BinaryK::Min));
        }
    }
}

/// Emit the gradient expression flowing into value `v`: the sum of the
/// per-consumer contributions (at most two — region precondition — and
/// IEEE addition commutes bitwise, so contribution order is free).
/// `g_slot` is the tape arg carrying the region output's upstream grad.
fn emit_grad(ctx: &RegionCtx, e: &mut Emitter, v: usize, out_value: usize, g_slot: usize) {
    if v == out_value {
        e.push(MicroOp::Load(g_slot as u8));
        return;
    }
    let cons = match ctx.consumers.get(&v) {
        Some(c) if !c.is_empty() => c,
        _ => {
            // No consumer inside the region: dead value, zero gradient.
            e.push(MicroOp::Const(0.0));
            return;
        }
    };
    for (i, &(pos, slot)) in cons.iter().enumerate() {
        emit_contribution(ctx, e, pos, slot, out_value, g_slot);
        if i > 0 {
            e.push(MicroOp::Bin(BinaryK::Add));
        }
    }
}

/// The gradient one consuming (node, input slot) contributes, mirroring
/// that op's eager backward formula with saved tensors replaced by
/// bitwise-identical recomputation from the region externals.
fn emit_contribution(
    ctx: &RegionCtx,
    e: &mut Emitter,
    pos: usize,
    slot: usize,
    out_value: usize,
    g_slot: usize,
) {
    let node = ctx.node(pos);
    let kind = fusible_kind(&node.name).expect("region nodes are fusible");
    let y = node.output;
    // Closure-free helpers: G = upstream grad of this node's output.
    macro_rules! g {
        () => {
            emit_grad(ctx, e, y, out_value, g_slot)
        };
    }
    match kind {
        FuseKind::Bin(BinaryK::Add) => g!(), // both slots: g
        FuseKind::Bin(BinaryK::Sub) => {
            g!();
            if slot == 1 {
                e.push(MicroOp::Un(UnaryK::Neg));
            }
        }
        FuseKind::Bin(BinaryK::Mul) => {
            // ga = g * b ; gb = g * a.
            g!();
            emit_value(ctx, e, node.inputs[1 - slot]);
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Bin(BinaryK::Div) => {
            if slot == 0 {
                // ga = g / b.
                g!();
                emit_value(ctx, e, node.inputs[1]);
                e.push(MicroOp::Bin(BinaryK::Div));
            } else {
                // gb = -(g * (a / (b*b))).
                g!();
                emit_value(ctx, e, node.inputs[0]);
                emit_value(ctx, e, node.inputs[1]);
                emit_value(ctx, e, node.inputs[1]);
                e.push(MicroOp::Bin(BinaryK::Mul));
                e.push(MicroOp::Bin(BinaryK::Div));
                e.push(MicroOp::Bin(BinaryK::Mul));
                e.push(MicroOp::Un(UnaryK::Neg));
            }
        }
        FuseKind::Bin(_) => unreachable!("non-differentiable Bin kinds never enter a region"),
        FuseKind::Un(UnaryK::Neg) => {
            g!();
            e.push(MicroOp::Un(UnaryK::Neg));
        }
        FuseKind::Un(UnaryK::Exp) => {
            // dydx = y (the saved output, recomputed bitwise).
            g!();
            emit_value(ctx, e, y);
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Un(UnaryK::Ln) => {
            // dydx = 1/x.
            g!();
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Un(UnaryK::Recip));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Un(UnaryK::Sqrt) => {
            // dydx = 0.5 / y.
            g!();
            e.push(MicroOp::Const(0.5));
            emit_value(ctx, e, y);
            e.push(MicroOp::Bin(BinaryK::Div));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Un(UnaryK::Tanh) => {
            // dydx = 1 - y*y, evaluated as (-(y*y)) + 1 (== bitwise).
            g!();
            emit_value(ctx, e, y);
            e.push(MicroOp::Dup);
            e.push(MicroOp::Bin(BinaryK::Mul));
            e.push(MicroOp::Un(UnaryK::Neg));
            e.push(MicroOp::Const(1.0));
            e.push(MicroOp::Bin(BinaryK::Add));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Un(_) => unreachable!("Recip never appears as a traced op"),
        FuseKind::Relu => {
            // dydx = [y > 0] (strict), as 1 - [y <= 0].
            g!();
            emit_value(ctx, e, y);
            e.push(MicroOp::Const(0.0));
            e.push(MicroOp::Bin(BinaryK::Le));
            e.push(MicroOp::Un(UnaryK::Neg));
            e.push(MicroOp::Const(1.0));
            e.push(MicroOp::Bin(BinaryK::Add));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Sigmoid => {
            // dydx = y * (1 - y).
            g!();
            emit_value(ctx, e, y);
            e.push(MicroOp::Dup);
            e.push(MicroOp::Un(UnaryK::Neg));
            e.push(MicroOp::Const(1.0));
            e.push(MicroOp::Bin(BinaryK::Add));
            e.push(MicroOp::Bin(BinaryK::Mul));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::AddScalar => g!(),
        FuseKind::MulScalar => {
            let s = param_f64(&node.params[0]).expect("scalar param");
            g!();
            e.push(MicroOp::Const(s));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
        FuseKind::Clamp => {
            // dydx = [x >= lo] * [x <= hi].
            let lo = param_f64(&node.params[0]).expect("clamp lo");
            let hi = param_f64(&node.params[1]).expect("clamp hi");
            g!();
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Const(lo));
            e.push(MicroOp::Bin(BinaryK::Ge));
            emit_value(ctx, e, node.inputs[0]);
            e.push(MicroOp::Const(hi));
            e.push(MicroOp::Bin(BinaryK::Le));
            e.push(MicroOp::Bin(BinaryK::Mul));
            e.push(MicroOp::Bin(BinaryK::Mul));
        }
    }
}

// ---------------------------------------------------------------------
// Region scanning + fusion
// ---------------------------------------------------------------------

/// Classify how an external of `shape` is read per output element of a
/// map over `out_shape` (trailing dim `inner`): the same patterns the
/// hand-registered fused kernels express via [`Access`].
fn classify_access(shape: &[usize], out_shape: &[usize]) -> Option<Access> {
    if shape == out_shape {
        return Some(Access::Flat);
    }
    let numel: usize = shape.iter().product();
    if numel == 1 {
        return Some(Access::Scalar);
    }
    let inner = *out_shape.last()?;
    if inner == 0 {
        return None;
    }
    // `[.., 1]` row statistics (layer-norm mean / inv_std).
    if shape.len() == out_shape.len()
        && shape[..shape.len() - 1] == out_shape[..out_shape.len() - 1]
        && *shape.last().unwrap() == 1
    {
        return Some(Access::Row(inner));
    }
    // `[d]` affine vectors broadcast over rows.
    if shape == [inner] {
        return Some(Access::Col(inner));
    }
    None
}

/// Can `nodes[lo..hi]` (indices into the live node list) fuse into one
/// map region producing `graph.nodes[order[hi-1]].output`? Returns the
/// built region on success.
fn try_region(
    graph: &Graph,
    order: &[usize],
    lo: usize,
    hi: usize,
    consumed_later: &dyn Fn(usize, usize) -> bool,
) -> Option<FusedRegion> {
    let span = &order[lo..hi];
    if hi - lo < 2 {
        return None;
    }
    let out_value = graph.nodes[span[hi - lo - 1]].output;
    let out_shape = graph.values[out_value].shape.clone();
    let dt = graph.values[out_value].dtype;
    if !dt.is_float() || out_shape.iter().product::<usize>() == 0 {
        return None;
    }

    let mut producer: BTreeMap<usize, usize> = BTreeMap::new();
    let mut ext_slot: BTreeMap<usize, usize> = BTreeMap::new();
    let mut exts: Vec<usize> = Vec::new();
    let mut access: Vec<Access> = Vec::new();
    let mut consumers: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
    let mut slots: BTreeMap<usize, usize> = BTreeMap::new();

    for (pos, &ni) in span.iter().enumerate() {
        let node = &graph.nodes[ni];
        let kind = fusible_kind(&node.name)?;
        // Interior values carry the region's map shape and dtype.
        let vo = &graph.values[node.output];
        if vo.shape != out_shape || vo.dtype != dt {
            return None;
        }
        if kind == FuseKind::Clamp {
            let lo_p = param_f64(&node.params[0])?;
            let hi_p = param_f64(&node.params[1])?;
            // max-then-min == clamp only for an ordered, NaN-free interval.
            if lo_p.is_nan() || hi_p.is_nan() || lo_p > hi_p {
                return None;
            }
        }
        if matches!(kind, FuseKind::AddScalar | FuseKind::MulScalar)
            && param_f64(&node.params[0]).is_none()
        {
            return None;
        }
        for (slot, &iv) in node.inputs.iter().enumerate() {
            consumers.entry(iv).or_default().push((pos, slot));
            *slots.entry(iv).or_insert(0) += 1;
            if producer.contains_key(&iv) || ext_slot.contains_key(&iv) {
                continue;
            }
            let info = &graph.values[iv];
            let acc = classify_access(&info.shape, &out_shape)?;
            if info.dtype != dt {
                return None;
            }
            ext_slot.insert(iv, exts.len());
            exts.push(iv);
            access.push(acc);
        }
        producer.insert(node.output, pos);
    }

    // One backward arg slot is reserved for the upstream grad G.
    if exts.len() > MAX_ARGS - 1 {
        return None;
    }

    // Bitwise-parity preconditions on the gradient side:
    // * at most two consuming slots per value — a two-way IEEE add (and
    //   `x + x`) reassociates bitwise; three-way sums would not;
    // * broadcast (non-Flat) externals feed exactly one slot, because
    //   `sum_to_shape` does not distribute over addition bitwise;
    // * interior values stay inside the region (single live output).
    for (v, &n) in &slots {
        if n > 2 {
            return None;
        }
        if let Some(&slot) = ext_slot.get(v) {
            if !matches!(access[slot], Access::Flat) && n != 1 {
                return None;
            }
        }
    }
    let region_last = span[span.len() - 1];
    for &v in producer.keys() {
        if v == out_value {
            continue;
        }
        if v == graph.output || consumed_later(v, region_last) {
            return None;
        }
    }
    // The region output must not also be consumed as a *broadcast* by
    // itself (it is Flat by construction), and externals must not be
    // session inputs of zero extent — covered above.

    let ctx = RegionCtx { graph, nodes: span, ext_slot, producer, consumers };

    let mut fe = Emitter::new();
    emit_value(&ctx, &mut fe, out_value);
    let fwd = fe.finish(exts.len())?;

    let g_slot = exts.len();
    let mut bwds: Vec<Tape> = Vec::with_capacity(exts.len());
    for &ev in &exts {
        let mut be = Emitter::new();
        emit_grad(&ctx, &mut be, ev, out_value, g_slot);
        bwds.push(be.finish(exts.len() + 1)?);
    }

    let ext_shapes = exts.iter().map(|&v| graph.values[v].shape.clone()).collect();
    Some(FusedRegion {
        fwd,
        bwds,
        exts,
        access,
        ext_shapes,
        out: out_value,
        map_shape: out_shape,
        reduce: None,
        n_ops: hi - lo,
    })
}

// ---------------------------------------------------------------------
// Graph::optimize — DCE, fusion, buffer planning
// ---------------------------------------------------------------------

impl Graph {
    /// Run the three passes and lower to an executable plan.
    pub(crate) fn optimize(&self) -> PlannedGraph {
        // ---- Pass 1: dead-code elimination. A node is live when its
        // output is (transitively) needed by the graph output or it is
        // impure. Backward sweep so consumers decide before producers.
        let n_nodes = self.nodes.len();
        let mut needed = vec![false; self.values.len()];
        needed[self.output] = true;
        let mut live = vec![false; n_nodes];
        for i in (0..n_nodes).rev() {
            let node = &self.nodes[i];
            if needed[node.output] || is_impure(&node.name) {
                live[i] = true;
                for &iv in &node.inputs {
                    needed[iv] = true;
                }
            }
        }
        let order: Vec<usize> = (0..n_nodes).filter(|&i| live[i]).collect();

        // Consumption map over the LIVE graph (for single-live-output
        // checks and buffer planning).
        let mut last_use: BTreeMap<usize, usize> = BTreeMap::new(); // value -> node idx
        let mut use_count: BTreeMap<usize, usize> = BTreeMap::new();
        for &i in &order {
            for &iv in &self.nodes[i].inputs {
                last_use.insert(iv, i);
                *use_count.entry(iv).or_insert(0) += 1;
            }
        }
        let consumed_later = |v: usize, after_node: usize| -> bool {
            match last_use.get(&v) {
                Some(&n) => n > after_node,
                None => false,
            }
        };

        // ---- Pass 2: automatic fusion. Greedy maximal regions: at each
        // start, take the longest consecutive fusible span that builds.
        let mut steps: Vec<Step> = Vec::new();
        let mut ops_fused: u64 = 0;
        let mut pos = 0usize;
        while pos < order.len() {
            let ni = self.nodes[order[pos]].clone();
            if fusible_kind(&ni.name).is_some() {
                // Longest fusible run starting here.
                let mut run = pos;
                while run < order.len()
                    && fusible_kind(&self.nodes[order[run]].name).is_some()
                {
                    run += 1;
                }
                let mut built: Option<(FusedRegion, usize)> = None;
                let mut hi = run;
                while hi > pos + 1 && built.is_none() {
                    if let Some(mut region) =
                        try_region(self, &order, pos, hi, &consumed_later)
                    {
                        // Reduce tail: region output consumed ONLY by a
                        // `sum` (then optionally only by a `mul_scalar`),
                        // both immediately following.
                        let mut consumed = hi;
                        if use_count.get(&region.out) == Some(&1)
                            && hi < order.len()
                            && self.nodes[order[hi]].name == "sum"
                            && self.nodes[order[hi]].inputs == [region.out]
                            && self.values[self.nodes[order[hi]].output].shape.is_empty()
                        {
                            let sum_out = self.nodes[order[hi]].output;
                            let mut scale = None;
                            let mut tail_end = hi + 1;
                            if use_count.get(&sum_out) == Some(&1)
                                && hi + 1 < order.len()
                                && self.nodes[order[hi + 1]].name == "mul_scalar"
                                && self.nodes[order[hi + 1]].inputs == [sum_out]
                            {
                                if let Some(s) = param_f64(&self.nodes[order[hi + 1]].params[0])
                                {
                                    scale = Some(s);
                                    tail_end = hi + 2;
                                }
                            }
                            if sum_out != self.output || tail_end == hi + 1 {
                                let final_out =
                                    self.nodes[order[tail_end - 1]].output;
                                region.n_ops += tail_end - hi;
                                region.out = final_out;
                                region.reduce = Some(ReduceTail { scale });
                                consumed = tail_end;
                            }
                        }
                        ops_fused += region.n_ops as u64;
                        built = Some((region, consumed));
                    } else {
                        hi -= 1;
                    }
                }
                if let Some((region, consumed)) = built {
                    steps.push(Step::Fused(region));
                    pos = consumed;
                    continue;
                }
            }
            steps.push(Step::Op {
                name: ni.name.clone(),
                inputs: ni.inputs.clone(),
                donate: Vec::new(),
                params: ni.params.clone(),
                out: ni.output,
            });
            pos += 1;
        }

        // ---- Pass 3: buffer planning. Recompute liveness over the
        // final step sequence: a value produced by a step and last used
        // at a later step is dropped right after that use; plain-op
        // inputs at their last use that appear once are donation
        // candidates for `call_owned`'s output-stealing.
        let mut produced_at: BTreeMap<usize, usize> = BTreeMap::new();
        for (si, s) in steps.iter().enumerate() {
            match s {
                Step::Op { out, .. } => produced_at.insert(*out, si),
                Step::Fused(r) => produced_at.insert(r.out, si),
            };
        }
        let step_inputs = |s: &Step| -> Vec<usize> {
            match s {
                Step::Op { inputs, .. } => inputs.clone(),
                Step::Fused(r) => r.exts.clone(),
            }
        };
        let mut last_step: BTreeMap<usize, usize> = BTreeMap::new();
        for (si, s) in steps.iter().enumerate() {
            for iv in step_inputs(s) {
                last_step.insert(iv, si);
            }
        }
        let interior = |v: usize| -> bool {
            v != self.output
                && produced_at.contains_key(&v)
                && self.values[v].external.is_none()
                && v >= self.n_session_inputs
        };
        let mut buffers_planned: u64 = 0;
        let mut drop_after: Vec<Vec<usize>> = vec![Vec::new(); steps.len()];
        for (si, s) in steps.iter_mut().enumerate() {
            let ins = step_inputs(s);
            if let Step::Op { inputs, donate, .. } = s {
                *donate = inputs
                    .iter()
                    .map(|&iv| {
                        interior(iv)
                            && last_step.get(&iv) == Some(&si)
                            && inputs.iter().filter(|&&x| x == iv).count() == 1
                    })
                    .collect();
                buffers_planned += donate.iter().filter(|&&d| d).count() as u64;
            }
            for iv in ins {
                if interior(iv) && last_step.get(&iv) == Some(&si) {
                    drop_after[si].push(iv);
                }
            }
        }

        let externals: Vec<(usize, Tensor)> = self
            .values
            .iter()
            .enumerate()
            .filter_map(|(i, v)| v.external.as_ref().map(|t| (i, t.clone())))
            .collect();

        PlannedGraph {
            steps,
            externals,
            n_session_inputs: self.n_session_inputs,
            n_values: self.values.len(),
            output: self.output,
            drop_after,
            ops_fused,
            buffers_planned,
        }
    }
}
