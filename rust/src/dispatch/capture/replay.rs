//! Replay of an optimized captured graph.
//!
//! Plain steps re-dispatch through [`crate::dispatch::call_owned`] — same
//! kernels, same TensorIter plans, same autograd recording — with
//! buffer-planned operands passed in owned so the donation protocol can
//! steal dying interior storages. Fused regions run through the `fuse`
//! drivers and record ONE autograd node whose gradients are the region's
//! emitted backward tapes; both paths are bitwise identical to the eager
//! trace at every thread count and SIMD mode (pinned by
//! `tests/capture_parity.rs`).

use crate::autograd::{self, ClosureFunction, SavedTensor};
use crate::dispatch::fuse::{self, Access};
use crate::dispatch::reduce::sum_to_shape;
use crate::dispatch::Param;
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

use super::graph::{FusedRegion, PlannedGraph, Step};

/// Execute `plan` against fresh session `inputs` (guard-checked by the
/// caller to match the captured shapes/dtypes).
pub(crate) fn replay(plan: &PlannedGraph, inputs: &[&Tensor]) -> Tensor {
    torsk_assert!(inputs.len() == plan.n_session_inputs, "capture: replay arity mismatch");
    let mut slots: Vec<Option<Tensor>> = vec![None; plan.n_values];
    for (i, t) in inputs.iter().enumerate() {
        slots[i] = Some((*t).clone());
    }
    for (vid, t) in &plan.externals {
        slots[*vid] = Some(t.clone());
    }

    for (si, step) in plan.steps.iter().enumerate() {
        match step {
            Step::Op { name, inputs: ivs, donate, params, out } => {
                let owned: Vec<Tensor> = ivs
                    .iter()
                    .zip(donate.iter())
                    .map(|(&iv, &d)| {
                        if d {
                            // Last use of an interior value: move the only
                            // handle in, arming the donation protocol.
                            slots[iv].take().expect("capture: donated slot not live")
                        } else {
                            slots[iv].as_ref().expect("capture: slot not live").clone()
                        }
                    })
                    .collect();
                let y = crate::dispatch::call_owned(name, owned, params);
                slots[*out] = Some(y);
            }
            Step::Fused(region) => {
                let y = run_region(region, &slots);
                slots[region.out] = Some(y);
            }
        }
        for &v in &plan.drop_after[si] {
            slots[v] = None;
        }
    }
    slots[plan.output].clone().expect("capture: graph output not produced")
}

/// Execute one fused region: forward through the map / map-reduce tape
/// driver, then record a single autograd node whose gradients run the
/// emitted backward tapes (mirroring the hand-registered fused kernels'
/// backward structure exactly).
fn run_region(region: &FusedRegion, slots: &[Option<Tensor>]) -> Tensor {
    let exts: Vec<Tensor> = region
        .exts
        .iter()
        .map(|&v| slots[v].as_ref().expect("capture: region operand not live").clone())
        .collect();
    let srcs: Vec<(&Tensor, Access)> =
        exts.iter().zip(region.access.iter()).map(|(t, &a)| (t, a)).collect();

    let n: usize = region.map_shape.iter().product();
    let dt = exts[0].dtype();
    let out = match &region.reduce {
        None => fuse::run_map("captured:fuse", &region.fwd, &srcs, &region.map_shape),
        Some(tail) => {
            // The trailing `mul_scalar` parameter as the runtime dtype
            // sees it (F32 kernels narrow first), exactly like
            // `mean_factor` does for the hand-fused losses; a bare `sum`
            // finishes with an exact `* 1.0`.
            let factor = match tail.scale {
                Some(s) if dt == DType::F32 => (s as f32) as f64,
                Some(s) => s,
                None => 1.0,
            };
            fuse::run_map_sum(
                "captured:fuse_sum",
                &region.fwd,
                &srcs,
                n,
                fuse::finish_mean,
                factor,
            )
        }
    };

    let ext_refs: Vec<&Tensor> = exts.iter().collect();
    if autograd::should_record(&ext_refs) {
        let bwds = region.bwds.clone();
        let access = region.access.clone();
        let ext_shapes = region.ext_shapes.clone();
        let map_shape = region.map_shape.clone();
        let scale = region.reduce.as_ref().map(|t| t.scale);
        let saved: Vec<SavedTensor> = exts.iter().map(SavedTensor::save).collect();
        autograd::record(&ext_refs, &out, || {
            ClosureFunction::new("captured:fuse", move |g| {
                let held: Vec<Tensor> = saved.iter().map(|s| s.unpack()).collect();
                // For reduce regions the upstream scalar grad is
                // prescaled by the folded `mul_scalar`'s backward —
                // the same dispatched op the eager chain ran — and read
                // with Scalar access (== the eager `broadcast_to`).
                let gs;
                let g_access;
                match scale {
                    Some(Some(s)) => {
                        gs = crate::dispatch::call_owned(
                            "mul_scalar",
                            vec![g.clone()],
                            &[Param::F64(s)],
                        );
                        g_access = Access::Scalar;
                    }
                    Some(None) => {
                        gs = g.clone();
                        g_access = Access::Scalar;
                    }
                    None => {
                        gs = g.clone();
                        g_access = Access::Flat;
                    }
                }
                let mut srcs: Vec<(&Tensor, Access)> =
                    held.iter().zip(access.iter()).map(|(t, &a)| (t, a)).collect();
                srcs.push((&gs, g_access));
                let mut grads: Vec<Option<Tensor>> = Vec::with_capacity(bwds.len());
                for (k, tape) in bwds.iter().enumerate() {
                    let full = fuse::run_map("captured:fuse_bwd", tape, &srcs, &map_shape);
                    let gk = if ext_shapes[k] == map_shape {
                        full
                    } else {
                        // Broadcast operand: reduce exactly like the
                        // eager engine's `grad_to` does.
                        sum_to_shape(&full, &ext_shapes[k])
                    };
                    grads.push(Some(gk));
                }
                grads
            })
        });
    }
    out
}
