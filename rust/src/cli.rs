//! `torsk` command-line launcher.
//!
//! Subcommands (offline crate set has no clap; parsing is hand-rolled):
//!
//! ```text
//! torsk train --model resnet50 --steps 20 [--mode eager|naive] [--device cpu|sim]
//! torsk bench --model alexnet --steps 10      one-off throughput probe
//! torsk profile --model resnet50 --ops 40     Figure 1 style timeline
//! torsk artifacts                             list AOT artifacts
//! torsk adoption                              Figure 3 pipeline demo
//! torsk info                                  build/config summary
//! ```

use std::collections::HashMap;
use std::time::Instant;

use crate::device::Device;
use crate::models::{self};
use crate::optim::{Optimizer, Sgd};
use crate::{adoption, device, profiler};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), value);
        }
        i += 1;
    }
    flags
}

fn get<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

/// Entry point used by `main.rs`.
pub fn run() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let code = match cmd {
        "train" => cmd_train(&flags),
        "bench" => cmd_bench(&flags),
        "profile" => cmd_profile(&flags),
        "artifacts" => cmd_artifacts(),
        "adoption" => cmd_adoption(&flags),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "torsk — an imperative-style, high-performance deep learning library\n\
         \n\
         USAGE: torsk <command> [--flags]\n\
         \n\
         COMMANDS:\n\
           train     --model <name> --steps N [--mode eager|naive] [--device cpu|sim] [--lr F]\n\
           bench     --model <name> --steps N [--device cpu|sim]\n\
           profile   --model <name> [--ops N]     (Figure 1 timeline)\n\
           artifacts                              (list AOT graphs)\n\
           adoption  [--months N]                 (Figure 3 pipeline)\n\
           info\n\
         \n\
         MODELS: {}",
        models::TABLE1_MODELS.join(", ")
    );
}

fn resolve_device(flags: &HashMap<String, String>) -> Device {
    match get(flags, "device", "cpu") {
        "sim" => Device::Sim,
        _ => Device::Cpu,
    }
}

fn cmd_train(flags: &HashMap<String, String>) -> i32 {
    let name = get(flags, "model", "alexnet");
    let steps: usize = get(flags, "steps", "10").parse().unwrap_or(10);
    let lr: f32 = get(flags, "lr", "0.05").parse().unwrap_or(0.05);
    let device = resolve_device(flags);
    let mode = get(flags, "mode", "eager");
    if mode == "naive" {
        device::set_async_enabled(false);
        crate::ctx::use_naive_sim_allocator();
    }
    let Some(model) = models::by_name_on(name, device) else {
        eprintln!("unknown model `{name}`");
        return 2;
    };
    println!("training {name} for {steps} steps (mode={mode}, device={device}, lr={lr})");
    let mut opt = Sgd::new(model.parameters(), lr).with_momentum(0.9);
    let t0 = Instant::now();
    let mut units = 0usize;
    for step in 0..steps {
        opt.zero_grad();
        let batch = model.make_batch(step as u64).to_device(device);
        let loss = model.loss(&batch);
        loss.backward();
        opt.step();
        units += batch.units();
        println!("  step {step:>4}  loss {:.4}", loss.item());
    }
    device::synchronize();
    let dt = t0.elapsed().as_secs_f64();
    println!("done: {:.2} units/s over {dt:.2}s", units as f64 / dt);
    0
}

fn cmd_bench(flags: &HashMap<String, String>) -> i32 {
    let name = get(flags, "model", "alexnet");
    let steps: usize = get(flags, "steps", "10").parse().unwrap_or(10);
    let device = resolve_device(flags);
    let Some(model) = models::by_name_on(name, device) else {
        eprintln!("unknown model `{name}`");
        return 2;
    };
    let mut opt = Sgd::new(model.parameters(), 0.05);
    // Warmup step outside the timed region.
    let batch = model.make_batch(0).to_device(device);
    model.loss(&batch).backward();
    opt.zero_grad();
    device::synchronize();
    let t0 = Instant::now();
    let mut units = 0;
    for step in 0..steps {
        opt.zero_grad();
        let batch = model.make_batch(step as u64).to_device(device);
        model.loss(&batch).backward();
        opt.step();
        units += batch.units();
    }
    device::synchronize();
    let dt = t0.elapsed().as_secs_f64();
    println!("{name}: {:.2} units/s ({steps} steps, {dt:.3}s)", units as f64 / dt);
    0
}

fn cmd_profile(flags: &HashMap<String, String>) -> i32 {
    let name = get(flags, "model", "resnet50");
    let max_ops: usize = get(flags, "ops", "40").parse().unwrap_or(40);
    let Some(model) = models::by_name_on(name, Device::Sim) else {
        eprintln!("unknown model `{name}`");
        return 2;
    };
    let batch = model.make_batch(0).to_device(Device::Sim);
    profiler::start();
    let loss = crate::autograd::no_grad(|| model.loss(&batch));
    let _ = loss.item(); // force sync
    let events = profiler::stop();
    let shown: Vec<profiler::TraceEvent> = events.into_iter().take(max_ops * 2).collect();
    println!("{}", profiler::ascii_timeline(&shown, 100));
    let host = profiler::track_stats(&shown, profiler::Track::Host);
    let dev = profiler::track_stats(&shown, profiler::Track::Stream(0));
    println!(
        "host busy {:.2} ms over {} spans; stream busy {:.2} ms, utilization {:.1}%",
        host.busy_ns as f64 / 1e6,
        host.spans,
        dev.busy_ns as f64 / 1e6,
        100.0 * dev.utilization()
    );
    0
}

fn cmd_artifacts() -> i32 {
    match crate::runtime::Runtime::global().list() {
        Ok(names) => {
            println!("AOT artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
            0
        }
        Err(e) => {
            eprintln!("cannot read artifacts: {e} (run `make artifacts`)");
            1
        }
    }
}

fn cmd_adoption(flags: &HashMap<String, String>) -> i32 {
    let months: usize = get(flags, "months", "30").parse().unwrap_or(30);
    let model = adoption::AdoptionModel { months, ..Default::default() };
    let papers = model.generate(7);
    let counts = adoption::count_mentions(&papers, months);
    let series = adoption::pytorch_share_series(&counts);
    println!("{}", adoption::ascii_chart(&series, 12));
    0
}

fn cmd_info() -> i32 {
    println!("torsk {} — PyTorch (NeurIPS 2019) reproduction", env!("CARGO_PKG_VERSION"));
    println!("kernel threads: {}", crate::kernels::num_threads());
    println!("async dispatch: {}", device::async_enabled());
    println!(
        "artifacts dir : {}",
        std::env::var("TORSK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    );
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let args: Vec<String> =
            ["--model", "resnet50", "--steps", "5", "--verbose"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args);
        assert_eq!(get(&f, "model", ""), "resnet50");
        assert_eq!(get(&f, "steps", "0"), "5");
        assert_eq!(get(&f, "verbose", "false"), "true");
        assert_eq!(get(&f, "missing", "dflt"), "dflt");
    }

    #[test]
    fn flag_parsing_empty() {
        let f = parse_flags(&[]);
        assert!(f.is_empty());
    }
}
