//! Index-order policies for the [`DataLoader`](super::DataLoader) (§4.2).
//!
//! A [`Sampler`] decides *which* example indices an epoch visits and in
//! what order; [`BatchSampler`] groups that order into batches. Keeping
//! the policy separate from the loader mirrors `torch.utils.data`'s
//! `Sampler`/`BatchSampler` split and is what makes epoch order
//! **seed-deterministic**: the order is a pure function of
//! `(seed, epoch, len)`, computed once on the calling thread — worker
//! threads only ever *execute* batches, never choose them, so the batch
//! sequence is identical at any worker count.

use crate::rng::Rng;
use crate::torsk_assert;

/// An epoch's visit order over a dataset of `len` examples.
///
/// Implementations must be pure functions of `(len, epoch)` and their own
/// configuration (seed): the loader may ask for the same epoch's order
/// twice and expects identical answers.
pub trait Sampler: Send + Sync {
    /// The index order for `epoch`. Every returned index must be `< len`.
    fn order(&self, len: usize, epoch: usize) -> Vec<usize>;
}

/// Visit `0..len` in order — the deterministic evaluation-mode sampler.
pub struct SequentialSampler;

impl Sampler for SequentialSampler {
    fn order(&self, len: usize, _epoch: usize) -> Vec<usize> {
        (0..len).collect()
    }
}

/// A seed-deterministic random permutation per epoch, driven by the
/// crate's [`Rng`] (xoshiro256**): epoch `e` shuffles with
/// `seed ^ e·0x9E37_79B9`, so every epoch reshuffles but the whole
/// schedule replays exactly from one seed — `torch.manual_seed` for the
/// data order.
pub struct RandomSampler {
    pub seed: u64,
}

impl RandomSampler {
    pub fn new(seed: u64) -> RandomSampler {
        RandomSampler { seed }
    }
}

impl Sampler for RandomSampler {
    fn order(&self, len: usize, epoch: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..len).collect();
        let mut r = Rng::new(self.seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9));
        r.shuffle(&mut order);
        order
    }
}

/// Groups a sampler's order into batch index lists.
#[derive(Clone, Copy, Debug)]
pub struct BatchSampler {
    pub batch_size: usize,
    /// Drop the trailing partial batch (fixed-shape training loops).
    pub drop_last: bool,
}

impl BatchSampler {
    pub fn new(batch_size: usize, drop_last: bool) -> BatchSampler {
        torsk_assert!(batch_size > 0, "BatchSampler: batch_size must be > 0");
        BatchSampler { batch_size, drop_last }
    }

    /// Number of batches an epoch over `len` examples yields.
    pub fn num_batches(&self, len: usize) -> usize {
        if self.drop_last {
            len / self.batch_size
        } else {
            len.div_ceil(self.batch_size)
        }
    }

    /// Chunk an epoch order into per-batch index lists.
    pub fn batches(&self, order: &[usize]) -> Vec<Vec<usize>> {
        order
            .chunks(self.batch_size)
            .filter(|c| !self.drop_last || c.len() == self.batch_size)
            .map(|c| c.to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_is_identity() {
        assert_eq!(SequentialSampler.order(5, 0), vec![0, 1, 2, 3, 4]);
        assert_eq!(SequentialSampler.order(5, 3), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn random_is_a_permutation_and_seed_deterministic() {
        let s = RandomSampler::new(7);
        let a = s.order(100, 0);
        let b = s.order(100, 0);
        assert_eq!(a, b, "same (seed, epoch) must replay the same order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<usize>>());
        assert_ne!(a, (0..100).collect::<Vec<usize>>(), "should not be identity");
    }

    #[test]
    fn random_reshuffles_per_epoch_but_not_per_instance() {
        let s1 = RandomSampler::new(11);
        let s2 = RandomSampler::new(11);
        assert_eq!(s1.order(64, 2), s2.order(64, 2));
        assert_ne!(s1.order(64, 0), s1.order(64, 1), "epochs should reshuffle");
        let s3 = RandomSampler::new(12);
        assert_ne!(s1.order(64, 0), s3.order(64, 0), "seeds should differ");
    }

    #[test]
    fn batch_sampler_chunks_and_drop_last() {
        let order: Vec<usize> = (0..10).collect();
        let keep = BatchSampler::new(4, false);
        assert_eq!(keep.num_batches(10), 3);
        assert_eq!(keep.batches(&order), vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]);
        let drop = BatchSampler::new(4, true);
        assert_eq!(drop.num_batches(10), 2);
        assert_eq!(drop.batches(&order), vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
    }

    #[test]
    fn batch_sampler_empty_order() {
        let bs = BatchSampler::new(4, false);
        assert_eq!(bs.num_batches(0), 0);
        assert!(bs.batches(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "batch_size must be > 0")]
    fn zero_batch_size_panics() {
        BatchSampler::new(0, false);
    }
}
