//! The data pipeline (§4.2): datasets, samplers, collation, and the
//! parallel prefetching loader.
//!
//! The paper's observation is operational, not architectural: "one of the
//! core design principles of PyTorch is that data loading should never
//! stall the computation" — workers prepare the *next* batch while the
//! accelerator chews on the current one, staging through reused pinned
//! buffers. torsk reproduces that shape with four separable pieces:
//!
//! | piece | role | determinism contract |
//! |---|---|---|
//! | [`Dataset`] | indexed example source (`len` + `get`) | `get(i)` is a pure function of `i` |
//! | [`Sampler`] / [`BatchSampler`] | epoch visit order, chunked into batches | pure function of `(seed, epoch, len)` |
//! | [`Collate`] | samples → batched tensors, through the caching allocator | pure function of the samples |
//! | [`DataLoader`] | N worker threads over a bounded prefetch queue | ordered reassembly by sequence number |
//!
//! Because each layer is deterministic and the loader reassembles
//! completed batches in claim order, **the batch stream is bitwise
//! identical at any worker count** — `workers(0)` (in-line), `1`, or `4`
//! produce the same tensors in the same order (`tests/data_loader.rs`).
//! Worker threads only hide latency; they never change results.
//!
//! The loader also *measures* what it hides: time the training thread
//! spends blocked inside `next()` is recorded as **loader stall**
//! ([`DataLoader::stats`]), and `benches/train_loop.rs` reports it as a
//! fraction of end-to-end wall time per worker count in
//! `BENCH_train.json` — the whole-model view that per-op microbenchmarks
//! (`BENCH_ops.json`) cannot see.
//!
//! Threads, not processes: the paper forks worker *processes* because of
//! the Python GIL and ships batches through shared memory
//! ([`crate::multiproc`] reproduces that machinery). A Rust loader has no
//! GIL to dodge, so workers are plain threads and a batch "ships" as an
//! `Arc` handoff over a channel — the same overlap, none of the
//! serialization cost the paper engineers around.
//!
//! [`synthetic`] provides the deterministic stand-in datasets for the
//! Table 1 workloads.

pub mod collate;
pub mod loader;
pub mod sampler;
pub mod synthetic;

pub use collate::{stack_into_batch, Collate, DefaultCollate};
pub use loader::{BatchIter, DataLoader, LoaderStats};
pub use sampler::{BatchSampler, RandomSampler, Sampler, SequentialSampler};
pub use synthetic::{SyntheticImages, SyntheticInteractions, SyntheticSeq2Seq};

use crate::tensor::Tensor;

/// An indexed example source: `__getitem__` + `__len__` (§4.2).
///
/// `get` must be deterministic per index (and cheap to call from multiple
/// threads at once): loader workers fetch concurrently, and the
/// bitwise-reproducibility guarantee of the pipeline rests on the dataset
/// returning the same bytes for the same index every time.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Fetch one example: (input, target).
    fn get(&self, index: usize) -> (Tensor, Tensor);
}
