//! Data loading (§4.2): `Dataset` behaves like a (possibly lazy) list;
//! `DataLoader` shuffles, batches, and parallelizes with background worker
//! threads (the paper's multiprocessing workers — see `crate::multiproc`
//! for the process-based variant).

pub mod synthetic;

pub use synthetic::{SyntheticImages, SyntheticInteractions, SyntheticSeq2Seq};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::ops;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// An indexed example source: `__getitem__` + `__len__` (§4.2).
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Fetch one example: (input, target).
    fn get(&self, index: usize) -> (Tensor, Tensor);
}

/// Batching, shuffling, parallel-prefetching loader.
pub struct DataLoader {
    dataset: Arc<dyn Dataset>,
    pub batch_size: usize,
    pub shuffle: bool,
    pub num_workers: usize,
    pub drop_last: bool,
    seed: u64,
    epoch: AtomicUsize,
}

impl DataLoader {
    pub fn new(dataset: Arc<dyn Dataset>, batch_size: usize) -> DataLoader {
        DataLoader {
            dataset,
            batch_size,
            shuffle: false,
            num_workers: 0,
            drop_last: false,
            seed: 0,
            epoch: AtomicUsize::new(0),
        }
    }

    pub fn shuffle(mut self, on: bool) -> DataLoader {
        self.shuffle = on;
        self
    }

    pub fn workers(mut self, n: usize) -> DataLoader {
        self.num_workers = n;
        self
    }

    pub fn drop_last(mut self, on: bool) -> DataLoader {
        self.drop_last = on;
        self
    }

    pub fn seed(mut self, s: u64) -> DataLoader {
        self.seed = s;
        self
    }

    /// Number of batches per epoch.
    pub fn num_batches(&self) -> usize {
        if self.drop_last {
            self.dataset.len() / self.batch_size
        } else {
            self.dataset.len().div_ceil(self.batch_size)
        }
    }

    fn epoch_order(&self, epoch: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.dataset.len()).collect();
        if self.shuffle {
            let mut r = Rng::new(self.seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9));
            r.shuffle(&mut order);
        }
        order
    }

    /// Iterate one epoch of batches.
    pub fn iter(&self) -> BatchIter {
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst);
        let order = self.epoch_order(epoch);
        let batches: Vec<Vec<usize>> = order
            .chunks(self.batch_size)
            .filter(|c| !self.drop_last || c.len() == self.batch_size)
            .map(|c| c.to_vec())
            .collect();

        if self.num_workers == 0 {
            BatchIter::Serial { dataset: self.dataset.clone(), batches, next: 0 }
        } else {
            // Background workers: each claims batch indices round-robin and
            // sends collated batches through a bounded channel (prefetch
            // queue), preserving order via per-batch sequence numbers.
            let (tx, rx) = mpsc::sync_channel(self.num_workers * 2);
            let counter = Arc::new(AtomicUsize::new(0));
            let batches = Arc::new(batches);
            for _ in 0..self.num_workers {
                let tx = tx.clone();
                let dataset = self.dataset.clone();
                let counter = counter.clone();
                let batches = batches.clone();
                std::thread::spawn(move || loop {
                    let i = counter.fetch_add(1, Ordering::SeqCst);
                    if i >= batches.len() {
                        return;
                    }
                    let b = collate(&*dataset, &batches[i]);
                    if tx.send((i, b)).is_err() {
                        return;
                    }
                });
            }
            BatchIter::Parallel {
                rx,
                pending: std::collections::HashMap::new(),
                next: 0,
                total: batches.len(),
            }
        }
    }
}

/// Stack examples into (inputs, targets) batch tensors.
fn collate(dataset: &dyn Dataset, indices: &[usize]) -> (Tensor, Tensor) {
    let examples: Vec<(Tensor, Tensor)> = indices.iter().map(|&i| dataset.get(i)).collect();
    let xs: Vec<&Tensor> = examples.iter().map(|(x, _)| x).collect();
    let ys: Vec<&Tensor> = examples.iter().map(|(_, y)| y).collect();
    (ops::stack(&xs, 0), stack_targets(&ys))
}

fn stack_targets(ys: &[&Tensor]) -> Tensor {
    // Targets may be i64 scalars (classification) or f32 tensors.
    match ys[0].dtype() {
        crate::tensor::DType::I64 => {
            let mut data = Vec::with_capacity(ys.len());
            for y in ys {
                data.extend(y.to_vec::<i64>());
            }
            let per = ys[0].numel();
            if per == 1 {
                Tensor::from_vec(data, &[ys.len()])
            } else {
                let mut shape = vec![ys.len()];
                shape.extend_from_slice(ys[0].shape());
                Tensor::from_vec(data, &shape)
            }
        }
        crate::tensor::DType::F32 | crate::tensor::DType::F64 => ops::stack(ys, 0),
    }
}

/// Iterator over collated batches.
pub enum BatchIter {
    Serial {
        dataset: Arc<dyn Dataset>,
        batches: Vec<Vec<usize>>,
        next: usize,
    },
    Parallel {
        rx: mpsc::Receiver<(usize, (Tensor, Tensor))>,
        pending: std::collections::HashMap<usize, (Tensor, Tensor)>,
        next: usize,
        total: usize,
    },
}

impl Iterator for BatchIter {
    type Item = (Tensor, Tensor);

    fn next(&mut self) -> Option<(Tensor, Tensor)> {
        match self {
            BatchIter::Serial { dataset, batches, next } => {
                if *next >= batches.len() {
                    return None;
                }
                let b = collate(&**dataset, &batches[*next]);
                *next += 1;
                Some(b)
            }
            BatchIter::Parallel { rx, pending, next, total } => {
                if *next >= *total {
                    return None;
                }
                loop {
                    if let Some(b) = pending.remove(next) {
                        *next += 1;
                        return Some(b);
                    }
                    match rx.recv() {
                        Ok((i, b)) => {
                            pending.insert(i, b);
                        }
                        Err(_) => return None,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Range100;
    impl Dataset for Range100 {
        fn len(&self) -> usize {
            100
        }
        fn get(&self, i: usize) -> (Tensor, Tensor) {
            (Tensor::full(&[2], i as f32), Tensor::from_vec(vec![i as i64], &[]))
        }
    }

    #[test]
    fn serial_loader_covers_dataset_in_order() {
        let dl = DataLoader::new(Arc::new(Range100), 16);
        let mut seen = vec![];
        for (x, y) in dl.iter() {
            assert_eq!(x.size(1), 2);
            assert_eq!(x.size(0), y.size(0));
            seen.extend(y.to_vec::<i64>());
        }
        assert_eq!(seen, (0..100).collect::<Vec<i64>>());
    }

    #[test]
    fn drop_last_trims_partial_batch() {
        let dl = DataLoader::new(Arc::new(Range100), 16).drop_last(true);
        assert_eq!(dl.num_batches(), 6);
        let n: usize = dl.iter().map(|(x, _)| x.size(0)).sum();
        assert_eq!(n, 96);
    }

    #[test]
    fn shuffle_is_a_permutation_and_differs_per_epoch() {
        let dl = DataLoader::new(Arc::new(Range100), 10).shuffle(true).seed(7);
        let epoch1: Vec<i64> = dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect();
        let epoch2: Vec<i64> = dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect();
        let mut s1 = epoch1.clone();
        s1.sort_unstable();
        assert_eq!(s1, (0..100).collect::<Vec<i64>>());
        assert_ne!(epoch1, epoch2, "epochs should reshuffle");
        assert_ne!(epoch1, (0..100).collect::<Vec<i64>>(), "should not be identity");
    }

    #[test]
    fn parallel_loader_matches_serial_order() {
        let serial: Vec<i64> = DataLoader::new(Arc::new(Range100), 8)
            .iter()
            .flat_map(|(_, y)| y.to_vec::<i64>())
            .collect();
        let parallel: Vec<i64> = DataLoader::new(Arc::new(Range100), 8)
            .workers(4)
            .iter()
            .flat_map(|(_, y)| y.to_vec::<i64>())
            .collect();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn collate_f32_targets() {
        struct Reg;
        impl Dataset for Reg {
            fn len(&self) -> usize {
                4
            }
            fn get(&self, i: usize) -> (Tensor, Tensor) {
                (Tensor::full(&[3], i as f32), Tensor::full(&[1], i as f32 * 2.0))
            }
        }
        let dl = DataLoader::new(Arc::new(Reg), 2);
        let (x, y) = dl.iter().next().unwrap();
        assert_eq!(x.shape(), &[2, 3]);
        assert_eq!(y.shape(), &[2, 1]);
        assert_eq!(y.to_vec::<f32>(), vec![0.0, 2.0]);
    }
}
