//! The parallel prefetching [`DataLoader`] (§4.2).
//!
//! One epoch is planned entirely on the calling thread — the
//! [`Sampler`](super::Sampler) computes a seed-deterministic index order,
//! the [`BatchSampler`](super::BatchSampler) chunks it — and only then do
//! `num_workers` background threads execute batches: each worker claims
//! the next unclaimed batch index, fetches its samples from the
//! [`Dataset`](super::Dataset), collates them, and pushes the result into
//! a **bounded prefetch queue** (`sync_channel`). The consuming iterator
//! reassembles results by per-batch sequence number, so the batch stream
//! is **identical — bitwise — at any worker count**, including 0 (the
//! serial in-line mode). `tests/data_loader.rs` pins that at workers
//! 0/1/4.
//!
//! Stall accounting: every nanosecond the training thread spends *inside*
//! `next()` — collating in-line at `workers = 0`, or blocked on the queue
//! waiting for the next in-order batch — is counted as loader stall
//! ([`DataLoader::stats`]). The end-to-end bench (`benches/train_loop.rs`
//! → `BENCH_train.json`) reports that stall as a fraction of wall time:
//! it is exactly the overlap the paper's worker processes exist to hide.
//!
//! Shutdown: the iterator owns its worker `JoinHandle`s. Dropping it
//! mid-epoch raises a shutdown flag and disconnects the queue — workers
//! blocked in `send` wake with an error, finish nothing further, and are
//! joined before `drop` returns. No worker outlives its epoch. A worker
//! that *panics* (dataset or collate bug) disconnects the channel early;
//! the consumer detects the missing batch and re-panics on the training
//! thread, so a bad dataset fails identically at any worker count
//! instead of silently truncating the epoch.
//!
//! The drop-time join is **bounded**: a worker wedged inside a buggy
//! `Dataset::get` or `Collate` (blocked on a lock, an FD, a remote call)
//! would otherwise hang `drop` forever. After
//! [`DataLoader::join_timeout_ms`] (default 30 s, env override
//! `TORSK_LOADER_JOIN_TIMEOUT_MS`) the drop names each stuck worker and
//! its last claimed batch index on stderr, records the event in
//! [`LoaderStats::join_timeouts`] / [`DataLoader::last_join_timeout`],
//! and detaches the threads instead of hanging the training process.
//!
//! Resume: [`DataLoader::resume`] pins the next `iter()` to a given
//! `(epoch, next_batch)` coordinate. Because the sampler order is a pure
//! function of `(seed, epoch, len)`, the resumed iterator re-plans the
//! epoch and skips the first `next_batch` batches, yielding exactly the
//! remaining schedule — bitwise, at any worker count (`tests/chaos.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::tensor::Tensor;
use crate::torsk_assert;

use super::collate::{Collate, DefaultCollate};
use super::sampler::{BatchSampler, RandomSampler, Sampler, SequentialSampler};
use super::Dataset;

/// Cumulative loader-side counters, shared between a [`DataLoader`] and
/// the iterators it hands out.
#[derive(Default)]
struct LoaderCounters {
    /// Nanoseconds the consumer spent blocked inside `next()`.
    stall_ns: AtomicU64,
    /// Batches yielded.
    batches: AtomicU64,
    /// Times a drop-time worker join hit its timeout and detached.
    join_timeouts: AtomicU64,
    /// Human-readable diagnostic from the most recent join timeout.
    last_join_timeout: Mutex<Option<String>>,
}

/// Counts live (not-yet-exited) workers so `drop` can wait for *thread
/// exit* with a timeout — `JoinHandle::join` alone cannot be bounded.
/// Each worker holds a [`Departing`] guard; the count drops even if the
/// worker panics.
struct ExitLatch {
    live: Mutex<usize>,
    cv: Condvar,
}

impl ExitLatch {
    fn new(n: usize) -> Arc<ExitLatch> {
        Arc::new(ExitLatch { live: Mutex::new(n), cv: Condvar::new() })
    }

    fn depart(&self) {
        let mut live = self.live.lock().unwrap_or_else(|e| e.into_inner());
        *live -= 1;
        self.cv.notify_all();
    }

    /// Wait until every worker has exited; `false` on timeout.
    fn wait_all_exited(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut live = self.live.lock().unwrap_or_else(|e| e.into_inner());
        while *live > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) =
                self.cv.wait_timeout(live, deadline - now).unwrap_or_else(|e| e.into_inner());
            live = guard;
        }
        true
    }
}

/// Drop guard a worker thread holds for its whole life: unwinding out of
/// a panicking `Dataset::get` still signals the latch.
struct Departing(Arc<ExitLatch>);

impl Drop for Departing {
    fn drop(&mut self) {
        self.0.depart();
    }
}

/// Sentinel in the per-worker claim table: no batch currently claimed.
const NO_BATCH: usize = usize::MAX;

/// A point-in-time snapshot of a loader's counters (see
/// [`DataLoader::stats`]); `delta` two snapshots around an epoch to get
/// per-epoch numbers, like [`crate::alloc::AllocStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoaderStats {
    /// Nanoseconds the training thread spent waiting on the loader.
    pub stall_ns: u64,
    /// Batches yielded so far.
    pub batches: u64,
    /// Drop-time worker joins that timed out and detached (see
    /// [`DataLoader::join_timeout_ms`]). Nonzero means a dataset or
    /// collate wedged; [`DataLoader::last_join_timeout`] names it.
    pub join_timeouts: u64,
}

impl LoaderStats {
    /// Difference of two snapshots.
    pub fn delta(&self, earlier: &LoaderStats) -> LoaderStats {
        LoaderStats {
            stall_ns: self.stall_ns - earlier.stall_ns,
            batches: self.batches - earlier.batches,
            join_timeouts: self.join_timeouts - earlier.join_timeouts,
        }
    }
}

/// Batching, shuffling, parallel-prefetching loader over a [`Dataset`].
///
/// ```no_run
/// # // no_run: rustdoc test binaries don't inherit the xla_extension
/// # // rpath; the same flow is executed in tests/data_loader.rs.
/// use std::sync::Arc;
/// use torsk::data::{DataLoader, SyntheticImages};
///
/// let dataset = Arc::new(SyntheticImages::new(512, 3, 32, 32, 10));
/// let loader = DataLoader::new(dataset, 32)
///     .shuffle(true)   // RandomSampler: epoch order derives from the seed
///     .seed(42)
///     .workers(4);     // 4 background threads over a bounded queue
/// for (images, labels) in loader.iter() {
///     assert_eq!(images.shape(), &[32, 3, 32, 32]);
///     assert_eq!(labels.shape(), &[32]);
///     // train_step(&images, &labels);
/// }
/// // Identical batches would have arrived with .workers(0) — order is
/// // pinned by sequence-number reassembly, not by thread timing.
/// let stats = loader.stats();
/// println!("loader stall: {} ns over {} batches", stats.stall_ns, stats.batches);
/// ```
pub struct DataLoader {
    dataset: Arc<dyn Dataset>,
    collate: Arc<dyn Collate>,
    custom_sampler: Option<Arc<dyn Sampler>>,
    pub batch_size: usize,
    pub shuffle: bool,
    pub num_workers: usize,
    pub drop_last: bool,
    /// Prefetch-queue capacity; 0 = auto (`2 × workers`, min 2).
    prefetch: usize,
    seed: u64,
    epoch: AtomicUsize,
    /// First batch index the next `iter()` yields (one-shot; see
    /// [`Self::resume`]).
    start_batch: AtomicUsize,
    join_timeout: Duration,
    counters: Arc<LoaderCounters>,
}

fn default_join_timeout() -> Duration {
    let ms = std::env::var("TORSK_LOADER_JOIN_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(30_000);
    Duration::from_millis(ms)
}

impl DataLoader {
    pub fn new(dataset: Arc<dyn Dataset>, batch_size: usize) -> DataLoader {
        DataLoader {
            dataset,
            collate: Arc::new(DefaultCollate),
            custom_sampler: None,
            batch_size,
            shuffle: false,
            num_workers: 0,
            drop_last: false,
            prefetch: 0,
            seed: 0,
            epoch: AtomicUsize::new(0),
            start_batch: AtomicUsize::new(0),
            join_timeout: default_join_timeout(),
            counters: Arc::new(LoaderCounters::default()),
        }
    }

    /// Shuffle with a [`RandomSampler`] (seed-deterministic per epoch).
    pub fn shuffle(mut self, on: bool) -> DataLoader {
        self.shuffle = on;
        self
    }

    /// Number of background worker threads (0 = collate in-line).
    pub fn workers(mut self, n: usize) -> DataLoader {
        self.num_workers = n;
        self
    }

    pub fn drop_last(mut self, on: bool) -> DataLoader {
        self.drop_last = on;
        self
    }

    pub fn seed(mut self, s: u64) -> DataLoader {
        self.seed = s;
        self
    }

    /// Override the prefetch-queue capacity (default `2 × workers`).
    pub fn prefetch(mut self, depth: usize) -> DataLoader {
        self.prefetch = depth;
        self
    }

    /// Replace the epoch-order policy (wins over [`Self::shuffle`]).
    pub fn sampler(mut self, s: Arc<dyn Sampler>) -> DataLoader {
        self.custom_sampler = Some(s);
        self
    }

    /// Replace the sample → batch assembly step.
    pub fn collate(mut self, c: Arc<dyn Collate>) -> DataLoader {
        self.collate = c;
        self
    }

    /// Bound the `Drop`-time worker join (default 30 s, or the
    /// `TORSK_LOADER_JOIN_TIMEOUT_MS` env var): past the timeout, stuck
    /// workers are named (with their last claimed batch index) on stderr
    /// and detached instead of hanging the process.
    pub fn join_timeout_ms(mut self, ms: u64) -> DataLoader {
        self.join_timeout = Duration::from_millis(ms);
        self
    }

    /// Set the epoch the next [`Self::iter`] call runs (epochs otherwise
    /// auto-increment per `iter()`); lets resumed training replay the
    /// exact shuffle schedule.
    pub fn set_epoch(&self, e: usize) {
        self.epoch.store(e, Ordering::SeqCst);
    }

    /// Resume mid-epoch from a checkpoint coordinate: the next
    /// [`Self::iter`] call runs `epoch` and yields batches from
    /// `next_batch` onward. Because the sampler order is a pure function
    /// of `(seed, epoch, len)`, the resumed stream is bitwise identical
    /// to the tail an uninterrupted run of `epoch` would have produced.
    /// One-shot: later `iter()` calls start their epochs from batch 0.
    pub fn resume(&self, epoch: usize, next_batch: usize) {
        self.epoch.store(epoch, Ordering::SeqCst);
        self.start_batch.store(next_batch, Ordering::SeqCst);
    }

    /// The sampler seed (recorded in checkpoints so a resumed loader can
    /// be rebuilt identically).
    pub fn seed_value(&self) -> u64 {
        self.seed
    }

    /// Diagnostic from the most recent drop-time join timeout, naming
    /// the stuck worker(s) and their last claimed batch index.
    pub fn last_join_timeout(&self) -> Option<String> {
        self.counters.last_join_timeout.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Number of batches per epoch.
    pub fn num_batches(&self) -> usize {
        BatchSampler::new(self.batch_size, self.drop_last).num_batches(self.dataset.len())
    }

    /// Cumulative stall/batch counters across all epochs so far.
    pub fn stats(&self) -> LoaderStats {
        LoaderStats {
            stall_ns: self.counters.stall_ns.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            join_timeouts: self.counters.join_timeouts.load(Ordering::Relaxed),
        }
    }

    fn epoch_batches(&self, epoch: usize) -> Vec<Vec<usize>> {
        let order = match &self.custom_sampler {
            Some(s) => s.order(self.dataset.len(), epoch),
            None if self.shuffle => RandomSampler::new(self.seed).order(self.dataset.len(), epoch),
            None => SequentialSampler.order(self.dataset.len(), epoch),
        };
        BatchSampler::new(self.batch_size, self.drop_last).batches(&order)
    }

    /// Iterate one epoch of `(inputs, targets)` batches.
    pub fn iter(&self) -> BatchIter {
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst);
        let start = self.start_batch.swap(0, Ordering::SeqCst);
        let mut batches = self.epoch_batches(epoch);
        torsk_assert!(
            start <= batches.len(),
            "DataLoader::resume: next_batch {start} exceeds the {} batches of epoch {epoch}",
            batches.len()
        );
        // Resume skip: plan the full epoch (same sampler stream), then
        // drop the batches the interrupted run already consumed.
        let batches = batches.split_off(start);

        let imp = if self.num_workers == 0 {
            IterImpl::Serial {
                dataset: self.dataset.clone(),
                collate: self.collate.clone(),
                batches,
                next: 0,
            }
        } else {
            let cap =
                if self.prefetch == 0 { (self.num_workers * 2).max(2) } else { self.prefetch };
            let (tx, rx) = mpsc::sync_channel(cap);
            let total = batches.len();
            let claim = Arc::new(AtomicUsize::new(0));
            let shutdown = Arc::new(AtomicBool::new(false));
            let batches = Arc::new(batches);
            let latch = ExitLatch::new(self.num_workers);
            let claims: Arc<Vec<AtomicUsize>> =
                Arc::new((0..self.num_workers).map(|_| AtomicUsize::new(NO_BATCH)).collect());
            let mut handles = Vec::with_capacity(self.num_workers);
            for w in 0..self.num_workers {
                let tx = tx.clone();
                let dataset = self.dataset.clone();
                let collate = self.collate.clone();
                let claim = claim.clone();
                let shutdown = shutdown.clone();
                let batches = batches.clone();
                let departing = Departing(latch.clone());
                let claims = claims.clone();
                let h = std::thread::Builder::new()
                    .name(format!("torsk-data-{w}"))
                    .spawn(move || {
                        // Held for the thread's whole life; dropping it
                        // (return *or* panic) signals the exit latch.
                        let _departing = departing;
                        loop {
                            if shutdown.load(Ordering::Acquire) {
                                return;
                            }
                            let i = claim.fetch_add(1, Ordering::SeqCst);
                            if i >= batches.len() {
                                claims[w].store(NO_BATCH, Ordering::Release);
                                return;
                            }
                            // Published so a timed-out drop can name the
                            // batch this worker is wedged on.
                            claims[w].store(i, Ordering::Release);
                            let samples: Vec<(Tensor, Tensor)> =
                                batches[i].iter().map(|&j| dataset.get(j)).collect();
                            let b = collate.collate(&samples);
                            // A send error means the consumer dropped the
                            // epoch: stop quietly.
                            if tx.send((i, b)).is_err() {
                                claims[w].store(NO_BATCH, Ordering::Release);
                                return;
                            }
                        }
                    })
                    .expect("spawn data worker");
                handles.push(h);
            }
            // The iterator holds only the receiver; once every worker
            // exits, the channel disconnects and `recv` reports the end.
            IterImpl::Parallel {
                rx: Some(rx),
                pending: HashMap::new(),
                next: 0,
                total,
                shutdown,
                handles,
                latch,
                claims,
                join_timeout: self.join_timeout,
            }
        };
        BatchIter { imp, counters: self.counters.clone(), stall_ns: 0 }
    }
}

enum IterImpl {
    Serial {
        dataset: Arc<dyn Dataset>,
        collate: Arc<dyn Collate>,
        batches: Vec<Vec<usize>>,
        next: usize,
    },
    Parallel {
        rx: Option<mpsc::Receiver<(usize, (Tensor, Tensor))>>,
        /// Out-of-order arrivals awaiting their turn. Workers claim
        /// indices in order, so this normally holds at most
        /// `workers + queue capacity` batches; one pathologically slow
        /// batch can let later ones accumulate here while the consumer
        /// drains the queue looking for it.
        pending: HashMap<usize, (Tensor, Tensor)>,
        next: usize,
        total: usize,
        shutdown: Arc<AtomicBool>,
        handles: Vec<std::thread::JoinHandle<()>>,
        latch: Arc<ExitLatch>,
        /// Per-worker last claimed batch index ([`NO_BATCH`] = none).
        claims: Arc<Vec<AtomicUsize>>,
        join_timeout: Duration,
    },
}

/// One epoch's batch stream; see [`DataLoader::iter`].
pub struct BatchIter {
    imp: IterImpl,
    counters: Arc<LoaderCounters>,
    stall_ns: u64,
}

impl BatchIter {
    /// Nanoseconds this epoch's consumer has spent blocked in `next()`.
    pub fn stall_ns(&self) -> u64 {
        self.stall_ns
    }
}

impl Iterator for BatchIter {
    type Item = (Tensor, Tensor);

    fn next(&mut self) -> Option<(Tensor, Tensor)> {
        let (got, stall) = match &mut self.imp {
            IterImpl::Serial { dataset, collate, batches, next } => {
                if *next >= batches.len() {
                    (None, 0)
                } else {
                    let t0 = Instant::now();
                    let samples: Vec<(Tensor, Tensor)> =
                        batches[*next].iter().map(|&j| dataset.get(j)).collect();
                    let b = collate.collate(&samples);
                    *next += 1;
                    (Some(b), t0.elapsed().as_nanos() as u64)
                }
            }
            IterImpl::Parallel { rx, pending, next, total, .. } => {
                if *next >= *total {
                    (None, 0)
                } else if let Some(b) = pending.remove(next) {
                    // Already reassembled: the prefetch hid the work.
                    *next += 1;
                    (Some(b), 0)
                } else {
                    let t0 = Instant::now();
                    let chan = rx.as_ref().expect("receiver alive while batches remain");
                    let got = loop {
                        match chan.recv() {
                            Ok((i, b)) => {
                                if i == *next {
                                    *next += 1;
                                    break Some(b);
                                }
                                pending.insert(i, b);
                            }
                            // Workers only exit early by panicking (the
                            // shutdown flag is raised exclusively in
                            // `drop`, which never calls `next`). Swallowing
                            // this would silently truncate the epoch —
                            // fail as loudly as workers=0 would have.
                            Err(_) => panic!(
                                "DataLoader worker thread panicked mid-epoch: batch {} of {} \
                                 never arrived (see the worker's panic message above)",
                                *next, *total
                            ),
                        }
                    };
                    (got, t0.elapsed().as_nanos() as u64)
                }
            }
        };
        if stall > 0 {
            self.stall_ns += stall;
            self.counters.stall_ns.fetch_add(stall, Ordering::Relaxed);
        }
        if got.is_some() {
            self.counters.batches.fetch_add(1, Ordering::Relaxed);
        }
        got
    }
}

impl Drop for BatchIter {
    fn drop(&mut self) {
        if let IterImpl::Parallel { rx, shutdown, handles, latch, claims, join_timeout, .. } =
            &mut self.imp
        {
            // Flag first, then disconnect: a worker blocked in `send`
            // wakes with an error the moment the receiver drops, and any
            // worker between batches sees the flag before claiming more.
            shutdown.store(true, Ordering::Release);
            drop(rx.take());
            // Bounded join: only a worker wedged *inside* `Dataset::get`
            // or `Collate` can still be running at this point, and it
            // may never come back.
            let stuck: Vec<String> = if latch.wait_all_exited(*join_timeout) {
                Vec::new()
            } else {
                handles
                    .iter()
                    .enumerate()
                    .filter(|(_, h)| !h.is_finished())
                    .map(|(w, h)| {
                        let name = h.thread().name().unwrap_or("torsk-data-?").to_string();
                        match claims[w].load(Ordering::Acquire) {
                            NO_BATCH => format!("{name} (no batch claimed)"),
                            b => format!("{name} (last claimed batch {b})"),
                        }
                    })
                    .collect()
            };
            if stuck.is_empty() {
                // Every worker has exited (or did so while we enumerated
                // the stragglers): reap them, surfacing no panics — the
                // consumer already re-panicked on missing batches.
                for h in handles.drain(..) {
                    let _ = h.join();
                }
            } else {
                let msg = format!(
                    "DataLoader drop: {} worker(s) still running after {:?} — {} — \
                     detaching; the dataset or collate is wedged",
                    stuck.len(),
                    join_timeout,
                    stuck.join(", ")
                );
                eprintln!("torsk: {msg}");
                self.counters.join_timeouts.fetch_add(1, Ordering::Relaxed);
                *self.counters.last_join_timeout.lock().unwrap_or_else(|e| e.into_inner()) =
                    Some(msg);
                // Dropping the handles detaches the stuck threads.
                handles.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Range100;
    impl Dataset for Range100 {
        fn len(&self) -> usize {
            100
        }
        fn get(&self, i: usize) -> (Tensor, Tensor) {
            (Tensor::full(&[2], i as f32), Tensor::from_vec(vec![i as i64], &[]))
        }
    }

    #[test]
    fn serial_loader_covers_dataset_in_order() {
        let dl = DataLoader::new(Arc::new(Range100), 16);
        let mut seen = vec![];
        for (x, y) in dl.iter() {
            assert_eq!(x.size(1), 2);
            assert_eq!(x.size(0), y.size(0));
            seen.extend(y.to_vec::<i64>());
        }
        assert_eq!(seen, (0..100).collect::<Vec<i64>>());
    }

    #[test]
    fn drop_last_trims_partial_batch() {
        let dl = DataLoader::new(Arc::new(Range100), 16).drop_last(true);
        assert_eq!(dl.num_batches(), 6);
        let n: usize = dl.iter().map(|(x, _)| x.size(0)).sum();
        assert_eq!(n, 96);
    }

    #[test]
    fn shuffle_is_a_permutation_and_differs_per_epoch() {
        let dl = DataLoader::new(Arc::new(Range100), 10).shuffle(true).seed(7);
        let epoch1: Vec<i64> = dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect();
        let epoch2: Vec<i64> = dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect();
        let mut s1 = epoch1.clone();
        s1.sort_unstable();
        assert_eq!(s1, (0..100).collect::<Vec<i64>>());
        assert_ne!(epoch1, epoch2, "epochs should reshuffle");
        assert_ne!(epoch1, (0..100).collect::<Vec<i64>>(), "should not be identity");
    }

    #[test]
    fn set_epoch_replays_the_same_shuffle() {
        let dl = DataLoader::new(Arc::new(Range100), 10).shuffle(true).seed(9);
        let first: Vec<i64> = dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect();
        dl.set_epoch(0);
        let replay: Vec<i64> = dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect();
        assert_eq!(first, replay);
    }

    #[test]
    fn parallel_loader_matches_serial_order() {
        let serial: Vec<i64> = DataLoader::new(Arc::new(Range100), 8)
            .iter()
            .flat_map(|(_, y)| y.to_vec::<i64>())
            .collect();
        let parallel: Vec<i64> = DataLoader::new(Arc::new(Range100), 8)
            .workers(4)
            .iter()
            .flat_map(|(_, y)| y.to_vec::<i64>())
            .collect();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn collate_f32_targets() {
        struct Reg;
        impl Dataset for Reg {
            fn len(&self) -> usize {
                4
            }
            fn get(&self, i: usize) -> (Tensor, Tensor) {
                (Tensor::full(&[3], i as f32), Tensor::full(&[1], i as f32 * 2.0))
            }
        }
        let dl = DataLoader::new(Arc::new(Reg), 2);
        let (x, y) = dl.iter().next().unwrap();
        assert_eq!(x.shape(), &[2, 3]);
        assert_eq!(y.shape(), &[2, 1]);
        assert_eq!(y.to_vec::<f32>(), vec![0.0, 2.0]);
    }

    #[test]
    fn empty_dataset_yields_no_batches() {
        struct Empty;
        impl Dataset for Empty {
            fn len(&self) -> usize {
                0
            }
            fn get(&self, _: usize) -> (Tensor, Tensor) {
                unreachable!("empty dataset")
            }
        }
        let dl = DataLoader::new(Arc::new(Empty), 4);
        assert_eq!(dl.num_batches(), 0);
        assert!(dl.iter().next().is_none());
        let dlp = DataLoader::new(Arc::new(Empty), 4).workers(2);
        assert!(dlp.iter().next().is_none());
    }

    #[test]
    fn stall_accounting_counts_batches_and_time() {
        let dl = DataLoader::new(Arc::new(Range100), 10);
        let before = dl.stats();
        let n = dl.iter().count();
        let d = dl.stats().delta(&before);
        assert_eq!(n, 10);
        assert_eq!(d.batches, 10);
        assert!(d.stall_ns > 0, "serial mode's collate time is all stall");
    }

    #[test]
    fn resume_yields_exactly_the_remaining_batches() {
        let dl = DataLoader::new(Arc::new(Range100), 10).shuffle(true).seed(5);
        let full: Vec<Vec<i64>> = dl.iter().map(|(_, y)| y.to_vec::<i64>()).collect();
        dl.resume(0, 4);
        let tail: Vec<Vec<i64>> = dl.iter().map(|(_, y)| y.to_vec::<i64>()).collect();
        assert_eq!(tail, full[4..], "resumed epoch must replay the exact remaining schedule");
        // One-shot: the next iter() runs epoch 1 in full.
        let next: Vec<Vec<i64>> = dl.iter().map(|(_, y)| y.to_vec::<i64>()).collect();
        assert_eq!(next.len(), 10);
        assert_ne!(next, full, "epoch 1 reshuffles");
    }

    #[test]
    fn resumed_tail_is_identical_at_any_worker_count() {
        let run = |workers: usize| -> Vec<i64> {
            let dl = DataLoader::new(Arc::new(Range100), 8).shuffle(true).seed(3).workers(workers);
            dl.resume(2, 5);
            dl.iter().flat_map(|(_, y)| y.to_vec::<i64>()).collect()
        };
        let serial = run(0);
        assert_eq!(serial.len(), 100 - 5 * 8);
        assert_eq!(serial, run(1));
        assert_eq!(serial, run(4));
    }

    #[test]
    fn resume_at_epoch_end_yields_nothing() {
        let dl = DataLoader::new(Arc::new(Range100), 10);
        dl.resume(0, 10);
        assert!(dl.iter().next().is_none());
    }

    #[test]
    #[should_panic(expected = "exceeds the 10 batches")]
    fn resume_past_the_epoch_is_a_loud_error() {
        let dl = DataLoader::new(Arc::new(Range100), 10);
        dl.resume(0, 11);
        let _ = dl.iter();
    }

    #[test]
    fn prefetch_capacity_override_still_covers_epoch() {
        let ys: Vec<i64> = DataLoader::new(Arc::new(Range100), 8)
            .workers(3)
            .prefetch(1)
            .iter()
            .flat_map(|(_, y)| y.to_vec::<i64>())
            .collect();
        assert_eq!(ys, (0..100).collect::<Vec<i64>>());
    }
}
