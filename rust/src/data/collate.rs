//! Sample → batch assembly (§4.2's pinned-buffer analogue).
//!
//! [`Collate`] turns a list of per-example `(input, target)` tensors into
//! one `(inputs, targets)` batch pair. [`DefaultCollate`] allocates the
//! batch tensors through the host **caching allocator** and writes each
//! sample with one contiguous `memcpy` — no per-sample views, no
//! intermediate `unsqueeze`/`cat` tensors. Because every epoch asks for
//! the same batch shapes, steady-state batches are served straight from
//! the allocator cache: the paper reuses pinned staging buffers across
//! iterations for the same reason, and `tests/data_loader.rs` pins the
//! cache-hit rate.
//!
//! Collation runs on loader worker threads; implementations must be
//! deterministic (no RNG, no global state) or batch contents would depend
//! on the worker count.

use crate::device::Device;
use crate::profiler::{self, Track};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// Assemble per-example samples into one batched `(inputs, targets)` pair.
pub trait Collate: Send + Sync {
    fn collate(&self, samples: &[(Tensor, Tensor)]) -> (Tensor, Tensor);
}

/// The standard collation: stack inputs along a new leading dim; stack
/// targets the same way, except one-element `i64` targets (`[1]`-shaped
/// classification labels) flatten to a `[N]` vector like scalar ones —
/// inputs never flatten.
pub struct DefaultCollate;

impl Collate for DefaultCollate {
    fn collate(&self, samples: &[(Tensor, Tensor)]) -> (Tensor, Tensor) {
        torsk_assert!(!samples.is_empty(), "collate: empty batch");
        let span = profiler::begin(Track::Host, "data:collate");
        let xs: Vec<&Tensor> = samples.iter().map(|(x, _)| x).collect();
        let ys: Vec<&Tensor> = samples.iter().map(|(_, y)| y).collect();
        let x = stack_into_batch(&xs);
        // Label-style targets: [1]-shaped i64 flattens to [N] (the [N,1]
        // batch is contiguous, so the reshape is a zero-copy view).
        let y0 = ys[0];
        let y = if y0.dtype() == DType::I64 && y0.shape() == [1] {
            stack_into_batch(&ys).reshape(&[ys.len()])
        } else {
            stack_into_batch(&ys)
        };
        profiler::end(span);
        (x, y)
    }
}

/// Stack equally-shaped host samples into a freshly allocated batch
/// tensor (served by the caching allocator) with one `memcpy` per sample.
///
/// Shape rule: sample shape `[d...]` → batch `[N, d...]`; scalar samples
/// (`[]`) → batch `[N]`.
pub fn stack_into_batch(samples: &[&Tensor]) -> Tensor {
    torsk_assert!(!samples.is_empty(), "stack_into_batch: empty sample list");
    let first = samples[0];
    let dtype = first.dtype();
    let shape = first.shape().to_vec();
    let per = first.numel();
    for s in samples.iter().skip(1) {
        torsk_assert!(s.dtype() == dtype, "collate: mixed sample dtypes");
        torsk_assert!(s.shape() == shape.as_slice(), "collate: mixed sample shapes");
    }
    let out_shape: Vec<usize> = if shape.is_empty() {
        vec![samples.len()]
    } else {
        let mut s = Vec::with_capacity(shape.len() + 1);
        s.push(samples.len());
        s.extend_from_slice(&shape);
        s
    };
    let out = Tensor::empty(&out_shape, dtype, Device::Cpu);
    let bytes = per * dtype.size();
    for (i, s) in samples.iter().enumerate() {
        torsk_assert!(s.device() == Device::Cpu, "collate expects host samples");
        let src = s.contiguous();
        // SAFETY: `out` is freshly allocated, contiguous and exclusively
        // owned; `src` is contiguous with exactly `per` elements of the
        // same dtype, and slot `i` is a disjoint `bytes`-sized region.
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.data_ptr().ptr() as *const u8,
                out.data_ptr().ptr().add(i * bytes),
                bytes,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stacks_f32_rows() {
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0]);
        let b = Tensor::from_slice(&[4.0f32, 5.0, 6.0]);
        let out = stack_into_batch(&[&a, &b]);
        assert_eq!(out.shape(), &[2, 3]);
        assert_eq!(out.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_samples_flatten_to_vector() {
        let a = Tensor::from_vec(vec![3i64], &[]);
        let b = Tensor::from_vec(vec![7i64], &[]);
        let out = stack_into_batch(&[&a, &b]);
        assert_eq!(out.shape(), &[2]);
        assert_eq!(out.to_vec::<i64>(), vec![3, 7]);
    }

    #[test]
    fn i64_unit_targets_flatten_but_inputs_never_do() {
        // [1]-shaped i64: a *target* flattens to [N] (classification
        // labels), an *input* keeps its dim (token ids stay [N,1]).
        let c = Tensor::from_vec(vec![9i64], &[1]);
        let d = Tensor::from_vec(vec![2i64], &[1]);
        assert_eq!(stack_into_batch(&[&c, &d]).shape(), &[2, 1]);
        let samples = vec![(c.clone(), c.clone()), (d.clone(), d.clone())];
        let (x, y) = DefaultCollate.collate(&samples);
        assert_eq!(x.shape(), &[2, 1], "inputs never flatten");
        assert_eq!(y.shape(), &[2], "unit i64 targets flatten");
        assert_eq!(y.to_vec::<i64>(), vec![9, 2]);
    }

    #[test]
    fn f32_single_element_targets_keep_their_dim() {
        let a = Tensor::from_vec(vec![0.5f32], &[1]);
        let b = Tensor::from_vec(vec![1.5f32], &[1]);
        let out = stack_into_batch(&[&a, &b]);
        assert_eq!(out.shape(), &[2, 1]);
        assert_eq!(out.to_vec::<f32>(), vec![0.5, 1.5]);
    }

    #[test]
    fn non_contiguous_samples_are_copied_correctly() {
        let m = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let col = m.t(); // strided view [[1,3],[2,4]]
        let out = stack_into_batch(&[&col, &col]);
        assert_eq!(out.shape(), &[2, 2, 2]);
        assert_eq!(out.to_vec::<f32>(), vec![1.0, 3.0, 2.0, 4.0, 1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn default_collate_pairs_inputs_and_targets() {
        let samples = vec![
            (Tensor::full(&[3], 1.0), Tensor::from_vec(vec![0i64], &[])),
            (Tensor::full(&[3], 2.0), Tensor::from_vec(vec![1i64], &[])),
        ];
        let (x, y) = DefaultCollate.collate(&samples);
        assert_eq!(x.shape(), &[2, 3]);
        assert_eq!(y.shape(), &[2]);
        assert_eq!(y.to_vec::<i64>(), vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "mixed sample shapes")]
    fn mixed_shapes_panic() {
        let a = Tensor::ones(&[2]);
        let b = Tensor::ones(&[3]);
        stack_into_batch(&[&a, &b]);
    }
}
