//! Synthetic datasets for the Table 1 workloads (DESIGN.md §2: stand-ins
//! for ImageNet / WMT / ml-20m, generated deterministically from a seed so
//! every execution mode sees identical data).

use super::Dataset;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Random images + labels (the AlexNet/VGG/ResNet/MobileNet workload).
pub struct SyntheticImages {
    pub n: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub classes: usize,
    pub seed: u64,
}

impl SyntheticImages {
    pub fn new(n: usize, channels: usize, height: usize, width: usize, classes: usize) -> Self {
        SyntheticImages { n, channels, height, width, classes, seed: 0 }
    }

    /// Builder-style seed override (a different deterministic split).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Dataset for SyntheticImages {
    fn len(&self) -> usize {
        self.n
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(self.seed, index as u64);
        let mut img = vec![0.0f32; self.channels * self.height * self.width];
        r.fill_normal(&mut img, 0.0, 1.0);
        let label = r.below(self.classes as u64) as i64;
        (
            Tensor::from_vec(img, &[self.channels, self.height, self.width]),
            Tensor::from_vec(vec![label], &[]),
        )
    }
}

/// Random token sequences (the GNMTv2 workload): source and target
/// sequences of fixed length from a vocabulary.
pub struct SyntheticSeq2Seq {
    pub n: usize,
    pub src_len: usize,
    pub tgt_len: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl SyntheticSeq2Seq {
    pub fn new(n: usize, src_len: usize, tgt_len: usize, vocab: usize) -> Self {
        SyntheticSeq2Seq { n, src_len, tgt_len, vocab, seed: 0 }
    }

    /// Builder-style seed override (a different deterministic split).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Dataset for SyntheticSeq2Seq {
    fn len(&self) -> usize {
        self.n
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(self.seed, index as u64);
        let src: Vec<i64> = (0..self.src_len).map(|_| r.below(self.vocab as u64) as i64).collect();
        let tgt: Vec<i64> = (0..self.tgt_len).map(|_| r.below(self.vocab as u64) as i64).collect();
        (
            Tensor::from_vec(src, &[self.src_len]),
            Tensor::from_vec(tgt, &[self.tgt_len]),
        )
    }
}

/// Random (user, item) -> click interactions (the NCF workload).
pub struct SyntheticInteractions {
    pub n: usize,
    pub users: usize,
    pub items: usize,
    pub seed: u64,
}

impl SyntheticInteractions {
    pub fn new(n: usize, users: usize, items: usize) -> Self {
        SyntheticInteractions { n, users, items, seed: 0 }
    }

    /// Builder-style seed override (a different deterministic split).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Dataset for SyntheticInteractions {
    fn len(&self) -> usize {
        self.n
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(self.seed, index as u64);
        let user = r.below(self.users as u64) as i64;
        let item = r.below(self.items as u64) as i64;
        // Planted structure: interaction likelihood depends on id parity so
        // models can actually learn something.
        let label = if (user + item) % 2 == 0 { r.bernoulli(0.8) } else { r.bernoulli(0.2) };
        (
            Tensor::from_vec(vec![user, item], &[2]),
            Tensor::from_vec(vec![if label { 1.0f32 } else { 0.0 }], &[1]),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_are_deterministic_per_index() {
        let d = SyntheticImages::new(10, 3, 4, 4, 5);
        let (x1, y1) = d.get(3);
        let (x2, y2) = d.get(3);
        assert_eq!(x1.to_vec::<f32>(), x2.to_vec::<f32>());
        assert_eq!(y1.to_vec::<i64>(), y2.to_vec::<i64>());
        let (x3, _) = d.get(4);
        assert_ne!(x1.to_vec::<f32>(), x3.to_vec::<f32>());
    }

    #[test]
    fn image_labels_in_range() {
        let d = SyntheticImages::new(50, 1, 2, 2, 7);
        for i in 0..50 {
            let (_, y) = d.get(i);
            let l = y.to_vec::<i64>()[0];
            assert!((0..7).contains(&l));
        }
    }

    #[test]
    fn seq2seq_shapes_and_vocab() {
        let d = SyntheticSeq2Seq::new(5, 12, 9, 100);
        let (src, tgt) = d.get(0);
        assert_eq!(src.shape(), &[12]);
        assert_eq!(tgt.shape(), &[9]);
        assert!(src.to_vec::<i64>().iter().all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn interactions_have_planted_signal() {
        let d = SyntheticInteractions::new(20_000, 100, 100);
        let (mut even_pos, mut even_n, mut odd_pos, mut odd_n) = (0f32, 0, 0f32, 0);
        for i in 0..d.len() {
            let (x, y) = d.get(i);
            let v = x.to_vec::<i64>();
            let label = y.to_vec::<f32>()[0];
            if (v[0] + v[1]) % 2 == 0 {
                even_pos += label;
                even_n += 1;
            } else {
                odd_pos += label;
                odd_n += 1;
            }
        }
        assert!(even_pos / even_n as f32 > 0.7);
        assert!((odd_pos / odd_n as f32) < 0.3);
    }
}
