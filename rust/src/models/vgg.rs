//! VGG-19 (Simonyan & Zisserman 2014), scaled to 32×32 at width/4.

use super::{image_batch, image_loss, Batch, BenchModel};
use crate::nn::{Conv2d, Flatten, Linear, MaxPool2d, Module, ReLU, Sequential};
use crate::tensor::Tensor;

/// VGG-19: 16 conv + 3 fc layers in five pooled blocks.
pub struct Vgg19 {
    net: Sequential,
    pub classes: usize,
    pub batch: usize,
    pub input: (usize, usize, usize),
}

impl Vgg19 {
    pub fn table1() -> Vgg19 {
        Vgg19::new(3, 32, 10, 16)
    }

    pub fn new(c_in: usize, hw: usize, classes: usize, batch: usize) -> Vgg19 {
        // Original widths /4: 64,128,256,512,512 -> 16,32,64,128,128.
        // Conv counts per block (VGG-19): 2,2,4,4,4.
        let cfg: [(usize, usize); 5] = [(16, 2), (32, 2), (64, 4), (128, 4), (128, 4)];
        let mut net = Sequential::new();
        let mut c = c_in;
        for (width, convs) in cfg {
            for _ in 0..convs {
                net.push(Box::new(Conv2d::new(c, width, 3, 1, 1)));
                net.push(Box::new(ReLU));
                c = width;
            }
            net.push(Box::new(MaxPool2d::new(2, 2)));
        }
        let spatial = hw / 32; // five 2x pools
        net.push(Box::new(Flatten));
        net.push(Box::new(Linear::new(128 * spatial * spatial, 256)));
        net.push(Box::new(ReLU));
        net.push(Box::new(Linear::new(256, 256)));
        net.push(Box::new(ReLU));
        net.push(Box::new(Linear::new(256, classes)));
        Vgg19 { net, classes, batch, input: (c_in, hw, hw) }
    }
}

impl Module for Vgg19 {
    fn forward(&self, x: &Tensor) -> Tensor {
        self.net.forward(x)
    }
    fn parameters(&self) -> Vec<Tensor> {
        self.net.parameters()
    }
    fn set_training(&mut self, training: bool) {
        self.net.set_training(training);
    }
    fn name(&self) -> &'static str {
        "Vgg19"
    }
}

impl BenchModel for Vgg19 {
    fn name(&self) -> &'static str {
        "vgg19"
    }
    fn parameters(&self) -> Vec<Tensor> {
        self.net.parameters()
    }
    fn loss(&self, batch: &Batch) -> Tensor {
        image_loss(&self.net, batch)
    }
    fn make_batch(&self, seed: u64) -> Batch {
        let (c, h, w) = self.input;
        image_batch(seed, self.batch, c, h, w, self.classes)
    }
    fn set_training(&mut self, training: bool) {
        self.net.set_training(training);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nineteen_weight_layers() {
        crate::rng::manual_seed(0);
        let m = Vgg19::table1();
        // 16 convs + 3 fcs, each with weight+bias.
        assert_eq!(Module::parameters(&m).len(), 19 * 2);
    }

    #[test]
    fn forward_and_backward_small() {
        crate::rng::manual_seed(0);
        let m = Vgg19::new(3, 32, 10, 1);
        let batch = m.make_batch(0);
        let loss = BenchModel::loss(&m, &batch);
        assert!(loss.item().is_finite());
        loss.backward();
        assert!(Module::parameters(&m)[0].grad().is_some());
    }
}
