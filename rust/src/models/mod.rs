//! The model zoo for the paper's Table 1: AlexNet, VGG-19, ResNet-50,
//! MobileNetV1, a GNMTv2-style attentional seq2seq, and NCF.
//!
//! Architectures follow the originals; input resolution and widths are
//! scaled (DESIGN.md §6) so CPU training is tractable — Table 1 compares
//! *execution modes on identical models*, so the mode ratios (not absolute
//! img/s) are the reproduced quantity.

pub mod alexnet;
pub mod gnmt;
pub mod mobilenet;
pub mod ncf;
pub mod resnet;
pub mod vgg;

pub use alexnet::AlexNet;
pub use gnmt::Gnmt;
pub use mobilenet::MobileNetV1;
pub use ncf::Ncf;
pub use resnet::ResNet50;
pub use vgg::Vgg19;

use crate::nn::Module;
use crate::tensor::Tensor;

/// A batch of training data, generic over task type.
pub enum Batch {
    /// images [N,C,H,W] + labels [N] (i64)
    Images(Tensor, Tensor),
    /// src tokens [N, S] + tgt tokens [N, T] (both i64)
    Seq2Seq(Tensor, Tensor),
    /// (user,item) pairs [N,2] (i64) + click labels [N,1] (f32)
    Interactions(Tensor, Tensor),
}

impl Batch {
    /// Units processed per step for throughput reporting: images for CNNs,
    /// target tokens for GNMT, samples for NCF — matching Table 1's units.
    pub fn units(&self) -> usize {
        match self {
            Batch::Images(x, _) => x.size(0),
            Batch::Seq2Seq(_, tgt) => tgt.numel(),
            Batch::Interactions(x, _) => x.size(0),
        }
    }

    /// Move the batch's tensors to a device.
    pub fn to_device(&self, d: crate::device::Device) -> Batch {
        match self {
            Batch::Images(x, y) => Batch::Images(x.to_device(d), y.to_device(d)),
            Batch::Seq2Seq(s, t) => Batch::Seq2Seq(s.to_device(d), t.to_device(d)),
            Batch::Interactions(x, y) => Batch::Interactions(x.to_device(d), y.to_device(d)),
        }
    }
}

/// A Table 1 benchmark model: forward + loss over a [`Batch`].
pub trait BenchModel: Send {
    fn name(&self) -> &'static str;
    fn parameters(&self) -> Vec<Tensor>;
    /// Forward pass + loss (the thing `backward()` is called on).
    fn loss(&self, batch: &Batch) -> Tensor;
    /// Generate a deterministic synthetic batch of the benchmark size.
    fn make_batch(&self, seed: u64) -> Batch;
    fn set_training(&mut self, training: bool);
}

/// Image-classifier helper: wraps a `Module` backbone + cross-entropy.
pub(crate) fn image_loss(backbone: &dyn Module, batch: &Batch) -> Tensor {
    match batch {
        Batch::Images(x, y) => {
            let logits = backbone.forward(x);
            crate::ops::cross_entropy(&logits, y)
        }
        _ => crate::torsk_bail!("image model expects an image batch"),
    }
}

/// Deterministic synthetic image batch.
pub(crate) fn image_batch(seed: u64, n: usize, c: usize, h: usize, w: usize, classes: usize) -> Batch {
    let mut r = crate::rng::Rng::new(seed);
    let mut img = vec![0.0f32; n * c * h * w];
    r.fill_normal(&mut img, 0.0, 1.0);
    let labels: Vec<i64> = (0..n).map(|_| r.below(classes as u64) as i64).collect();
    Batch::Images(Tensor::from_vec(img, &[n, c, h, w]), Tensor::from_vec(labels, &[n]))
}

/// Construct a benchmark model by name, placing parameters on `device`.
pub fn by_name_on(name: &str, device: crate::device::Device) -> Option<Box<dyn BenchModel>> {
    crate::device::with_default_device(device, || by_name(name))
}

/// Construct a benchmark model by Table 1 name.
pub fn by_name(name: &str) -> Option<Box<dyn BenchModel>> {
    match name {
        "alexnet" => Some(Box::new(AlexNet::table1())),
        "vgg19" => Some(Box::new(Vgg19::table1())),
        "resnet50" => Some(Box::new(ResNet50::table1())),
        "mobilenet" => Some(Box::new(MobileNetV1::table1())),
        "gnmt" => Some(Box::new(Gnmt::table1())),
        "ncf" => Some(Box::new(Ncf::table1())),
        _ => None,
    }
}

/// The six Table 1 model names, in the paper's column order.
pub const TABLE1_MODELS: [&str; 6] = ["alexnet", "vgg19", "resnet50", "mobilenet", "gnmt", "ncf"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all_table1_models() {
        crate::rng::manual_seed(0);
        for name in TABLE1_MODELS {
            let m = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!m.parameters().is_empty(), "{name} has params");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn batch_units_match_table1_semantics() {
        let img = image_batch(0, 4, 3, 8, 8, 10);
        assert_eq!(img.units(), 4);
        let s2s = Batch::Seq2Seq(
            Tensor::from_vec(vec![0i64; 2 * 5], &[2, 5]),
            Tensor::from_vec(vec![0i64; 2 * 7], &[2, 7]),
        );
        assert_eq!(s2s.units(), 14, "tokens per step");
    }
}
