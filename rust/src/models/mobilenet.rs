//! MobileNetV1 (Howard et al. 2017): depthwise-separable convolutions, at
//! width/2 on 32×32 inputs. Exercises grouped convolution (groups = C).

use super::{image_batch, image_loss, Batch, BenchModel};
use crate::nn::{BatchNorm2d, Conv2d, GlobalAvgPool, Linear, Module, ReLU, Sequential};
use crate::tensor::Tensor;

/// One depthwise-separable unit: DW 3×3 + BN + ReLU, PW 1×1 + BN + ReLU.
fn separable(net: &mut Sequential, c_in: usize, c_out: usize, stride: usize) {
    net.push(Box::new(Conv2d::with_groups(c_in, c_in, 3, stride, 1, c_in, false)));
    net.push(Box::new(BatchNorm2d::new(c_in)));
    net.push(Box::new(ReLU));
    net.push(Box::new(Conv2d::with_groups(c_in, c_out, 1, 1, 0, 1, false)));
    net.push(Box::new(BatchNorm2d::new(c_out)));
    net.push(Box::new(ReLU));
}

/// MobileNetV1 backbone + classifier.
pub struct MobileNetV1 {
    net: Sequential,
    pub classes: usize,
    pub batch: usize,
    pub input: (usize, usize, usize),
}

impl MobileNetV1 {
    pub fn table1() -> MobileNetV1 {
        MobileNetV1::new(3, 32, 10, 32)
    }

    pub fn new(c_in: usize, hw: usize, classes: usize, batch: usize) -> MobileNetV1 {
        // Original widths /2: 32,64,128,256,512,1024 -> 16,32,64,128,256,512.
        let mut net = Sequential::new();
        net.push(Box::new(Conv2d::with_groups(c_in, 16, 3, 1, 1, 1, false)));
        net.push(Box::new(BatchNorm2d::new(16)));
        net.push(Box::new(ReLU));
        separable(&mut net, 16, 32, 1);
        separable(&mut net, 32, 64, 2); // 16
        separable(&mut net, 64, 64, 1);
        separable(&mut net, 64, 128, 2); // 8
        separable(&mut net, 128, 128, 1);
        separable(&mut net, 128, 256, 2); // 4
        for _ in 0..5 {
            separable(&mut net, 256, 256, 1);
        }
        separable(&mut net, 256, 512, 2); // 2
        separable(&mut net, 512, 512, 1);
        net.push(Box::new(GlobalAvgPool));
        net.push(Box::new(Linear::new(512, classes)));
        MobileNetV1 { net, classes, batch, input: (c_in, hw, hw) }
    }
}

impl Module for MobileNetV1 {
    fn forward(&self, x: &Tensor) -> Tensor {
        self.net.forward(x)
    }
    fn parameters(&self) -> Vec<Tensor> {
        self.net.parameters()
    }
    fn buffers(&self) -> Vec<Tensor> {
        self.net.buffers()
    }
    fn set_training(&mut self, training: bool) {
        self.net.set_training(training);
    }
    fn name(&self) -> &'static str {
        "MobileNetV1"
    }
}

impl BenchModel for MobileNetV1 {
    fn name(&self) -> &'static str {
        "mobilenet"
    }
    fn parameters(&self) -> Vec<Tensor> {
        self.net.parameters()
    }
    fn loss(&self, batch: &Batch) -> Tensor {
        image_loss(&self.net, batch)
    }
    fn make_batch(&self, seed: u64) -> Batch {
        let (c, h, w) = self.input;
        image_batch(seed, self.batch, c, h, w, self.classes)
    }
    fn set_training(&mut self, training: bool) {
        self.net.set_training(training);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_separable_blocks() {
        crate::rng::manual_seed(0);
        let m = MobileNetV1::new(3, 32, 10, 1);
        // DW conv weights have weight.size(1) == 1 (groups == channels).
        let dw = Module::parameters(&m)
            .iter()
            .filter(|p| p.ndim() == 4 && p.size(1) == 1 && p.size(2) == 3)
            .count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn forward_backward() {
        crate::rng::manual_seed(0);
        let m = MobileNetV1::new(3, 32, 10, 1);
        let b = m.make_batch(0);
        let loss = BenchModel::loss(&m, &b);
        assert!(loss.item().is_finite());
        loss.backward();
        assert!(Module::parameters(&m)[0].grad().is_some());
    }
}
