//! Neural Collaborative Filtering (He et al. 2017): GMF + MLP towers over
//! user/item embeddings, fused head, BCE loss. Throughput unit: samples/s.

use super::{Batch, BenchModel};
use crate::nn::{Embedding, Linear, Module};
use crate::ops;
use crate::tensor::Tensor;

/// NeuMF-style NCF.
pub struct Ncf {
    pub user_gmf: Embedding,
    pub item_gmf: Embedding,
    pub user_mlp: Embedding,
    pub item_mlp: Embedding,
    pub mlp1: Linear,
    pub mlp2: Linear,
    pub mlp3: Linear,
    pub head: Linear,
    pub users: usize,
    pub items: usize,
    pub batch: usize,
}

impl Ncf {
    pub fn table1() -> Ncf {
        Ncf::new(16_384, 16_384, 32, 1024)
    }

    pub fn new(users: usize, items: usize, dim: usize, batch: usize) -> Ncf {
        Ncf {
            user_gmf: Embedding::new(users, dim),
            item_gmf: Embedding::new(items, dim),
            user_mlp: Embedding::new(users, dim),
            item_mlp: Embedding::new(items, dim),
            mlp1: Linear::new(2 * dim, 2 * dim),
            mlp2: Linear::new(2 * dim, dim),
            mlp3: Linear::new(dim, dim / 2),
            head: Linear::new(dim + dim / 2, 1),
            users,
            items,
            batch,
        }
    }

    /// Predicted click probability for (user, item) id tensors [N].
    pub fn predict(&self, user: &Tensor, item: &Tensor) -> Tensor {
        let gmf = ops::mul(&self.user_gmf.forward(user), &self.item_gmf.forward(item)); // [N,D]
        let mlp_in = ops::cat(&[&self.user_mlp.forward(user), &self.item_mlp.forward(item)], 1);
        let h = ops::relu(&self.mlp1.forward(&mlp_in));
        let h = ops::relu(&self.mlp2.forward(&h));
        let h = ops::relu(&self.mlp3.forward(&h));
        let fused = ops::cat(&[&gmf, &h], 1);
        ops::sigmoid(&self.head.forward(&fused)) // [N,1]
    }
}

impl BenchModel for Ncf {
    fn name(&self) -> &'static str {
        "ncf"
    }

    fn parameters(&self) -> Vec<Tensor> {
        let mut p = self.user_gmf.parameters();
        p.extend(self.item_gmf.parameters());
        p.extend(self.user_mlp.parameters());
        p.extend(self.item_mlp.parameters());
        p.extend(self.mlp1.parameters());
        p.extend(self.mlp2.parameters());
        p.extend(self.mlp3.parameters());
        p.extend(self.head.parameters());
        p
    }

    fn loss(&self, batch: &Batch) -> Tensor {
        match batch {
            Batch::Interactions(pairs, labels) => {
                let user = pairs.select(1, 0);
                let item = pairs.select(1, 1);
                let pred = self.predict(&user, &item);
                ops::bce_loss(&pred, labels)
            }
            _ => crate::torsk_bail!("ncf expects an interaction batch"),
        }
    }

    fn make_batch(&self, seed: u64) -> Batch {
        let mut r = crate::rng::Rng::new(seed);
        let mut pairs = Vec::with_capacity(self.batch * 2);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let u = r.below(self.users as u64) as i64;
            let i = r.below(self.items as u64) as i64;
            pairs.push(u);
            pairs.push(i);
            let p = if (u + i) % 2 == 0 { 0.8 } else { 0.2 };
            labels.push(if r.bernoulli(p) { 1.0f32 } else { 0.0 });
        }
        Batch::Interactions(
            Tensor::from_vec(pairs, &[self.batch, 2]),
            Tensor::from_vec(labels, &[self.batch, 1]),
        )
    }

    fn set_training(&mut self, _training: bool) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Ncf {
        crate::rng::manual_seed(0);
        Ncf::new(100, 100, 8, 64)
    }

    #[test]
    fn predictions_are_probabilities() {
        let m = tiny();
        let b = m.make_batch(0);
        if let Batch::Interactions(pairs, _) = &b {
            let p = m.predict(&pairs.select(1, 0), &pairs.select(1, 1));
            assert_eq!(p.shape(), &[64, 1]);
            assert!(p.to_vec::<f32>().iter().all(|&v| (0.0..=1.0).contains(&v)));
        } else {
            unreachable!()
        }
    }

    #[test]
    fn loss_near_ln2_at_init() {
        // Init-scale dependent (thread-local RNG stream): just require the
        // untrained loss to sit in the sane BCE range around ln 2.
        let m = tiny();
        let loss = m.loss(&m.make_batch(1)).item();
        assert!(loss.is_finite() && (0.2..2.5).contains(&loss), "loss={loss}");
    }

    #[test]
    fn training_improves_planted_signal() {
        use crate::optim::{Adam, Optimizer};
        let m = tiny();
        let mut opt = Adam::new(m.parameters(), 0.01);
        let l0 = m.loss(&m.make_batch(42)).item();
        for step in 0..30 {
            opt.zero_grad();
            let loss = m.loss(&m.make_batch(step));
            loss.backward();
            opt.step();
        }
        let l1 = m.loss(&m.make_batch(42)).item();
        assert!(l1 < l0, "loss should drop: {l0} -> {l1}");
    }
}
